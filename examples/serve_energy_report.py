"""Serve a small model with batched requests and produce the unary-DLA
energy report — the paper's evaluation applied to a whole LLM serving stack.

For each GEMM backend (uGEMM / tuGEMM / tubGEMM / bGEMM) x bit-width, prices
every projection matmul of a decode step on the calibrated PPA model with the
measured block-max bit sparsity of the actual weights (Eq. 1).

    PYTHONPATH=src python examples/serve_energy_report.py [--arch internlm2-1.8b]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import accounting, sparsity
from repro.launch.mesh import single_device_mesh
from repro.launch.serve import build_workload, generate
from repro.models import model as M


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b",
                    choices=list(configs.ARCH_IDS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--unit-n", type=int, default=128,
                    help="PE array size (128 = CloudTPUv3-like, per Table IV)")
    ap.add_argument("--units", type=int, default=64)
    args = ap.parse_args()

    cfg = configs.get_smoke_config(args.arch)
    mesh = single_device_mesh()
    with mesh:
        params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (args.batch, 16)),
                         jnp.int32)
    t0 = time.time()
    toks = generate(cfg, params, mesh, prompt, args.tokens)
    print(f"served {toks.shape[0]} requests x {toks.shape[1]} tokens "
          f"in {time.time() - t0:.2f}s (CPU simulation)\n")

    print(f"{'bits':>5} {'design':>9} {'wc_uJ/tok':>10} {'dyn_uJ/tok':>11} "
          f"{'dyn_us/tok':>11} {'saving':>7}")
    for bits in (8, 4, 2):
        rec, stats = build_workload(cfg, params, args.batch, 16, bits)
        agg = sparsity.combine_stats(list(stats.values()))
        for design in ("ugemm", "tugemm", "tubgemm", "bgemm"):
            c = accounting.price_workload(rec.calls, design=design, bits=bits,
                                          unit_n=args.unit_n,
                                          num_units=args.units)
            print(f"{bits:>5} {design:>9} {c.wc_energy_uj:10.2f} "
                  f"{c.dyn_energy_uj:11.2f} {c.dyn_latency_us:11.2f} "
                  f"{c.sparsity_saving:6.1%}")
        print(f"      (weight bit-sparsity blockmax @{bits}b: "
              f"{agg.bit_blockmax:.3f})")
    print("\npaper's takeaway, reproduced at model level: tubGEMM is the "
          "energy sweet spot at <=4 bits on large arrays; bGEMM wins at "
          "8 bits; tuGEMM trades enormous latency for minimal area/power.")


if __name__ == "__main__":
    main()
