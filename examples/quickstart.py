"""Quickstart: the paper's four GEMM units, end to end, in five minutes.

1. Simulate all four units on a small integer GEMM (exactness + stochastic error)
2. Price them with the calibrated Nangate45 PPA model (paper Tables I-IV)
3. Profile weight sparsity and apply Eq. 1 (dynamic energy)
4. Run a quantized matmul through the Pallas kernel (TPU target, interpret here)

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import gemm_sims as gs, ppa, sparsity
from repro.core.quantization import quantize, vmax
from repro.kernels import ops

rng = np.random.default_rng(0)
BITS = 4
V = vmax(BITS)

# --- 1. the four units on one GEMM -----------------------------------------
a = jnp.asarray(rng.integers(-V, V + 1, (16, 32)), jnp.int8)
b = jnp.asarray(rng.integers(-V, V + 1, (32, 16)), jnp.int8)
oracle = gs.bgemm_exact(a, b)

tu, tu_cyc = gs.tugemm_stream(a, b, BITS)
tub, tub_cyc = gs.tubgemm_stream(a, b, BITS)
u, u_cyc = gs.ugemm_stream(a, b, BITS)
print(f"{BITS}-bit 16x16x32 GEMM:")
print(f"  tuGEMM : bit-exact={bool(jnp.all(tu == oracle))}   cycles={tu_cyc}")
print(f"  tubGEMM: bit-exact={bool(jnp.all(tub == oracle))}   cycles={tub_cyc}")
rel = float(jnp.sqrt(jnp.mean((u - oracle) ** 2)) /
            jnp.sqrt(jnp.mean(oracle.astype(jnp.float32) ** 2)))
print(f"  uGEMM  : stochastic rel-RMSE={rel:.3f}  cycles={u_cyc}")
print(f"  bGEMM  : the oracle                 cycles={gs.wc_cycles('bgemm', BITS, 32)}")

# --- 2. PPA (paper Tables I-IV, calibrated) ---------------------------------
print(f"\n{BITS}-bit 32x32 unit PPA (Nangate45 @400MHz):")
print(f"{'design':>9} {'area um2':>12} {'power mW':>10} {'energy nJ':>10} {'ADP':>8}")
for d in gs.DESIGNS:
    print(f"{d:>9} {ppa.area_um2(d, BITS, 32):12.0f} "
          f"{ppa.power_mw(d, BITS, 32):10.1f} "
          f"{ppa.energy_nj(d, BITS, 32):10.2f} "
          f"{ppa.adp_mm2_ns(d, BITS, 32):8.1f}")

# --- 3. sparsity -> Eq. 1 dynamic energy ------------------------------------
w = rng.normal(0, 0.02, (512, 512)).astype(np.float32)
st = sparsity.profile_tensor(jnp.asarray(w), bits=BITS)
print(f"\nweight profile @{BITS}-bit: word={st.word:.3f} "
      f"bit(blockmax)={st.bit_blockmax:.3f}")
for d in ("tubgemm", "bgemm"):
    wc = ppa.energy_nj(d, BITS, 32)
    dyn = ppa.dynamic_energy_nj(d, BITS, 32, st.bit_blockmax)
    print(f"  {d}: worst-case {wc:.2f} nJ -> dynamic {dyn:.2f} nJ "
          f"({1 - dyn / wc:.0%} saved)" if wc != dyn else
          f"  {d}: {wc:.2f} nJ (no sparsity benefit — not temporal)")

# --- 4. the Pallas kernel (TPU-target; interpret mode on CPU) ----------------
x = jnp.asarray(rng.normal(0, 1, (64, 256)), jnp.float32)
wq = quantize(jnp.asarray(rng.normal(0, 0.05, (256, 128)), jnp.float32),
              bits=BITS)
out = ops.quantized_matmul(x, wq)
ref = x @ wq.dequantize()
err = float(jnp.max(jnp.abs(out - ref)) / jnp.max(jnp.abs(ref)))
print(f"\nPallas packed-int{BITS} matmul vs dequant reference: rel err {err:.4f}")
print("done.")
