"""Example 4: drive the multi-pod dry-run programmatically for one cell and
pretty-print the roofline terms (what `repro.launch.dryrun --all` does for
every cell).

NOTE: must run in a fresh process (sets XLA_FLAGS before jax init).

    PYTHONPATH=src python examples/multipod_dryrun.py --arch llama3-8b \
        --shape decode_32k --multi-pod
"""

import argparse
import json


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    # import AFTER parsing so --help doesn't spin up 512 devices
    from repro.launch.dryrun import run_cell

    rec = run_cell(args.arch, args.shape, args.multi_pod, out_dir=None)
    rl = rec.pop("roofline")
    print(json.dumps(rec, indent=1, default=str)[:1200])
    print("\nroofline terms (per chip):")
    print(f"  compute    {rl['compute_s']:.3e} s")
    print(f"  memory     {rl['memory_s']:.3e} s")
    print(f"  collective {rl['collective_s']:.3e} s")
    print(f"  dominant   {rl['dominant']}")
    print(f"  useful-FLOPs ratio {rl['useful_flops_ratio']:.2f}")


if __name__ == "__main__":
    main()
