"""End-to-end driver: train a reduced llama3-family model for a few hundred
steps on CPU with the full production loop (checkpointing, auto-resume,
straggler watchdog, retries), then report the loss curve.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--arch llama3-8b]
"""

import argparse
import logging
import tempfile

from repro import configs
from repro.launch.mesh import single_device_mesh
from repro.launch.train import TrainLoopConfig, train


def main():
    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=list(configs.ARCH_IDS))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = configs.get_smoke_config(args.arch)
    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_train_")
    loop = TrainLoopConfig(steps=args.steps, batch=args.batch, seq=args.seq,
                           lr=1e-3, warmup=30, ckpt_dir=ckpt, ckpt_every=100,
                           log_every=20)
    state, history, watchdog = train(cfg, single_device_mesh(), loop)
    first, last = history[0][1]["loss"], history[-1][1]["loss"]
    print(f"\n{args.arch} (reduced config, {args.steps} steps): "
          f"loss {first:.3f} -> {last:.3f}")
    print(f"checkpoints in {ckpt} (re-run with --ckpt-dir {ckpt} to resume)")
    assert last < first, "loss did not decrease"


if __name__ == "__main__":
    main()
