"""Docs check: every code reference in docs/PAPER_MAP.md must resolve.

Two reference syntaxes inside backticks:

* dotted names (``repro.core.ppa.AREA_UM2``, ``benchmarks.tables.table1_area``)
  — the longest importable module prefix is imported and the remainder is
  resolved with ``getattr`` (class attributes/methods included);
* file paths (``src/repro/launch/serve.py``, optionally with a
  ``::Fragment`` suffix, e.g. ``tests/test_ppa_model.py::test_fig2_slopes``)
  — the file must exist and contain the fragment text.

Backticked tokens that are neither (formulae, CLI flags, metric labels) are
ignored.  It also enforces *coverage*: Tables I–V and Figs. 2–3 must each
have a section.

Usage: ``PYTHONPATH=src python tools/check_paper_map.py [repo_root]``
Exit status 0 iff everything resolves (this is the CI docs gate, and
``tests/test_docs.py`` runs the same checker in tier-1).
"""

from __future__ import annotations

import importlib
import pathlib
import re
import sys

CODE_RE = re.compile(r"`([^`\n]+)`")
DOTTED_RE = re.compile(r"^(repro|benchmarks|tools|examples)(\.\w+)+$")
REQUIRED_SECTIONS = ("Table I ", "Table II ", "Table III ", "Table IV ",
                     "Table V ", "Fig. 2 ", "Fig. 3 ", "Eq. 1 ")


def _check_dotted(token: str) -> str | None:
    """Import the longest module prefix, getattr the rest; None if it resolves."""
    parts = token.split(".")
    mod, idx = None, 0
    for i in range(len(parts), 0, -1):
        try:
            mod = importlib.import_module(".".join(parts[:i]))
            idx = i
            break
        except ImportError:
            continue
    if mod is None:
        return f"{token}: no importable module prefix"
    obj = mod
    for attr in parts[idx:]:
        try:
            obj = getattr(obj, attr)
        except AttributeError:
            return f"{token}: {type(obj).__name__} has no attribute {attr!r}"
    return None


def _check_path(root: pathlib.Path, token: str) -> str | None:
    path_part, _, frag = token.partition("::")
    p = root / path_part
    if not p.is_file():
        return f"{token}: file {path_part} does not exist"
    if frag and frag not in p.read_text():
        return f"{token}: {frag!r} not found in {path_part}"
    return None


def check(root: pathlib.Path) -> list[str]:
    """Return a list of human-readable problems (empty = docs check passes)."""
    map_path = root / "docs" / "PAPER_MAP.md"
    if not map_path.is_file():
        return ["docs/PAPER_MAP.md is missing"]
    text = map_path.read_text()

    errors = [f"PAPER_MAP.md: no section for {sec.strip()!r}"
              for sec in REQUIRED_SECTIONS if sec not in text]
    checked = 0
    for token in CODE_RE.findall(text):
        token = token.strip()
        if "/" in token and ".py" in token and " " not in token:
            err = _check_path(root, token)
        elif DOTTED_RE.match(token):
            err = _check_dotted(token)
        else:
            continue  # formula / CLI flag / prose in backticks
        checked += 1
        if err:
            errors.append(err)
    if checked < 20:
        errors.append(f"PAPER_MAP.md: only {checked} checkable code references "
                      "found — map looks gutted")
    return errors


def main(argv: list[str]) -> int:
    root = pathlib.Path(argv[1]) if len(argv) > 1 else \
        pathlib.Path(__file__).resolve().parent.parent
    sys.path.insert(0, str(root))          # benchmarks/, tools/ packages
    sys.path.insert(0, str(root / "src"))  # repro package
    errors = check(root)
    for e in errors:
        print(f"PAPER_MAP check FAILED: {e}")
    if not errors:
        print("PAPER_MAP check OK: all code references resolve")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
