"""Quantization + sparsity profiling (Table V machinery)."""

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # CI image has no hypothesis; use the local shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import sparsity
from repro.core.quantization import fake_quant, quantize, vmax


class TestQuantization:
    @pytest.mark.parametrize("bits", [2, 4, 8])
    def test_range(self, rng, bits):
        x = jnp.asarray(rng.normal(0, 3, (32, 16)), jnp.float32)
        q = quantize(x, bits=bits)
        v = vmax(bits)
        assert int(jnp.max(q.values)) <= v and int(jnp.min(q.values)) >= -v

    @pytest.mark.parametrize("bits", [4, 8])
    def test_roundtrip_error_bounded(self, rng, bits):
        x = jnp.asarray(rng.normal(0, 1, (64, 64)), jnp.float32)
        err = jnp.max(jnp.abs(fake_quant(x, bits=bits) - x))
        # per-channel absmax: max error <= scale/2 = absmax/(2 Vmax)
        bound = float(jnp.max(jnp.abs(x))) / (2 * vmax(bits)) * 1.001
        assert float(err) <= bound

    @given(bits=st.sampled_from([2, 4, 8]), seed=st.integers(0, 10_000),
           scale=st.floats(1e-3, 1e3))
    @settings(max_examples=30, deadline=None)
    def test_property_scale_invariance(self, bits, seed, scale):
        r = np.random.default_rng(seed)
        x = jnp.asarray(r.normal(0, 1, (8, 8)), jnp.float32)
        q1 = quantize(x, bits=bits).values
        q2 = quantize(x * scale, bits=bits).values
        assert bool(jnp.all(q1 == q2))   # symmetric absmax is scale-invariant

    def test_zero_channel_safe(self):
        x = jnp.zeros((4, 4), jnp.float32)
        q = quantize(x, bits=8)
        assert bool(jnp.all(q.values == 0))
        assert bool(jnp.all(jnp.isfinite(q.scale)))


class TestSparsity:
    def test_word_sparsity_exact(self):
        q = jnp.asarray([[0, 1, 0, 2], [0, 0, 3, -1]], jnp.int8)
        assert float(sparsity.word_sparsity(q)) == pytest.approx(4 / 8)

    def test_bit_sparsity_blockmax_constant(self):
        # all values at magnitude Vmax -> the stream-length floor
        # 1 - Vmax/2^(w-1) (= the paper's Table V LLM values: 0.78% @ 8-bit)
        q = jnp.full((64, 64), vmax(8), jnp.int8)
        assert float(sparsity.bit_sparsity_blockmax(q, 8)) == \
            pytest.approx(1.0 - vmax(8) / 2 ** 7)
        # all zeros -> full sparsity
        q = jnp.zeros((64, 64), jnp.int8)
        assert float(sparsity.bit_sparsity_blockmax(q, 8)) == pytest.approx(1.0)

    def test_blockmax_below_elementwise(self, rng):
        """Block-max sparsity (paper's latency-relevant stat) is a lower
        bound on element-wise bit sparsity."""
        x = jnp.asarray(rng.normal(0, 1, (128, 128)), jnp.float32)
        st_ = sparsity.profile_tensor(x, bits=8)
        assert st_.bit_blockmax <= st_.bit_elem + 1e-6

    def test_bit_subsumes_word(self, rng):
        """Paper: 'bit sparsity subsumes word sparsity' (elementwise)."""
        x = np.asarray(rng.normal(0, 1, (64, 64)), np.float32)
        x[rng.random(x.shape) < 0.3] = 0.0
        st_ = sparsity.profile_tensor(jnp.asarray(x), bits=8)
        assert st_.bit_elem >= st_.word - 1e-6

    def test_outlier_structure_raises_block_sparsity(self, rng):
        """Per-tensor quant + outlier rows -> most blocks far from Vmax."""
        x = np.asarray(rng.normal(0, 0.02, (256, 256)), np.float32)
        x[:32] *= 50.0   # outlier region pins the global scale
        st_ = sparsity.profile_tensor(jnp.asarray(x), bits=8)
        assert st_.bit_blockmax > 0.5

    def test_combine_stats_weighting(self):
        a = sparsity.SparsityStats(8, word=0.0, bit_elem=0.0, bit_blockmax=0.0,
                                   numel=100)
        b = sparsity.SparsityStats(8, word=1.0, bit_elem=1.0, bit_blockmax=1.0,
                                   numel=300)
        c = sparsity.combine_stats([a, b])
        assert c.word == pytest.approx(0.75)
        assert c.numel == 400

    def test_profile_tree_skips_vectors(self, rng):
        params = {"w": jnp.asarray(rng.normal(0, 1, (16, 16)), jnp.float32),
                  "b": jnp.zeros((16,), jnp.float32)}
        out = sparsity.profile_tree(params, bits=8)
        assert list(out) == ["w"]

    @given(seed=st.integers(0, 10_000), bits=st.sampled_from([2, 4, 8]))
    @settings(max_examples=20, deadline=None)
    def test_property_stats_in_unit_interval(self, seed, bits):
        r = np.random.default_rng(seed)
        x = jnp.asarray(r.normal(0, 1, (40, 40)), jnp.float32)
        st_ = sparsity.profile_tensor(x, bits=bits)
        for f in (st_.word, st_.bit_elem, st_.bit_blockmax):
            assert -1e-6 <= f <= 1.0 + 1e-6
