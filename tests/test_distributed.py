"""Distribution layer on the single real CPU device: steps build/run under a
trivial mesh, sharding trees are well-formed, HLO cost analysis is exact."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax

from repro import configs
from repro.launch import hlo_cost, steps as steps_lib
from repro.launch.mesh import single_device_mesh
from repro.models import model as M
from repro.optim import AdamWConfig

import conftest

# The persistent compilation cache segfaults on this jax/CPU build when the
# train/serve loop reloads donated step executables (see tests/conftest.py);
# run this module with the cache off.
_no_xla_cache = pytest.fixture(autouse=True, scope="module")(
    conftest.disable_compilation_cache)


@pytest.fixture(scope="module")
def mesh():
    return single_device_mesh()


class TestSteps:
    def test_train_step_runs_and_descends(self, mesh, rng):
        cfg = configs.get_smoke_config("internlm2-1.8b")
        opt_cfg = AdamWConfig(lr=1e-3)
        with mesh:
            step = steps_lib.make_train_step(cfg, mesh, opt_cfg, donate=False)
            state = steps_lib.init_train_state(cfg, opt_cfg, jax.random.PRNGKey(0))
            batch = {
                "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)),
                                      jnp.int32),
                "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)),
                                       jnp.int32)}
            losses = []
            for _ in range(5):
                state, metrics = step(state, batch)
                losses.append(float(metrics["loss"]))
            assert losses[-1] < losses[0]
            assert int(state.step) == 5

    def test_prefill_decode_steps(self, mesh, rng):
        cfg = configs.get_smoke_config("llama3-8b")
        with mesh:
            params = M.init_params(cfg, jax.random.PRNGKey(0))
            pstep = steps_lib.make_prefill_step(cfg, mesh)
            dstep = steps_lib.make_decode_step(cfg, mesh)
            toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8)), jnp.int32)
            caches = M.init_caches(cfg, 2, 16, dtype=jnp.bfloat16)
            logits, caches = pstep(params, {"tokens": toks}, caches)
            assert logits.shape == (2, 8, cfg.vocab_size)
            dlog, caches = dstep(params, toks[:, -1:], caches, jnp.int32(8))
            assert dlog.shape == (2, 1, cfg.vocab_size)
            assert not bool(jnp.any(jnp.isnan(dlog)))

    def test_input_specs_cover_all_cells(self):
        for arch, shape in configs.cells():
            cfg = configs.get_config(arch)
            ins = steps_lib.input_specs(cfg, shape)
            sh = configs.SHAPES[shape]
            if sh["step"] == "decode":
                assert ins["tokens"].shape == (sh["global_batch"], 1)
            else:
                key = "embeds" if cfg.frontend_stub else "tokens"
                assert ins[key].shape[:2] == (sh["global_batch"], sh["seq_len"])

    def test_pspec_trees_match_param_trees(self, mesh):
        for arch in ("llama3-8b", "deepseek-v3-671b", "zamba2-1.2b", "rwkv6-3b"):
            cfg = configs.get_smoke_config(arch)
            params = jax.eval_shape(
                lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
            specs = M.param_pspecs(cfg, mesh)
            jax.tree_util.tree_map(lambda a, b: None, params, specs)  # same treedef


class TestHloCost:
    def test_scan_vs_unroll_flops_identical(self):
        def f_scan(x, w):
            return lax.scan(lambda c, _: (jnp.tanh(c @ w), None), x, None,
                            length=24)[0]

        def f_unroll(x, w):
            c = x
            for _ in range(24):
                c = jnp.tanh(c @ w)
            return c

        x = jax.ShapeDtypeStruct((8, 128), jnp.float32)
        w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        expected = 2 * 8 * 128 * 128 * 24
        for f in (f_scan, f_unroll):
            c = hlo_cost.analyze(jax.jit(f).lower(x, w).compile().as_text())
            assert c.flops == expected

    def test_nested_scan(self):
        def g(x, w):
            def outer(c, _):
                inner = lax.scan(lambda ci, _: (ci @ w, None), c, None, length=4)
                return inner[0], None
            return lax.scan(outer, x, None, length=6)[0]

        x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
        w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        c = hlo_cost.analyze(jax.jit(g).lower(x, w).compile().as_text())
        assert c.flops == 2 * 8 * 64 * 64 * 24

    def test_bytes_amortize_loop_invariant_buffers(self):
        """A scan slicing a stacked weight must charge ~the stack once, not
        stack x trips."""
        L, D = 16, 256

        def f(x, ws):
            return lax.scan(lambda c, w: (jnp.tanh(c @ w), None), x, ws)[0]

        x = jax.ShapeDtypeStruct((4, D), jnp.float32)
        ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
        c = hlo_cost.analyze(jax.jit(f).lower(x, ws).compile().as_text())
        stack_bytes = L * D * D * 4
        assert c.bytes_accessed < 6 * stack_bytes  # would be ~L x with the bug

    def test_roofline_terms(self):
        from repro.launch.hlo_stats import CollectiveStats, roofline
        coll = CollectiveStats(total_bytes=1e9, by_op={}, counts={})
        t = roofline({"flops": 197e12, "bytes accessed": 819e9}, coll,
                     chips=256, model_flops=197e12 * 256 * 0.5)
        assert t.compute_s == pytest.approx(1.0)
        assert t.memory_s == pytest.approx(1.0)
        assert t.collective_s == pytest.approx(1e9 / 50e9)
        assert t.dominant in ("compute", "memory")
        assert t.useful_flops_ratio == pytest.approx(0.5)


class TestGradCompression:
    def test_error_feedback_reduces_bias(self, rng):
        from repro.optim.compression import compress_with_error_feedback
        g = {"w": jnp.asarray(rng.normal(0, 1e-3, (64, 64)), jnp.float32)}
        ef = {"w": jnp.zeros((64, 64), jnp.float32)}
        total = jnp.zeros((64, 64), jnp.float32)
        for _ in range(8):
            out, ef = compress_with_error_feedback(g, ef)
            total = total + out["w"]
        # accumulated compressed grads ~ accumulated true grads; the residual
        # is bounded by ONE quantization step (amax/127), not zero
        step = float(jnp.max(jnp.abs(g["w"]))) / 127.0
        np.testing.assert_allclose(np.asarray(total), np.asarray(8 * g["w"]),
                                   rtol=0.05, atol=2 * step)

    def test_int8_psum_single_device(self, mesh, rng):
        from repro.optim.compression import int8_psum
        g = {"w": jnp.asarray(rng.normal(0, 1, (32, 32)), jnp.float32)}
        with mesh:
            out = int8_psum(g, mesh, axis="data")
        np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]),
                                   rtol=0.02, atol=0.02)

    def test_compressed_train_step(self, mesh, rng):
        cfg = configs.get_smoke_config("phi3-mini-3.8b")
        opt_cfg = AdamWConfig(lr=1e-3, compress_grads=True)
        with mesh:
            step = steps_lib.make_train_step(cfg, mesh, opt_cfg, donate=False)
            state = steps_lib.init_train_state(cfg, opt_cfg, jax.random.PRNGKey(0))
            batch = {
                "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)),
                                      jnp.int32),
                "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)),
                                       jnp.int32)}
            l0 = None
            for _ in range(5):
                state, metrics = step(state, batch)
                l0 = float(metrics["loss"]) if l0 is None else l0
            assert float(metrics["loss"]) < l0
