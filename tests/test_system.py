"""End-to-end system behaviour: the paper's technique wired through the
full stack (quantized serving with DLA energy accounting, uGEMM accuracy
claim, workload pricing against the paper's findings)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import accounting, gemm_sims as gs
from repro.launch.mesh import single_device_mesh
from repro.models import model as M

import conftest

# The persistent compilation cache segfaults on this jax/CPU build when the
# train/serve loop reloads donated step executables (see tests/conftest.py);
# run this module with the cache off.
_no_xla_cache = pytest.fixture(autouse=True, scope="module")(
    conftest.disable_compilation_cache)


class TestQuantizedExecution:
    def test_quant_kernel_inference_close_to_float(self, rng):
        """Running a smoke model through the Pallas int8 path ~ float path."""
        cfg = configs.get_smoke_config("phi3-mini-3.8b").replace(
            compute_dtype="float32")
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8)), jnp.int32)
        ref_logits, _ = M.forward(params, cfg, toks)
        qcfg = cfg.replace(quant_bits=8, quant_kernel=True,
                           quant_backend="tubgemm")
        q_logits, _ = M.forward(params, qcfg, toks)
        agree = float(jnp.mean((jnp.argmax(ref_logits, -1) ==
                                jnp.argmax(q_logits, -1)).astype(jnp.float32)))
        assert agree > 0.7, f"top-1 agreement {agree}"

    def test_exact_designs_identical_outputs(self, rng):
        """tuGEMM / tubGEMM / bGEMM backends are numerically identical."""
        cfg = configs.get_smoke_config("internlm2-1.8b").replace(
            compute_dtype="float32")
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 8)), jnp.int32)
        outs = {}
        for backend in ("tubgemm", "tugemm", "bgemm"):
            qcfg = cfg.replace(quant_bits=8, quant_kernel=True,
                               quant_backend=backend)
            out, _ = M.forward(params, qcfg, toks)
            outs[backend] = np.asarray(out)
        np.testing.assert_array_equal(outs["tubgemm"], outs["tugemm"])
        np.testing.assert_array_equal(outs["tubgemm"], outs["bgemm"])


class TestUGEMMAccuracyClaim:
    def test_model_level_accuracy_drop(self, rng):
        """Paper §V: quantized-model accuracy drops under uGEMM's stochastic
        compute (96.08 -> 94.7 on their MLP) but stays usable; measured here
        as top-1 logits agreement vs the exact INT8 path."""
        cfg = configs.get_smoke_config("internlm2-1.8b").replace(
            compute_dtype="float32")
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8)), jnp.int32)
        ref, _ = M.forward(params, cfg.replace(quant_bits=8, quant_kernel=True,
                                               quant_backend="bgemm"), toks)
        uout, _ = M.forward(params, cfg.replace(quant_bits=8, quant_kernel=True,
                                                quant_backend="ugemm"), toks)
        agree = float(jnp.mean((jnp.argmax(ref, -1) ==
                                jnp.argmax(uout, -1)).astype(jnp.float32)))
        assert 0.5 < agree <= 1.0


class TestEndToEndEnergyAccounting:
    def test_serving_cost_report(self, rng):
        """Full-model DLA pricing reproduces the paper's ordering."""
        from repro.launch.serve import build_workload
        cfg = configs.get_smoke_config("llama3-8b")
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        rec, stats = build_workload(cfg, params, batch=4, ctx_len=16, bits=4)
        assert rec.calls and all(0 <= c.bit_sparsity <= 1 for c in rec.calls)
        costs = {d: accounting.price_workload(rec.calls, design=d, bits=4,
                                              unit_n=128, num_units=16)
                 for d in gs.DESIGNS}
        # Table IV at 128x128/4-bit: tubGEMM beats bGEMM on energy;
        # tuGEMM pays enormous latency; only temporal designs see Eq.1 savings
        assert costs["tubgemm"].wc_energy_uj < costs["bgemm"].wc_energy_uj
        assert costs["tugemm"].dyn_latency_us > \
            10 * costs["tubgemm"].dyn_latency_us
        assert costs["tubgemm"].sparsity_saving >= 0
        assert costs["bgemm"].sparsity_saving == pytest.approx(0.0)

    def test_generate_runs(self, rng):
        from repro.launch.serve import generate
        cfg = configs.get_smoke_config("internlm2-1.8b")
        mesh = single_device_mesh()
        with mesh:
            params = M.init_params(cfg, jax.random.PRNGKey(0))
        prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8)), jnp.int32)
        toks = generate(cfg, params, mesh, prompt, max_new=6)
        assert toks.shape == (2, 6)
        assert int(jnp.max(toks)) < cfg.vocab_size


class TestBackendExecution:
    """serve --execute-backend: the model actually runs on the typed backend."""

    def test_serve_execute_backend_end_to_end(self, rng):
        from repro import backends
        from repro.launch import serve
        cfg = configs.get_smoke_config("llama3-8b")
        mesh = single_device_mesh()
        with mesh:
            params = M.init_params(cfg, jax.random.PRNGKey(0))
        prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8)), jnp.int32)
        backend = backends.resolve("tubgemm", bits=4)
        result = serve.run_backend_execution(
            cfg, params, mesh, prompt, backend, 4, unit_n=128, num_units=64)
        assert result["tokens"].shape == (2, 4)
        assert int(jnp.max(result["tokens"])) < cfg.vocab_size
        assert result["sites"] > 0                    # dense layers contracted
        assert result["rel_rmse"] == 0.0              # int GEMMs == oracle
        assert 0.0 <= result["top1_agreement"] <= 1.0
        cyc = result["cycles"]
        assert cyc["dyn_floor"] - 0.5 <= cyc["measured"] <= cyc["wc"] + 0.5
        # nothing leaked: later code sees the float path again
        assert backends.active_backend() is None


class TestPaperSweepConfig:
    def test_grids(self):
        from repro.configs import paper_gemm
        grid = paper_gemm.table_grid()
        assert len(grid) == 3 * 2 * 4       # bits x sizes x designs
        tpu = paper_gemm.tpu_grid()
        assert {c.n for c in tpu} == {64, 128}
        assert all(c.bits == 4 for c in tpu)
