"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # CI image has no hypothesis; use the local shim
    from _hypothesis_fallback import given, settings, strategies as st

import conftest
from repro.core import sparsity
from repro.core.quantization import quantize, vmax
from repro.kernels import ops, ref

# TestKernelBackends registers the *_pallas mirrors; don't leak them to
# later modules that iterate the live gemm_sims.DESIGNS
_registry = pytest.fixture(autouse=True, scope="module")(
    conftest.restore_design_registry)


def rand_codes(rng, bits, shape):
    v = vmax(bits)
    return jnp.asarray(rng.integers(-v, v + 1, shape), jnp.int8)


class TestPacking:
    @pytest.mark.parametrize("bits", [8, 4, 2])
    @pytest.mark.parametrize("shape,axis", [((64, 48), 0), ((32, 64), 1),
                                            ((8, 16, 24), 1)])
    def test_roundtrip(self, rng, bits, shape, axis):
        q = rand_codes(rng, bits, shape)
        packed = ops.pack_values(q, bits, axis=axis)
        pack = 8 // bits
        assert packed.shape[axis] == shape[axis] // pack
        out = ref.unpack_values_ref(packed, bits, axis=axis)
        assert bool(jnp.all(out == q))

    def test_kernel_unpack_matches_ref(self, rng):
        from repro.kernels.quant_gemm import unpack_values
        for bits in (4, 2):
            q = rand_codes(rng, bits, (32, 16))
            packed = ops.pack_values(q, bits, axis=0)
            assert bool(jnp.all(unpack_values(packed, bits, axis=0) ==
                                ref.unpack_values_ref(packed, bits, axis=0)))


class TestQuantGemmKernel:
    @pytest.mark.parametrize("bits", [8, 4, 2])
    @pytest.mark.parametrize("mkn", [(4, 8, 12), (130, 260, 70), (1, 512, 128),
                                     (128, 128, 128), (37, 64, 200)])
    def test_matches_ref_int(self, rng, bits, mkn):
        m, k, n = mkn
        pack = 8 // bits
        k += (-k) % pack
        x = jnp.asarray(rng.integers(-127, 128, (m, k)), jnp.int8)
        w = rand_codes(rng, bits, (k, n))
        wp = ops.pack_values(w, bits, axis=0)
        got = ops.int_matmul(x, wp, bits=bits, interpret=True)
        want = ref.quant_gemm_ref(x, wp, bits=bits)
        assert bool(jnp.all(got == want))

    @pytest.mark.parametrize("block", [(128, 128, 128), (64, 64, 64),
                                       (32, 128, 64)])
    def test_block_shapes(self, rng, block):
        x = jnp.asarray(rng.integers(-127, 128, (96, 192)), jnp.int8)
        w = rand_codes(rng, 8, (192, 96))
        wp = ops.pack_values(w, 8, axis=0)
        got = ops.int_matmul(x, wp, bits=8, block=block, interpret=True)
        assert bool(jnp.all(got == ref.quant_gemm_ref(x, wp, bits=8)))

    def test_fused_dequant_epilogue(self, rng):
        x = jnp.asarray(rng.normal(0, 1, (33, 64)), jnp.float32)
        w = jnp.asarray(rng.normal(0, 0.1, (64, 40)), jnp.float32)
        wq = quantize(w, bits=8)
        got = ops.quantized_matmul(x, wq, interpret=True)
        rel = float(jnp.max(jnp.abs(got - x @ w)) / jnp.max(jnp.abs(x @ w)))
        assert rel < 0.05

    @pytest.mark.parametrize("bits", [4, 2])
    def test_low_bit_end_to_end(self, rng, bits):
        x = jnp.asarray(rng.normal(0, 1, (16, 128)), jnp.float32)
        w = jnp.asarray(rng.normal(0, 0.1, (128, 32)), jnp.float32)
        wq = quantize(w, bits=bits)
        got = ops.quantized_matmul(x, wq, interpret=True)
        # w-bit weights: coarse but correlated
        ref_out = x @ wq.dequantize()
        rel = float(jnp.sqrt(jnp.mean((got - ref_out) ** 2)) /
                    jnp.sqrt(jnp.mean(ref_out ** 2)))
        assert rel < 0.25

    @given(m=st.integers(1, 64), k=st.integers(1, 64), n=st.integers(1, 64),
           seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=15, deadline=None)
    def test_property_int8_kernel_exact(self, m, k, n, seed):
        r = np.random.default_rng(seed)
        x = jnp.asarray(r.integers(-127, 128, (m, k)), jnp.int8)
        w = jnp.asarray(r.integers(-127, 128, (k, n)), jnp.int8)
        got = ops.int_matmul(x, w, bits=8, block=(32, 32, 32), interpret=True)
        want = jnp.matmul(x.astype(jnp.int32), w.astype(jnp.int32))
        assert bool(jnp.all(got == want))


class TestUnaryTubGemmKernel:
    """tubGEMM 2-unary slot-loop kernel: bit-identical to binary GEMM."""

    @pytest.mark.parametrize("bits", [2, 4, 8])
    @pytest.mark.parametrize("mkn", [(4, 8, 12), (37, 64, 100), (1, 130, 70),
                                     (128, 128, 128)])
    def test_matches_ref_and_oracle(self, rng, bits, mkn):
        from repro.core import gemm_sims as gs
        m, k, n = mkn
        a = rand_codes(rng, bits, (m, k))
        b = jnp.asarray(rng.integers(-127, 128, (k, n)), jnp.int8)
        got, cycles = ops.tub_matmul(a, b, bits=bits, block=(64, 64, 64),
                                     interpret=True)
        assert bool(jnp.all(got == ref.tub_gemm_ref(a, b, bits=bits)))
        assert bool(jnp.all(got == gs.bgemm_exact(a, b)))
        assert int(cycles) == k * max(1, 2 ** (bits - 2))

    @pytest.mark.parametrize("block", [(128, 128, 128), (32, 128, 64)])
    def test_block_shapes(self, rng, block):
        from repro.core import gemm_sims as gs
        a = rand_codes(rng, 8, (96, 192))
        b = jnp.asarray(rng.integers(-127, 128, (192, 48)), jnp.int8)
        got, _ = ops.tub_matmul(a, b, bits=8, block=block, interpret=True)
        assert bool(jnp.all(got == gs.bgemm_exact(a, b)))

    def test_agrees_with_stream_simulator(self, rng):
        """Kernel and slot-parallel stream sim: same output, same cycles."""
        from repro.core import gemm_sims as gs
        a, b = rand_codes(rng, 4, (8, 16)), rand_codes(rng, 4, (16, 8))
        k_out, k_cyc = ops.tub_matmul(a, b, bits=4, block=(32, 32, 32),
                                      interpret=True)
        s_out, s_cyc = gs.tubgemm_stream(a, b, 4)
        assert bool(jnp.all(k_out == s_out))
        assert int(k_cyc) == int(s_cyc)

    def test_rejects_non_int8(self, rng):
        from repro.kernels.unary_gemm import tub_gemm
        a = jnp.ones((4, 4), jnp.int32)
        b = jnp.ones((4, 4), jnp.int8)
        with pytest.raises(TypeError, match="int8"):
            tub_gemm(a, b, bits=4, interpret=True)


class TestUnaryTuGemmKernel:
    """tuGEMM temporal slot-loop kernel: bit-identical to binary GEMM."""

    @pytest.mark.parametrize("bits", [2, 3, 4, 8])
    @pytest.mark.parametrize("mkn", [(4, 8, 12), (37, 64, 100), (1, 130, 70)])
    def test_matches_ref_and_oracle(self, rng, bits, mkn):
        from repro.core import gemm_sims as gs
        m, k, n = mkn
        a = rand_codes(rng, bits, (m, k))
        b = jnp.asarray(rng.integers(-127, 128, (k, n)), jnp.int8)
        got, cycles = ops.tu_matmul(a, b, bits=bits, block=(64, 64, 64),
                                    interpret=True)
        assert bool(jnp.all(got == ref.tu_gemm_ref(a, b, bits=bits)))
        assert bool(jnp.all(got == gs.tugemm_exact(a, b)))
        assert int(cycles) == gs.wc_cycles("tugemm", bits, k)

    @pytest.mark.parametrize("block", [(128, 128, 128), (32, 128, 64)])
    def test_block_shapes(self, rng, block):
        from repro.core import gemm_sims as gs
        a = rand_codes(rng, 4, (96, 192))
        b = jnp.asarray(rng.integers(-127, 128, (192, 48)), jnp.int8)
        got, _ = ops.tu_matmul(a, b, bits=4, block=block, interpret=True)
        assert bool(jnp.all(got == gs.bgemm_exact(a, b)))

    def test_agrees_with_stream_simulator(self, rng):
        """Kernel and slot-parallel stream sim: same output, same cycles."""
        from repro.core import gemm_sims as gs
        a, b = rand_codes(rng, 4, (8, 16)), rand_codes(rng, 4, (16, 8))
        k_out, k_cyc = ops.tu_matmul(a, b, bits=4, block=(32, 32, 32),
                                     interpret=True)
        s_out, s_cyc = gs.tugemm_stream(a, b, 4)
        assert bool(jnp.all(k_out == s_out))
        assert int(k_cyc) == int(s_cyc)

    def test_rejects_non_int8(self, rng):
        from repro.kernels.unary_gemm import tu_gemm
        a = jnp.ones((4, 4), jnp.int32)
        b = jnp.ones((4, 4), jnp.int8)
        with pytest.raises(TypeError, match="int8"):
            tu_gemm(a, b, bits=4, interpret=True)


class TestKernelBackends:
    """Pallas kernels registered as dispatchable designs in the registry."""

    def test_registration_and_dispatch(self, rng):
        from repro.core import gemm_sims as gs
        from repro.kernels import backends
        names = backends.register_kernel_backends(block=(32, 32, 32),
                                                  interpret=True)
        assert set(names) <= set(gs.DESIGNS)
        a, b = rand_codes(rng, 4, (8, 16)), rand_codes(rng, 4, (16, 8))
        for name in names:
            sibling = backends.KERNEL_SIBLINGS[name]
            k_out, k_cyc = gs.stream_gemm(name, a, b, 4)
            s_out, s_cyc = gs.stream_gemm(sibling, a, b, 4)
            assert bool(jnp.all(k_out == s_out))
            assert int(k_cyc) == int(s_cyc) == gs.wc_cycles(sibling, 4, 16)
            # exact path drops the cycle report
            assert bool(jnp.all(gs.gemm(name, a, b, 4) == s_out))

    def test_reregistration_is_idempotent(self):
        from repro.kernels import backends
        assert backends.register_kernel_backends() == \
            backends.register_kernel_backends()

    def test_mirrors_share_cost_model(self):
        from repro.core import gemm_sims as gs
        from repro.kernels import backends
        backends.register_kernel_backends()
        for name, sibling in backends.KERNEL_SIBLINGS.items():
            for bits in (2, 4, 8):
                assert gs.wc_cycles(name, bits, 64) == \
                    gs.wc_cycles(sibling, bits, 64)
            assert gs.get_design(name).sparsity_aware == \
                gs.get_design(sibling).sparsity_aware


class TestBitSparsityKernel:
    @pytest.mark.parametrize("shape", [(32, 32), (100, 300), (257, 65), (7, 9)])
    @pytest.mark.parametrize("bits", [4, 8])
    def test_matches_core_profile(self, rng, shape, bits):
        q = quantize(jnp.asarray(rng.normal(0, 0.1, shape), jnp.float32),
                     bits=bits, per_channel=False).values
        word_k, bspa_k = ops.bit_sparsity_stats(q, bits=bits, interpret=True)
        st_ = sparsity.profile_tensor(q, bits=bits, pre_quantized=True)
        assert float(word_k) == pytest.approx(st_.word, abs=1e-6)
        assert float(bspa_k) == pytest.approx(st_.bit_blockmax, abs=1e-6)

    def test_matches_ref(self, rng):
        q = rand_codes(rng, 8, (96, 160))
        word_k, bspa_k = ops.bit_sparsity_stats(q, bits=8, interpret=True)
        word_r, bspa_r = ref.bit_sparsity_stats_ref(q, bits=8)
        assert float(word_k) == pytest.approx(float(word_r), abs=1e-6)
        assert float(bspa_k) == pytest.approx(float(bspa_r), abs=1e-6)
