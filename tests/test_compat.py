"""Unit tests for the cross-version jax shims in ``repro.compat``.

Pins the *selection* itself: when the running jax exposes the native
``jax.shard_map`` the wrapper must dispatch to it (with the ``check_vma``
spelling), and on 0.4.x toolchains it must fall back to
``jax.experimental.shard_map.shard_map`` with ``check_vma`` translated to
``check_rep`` — not silently dropped.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.launch.mesh import single_device_mesh


def test_selected_symbol_matches_running_jax():
    assert compat.HAS_NATIVE_SHARD_MAP == hasattr(jax, "shard_map")
    if compat.HAS_NATIVE_SHARD_MAP:
        # Native path: the wrapper must not have imported the experimental
        # fallback at module scope.
        assert not hasattr(compat, "_experimental_shard_map")
    else:
        from jax.experimental.shard_map import shard_map as experimental
        assert compat._experimental_shard_map is experimental


def test_wrapper_translates_check_vma(monkeypatch):
    # Drive the wrapper through a recording stand-in for whichever backend
    # the running jax selected, and assert the keyword it receives.
    seen = {}

    def recorder(f, *, mesh, in_specs, out_specs, **kw):
        seen.update(kw)
        return f

    if compat.HAS_NATIVE_SHARD_MAP:
        monkeypatch.setattr(jax, "shard_map", recorder)
        expected_kw = "check_vma"
    else:
        monkeypatch.setattr(compat, "_experimental_shard_map", recorder)
        expected_kw = "check_rep"
    compat.shard_map(lambda x: x, mesh=None, in_specs=None, out_specs=None,
                     check_vma=False)
    assert seen == {expected_kw: False}


def test_shard_map_executes_on_a_mesh():
    mesh = single_device_mesh()
    spec = jax.sharding.PartitionSpec()
    f = compat.shard_map(lambda x: x * 2, mesh=mesh,
                         in_specs=spec, out_specs=spec, check_vma=False)
    with mesh:
        out = f(jnp.arange(4.0))
    np.testing.assert_allclose(np.asarray(out), [0.0, 2.0, 4.0, 6.0])
