"""Acceptance tests for ``python -m repro.analysis`` — the gate exits 0 on
the clean repo and non-zero on each hazardous fixture."""

import json
import pathlib
import textwrap

import pytest

from repro.analysis.__main__ import main
from repro.backends.plan import BackendPlan, SiteAssignment

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]


def write_plan(tmp_path, entries, name="fixture.json"):
    p = tmp_path / name
    p.write_text(BackendPlan(sites=tuple(entries)).to_json())
    return p


@pytest.fixture
def bare_root(tmp_path):
    """A --root with no example plans and no lintable source."""
    (tmp_path / "examples" / "plans").mkdir(parents=True)
    (tmp_path / "src").mkdir()
    return tmp_path


class TestCliFixtures:
    def test_overflow_hazardous_plan_exits_nonzero(self, tmp_path, bare_root,
                                                   capsys):
        plan = write_plan(tmp_path, [
            SiteAssignment("big", "ugemm", 8, k=2**20)])
        rc = main(["--skip-ranges", "--root", str(bare_root),
                   "--plan", str(plan)])
        assert rc != 0
        out = capsys.readouterr().out
        assert "acc-overflow" in out and "error" in out

    def test_shadowed_pattern_plan_exits_nonzero(self, tmp_path, bare_root,
                                                 capsys):
        plan = write_plan(tmp_path, [
            SiteAssignment("layers/*", "bgemm", 8),
            SiteAssignment("layers/*", "tubgemm", 4)])
        rc = main(["--skip-ranges", "--root", str(bare_root),
                   "--plan", str(plan)])
        assert rc != 0
        assert "shadowed-pattern" in capsys.readouterr().out

    def test_registry_mutation_source_exits_nonzero(self, bare_root, capsys):
        (bare_root / "src" / "sneaky.py").write_text(textwrap.dedent("""\
            from repro.core.gemm_sims import register_design
            register_design(spec)
        """))
        rc = main(["--skip-ranges", "--skip-plans",
                   "--root", str(bare_root)])
        assert rc != 0
        assert "registry-mutation" in capsys.readouterr().out

    def test_clean_fixture_root_exits_zero(self, bare_root, capsys):
        rc = main(["--skip-ranges", "--root", str(bare_root)])
        assert rc == 0
        assert "analysis: OK" in capsys.readouterr().out

    def test_unknown_arch_rejected(self, bare_root):
        with pytest.raises(SystemExit):
            main(["--arch", "not-a-model", "--root", str(bare_root)])


class TestCliOnRepo:
    def test_plans_and_source_pass_on_clean_repo(self, capsys):
        # the shipped example plans + the repo's own source lint clean
        rc = main(["--skip-ranges", "--root", str(REPO_ROOT)])
        assert rc == 0
        out = capsys.readouterr().out
        assert "plan-lint" in out and "source-lint" in out

    def test_all_three_passes_run_and_exit_zero(self, tmp_path, capsys):
        # full gate on one registered config: ranges (abstract trace of the
        # real published config), plan lint, source lint — and --json output
        report = tmp_path / "findings.json"
        rc = main(["--arch", "musicgen-medium", "--root", str(REPO_ROOT),
                   "--json", str(report)])
        assert rc == 0
        out = capsys.readouterr().out
        assert "ranges: musicgen-medium" in out
        assert "envelope points" in out
        doc = json.loads(report.read_text())
        assert doc["verdict"].startswith("analysis:")
        assert all(f["severity"] == "warning" for f in doc["findings"])

    def test_shipped_plans_carry_pruning_evidence(self):
        # the regenerated example plans ship the verifier's meta block
        for p in sorted((REPO_ROOT / "examples" / "plans").glob("*.json")):
            doc = json.loads(p.read_text())
            meta = doc.get("meta", {})
            assert "range_pruned" in meta, p.name
            assert meta["range_pruned"] == []
