"""Bit-packed weight subsystem: differential conformance + plan replay.

The packed store's whole claim is *bit-identity*: a ``PackedQuantized``
leaf carries exactly the codes and scales ``quantize`` produces, so
executing from it — simulator, Pallas mirror, grid shard, serving engine —
must match the quantize-then-execute float path bit for bit at every
width.  This module holds that claim differentially:

* pack/unpack round-trip properties (hypothesis when available, the local
  shim otherwise): every signed ``bits``-wide code survives, odd and
  non-word-divisible lengths included, per-channel and per-row scales;
* packed-vs-float ``dense`` bit-identity across EVERY registered backend
  spec at bits {2, 4, 8}, plus the fused Pallas kernel vs a materializing
  int reference;
* (1,1)-grid in-process parity and a 2x2-grid subprocess parity run
  (pinned 8 fake host devices, like ``test_grid.test_grid_multidevice``);
* plan-replay regression: ``serve``'s plan evidence (tokens, drift,
  rel-RMSE, measured-cycle bounds) is identical packed vs unpacked;
* the stale-weight hazards: re-quantizing packed codes at a second width
  raises everywhere it could silently happen, and the analysis passes
  (``packed-materialize`` source rule, ``packed-width-mismatch`` plan
  rule) flag the static versions.
"""

import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # CI image has no hypothesis; use the local shim
    from _hypothesis_fallback import given, settings, strategies as st

import conftest
from repro import backends, configs
from repro.analysis import plan_lint, source_lint
from repro.backends.plan import BackendPlan, SiteAssignment
from repro.core import accounting, packing
from repro.core.quantization import quantize, quantize_per_row, vmax
from repro.eval import planner as planner_lib
from repro.kernels import packed_gemm as pk
from repro.launch import serve as serve_lib
from repro.launch.mesh import single_device_mesh
from repro.models import common, model as model_lib
from repro.serving import ServingEngine, TrafficConfig, generate_trace

_no_xla_cache = pytest.fixture(autouse=True, scope="module")(
    conftest.disable_compilation_cache)

#: every registered spec, stochastic ones pinned to a short stream
ALL_SPECS = tuple(
    name + (":16" if name == "ugemm_stochastic" else "")
    for name in backends.available())


def _resolve(spec, bits):
    kw = {"interpret": True} if spec.endswith("_pallas") else {}
    return backends.resolve(spec, bits=bits, **kw)


@pytest.fixture(scope="module")
def cfg():
    return dataclasses.replace(configs.get_smoke_config("llama3-8b"),
                               compute_dtype="float32",
                               param_dtype="float32")


@pytest.fixture(scope="module")
def params(cfg):
    return model_lib.init_params(cfg, jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# 1. pack/unpack round-trip properties
# ---------------------------------------------------------------------------

class TestRoundTrip:

    @settings(max_examples=40, deadline=None)
    @given(bits=st.sampled_from([2, 4, 8]),
           k=st.integers(min_value=1, max_value=37),
           n=st.integers(min_value=1, max_value=9),
           seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_full_signed_range_round_trips(self, bits, k, n, seed):
        # the whole signed range, including -2^(bits-1) (below the symmetric
        # quantizer's -vmax) — the word layout must not assume the quantizer
        rng = np.random.default_rng(seed)
        lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
        codes = jnp.asarray(rng.integers(lo, hi + 1, (k, n)), jnp.int8)
        words = packing.pack_codes(codes, bits)
        assert words.dtype == jnp.int32
        assert words.shape == (-(-k // packing.codes_per_word(bits)), n)
        back = packing.unpack_codes(words, bits, k)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(codes))

    @settings(max_examples=25, deadline=None)
    @given(bits=st.sampled_from([2, 4, 8]),
           k=st.integers(min_value=2, max_value=33),
           n=st.integers(min_value=1, max_value=8),
           seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_pack_quantized_matches_quantize(self, bits, k, n, seed):
        rng = np.random.default_rng(seed)
        w = jnp.asarray(rng.normal(0, 1, (k, n)), jnp.float32)
        store = packing.pack_quantized(w, bits=bits)
        ref = quantize(w, bits=bits)
        np.testing.assert_array_equal(np.asarray(store.codes()),
                                      np.asarray(ref.values))
        np.testing.assert_array_equal(np.asarray(store.scale),
                                      np.asarray(ref.scale))
        np.testing.assert_array_equal(np.asarray(store.dequantize()),
                                      np.asarray(ref.dequantize()))

    @settings(max_examples=15, deadline=None)
    @given(bits=st.sampled_from([2, 4, 8]),
           seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_per_row_scales_round_trip(self, bits, seed):
        rng = np.random.default_rng(seed)
        w = jnp.asarray(rng.normal(0, 1, (11, 6)), jnp.float32)
        q = quantize_per_row(w, bits=bits)
        store = packing.from_quantized(q)
        assert store.scale.shape == (11, 1)
        np.testing.assert_array_equal(np.asarray(store.dequantize()),
                                      np.asarray(q.dequantize()))

    def test_stacked_leaf_packs_per_slice(self, rng):
        # a scanned-layers leaf: every slice gets its own per-channel scales
        w = jnp.asarray(rng.normal(0, 1, (3, 10, 4)), jnp.float32)
        store = packing.pack_quantized(w, bits=4, k=10, n_out=4)
        ref = jax.vmap(lambda m: quantize(m, bits=4))(w)
        np.testing.assert_array_equal(np.asarray(store.codes()),
                                      np.asarray(ref.values))
        # lax.scan-style slicing keeps the aux consistent per layer
        leaves, treedef = jax.tree_util.tree_flatten(store)
        sliced = jax.tree_util.tree_unflatten(
            treedef, [l[1] for l in leaves])
        assert sliced.shape == (10, 4)
        np.testing.assert_array_equal(np.asarray(sliced.codes()),
                                      np.asarray(ref.values[1]))

    def test_multi_axis_k_and_tail(self, rng):
        # out-projection-shaped leaf: k folds (heads, head_dim)
        w = jnp.asarray(rng.normal(0, 1, (4, 8, 12)), jnp.float32)
        store = packing.pack_quantized(w, bits=4, k=32, n_out=12)
        assert store.shape == (4, 8, 12)
        flat = store.reshape(32, 12)
        assert flat.shape == (32, 12)
        ref = quantize(w.reshape(32, 12), bits=4)
        np.testing.assert_array_equal(np.asarray(flat.codes()),
                                      np.asarray(ref.values))
        with pytest.raises(ValueError, match="without mixing"):
            store.reshape(12, 32)

    def test_grid_shards_reassemble_to_full_codes(self, rng):
        # per-band packing (k=10 over 4 bands: ceil split, padded last band)
        w = jnp.asarray(rng.normal(0, 1, (10, 6)), jnp.float32)
        store = packing.pack_quantized(w, bits=4, grid_x=4)
        assert store.grid_x == 4
        ref = quantize(w, bits=4)
        np.testing.assert_array_equal(np.asarray(store.codes()),
                                      np.asarray(ref.values))
        np.testing.assert_array_equal(np.asarray(store.dequantize()),
                                      np.asarray(ref.dequantize()))

    def test_bad_widths_and_shapes_raise(self, rng):
        with pytest.raises(ValueError, match="packable widths"):
            packing.codes_per_word(3)
        w = jnp.asarray(rng.normal(0, 1, (6, 4)), jnp.float32)
        with pytest.raises(ValueError, match="not a stack"):
            packing.pack_quantized(w, bits=4, k=5, n_out=4)
        store = packing.pack_quantized(w, bits=4)
        with pytest.raises(ValueError, match="second width"):
            packing.pack_quantized(store, bits=2)


# ---------------------------------------------------------------------------
# 2. packed-vs-float dense bit-identity, every backend spec x {2, 4, 8}
# ---------------------------------------------------------------------------

class TestDenseBitIdentity:

    @pytest.mark.parametrize("spec", ALL_SPECS)
    @pytest.mark.parametrize("bits", [2, 4, 8])
    def test_packed_equals_float_path(self, rng, spec, bits):
        k, n = 24, 12  # small: the Pallas mirrors pad to their block
        w = jnp.asarray(rng.normal(0, 1, (k, n)), jnp.float32)
        x = jnp.asarray(rng.normal(0, 1, (3, k)), jnp.float32)
        backend = _resolve(spec, bits)
        store = packing.pack_quantized(w, bits=bits)
        with backends.use_backend(backend):
            ref = common.dense(w, x, name="w")
        with backends.use_backend(backend) as execution:
            got = common.dense(store, x, name="w")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
        call = execution.calls[0]
        assert (call.k, call.n_out) == (k, n)

    def test_width_mismatch_raises(self, rng):
        w = jnp.asarray(rng.normal(0, 1, (16, 8)), jnp.float32)
        x = jnp.asarray(rng.normal(0, 1, (2, 16)), jnp.float32)
        store = packing.pack_quantized(w, bits=8)
        with backends.use_backend("tubgemm", bits=4):
            with pytest.raises(ValueError, match="packed-width-mismatch"):
                common.dense(store, x, name="w")

    def test_unmatched_plan_site_dequantizes(self, rng):
        # a site the plan leaves unmatched runs FLOAT from dequantized codes
        w = jnp.asarray(rng.normal(0, 1, (16, 8)), jnp.float32)
        x = jnp.asarray(rng.normal(0, 1, (2, 16)), jnp.float32)
        store = packing.pack_quantized(w, bits=4)
        plan = BackendPlan(sites=(SiteAssignment(
            pattern="other/*", design="tubgemm", bits=4),))
        with backends.use_plan(plan):
            got = common.dense(store, x, name="w")
        want = x @ store.dequantize()
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_quant_kernel_path_refuses_packed(self, rng, cfg):
        w = jnp.asarray(rng.normal(0, 1, (16, 8)), jnp.float32)
        x = jnp.asarray(rng.normal(0, 1, (2, 16)), jnp.float32)
        store = packing.pack_quantized(w, bits=4)
        qcfg = dataclasses.replace(cfg, quant_bits=4, quant_kernel=True)
        with pytest.raises(TypeError, match="second time"):
            common.dense(store, x, qcfg, name="w")


# ---------------------------------------------------------------------------
# 3. fused Pallas kernel vs the materializing reference
# ---------------------------------------------------------------------------

class TestFusedKernel:

    @pytest.mark.parametrize("bits", [2, 4, 8])
    def test_bit_exact_vs_materializing_reference(self, rng, bits):
        m, k, n = 5, 37, 11  # odd everything: padding + last-word lanes
        v = vmax(bits)
        x = jnp.asarray(rng.integers(-v, v + 1, (m, k)), jnp.int8)
        codes = jnp.asarray(rng.integers(-v, v + 1, (k, n)), jnp.int8)
        words = packing.pack_codes(codes, bits)
        got = pk.packed_gemm(x, words, bits=bits, k=k, block=(8, 8, 32),
                             interpret=True)
        ref = jnp.matmul(x.astype(jnp.int32), codes.astype(jnp.int32))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))

    def test_fused_dequant_epilogue(self, rng):
        w = jnp.asarray(rng.normal(0, 1, (20, 6)), jnp.float32)
        store = packing.pack_quantized(w, bits=4)
        v = vmax(4)
        x = jnp.asarray(rng.integers(-v, v + 1, (3, 20)), jnp.int8)
        got = pk.packed_matmul(x, store, block=(8, 8, 16), interpret=True)
        acc = jnp.matmul(x.astype(jnp.int32),
                         store.codes().astype(jnp.int32))
        ref = acc.astype(jnp.float32) * store.scale.reshape(1, -1)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))

    def test_kernel_validates_inputs(self, rng):
        store = packing.pack_quantized(
            jnp.ones((8, 4), jnp.float32), bits=4)
        with pytest.raises(TypeError, match="int8 activations"):
            pk.packed_gemm(jnp.ones((2, 8), jnp.float32), store.packed,
                           bits=4, k=8)
        with pytest.raises(ValueError, match="multiple of"):
            pk.packed_gemm(jnp.ones((2, 8), jnp.int8), store.packed,
                           bits=4, k=8, block=(8, 8, 12))
        grid_store = packing.pack_quantized(
            jnp.ones((8, 4), jnp.float32), bits=4, grid_x=2)
        with pytest.raises(ValueError, match="flat"):
            pk.packed_matmul(jnp.ones((2, 8), jnp.int8), grid_store)


# ---------------------------------------------------------------------------
# 4. pack_weights + whole-model / grid parity
# ---------------------------------------------------------------------------

def _uniform_plan(cfg, params, design="tubgemm", bits=4):
    sites = planner_lib.discover_sites(cfg, params)
    return BackendPlan(sites=tuple(
        SiteAssignment(pattern=s.name, design=design, bits=bits,
                       m=s.m, k=s.k, n_out=s.n_out, count=s.count)
        for s in sites))


class TestModelParity:

    def test_pack_weights_uniform_bits_forward_bit_identical(self, cfg,
                                                             params):
        packed = backends.pack_weights(cfg, params, bits=4)
        tokens = jnp.zeros((2, 4), jnp.int32)
        with backends.use_backend("tubgemm", bits=4):
            ref, _ = model_lib.forward(params, cfg, tokens)
        with backends.use_backend("tubgemm", bits=4):
            got, _ = model_lib.forward(packed, cfg, tokens)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))

    def test_pack_weights_plan_forward_bit_identical(self, cfg, params):
        plan = _uniform_plan(cfg, params)
        packed = backends.pack_weights(cfg, params, plan)
        widths = packing.packed_widths(packed)
        assert widths and set(widths.values()) == {4}
        tokens = jnp.zeros((2, 4), jnp.int32)
        with backends.use_plan(plan):
            ref, _ = model_lib.forward(params, cfg, tokens)
        with backends.use_plan(plan):
            got, _ = model_lib.forward(packed, cfg, tokens)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))

    def test_one_by_one_grid_parity(self, cfg, params):
        flat = _uniform_plan(cfg, params)
        gplan = backends.GridPlan(units_x=1, units_y=1, aggregate=flat,
                                  shards=())
        packed = backends.pack_weights(cfg, params, gplan)
        tokens = jnp.zeros((2, 4), jnp.int32)
        with backends.use_plan(gplan):
            ref, _ = model_lib.forward(params, cfg, tokens)
        with backends.use_plan(gplan):
            got, _ = model_lib.forward(packed, cfg, tokens)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))

    def test_pack_weights_wants_exactly_one_selector(self, cfg, params):
        with pytest.raises(ValueError, match="exactly one"):
            backends.pack_weights(cfg, params)
        plan = _uniform_plan(cfg, params)
        with pytest.raises(ValueError, match="exactly one"):
            backends.pack_weights(cfg, params, plan, bits=4)

    def test_pack_weights_width_conflict_raises(self, cfg, params):
        packed = backends.pack_weights(cfg, params, bits=8)
        # matching width: packed leaves pass through untouched
        again = backends.pack_weights(cfg, packed, bits=8)
        assert packing.packed_widths(again) == packing.packed_widths(packed)
        with pytest.raises(ValueError, match="packed-width-mismatch"):
            backends.pack_weights(cfg, packed, bits=4)

    def test_store_report_reductions(self, cfg, params):
        rep4 = accounting.packed_store_report(
            backends.pack_weights(cfg, params, bits=4))
        rep8 = accounting.packed_store_report(
            backends.pack_weights(cfg, params, bits=8))
        assert rep4.packed_sites > 0
        assert rep4.packed_sites == rep8.packed_sites
        # 4-bit: 8 codes/word -> ~8x on packed sites; 8-bit: 4 codes/word
        # -> just under 4x (the per-channel scales cost a few rows)
        assert 3.0 < rep8.packed_reduction < 4.0
        assert 6.0 < rep4.packed_reduction < 8.0
        assert rep4.packed_reduction > 1.7 * rep8.packed_reduction
        assert rep4.stored_bytes < rep8.stored_bytes < rep8.float32_bytes


# ---------------------------------------------------------------------------
# 5. plan-replay regression: packed evidence == unpacked evidence
# ---------------------------------------------------------------------------

class TestPlanReplayRegression:

    def test_serve_plan_evidence_identical(self, cfg, params):
        plan = _uniform_plan(cfg, params)
        prompt = jnp.asarray(
            np.random.default_rng(7).integers(0, cfg.vocab_size, (1, 4)),
            jnp.int32)
        mesh = single_device_mesh()
        ref = serve_lib.run_plan_execution(cfg, params, mesh, prompt,
                                           plan, 2)
        got = serve_lib.run_plan_execution(cfg, params, mesh, prompt,
                                           plan, 2, packed=True)
        np.testing.assert_array_equal(np.asarray(got["tokens"]),
                                      np.asarray(ref["tokens"]))
        assert got["site_backends"] == ref["site_backends"]
        assert got["drift"] == ref["drift"]
        assert got["top1_agreement"] == ref["top1_agreement"]
        assert got["rel_rmse"] == ref["rel_rmse"]
        assert got["site_cycles"] == ref["site_cycles"]
        for cyc in got["site_cycles"].values():
            assert cyc["measured"] <= cyc["wc"] + 0.5

    def test_serving_engine_packed_streams_identical(self, cfg, params):
        trace = generate_trace(TrafficConfig(
            num_requests=4, arrival_rate=1.0, seed=3,
            prompt_short=(2, 4), prompt_long=(4, 6),
            output_short=(2, 3), output_long=(3, 5)))
        kw = dict(max_batch=2, page_size=4, max_seq_len=32,
                  backend="tubgemm", bits=4)
        ref = ServingEngine(cfg, params, **kw).run(trace, "continuous")
        eng = ServingEngine(cfg, params, packed=True, **kw)
        got = eng.run(trace, "continuous")
        assert got.request_tokens == ref.request_tokens
        assert got.energy_uj == ref.energy_uj  # pricing reads float leaves

    def test_serving_engine_packed_needs_scope(self, cfg, params):
        with pytest.raises(ValueError, match="packed=True needs"):
            ServingEngine(cfg, params, packed=True)


# ---------------------------------------------------------------------------
# 6. the stale-weight hazards + analysis rules
# ---------------------------------------------------------------------------

class TestHazards:

    def test_weight_matrix_refuses_packed_leaf(self, rng):
        leaf = packing.pack_quantized(
            jnp.asarray(rng.normal(0, 1, (8, 4)), jnp.float32), bits=4)
        site = planner_lib.GemmSite(name="blk/w", m=1, k=8, n_out=4,
                                    count=1, leaf=leaf)
        with pytest.raises(TypeError, match="already-packed"):
            site.weight_matrix()

    def test_measure_matrix_cycles_refuses_packed(self, rng):
        leaf = packing.pack_quantized(
            jnp.asarray(rng.normal(0, 1, (8, 4)), jnp.float32), bits=4)
        backend = backends.resolve("tubgemm", bits=4)
        with pytest.raises(TypeError, match="float weight"):
            backends.measure_matrix_cycles(backend, leaf, rows=1,
                                           unit_n=4, num_units=4)

    def test_plan_lint_packed_width_mismatch(self):
        plan = BackendPlan(sites=(
            SiteAssignment(pattern="layers/attn/wq", design="tubgemm",
                           bits=4),
            SiteAssignment(pattern="lm_head", design="bgemm", bits=8),))
        clean = plan_lint.lint_plan(
            plan, packed_bits={"layers/attn/wq": 4, "lm_head": 8})
        assert not [f for f in clean if f.rule == "packed-width-mismatch"]
        found = plan_lint.lint_plan(
            plan, packed_bits={"layers/attn/wq": 8, "unplanned/site": 2})
        hits = [f for f in found if f.rule == "packed-width-mismatch"]
        assert len(hits) == 1  # the unmatched site runs float: no conflict
        assert "repack" in hits[0].message

    def test_source_lint_packed_materialize_rule(self):
        bad = ("def packed_gemm(x, store):\n"
               "    w = store.dequantize()\n"
               "    return x @ w\n")
        found = source_lint.lint_source(
            bad, rel="src/repro/kernels/packed_gemm.py")
        assert [f.rule for f in found] == ["packed-materialize"]
        # elsewhere the same call is fine
        assert not source_lint.lint_source(
            bad, rel="src/repro/serving/energy.py")
        # and the shipped kernel module itself lints clean
        src = open(os.path.join(os.path.dirname(__file__), "..", "src",
                                "repro", "kernels", "packed_gemm.py")).read()
        assert not source_lint.lint_source(
            src, rel="src/repro/kernels/packed_gemm.py")


# ---------------------------------------------------------------------------
# 7. 2x2-grid subprocess parity (8 fake host devices)
# ---------------------------------------------------------------------------

MULTIDEV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
from repro import backends, configs
from repro.backends.plan import BackendPlan, SiteAssignment
from repro.eval import planner
from repro.models import model as model_lib

cfg = configs.get_smoke_config("llama3-8b")
params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
sites = planner.discover_sites(cfg, params)
flat = BackendPlan(sites=tuple(
    SiteAssignment(pattern=s.name, design="tubgemm", bits=4,
                   m=s.m, k=s.k, n_out=s.n_out, count=s.count)
    for s in sites))
gplan = backends.GridPlan(units_x=2, units_y=2, aggregate=flat, shards=())
packed = backends.pack_weights(cfg, params, gplan)
from repro.core import packing
leaf = next(l for l in jax.tree_util.tree_leaves(
    packed, is_leaf=packing.is_packed) if packing.is_packed(l))
assert leaf.grid_x == 2, leaf.grid_x  # per-shard word stores
tokens = jnp.zeros((2, 4), jnp.int32)
with backends.use_plan(gplan):
    ref, _ = model_lib.forward(params, cfg, tokens)
with backends.use_plan(gplan):
    got, _ = model_lib.forward(packed, cfg, tokens)
assert np.array_equal(np.asarray(got), np.asarray(ref))
print("PACKED_GRID_OK", len(sites))
"""


def test_packed_grid_multidevice():
    """On a 2x2 device mesh, executing from the per-shard packed store is
    bit-identical to the quantize-then-shard float path."""
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
           "JAX_PLATFORMS": "cpu",
           "JAX_DISABLE_MOST_OPTIMIZATIONS": "1",
           "JAX_COMPILATION_CACHE_DIR": os.path.abspath(".jax_cache"),
           "JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS": "0"}
    res = subprocess.run([sys.executable, "-c", MULTIDEV_SCRIPT],
                         capture_output=True, text=True, timeout=900,
                         env=env)
    assert "PACKED_GRID_OK" in res.stdout, \
        f"missing PACKED_GRID_OK\n{res.stdout}\n{res.stderr}"
