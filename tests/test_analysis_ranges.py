"""Property tests for ``repro.analysis.ranges`` — the interval bounds are
checked against brute-force max-accumulator enumeration and against the
actual simulators, and the runtime guards built on them are exercised."""

import itertools

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # CI image has no hypothesis; use the local shim
    from _hypothesis_fallback import given, settings, strategies as st

import jax.numpy as jnp

from repro.analysis import ranges
from repro.backends import as_grid, resolve
from repro.core import gemm_sims
from repro.core.quantization import vmax

EXACT_FNS = {
    "bgemm": gemm_sims.bgemm_exact,
    "tugemm": gemm_sims.tugemm_exact,
    "tubgemm": gemm_sims.tubgemm_exact,
}
EXACT_DESIGNS = tuple(EXACT_FNS)
BITS = (2, 3, 4, 8)


class TestInterval:
    def test_mul_matches_corner_enumeration(self):
        for lo1, hi1, lo2, hi2 in [(-3, 5, -2, 7), (-1, 1, -1, 1),
                                   (0, 4, -6, -2), (-5, -1, 3, 9)]:
            got = ranges.Interval(lo1, hi1) * ranges.Interval(lo2, hi2)
            vals = [a * b for a in range(lo1, hi1 + 1)
                    for b in range(lo2, hi2 + 1)]
            assert got.lo == min(vals) and got.hi == max(vals)

    def test_add_and_scale(self):
        i = ranges.Interval(-2, 3)
        assert (i + i) == ranges.Interval(-4, 6)
        assert i.scale(4) == ranges.Interval(-8, 12)
        with pytest.raises(ValueError):
            i.scale(-1)

    def test_empty_interval_rejected(self):
        with pytest.raises(ValueError):
            ranges.Interval(2, 1)


class TestOutputBound:
    @pytest.mark.parametrize("design", EXACT_DESIGNS)
    @pytest.mark.parametrize("bits", BITS)
    def test_tight_at_all_vmax(self, design, bits):
        # the hi corner is achieved: an all-+Vmax contraction lands ON it
        for k in (1, 3, 7):
            v = vmax(bits)
            a = jnp.full((1, k), v, jnp.int32)
            b = jnp.full((k, 1), v, jnp.int32)
            out = int(np.asarray(EXACT_FNS[design](a, b))[0, 0])
            iv = ranges.output_interval(design, bits, k)
            assert out == iv.hi == k * v * v
            out_lo = int(np.asarray(EXACT_FNS[design](-a, b))[0, 0])
            assert out_lo == iv.lo

    @pytest.mark.parametrize("design", EXACT_DESIGNS)
    def test_brute_force_enumeration_small(self, design):
        # exhaustive: every code vector pair at tiny (bits, k) stays inside
        # the interval, and the enumerated max hits the bound exactly
        for bits, k in [(2, 1), (2, 2), (3, 1), (3, 2)]:
            v = vmax(bits)
            codes = range(-v, v + 1)
            iv = ranges.output_interval(design, bits, k)
            worst = 0
            for avec in itertools.product(codes, repeat=k):
                for bvec in itertools.product(codes, repeat=k):
                    dot = sum(x * y for x, y in zip(avec, bvec))
                    assert iv.contains(dot)
                    worst = max(worst, abs(dot))
            assert worst == iv.abs_max == k * v * v

    @given(design=st.sampled_from(EXACT_DESIGNS),
           bits=st.sampled_from(BITS),
           k=st.integers(min_value=1, max_value=8),
           seed=st.integers(min_value=0, max_value=2**31 - 1))
    @settings(max_examples=40, deadline=None)
    def test_simulator_outputs_within_interval(self, design, bits, k, seed):
        v = vmax(bits)
        rng = np.random.default_rng(seed)
        a = rng.integers(-v, v + 1, (3, k)).astype(np.int32)
        b = rng.integers(-v, v + 1, (k, 2)).astype(np.int32)
        out = np.asarray(EXACT_FNS[design](jnp.asarray(a), jnp.asarray(b)))
        iv = ranges.output_interval(design, bits, k)
        assert out.max() <= iv.hi and out.min() >= iv.lo
        # every prefix partial sum is also bounded (j-fold interval ⊆ k-fold)
        partials = np.cumsum(a[:, :, None] * b[None, :, :], axis=1)
        assert abs(partials).max() <= iv.abs_max

    def test_word_sparsity_tightens_monotonically(self):
        base = ranges.output_interval("bgemm", 8, 100)
        tighter = ranges.output_interval("bgemm", 8, 100, word_sparsity=0.5)
        zero = ranges.output_interval("bgemm", 8, 100, word_sparsity=1.0)
        assert tighter.abs_max < base.abs_max
        assert zero.abs_max == 0
        with pytest.raises(ValueError):
            ranges.output_interval("bgemm", 8, 100, word_sparsity=1.5)


class TestCounterBound:
    @pytest.mark.parametrize("design", EXACT_DESIGNS)
    @pytest.mark.parametrize("bits", BITS)
    def test_register_dominates_output_for_exact_designs(self, design, bits):
        # bgemm/tubgemm registers ARE the partial sum; tugemm's pulse count
        # dominates it.  (uGEMM is excluded: its register holds AND-pulse
        # counts — a different domain checked against the fp32 window.)
        for k in (1, 5, 64):
            reg = ranges.counter_interval(design, bits, k)
            out = ranges.output_interval(design, bits, k)
            assert reg.abs_max >= out.abs_max

    @pytest.mark.parametrize("bits", BITS)
    def test_ugemm_counts_slots_per_step(self, bits):
        for k in (1, 5, 64):
            reg = ranges.counter_interval("ugemm", bits, k)
            assert reg.abs_max == k * 2 ** bits

    def test_tugemm_counts_slot_pulses_not_products(self):
        # K * L^2 pulses with L = 2^(bits-1): strictly above K * Vmax^2
        bits, k = 4, 10
        reg = ranges.counter_interval("tugemm", bits, k)
        assert reg.abs_max == k * (2 ** (bits - 1)) ** 2
        assert reg.abs_max > ranges.output_interval("tugemm", bits, k).abs_max

    def test_pallas_mirrors_inherit_sibling_envelope(self):
        for name in ("tugemm_pallas", "tubgemm_pallas"):
            base = name[:-len("_pallas")]
            assert ranges.design_family(name) == base
            assert ranges.max_safe_k(name, 4) == ranges.max_safe_k(base, 4)


class TestMaxSafeK:
    def test_ugemm_matches_paper_fp32_window(self):
        # the paper's L*K < 2^24 streaming envelope: L = 2^bits slots
        assert ranges.max_safe_k("ugemm", 8) == (2**24 - 1) // 2**8 == 65535
        assert ranges.capacity("ugemm", 8) == ranges.FLOAT32_EXACT_MAX

    @pytest.mark.parametrize("design", ranges.FAMILIES)
    @pytest.mark.parametrize("bits", BITS)
    def test_boundary_is_exact(self, design, bits):
        edge = ranges.max_safe_k(design, bits)
        assert ranges.accumulator_bound(design, bits, edge).ok
        assert ranges.check_gemm(design, bits, edge, where="t") is None
        bad = ranges.check_gemm(design, bits, edge + 1, where="t")
        assert bad is not None and bad.rule == "acc-overflow"
        assert bad.severity == ranges.ERROR

    def test_empty_envelope_width(self):
        # hypothetical ugemm at 24 bits: 2^24 counts/step > fp32 window
        assert ranges.max_safe_k("ugemm", 24) == 0

    def test_unknown_design(self):
        with pytest.raises(KeyError):
            ranges.accumulator_bound("mystery", 8, 4)
        f = ranges.check_gemm("mystery", 8, 4, where="t")
        assert f is not None and f.rule == "unknown-design"
        # runtime guard passes unknowns silently (custom registrations)
        ranges.assert_within_envelope("mystery", 8, 10**9)


class TestRuntimeGuards:
    def test_execute_rejects_over_envelope_contraction(self):
        backend = resolve("ugemm", bits=8)
        k = ranges.max_safe_k("ugemm", 8) + 1
        a = jnp.ones((1, k), jnp.int32)
        b = jnp.ones((k, 1), jnp.int32)
        with pytest.raises(ValueError, match="bit-exact"):
            backend.execute(a, b)
        with pytest.raises(ValueError, match="largest safe K"):
            backend.stream(a, b)

    def test_resolve_rejects_empty_envelope_width(self):
        with pytest.raises(ValueError, match="empty accumulator envelope"):
            resolve("ugemm", bits=24)

    def test_grid_guard_uses_shard_local_k(self):
        k = ranges.max_safe_k("ugemm", 8) + 1
        a = jnp.ones((1, k), jnp.int32)
        b = jnp.ones((k, 1), jnp.int32)
        with pytest.raises(ValueError, match="cannot run"):
            as_grid(resolve("ugemm", bits=8), 1, 1).execute(a, b)
        # a 2-way K split halves the shard-local contraction back inside
        grid2 = as_grid(resolve("ugemm", bits=8), 2, 1)
        assert grid2.shard_common_dim(k) <= ranges.max_safe_k("ugemm", 8)
        ranges.assert_within_envelope("ugemm", 8, grid2.shard_common_dim(k))

    def test_use_plan_validates_recorded_geometry(self):
        from repro.backends import runtime
        from repro.backends.plan import BackendPlan, SiteAssignment
        bad = BackendPlan(sites=(
            SiteAssignment("big", "ugemm", 8, k=2**20),))
        with pytest.raises(ValueError, match="plan entry 'big'"):
            with runtime.use_plan(bad):
                pass
        # the same assignment is accepted once a grid splits K back inside
        with runtime.use_plan(bad, grid=(32, 1)):
            pass

    def test_exact_designs_accept_model_scale_k(self):
        backend = resolve("tubgemm", bits=8)
        a = jnp.ones((1, 16384), jnp.int32)
        b = jnp.ones((16384, 1), jnp.int32)
        assert int(np.asarray(backend.execute(a, b))[0, 0]) == 16384


class TestPlannerPruning:
    def _huge_site(self, k=100_000):
        from repro.eval import planner
        leaf = np.random.default_rng(0).standard_normal((k, 4)) \
            .astype(np.float32)
        return planner.GemmSite(name="huge", m=1, k=k, n_out=4, count=1,
                                leaf=leaf)

    def test_site_candidates_prunes_and_records(self):
        from repro.eval import planner
        pruned = []
        cands = planner.site_candidates(
            self._huge_site(), designs=("ugemm", "bgemm"),
            bits_candidates=(4, 8), pruned=pruned)
        pairs = {(c.design, c.bits) for c in cands}
        assert ("ugemm", 8) not in pairs and ("bgemm", 8) in pairs
        assert [(r["design"], r["bits"]) for r in pruned] == [("ugemm", 8)]
        assert pruned[0]["max_safe_k"] == ranges.max_safe_k("ugemm", 8)

    def test_build_plan_records_evidence_and_raises_when_infeasible(self):
        from repro.eval import planner
        site = self._huge_site()
        plan = planner.build_plan(object(), None, sites=[site],
                                  designs=("ugemm", "bgemm"),
                                  bits_candidates=(4, 8))
        meta = dict(plan.meta)
        assert [(r["design"], r["bits"]) for r in meta["range_pruned"]] \
            == [("ugemm", 8)]
        assert "ugemm@8" not in meta["totals"]["uniform"]
        with pytest.raises(ValueError, match="accumulator envelope"):
            planner.build_plan(object(), None, sites=[site],
                               designs=("ugemm",), bits_candidates=(8,))
