"""Unit tests for ``repro.analysis.plan_lint`` and ``source_lint``."""

import textwrap

import pytest

from repro.analysis import findings as findings_lib
from repro.analysis import plan_lint, source_lint
from repro.backends.grid import GridPlan
from repro.backends.plan import BackendPlan, SiteAssignment


def rules(found):
    return sorted(f.rule for f in found)


def errors(found):
    return sorted(f.rule for f in findings_lib.errors(found))


class TestPlanLint:
    def test_clean_plan(self):
        plan = BackendPlan(sites=(
            SiteAssignment("layers/attn/wq", "tubgemm", 4, k=512),
            SiteAssignment("*", "bgemm", 8, k=512),
        ))
        found = plan_lint.lint_backend_plan(
            plan, site_names=["layers/attn/wq", "layers/mlp/w_up"])
        assert errors(found) == []

    def test_overflow_hazardous_entry(self):
        plan = BackendPlan(sites=(
            SiteAssignment("big", "ugemm", 8, k=2**20),))
        found = plan_lint.lint_backend_plan(plan)
        assert "acc-overflow" in errors(found)

    def test_unknown_design_and_invalid_bits(self):
        plan = BackendPlan(sites=(
            SiteAssignment("a", "mystery", 4),
            SiteAssignment("b", "bgemm", 77),))
        assert errors(plan_lint.lint_backend_plan(plan)) \
            == ["invalid-bits", "unknown-design"]

    def test_duplicate_pattern_is_shadowed(self):
        plan = BackendPlan(sites=(
            SiteAssignment("layers/*", "bgemm", 8),
            SiteAssignment("layers/*", "tubgemm", 4),))
        found = plan_lint.lint_backend_plan(plan)
        assert "shadowed-pattern" in errors(found)

    def test_shadowed_by_more_specific_cover(self):
        # the exact pattern takes every site the wildcard could win, and the
        # wildcard matches nothing else in the inventory -> it never wins
        plan = BackendPlan(sites=(
            SiteAssignment("layers/attn/wq", "bgemm", 8),
            SiteAssignment("layers/attn/*", "tubgemm", 4),))
        found = plan_lint.lint_backend_plan(
            plan, site_names=["layers/attn/wq"])
        assert "shadowed-pattern" in errors(found)

    def test_dead_pattern_and_unmatched_site(self):
        plan = BackendPlan(sites=(
            SiteAssignment("nothing/matches/me", "bgemm", 8),))
        found = plan_lint.lint_backend_plan(
            plan, site_names=["layers/attn/wq"])
        assert "dead-pattern" in errors(found)
        warn_rules = [f.rule for f in findings_lib.warnings_(found)]
        assert "unmatched-site" in warn_rules

    def test_guard_relaxed_is_warning_not_error(self):
        plan = BackendPlan(sites=(
            SiteAssignment("a", "bgemm", 8, guard_relaxed=True),))
        found = plan_lint.lint_backend_plan(plan)
        assert errors(found) == []
        assert "guard-relaxed" in [f.rule for f in
                                   findings_lib.warnings_(found)]

    def test_grid_plan_checks_shard_local_k(self):
        # aggregate K=100k splits to 50k per shard on units_x=2 — inside
        # ugemm@8's 65535 envelope, so the grid plan is clean while the
        # same assignment in a flat plan overflows
        agg = BackendPlan(sites=(
            SiteAssignment("big", "ugemm", 8, k=100_000),))
        shard = BackendPlan(sites=(
            SiteAssignment("big", "ugemm", 8, k=50_000),))
        gplan = GridPlan(units_x=2, units_y=1, aggregate=agg,
                         shards=(("0,0", shard), ("1,0", shard)))
        assert errors(plan_lint.lint_grid_plan(gplan)) == []
        assert "acc-overflow" in errors(plan_lint.lint_backend_plan(agg))

    def test_grid_plan_overflow_at_shard_k(self):
        agg = BackendPlan(sites=(
            SiteAssignment("big", "ugemm", 8, k=200_000),))
        gplan = GridPlan(units_x=2, units_y=1, aggregate=agg, shards=())
        assert "acc-overflow" in errors(plan_lint.lint_grid_plan(gplan))

    def test_lint_plan_file_unloadable(self, tmp_path):
        p = tmp_path / "broken.json"
        p.write_text("{not json")
        found = plan_lint.lint_plan_file(p)
        assert errors(found) == ["unloadable-plan"]


SRC_MUTATION = textwrap.dedent("""\
    from repro.core.gemm_sims import register_design
    register_design(spec)
""")

SRC_SCOPED = textwrap.dedent("""\
    from repro.core.gemm_sims import register_design, scoped_registry
    with scoped_registry():
        register_design(spec)
""")

SRC_PRAGMA = textwrap.dedent("""\
    from repro.core.gemm_sims import register_design
    register_design(spec)  # analysis: allow-registry-mutation
""")

SRC_SHIM = textwrap.dedent("""\
    from repro.core import gemm_sims
    out = gemm_sims.gemm(a, b, design="tubgemm")
""")

SRC_FLOAT_ACC = textwrap.dedent("""\
    import jax.numpy as jnp
    def tugemm_kernel(a, b):
        return jnp.einsum("mk,kn->mn", a, b)
""")

SRC_INT_ACC = textwrap.dedent("""\
    import jax.numpy as jnp
    def tugemm_kernel(a, b):
        return jnp.einsum("mk,kn->mn", a, b,
                          preferred_element_type=jnp.int32)
""")

SRC_RNG = textwrap.dedent("""\
    import jax
    def sample(key):
        return jax.random.normal(key, (4,))
""")

SRC_RNG_JITTED = textwrap.dedent("""\
    import jax
    @jax.jit
    def sample(key):
        return jax.random.normal(key, (4,))
""")


class TestSourceLint:
    def test_unscoped_registry_mutation(self):
        found = source_lint.lint_source(SRC_MUTATION, rel="src/foo.py")
        assert rules(found) == ["registry-mutation"]

    def test_scoped_mutation_is_clean(self):
        assert source_lint.lint_source(SRC_SCOPED, rel="src/foo.py") == []

    def test_pragma_suppresses(self):
        assert source_lint.lint_source(SRC_PRAGMA, rel="src/foo.py") == []

    def test_defining_module_exempt(self):
        found = source_lint.lint_source(
            SRC_MUTATION, rel="src/repro/core/gemm_sims.py")
        assert found == []

    def test_deprecated_shim_call(self):
        found = source_lint.lint_source(SRC_SHIM, rel="src/foo.py")
        assert rules(found) == ["deprecated-shim"]

    def test_float_accumulation_in_exact_kernel(self):
        found = source_lint.lint_source(
            SRC_FLOAT_ACC, rel="src/repro/kernels/foo.py")
        assert "float-accumulation" in rules(found)
        assert source_lint.lint_source(
            SRC_INT_ACC, rel="src/repro/kernels/foo.py") == []

    def test_unjitted_rng_only_on_execute_path(self):
        found = source_lint.lint_source(
            SRC_RNG, rel="src/repro/backends/foo.py")
        assert rules(found) == ["unjitted-rng"]
        assert source_lint.lint_source(
            SRC_RNG_JITTED, rel="src/repro/backends/foo.py") == []
        # the same code outside the execute layer is fine
        assert source_lint.lint_source(SRC_RNG, rel="src/foo.py") == []

    def test_syntax_error_is_a_finding(self):
        found = source_lint.lint_source("def broken(:", rel="src/foo.py")
        assert rules(found) == ["syntax-error"]

    def test_repo_is_clean(self):
        import pathlib
        root = pathlib.Path(__file__).resolve().parents[1]
        found = source_lint.lint_repo(root)
        assert found == [], "\n".join(f.render() for f in found)

    def test_tests_are_exempt(self, tmp_path):
        (tmp_path / "src").mkdir()
        (tmp_path / "src" / "tests").mkdir()
        (tmp_path / "src" / "tests" / "test_x.py").write_text(SRC_MUTATION)
        assert source_lint.lint_repo(tmp_path) == []
