"""Tier-1 tests for the rate-coded stochastic uGEMM family.

Covers the bitstream layer (seeded determinism, scan/vectorized bit-identity,
full-period exactness), the GEMM engine (error vs the exact uGEMM oracle,
UnaryLinear scaled accumulation), the ``ugemm_stochastic`` backend contract
(resolve/execute/stream/cycles/price), plan round-trips with ``stream_len``,
the plan-lint stream rules and the planner's stochastic candidates.

Property tests use hypothesis when available and the local shim otherwise;
the analytic error envelope is calibrated for the default Sobol engine, so
the monotonicity/tail properties pin ``rng_kind="sobol"``.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # CI image has no hypothesis; use the local shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro import backends
from repro.analysis import plan_lint, ranges
from repro.core import gemm_sims
from repro.core.quantization import vmax
from repro.stochastic import error as stoch_error
from repro.stochastic import gen, sgemm

BITS = 8
PERIOD = 2 ** BITS


# ---------------------------------------------------------------------------
# RNG stage
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["sobol", "lfsr"])
def test_rng_seeded_determinism(kind):
    a = gen.rng_sequence(kind, BITS, 48, dim=0, seed=3)
    b = gen.rng_sequence(kind, BITS, 48, dim=0, seed=3)
    c = gen.rng_sequence(kind, BITS, 48, dim=0, seed=4)
    assert (np.asarray(a) == np.asarray(b)).all()
    assert (np.asarray(a) != np.asarray(c)).any()


def test_sobol_full_period_is_permutation():
    for dim in (0, 1):
        seq = np.asarray(gen.rng_sequence("sobol", BITS, PERIOD, dim=dim))
        assert sorted(seq.tolist()) == list(range(PERIOD))


def test_operand_dims_give_distinct_sequences():
    a = np.asarray(gen.rng_sequence("sobol", BITS, 64, dim=0, seed=0))
    b = np.asarray(gen.rng_sequence("sobol", BITS, 64, dim=1, seed=0))
    assert (a != b).any()


@pytest.mark.parametrize("kind", ["sobol", "lfsr"])
def test_scan_form_bit_identical(kind):
    # Crossing a period boundary exercises the per-period reseeding too.
    period = PERIOD if kind == "sobol" else PERIOD - 1
    length = period + 17
    vec = np.asarray(gen.rng_sequence(kind, BITS, length, dim=1, seed=5))
    scan = np.asarray(gen.rng_sequence_scan(kind, BITS, length, dim=1, seed=5))
    assert (vec == scan).all()


def test_bsgen_scan_bit_identical():
    tau = gen.source_gen(jnp.asarray([0.0, 0.25, 0.5, 1.0]), BITS)
    seq = gen.rng_sequence("sobol", BITS, 40, dim=0, seed=2)
    fast = np.asarray(gen.bsgen(tau, seq))
    slow = np.asarray(gen.bsgen_scan(tau, kind="sobol", bits=BITS, length=40,
                                     dim=0, seed=2))
    assert (fast == slow).all()


# ---------------------------------------------------------------------------
# SourceGen / BSGen / decode
# ---------------------------------------------------------------------------

def test_unipolar_full_period_exact():
    # Over one full Sobol period the sequence is a permutation, so the
    # stream carries exactly tau ones: every unipolar constant decodes back
    # exactly (the L = 2^bits convergence point).
    probs = jnp.arange(PERIOD + 1, dtype=jnp.float32) / PERIOD
    tau = gen.source_gen(probs, BITS)
    seq = gen.rng_sequence("sobol", BITS, PERIOD, dim=0, seed=7)
    counts = gen.bsgen(tau, seq).astype(jnp.int32).sum(axis=0)
    assert (np.asarray(counts) == np.asarray(tau)).all()
    decoded = gen.decode_counts(counts, PERIOD)
    np.testing.assert_allclose(np.asarray(decoded), np.asarray(probs),
                               atol=1e-7)


def test_bipolar_encode_decode_roundtrip():
    vals = jnp.asarray([-1.0, -0.5, 0.0, 0.5, 1.0])
    tau = gen.source_gen(vals, BITS, mode="bipolar")
    seq = gen.rng_sequence("sobol", BITS, PERIOD, dim=0, seed=0)
    counts = gen.bsgen(tau, seq).astype(jnp.int32).sum(axis=0)
    decoded = gen.decode_counts(counts, PERIOD, mode="bipolar")
    np.testing.assert_allclose(np.asarray(decoded), np.asarray(vals),
                               atol=1e-7)


def test_bipolar_xnor_multiplies_values():
    # XNOR on independent full-period streams: rate decodes to x*y.
    x, y = 0.5, -0.75
    ta = gen.source_gen(jnp.asarray([x]), BITS, mode="bipolar")
    tb = gen.source_gen(jnp.asarray([y]), BITS, mode="bipolar")
    sa = gen.bsgen(ta, gen.rng_sequence("sobol", BITS, PERIOD, dim=0))
    sb = gen.bsgen(tb, gen.rng_sequence("sobol", BITS, PERIOD, dim=1))
    prod = gen.bipolar_xnor(sa, sb).astype(jnp.int32).sum(axis=0)
    got = float(gen.decode_counts(prod, PERIOD, mode="bipolar")[0])
    assert abs(got - x * y) < 0.05


def test_unipolar_and_truth_table():
    a = jnp.asarray([0, 0, 1, 1], jnp.int8)
    b = jnp.asarray([0, 1, 0, 1], jnp.int8)
    assert np.asarray(gen.unipolar_and(a, b)).tolist() == [0, 0, 0, 1]
    assert np.asarray(gen.bipolar_xnor(a, b)).tolist() == [1, 0, 0, 1]


# ---------------------------------------------------------------------------
# Stochastic GEMM engine
# ---------------------------------------------------------------------------

def _codes(rows, cols, seed):
    rng = np.random.default_rng(seed)
    v = vmax(BITS)
    return jnp.asarray(rng.integers(-v, v + 1, (rows, cols)), jnp.int8)


def test_stochastic_gemm_seeded_determinism():
    a, b = _codes(4, 32, 0), _codes(32, 8, 1)
    x = sgemm.stochastic_gemm(a, b, BITS, stream_len=32, seed=0)
    y = sgemm.stochastic_gemm(a, b, BITS, stream_len=32, seed=0)
    z = sgemm.stochastic_gemm(a, b, BITS, stream_len=32, seed=1)
    assert (np.asarray(x) == np.asarray(y)).all()
    assert (np.asarray(x) != np.asarray(z)).any()


def test_stochastic_gemm_error_under_tail_bound():
    a, b = _codes(4, 64, 2), _codes(64, 16, 3)
    oracle = gemm_sims.ugemm_exact(a, b, bits=BITS)
    for L in (16, 64, 256):
        est = sgemm.stochastic_gemm(a, b, BITS, stream_len=L)
        rel = gemm_sims.rel_rmse(est, oracle)
        assert rel <= ranges.stochastic_error_bound(BITS, L).tail


def test_stream_form_returns_stream_len_cycles():
    a, b = _codes(2, 16, 4), _codes(16, 4, 5)
    est, cycles = sgemm.stochastic_gemm_stream(a, b, BITS, stream_len=48)
    assert cycles == 48
    assert (np.asarray(est)
            == np.asarray(sgemm.stochastic_gemm(a, b, BITS,
                                                stream_len=48))).all()


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 63))
def test_rmse_monotone_in_stream_length(seed):
    # 4x stream-length jumps with the default Sobol engine: the measured
    # error must not increase (quadrupling the sample count dominates the
    # seed-to-seed noise that 2x jumps can leave visible).
    curve = stoch_error.rmse_curve(BITS, (16, 64, 256), m=4, k=64, n=16,
                                   seed=seed)
    vals = [r for _, r in curve]
    assert all(b <= a + 1e-9 for a, b in zip(vals, vals[1:])), vals


def test_site_rmse_curve_matches_measured_scale():
    rng = np.random.default_rng(0)
    w = rng.normal(size=(64, 16)).astype(np.float32)
    curve = dict(stoch_error.site_rmse_curve(w, BITS, (16, 128), rows=4))
    assert set(curve) == {16, 128}
    assert 0.0 < curve[128] < curve[16] < 1.0


# ---------------------------------------------------------------------------
# UnaryLinear scaled accumulation
# ---------------------------------------------------------------------------

def test_unary_linear_acc_bookkeeping():
    acc = sgemm.UnaryLinearAcc(in_features=8)
    assert acc.acc_bound == 8 and acc.offset == 0.0
    accb = sgemm.UnaryLinearAcc(in_features=8, bias=True, bipolar=True)
    assert accb.acc_bound == 9
    assert accb.offset == (8 - 1) / 2 + 0.5


def test_scaled_output_stream_preserves_rate():
    # k parallel streams with rates p_k folded through the rate divider:
    # output 1-rate -> sum(p_k) / acc_bound.
    probs = jnp.asarray([0.25, 0.5, 0.125, 0.75])
    tau = gen.source_gen(probs, BITS)
    bits_in = gen.bsgen(tau, gen.rng_sequence("sobol", BITS, PERIOD, dim=0))
    acc = sgemm.UnaryLinearAcc(in_features=4)
    out = sgemm.scaled_output_stream(bits_in, acc)
    assert out.shape == (PERIOD,)
    got = float(jnp.sum(out.astype(jnp.int32))) / PERIOD
    want = float(jnp.sum(probs)) / acc.acc_bound
    assert abs(got - want) < 2.0 / PERIOD


# ---------------------------------------------------------------------------
# Backend contract
# ---------------------------------------------------------------------------

def test_resolve_stochastic_backend_defaults():
    be = backends.resolve("ugemm_stochastic", bits=BITS)
    assert be.name == "ugemm_stochastic"
    assert be.stream_len == sgemm.default_stream_len(BITS) == PERIOD
    assert be.pricing_design == "ugemm"
    assert be.cycle_scale == 1.0
    assert not be.exact


def test_resolve_spec_string_and_stream_len_kw():
    be = backends.resolve("ugemm_stochastic:64", bits=BITS)
    assert (be.name, be.bits, be.stream_len) == ("ugemm_stochastic", BITS, 64)
    assert be.cycle_scale == 64 / PERIOD
    kw = backends.resolve("ugemm_stochastic", bits=BITS, stream_len=64)
    assert kw.stream_len == 64
    assert be.cycles(common_dim=512) == 64  # k-independent, like uGEMM


def test_resolve_rejects_bad_specs():
    with pytest.raises(ValueError):
        backends.resolve("ugemm_stochastic:zero", bits=BITS)
    with pytest.raises(ValueError):
        backends.resolve("bgemm", bits=BITS, stream_len=64)
    with pytest.raises(ValueError):
        backends.resolve("ugemm_stochastic", bits=BITS, stream_len=0)


def test_backend_execute_and_stream_match_engine():
    be = backends.resolve("ugemm_stochastic:32", bits=BITS)
    a, b = _codes(4, 32, 6), _codes(32, 8, 7)
    want = sgemm.stochastic_gemm(a, b, BITS, stream_len=32)
    assert (np.asarray(be.execute(a, b)) == np.asarray(want)).all()
    _, cycles = be.stream(a, b)
    assert cycles == 32 == be.cycles(common_dim=32)


def test_backend_price_scales_with_stream_len():
    from repro.core.accounting import GemmCall
    calls = [GemmCall(name="probe", m=4, k=256, n_out=64, bit_sparsity=0.3)]
    full = backends.resolve("ugemm_stochastic", bits=BITS) \
        .price(calls, unit_n=64, num_units=4)
    quarter = backends.resolve("ugemm_stochastic:64", bits=BITS) \
        .price(calls, unit_n=64, num_units=4)
    assert quarter.wc_energy_uj == pytest.approx(full.wc_energy_uj / 4)
    assert quarter.dyn_latency_us == pytest.approx(full.dyn_latency_us / 4)
    ugemm = backends.resolve("ugemm", bits=BITS) \
        .price(calls, unit_n=64, num_units=4)
    assert full.wc_energy_uj == pytest.approx(ugemm.wc_energy_uj)


def test_available_lists_stochastic_family():
    assert "ugemm_stochastic" in backends.available()


def test_execution_records_stream_len():
    from repro.models import common
    x = jnp.asarray(np.random.default_rng(0).normal(size=(1, 2, 16)),
                    jnp.float32)
    w = jnp.asarray(np.random.default_rng(1).normal(size=(16, 8)),
                    jnp.float32)
    with backends.use_backend("ugemm_stochastic", bits=BITS,
                              stream_len=32) as ex:
        common.dense(w, x, name="probe")
    assert ex.calls and ex.calls[0].stream_len == 32


# ---------------------------------------------------------------------------
# Plans, lint, planner
# ---------------------------------------------------------------------------

def _entry(**kw):
    base = dict(pattern="layers/attn/wq", design="ugemm_stochastic", bits=8,
                stream_len=32)
    base.update(kw)
    return backends.SiteAssignment(**base)


def test_plan_roundtrip_preserves_stream_len():
    plan = backends.BackendPlan(
        sites=(_entry(), _entry(pattern="lm_head", design="bgemm", bits=4,
                                stream_len=0)),
        meta=(("max_rel_mse", 0.05),))
    back = backends.BackendPlan.from_json(plan.to_json())
    assert back == plan
    assert back.sites[0].stream_len == 32
    assert back.sites[0].engine_label == "ugemm_stochastic@8:32"
    assert back.sites[1].engine_label == "bgemm@4"
    assert back.distinct_engines() == (("bgemm", 4, 0),
                                       ("ugemm_stochastic", 8, 32))


def test_plan_entry_backend_carries_stream_len():
    be = _entry().backend()
    assert be.stream_len == 32 and be.name == "ugemm_stochastic"


def test_lint_flags_stream_len_on_exact_design():
    plan = backends.BackendPlan(sites=(_entry(design="bgemm", bits=4),))
    found = plan_lint.lint_plan(plan)
    assert any(f.rule == "invalid-stream" and f.severity == "error"
               for f in found)


def test_lint_flags_guard_violating_stream_len():
    # Analytic expected error at L=4 far exceeds a 0.05 rel-MSE guard.
    plan = backends.BackendPlan(sites=(_entry(stream_len=4),),
                                meta=(("max_rel_mse", 0.05),))
    found = plan_lint.lint_plan(plan)
    assert any(f.rule == "stream-guard" and f.severity == "error"
               for f in found)
    # The same entry with the guard relaxed (or no guard) passes.
    relaxed = backends.BackendPlan(
        sites=(_entry(stream_len=4, guard_relaxed=True),),
        meta=(("max_rel_mse", 0.05),))
    assert not [f for f in plan_lint.lint_plan(relaxed)
                if f.rule == "stream-guard"]


def test_lint_accepts_guard_satisfying_stream_len():
    plan = backends.BackendPlan(sites=(_entry(stream_len=256),),
                                meta=(("max_rel_mse", 0.05),))
    assert not [f for f in plan_lint.lint_plan(plan)
                if f.rule in ("stream-guard", "invalid-stream")]


def test_stochastic_error_bound_shape():
    b16 = ranges.stochastic_error_bound(8, 16)
    b256 = ranges.stochastic_error_bound(8, 256)
    assert b16.expected > b256.expected > 0.0
    assert b16.tail == pytest.approx(2 * b16.expected)
    assert b16.expected_rel_mse == pytest.approx(b16.expected ** 2)
    with pytest.raises(ValueError):
        ranges.stochastic_error_bound(8, 0)


def test_envelope_threads_stream_len():
    full = ranges.max_safe_k("ugemm_stochastic", 8)
    short = ranges.max_safe_k("ugemm_stochastic", 8, stream_len=16)
    assert short >= full  # shorter streams accumulate smaller counts
    bound = ranges.accumulator_bound("ugemm_stochastic", 8, k=64,
                                     stream_len=16)
    assert bound.stream_len == 16 and "L=16" in bound.describe()


def test_planner_emits_stochastic_candidates():
    from repro import configs
    from repro.eval import planner
    from repro.models import model as model_lib
    cfg = configs.get_smoke_config("llama3-8b")
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    site = planner.discover_sites(cfg, params, batch=2)[0]
    designs = planner.DEFAULT_DESIGNS + (planner.STOCHASTIC_DESIGN,)
    cands = planner.site_candidates(
        site, bits_candidates=(8,), designs=designs, unit_n=64, num_units=16,
        stream_lens=(64, 256))
    sto = [c for c in cands if c.design == planner.STOCHASTIC_DESIGN]
    assert sto, "no stochastic candidates emitted"
    assert {c.stream_len for c in sto} <= {64, 256}
    exact8 = [c for c in cands if c.design != planner.STOCHASTIC_DESIGN
              and c.bits == 8]
    # Combined guard: stream error adds variance on top of quantization.
    assert all(c.rel_mse > min(e.rel_mse for e in exact8) for c in sto)
    longer = {c.stream_len: c.rel_mse for c in sto}
    if {64, 256} <= set(longer):
        assert longer[256] <= longer[64]
