"""Sweet-spot explorer: sweep pricing, winners, frontiers, reports, serving."""

import json

import pytest

import conftest
from repro.core import ppa
from repro.core.accounting import GemmWorkloadRecorder
from repro.eval import report as report_lib
from repro.eval import sweetspot as ss

# kernel_crosscheck scopes its *_pallas registration (backends.kernel_backends
# restores the registry); this fixture is defense-in-depth should that change
_registry = pytest.fixture(autouse=True, scope="module")(
    conftest.restore_design_registry)


@pytest.fixture(scope="module")
def points():
    return ss.sweep()


@pytest.fixture(scope="module")
def full_report():
    return ss.build_report(crosscheck=True)


class TestSweep:
    def test_covers_full_cross_product(self, points):
        keys = {(p.design, p.bits, p.n) for p in points}
        assert len(points) == len(keys) == \
            len(ss.CALIBRATED_DESIGNS) * len(ss.DEFAULT_BITS) * len(ss.DEFAULT_SIZES)

    def test_grid_points_exact_vs_paper(self, points):
        """On-grid sweep values are the published Table I/II numbers."""
        for p in points:
            if not p.on_grid:
                continue
            assert p.area_um2 == ppa.AREA_UM2[(p.bits, p.n)][p.design]
            assert p.power_mw == ppa.POWER_MW[(p.bits, p.n)][p.design]

    def test_grid_fidelity(self, points):
        errs = ss.grid_fidelity(points)
        assert errs["area_um2"] == 0.0
        assert errs["power_mw"] == 0.0
        assert errs["energy_nj"] < 0.01     # paper rounding
        assert errs["adp_mm2_ns"] < 0.01

    def test_offgrid_flagged(self, points):
        flags = {(p.bits, p.n): p.on_grid for p in points}
        assert flags[(4, 64)] and flags[(8, 32)]
        assert not flags[(2, 64)] and not flags[(8, 256)]

    def test_wc_cycles_attached(self, points):
        for p in points:
            if p.design == "tubgemm":
                assert p.wc_cycles == p.n * 2 ** (p.bits - 2)


class TestWinners:
    def test_every_cell_every_metric_has_winner(self, points):
        ws = ss.winners(points)
        cells = len(ss.DEFAULT_BITS) * len(ss.DEFAULT_SIZES)
        assert len(ws) == cells * len(ss.METRICS)
        for w in ws:
            assert w.design in ss.CALIBRATED_DESIGNS
            assert w.margin >= 1.0
            assert w.value == min(w.values.values())

    def test_paper_takeaways(self, points):
        """The sweep reproduces the paper's §IV conclusions."""
        grid = ss.winner_grid(points)
        # tuGEMM wins area everywhere
        assert all(w.design == "tugemm" for w in grid["area_um2"].values())
        # tubGEMM most energy-efficient at 2-bit, bGEMM at 8-bit
        for n in ss.DEFAULT_SIZES:
            assert grid["energy_nj"][(2, n)].design == "tubgemm"
            assert grid["energy_nj"][(8, n)].design == "bgemm"
        # the 4-bit energy sweet spot flips to tubGEMM at CloudTPUv3 size
        assert grid["energy_nj"][(4, 64)].design == "bgemm"
        assert grid["energy_nj"][(4, 128)].design == "tubgemm"

    def test_crossovers_consistent_with_winners(self, points):
        grid = ss.winner_grid(points)
        for c in ss.crossovers(points):
            assert grid[c.metric][(c.bits, c.n_below)].design == c.from_design
            assert grid[c.metric][(c.bits, c.n_at)].design == c.to_design
        # the paper's 4-bit energy crossover is found
        assert any(c.metric == "energy_nj" and c.bits == 4 and
                   c.to_design == "tubgemm" and c.n_at == 128
                   for c in ss.crossovers(points))


class TestKernelCrosscheck:
    def test_kernels_match_simulators_and_cycle_model(self, full_report):
        assert full_report.kernel_crosscheck, "crosscheck ran"
        for row in full_report.kernel_crosscheck:
            assert row["output_ok"], row
            assert row["cycles_ok"], row
            assert row["kernel_cycles"] == row["sim_cycles"] == row["wc_cycles"]

    def test_crosscheck_does_not_leak_registry_state(self):
        """The scoped registration restores gemm_sims.DESIGNS afterwards."""
        from repro.core import gemm_sims
        before = gemm_sims.DESIGNS
        ss.kernel_crosscheck(bits_list=(2,))
        assert gemm_sims.DESIGNS == before


class TestReport:
    def test_json_roundtrip(self, full_report):
        doc = json.loads(report_lib.to_json(full_report))
        assert doc["schema"] == "repro.eval.sweetspot/v1"
        assert len(doc["points"]) == len(full_report.points)
        assert {w["metric"] for w in doc["winners"]} == set(ss.METRICS)

    def test_markdown_names_winners(self, full_report):
        md = report_lib.to_markdown(full_report)
        for metric in ss.METRICS:
            assert f"### {metric}" in md
        assert "tubgemm" in md and "Crossover frontier" in md
        assert "Pallas kernel cross-check" in md

    def test_write_emits_both_files(self, full_report, tmp_path):
        json_path, md_path = report_lib.write(full_report, str(tmp_path))
        assert json.load(open(json_path))["points"]
        assert "Sweet-spot report" in open(md_path).read()


class TestRecommendBackend:
    def test_picks_cheapest_design_for_workload(self):
        rec = GemmWorkloadRecorder()
        rec.record("fc1", m=8, k=256, n_out=512, bit_sparsity=0.3)
        rec.record("attn", m=8, k=512, n_out=512, bit_sparsity=0.1)
        out = ss.recommend_backend(rec.calls, bits=4, unit_n=128)
        for objective, res in out.items():
            ranking = res["ranking"]
            assert res["best"] == ranking[0][0]
            vals = [v for _, v in ranking]
            assert vals == sorted(vals)
            assert {d for d, _ in ranking} == set(ss.CALIBRATED_DESIGNS)
        # 4-bit large-k workload: tubgemm should beat tugemm on energy
        e = dict(out["dyn_energy_uj"]["ranking"])
        assert e["tubgemm"] < e["tugemm"]
