"""Model substrate: sequence-mixing kernels vs oracles, block behaviours."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as A
from repro.models import rwkv as R
from repro.models import ssm as S
from repro.models.common import init_tree
from repro.models.config import ModelConfig, MoEConfig, RWKVConfig, SSMConfig


class TestAttention:
    def test_blockwise_equals_naive(self, rng):
        q = jnp.asarray(rng.normal(0, 1, (2, 64, 4, 16)), jnp.float32)
        k = jnp.asarray(rng.normal(0, 1, (2, 64, 4, 16)), jnp.float32)
        v = jnp.asarray(rng.normal(0, 1, (2, 64, 4, 16)), jnp.float32)
        o1 = A.naive_attention(q, k, v, causal=True)
        o2 = A.blockwise_attention(q, k, v, causal=True, q_chunk=16, kv_chunk=16)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   rtol=1e-5, atol=1e-5)

    def test_blockwise_noncausal(self, rng):
        q = jnp.asarray(rng.normal(0, 1, (1, 32, 2, 8)), jnp.float32)
        kv = jnp.asarray(rng.normal(0, 1, (1, 32, 2, 8)), jnp.float32)
        o1 = A.naive_attention(q, kv, kv, causal=False)
        o2 = A.blockwise_attention(q, kv, kv, causal=False, q_chunk=8, kv_chunk=8)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   rtol=1e-5, atol=1e-5)

    def test_gqa_repeat_matches_full_heads(self, rng):
        """KV-head repetition == attention with explicitly tiled KV."""
        q = jnp.asarray(rng.normal(0, 1, (1, 16, 4, 8)), jnp.float32)
        k2 = jnp.asarray(rng.normal(0, 1, (1, 16, 2, 8)), jnp.float32)
        v2 = jnp.asarray(rng.normal(0, 1, (1, 16, 2, 8)), jnp.float32)
        k4 = jnp.repeat(k2, 2, axis=2)
        v4 = jnp.repeat(v2, 2, axis=2)
        out = A.naive_attention(q, k4, v4, causal=True)
        assert out.shape == (1, 16, 4, 8)

    def test_causal_mask_blocks_future(self, rng):
        """Changing future tokens must not change past outputs."""
        q = jnp.asarray(rng.normal(0, 1, (1, 8, 2, 4)), jnp.float32)
        k = jnp.asarray(rng.normal(0, 1, (1, 8, 2, 4)), jnp.float32)
        v = jnp.asarray(rng.normal(0, 1, (1, 8, 2, 4)), jnp.float32)
        o1 = A.naive_attention(q, k, v, causal=True)
        k2 = k.at[:, -1].set(99.0)
        v2 = v.at[:, -1].set(-99.0)
        o2 = A.naive_attention(q, k2, v2, causal=True)
        np.testing.assert_allclose(np.asarray(o1[:, :-1]), np.asarray(o2[:, :-1]),
                                   rtol=1e-6)


class TestSSM:
    @pytest.mark.parametrize("chunk", [4, 8, 7, 24])
    def test_chunked_equals_recurrent(self, rng, chunk):
        B, Sq, H, P, G, N = 2, 24, 4, 8, 2, 6
        x = jnp.asarray(rng.normal(0, 1, (B, Sq, H, P)), jnp.float32)
        dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, Sq, H)), jnp.float32)
        a = -jnp.asarray(rng.uniform(0.5, 2.0, (H,)), jnp.float32)
        b = jnp.asarray(rng.normal(0, 1, (B, Sq, G, N)), jnp.float32)
        c = jnp.asarray(rng.normal(0, 1, (B, Sq, G, N)), jnp.float32)
        y_ref, st_ref = S.ssd_recurrent_ref(x, dt, a, b, c)
        y, st_ = S.ssd_chunked(x, dt, a, b, c, chunk)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(st_), np.asarray(st_ref),
                                   rtol=1e-4, atol=1e-5)

    def test_state_carry_across_calls(self, rng):
        """Splitting a sequence across two chunked calls == one call."""
        B, Sq, H, P, G, N = 1, 16, 2, 4, 1, 4
        x = jnp.asarray(rng.normal(0, 1, (B, Sq, H, P)), jnp.float32)
        dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, Sq, H)), jnp.float32)
        a = -jnp.asarray(rng.uniform(0.5, 2.0, (H,)), jnp.float32)
        b = jnp.asarray(rng.normal(0, 1, (B, Sq, G, N)), jnp.float32)
        c = jnp.asarray(rng.normal(0, 1, (B, Sq, G, N)), jnp.float32)
        y_full, st_full = S.ssd_chunked(x, dt, a, b, c, 8)
        y1, st1 = S.ssd_chunked(x[:, :8], dt[:, :8], a, b[:, :8], c[:, :8], 8)
        y2, st2 = S.ssd_chunked(x[:, 8:], dt[:, 8:], a, b[:, 8:], c[:, 8:], 8,
                                init_state=st1)
        np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                                   np.asarray(y_full), rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full),
                                   rtol=1e-4, atol=1e-5)

    def test_block_decode_matches_full(self, rng):
        cfg = ModelConfig(d_model=32, family="ssm", attention="none",
                          ssm=SSMConfig(state_dim=8, head_dim=8, expand=2,
                                        n_groups=2, chunk=8), remat=False)
        params = init_tree(S.ssm_defs(cfg), jax.random.PRNGKey(0), jnp.float32)
        x = jnp.asarray(rng.normal(0, 1, (2, 12, 32)), jnp.float32)
        out_full, _ = S.ssm_fwd(params, x, cfg)
        cache = S.init_ssm_cache(cfg, 2)
        outs = []
        for t in range(12):
            o, cache = S.ssm_fwd(params, x[:, t:t + 1], cfg, cache=cache)
            outs.append(o)
        np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                                   np.asarray(out_full), rtol=1e-4, atol=1e-5)


class TestRWKV:
    @pytest.mark.parametrize("chunk", [4, 5, 32])
    def test_chunked_equals_recurrent(self, rng, chunk):
        B, Sq, H, K = 2, 20, 3, 8
        r = jnp.asarray(rng.normal(0, 1, (B, Sq, H, K)), jnp.float32)
        k = jnp.asarray(rng.normal(0, 1, (B, Sq, H, K)), jnp.float32)
        v = jnp.asarray(rng.normal(0, 1, (B, Sq, H, K)), jnp.float32)
        logw = -jnp.asarray(rng.uniform(0.05, 1.5, (B, Sq, H, K)), jnp.float32)
        u = jnp.asarray(rng.normal(0, 0.3, (H, K)), jnp.float32)
        y_ref, st_ref = R.wkv_recurrent_ref(r, k, v, logw, u)
        y, st_ = R.wkv_chunked(r, k, v, logw, u, chunk=chunk)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(st_), np.asarray(st_ref),
                                   rtol=1e-4, atol=1e-4)

    def test_block_decode_matches_full(self, rng):
        cfg = ModelConfig(d_model=24, d_ff=64, family="ssm", attention="none",
                          rwkv=RWKVConfig(head_dim=8, decay_lora=4), remat=False)
        params = init_tree(R.rwkv_defs(cfg), jax.random.PRNGKey(0), jnp.float32)
        x = jnp.asarray(rng.normal(0, 1, (2, 10, 24)), jnp.float32)
        out_full, _ = R.rwkv_block_fwd(params, x, cfg)
        cache = R.init_rwkv_cache(cfg, 2)
        outs = []
        for t in range(10):
            o, cache = R.rwkv_block_fwd(params, x[:, t:t + 1], cfg, cache=cache)
            outs.append(o)
        np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                                   np.asarray(out_full), rtol=2e-4, atol=2e-4)


class TestMoE:
    def _cfg(self, ep_impl="psum", cf=8.0):
        return ModelConfig(
            family="moe", d_model=32, d_ff=64, num_heads=2, num_kv_heads=2,
            vocab_size=64, remat=False,
            moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=32,
                          capacity_factor=cf, ep_impl=ep_impl))

    def _dense_ref(self, params, x, cfg):
        """No-capacity dense reference: every token x its top-k experts."""
        from repro.models.moe import _routing
        b, s, d = x.shape
        xf = x.reshape(-1, d)
        idx, w, _ = _routing(params["router"], xf, cfg)
        out = jnp.zeros_like(xf)
        for e in range(cfg.moe.num_experts):
            h = jax.nn.silu(xf @ params["w_gate"][e]) * (xf @ params["w_up"][e])
            y = h @ params["w_down"][e]
            we = jnp.sum(jnp.where(idx == e, w, 0.0), axis=-1)[:, None]
            out = out + y * we.astype(y.dtype)
        return out.reshape(b, s, d)

    def test_capacity_pass_matches_dense_ref(self, rng):
        """With ample capacity, the EP path == the dense reference."""
        from repro.models import moe as M
        cfg = self._cfg()
        params = init_tree(M.moe_defs(cfg), jax.random.PRNGKey(1), jnp.float32)
        x = jnp.asarray(rng.normal(0, 1, (2, 8, 32)), jnp.float32)
        out, aux = M.moe_fwd(params, x, cfg)
        want = self._dense_ref(params, x, cfg)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-4, atol=1e-5)
        assert float(aux) > 0.0

    def test_capacity_drops_tokens(self, rng):
        """Tiny capacity must drop load -> different (smaller) output norm."""
        from repro.models import moe as M
        cfg_hi = self._cfg(cf=8.0)
        cfg_lo = self._cfg(cf=0.1)
        params = init_tree(M.moe_defs(cfg_hi), jax.random.PRNGKey(1), jnp.float32)
        x = jnp.asarray(rng.normal(0, 1, (2, 32, 32)), jnp.float32)
        hi, _ = M.moe_fwd(params, x, cfg_hi)
        lo, _ = M.moe_fwd(params, x, cfg_lo)
        assert float(jnp.linalg.norm(lo)) < float(jnp.linalg.norm(hi))
