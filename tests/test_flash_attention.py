"""Flash-attention Pallas kernel vs the jnp oracle: values AND gradients,
shape/dtype/causality sweeps, interpret mode (CPU container; TPU target)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.models.attention import naive_attention


def qkv(rng, b, s, h, d, dtype=jnp.float32):
    mk = lambda: jnp.asarray(rng.normal(0, 1, (b, s, h, d)), dtype)
    return mk(), mk(), mk()


class TestForward:
    @pytest.mark.parametrize("causal", [True, False])
    @pytest.mark.parametrize("shape", [(1, 128, 2, 32), (2, 256, 4, 16),
                                       (2, 64, 1, 64)])
    def test_matches_naive(self, rng, causal, shape):
        b, s, h, d = shape
        q, k, v = qkv(rng, b, s, h, d)
        got = flash_attention(q, k, v, causal=causal, bq=64, bk=64,
                              interpret=True)
        want = naive_attention(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    def test_block_shape_independence(self, rng):
        q, k, v = qkv(rng, 1, 256, 2, 32)
        outs = [np.asarray(flash_attention(q, k, v, causal=True, bq=bq, bk=bk,
                                           interpret=True))
                for bq, bk in ((64, 64), (128, 64), (256, 128), (256, 256))]
        for o in outs[1:]:
            np.testing.assert_allclose(o, outs[0], rtol=2e-5, atol=2e-5)

    def test_bf16(self, rng):
        q, k, v = qkv(rng, 1, 128, 2, 32, jnp.bfloat16)
        got = flash_attention(q, k, v, causal=True, bq=64, bk=64,
                              interpret=True)
        want = naive_attention(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=3e-2, atol=3e-2)


class TestBackward:
    @pytest.mark.parametrize("causal", [True, False])
    def test_grads_match_naive(self, rng, causal):
        b, s, h, d = 1, 128, 2, 32
        q, k, v = qkv(rng, b, s, h, d)

        def f_kernel(q, k, v):
            return jnp.sum(flash_attention(q, k, v, causal=causal, bq=64,
                                           bk=64, interpret=True) ** 2)

        def f_naive(q, k, v):
            return jnp.sum(naive_attention(q, k, v, causal=causal) ** 2)

        g1 = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
        g2 = jax.grad(f_naive, argnums=(0, 1, 2))(q, k, v)
        for a, b_ in zip(g1, g2):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       rtol=2e-4, atol=2e-4)

    def test_grad_block_independence(self, rng):
        q, k, v = qkv(rng, 1, 128, 1, 16)

        def loss(bq):
            def f(q, k, v):
                return jnp.sum(flash_attention(q, k, v, causal=True, bq=bq,
                                               bk=bq, interpret=True) ** 2)
            return jax.grad(f)(q, k, v)

        g64 = loss(64)
        g128 = loss(128)
        np.testing.assert_allclose(np.asarray(g64), np.asarray(g128),
                                   rtol=1e-4, atol=1e-4)


class TestRaggedLengths:
    """Seq lens that do not divide the block size: the wrapper pads q/k/v to
    block multiples and masks the padded keys inside the kernel (it used to
    raise).  Values and grads must agree with the unpadded oracle."""

    @pytest.mark.parametrize("causal", [True, False])
    @pytest.mark.parametrize("shape", [(1, 20, 2, 16), (2, 49, 1, 32),
                                       (1, 10, 2, 16)])
    def test_matches_naive(self, rng, causal, shape):
        b, s, h, d = shape
        q, k, v = qkv(rng, b, s, h, d)
        got = flash_attention(q, k, v, causal=causal, bq=16, bk=16,
                              interpret=True)
        assert got.shape == q.shape
        want = naive_attention(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    def test_cross_lengths(self, rng):
        """q and kv lengths ragged independently (non-causal)."""
        q = jnp.asarray(rng.normal(0, 1, (1, 10, 2, 16)), jnp.float32)
        k = jnp.asarray(rng.normal(0, 1, (1, 26, 2, 16)), jnp.float32)
        v = jnp.asarray(rng.normal(0, 1, (1, 26, 2, 16)), jnp.float32)
        got = flash_attention(q, k, v, causal=False, bq=16, bk=16,
                              interpret=True)
        want = naive_attention(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    def test_padded_agrees_with_exact_multiple(self, rng):
        """Regression for the pad+mask path itself: a ragged (s=20) call and
        the same data embedded in an exact-multiple call agree on the valid
        prefix."""
        q, k, v = qkv(rng, 1, 32, 2, 16)
        ragged = flash_attention(q[:, :20], k[:, :20], v[:, :20], causal=True,
                                 bq=16, bk=16, interpret=True)
        full = naive_attention(q[:, :20], k[:, :20], v[:, :20], causal=True)
        np.testing.assert_allclose(np.asarray(ragged), np.asarray(full),
                                   rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("causal", [True, False])
    def test_grads_match_naive(self, rng, causal):
        q, k, v = qkv(rng, 1, 20, 2, 16)

        def f_kernel(q, k, v):
            return jnp.sum(flash_attention(q, k, v, causal=causal, bq=16,
                                           bk=16, interpret=True) ** 2)

        def f_naive(q, k, v):
            return jnp.sum(naive_attention(q, k, v, causal=causal) ** 2)

        g1 = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
        g2 = jax.grad(f_naive, argnums=(0, 1, 2))(q, k, v)
        for a, b_ in zip(g1, g2):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       rtol=2e-4, atol=2e-4)
