"""The typed GEMM backend API: resolve/conformance, scoped execution through
the model, registry snapshot/restore, and the deprecation shims."""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import conftest
from repro import backends
from repro.core import gemm_sims as gs
from repro.core.accounting import GemmCall, ModelCost
from repro.core.quantization import quantize, vmax
from repro.models import common

# Some tests exercise the registry-mutating legacy surface; never leak.
_registry = pytest.fixture(autouse=True, scope="module")(
    conftest.restore_design_registry)


@pytest.fixture()
def rng():
    # module-local stream: don't consume the session rng — downstream
    # modules (test_system's stochastic-uGEMM agreement bound) are
    # sensitive to their position in the shared stream
    return np.random.default_rng(1234)

BUILTIN = ("ugemm", "tugemm", "tubgemm", "bgemm")
MIRRORS = ("tugemm_pallas", "tubgemm_pallas")
ALL_BACKENDS = BUILTIN + MIRRORS


def rand_codes(rng, bits, shape):
    v = vmax(bits)
    return jnp.asarray(rng.integers(-v, v + 1, shape), jnp.int8)


def make(name, bits):
    # mirrors run in interpret mode on CPU with a small block
    if name in MIRRORS:
        return backends.resolve(name, bits=bits, block=(32, 32, 32),
                                interpret=True)
    return backends.resolve(name, bits=bits)


class TestResolve:
    def test_metadata(self):
        b = backends.resolve("tubgemm", bits=4)
        assert (b.name, b.bits, b.exact, b.has_synthesis_data,
                b.pricing_design) == ("tubgemm", 4, True, True, "tubgemm")
        u = backends.resolve("ugemm", bits=8)
        assert not u.exact and u.has_synthesis_data
        m = backends.resolve("tubgemm_pallas", bits=4)
        assert m.exact and not m.has_synthesis_data
        assert m.pricing_design == "tubgemm"

    def test_mirrors_resolve_without_registry_mutation(self):
        before = gs.DESIGNS
        for name in MIRRORS:
            make(name, 4)
        assert gs.DESIGNS == before == BUILTIN

    def test_backend_instance_passthrough_and_rebits(self):
        b4 = backends.resolve("tubgemm", bits=4)
        assert backends.resolve(b4) is b4
        b8 = backends.resolve(b4, bits=8)
        assert b8.bits == 8 and b8.name == "tubgemm"

    def test_equal_construction_args_compare_equal(self):
        # includes the mirrors: spec closures are excluded from equality
        for name in ALL_BACKENDS:
            assert make(name, 4) == make(name, 4)
        assert make("tubgemm", 4) != make("tubgemm", 8)
        assert make("tubgemm", 4) != make("tugemm", 4)
        assert len({make(n, 4) for n in ALL_BACKENDS}) == len(ALL_BACKENDS)

    def test_re_resolving_mirror_keeps_other_kernel_knob(self):
        b = backends.resolve("tubgemm_pallas", bits=4, block=(32, 32, 32))
        assert b.block == (32, 32, 32) and b.interpret is None
        b2 = backends.resolve(b, interpret=True)
        assert b2.block == (32, 32, 32) and b2.interpret is True
        b3 = backends.resolve(b2, block=(64, 64, 64))
        assert b3.block == (64, 64, 64) and b3.interpret is True

    def test_unknown_name_raises_with_available_list(self):
        with pytest.raises(ValueError, match="unknown design"):
            backends.resolve("nope", bits=4)
        with pytest.raises(ValueError, match="tubgemm_pallas"):
            backends.resolve("nope", bits=4)

    def test_kernel_knobs_rejected_for_simulated_designs(self):
        with pytest.raises(ValueError, match="Pallas-kernel knobs"):
            backends.resolve("tubgemm", bits=4, interpret=True)

    def test_bad_bits_rejected(self):
        with pytest.raises(ValueError, match="bits"):
            backends.resolve("tubgemm", bits=1)

    def test_available_lists_builtin_plus_mirrors(self):
        # the stochastic family is always constructible, hence always listed
        assert backends.available() == ALL_BACKENDS + ("ugemm_stochastic",)

    def test_runtime_registered_design_resolvable(self):
        with gs.scoped_registry():
            gs.register_design("twice_bgemm",
                               exact_fn=lambda a, b, bits: 2 * gs.bgemm_exact(a, b),
                               stream_fn=lambda a, b, bits: (2 * gs.bgemm_exact(a, b), 9),
                               wc_cycles_fn=lambda bits, k: 9)
            b = backends.resolve("twice_bgemm", bits=4)
            assert not b.has_synthesis_data and b.pricing_design == "twice_bgemm"
            a = jnp.ones((2, 3), jnp.int8)
            assert bool(jnp.all(b.execute(a, a.T) == 2 * gs.bgemm_exact(a, a.T)))


class TestConformance:
    """One shared execute/cycles/price contract for all six backends."""

    @pytest.mark.parametrize("bits", [2, 4, 8])
    @pytest.mark.parametrize("name", ALL_BACKENDS)
    def test_execute_cycles_price(self, rng, name, bits):
        before = gs.DESIGNS
        b = make(name, bits)
        m, k, n = 4, 8, 5
        a = rand_codes(rng, bits, (m, k))
        w = rand_codes(rng, bits, (k, n))
        out = b.execute(a, w)
        assert out.shape == (m, n)
        oracle = gs.bgemm_exact(a, w)
        if b.exact:
            assert bool(jnp.all(out == oracle))
        else:
            assert gs.rel_rmse(out, oracle) < 0.5
        # stream: (out, cycles), cycles == the worst-case model
        s_out, cycles = b.stream(a, w)
        assert int(cycles) == b.cycles(k) == gs.wc_cycles(b.pricing_design,
                                                          bits, k)
        np.testing.assert_array_equal(np.asarray(s_out), np.asarray(out))
        # price: every backend prices through its calibrated design
        cost = b.price([GemmCall("l", 4, 64, 64, 0.25)], unit_n=64)
        assert isinstance(cost, ModelCost)
        assert cost.design == b.pricing_design and cost.bits == bits
        assert cost.dyn_energy_uj > 0
        # none of the above touched the global registry
        assert gs.DESIGNS == before

    @pytest.mark.parametrize("name", ALL_BACKENDS)
    def test_batched_execute_matches_per_problem(self, rng, name):
        b = make(name, 4)
        a = jnp.stack([rand_codes(rng, 4, (3, 8)) for _ in range(3)])
        w = jnp.stack([rand_codes(rng, 4, (8, 4)) for _ in range(3)])
        out = b.execute(a, w)
        assert out.shape == (3, 3, 4)
        for i in range(3):
            np.testing.assert_array_equal(np.asarray(out[i]),
                                          np.asarray(b.execute(a[i], w[i])))
        # shared weight operand (the serving case)
        out_shared = b.execute(a, w[0])
        np.testing.assert_array_equal(np.asarray(out_shared[1]),
                                      np.asarray(b.execute(a[1], w[0])))

    def test_dyn_cycles_sources(self, rng):
        b = backends.resolve("tubgemm", bits=4)
        q = rand_codes(rng, 4, (16, 8))
        wc = b.cycles(16)
        assert b.dyn_cycles(16) == float(wc)
        assert b.dyn_cycles(16, bit_sparsity=0.5) == pytest.approx(wc * 0.5)
        measured = b.dyn_cycles(operand=q)
        assert 0 < measured <= wc
        # non-sparsity-aware designs ignore the statistic
        assert backends.resolve("bgemm", bits=4).dyn_cycles(
            16, bit_sparsity=0.9) == 16.0
        with pytest.raises(ValueError, match="not both"):
            b.dyn_cycles(16, bit_sparsity=0.5, operand=q)
        with pytest.raises(ValueError, match="common_dim"):
            b.dyn_cycles(bit_sparsity=0.5)

    def test_price_accepts_recorder(self):
        from repro.core.accounting import GemmWorkloadRecorder
        rec = GemmWorkloadRecorder()
        rec.record("l0", m=2, k=32, n_out=32, bit_sparsity=0.3)
        cost = backends.resolve("tubgemm", bits=4).price(rec, unit_n=32)
        assert cost.total_macs == 2 * 32 * 32


class TestUseBackend:
    def test_scoping_nesting_and_exception_unwind(self):
        assert backends.active_backend() is None
        with backends.use_backend("tubgemm", bits=4) as outer:
            assert backends.active_backend().name == "tubgemm"
            with backends.use_backend("bgemm", bits=8):
                assert backends.active_backend().name == "bgemm"
            assert backends.active_backend() is outer.backend
        assert backends.active_backend() is None
        with pytest.raises(RuntimeError, match="boom"):
            with backends.use_backend("tubgemm", bits=4):
                raise RuntimeError("boom")
        assert backends.active_backend() is None

    def test_dense_contracts_on_backend(self, rng):
        w = jnp.asarray(rng.normal(0, 0.1, (32, 16)), jnp.float32)
        x = jnp.asarray(rng.normal(0, 1.0, (2, 5, 32)), jnp.float32)
        with backends.use_backend("tubgemm", bits=8) as execution:
            out = common.dense(w, x)
        assert execution.calls == [backends.ExecutedGemm(10, 32, 16,
                                                         "tubgemm", 8)]
        # manual reference: quantize both operands, int matmul, dequantize
        wq = quantize(w, bits=8)
        xq = quantize(x.reshape(-1, 32), bits=8, per_channel=False)
        want = (gs.bgemm_exact(xq.values, wq.values).astype(jnp.float32)
                * (xq.scale * wq.scale.reshape(1, -1))).reshape(2, 5, 16)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-6)
        # float path untouched outside the scope
        np.testing.assert_allclose(np.asarray(common.dense(w, x)),
                                   np.asarray(x @ w), rtol=1e-5)

    def test_exact_backends_and_kernel_mirror_agree_in_model(self, rng):
        """Whole-model forward: tubgemm sim and its Pallas mirror produce the
        same quantized execution (identical int GEMMs -> identical logits)."""
        from repro import configs
        from repro.models import model as M
        cfg = configs.get_smoke_config("internlm2-1.8b").replace(
            compute_dtype="float32")
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 8)), jnp.int32)
        ref, _ = M.forward(params, cfg, toks)
        outs = {}
        for name in ("tubgemm", "tugemm", "tubgemm_pallas"):
            with backends.use_backend(make(name, 8)) as execution:
                out, _ = M.forward(params, cfg, toks)
            assert len(execution.calls) > 0
            outs[name] = np.asarray(out)
        np.testing.assert_array_equal(outs["tubgemm"], outs["tugemm"])
        np.testing.assert_array_equal(outs["tubgemm"], outs["tubgemm_pallas"])
        agree = float(np.mean(np.argmax(outs["tubgemm"], -1)
                              == np.argmax(np.asarray(ref), -1)))
        assert agree > 0.5

    def test_jit_traced_inside_scope_executes_backend(self, rng):
        w = jnp.asarray(rng.normal(0, 0.1, (16, 8)), jnp.float32)
        x = jnp.asarray(rng.normal(0, 1.0, (4, 16)), jnp.float32)
        with backends.use_backend("tubgemm", bits=8) as execution:
            out = jax.jit(lambda w, x: common.dense(w, x))(w, x)
            eager = common.dense(w, x)
        assert len(execution.calls) == 2  # one per trace
        np.testing.assert_allclose(np.asarray(out), np.asarray(eager),
                                   rtol=1e-5)


class TestRegistrySnapshot:
    def test_snapshot_restore_roundtrip(self):
        snap = gs.registry_snapshot()
        gs.register_design("tmp_design",
                           exact_fn=lambda a, b, bits: gs.bgemm_exact(a, b),
                           stream_fn=lambda a, b, bits: (gs.bgemm_exact(a, b), 1),
                           wc_cycles_fn=lambda bits, k: 1)
        assert "tmp_design" in gs.DESIGNS
        gs.registry_restore(snap)
        assert gs.DESIGNS == BUILTIN

    def test_scoped_registry_nests_and_survives_exceptions(self):
        def reg(name):
            gs.register_design(name,
                               exact_fn=lambda a, b, bits: gs.bgemm_exact(a, b),
                               stream_fn=lambda a, b, bits: (gs.bgemm_exact(a, b), 1),
                               wc_cycles_fn=lambda bits, k: 1)

        with gs.scoped_registry():
            reg("outer_design")
            with gs.scoped_registry():
                reg("inner_design")
                assert {"outer_design", "inner_design"} <= set(gs.DESIGNS)
            assert "inner_design" not in gs.DESIGNS
            assert "outer_design" in gs.DESIGNS
        assert gs.DESIGNS == BUILTIN
        with pytest.raises(RuntimeError, match="boom"):
            with gs.scoped_registry():
                reg("doomed_design")
                raise RuntimeError("boom")
        assert gs.DESIGNS == BUILTIN

    def test_kernel_backends_context_nests_and_keeps_designs_synced(self):
        """The satellite fix: kernels.backends restore goes through the
        registry API, so gemm_sims.DESIGNS never desyncs from the registry
        contents — nested scopes and exceptions included."""
        from repro.kernels import backends as kb
        assert gs.DESIGNS == BUILTIN
        with kb.kernel_backends(block=(32, 32, 32), interpret=True):
            assert set(MIRRORS) <= set(gs.DESIGNS)
            assert gs.DESIGNS == tuple(gs.registry_snapshot())
            with kb.kernel_backends(interpret=True):  # overwrite + restore
                assert set(MIRRORS) <= set(gs.DESIGNS)
            assert set(MIRRORS) <= set(gs.DESIGNS)  # outer scope intact
            assert gs.DESIGNS == tuple(gs.registry_snapshot())
        assert gs.DESIGNS == BUILTIN
        with pytest.raises(RuntimeError, match="boom"):
            with kb.kernel_backends(interpret=True):
                raise RuntimeError("boom")
        assert gs.DESIGNS == BUILTIN == tuple(gs.registry_snapshot())


class TestDeprecationShims:
    @pytest.fixture(autouse=True)
    def _reset_once_flags(self):
        saved = set(gs._DEPRECATION_EMITTED)
        gs._DEPRECATION_EMITTED.clear()
        yield
        gs._DEPRECATION_EMITTED.clear()
        gs._DEPRECATION_EMITTED.update(saved)

    def _count(self, recorded):
        return sum(issubclass(w.category, DeprecationWarning) for w in recorded)

    @pytest.mark.parametrize("bits", [2, 4, 8])
    @pytest.mark.parametrize("design", BUILTIN)
    def test_shims_bit_identical_to_new_api(self, rng, design, bits):
        backend = backends.resolve(design, bits=bits)
        a, w = rand_codes(rng, bits, (4, 8)), rand_codes(rng, bits, (8, 5))
        ab = jnp.stack([a, a]), jnp.stack([w, w])
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            np.testing.assert_array_equal(np.asarray(gs.gemm(design, a, w, bits)),
                                          np.asarray(backend.execute(a, w)))
            s_old, c_old = gs.stream_gemm(design, a, w, bits)
            s_new, c_new = backend.stream(a, w)
            np.testing.assert_array_equal(np.asarray(s_old), np.asarray(s_new))
            assert int(c_old) == int(c_new)
            np.testing.assert_array_equal(
                np.asarray(gs.gemm_batched(design, *ab, bits)),
                np.asarray(backend.execute(*ab)))

    def test_each_shim_warns_exactly_once(self, rng):
        a, w = rand_codes(rng, 4, (2, 3)), rand_codes(rng, 4, (3, 2))
        for fn in (lambda: gs.gemm("bgemm", a, w, 4),
                   lambda: gs.stream_gemm("bgemm", a, w, 4),
                   lambda: gs.gemm_batched("tubgemm", a, w, 4)):
            with warnings.catch_warnings(record=True) as rec:
                warnings.simplefilter("always")
                fn()
                fn()
            assert self._count(rec) == 1

    def test_register_kernel_backends_warns_once_and_registers(self):
        from repro.kernels import backends as kb
        kb._DEPRECATION_EMITTED = False
        with gs.scoped_registry():
            with warnings.catch_warnings(record=True) as rec:
                warnings.simplefilter("always")
                names = kb.register_kernel_backends(interpret=True)
                assert kb.register_kernel_backends(interpret=True) == names
            assert self._count(rec) == 1
            assert set(names) <= set(gs.DESIGNS)
        assert gs.DESIGNS == BUILTIN


class TestServeExecution:
    @pytest.fixture(scope="class")
    def smoke_model(self):
        from repro import configs
        from repro.models import model as M
        cfg = configs.get_smoke_config("llama3-8b")
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        return cfg, params

    def test_validate_backend_numerics(self, smoke_model):
        from repro.launch.serve import validate_backend_numerics
        cfg, params = smoke_model
        for name in ("tubgemm", "tugemm", "bgemm"):
            assert validate_backend_numerics(params, name, bits=4) == 0.0
        # backend objects work too, defaulting to their own width
        backend = backends.resolve("tubgemm_pallas", bits=4, interpret=True)
        assert validate_backend_numerics(params, backend) == 0.0
        rel = validate_backend_numerics(params, "ugemm", bits=8)
        assert 0.0 < rel < 0.2

    def test_validate_backend_numerics_no_weights(self):
        from repro.launch.serve import validate_backend_numerics
        assert validate_backend_numerics({}, "tubgemm", bits=4) == 0.0

    @pytest.mark.parametrize("name", ["tubgemm", "tugemm", "bgemm", "ugemm"])
    def test_measured_cycles_within_ppa_bounds(self, smoke_model, name):
        from repro.launch.serve import measure_decode_cycles
        cfg, params = smoke_model
        backend = backends.resolve(name, bits=4)
        cyc = measure_decode_cycles(cfg, params, backend, batch=4,
                                    unit_n=128, num_units=64)
        assert cyc["dyn_floor"] - 0.5 <= cyc["measured"] <= cyc["wc"] + 0.5
        if backend.spec.sparsity_aware:
            assert cyc["measured"] < cyc["wc"]
        else:
            assert cyc["measured"] == cyc["dyn"] == cyc["wc"]

    def test_measured_cycles_use_executed_per_channel_codes(self, smoke_model):
        """measured must reflect the per-channel codes dense contracts: with
        a single outlier element, per-channel quantization keeps every other
        column's codes saturated (own-scale), so every outer-product step
        stays gated near vmax -> measured ~ wc.  Per-tensor codes (the bug:
        everything crushed toward zero by the outlier's global scale) would
        report ~wc/4 for the same weights."""
        from repro.launch import serve
        cfg, params = smoke_model
        backend = backends.resolve("tubgemm", bits=4)
        w = np.full((64, 64), 0.1, np.float32)
        w[0, 0] = 10.0                          # one outlier element
        fake_params = {"layer": jnp.asarray(w)}
        cyc = serve.measure_decode_cycles(cfg, fake_params, backend, batch=1,
                                          unit_n=64, num_units=1)
        assert cyc["measured"] > 0.8 * cyc["wc"]

    def test_measured_cycles_reuses_provided_stats(self, smoke_model):
        from repro.launch.serve import build_workload, measure_decode_cycles
        cfg, params = smoke_model
        backend = backends.resolve("tubgemm", bits=4)
        _, stats = build_workload(cfg, params, batch=4, ctx_len=8, bits=4)
        with_stats = measure_decode_cycles(cfg, params, backend, batch=4,
                                           unit_n=128, num_units=64,
                                           stats=stats)
        fresh = measure_decode_cycles(cfg, params, backend, batch=4,
                                      unit_n=128, num_units=64)
        assert with_stats == fresh
