"""Minimal, dependency-free stand-in for the slice of `hypothesis` these
tests use (``given`` / ``settings`` / ``strategies``).

The CI image cannot install hypothesis, and four test modules use it for
light property-based sweeps.  This shim keeps those tests collectable and
meaningful everywhere: each ``@given`` test runs ``max_examples`` examples
drawn from a deterministic per-test PRNG (seeded from the test's qualified
name, so failures reproduce).  When real hypothesis is available the test
modules import it instead and this file is inert.

Only the surface actually used in this repo is implemented:

    st.sampled_from(seq)   st.integers(lo, hi)   st.floats(lo, hi)
    @given(**kwargs)       @settings(max_examples=..., deadline=...)

No shrinking, no database, no assume/note — a failing example's kwargs are
attached to the assertion message instead.
"""

from __future__ import annotations

import functools
import inspect
import random
import zlib

__all__ = ["given", "settings", "strategies", "st"]

_DEFAULT_MAX_EXAMPLES = 20


class SearchStrategy:
    """A draw function plus a repr for failure messages."""

    def __init__(self, draw, label: str):
        self._draw = draw
        self._label = label

    def draw(self, rnd: random.Random):
        return self._draw(rnd)

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return self._label


class strategies:
    """Namespace mirroring ``hypothesis.strategies`` (the used subset)."""

    @staticmethod
    def sampled_from(elements) -> SearchStrategy:
        pool = list(elements)
        if not pool:
            raise ValueError("sampled_from needs a non-empty collection")
        return SearchStrategy(lambda r: r.choice(pool),
                              f"sampled_from({pool!r})")

    @staticmethod
    def integers(min_value: int, max_value: int) -> SearchStrategy:
        return SearchStrategy(lambda r: r.randint(min_value, max_value),
                              f"integers({min_value}, {max_value})")

    @staticmethod
    def floats(min_value: float, max_value: float) -> SearchStrategy:
        def draw(r: random.Random) -> float:
            # hit the endpoints occasionally — they are the usual bug nests
            roll = r.random()
            if roll < 0.05:
                return float(min_value)
            if roll < 0.10:
                return float(max_value)
            return r.uniform(min_value, max_value)
        return SearchStrategy(draw, f"floats({min_value}, {max_value})")

    @staticmethod
    def booleans() -> SearchStrategy:
        return SearchStrategy(lambda r: bool(r.getrandbits(1)), "booleans()")


st = strategies


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    """Record ``max_examples``; ``deadline`` and the rest are accepted no-ops."""

    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(**strats):
    """Run the test once per drawn example, deterministically seeded."""
    for name, s in strats.items():
        if not isinstance(s, SearchStrategy):
            raise TypeError(f"@given kwarg {name!r} is not a strategy: {s!r}")

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_fallback_max_examples",
                        _DEFAULT_MAX_EXAMPLES)
            seed = zlib.crc32(f"{fn.__module__}.{fn.__qualname__}".encode())
            rnd = random.Random(seed)
            for i in range(n):
                drawn = {k: strats[k].draw(rnd) for k in sorted(strats)}
                try:
                    fn(*args, **drawn, **kwargs)
                except Exception as e:  # noqa: BLE001 - re-raise with context
                    raise AssertionError(
                        f"falsifying example {i + 1}/{n}: {drawn!r}") from e

        # pytest resolves undeclared params as fixtures; hide the strategy
        # kwargs (which we inject) from the visible signature, and drop
        # __wrapped__ so inspect doesn't tunnel back to the original.
        del wrapper.__wrapped__
        sig = inspect.signature(fn)
        kept = [p for name, p in sig.parameters.items() if name not in strats]
        wrapper.__signature__ = sig.replace(parameters=kept)
        return wrapper

    return deco
