"""Docs stay true: PAPER_MAP code references resolve, ARCHITECTURE exists."""

import importlib.util
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent


def _load_checker():
    spec = importlib.util.spec_from_file_location(
        "check_paper_map", ROOT / "tools" / "check_paper_map.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_paper_map_references_resolve():
    """Every code reference in docs/PAPER_MAP.md imports / exists (the same
    check CI runs via tools/check_paper_map.py)."""
    sys.path.insert(0, str(ROOT))  # benchmarks/ package for dotted refs
    try:
        errors = _load_checker().check(ROOT)
    finally:
        sys.path.remove(str(ROOT))
    assert not errors, "\n".join(errors)


def test_paper_map_covers_tables_and_figures():
    text = (ROOT / "docs" / "PAPER_MAP.md").read_text()
    for section in ("Table I ", "Table II ", "Table III ", "Table IV ",
                    "Table V ", "Fig. 2", "Fig. 3", "Eq. 1"):
        assert section in text, f"PAPER_MAP.md lost its {section.strip()} section"


def test_architecture_doc_names_the_layers():
    text = (ROOT / "docs" / "ARCHITECTURE.md").read_text()
    for needle in ("src/repro/core/", "src/repro/kernels/", "src/repro/eval/",
                   "src/repro/launch/", "benchmarks/", "register_design",
                   "design registry"):
        assert needle in text, f"ARCHITECTURE.md lost {needle!r}"


def test_readme_links_docs_and_sweetspot():
    text = (ROOT / "README.md").read_text()
    assert "docs/ARCHITECTURE.md" in text
    assert "docs/PAPER_MAP.md" in text
    assert "docs/PLANNER.md" in text
    assert "sweetspot" in text
    assert "--backend-plan" in text
    assert "serve plan" in text


def test_planner_doc_exists_and_is_cross_linked():
    """docs/PLANNER.md covers the plan contract and the stack links to it."""
    text = (ROOT / "docs" / "PLANNER.md").read_text()
    for needle in ("repro.backends.plan/v1", "specific wins",
                   "Accuracy-guard semantics", "Eq. 1", "use_plan",
                   "serve plan", "--backend-plan", "fnmatch"):
        assert needle in text, f"PLANNER.md lost {needle!r}"
    assert "PLANNER.md" in (ROOT / "docs" / "BACKENDS.md").read_text()
    assert "PLANNER.md" in (ROOT / "docs" / "ARCHITECTURE.md").read_text()
    assert "planner" in (ROOT / "docs" / "ARCHITECTURE.md").read_text()
