"""The benchmarks.run driver CLI: selection, unknown-name handling."""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
from benchmarks import run as run_mod  # noqa: E402


class TestUnknownBenchmark:
    def test_unknown_name_exits_nonzero_with_available_list(self, capsys):
        rc = run_mod.main(["definitely_not_a_benchmark"])
        assert rc == 2
        err = capsys.readouterr().err
        assert "unknown benchmark(s): definitely_not_a_benchmark" in err
        assert "available benchmarks:" in err
        assert "sweetspot" in err and "table1_area" in err

    def test_mixed_known_unknown_still_errors(self, capsys):
        rc = run_mod.main(["sweetspot", "nope1", "nope2"])
        assert rc == 2
        err = capsys.readouterr().err
        assert "nope1, nope2" in err

    def test_gated_benchmark_selectable_by_name(self):
        # naming the --full-gated sweep explicitly must not trip the
        # unknown-name check (it is appended to the known set)
        specs = run_mod.available_benchmarks(full=True)
        assert run_mod.GATED_SPEC[0] in specs
        assert run_mod.GATED_SPEC[0] not in run_mod.available_benchmarks(False)


class TestSelection:
    def test_known_selection_runs_only_named(self, capsys):
        rc = run_mod.main(["table1_area"])
        out = capsys.readouterr().out
        assert rc == 0
        lines = [l for l in out.splitlines() if "," in l]
        assert lines[0] == "name,us_per_call,derived"
        assert len(lines) == 2 and lines[1].startswith("table1_area,")
