"""Fused page-walk decode attention: differential conformance + hot path.

The fused kernel's claim is *oracle-equivalence without materialization*:
walking the block table page-by-page (online softmax in the Pallas kernel,
oracle-shaped softmax in the XLA lowering) must stay within
``FUSED_LOGIT_TOL`` of ``paged_decode_attention`` everywhere, and the
serving engine's sampled token streams must be *identical* on seeded
traces — including under low-bit per-row activation quantization, where
any systematic numeric drift in the attention path gets amplified into
argmax flips.  This module holds that claim differentially:

* kernel-level fused-vs-gather parity across page sizes {3, 4, 8}, GQA
  ratios {1, 2, 4}, batch 1..max and ragged length mixes (len-1,
  page-boundary, post-evict page reuse), for both the XLA lowering and
  the Pallas kernel in interpret mode (hypothesis when available, the
  local shim otherwise);
* early-exit evidence: K pages past the batch's live high-water mark are
  never read (NaN poison stays un-observed);
* the bf16 dtype-schedule regression: the XLA lowering must mirror the
  oracle's cast points, not silently run at higher precision;
* engine-level stream identity fused vs gather (float and per-row
  tubgemm paths), batched vs per-request prefill admission parity, and
  the shared bounded prefill-fn cache;
* Eq.-1 energy pinned against the event stream (admission charges
  prefill exactly once; the first token never costs a decode tick);
* an 8-fake-device (1,1)-grid subprocess parity run, mirroring
  ``test_packed.test_packed_grid_multidevice``.
"""

import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # CI image has no hypothesis; use the local shim
    from _hypothesis_fallback import given, settings, strategies as st

import conftest
from repro import configs
from repro.analysis import source_lint
from repro.kernels import paged_attention_fused as fused_lib
from repro.kernels.paged_attention import paged_decode_attention
from repro.models import common as common_lib, model as model_lib
from repro.serving import (FUSED_LOGIT_TOL, PagedKVCache, ServingEngine,
                           TrafficConfig, fused_vs_gather_probe,
                           generate_trace)
from repro.serving import engine as engine_lib

_no_xla_cache = pytest.fixture(autouse=True, scope="module")(
    conftest.disable_compilation_cache)

#: kernel-level differential tolerance: the XLA lowering matches the oracle
#: elementwise (reduction association is the only freedom); the Pallas
#: online softmax re-associates more aggressively.
KERNEL_TOL = 2e-5


@pytest.fixture(scope="module")
def cfg():
    return dataclasses.replace(configs.get_smoke_config("llama3-8b"),
                               compute_dtype="float32",
                               param_dtype="float32")


@pytest.fixture(scope="module")
def params(cfg):
    return model_lib.init_params(cfg, jax.random.PRNGKey(0))


def _case(seed, *, batch, page_size, kvh, heads, hd, max_blocks, lengths,
          dtype=jnp.float32):
    """Random pools + shuffled (non-contiguous) block tables."""
    assert len(lengths) == batch
    num_pages = 1 + batch * max_blocks
    rng = np.random.default_rng(seed)
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    pool_shape = (num_pages, page_size, kvh, hd)
    pool_k = jax.random.normal(k1, pool_shape).astype(dtype)
    pool_v = jax.random.normal(k2, pool_shape).astype(dtype)
    q = jax.random.normal(k3, (batch, 1, heads, hd)).astype(dtype)
    pages = rng.permutation(np.arange(1, num_pages))  # page 0 = trash
    bt = jnp.asarray(pages.reshape(batch, max_blocks), jnp.int32)
    return q, pool_k, pool_v, bt, jnp.asarray(lengths, jnp.int32)


def _diff(a, b):
    return float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                 - b.astype(jnp.float32))))


# ---------------------------------------------------------------------------
# 1. kernel-level differential conformance
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("page_size", [3, 4, 8])
@pytest.mark.parametrize("gqa", [1, 2, 4])
def test_fused_matches_oracle_page_gqa(impl, page_size, gqa):
    """Page sizes x GQA ratios x a ragged length mix incl. len-1 and exact
    page boundaries, against the gather oracle."""
    heads, kvh, hd = 4, 4 // gqa, 8
    max_blocks = 5
    lengths = [1, page_size, page_size + 1, min(3 * page_size + 2,
                                                max_blocks * page_size)]
    args = _case(page_size * 10 + gqa, batch=4, page_size=page_size, kvh=kvh,
                 heads=heads, hd=hd, max_blocks=max_blocks, lengths=lengths)
    ref = paged_decode_attention(*args, num_heads=heads)
    got = fused_lib.fused_paged_decode_attention(
        *args, num_heads=heads, impl=impl, interpret=(impl == "pallas"))
    assert got.shape == ref.shape and got.dtype == ref.dtype
    assert _diff(got, ref) <= KERNEL_TOL


@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("batch", [1, 2, 3, 4])
def test_fused_matches_oracle_batch(impl, batch):
    """Batch 1..max with per-request ragged lengths."""
    heads, kvh, hd, page_size, max_blocks = 8, 2, 16, 4, 4
    lengths = [1 + (3 * i) % (max_blocks * page_size) for i in range(batch)]
    args = _case(100 + batch, batch=batch, page_size=page_size, kvh=kvh,
                 heads=heads, hd=hd, max_blocks=max_blocks, lengths=lengths)
    ref = paged_decode_attention(*args, num_heads=heads)
    got = fused_lib.fused_paged_decode_attention(
        *args, num_heads=heads, impl=impl, interpret=(impl == "pallas"))
    assert _diff(got, ref) <= KERNEL_TOL


@settings(deadline=None, max_examples=20)
@given(seed=st.integers(min_value=0, max_value=10_000),
       page_size=st.sampled_from([3, 4, 8]),
       gqa=st.sampled_from([1, 2, 4]),
       batch=st.integers(min_value=1, max_value=4))
def test_fused_matches_oracle_property(seed, page_size, gqa, batch):
    """Random lengths/pages/grouping: fused stays within tolerance."""
    heads, hd = 4, 8
    max_blocks = -(-24 // page_size)
    lengths = [1 + ((seed + 7 * i) % (max_blocks * page_size))
               for i in range(batch)]
    args = _case(seed, batch=batch, page_size=page_size,
                 kvh=heads // gqa, heads=heads, hd=hd, max_blocks=max_blocks,
                 lengths=lengths)
    ref = paged_decode_attention(*args, num_heads=heads)
    got = fused_lib.fused_paged_decode_attention(*args, num_heads=heads,
                                                 impl="xla")
    assert _diff(got, ref) <= KERNEL_TOL


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_fused_post_evict_page_reuse(impl):
    """Block tables from a real allocate/free/allocate cycle: a freed
    request's pages are reused out of order by its successor."""
    page_size, kvh, heads, hd = 4, 2, 4, 8
    cache = PagedKVCache(num_layers=1, num_kv_heads=kvh, head_dim=hd,
                         num_pages=9, page_size=page_size, max_seq_len=16)
    rng = np.random.default_rng(7)
    cache.allocate(0, 9)    # 3 pages
    cache.allocate(1, 7)    # 2 pages
    cache.free_request(0)
    cache.allocate(2, 11)   # 3 pages, reusing request 0's freed pages
    for rid, n in ((1, 7), (2, 11)):
        k = rng.standard_normal((1, n, kvh, hd)).astype(np.float32)
        v = rng.standard_normal((1, n, kvh, hd)).astype(np.float32)
        cache.write_prefill(rid, jnp.asarray(k), jnp.asarray(v))
    bt = jnp.asarray(np.stack([cache.block_table_row(1),
                               cache.block_table_row(2)]), jnp.int32)
    lengths = jnp.asarray([7, 11], jnp.int32)
    q = jax.random.normal(jax.random.PRNGKey(3), (2, 1, heads, hd))
    args = (q, cache.k_pool[0], cache.v_pool[0], bt, lengths)
    ref = paged_decode_attention(*args, num_heads=heads)
    got = fused_lib.fused_paged_decode_attention(
        *args, num_heads=heads, impl=impl, interpret=(impl == "pallas"))
    assert _diff(got, ref) <= KERNEL_TOL


def test_fused_xla_early_exit_never_reads_dead_k_pages():
    """K pages past the batch's live high-water mark carry NaN poison; the
    chunked walk (pages_per_chunk=1) must stop before touching them."""
    heads, kvh, hd, page_size, max_blocks = 4, 2, 8, 4, 8
    lengths = [5, 7]  # high-water mark: 2 pages per request
    args = _case(11, batch=2, page_size=page_size, kvh=kvh, heads=heads,
                 hd=hd, max_blocks=max_blocks, lengths=lengths)
    q, pool_k, pool_v, bt, lens = args
    live_pages = np.unique(np.asarray(bt)[:, :2])
    dead = np.setdiff1d(np.arange(pool_k.shape[0]), live_pages)
    poisoned_k = pool_k.at[jnp.asarray(dead)].set(jnp.nan)
    clean = fused_lib.fused_paged_decode_attention(
        q, pool_k, pool_v, bt, lens, num_heads=heads, impl="xla",
        pages_per_chunk=1)
    got = fused_lib.fused_paged_decode_attention(
        q, poisoned_k, pool_v, bt, lens, num_heads=heads, impl="xla",
        pages_per_chunk=1)
    assert np.array_equal(np.asarray(got), np.asarray(clean))
    assert np.isfinite(np.asarray(got)).all()


def test_fused_bf16_mirrors_oracle_dtype_schedule():
    """Under bf16 compute the oracle rounds K/V and the softmax weights to
    bf16 mid-path; the XLA lowering must mirror those cast points (same
    output dtype, bf16-level agreement), not run at silent fp32 — the
    regression that flipped per-row-quantized token streams."""
    heads, kvh, hd, page_size, max_blocks = 4, 2, 8, 4, 4
    args = _case(21, batch=3, page_size=page_size, kvh=kvh, heads=heads,
                 hd=hd, max_blocks=max_blocks, lengths=[1, 6, 13],
                 dtype=jnp.bfloat16)
    ref = paged_decode_attention(*args, num_heads=heads)
    got = fused_lib.fused_paged_decode_attention(*args, num_heads=heads,
                                                 impl="xla")
    assert got.dtype == ref.dtype == jnp.bfloat16
    # elementwise ops match the oracle bit-for-bit; only f32 reduction
    # association can differ, which the final bf16 rounding absorbs
    assert _diff(got, ref) <= 2 * float(jnp.finfo(jnp.bfloat16).eps)


def test_fused_rejects_bad_shapes_and_impl():
    args = _case(5, batch=2, page_size=4, kvh=2, heads=4, hd=8,
                 max_blocks=2, lengths=[3, 5])
    with pytest.raises(ValueError, match="impl"):
        fused_lib.fused_paged_decode_attention(*args, num_heads=4,
                                               impl="cuda")
    with pytest.raises(ValueError, match="divide"):
        fused_lib.fused_paged_decode_attention(*args, num_heads=3)
    q_bad = jnp.zeros((2, 2, 4, 8))
    with pytest.raises(ValueError, match="B, 1, H"):
        fused_lib.fused_paged_decode_attention(q_bad, *args[1:], num_heads=4)


# ---------------------------------------------------------------------------
# 2. modeled traffic
# ---------------------------------------------------------------------------

def test_bytes_moved_model():
    """Fused traffic scales with live history at KV width; gather with the
    padded pool at query width."""
    fused = fused_lib.fused_decode_bytes_moved(
        [1, 8, 9], page_size=4, num_kv_heads=2, head_dim=64)
    # ceil(1/4)+ceil(8/4)+ceil(9/4) = 1+2+3 pages, K and V, f32
    assert fused == 2 * 6 * 4 * 2 * 64 * 4
    gather = fused_lib.gather_decode_bytes_moved(
        batch=3, max_blocks=16, page_size=4, num_kv_heads=2, num_heads=8,
        head_dim=64)
    assert gather == 2 * 3 * 16 * 4 * 8 * 64 * 4
    # the acceptance regime: B=8, 512 of 1024 context, page 4 -> >= 4x
    full = fused_lib.gather_decode_bytes_moved(
        batch=8, max_blocks=256, page_size=4, num_kv_heads=2, num_heads=8,
        head_dim=64)
    walk = fused_lib.fused_decode_bytes_moved(
        [512] * 8, page_size=4, num_kv_heads=2, head_dim=64)
    assert full / walk >= 4.0


# ---------------------------------------------------------------------------
# 3. engine-level stream identity + probes
# ---------------------------------------------------------------------------

def _run(cfg, params, attention, *, tcfg=None, scheduler="continuous", **kw):
    trace = generate_trace(tcfg or TrafficConfig(num_requests=8,
                                                 arrival_rate=1.0, seed=0))
    eng = ServingEngine(cfg, params, max_batch=4, page_size=8,
                        max_seq_len=64, attention=attention, **kw)
    return eng.run(trace, scheduler)


def test_engine_fused_vs_gather_streams_float(cfg, params):
    rf = _run(cfg, params, "fused")
    rg = _run(cfg, params, "gather")
    assert rf.request_tokens == rg.request_tokens
    assert rf.events == rg.events


def test_engine_fused_vs_gather_streams_per_row_quantized(cfg, params):
    """The strict serve-traffic gate in miniature: per-row act quant over
    tubgemm@4 amplifies any systematic attention drift into token flips."""
    with common_lib.activation_scaling("per-row"):
        rf = _run(cfg, params, "fused", backend="tubgemm", bits=4,
                  unit_n=64, num_units=64)
        rg = _run(cfg, params, "gather", backend="tubgemm", bits=4,
                  unit_n=64, num_units=64)
    assert rf.request_tokens == rg.request_tokens


def test_fused_vs_gather_probe_within_tol(cfg, params):
    assert fused_vs_gather_probe(cfg, params) <= FUSED_LOGIT_TOL


def test_fused_vs_gather_probe_pallas_interpret(cfg, params):
    """The Pallas kernel (interpret mode on CPU) through the whole engine
    decode step, against the gather oracle."""
    diff = fused_vs_gather_probe(cfg, params, attention_impl="pallas",
                                 batch=2, steps=2)
    assert diff <= FUSED_LOGIT_TOL


def test_engine_rejects_bad_attention_args(cfg, params):
    with pytest.raises(ValueError, match="attention must be"):
        ServingEngine(cfg, params, attention="flash")
    with pytest.raises(ValueError, match="attention_impl"):
        ServingEngine(cfg, params, attention_impl="cuda")


# ---------------------------------------------------------------------------
# 4. batched prefill admission + shared prefill cache
# ---------------------------------------------------------------------------

def test_batched_prefill_streams_identical_to_per_request(cfg, params):
    """Grouping same-step admissions into one bucketed prefill call must be
    invisible in every token and event."""
    tcfg = TrafficConfig(num_requests=10, arrival_rate=2.0, seed=3)
    rb = _run(cfg, params, "fused", tcfg=tcfg, batched_prefill=True)
    rs = _run(cfg, params, "fused", tcfg=tcfg, batched_prefill=False)
    assert rb.request_tokens == rs.request_tokens
    assert rb.events == rs.events
    assert rb.energy_uj == rs.energy_uj


def test_prefill_cache_shared_across_engines(cfg, params):
    """Two engines with identical (cfg, scope, bucket) keys reuse one
    compiled prefill instead of recompiling per construction."""
    e1 = ServingEngine(cfg, params, max_batch=2, page_size=8, max_seq_len=64)
    e2 = ServingEngine(cfg, params, max_batch=4, page_size=4, max_seq_len=64)
    toks = jnp.zeros((1, 8), jnp.int32)
    e1._prefill(toks)
    key = e1._prefill_cache_key(8)
    fn = engine_lib._PREFILL_FNS[key]
    e2._prefill(toks)
    assert engine_lib._PREFILL_FNS[key] is fn  # same compiled entry
    assert e1._prefill_cache_key(8) == e2._prefill_cache_key(8)
    # the key tracks trace-time context: bucket and act-scale mode split it
    assert e1._prefill_cache_key(16) != key
    with common_lib.activation_scaling("per-row"):
        assert e1._prefill_cache_key(8) != key


def test_prefill_cache_bounded():
    base = dict(engine_lib._PREFILL_FNS)
    try:
        for i in range(engine_lib.PREFILL_CACHE_MAXSIZE + 7):
            engine_lib._prefill_cache_get(("test-bound", i), lambda: object())
        assert len(engine_lib._PREFILL_FNS) <= engine_lib.PREFILL_CACHE_MAXSIZE
    finally:
        engine_lib._PREFILL_FNS.clear()
        engine_lib._PREFILL_FNS.update(base)


# ---------------------------------------------------------------------------
# 5. Eq.-1 energy pinned against the event stream
# ---------------------------------------------------------------------------

def _single_request_report(cfg, params, output_len):
    trace = (engine_lib.TrafficRequest(req_id=0, arrival_step=0,
                                       prompt_len=5, output_len=output_len),)
    eng = ServingEngine(cfg, params, max_batch=2, page_size=8,
                        max_seq_len=32)
    return eng, eng.run(trace, "continuous")


def test_energy_single_request_prefill_only(cfg, params):
    """output_len=1: the one token comes off the prefill logits at
    admission — energy is EXACTLY one prefill, zero decode ticks."""
    eng, rep = _single_request_report(cfg, params, output_len=1)
    assert rep.tokens == 1
    assert rep.energy_uj == eng.energy.prefill_energy_uj(5)


def test_energy_single_request_one_decode_step(cfg, params):
    """output_len=2: one admission + one decode tick with one active slot —
    energy == prefill(P) + 1 decode token, no prefill double-count on the
    admission step."""
    eng, rep = _single_request_report(cfg, params, output_len=2)
    assert rep.tokens == 2
    expect = eng.energy.prefill_energy_uj(5) + eng.energy.decode_energy_uj(1)
    assert rep.energy_uj == expect


def test_energy_matches_event_stream(cfg, params):
    """Replaying the report's event stream reprices the whole trace: each
    admit charges its request's true prompt length once, each decode tick
    charges its active-slot count once."""
    trace = generate_trace(TrafficConfig(num_requests=8, arrival_rate=1.0,
                                         seed=5))
    eng = ServingEngine(cfg, params, max_batch=4, page_size=8,
                        max_seq_len=64)
    rep = eng.run(trace, "continuous")
    prompt_len = {r.req_id: r.prompt_len for r in trace}
    expect = sum(eng.energy.prefill_energy_uj(prompt_len[rid])
                 for _, kind, rid in rep.events if kind == "admit")
    # reconstruct per-step active counts from admit/evict events: a request
    # decodes on every step after its admission until its eviction step
    admit = {rid: at for at, kind, rid in rep.events if kind == "admit"}
    evict = {rid: at for at, kind, rid in rep.events if kind == "evict"}
    for step in range(rep.steps):
        n = sum(1 for rid in admit
                if admit[rid] < step <= evict[rid])
        expect += eng.energy.decode_energy_uj(n)
    assert rep.energy_uj == pytest.approx(expect, rel=0, abs=1e-9)


# ---------------------------------------------------------------------------
# 6. source-lint coverage of the fused kernel
# ---------------------------------------------------------------------------

def test_source_lint_covers_fused_kernel():
    """The float-accumulation rule sees fused-kernel names; the shipped
    kernel passes only because its fp32-softmax pragmas are present."""
    bad = ("import jax.numpy as jnp\n"
           "def _fused_decode_probe(a, b):\n"
           "    return jnp.einsum('ij,jk->ik', a, b)\n")
    findings = source_lint.lint_source(
        bad, rel="src/repro/kernels/paged_attention_fused.py")
    assert any(f.rule == "float-accumulation" for f in findings)
    path = os.path.join(os.path.dirname(__file__), "..", "src", "repro",
                        "kernels", "paged_attention_fused.py")
    with open(path) as fh:
        shipped = fh.read()
    assert not source_lint.lint_source(
        shipped, rel="src/repro/kernels/paged_attention_fused.py")
    assert shipped.count("analysis: allow-float-accumulation") >= 2


# ---------------------------------------------------------------------------
# 7. 8-fake-device (1,1)-grid subprocess parity
# ---------------------------------------------------------------------------

MULTIDEV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax
from repro import configs
from repro.models import model as model_lib
from repro.serving import ServingEngine, TrafficConfig, generate_trace

cfg = dataclasses.replace(configs.get_smoke_config("llama3-8b"),
                          compute_dtype="float32", param_dtype="float32")
params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
trace = generate_trace(TrafficConfig(num_requests=4, arrival_rate=1.0,
                                     seed=0))
kw = dict(max_batch=2, page_size=8, max_seq_len=64, backend="tubgemm",
          bits=4, unit_n=64, num_units=64, grid=(1, 1))
rf = ServingEngine(cfg, params, attention="fused", **kw).run(
    trace, "continuous")
rg = ServingEngine(cfg, params, attention="gather", **kw).run(
    trace, "continuous")
assert rf.request_tokens == rg.request_tokens, (rf.request_tokens,
                                                rg.request_tokens)
print("FUSED_GRID_OK", rf.tokens)
"""


def test_fused_grid_multidevice():
    """With 8 fake host devices and a (1,1) shard grid, the fused decode
    path's token streams match the gather oracle's."""
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
           "JAX_PLATFORMS": "cpu",
           "JAX_DISABLE_MOST_OPTIMIZATIONS": "1",
           "JAX_COMPILATION_CACHE_DIR": os.path.abspath(".jax_cache"),
           "JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS": "0"}
    res = subprocess.run([sys.executable, "-c", MULTIDEV_SCRIPT],
                         capture_output=True, text=True, timeout=900,
                         env=env)
    assert "FUSED_GRID_OK" in res.stdout, \
        f"missing FUSED_GRID_OK\n{res.stdout}\n{res.stderr}"
