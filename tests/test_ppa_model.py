"""PPA cost model: exact reproduction of the paper's Tables I-IV + Fig. 2."""

import math

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # CI image has no hypothesis; use the local shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import ppa
from repro.core.accounting import GemmCall, GemmWorkloadRecorder, price_workload
from repro.core.gemm_sims import DESIGNS


class TestPaperTables:
    def test_table3_energy_reproduced(self):
        """Derived energy (power x WC latency) matches Table III to <1%."""
        for (bits, n), row in ppa.PAPER_ENERGY_NJ.items():
            for design, ref in row.items():
                got = ppa.energy_nj(design, bits, n)
                assert got == pytest.approx(ref, rel=0.01), \
                    f"{design} {bits}b {n}x{n}: {got} vs paper {ref}"

    def test_table4_adp_reproduced(self):
        for (bits, n), row in ppa.PAPER_ADP_MM2_NS.items():
            for design, ref in row.items():
                assert ppa.adp_mm2_ns(design, bits, n) == \
                    pytest.approx(ref, rel=0.01)

    def test_area_power_grid_hits_are_exact(self):
        assert ppa.area_um2("tugemm", 8, 16) == 61_064.0
        assert ppa.power_mw("bgemm", 8, 32) == 321.3
        assert ppa.area_um2("ugemm", 4, 128) == pytest.approx(140.24e6)

    def test_fig2_slopes(self):
        """Paper Fig. 2: per-bitwidth-doubling ratios at 32x32."""
        area = {d: ppa.fig2_slope(ppa.AREA_UM2, d) for d in DESIGNS}
        assert area["tugemm"] == pytest.approx(2.12, abs=0.02)
        assert area["tubgemm"] == pytest.approx(2.12, abs=0.02)
        assert area["ugemm"] == pytest.approx(2.16, abs=0.02)
        assert area["bgemm"] == pytest.approx(2.90, abs=0.02)
        power = {d: ppa.fig2_slope(ppa.POWER_MW, d) for d in DESIGNS}
        assert power["ugemm"] == pytest.approx(1.56, abs=0.02)   # best scaling
        assert power["tugemm"] == pytest.approx(2.02, abs=0.02)
        assert power["tubgemm"] == pytest.approx(2.15, abs=0.02)
        assert power["bgemm"] == pytest.approx(3.25, abs=0.04)

    def test_key_takeaways(self):
        """The paper's qualitative conclusions hold in the model."""
        # tuGEMM best area/power everywhere on the grid
        for (bits, n) in ppa.AREA_UM2:
            assert min(ppa.AREA_UM2[(bits, n)], key=ppa.AREA_UM2[(bits, n)].get) \
                == "tugemm"
        # tubGEMM most energy-efficient at 2 bits (beats bGEMM)
        assert ppa.energy_nj("tubgemm", 2, 32) < ppa.energy_nj("bgemm", 2, 32)
        # bGEMM most energy-efficient at 8 bits
        assert all(ppa.energy_nj("bgemm", 8, 32) < ppa.energy_nj(d, 8, 32)
                   for d in DESIGNS if d != "bgemm")
        # tubGEMM overtakes bGEMM at CloudTPUv3 (128x128) size, 4-bit (~12%)
        e_tub = ppa.energy_nj("tubgemm", 4, 128)
        e_b = ppa.energy_nj("bgemm", 4, 128)
        assert e_tub < e_b
        assert (1 - e_tub / e_b) == pytest.approx(0.11, abs=0.03)
        # bGEMM lowest ADP
        for n in (64, 128):
            assert min(DESIGNS, key=lambda d: ppa.adp_mm2_ns(d, 4, n)) == "bgemm"

    def test_offgrid_fit_interpolates_sanely(self):
        """Fit predictions are monotone and within ~2x of neighbors."""
        for d in DESIGNS:
            a16, a24, a32 = (ppa.area_um2(d, 4, n) for n in (16, 24, 32))
            assert a16 < a24 < a32
            p2, p3, p4 = (ppa.power_mw(d, b, 16) for b in (2, 3, 4))
            assert p2 < p3 < p4


class TestOffgridFit:
    """The log-log fit pricing the sweet-spot sweep's off-grid points."""

    def test_fit_exact_on_every_grid_point(self):
        """Grid (bits, n) hits return the published value verbatim — the
        fit must never be consulted on a calibration point."""
        for (bits, n), row in ppa.AREA_UM2.items():
            for design, ref in row.items():
                assert ppa.area_um2(design, bits, n) == ref
        for (bits, n), row in ppa.POWER_MW.items():
            for design, ref in row.items():
                assert ppa.power_mw(design, bits, n) == ref

    @pytest.mark.parametrize("bits", [2, 3, 4, 8])
    def test_fit_monotone_in_n(self, bits):
        """Area and power strictly increase with array size n per design,
        across a mix of grid-exact and fit-priced points (guards the
        sweet-spot sweep: a non-monotone fit would fabricate crossovers)."""
        ns = (16, 24, 32, 48, 64, 96, 128, 192, 256)
        for d in DESIGNS:
            for fn in (ppa.area_um2, ppa.power_mw):
                vals = [fn(d, bits, n) for n in ns]
                assert all(lo < hi for lo, hi in zip(vals, vals[1:])), \
                    f"{fn.__name__} not monotone in n for {d} at {bits}b: {vals}"

    def test_fit_monotone_in_bits(self):
        """At fixed n, widening the datapath never shrinks area or power."""
        for d in DESIGNS:
            for n in (16, 32, 64, 128, 256):
                for fn in (ppa.area_um2, ppa.power_mw):
                    vals = [fn(d, b, n) for b in (2, 3, 4, 6, 8)]
                    assert all(lo < hi for lo, hi in zip(vals, vals[1:]))

    def test_uncalibrated_design_raises(self):
        with pytest.raises(ValueError, match="no PPA calibration"):
            ppa.area_um2("tugemm_pallas", 4, 64)


class TestSparsityEnergy:
    def test_fig3_sparsity_improvements(self):
        """Fig. 3: with CNN-level bit sparsity (~45%), tubGEMM's 2-bit gap
        grows and the crossover with bGEMM moves earlier."""
        b_spa = 0.45
        e_tub_dyn = ppa.dynamic_energy_nj("tubgemm", 2, 32, b_spa)
        e_b = ppa.energy_nj("bgemm", 2, 32)
        assert e_tub_dyn < ppa.energy_nj("tubgemm", 2, 32) < e_b
        # at 4-bit WC tubGEMM loses to bGEMM; with sparsity the gap shrinks
        gap_wc = ppa.energy_nj("tubgemm", 4, 32) / ppa.energy_nj("bgemm", 4, 32)
        gap_dyn = ppa.dynamic_energy_nj("tubgemm", 4, 32, b_spa) / \
            ppa.energy_nj("bgemm", 4, 32)
        assert gap_dyn < gap_wc

    @given(bspa=st.floats(0.0, 0.99), bits=st.sampled_from([2, 4, 8]))
    @settings(max_examples=30, deadline=None)
    def test_property_sparsity_only_helps_temporal(self, bspa, bits):
        for d in DESIGNS:
            dyn = ppa.dynamic_energy_nj(d, bits, 32, bspa)
            wc = ppa.energy_nj(d, bits, 32)
            if d in ("tugemm", "tubgemm"):
                assert dyn <= wc + 1e-12
            else:
                assert dyn == pytest.approx(wc)


class TestDLAModel:
    def test_tiling(self):
        dla = ppa.DLAModel(design="tubgemm", bits=4, n=128, num_units=4)
        assert dla.tiles(128, 128) == 1
        assert dla.tiles(129, 128) == 2
        assert dla.tiles(512, 512) == 16

    def test_workload_pricing_consistency(self):
        rec = GemmWorkloadRecorder()
        rec.record("fc1", m=64, k=256, n_out=512, bit_sparsity=0.4)
        rec.record("fc2", m=64, k=512, n_out=256, bit_sparsity=0.0, count=2)
        cost = price_workload(rec.calls, design="tubgemm", bits=4, unit_n=128,
                              num_units=2)
        assert cost.total_macs == 64 * 256 * 512 + 2 * 64 * 512 * 256
        assert cost.dyn_energy_uj < cost.wc_energy_uj          # sparsity helps
        cost_b = price_workload(rec.calls, design="bgemm", bits=4, unit_n=128)
        assert cost_b.dyn_energy_uj == pytest.approx(cost_b.wc_energy_uj)

    @given(m=st.integers(1, 300), k=st.integers(1, 300), n=st.integers(1, 300))
    @settings(max_examples=30, deadline=None)
    def test_property_energy_scales_with_tiles(self, m, k, n):
        dla = ppa.DLAModel(design="tubgemm", bits=4, n=64)
        e1 = dla.matmul_energy_nj(m, k, n)
        e2 = dla.matmul_energy_nj(2 * m, k, n)
        assert e2 >= e1
