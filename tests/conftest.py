import os

# The CI image ships libtpu but no TPU: left alone, jax's backend discovery
# stalls for minutes trying to initialize it.  Default to CPU (tier-1 runs
# in interpret mode anyway); export JAX_PLATFORMS explicitly to override,
# e.g. on a real TPU host.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# The suite is XLA-compile-bound (hundreds of model-sized jits on a slow
# CPU), and every tensor in it is tiny: skip most backend optimization
# passes.  Compiles get ~2x faster; steady-state execution is slightly
# slower, which is irrelevant at test sizes.  Correctness assertions are
# tolerance- or bit-exactness-based and do not depend on XLA fusion choices.
os.environ.setdefault("JAX_DISABLE_MOST_OPTIMIZATIONS", "1")

# NOTE: the persistent XLA compilation cache (JAX_COMPILATION_CACHE_DIR) is
# deliberately NOT enabled process-wide: on this jax/CPU build it corrupts
# the CPU client once the train/serve loop is involved (aborts/segfaults in
# later checkpoint saves even when the cache is config.update()-disabled for
# the affected module — reproduced via test_fault_tolerance).  Only the
# isolated subprocess tests (test_pipeline, test_multidevice) opt in.

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def disable_compilation_cache():
    """Module-scoped generator: cache off on entry, restored on exit.

    Usage (in modules that drive the train/serve loops):

        _no_xla_cache = pytest.fixture(autouse=True, scope="module")(
            conftest.disable_compilation_cache)
    """
    import jax
    old = jax.config.jax_compilation_cache_dir
    jax.config.update("jax_compilation_cache_dir", None)
    yield
    jax.config.update("jax_compilation_cache_dir", old)


def restore_design_registry():
    """Module-scoped generator: snapshot the gemm_sims design registry on
    entry, restore it on exit.

    Modules that call ``kernels.backends.register_kernel_backends`` (or
    register ad-hoc designs) use this so the ``tugemm_pallas`` /
    ``tubgemm_pallas`` mirrors don't leak into later modules — several
    consumers iterate the *live* ``gemm_sims.DESIGNS`` and expect exactly
    the four calibrated designs.  Usage:

        _registry = pytest.fixture(autouse=True, scope="module")(
            conftest.restore_design_registry)
    """
    from repro.core import gemm_sims
    saved = gemm_sims.registry_snapshot()
    yield
    gemm_sims.registry_restore(saved)
