"""Sharded PE-array grid backends: topology/cost accounting, GridPlan
semantics, the shared measured-cycles helper, streamed site discovery, and
(in a pinned-device subprocess) multi-device bit-exactness + sharded plan
execution."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import backends, configs
from repro.backends.grid import (GRID_SCHEMA, GridBackend, GridPlan, as_grid,
                                 grid_matrix_cycles, load_plan, parse_grid,
                                 shard_site, shard_slices)
from repro.backends.plan import BackendPlan, SiteAssignment
from repro.core import accounting, ppa
from repro.eval import planner
from repro.models import common, model as model_lib

ALL_DESIGNS = ("ugemm", "tugemm", "tubgemm", "bgemm")
EXACT_DESIGNS = ("tugemm", "tubgemm", "bgemm")


@pytest.fixture(scope="module")
def llama_smoke():
    cfg = configs.get_smoke_config("llama3-8b")
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def llama_grid_plan(llama_smoke):
    cfg, params = llama_smoke
    return planner.build_grid_plan(cfg, params, batch=4, grid=(2, 2),
                                   unit_n=64, num_units=64)


def _codes(rng, shape, bits):
    v = 2 ** (bits - 1) - 1
    return jnp.asarray(rng.integers(-v, v + 1, shape), jnp.int8)


# ---------------------------------------------------------------------------
# Topology plumbing
# ---------------------------------------------------------------------------

class TestParseGrid:
    def test_accepts_tuple_list_and_strings(self):
        assert parse_grid((2, 4)) == (2, 4)
        assert parse_grid([2, 4]) == (2, 4)
        assert parse_grid("2,4") == (2, 4)
        assert parse_grid("2x4") == (2, 4)

    def test_rejects_bad_specs(self):
        with pytest.raises(ValueError):
            parse_grid("2,0")
        with pytest.raises(ValueError):
            parse_grid("2")
        with pytest.raises(ValueError):
            parse_grid((0, 1))

    def test_shard_site_format(self):
        assert shard_site((1, 2), "layers/attn/wq") == "1,2/layers/attn/wq"

    def test_shard_slices_cover_and_partition(self):
        slices = shard_slices(10, 7, 4, 2)
        cover = np.zeros((10, 7), np.int32)
        for rows, cols in slices.values():
            cover[rows, cols] += 1
        assert (cover == 1).all()  # exact partition of the real elements


class TestGridBackendBasics:
    def test_is_a_gemm_backend_with_inner_metadata(self):
        b = backends.resolve("tubgemm", bits=4)
        g = as_grid(b, 2, 2)
        assert isinstance(g, backends.GemmBackend)
        assert (g.name, g.bits, g.exact, g.pricing_design) == \
            (b.name, b.bits, b.exact, b.pricing_design)
        assert g.grid == (2, 2) and g.num_shards == 4
        assert g.inner() == b

    def test_regrid_is_reshape_not_nesting(self):
        g = as_grid(backends.resolve("bgemm", bits=8), 2, 2)
        g2 = as_grid(g, 4, 1)
        assert g2.grid == (4, 1) and g2.inner() == g.inner()

    def test_equality_distinguishes_grid_shapes(self):
        b = backends.resolve("tugemm", bits=4)
        assert as_grid(b, 2, 2) == as_grid(b, 2, 2)
        assert as_grid(b, 2, 2) != as_grid(b, 2, 1)
        assert as_grid(b, 1, 1) != b  # a grid is not its inner unit

    def test_resolve_passes_grid_backends_through(self):
        g = as_grid(backends.resolve("tubgemm", bits=4), 2, 2)
        assert backends.resolve(g) is g
        rewidthed = backends.resolve(g, bits=8)
        assert isinstance(rewidthed, GridBackend)
        assert rewidthed.bits == 8 and rewidthed.grid == (2, 2)

    def test_stream_refuses_with_guidance(self):
        g = as_grid(backends.resolve("tubgemm", bits=4), 2, 2)
        with pytest.raises(NotImplementedError, match="per shard"):
            g.stream(jnp.zeros((4, 4), jnp.int8), jnp.zeros((4, 4), jnp.int8))

    @pytest.mark.parametrize("design", ALL_DESIGNS)
    def test_degenerate_grid_execute_matches_inner(self, rng, design):
        """(1,1) runs the real shard_map path on the single CPU device."""
        b = backends.resolve(design, bits=4)
        g = as_grid(b, 1, 1)
        a = _codes(rng, (6, 24), 4)
        w = _codes(rng, (24, 10), 4)
        np.testing.assert_array_equal(np.asarray(g.execute(a, w)),
                                      np.asarray(b.execute(a, w)))

    def test_batched_execute_shapes(self, rng):
        g = as_grid(backends.resolve("bgemm", bits=4), 1, 1)
        a = _codes(rng, (3, 5, 8), 4)
        w_shared = _codes(rng, (8, 6), 4)
        w_each = _codes(rng, (3, 8, 6), 4)
        assert g.execute(a, w_shared).shape == (3, 5, 6)
        assert g.execute(a, w_each).shape == (3, 5, 6)


# ---------------------------------------------------------------------------
# Cycle + cost accounting
# ---------------------------------------------------------------------------

class TestGridCycles:
    def test_hop_term_and_shard_common_dim(self):
        g = as_grid(backends.resolve("tubgemm", bits=4), 4, 2)
        assert g.hop_cycles() == ppa.HOP_CYCLES * (3 + 1)
        assert g.shard_common_dim(64) == 16
        assert g.shard_common_dim(10) == 3  # ceil split
        inner = g.inner()
        assert g.cycles(64) == inner.cycles(16) + g.hop_cycles()

    def test_wc_cycles_decrease_with_k_partitions_for_large_k(self):
        b = backends.resolve("tubgemm", bits=4)
        k = 4096
        chain = [as_grid(b, x, 1).cycles(k) for x in (1, 2, 4, 8)]
        assert chain == sorted(chain, reverse=True)
        assert chain[-1] < chain[0]

    @pytest.mark.parametrize("design", ALL_DESIGNS)
    def test_operand_dyn_cycles_within_bounds(self, rng, design):
        g = as_grid(backends.resolve(design, bits=4), 2, 2)
        q = _codes(rng, (32, 12), 4)
        measured = g.dyn_cycles(operand=q)
        wc = g.cycles(32)
        floor = g.dyn_cycles(32, bit_sparsity=0.999)
        assert floor <= measured <= wc

    def test_operand_and_sparsity_are_mutually_exclusive(self):
        g = as_grid(backends.resolve("tubgemm", bits=4), 2, 1)
        with pytest.raises(ValueError, match="not both"):
            g.dyn_cycles(operand=jnp.zeros((4,)), bit_sparsity=0.5)
        with pytest.raises(ValueError, match="common_dim"):
            g.dyn_cycles(bit_sparsity=0.5)

    def test_sparsity_only_helps_sparsity_aware_designs(self):
        gt = as_grid(backends.resolve("tubgemm", bits=4), 2, 2)
        gb = as_grid(backends.resolve("bgemm", bits=4), 2, 2)
        assert gt.dyn_cycles(64, bit_sparsity=0.5) < gt.cycles(64)
        assert gb.dyn_cycles(64, bit_sparsity=0.5) == gb.cycles(64)


class TestGridCost:
    def _calls(self):
        return [accounting.GemmCall("a", 4, 64, 192, 0.3, 2),
                accounting.GemmCall("b", 4, 192, 64, 0.2, 2)]

    def test_grid_cost_is_a_model_cost_with_grid_fields(self):
        cost = accounting.price_workload(self._calls(), design="tubgemm",
                                         bits=4, unit_n=64, num_units=64,
                                         grid=(2, 2))
        assert isinstance(cost, accounting.ModelCost)
        assert isinstance(cost, accounting.GridCost)
        assert cost.grid == (2, 2)
        assert cost.hop_energy_uj > 0
        assert 0 < cost.hop_energy_share < 1
        assert cost.utilization == 1.0

    def test_grid_backend_prices_itself_through_the_grid_branch(self):
        g = as_grid(backends.resolve("tubgemm", bits=4), 2, 2)
        cost = g.price(self._calls(), unit_n=64, num_units=64)
        explicit = accounting.price_workload(
            self._calls(), design="tubgemm", bits=4, unit_n=64,
            num_units=64, grid=(2, 2))
        assert cost == explicit

    @pytest.mark.parametrize("design", ALL_DESIGNS)
    def test_energy_monotone_in_grid_refinement(self, design):
        chain = [(1, 1), (1, 2), (2, 2), (2, 4), (4, 4)]
        costs = [accounting.price_workload(self._calls(), design=design,
                                           bits=4, unit_n=64, num_units=64,
                                           grid=g) for g in chain]
        energies = [c.dyn_energy_uj for c in costs]
        assert energies == sorted(energies)

    def test_padding_shows_up_as_utilization_below_one(self):
        calls = [accounting.GemmCall("odd", 4, 65, 33, 0.0, 1)]
        cost = accounting.price_workload(calls, design="bgemm", bits=4,
                                         unit_n=64, num_units=64,
                                         grid=(4, 4))
        assert cost.utilization < 1.0

    def test_trivial_grid_matches_flat_pricing_plus_type(self):
        flat = accounting.price_workload(self._calls(), design="tubgemm",
                                         bits=4, unit_n=64, num_units=64)
        g11 = accounting.price_workload(self._calls(), design="tubgemm",
                                        bits=4, unit_n=64, num_units=64,
                                        grid=(1, 1))
        assert g11.hop_energy_uj == 0.0
        assert g11.dyn_energy_uj == pytest.approx(flat.dyn_energy_uj)
        assert g11.wc_latency_us == pytest.approx(flat.wc_latency_us)


# ---------------------------------------------------------------------------
# Shared measured-cycles helper (the deduplicated serve/planner contract)
# ---------------------------------------------------------------------------

class TestMeasureMatrixCycles:
    @pytest.mark.parametrize("design", EXACT_DESIGNS)
    def test_bounds_hold_per_design(self, rng, design):
        b = backends.resolve(design, bits=4)
        w = rng.normal(0, 1, (48, 24)).astype(np.float32)
        cyc = backends.measure_matrix_cycles(b, w, rows=4, unit_n=16,
                                             num_units=4)
        assert cyc["dyn_floor"] - 1e-6 <= cyc["measured"] <= cyc["wc"] + 1e-6
        # tiles(4, 24) on 16x16 units = 2; ceil(2 / 4 units) = 1 wave
        assert cyc["wc"] == b.cycles(48)

    def test_non_sparsity_aware_designs_report_all_equal(self, rng):
        b = backends.resolve("bgemm", bits=4)
        w = rng.normal(0, 1, (32, 16)).astype(np.float32)
        cyc = backends.measure_matrix_cycles(b, w, rows=2, unit_n=16,
                                             num_units=4)
        assert cyc["measured"] == cyc["dyn"] == cyc["dyn_floor"] == cyc["wc"]

    def test_grid_backend_waves_use_shard_output_share(self, rng):
        """A grid's per-tile cycles already cover the ceil-split K; the wave
        count must come from a shard's output-column share, not the full
        matrix (shards run their waves in parallel)."""
        w = rng.normal(0, 1, (64, 64)).astype(np.float32)
        flat = backends.resolve("bgemm", bits=4)
        g = as_grid(flat, 1, 4)
        # unit_n=16, num_units=1: flat tiles(4,64)=4 waves; per shard
        # tiles(4,16)=1 wave.  bgemm wc = k cycles per tile (+0 grid hops
        # on the k axis; 3 column hops).
        flat_cyc = backends.measure_matrix_cycles(flat, w, rows=4,
                                                  unit_n=16, num_units=1)
        grid_cyc = backends.measure_matrix_cycles(g, w, rows=4,
                                                  unit_n=16, num_units=1)
        assert flat_cyc["wc"] == 64 * 4
        assert grid_cyc["wc"] == (64 + g.hop_cycles()) * 1

    def test_supplied_stats_skip_reprofiling(self, rng):
        b = backends.resolve("tubgemm", bits=4)
        w = rng.normal(0, 1, (32, 16)).astype(np.float32)
        cyc = backends.measure_matrix_cycles(b, w, rows=2, unit_n=16,
                                             num_units=4, bit_blockmax=0.5,
                                             bit_elem=0.75)
        assert cyc["dyn"] == pytest.approx(b.cycles(32) * 0.5)
        assert cyc["dyn_floor"] == pytest.approx(b.cycles(32) * 0.25)

    def test_serve_totals_are_sums_of_the_shared_helper(self, llama_smoke):
        """Dedup contract, serve side: ``measure_decode_cycles`` is exactly
        the shared helper summed over serve's weight walk."""
        from repro.launch import serve as serve_lib
        cfg, params = llama_smoke
        backend = backends.resolve("tubgemm", bits=4)
        want = {"measured": 0.0, "dyn": 0.0, "dyn_floor": 0.0, "wc": 0.0}
        for _name, w in serve_lib._iter_weight_matrices(cfg, params):
            cyc = backends.measure_matrix_cycles(backend, w, rows=4,
                                                 unit_n=64, num_units=64)
            for key in want:
                want[key] += cyc[key]
        got = serve_lib.measure_decode_cycles(cfg, params, backend, batch=4,
                                              unit_n=64, num_units=64)
        for key in want:
            assert got[key] == pytest.approx(want[key])

    def test_planner_site_cycles_are_sums_of_the_shared_helper(
            self, llama_smoke):
        """Dedup contract, planner side: ``measure_site_cycles`` is exactly
        the shared helper summed over the site's physical weight copies."""
        cfg, params = llama_smoke
        sites = {s.name: s for s in planner.discover_sites(cfg, params,
                                                           batch=4)}
        site = sites["layers/mlp/w_up"]
        entry = SiteAssignment(pattern=site.name, design="tubgemm", bits=4,
                               bit_blockmax=0.3, bit_elem=0.6)
        backend = entry.backend()
        w3 = site.weight_matrix().reshape(-1, site.k, site.n_out)
        want = {"measured": 0.0, "dyn": 0.0, "dyn_floor": 0.0, "wc": 0.0}
        for i in range(w3.shape[0]):
            cyc = backends.measure_matrix_cycles(
                backend, w3[i], rows=site.m, unit_n=64, num_units=64,
                bit_blockmax=0.3, bit_elem=0.6)
            for key in want:
                want[key] += cyc[key]
        got = planner.measure_site_cycles(site, entry, unit_n=64,
                                          num_units=64)
        for key in want:
            assert got[key] == pytest.approx(want[key])

    def test_grid_matrix_cycles_per_shard_bounds(self, rng):
        g = as_grid(backends.resolve("tubgemm", bits=4), 2, 2)
        w = rng.normal(0, 1, (64, 32)).astype(np.float32)
        per_shard = grid_matrix_cycles(g, w, rows=4, unit_n=16, num_units=4)
        assert set(per_shard) == {"0,0", "0,1", "1,0", "1,1"}
        hops = g.hop_cycles()
        for cyc in per_shard.values():
            assert cyc["dyn_floor"] - 1e-6 <= cyc["measured"] \
                <= cyc["wc"] + 1e-6
            assert cyc["wc"] >= hops  # the hop term rides every bound


# ---------------------------------------------------------------------------
# Streamed site discovery (memory-hazard fix)
# ---------------------------------------------------------------------------

class TestStreamedDiscovery:
    def test_sites_hold_leaves_by_reference(self, llama_smoke):
        cfg, params = llama_smoke
        sites = {s.name: s for s in planner.discover_sites(cfg, params,
                                                           batch=2)}
        flat = {"/".join(str(getattr(p, "key", p)) for p in path): leaf
                for path, leaf in
                jax.tree_util.tree_flatten_with_path(params)[0]}
        wq = sites["layers/attn/wq"]
        assert wq.leaf is flat["layers/attn/wq"]  # zero-copy discovery

    def test_weight_matrix_materializes_on_demand(self, llama_smoke):
        cfg, params = llama_smoke
        sites = {s.name: s for s in planner.discover_sites(cfg, params,
                                                           batch=2)}
        wq = sites["layers/attn/wq"]
        w = wq.weight_matrix()
        assert isinstance(w, np.ndarray) and w.dtype == np.float32
        assert w.shape == (wq.count * wq.k, wq.n_out)
        # the back-compat property keeps the old surface
        assert wq.weight.shape == w.shape


# ---------------------------------------------------------------------------
# GridPlan semantics
# ---------------------------------------------------------------------------

class TestGridPlan:
    def test_per_shard_planned_beats_every_shard_uniform(self,
                                                         llama_grid_plan):
        meta = llama_grid_plan.metadata()
        for key, verdict in meta["totals"]["per_shard"].items():
            planned = verdict["planned"]["dyn_energy_uj"]
            for name, tot in verdict["uniform"].items():
                assert planned <= tot["dyn_energy_uj"] * (1 + 1e-9), \
                    f"shard {key} lost to uniform {name}"

    def test_aggregate_planned_beats_every_uniform_grid(self,
                                                        llama_grid_plan):
        agg = llama_grid_plan.metadata()["totals"]["aggregate"]
        for name, tot in agg["uniform"].items():
            assert agg["planned"]["dyn_energy_uj"] \
                <= tot["dyn_energy_uj"] * (1 + 1e-9)
            assert agg["planned_heterogeneous"]["dyn_energy_uj"] \
                <= tot["dyn_energy_uj"] * (1 + 1e-9)

    def test_heterogeneous_planned_no_worse_than_executed(self,
                                                          llama_grid_plan):
        agg = llama_grid_plan.metadata()["totals"]["aggregate"]
        assert agg["planned_heterogeneous"]["dyn_energy_uj"] \
            <= agg["planned"]["dyn_energy_uj"] * (1 + 1e-9)

    def test_shipped_smoke_grid_plan_is_mixed(self, llama_grid_plan):
        assert len(llama_grid_plan.shard_distinct_backends()) >= 2

    def test_round_trip_is_byte_stable(self, llama_grid_plan):
        text = llama_grid_plan.to_json()
        again = GridPlan.from_json(text)
        assert again.to_json() == text
        assert again.grid == llama_grid_plan.grid

    def test_load_plan_sniffs_both_schemas(self, tmp_path, llama_grid_plan):
        gp = tmp_path / "grid.json"
        llama_grid_plan.save(gp)
        assert isinstance(load_plan(gp), GridPlan)
        flat = tmp_path / "flat.json"
        llama_grid_plan.aggregate.save(flat)
        assert isinstance(load_plan(flat), BackendPlan)
        bad = tmp_path / "bad.json"
        bad.write_text(json.dumps({"schema": "nope"}))
        with pytest.raises(ValueError, match="unknown plan schema"):
            load_plan(bad)

    def test_plain_site_names_resolve_grid_wrapped(self, llama_grid_plan):
        b = llama_grid_plan.backend_for("layers/attn/wq")
        assert isinstance(b, GridBackend)
        assert b.grid == llama_grid_plan.grid

    def test_shard_local_site_names_resolve_single_node(self,
                                                        llama_grid_plan):
        for key, shard_plan in llama_grid_plan.shards:
            entry = shard_plan.assignment_for("layers/attn/wq")
            gx, gy = (int(p) for p in key.split(","))
            b = llama_grid_plan.backend_for(
                shard_site((gx, gy), "layers/attn/wq"))
            assert not isinstance(b, GridBackend)
            assert (b.name, b.bits) == (entry.design, entry.bits)

    def test_unknown_site_resolves_none(self, llama_grid_plan):
        assert llama_grid_plan.backend_for("not/a/site") is None
        assert llama_grid_plan.backend_for("9,9/layers/attn/wq") is None

    def test_shard_qualified_miss_never_falls_back_to_aggregate(self):
        """A shard-local name must not leak into the aggregate's globs."""
        flat = BackendPlan(sites=(SiteAssignment(pattern="*",
                                                 design="tubgemm", bits=4),))
        gplan = GridPlan(units_x=2, units_y=2, aggregate=flat, shards=())
        assert gplan.backend_for("5,5/layers/attn/wq") is None
        assert gplan.backend_for("0,0/layers/attn/wq") is None  # no shard plan
        assert isinstance(gplan.backend_for("layers/attn/wq"), GridBackend)

    def test_planner_wc_totals_match_the_grid_pricer(self):
        """Aggregate candidate costs must agree with GridDLAModel (energy
        summed over ALL shards incl. pure-padding ones, latency = slowest
        shard), pinned via the stat-independent worst case on a
        non-divisible site."""
        leaf = np.random.default_rng(0).normal(0, 1, (5, 12)) \
            .astype(np.float32)
        site = planner.GemmSite(name="odd", m=4, k=5, n_out=12, count=1,
                                leaf=leaf)
        cfg = configs.get_smoke_config("llama3-8b")
        gplan = planner.build_grid_plan(cfg, None, grid=(4, 2),
                                        bits_candidates=(4,),
                                        designs=("tubgemm",),
                                        unit_n=16, num_units=4,
                                        sites=[site])
        gdla = ppa.GridDLAModel(design="tubgemm", bits=4, n=16, num_units=4,
                                units_x=4, units_y=2)
        want_e = gdla.matmul_energy_nj(4, 5, 12, 0.0) * 1e-3
        want_l = gdla.matmul_latency_ns(4, 5, 12, 0.0) * 1e-3
        agg = gplan.metadata()["totals"]["aggregate"]
        got = agg["uniform"]["tubgemm@4"]
        assert got["wc_energy_uj"] == pytest.approx(want_e)
        assert got["wc_latency_us"] == pytest.approx(want_l)

    def test_use_plan_rejects_conflicting_grid(self, llama_grid_plan):
        with pytest.raises(ValueError, match="conflicts"):
            with backends.use_plan(llama_grid_plan, grid=(4, 1)):
                pass

    def test_markdown_renders(self, llama_grid_plan):
        md = planner.grid_plan_to_markdown(llama_grid_plan)
        assert "Per-shard verdicts" in md
        assert "uniform" in md.lower()


class TestGridPlanExecution:
    """Degenerate (1,1) grids exercise the sharded dense path on the single
    tier-1 CPU device; the multi-device path runs in the subprocess test."""

    def _dense_site(self, w, x, plan_like):
        with backends.use_plan(plan_like) as execution:
            with backends.site_scope("blk"):
                out = common.dense(w, x, name="w")
        return out, execution

    def test_grid_plan_execution_bit_exact_vs_flat_backend(self, rng):
        w = jnp.asarray(rng.normal(0, 1, (16, 8)), jnp.float32)
        x = jnp.asarray(rng.normal(0, 1, (4, 16)), jnp.float32)
        flat = BackendPlan(sites=(SiteAssignment(pattern="blk/w",
                                                 design="tubgemm", bits=4),))
        gplan = GridPlan(units_x=1, units_y=1, aggregate=flat, shards=())
        out_grid, execution = self._dense_site(w, x, gplan)
        with backends.use_backend("tubgemm", bits=4):
            with backends.site_scope("blk"):
                out_flat = common.dense(w, x, name="w")
        np.testing.assert_array_equal(np.asarray(out_grid),
                                      np.asarray(out_flat))
        assert [c.site for c in execution.calls] == ["blk/w"]
        assert execution.calls[0].backend == "tubgemm"

    def test_use_plan_grid_kwarg_wraps_flat_plans(self, rng):
        w = jnp.asarray(rng.normal(0, 1, (12, 6)), jnp.float32)
        x = jnp.asarray(rng.normal(0, 1, (3, 12)), jnp.float32)
        plan = BackendPlan(sites=(SiteAssignment(pattern="*", design="bgemm",
                                                 bits=8),))
        with backends.use_plan(plan, grid="1,1") as execution:
            common.dense(w, x, name="w")
        backend = execution.backend_for("w")
        assert isinstance(backend, GridBackend)
        assert backend.grid == (1, 1)


# ---------------------------------------------------------------------------
# Multi-device: bit-exactness + sharded plan replay (pinned subprocess)
# ---------------------------------------------------------------------------

MULTIDEV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
from repro import backends, configs, compat
from repro.backends.plan import BackendPlan, SiteAssignment
from repro.eval import planner
from repro.models import common, model as model_lib
from jax.sharding import PartitionSpec as P

rng = np.random.default_rng(0)

# ---- 1. grid execute bit-exact vs the single-unit backend ------------------
for bits in (2, 4, 8):
    v = 2 ** (bits - 1) - 1
    a = jnp.asarray(rng.integers(-v, v + 1, (6, 24)), jnp.int8)
    w = jnp.asarray(rng.integers(-v, v + 1, (24, 20)), jnp.int8)
    for design in ("ugemm", "tugemm", "tubgemm", "bgemm"):
        b = backends.resolve(design, bits=bits)
        ref = np.asarray(b.execute(a, w))
        for grid in ((2, 2), (4, 2), (3, 2)):
            got = np.asarray(backends.as_grid(b, *grid).execute(a, w))
            assert np.array_equal(got, ref), (design, bits, grid)
print("GRID_BITEXACT_OK")

# ---- 2. site lookup resolves identically on every shard --------------------
# A (2,2) grid plan executes the model SPMD: the traced dense sites must be
# exactly the flat plan's sites, and an exact design's logits bit-identical
# to the unsharded use_backend run.
cfg = configs.get_smoke_config("llama3-8b")
params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
tokens = jnp.zeros((2, 4), jnp.int32)
flat = BackendPlan(sites=(SiteAssignment(pattern="*", design="tubgemm",
                                         bits=4),))
gplan = backends.GridPlan(units_x=2, units_y=2, aggregate=flat, shards=())
with backends.use_plan(gplan) as grid_exec:
    logits_grid, _ = model_lib.forward(params, cfg, tokens)
with backends.use_backend("tubgemm", bits=4) as flat_exec:
    logits_flat, _ = model_lib.forward(params, cfg, tokens)
grid_sites = sorted(c.site for c in grid_exec.calls)
flat_sites = sorted(c.site for c in flat_exec.calls)
assert grid_sites == flat_sites, (grid_sites, flat_sites)
assert all(isinstance(grid_exec.backend_for(s), backends.GridBackend)
           for s in grid_sites)
assert np.array_equal(np.asarray(logits_grid), np.asarray(logits_flat))
print("GRID_MODEL_BITEXACT_OK", len(grid_sites))

# ---- 3. per-shard heterogeneous plan: derive + grid-execute ----------------
gp = planner.build_grid_plan(cfg, params, batch=2, grid=(2, 2), unit_n=64,
                             num_units=64)
with backends.use_plan(gp) as execution:
    logits_plan, _ = model_lib.forward(params, cfg, tokens)
assert len(execution.calls) == len(gp.aggregate.sites)
tags = {c.site: (c.backend, c.bits) for c in execution.calls}
for entry in gp.aggregate.sites:
    assert tags[entry.pattern] == (entry.design, entry.bits)
print("GRID_PLAN_REPLAY_OK", len(gp.heterogeneous_sites()))

# ---- 4. dense inside an explicit shard_map sees the same site --------------
# (the models/common.dense site-lookup contract under shard_map: trace-time
# thread-local state is shared by every shard of the single SPMD trace)
from repro.launch.mesh import make_grid_mesh
mesh = make_grid_mesh(2, 2)
w2 = jnp.asarray(rng.normal(0, 1, (8, 4)), jnp.float32)
x2 = jnp.asarray(rng.normal(0, 1, (4, 8)), jnp.float32)
with backends.use_backend("bgemm", bits=8) as execution:
    def body(xs):
        with backends.site_scope("inner"):
            return common.dense(w2, xs, name="w")
    fn = compat.shard_map(body, mesh=mesh, in_specs=(P(),), out_specs=P(),
                          check_vma=False)
    out_sharded = fn(x2)
assert [c.site for c in execution.calls] == ["inner/w"]
with backends.use_backend("bgemm", bits=8):
    with backends.site_scope("inner"):
        out_ref = common.dense(w2, x2, name="w")
assert np.array_equal(np.asarray(out_sharded), np.asarray(out_ref))
print("DENSE_UNDER_SHARD_MAP_OK")
"""


def test_grid_multidevice():
    """The acceptance claim: on a >= 4-device host mesh, GridBackend.execute
    is bit-exact vs the single-unit backend for every simulated design at
    bits {2, 4, 8}, per-shard plans replay SPMD, and dense's site lookup
    resolves identically on every shard."""
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
           "JAX_PLATFORMS": "cpu",
           "JAX_DISABLE_MOST_OPTIMIZATIONS": "1",
           "JAX_COMPILATION_CACHE_DIR": os.path.abspath(".jax_cache"),
           "JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS": "0"}
    res = subprocess.run([sys.executable, "-c", MULTIDEV_SCRIPT],
                         capture_output=True, text=True, timeout=900,
                         env=env)
    out = res.stdout
    for marker in ("GRID_BITEXACT_OK", "GRID_MODEL_BITEXACT_OK",
                   "GRID_PLAN_REPLAY_OK", "DENSE_UNDER_SHARD_MAP_OK"):
        assert marker in out, f"missing {marker}\n{out}\n{res.stderr}"
