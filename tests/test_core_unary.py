"""Core unary arithmetic: encodings, simulators, equivalence to the oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import gemm_sims as gs
from repro.core import unary
from repro.core.quantization import quantize, vmax


def rand_ints(rng, bits, shape):
    v = vmax(bits)
    return jnp.asarray(rng.integers(-v, v + 1, shape), jnp.int8)


class TestEncodings:
    @pytest.mark.parametrize("bits", [2, 3, 4, 8])
    def test_temporal_roundtrip(self, rng, bits):
        q = rand_ints(rng, bits, (4, 5))
        stream, sign = unary.encode_temporal(q, bits)
        assert stream.shape[0] == unary.temporal_stream_len(bits)
        assert bool(jnp.all(unary.decode_temporal(stream, sign) == q))

    @pytest.mark.parametrize("bits", [2, 3, 4, 8])
    def test_tub_roundtrip(self, rng, bits):
        q = rand_ints(rng, bits, (6,))
        s2, lsb, sign = unary.encode_tub(q, bits)
        assert s2.shape[0] == unary.tub_stream_len(bits)
        assert bool(jnp.all(unary.decode_tub(s2, lsb, sign) == q))

    def test_temporal_stream_is_thermometer(self, rng):
        """1s are consecutive from slot 0 (exactly two signal transitions)."""
        q = rand_ints(rng, 4, (8,))
        stream, _ = unary.encode_temporal(q, 4)
        diffs = jnp.diff(stream.astype(jnp.int32), axis=0)
        # once the stream drops to 0 it never rises again
        assert bool(jnp.all(diffs <= 0))

    def test_van_der_corput_low_discrepancy(self):
        seq = np.asarray(unary.van_der_corput(256))
        assert len(np.unique(seq)) == 256
        # first 2^k prefix is equidistributed
        for k in (16, 64, 256):
            assert abs(np.mean(seq[:k]) - 0.5) < 1.0 / k + 0.01

    @pytest.mark.parametrize("bits,scheme", [(4, "temporal"), (4, "tub"),
                                             (8, "temporal")])
    def test_bit_sparsity_of_stream(self, rng, bits, scheme):
        q = rand_ints(rng, bits, (64,))
        b = float(unary.bit_sparsity_of_stream(q, bits, scheme))
        assert 0.0 <= b <= 1.0


class TestExactSimulators:
    """tuGEMM and tubGEMM are deterministic: bit-identical to integer GEMM."""

    @pytest.mark.parametrize("bits", [2, 3, 4])
    @pytest.mark.parametrize("shape", [(3, 4, 5), (1, 8, 2), (7, 3, 7)])
    def test_tugemm_stream_equals_oracle(self, rng, bits, shape):
        m, k, n = shape
        a, b = rand_ints(rng, bits, (m, k)), rand_ints(rng, bits, (k, n))
        out, cycles = gs.tugemm_stream(a, b, bits)
        assert bool(jnp.all(out == gs.bgemm_exact(a, b)))
        assert cycles == k * (2 ** (bits - 1)) ** 2

    @pytest.mark.parametrize("bits", [2, 3, 4, 8])
    @pytest.mark.parametrize("shape", [(3, 4, 5), (2, 6, 3)])
    def test_tubgemm_stream_equals_oracle(self, rng, bits, shape):
        m, k, n = shape
        a, b = rand_ints(rng, bits, (m, k)), rand_ints(rng, bits, (k, n))
        out, cycles = gs.tubgemm_stream(a, b, bits)
        assert bool(jnp.all(out == gs.bgemm_exact(a, b)))
        assert cycles == k * max(1, 2 ** (bits - 2))

    @given(bits=st.sampled_from([2, 3, 4]),
           m=st.integers(1, 5), k=st.integers(1, 6), n=st.integers(1, 5),
           seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_property_exact_designs_match_oracle(self, bits, m, k, n, seed):
        r = np.random.default_rng(seed)
        v = vmax(bits)
        a = jnp.asarray(r.integers(-v, v + 1, (m, k)), jnp.int8)
        b = jnp.asarray(r.integers(-v, v + 1, (k, n)), jnp.int8)
        oracle = gs.bgemm_exact(a, b)
        assert bool(jnp.all(gs.tugemm_stream(a, b, bits)[0] == oracle))
        assert bool(jnp.all(gs.tubgemm_stream(a, b, bits)[0] == oracle))


class TestUGEMM:
    def test_stream_matches_lut_fast_path(self, rng):
        for bits in (2, 4, 8):
            a, b = rand_ints(rng, bits, (5, 16)), rand_ints(rng, bits, (16, 5))
            s, cycles = gs.ugemm_stream(a, b, bits)
            f = gs.ugemm_exact(a, b, bits=bits)
            assert cycles == 2 ** bits
            np.testing.assert_allclose(np.asarray(s), np.asarray(f),
                                       rtol=1e-4, atol=1e-2)

    def test_exact_at_2bit(self, rng):
        a, b = rand_ints(rng, 2, (4, 8)), rand_ints(rng, 2, (8, 4))
        out = gs.ugemm_exact(a, b, bits=2)
        assert bool(jnp.all(out == gs.bgemm_exact(a, b)))

    def test_8bit_error_small(self, rng):
        """Paper: uGEMM output within ~1% of ideal at 8-bit GEMM level."""
        a, b = rand_ints(rng, 8, (16, 64)), rand_ints(rng, 8, (64, 16))
        est = gs.ugemm_exact(a, b, bits=8)
        oracle = np.asarray(gs.bgemm_exact(a, b), np.float64)
        rel = np.sqrt(np.mean((np.asarray(est) - oracle) ** 2)) / \
            np.sqrt(np.mean(oracle ** 2))
        assert rel < 0.04

    def test_stochastic_error_decreases_with_bits(self, rng):
        errs = {}
        for bits in (4, 8):
            a, b = rand_ints(rng, bits, (8, 32)), rand_ints(rng, bits, (32, 8))
            est = np.asarray(gs.ugemm_exact(a, b, bits=bits), np.float64)
            oracle = np.asarray(gs.bgemm_exact(a, b), np.float64)
            errs[bits] = np.sqrt(np.mean((est - oracle) ** 2)) / \
                np.sqrt(np.mean(oracle ** 2))
        assert errs[8] < errs[4]


class TestLatencyModel:
    def test_wc_cycles_formulas(self):
        # paper §II: bGEMM N, uGEMM 2^w, tuGEMM N(2^(w-1))^2, tubGEMM N·2^(w-2)
        assert gs.wc_cycles("bgemm", 8, 16) == 16
        assert gs.wc_cycles("ugemm", 8, 16) == 256
        assert gs.wc_cycles("tugemm", 8, 16) == 16 * 128 ** 2
        assert gs.wc_cycles("tubgemm", 8, 16) == 16 * 64

    def test_dynamic_cycles_eq1(self):
        # Eq. 1: dynamic = WC * (1 - b_spa); only temporal designs benefit
        wc = gs.wc_cycles("tubgemm", 8, 32)
        assert gs.dynamic_cycles_from_sparsity("tubgemm", 8, 32, 0.4) == \
            pytest.approx(wc * 0.6)
        assert gs.dynamic_cycles_from_sparsity("bgemm", 8, 32, 0.9) == \
            gs.wc_cycles("bgemm", 8, 32)
        assert gs.dynamic_cycles_from_sparsity("ugemm", 8, 32, 0.9) == \
            gs.wc_cycles("ugemm", 8, 32)

    @given(bspa=st.floats(0.0, 1.0), bits=st.sampled_from([2, 4, 8]),
           n=st.sampled_from([16, 32, 64]))
    @settings(max_examples=40, deadline=None)
    def test_property_dynamic_never_exceeds_wc(self, bspa, bits, n):
        for d in gs.DESIGNS:
            dyn = gs.dynamic_cycles_from_sparsity(d, bits, n, bspa)
            assert dyn <= gs.wc_cycles(d, bits, n) + 1e-9
