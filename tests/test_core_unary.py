"""Core unary arithmetic: encodings, simulators, equivalence to the oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # CI image has no hypothesis; use the local shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import gemm_sims as gs
from repro.core import unary
from repro.core.quantization import quantize, vmax


def rand_ints(rng, bits, shape):
    v = vmax(bits)
    return jnp.asarray(rng.integers(-v, v + 1, shape), jnp.int8)


class TestEncodings:
    @pytest.mark.parametrize("bits", [2, 3, 4, 8])
    def test_temporal_roundtrip(self, rng, bits):
        q = rand_ints(rng, bits, (4, 5))
        stream, sign = unary.encode_temporal(q, bits)
        assert stream.shape[0] == unary.temporal_stream_len(bits)
        assert bool(jnp.all(unary.decode_temporal(stream, sign) == q))

    @pytest.mark.parametrize("bits", [2, 3, 4, 8])
    def test_tub_roundtrip(self, rng, bits):
        q = rand_ints(rng, bits, (6,))
        s2, lsb, sign = unary.encode_tub(q, bits)
        assert s2.shape[0] == unary.tub_stream_len(bits)
        assert bool(jnp.all(unary.decode_tub(s2, lsb, sign) == q))

    def test_temporal_stream_is_thermometer(self, rng):
        """1s are consecutive from slot 0 (exactly two signal transitions)."""
        q = rand_ints(rng, 4, (8,))
        stream, _ = unary.encode_temporal(q, 4)
        diffs = jnp.diff(stream.astype(jnp.int32), axis=0)
        # once the stream drops to 0 it never rises again
        assert bool(jnp.all(diffs <= 0))

    def test_van_der_corput_low_discrepancy(self):
        seq = np.asarray(unary.van_der_corput(256))
        assert len(np.unique(seq)) == 256
        # first 2^k prefix is equidistributed
        for k in (16, 64, 256):
            assert abs(np.mean(seq[:k]) - 0.5) < 1.0 / k + 0.01

    @pytest.mark.parametrize("bits,scheme", [(4, "temporal"), (4, "tub"),
                                             (8, "temporal")])
    def test_bit_sparsity_of_stream(self, rng, bits, scheme):
        q = rand_ints(rng, bits, (64,))
        b = float(unary.bit_sparsity_of_stream(q, bits, scheme))
        assert 0.0 <= b <= 1.0

    @pytest.mark.parametrize("bits", [2, 4, 8])
    @pytest.mark.parametrize("phase,reflect", [(0, False), (3, False),
                                               (0, True), (5, True)])
    def test_rate_roundtrip_decorrelation_modes(self, rng, bits, phase, reflect):
        """decode(encode) recovers q exactly after rounding, in every mode.

        The comparator values are the L = 2^w multiples of 1/L, so the count
        error is < Vmax/L = 1/2 - 2^-w < 0.5 codes for the base, rolled, and
        reflected sequences alike — rounding recovers the code exactly.
        """
        q = rand_ints(rng, bits, (32,))
        stream, sign = unary.encode_rate(q, bits, phase=phase, reflect=reflect)
        dec = unary.decode_rate(stream, sign, bits)
        assert bool(jnp.all(jnp.round(dec).astype(jnp.int32) == q))

    def test_rate_phase_and_reflect_are_independent(self, rng):
        """phase rotates (count-preserving); reflect mirrors (count-shifting)."""
        q = jnp.asarray(rng.integers(1, vmax(8) + 1, (64,)), jnp.int8)
        base, _ = unary.encode_rate(q, 8)
        rolled, _ = unary.encode_rate(q, 8, phase=3)
        reflected, _ = unary.encode_rate(q, 8, reflect=True)
        # a pure rotation permutes slots: per-element 1s-count is unchanged
        assert bool(jnp.all(unary.ones_count(rolled) == unary.ones_count(base)))
        # but the slot order really did change for some element
        assert not bool(jnp.all(rolled == base))
        # reflection drops exactly one slot per nonzero magnitude
        assert bool(jnp.all(unary.ones_count(reflected)
                            == unary.ones_count(base) - 1))


class TestExactSimulators:
    """tuGEMM and tubGEMM are deterministic: bit-identical to integer GEMM."""

    @pytest.mark.parametrize("bits", [2, 3, 4])
    @pytest.mark.parametrize("shape", [(3, 4, 5), (1, 8, 2), (7, 3, 7)])
    def test_tugemm_stream_equals_oracle(self, rng, bits, shape):
        m, k, n = shape
        a, b = rand_ints(rng, bits, (m, k)), rand_ints(rng, bits, (k, n))
        out, cycles = gs.tugemm_stream(a, b, bits)
        assert bool(jnp.all(out == gs.bgemm_exact(a, b)))
        assert cycles == k * (2 ** (bits - 1)) ** 2

    @pytest.mark.parametrize("bits", [2, 3, 4, 8])
    @pytest.mark.parametrize("shape", [(3, 4, 5), (2, 6, 3)])
    def test_tubgemm_stream_equals_oracle(self, rng, bits, shape):
        m, k, n = shape
        a, b = rand_ints(rng, bits, (m, k)), rand_ints(rng, bits, (k, n))
        out, cycles = gs.tubgemm_stream(a, b, bits)
        assert bool(jnp.all(out == gs.bgemm_exact(a, b)))
        assert cycles == k * max(1, 2 ** (bits - 2))

    @given(bits=st.sampled_from([2, 3, 4]),
           m=st.integers(1, 5), k=st.integers(1, 6), n=st.integers(1, 5),
           seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_property_exact_designs_match_oracle(self, bits, m, k, n, seed):
        r = np.random.default_rng(seed)
        v = vmax(bits)
        a = jnp.asarray(r.integers(-v, v + 1, (m, k)), jnp.int8)
        b = jnp.asarray(r.integers(-v, v + 1, (k, n)), jnp.int8)
        oracle = gs.bgemm_exact(a, b)
        assert bool(jnp.all(gs.tugemm_stream(a, b, bits)[0] == oracle))
        assert bool(jnp.all(gs.tubgemm_stream(a, b, bits)[0] == oracle))


class TestUGEMM:
    def test_stream_matches_lut_fast_path(self, rng):
        for bits in (2, 4, 8):
            a, b = rand_ints(rng, bits, (5, 16)), rand_ints(rng, bits, (16, 5))
            s, cycles = gs.ugemm_stream(a, b, bits)
            f = gs.ugemm_exact(a, b, bits=bits)
            assert cycles == 2 ** bits
            np.testing.assert_allclose(np.asarray(s), np.asarray(f),
                                       rtol=1e-4, atol=1e-2)

    def test_exact_at_2bit(self, rng):
        a, b = rand_ints(rng, 2, (4, 8)), rand_ints(rng, 2, (8, 4))
        out = gs.ugemm_exact(a, b, bits=2)
        assert bool(jnp.all(out == gs.bgemm_exact(a, b)))

    def test_8bit_error_small(self, rng):
        """Paper: uGEMM output within ~1% of ideal at 8-bit GEMM level."""
        a, b = rand_ints(rng, 8, (16, 64)), rand_ints(rng, 8, (64, 16))
        est = gs.ugemm_exact(a, b, bits=8)
        oracle = np.asarray(gs.bgemm_exact(a, b), np.float64)
        rel = np.sqrt(np.mean((np.asarray(est) - oracle) ** 2)) / \
            np.sqrt(np.mean(oracle ** 2))
        assert rel < 0.04

    def test_stochastic_error_decreases_with_bits(self, rng):
        errs = {}
        for bits in (4, 8):
            a, b = rand_ints(rng, bits, (8, 32)), rand_ints(rng, bits, (32, 8))
            est = np.asarray(gs.ugemm_exact(a, b, bits=bits), np.float64)
            oracle = np.asarray(gs.bgemm_exact(a, b), np.float64)
            errs[bits] = np.sqrt(np.mean((est - oracle) ** 2)) / \
                np.sqrt(np.mean(oracle ** 2))
        assert errs[8] < errs[4]


class TestVectorizedEngineMatchesScan:
    """The slot-parallel engine is bit-identical — outputs *and* cycle
    counts — to the sequential ``lax.scan`` references it replaced."""

    @pytest.mark.parametrize("bits", [2, 3, 4])
    @pytest.mark.parametrize("shape", [(3, 4, 5), (1, 8, 2), (6, 3, 7)])
    def test_tugemm_vec_equals_scan(self, rng, bits, shape):
        m, k, n = shape
        a, b = rand_ints(rng, bits, (m, k)), rand_ints(rng, bits, (k, n))
        out_v, cyc_v = gs.tugemm_stream(a, b, bits)
        out_s, cyc_s = gs.tugemm_stream_scan(a, b, bits)
        assert out_v.dtype == out_s.dtype
        assert bool(jnp.all(out_v == out_s))
        assert int(cyc_v) == int(cyc_s)

    @pytest.mark.parametrize("bits", [2, 3, 4, 8])
    @pytest.mark.parametrize("shape", [(3, 4, 5), (2, 6, 3), (5, 7, 2)])
    def test_tubgemm_vec_equals_scan(self, rng, bits, shape):
        m, k, n = shape
        a, b = rand_ints(rng, bits, (m, k)), rand_ints(rng, bits, (k, n))
        out_v, cyc_v = gs.tubgemm_stream(a, b, bits)
        out_s, cyc_s = gs.tubgemm_stream_scan(a, b, bits)
        assert out_v.dtype == out_s.dtype
        assert bool(jnp.all(out_v == out_s))
        assert int(cyc_v) == int(cyc_s)

    @pytest.mark.parametrize("bits", [2, 4, 8])
    @pytest.mark.parametrize("shape", [(5, 12, 7), (2, 9, 3)])
    def test_ugemm_vec_equals_scan_bitwise(self, rng, bits, shape):
        """Even the float uGEMM estimate matches bit-for-bit: the AND counts
        are exact integers in both engines, scaled by the same constant."""
        m, k, n = shape
        a, b = rand_ints(rng, bits, (m, k)), rand_ints(rng, bits, (k, n))
        out_v, cyc_v = gs.ugemm_stream(a, b, bits)
        out_s, cyc_s = gs.ugemm_stream_scan(a, b, bits)
        assert np.array_equal(np.asarray(out_v), np.asarray(out_s))
        assert int(cyc_v) == int(cyc_s) == 2 ** bits


class TestDesignRegistry:
    def test_builtin_designs_registered(self):
        assert gs.DESIGNS == ("ugemm", "tugemm", "tubgemm", "bgemm")
        for d in gs.DESIGNS:
            assert gs.get_design(d).name == d

    def test_unknown_design_raises_everywhere(self, rng):
        a, b = rand_ints(rng, 4, (2, 3)), rand_ints(rng, 4, (3, 2))
        for fn in (lambda: gs.gemm("nope", a, b, 4),
                   lambda: gs.wc_cycles("nope", 4, 8),
                   lambda: gs.dynamic_cycles_from_sparsity("nope", 4, 8, 0.5),
                   lambda: gs.stream_gemm("nope", a, b, 4)):
            with pytest.raises(ValueError, match="unknown design"):
                fn()

    def test_duplicate_registration_rejected(self):
        with pytest.raises(ValueError, match="already registered"):
            gs.register_design("bgemm", gs.get_design("bgemm").exact_fn,
                               gs.get_design("bgemm").stream_fn,
                               gs.get_design("bgemm").wc_cycles_fn)

    def test_custom_design_plugs_into_dispatch(self, rng):
        name = "test_double_bgemm"
        try:
            gs.register_design(
                name,
                exact_fn=lambda a, b, bits: 2 * gs.bgemm_exact(a, b),
                stream_fn=lambda a, b, bits: (2 * gs.bgemm_exact(a, b), 42),
                wc_cycles_fn=lambda bits, common_dim: 7 * common_dim,
                sparsity_aware=True)
            a, b = rand_ints(rng, 4, (3, 4)), rand_ints(rng, 4, (4, 2))
            assert bool(jnp.all(gs.gemm(name, a, b, 4)
                                == 2 * gs.bgemm_exact(a, b)))
            assert gs.wc_cycles(name, 4, 8) == 56
            assert gs.dynamic_cycles_from_sparsity(name, 4, 8, 0.5) == \
                pytest.approx(28.0)
            assert name in gs.DESIGNS
        finally:
            gs._REGISTRY.pop(name, None)
            gs.DESIGNS = tuple(gs._REGISTRY)

    def test_stream_gemm_dispatch(self, rng):
        a, b = rand_ints(rng, 4, (3, 5)), rand_ints(rng, 4, (5, 3))
        out, cycles = gs.stream_gemm("bgemm", a, b, 4)
        assert bool(jnp.all(out == gs.bgemm_exact(a, b)))
        assert int(cycles) == 5
        out, cycles = gs.stream_gemm("tubgemm", a, b, 4)
        assert bool(jnp.all(out == gs.bgemm_exact(a, b)))
        assert int(cycles) == 5 * 4


class TestGemmBatched:
    @pytest.mark.parametrize("bits", [2, 4, 8])
    def test_matches_per_problem_gemm(self, rng, bits):
        batch, (m, k, n) = 3, (4, 6, 5)
        a = jnp.stack([rand_ints(rng, bits, (m, k)) for _ in range(batch)])
        b = jnp.stack([rand_ints(rng, bits, (k, n)) for _ in range(batch)])
        for design in gs.DESIGNS:
            out = gs.gemm_batched(design, a, b, bits)
            assert out.shape == (batch, m, n)
            for i in range(batch):
                want = gs.gemm(design, a[i], b[i], bits)
                assert np.array_equal(np.asarray(out[i]), np.asarray(want))

    def test_shared_weight_operand(self, rng):
        """(B, M, K) activations against one (K, N) weight — the serving case."""
        a = jnp.stack([rand_ints(rng, 8, (4, 6)) for _ in range(3)])
        b = rand_ints(rng, 8, (6, 5))
        out = gs.gemm_batched("tubgemm", a, b, 8)
        for i in range(3):
            assert bool(jnp.all(out[i] == gs.bgemm_exact(a[i], b)))

    def test_unbatched_falls_through(self, rng):
        a, b = rand_ints(rng, 4, (3, 4)), rand_ints(rng, 4, (4, 3))
        assert bool(jnp.all(gs.gemm_batched("bgemm", a, b, 4)
                            == gs.bgemm_exact(a, b)))


class TestLatencyModel:
    def test_wc_cycles_formulas(self):
        # paper §II: bGEMM N, uGEMM 2^w, tuGEMM N(2^(w-1))^2, tubGEMM N·2^(w-2)
        assert gs.wc_cycles("bgemm", 8, 16) == 16
        assert gs.wc_cycles("ugemm", 8, 16) == 256
        assert gs.wc_cycles("tugemm", 8, 16) == 16 * 128 ** 2
        assert gs.wc_cycles("tubgemm", 8, 16) == 16 * 64

    def test_dynamic_cycles_eq1(self):
        # Eq. 1: dynamic = WC * (1 - b_spa); only temporal designs benefit
        wc = gs.wc_cycles("tubgemm", 8, 32)
        assert gs.dynamic_cycles_from_sparsity("tubgemm", 8, 32, 0.4) == \
            pytest.approx(wc * 0.6)
        assert gs.dynamic_cycles_from_sparsity("bgemm", 8, 32, 0.9) == \
            gs.wc_cycles("bgemm", 8, 32)
        assert gs.dynamic_cycles_from_sparsity("ugemm", 8, 32, 0.9) == \
            gs.wc_cycles("ugemm", 8, 32)

    @given(bspa=st.floats(0.0, 1.0), bits=st.sampled_from([2, 4, 8]),
           n=st.sampled_from([16, 32, 64]))
    @settings(max_examples=40, deadline=None)
    def test_property_dynamic_never_exceeds_wc(self, bspa, bits, n):
        for d in gs.DESIGNS:
            dyn = gs.dynamic_cycles_from_sparsity(d, bits, n, bspa)
            assert dyn <= gs.wc_cycles(d, bits, n) + 1e-9
