"""Per-layer mixed-precision planner: plan round-trip, site-pattern
precedence, use_plan execution bit-exactness, and pricing properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import backends, configs
from repro.backends.plan import SCHEMA, BackendPlan, SiteAssignment
from repro.eval import planner
from repro.models import common, model as model_lib


@pytest.fixture(scope="module")
def llama_smoke():
    cfg = configs.get_smoke_config("llama3-8b")
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def llama_plan(llama_smoke):
    cfg, params = llama_smoke
    return planner.build_plan(cfg, params, batch=2, unit_n=64, num_units=64)


def _entry(pattern, design="tubgemm", bits=4, **kw):
    return SiteAssignment(pattern=pattern, design=design, bits=bits, **kw)


# ---------------------------------------------------------------------------
# Pattern matching / precedence
# ---------------------------------------------------------------------------

class TestPatternPrecedence:
    def test_exact_beats_any_glob(self):
        plan = BackendPlan(sites=(
            _entry("layers/attn/*", "tubgemm", 4),
            _entry("layers/attn/wq", "bgemm", 8),
            _entry("*", "tugemm", 4),
        ))
        assert plan.assignment_for("layers/attn/wq").design == "bgemm"
        assert plan.assignment_for("layers/attn/wv").design == "tubgemm"
        assert plan.assignment_for("lm_head").design == "tugemm"

    def test_most_literal_glob_wins(self):
        plan = BackendPlan(sites=(
            _entry("*", "tugemm", 4),
            _entry("layers/mlp/*", "bgemm", 4),
            _entry("layers/*", "tubgemm", 4),
        ))
        # "layers/mlp/*" (10 literals) beats "layers/*" (7) beats "*" (0)
        assert plan.assignment_for("layers/mlp/w_up").design == "bgemm"
        assert plan.assignment_for("layers/attn/wq").design == "tubgemm"

    def test_tie_goes_to_earliest_entry(self):
        plan = BackendPlan(sites=(
            _entry("layers/*/wq", "bgemm", 4),
            _entry("layers/a*wq", "tugemm", 4),  # same literal count (9)
        ))
        assert plan.assignment_for("layers/attn/wq").design == "bgemm"

    def test_star_crosses_path_separators(self):
        plan = BackendPlan(sites=(_entry("*w_up", "bgemm", 4),))
        assert plan.assignment_for("layers/mlp/w_up") is not None
        assert plan.assignment_for("layers/moe/shared/w_up") is not None

    def test_no_match_means_float_path(self):
        plan = BackendPlan(sites=(_entry("layers/*", "tubgemm", 4),))
        assert plan.assignment_for("lm_head") is None
        assert plan.backend_for("lm_head") is None

    def test_backend_for_resolves_design_and_bits(self):
        plan = BackendPlan(sites=(_entry("a", "bgemm", 8),))
        b = plan.backend_for("a")
        assert (b.name, b.bits) == ("bgemm", 8)


# ---------------------------------------------------------------------------
# JSON round-trip
# ---------------------------------------------------------------------------

class TestRoundTrip:
    def test_json_round_trip_is_identity(self, llama_plan):
        again = BackendPlan.from_json(llama_plan.to_json())
        assert again == llama_plan

    def test_save_load_round_trip(self, llama_plan, tmp_path):
        path = llama_plan.save(tmp_path / "plan.json")
        assert BackendPlan.load(path) == llama_plan

    def test_schema_is_validated(self):
        with pytest.raises(ValueError, match="schema"):
            BackendPlan.from_json('{"schema": "bogus", "sites": []}')

    def test_required_fields_are_validated(self):
        doc = ('{"schema": "%s", "sites": [{"pattern": "x"}]}' % SCHEMA)
        with pytest.raises(ValueError, match="missing"):
            BackendPlan.from_json(doc)
        doc = ('{"schema": "%s", "sites": [{"pattern": "x", "design": '
               '"bgemm", "bits": 4, "bogus": 1}]}' % SCHEMA)
        with pytest.raises(ValueError, match="unknown site fields"):
            BackendPlan.from_json(doc)

    def test_meta_survives(self, llama_plan):
        meta = BackendPlan.from_json(llama_plan.to_json()).metadata()
        assert meta["unit_n"] == 64
        assert "totals" in meta


# ---------------------------------------------------------------------------
# Execution: use_plan vs use_backend
# ---------------------------------------------------------------------------

class TestPlanExecution:
    def test_wildcard_plan_matches_use_backend_bit_exactly(self, llama_smoke):
        """A '*' plan is semantically use_backend: bit-identical logits."""
        cfg, params = llama_smoke
        tokens = jnp.asarray(
            np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 8)),
            jnp.int32)
        plan = BackendPlan(sites=(_entry("*", "tubgemm", 4),))
        with backends.use_plan(plan):
            got, _ = model_lib.forward(params, cfg, tokens)
        with backends.use_backend("tubgemm", bits=4):
            ref, _ = model_lib.forward(params, cfg, tokens)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))

    def test_per_site_dense_matches_assigned_use_backend(self):
        """Mixed plan: each site's dense output equals use_backend of the
        backend the plan assigns to that site (differing bit-widths)."""
        rng = np.random.default_rng(1)
        w = jnp.asarray(rng.normal(size=(24, 16)), jnp.float32)
        x = jnp.asarray(rng.normal(size=(4, 24)), jnp.float32)
        plan = BackendPlan(sites=(
            _entry("layers/attn/wq", "tubgemm", 8),
            _entry("layers/*", "bgemm", 4),
        ))
        for leaf, assigned in (("wq", ("tubgemm", 8)), ("wv", ("bgemm", 4))):
            with backends.use_plan(plan), \
                    backends.site_scope("layers"), backends.site_scope("attn"):
                got = common.dense(w, x, name=leaf)
            with backends.use_backend(*assigned[:1], bits=assigned[1]):
                ref = common.dense(w, x)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))

    def test_mixed_plan_records_assigned_backend_per_site(self, llama_smoke):
        cfg, params = llama_smoke
        tokens = jnp.zeros((2, 8), jnp.int32)
        plan = BackendPlan(sites=(
            _entry("layers/attn/wv", "bgemm", 4),
            _entry("*", "tubgemm", 4),
        ))
        with backends.use_plan(plan) as execution:
            model_lib.forward(params, cfg, tokens)
        by_site = {c.site: (c.backend, c.bits) for c in execution.calls}
        assert by_site["layers/attn/wv"] == ("bgemm", 4)
        assert by_site["layers/attn/wq"] == ("tubgemm", 4)
        assert by_site["lm_head"] == ("tubgemm", 4)

    def test_unmatched_sites_stay_float(self, llama_smoke):
        cfg, params = llama_smoke
        tokens = jnp.zeros((2, 8), jnp.int32)
        plan = BackendPlan(sites=(_entry("layers/mlp/*", "tubgemm", 4),))
        with backends.use_plan(plan) as execution:
            model_lib.forward(params, cfg, tokens)
        contracted = {c.site for c in execution.calls}
        assert contracted == {"layers/mlp/w_up", "layers/mlp/w_gate",
                              "layers/mlp/w_down"}

    def test_unmatched_sites_run_float_even_with_quant_kernel_cfg(self):
        """A live scope owns execution: plan-unmatched sites run FLOAT, never
        the cfg.quant_kernel quantized path (the documented contract)."""
        rng = np.random.default_rng(2)
        w = jnp.asarray(rng.normal(size=(32, 16)), jnp.float32)
        x = jnp.asarray(rng.normal(size=(4, 32)), jnp.float32)
        cfg = configs.get_smoke_config("llama3-8b").replace(
            quant_bits=4, quant_kernel=True)
        plan = BackendPlan(sites=(_entry("matches/nothing", "tubgemm", 4),))
        with backends.use_plan(plan):
            got = common.dense(w, x, cfg, name="unplanned")
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(jnp.matmul(x, w)))
        # outside any scope the same cfg takes the quantized kernel path
        assert not np.array_equal(np.asarray(common.dense(w, x, cfg)),
                                  np.asarray(jnp.matmul(x, w)))

    def test_saved_plan_replays_bit_exactly(self, llama_smoke, llama_plan,
                                            tmp_path):
        """plan -> JSON -> load -> use_plan executes bit-exactly vs the
        in-memory plan object."""
        cfg, params = llama_smoke
        tokens = jnp.zeros((2, 8), jnp.int32)
        loaded = BackendPlan.load(llama_plan.save(tmp_path / "p.json"))
        with backends.use_plan(llama_plan):
            ref, _ = model_lib.forward(params, cfg, tokens)
        with backends.use_plan(loaded):
            got, _ = model_lib.forward(params, cfg, tokens)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


# ---------------------------------------------------------------------------
# Site discovery
# ---------------------------------------------------------------------------

class TestDiscovery:
    def test_sites_match_param_tree_paths(self, llama_smoke):
        cfg, params = llama_smoke
        sites = planner.discover_sites(cfg, params, batch=2)
        names = {s.name for s in sites}
        assert names == {"layers/attn/wq", "layers/attn/wk",
                         "layers/attn/wv", "layers/attn/wo",
                         "layers/mlp/w_up", "layers/mlp/w_gate",
                         "layers/mlp/w_down", "lm_head"}

    def test_counts_and_shapes(self, llama_smoke):
        cfg, params = llama_smoke
        by = {s.name: s for s in planner.discover_sites(cfg, params, batch=2)}
        wq = by["layers/attn/wq"]
        assert (wq.k, wq.n_out, wq.count) == (
            cfg.d_model, cfg.num_heads * cfg.resolved_head_dim,
            cfg.num_layers)
        assert wq.weight.shape == (wq.count * wq.k, wq.n_out)
        assert by["lm_head"].count == 1

    def test_rwkv_and_hybrid_families_discover(self):
        for arch, needle in (("rwkv6-3b", "layers/tm/w_r"),
                             ("zamba2-1.2b", "shared/attn/wq")):
            cfg = configs.get_smoke_config(arch)
            params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
            names = {s.name for s in
                     planner.discover_sites(cfg, params, batch=2)}
            assert needle in names


# ---------------------------------------------------------------------------
# Pricing properties
# ---------------------------------------------------------------------------

class TestPricingProperties:
    def test_sparsity_never_raises_unary_dynamic_energy(self):
        """Planner monotonicity: higher measured bit sparsity never increases
        a temporal (sparsity-aware) design's priced dynamic energy."""
        grid = [i / 10 for i in range(10)]
        for design in ("tugemm", "tubgemm"):
            for bits in (2, 4, 8):
                costs = [planner.price_site(
                    design, bits, m=4, k=96, n_out=192, count=3,
                    bit_sparsity=s, unit_n=64,
                    num_units=8)["dyn_energy_uj"] for s in grid]
                assert all(a >= b - 1e-12 for a, b in zip(costs, costs[1:])), \
                    f"{design}@{bits}: dyn energy not monotone in sparsity"

    def test_sparsity_is_ignored_by_binary(self):
        lo = planner.price_site("bgemm", 4, m=4, k=96, n_out=192, count=3,
                                bit_sparsity=0.0, unit_n=64, num_units=8)
        hi = planner.price_site("bgemm", 4, m=4, k=96, n_out=192, count=3,
                                bit_sparsity=0.9, unit_n=64, num_units=8)
        assert lo == hi

    def test_quantization_mse_shrinks_with_bits(self):
        w = np.random.default_rng(3).normal(size=(64, 48)).astype(np.float32)
        mses = [planner.quantization_rel_mse(w, b) for b in (2, 4, 8)]
        assert mses[0] > mses[1] > mses[2]
        assert mses[2] < 1e-3


# ---------------------------------------------------------------------------
# build_plan acceptance properties
# ---------------------------------------------------------------------------

class TestBuildPlan:
    def test_planned_total_beats_every_uniform_baseline(self, llama_plan):
        totals = llama_plan.metadata()["totals"]
        planned = totals["planned"]["dyn_energy_uj"]
        assert totals["uniform"], "no guard-feasible uniform baseline"
        for name, tot in totals["uniform"].items():
            assert planned <= tot["dyn_energy_uj"] * (1 + 1e-9), \
                f"planned total lost to uniform {name}"

    def test_shipped_config_plan_is_mixed(self, llama_plan):
        """The paper's headline as an artifact: >= 2 distinct backends,
        tubGEMM on high-sparsity sites, binary keeping the least sparse."""
        distinct = llama_plan.distinct_backends()
        assert len(distinct) >= 2
        designs_used = {d for d, _ in distinct}
        assert "tubgemm" in designs_used and "bgemm" in designs_used
        by = {e.pattern: e for e in llama_plan.sites}
        tub_spa = [e.bit_blockmax for e in by.values() if e.design == "tubgemm"]
        b_spa = [e.bit_blockmax for e in by.values() if e.design == "bgemm"]
        assert min(tub_spa) > max(b_spa), \
            "sparsity did not drive the design split"

    def test_guard_blocks_two_bit_everywhere(self, llama_plan):
        assert all(e.bits >= 4 for e in llama_plan.sites)
        assert not any(e.guard_relaxed for e in llama_plan.sites)
        feasible = set(llama_plan.metadata()["totals"]["uniform"])
        assert not any(name.endswith("@2") for name in feasible)

    def test_impossible_guard_relaxes_to_most_accurate(self, llama_smoke):
        cfg, params = llama_smoke
        plan = planner.build_plan(cfg, params, batch=2, unit_n=64,
                                  num_units=64, max_rel_mse=0.0)
        assert all(e.guard_relaxed for e in plan.sites)
        assert all(e.bits == 8 for e in plan.sites)  # most accurate width
        assert plan.metadata()["totals"]["uniform_best"] is None

    def test_measured_cycles_within_bounds(self, llama_smoke, llama_plan):
        cfg, params = llama_smoke
        sites = {s.name: s for s in
                 planner.discover_sites(cfg, params, batch=2)}
        for e in llama_plan.sites:
            cyc = planner.measure_site_cycles(sites[e.pattern], e,
                                              unit_n=64, num_units=64)
            assert cyc["dyn_floor"] - 0.5 <= cyc["measured"] <= cyc["wc"] + 0.5

    def test_hybrid_shared_sites_measure_and_plan(self):
        """Zamba2's shared block: one physical weight applied n_groups times
        per step — counts scale, cycle measurement stays within bounds."""
        from repro.models import blocks as blocks_lib
        cfg = configs.get_smoke_config("zamba2-1.2b")
        params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
        plan = planner.build_plan(cfg, params, batch=2, unit_n=64,
                                  num_units=64, designs=("tubgemm",),
                                  bits_candidates=(4,))
        n_groups = blocks_lib.hybrid_counts(cfg)[0]
        sites = {s.name: s for s in
                 planner.discover_sites(cfg, params, batch=2)}
        shared = [e for e in plan.sites if e.pattern.startswith("shared/")]
        assert shared, "hybrid plan lost its shared-block sites"
        for e in shared:
            assert e.count == n_groups
            cyc = planner.measure_site_cycles(sites[e.pattern], e,
                                              unit_n=64, num_units=64)
            assert cyc["dyn_floor"] - 0.5 <= cyc["measured"] <= cyc["wc"] + 0.5

    def test_plan_entries_are_exact_site_names(self, llama_smoke, llama_plan):
        cfg, params = llama_smoke
        names = {s.name for s in planner.discover_sites(cfg, params, batch=2)}
        assert {e.pattern for e in llama_plan.sites} == names
