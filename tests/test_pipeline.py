"""Pipeline parallelism (GPipe over the pod axis): forward AND gradient
equivalence to the sequential reference, on a fake 4-pod mesh (subprocess —
device count must be pinned before jax initializes)."""

import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
import numpy as np
from repro.launch.mesh import make_mesh
from repro.launch.pipeline import pipeline_apply, split_stages

rng = np.random.default_rng(0)
L, D, MB, M = 8, 16, 4, 6      # 8 layers -> 4 stages x 2; 6 microbatches of 4
ws = jnp.asarray(rng.normal(0, 0.3, (L, D, D)), jnp.float32)
bs = jnp.asarray(rng.normal(0, 0.1, (L, D)), jnp.float32)
x = jnp.asarray(rng.normal(0, 1, (M, MB, D)), jnp.float32)

def layer(w, b, h):
    return jnp.tanh(h @ w + b)

def sequential(params, x):
    ws, bs = params
    h = x.reshape(M * MB, D)
    for i in range(L):
        h = layer(ws[i], bs[i], h)
    return h.reshape(M, MB, D)

def stage_fn(stage_params, h):
    sw, sb = stage_params
    for i in range(sw.shape[0]):
        h = layer(sw[i], sb[i], h)
    return h

mesh = make_mesh((4,), ("pod",))
staged = split_stages((ws, bs), 4)
with mesh:
    out_pipe = pipeline_apply(stage_fn, staged, x, mesh)
out_ref = sequential((ws, bs), x)
err = float(jnp.max(jnp.abs(out_pipe - out_ref)))
assert err < 1e-5, f"forward mismatch {err}"

# gradient equivalence: grad wrt weights through the pipeline
def loss_pipe(params):
    staged = split_stages(params, 4)
    with mesh:
        return jnp.sum(pipeline_apply(stage_fn, staged, x, mesh) ** 2)

def loss_ref(params):
    return jnp.sum(sequential(params, x) ** 2)

g_pipe = jax.grad(loss_pipe)((ws, bs))
g_ref = jax.grad(loss_ref)((ws, bs))
for a, b in zip(jax.tree_util.tree_leaves(g_pipe), jax.tree_util.tree_leaves(g_ref)):
    gerr = float(jnp.max(jnp.abs(a - b)))
    assert gerr < 1e-4, f"grad mismatch {gerr}"
print("PIPELINE_OK")
"""


def test_pipeline_forward_and_grad_equivalence():
    # JAX_PLATFORMS=cpu: without it jax tries to initialize the TPU backend
    # (libtpu is installed in the image) and stalls for minutes before
    # falling back — the fake-device mesh only needs the CPU platform.
    # Persistent compilation cache is safe here (isolated process, no data
    # threads / donated-buffer reloads) and cuts warm reruns to seconds.
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
           "JAX_PLATFORMS": "cpu",
           "JAX_DISABLE_MOST_OPTIMIZATIONS": "1",
           "JAX_COMPILATION_CACHE_DIR": os.path.abspath(".jax_cache"),
           "JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS": "0"}
    res = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                         text=True, timeout=600, env=env)
    assert "PIPELINE_OK" in res.stdout, res.stdout + res.stderr
