"""Serving path: page-allocator properties, paged-vs-contiguous
bit-exactness, scheduler/traffic determinism, continuous-vs-static gate.

Property tests use hypothesis when available and the local shim otherwise;
the 2x2-grid variant runs in a pinned subprocess (8 fake host devices) like
``test_grid.test_grid_multidevice``.
"""

import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # CI image has no hypothesis; use the local shim
    from _hypothesis_fallback import given, settings, strategies as st

import conftest
from repro import configs
from repro.kernels import paged_attention as paged_lib
from repro.launch import serve as serve_lib
from repro.launch.mesh import single_device_mesh
from repro.models import model as model_lib
from repro.models.attention import _repeat_kv, naive_attention
from repro.serving import (OutOfPages, PageAllocator, PagedKVCache,
                           ServingEngine, TrafficConfig, generate_trace,
                           make_scheduler, paged_vs_contiguous_probe)
from repro.serving.scheduler import Request

# the serving loop drives jitted prefill/decode like the serve driver does;
# keep the flaky persistent XLA cache out of it (see conftest)
_no_xla_cache = pytest.fixture(autouse=True, scope="module")(
    conftest.disable_compilation_cache)


@pytest.fixture(scope="module")
def cfg():
    # fp32 end to end: every bit-exactness assertion below relies on the
    # paged and contiguous paths sharing one float path
    return dataclasses.replace(configs.get_smoke_config("llama3-8b"),
                               compute_dtype="float32",
                               param_dtype="float32")


@pytest.fixture(scope="module")
def params(cfg):
    return model_lib.init_params(cfg, jax.random.PRNGKey(0))


def _tcfg(seed=0, n=6, rate=1.0):
    """Small, fast trace: lengths sized for max_seq_len=32 test engines."""
    return TrafficConfig(num_requests=n, arrival_rate=rate,
                         prompt_short=(2, 5), prompt_long=(6, 10),
                         output_short=(2, 4), output_long=(5, 8),
                         p_long=0.4, seed=seed)


def _engine(cfg, params, **kw):
    kw.setdefault("max_batch", 3)
    kw.setdefault("page_size", 4)
    kw.setdefault("max_seq_len", 32)
    return ServingEngine(cfg, params, **kw)


# ---------------------------------------------------------------------------
# Page allocator properties
# ---------------------------------------------------------------------------

class TestPageAllocator:
    @given(seed=st.integers(0, 10_000), num_pages=st.integers(2, 40))
    @settings(max_examples=25, deadline=None)
    def test_alloc_free_invariants(self, seed, num_pages):
        """Arbitrary alloc/free sequences: no aliasing across live owners,
        the reserved trash page is never handed out, and the free count is
        conserved at capacity minus what is live."""
        rng = np.random.default_rng(seed)
        alloc = PageAllocator(num_pages)
        live: dict[int, list[int]] = {}
        next_owner = 0
        for _ in range(60):
            if live and rng.random() < 0.4:
                owner = int(rng.choice(list(live)))
                alloc.free(live.pop(owner), owner)
            else:
                n = int(rng.integers(0, max(2, num_pages // 2)))
                if n > alloc.num_free:
                    with pytest.raises(OutOfPages):
                        alloc.alloc(n, next_owner)
                else:
                    live[next_owner] = alloc.alloc(n, next_owner)
                    next_owner += 1
            owned = [p for pages in live.values() for p in pages]
            assert len(owned) == len(set(owned)), "page aliased"
            assert all(p >= 1 for p in owned), "trash page handed out"
            assert alloc.num_free + len(owned) == alloc.capacity
            for owner, pages in live.items():
                assert all(alloc.owner_of(p) == owner for p in pages)

    def test_free_by_wrong_owner_asserts(self):
        alloc = PageAllocator(8)
        pages = alloc.alloc(2, "a")
        with pytest.raises(AssertionError):
            alloc.free(pages, "b")

    def test_double_allocate_request_rejected(self):
        cache = PagedKVCache(num_layers=1, num_kv_heads=1, head_dim=2,
                             num_pages=8, page_size=4, max_seq_len=16)
        cache.allocate(0, 5)
        with pytest.raises(ValueError):
            cache.allocate(0, 3)


# ---------------------------------------------------------------------------
# Paged cache reconstruction vs an append-only contiguous cache
# ---------------------------------------------------------------------------

class TestPagedReconstruction:
    @given(seed=st.integers(0, 10_000), page_size=st.integers(1, 5))
    @settings(max_examples=8, deadline=None)
    def test_block_table_walk_matches_contiguous(self, seed, page_size):
        """Interleaved prefill/append across requests (with a mid-sequence
        free + page reuse): walking each block table reconstructs exactly
        the values an append-only contiguous cache would hold."""
        rng = np.random.default_rng(seed)
        shape = dict(num_layers=2, num_kv_heads=2, head_dim=3)
        totals = [int(rng.integers(1, 3 * page_size + 1)) for _ in range(3)]
        num_pages = 1 + sum(-(-t // page_size) for t in totals)
        cache = PagedKVCache(num_pages=num_pages, page_size=page_size,
                             max_seq_len=4 * page_size, **shape)

        def vecs(*lead):
            return rng.normal(size=(*lead, 2, 2, 3)).astype(np.float32)

        ref_k: dict[int, list] = {}
        ref_v: dict[int, list] = {}
        for r, total in enumerate(totals):
            cache.allocate(r, total)
            s = int(rng.integers(1, total + 1))
            k = vecs(s).transpose(1, 0, 2, 3)   # (L, s, KVH, hd)
            v = vecs(s).transpose(1, 0, 2, 3)
            cache.write_prefill(r, jnp.asarray(k), jnp.asarray(v))
            ref_k[r], ref_v[r] = [k], [v]
        # free the middle request; a newcomer reuses its pages
        cache.free_request(1)
        cache.allocate(3, totals[1])
        s = max(1, totals[1] // 2)
        k = vecs(s).transpose(1, 0, 2, 3)
        v = vecs(s).transpose(1, 0, 2, 3)
        cache.write_prefill(3, jnp.asarray(k), jnp.asarray(v))
        ref_k[3], ref_v[3] = [k], [v]
        del ref_k[1], ref_v[1]
        lengths = {0: totals[0], 2: totals[2], 3: totals[1]}
        # interleaved single-token appends up to each reservation
        while any(cache.lengths[r] < lengths[r] for r in lengths):
            r = int(rng.choice([r for r in lengths
                                if cache.lengths[r] < lengths[r]]))
            k1, v1 = vecs(), vecs()          # (L, KVH, hd) single positions
            cache.append_token(r, jnp.asarray(k1), jnp.asarray(v1))
            ref_k[r].append(k1[:, None])
            ref_v[r].append(v1[:, None])
        for r in lengths:
            got_k, got_v = cache.gather_request(r)
            np.testing.assert_array_equal(got_k,
                                          np.concatenate(ref_k[r], axis=1))
            np.testing.assert_array_equal(got_v,
                                          np.concatenate(ref_v[r], axis=1))


# ---------------------------------------------------------------------------
# Paged decode bit-exactness vs the contiguous reference
# ---------------------------------------------------------------------------

class TestPagedBitExact:
    @pytest.mark.parametrize("page_size", [3, 8])
    def test_probe_bitexact(self, cfg, params, page_size):
        """Full-model probe: the engine's paged decode step equals the
        contiguous ``decode_step`` logits bit for bit at fp32, including at
        a page size that does not divide the prompt length."""
        assert paged_vs_contiguous_probe(cfg, params, prompt_len=5, steps=3,
                                         page_size=page_size) == 0.0

    @pytest.mark.parametrize("page_size", [3, 8])
    def test_ragged_paged_attention_exact(self, page_size):
        """Kernel-level: paged gather + masked attention over a ragged
        request mix equals the contiguous path exactly, even when the
        contiguous buffer's tail holds DIFFERENT garbage than the pool
        (masked scores underflow to exact zeros in fp32)."""
        rng = np.random.default_rng(3)
        kvh, heads, hd = 2, 4, 5
        lens = [7, 1, 12, page_size]            # page_size | 12? both sizes
        b = len(lens)
        cache = PagedKVCache(num_layers=1, num_kv_heads=kvh, head_dim=hd,
                             num_pages=1 + sum(-(-n // page_size)
                                               for n in lens),
                             page_size=page_size, max_seq_len=16)
        # contiguous reference at the gathered width: masked tail positions
        # contribute exact fp32 zeros whatever garbage they hold, but the
        # reduction *tree* must see the same width for bit-equality in eager
        # mode (within jit the engine probe also pins the unequal-width case)
        maxlen = cache.max_blocks * page_size
        contig_k = rng.normal(size=(b, maxlen, kvh, hd)).astype(np.float32)
        contig_v = rng.normal(size=(b, maxlen, kvh, hd)).astype(np.float32)
        btables = np.zeros((b, cache.max_blocks), np.int32)
        for i, n in enumerate(lens):
            cache.allocate(i, n)
            cache.write_prefill(i, jnp.asarray(contig_k[None, i, :n]),
                                jnp.asarray(contig_v[None, i, :n]))
            btables[i] = cache.block_table_row(i)
            contig_k[i, n:] = rng.normal(size=(maxlen - n, kvh, hd))
            contig_v[i, n:] = rng.normal(size=(maxlen - n, kvh, hd))
        # the gathered prefix is element-identical to the contiguous cache
        gk = np.asarray(paged_lib.gather_kv(cache.k_pool[0],
                                            jnp.asarray(btables)))
        for i, n in enumerate(lens):
            np.testing.assert_array_equal(gk[i, :n], contig_k[i, :n])
        valid = jnp.asarray(lens, jnp.int32)
        q = jnp.asarray(rng.normal(size=(b, 1, heads, hd)), jnp.float32)
        paged = paged_lib.paged_decode_attention(
            q, cache.k_pool[0], cache.v_pool[0], jnp.asarray(btables), valid,
            num_heads=heads)
        ref = naive_attention(q, _repeat_kv(jnp.asarray(contig_k), heads),
                              _repeat_kv(jnp.asarray(contig_v), heads),
                              causal=False, kv_valid_len=valid)
        np.testing.assert_array_equal(np.asarray(paged), np.asarray(ref))

    def test_gathered_kv_through_flash_attention(self):
        """The gathered pages ARE the contiguous tensor: pushing both
        through ``flash_attention`` (interpret mode) is bit-identical."""
        from repro.kernels.flash_attention import flash_attention
        rng = np.random.default_rng(7)
        kvh, hd, n = 2, 4, 10
        cache = PagedKVCache(num_layers=1, num_kv_heads=kvh, head_dim=hd,
                             num_pages=6, page_size=4, max_seq_len=16)
        k = rng.normal(size=(1, n, kvh, hd)).astype(np.float32)
        v = rng.normal(size=(1, n, kvh, hd)).astype(np.float32)
        cache.allocate(0, n)
        cache.write_prefill(0, jnp.asarray(k), jnp.asarray(v))
        gk, gv = cache.gather_request(0)   # (L=1, n, KVH, hd) == (B, S, H, d)
        q = jnp.asarray(rng.normal(size=(1, n, kvh, hd)), jnp.float32)
        out_paged = flash_attention(q, jnp.asarray(gk), jnp.asarray(gv),
                                    causal=True, interpret=True)
        out_ref = flash_attention(q, jnp.asarray(k), jnp.asarray(v),
                                  causal=True, interpret=True)
        np.testing.assert_array_equal(np.asarray(out_paged),
                                      np.asarray(out_ref))


# ---------------------------------------------------------------------------
# Schedulers: admission rules + the continuous-beats-static gate
# ---------------------------------------------------------------------------

class TestSchedulers:
    def _cache(self, num_pages=9, page_size=4):
        return PagedKVCache(num_layers=1, num_kv_heads=1, head_dim=2,
                            num_pages=num_pages, page_size=page_size,
                            max_seq_len=32)

    @staticmethod
    def _req(req_id, arrival, total):
        from repro.serving.traffic import TrafficRequest
        return Request(spec=TrafficRequest(req_id=req_id,
                                           arrival_step=arrival,
                                           prompt_len=total - 1,
                                           output_len=1))

    def test_static_admits_only_into_empty_batch(self):
        sched = make_scheduler("static", 2)
        waiting = [self._req(0, 0, 4), self._req(1, 0, 4)]
        assert len(sched.admissions(0, waiting, 0, self._cache())) == 2
        assert sched.admissions(0, waiting, 1, self._cache()) == []

    def test_fifo_head_of_line_blocks(self):
        """A head request that cannot reserve its pages blocks later ones
        (deterministic FIFO) even if they would fit."""
        sched = make_scheduler("continuous", 4)
        cache = self._cache(num_pages=3)      # 2 allocatable pages
        waiting = [self._req(0, 0, 12), self._req(1, 0, 4)]   # needs 3 vs 1
        assert sched.admissions(0, waiting, 0, cache) == []

    def test_not_yet_arrived_requests_wait(self):
        sched = make_scheduler("continuous", 4)
        waiting = [self._req(0, 5, 4)]
        assert sched.admissions(0, waiting, 0, self._cache()) == []
        assert len(sched.admissions(5, waiting, 0, self._cache())) == 1

    def test_engine_rejects_impossible_requests(self, cfg, params):
        eng = _engine(cfg, params, max_seq_len=16)
        bad = TrafficConfig(num_requests=1, prompt_short=(20, 20),
                            output_short=(9, 9), p_long=0.0, seed=0)
        with pytest.raises(ValueError, match="max_seq_len"):
            eng.run(generate_trace(bad))

    def test_continuous_beats_static(self, cfg, params):
        """The tentpole gate: on one seeded trace, continuous batching gets
        >= static throughput and >= occupancy; on the float path both
        schedulers generate identical per-request token streams."""
        eng = _engine(cfg, params)
        trace = generate_trace(_tcfg(n=6, rate=1.5))
        rc = eng.run(trace, "continuous")
        rs = eng.run(trace, "static")
        assert rc.requests == rs.requests == len(trace)
        assert rc.throughput_tok_per_step >= rs.throughput_tok_per_step
        assert rc.occupancy >= rs.occupancy
        assert rc.latency_p99 <= rs.latency_p99
        assert rc.request_tokens == rs.request_tokens
        assert rc.tokens == sum(r.output_len for r in trace)

    def test_page_pressure_queues_but_completes(self, cfg, params):
        """With a pool too small to co-run everything, admission stalls on
        pages but every request still completes (conservative reservation:
        no mid-decode out-of-pages)."""
        trace = generate_trace(_tcfg(n=5, rate=3.0))
        biggest = max(-(-r.total_len // 4) for r in trace)
        eng = _engine(cfg, params, num_pages=1 + biggest + 1)
        rep = eng.run(trace, "continuous")
        assert rep.requests == len(trace)
        admits = {e[2]: e[0] for e in rep.events if e[1] == "admit"}
        assert len(admits) == len(trace)


# ---------------------------------------------------------------------------
# Determinism: traffic, schedule, metrics
# ---------------------------------------------------------------------------

class TestDeterminism:
    def test_same_seed_same_trace(self):
        assert generate_trace(_tcfg(seed=3)) == generate_trace(_tcfg(seed=3))

    def test_different_seed_different_trace(self):
        assert generate_trace(_tcfg(seed=0)) != generate_trace(_tcfg(seed=1))

    def test_same_seed_same_schedule_and_metrics(self, cfg, params):
        """Two full serves of the same seeded trace produce identical
        join/evict event streams, latencies, tokens and energy."""
        eng = _engine(cfg, params)
        trace = generate_trace(_tcfg(seed=4, n=5))
        a = eng.run(trace, "continuous")
        b = eng.run(trace, "continuous")
        assert a.to_dict() == b.to_dict()

    def test_different_seed_different_schedule(self, cfg, params):
        eng = _engine(cfg, params)
        a = eng.run(generate_trace(_tcfg(seed=0, n=5)), "continuous")
        b = eng.run(generate_trace(_tcfg(seed=9, n=5)), "continuous")
        assert a.events != b.events


# ---------------------------------------------------------------------------
# Engine parity with the one-shot serve driver + backend/grid execution
# ---------------------------------------------------------------------------

class TestEngineParity:
    def test_single_request_matches_generate(self, cfg, params):
        """A lone request served through the paged engine emits exactly the
        greedy tokens ``launch.serve.generate`` produces for its prompt."""
        from repro.serving.traffic import TrafficRequest
        spec = TrafficRequest(req_id=0, arrival_step=0, prompt_len=6,
                              output_len=5)
        eng = _engine(cfg, params)
        rep = eng.run((spec,), "continuous")
        prompt = jnp.asarray(eng.prompt_tokens(spec)[None])
        ref = serve_lib.generate(cfg, params, single_device_mesh(), prompt,
                                 spec.output_len)
        assert rep.request_tokens[0] == tuple(int(t) for t in
                                              np.asarray(ref)[0])

    def test_backend_execution_flat_vs_1x1_grid(self, cfg, params):
        """Under tubgemm execution, a (1,1) PE-array grid serves the trace
        with exactly the flat backend's tokens and metrics (GridBackend is
        bit-exact vs its single-unit design)."""
        trace = generate_trace(_tcfg(n=3))
        flat = _engine(cfg, params, backend="tubgemm", bits=4).run(trace)
        grid = _engine(cfg, params, backend="tubgemm", bits=4,
                       grid=(1, 1)).run(trace)
        assert flat.request_tokens == grid.request_tokens
        assert flat.events == grid.events
        assert flat.throughput_tok_per_step == grid.throughput_tok_per_step


SERVING_GRID_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import numpy as np
from repro import configs
import jax
from repro.models import model as model_lib
from repro.serving import ServingEngine, TrafficConfig, generate_trace

cfg = dataclasses.replace(configs.get_smoke_config("llama3-8b"),
                          compute_dtype="float32", param_dtype="float32")
params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
trace = generate_trace(TrafficConfig(
    num_requests=3, arrival_rate=1.0, prompt_short=(2, 5),
    prompt_long=(6, 10), output_short=(2, 4), output_long=(5, 8),
    p_long=0.4, seed=0))
kw = dict(max_batch=3, page_size=4, max_seq_len=32, backend="tubgemm",
          bits=4)
flat = ServingEngine(cfg, params, **kw).run(trace)
grid = ServingEngine(cfg, params, grid=(2, 2), **kw).run(trace)
assert grid.requests == len(trace), grid.requests
assert flat.request_tokens == grid.request_tokens, (flat.request_tokens,
                                                    grid.request_tokens)
assert flat.events == grid.events
print("SERVING_GRID_2X2_OK")
"""


def test_serving_grid_2x2_subprocess():
    """On a 2x2 PE-array grid (8 fake host devices), the paged serving loop
    under sharded tubgemm execution generates exactly the flat backend's
    token streams and schedule."""
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
           "JAX_PLATFORMS": "cpu",
           "JAX_DISABLE_MOST_OPTIMIZATIONS": "1",
           "JAX_COMPILATION_CACHE_DIR": os.path.abspath(".jax_cache"),
           "JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS": "0"}
    res = subprocess.run([sys.executable, "-c", SERVING_GRID_SCRIPT],
                         capture_output=True, text=True, timeout=900,
                         env=env)
    assert "SERVING_GRID_2X2_OK" in res.stdout, \
        f"{res.stdout}\n{res.stderr}"
