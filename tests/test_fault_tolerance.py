"""Fault tolerance: checkpoint atomicity/retention, auto-resume, elastic
resharding, retries, straggler detection, data pipeline."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, latest_step, restore, save
from repro.data import DataConfig, SyntheticLM, make_pipeline
from repro.runtime import StragglerWatchdog, plan_mesh, retry_with_backoff

import conftest

# The persistent compilation cache segfaults on this jax/CPU build when the
# train/serve loop reloads donated step executables (see tests/conftest.py);
# run this module with the cache off.
_no_xla_cache = pytest.fixture(autouse=True, scope="module")(
    conftest.disable_compilation_cache)


class TestCheckpoint:
    def _tree(self, rng):
        return {"a": jnp.asarray(rng.normal(0, 1, (8, 4)), jnp.float32),
                "nested": {"b": jnp.arange(10, dtype=jnp.int32)},
                "scalar": jnp.float32(3.5)}

    def test_roundtrip(self, tmp_path, rng):
        tree = self._tree(rng)
        save(str(tmp_path), 7, tree, extras={"loss": 1.25})
        out, step, extras = restore(str(tmp_path), tree)
        assert step == 7 and extras["loss"] == 1.25
        for a, b in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(out)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_incomplete_checkpoint_ignored(self, tmp_path, rng):
        tree = self._tree(rng)
        save(str(tmp_path), 5, tree)
        # simulate a crash mid-save: directory without COMPLETE
        broken = tmp_path / "step_000000009"
        broken.mkdir()
        (broken / "manifest.json").write_text("{}")
        assert latest_step(str(tmp_path)) == 5

    def test_keep_last_k(self, tmp_path, rng):
        mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
        tree = self._tree(rng)
        for s in (1, 2, 3, 4):
            mgr.save(s, tree)
        steps = sorted(int(n.split("_")[1]) for n in os.listdir(tmp_path)
                       if n.startswith("step_"))
        assert steps == [3, 4]

    def test_async_save(self, tmp_path, rng):
        mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
        mgr.save(1, self._tree(rng))
        mgr.wait()
        assert latest_step(str(tmp_path)) == 1

    def test_elastic_restore_with_shardings(self, tmp_path, rng):
        """Restore onto explicit (trivial-mesh) shardings — the elastic path."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import single_device_mesh
        tree = self._tree(rng)
        save(str(tmp_path), 3, tree)
        mesh = single_device_mesh()
        shardings = jax.tree_util.tree_map(
            lambda x: NamedSharding(mesh, P()), tree)
        out, step, _ = restore(str(tmp_path), tree, shardings=shardings)
        assert step == 3
        assert all(x.sharding == NamedSharding(mesh, P())
                   for x in jax.tree_util.tree_leaves(out))

    def test_shape_mismatch_rejected(self, tmp_path, rng):
        tree = self._tree(rng)
        save(str(tmp_path), 1, tree)
        bad = dict(tree, a=jnp.zeros((4, 4), jnp.float32))
        with pytest.raises(ValueError, match="shape mismatch"):
            restore(str(tmp_path), bad)


class TestRuntime:
    def test_retry_succeeds_after_failures(self):
        calls = {"n": 0}

        def flaky():
            calls["n"] += 1
            if calls["n"] < 3:
                raise RuntimeError("boom")
            return "ok"

        assert retry_with_backoff(flaky, retries=3, base_delay=0.0) == "ok"
        assert calls["n"] == 3

    def test_retry_exhausts(self):
        def dead():
            raise RuntimeError("always")

        with pytest.raises(RuntimeError):
            retry_with_backoff(dead, retries=2, base_delay=0.0)

    def test_straggler_detection(self):
        wd = StragglerWatchdog(threshold=2.0, warmup=3)
        for _ in range(6):
            assert not wd.observe(0.1)
        assert wd.observe(0.5)          # 5x median -> straggler
        assert wd.slow_steps == 1

    def test_plan_mesh_elastic(self):
        # full pods
        assert plan_mesh(512) == ((2, 16, 16), ("pod", "data", "model"))
        assert plan_mesh(256) == ((16, 16), ("data", "model"))
        # degraded: lost 16 chips -> shrink data parallelism
        shape, axes = plan_mesh(240)
        assert shape == (15, 16) and axes == ("data", "model")
        # tiny
        assert plan_mesh(1) == ((1, 1), ("data", "model"))


class TestDataPipeline:
    def test_deterministic_per_host(self):
        cfg = DataConfig(batch_size=2, seq_len=16, vocab_size=64, seed=3)
        a = next(iter(SyntheticLM(cfg)))
        b = next(iter(SyntheticLM(cfg)))
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_hosts_get_disjoint_streams(self):
        cfg0 = DataConfig(batch_size=2, seq_len=16, vocab_size=64, seed=3,
                          host_index=0, host_count=2)
        cfg1 = DataConfig(batch_size=2, seq_len=16, vocab_size=64, seed=3,
                          host_index=1, host_count=2)
        a = next(iter(SyntheticLM(cfg0)))
        b = next(iter(SyntheticLM(cfg1)))
        assert not np.array_equal(a["tokens"], b["tokens"])

    def test_prefetcher(self):
        cfg = DataConfig(batch_size=2, seq_len=8, vocab_size=32)
        it = make_pipeline(cfg, prefetch=2)
        batches = [next(it) for _ in range(5)]
        assert all(b["tokens"].shape == (2, 7) for b in batches)

    def test_token_file(self, tmp_path):
        path = tmp_path / "tokens.bin"
        np.arange(1000, dtype=np.int32).tofile(path)
        cfg = DataConfig(batch_size=2, seq_len=16, path=str(path))
        from repro.data import TokenFile
        b = next(iter(TokenFile(cfg)))
        assert b["tokens"].shape == (2, 16)
        np.testing.assert_array_equal(b["targets"][:, :-1], b["tokens"][:, 1:])

    def test_frontend_stub_embeddings(self):
        cfg = DataConfig(batch_size=2, seq_len=8, vocab_size=32, embed_dim=16)
        b = next(iter(SyntheticLM(cfg)))
        assert b["embeds"].shape == (2, 7, 16)


class TestEndToEndResume:
    def test_train_resume_after_interrupt(self, tmp_path):
        """Loop-level checkpoint/restart: a second run resumes, not restarts."""
        from repro import configs
        from repro.launch.mesh import single_device_mesh
        from repro.launch.train import TrainLoopConfig, train
        cfg = configs.get_smoke_config("musicgen-medium")
        loop = TrainLoopConfig(steps=6, ckpt_every=3, log_every=2,
                               ckpt_dir=str(tmp_path), batch=2, seq=16)
        mesh = single_device_mesh()
        train(cfg, mesh, loop)
        assert latest_step(str(tmp_path)) == 6
        # extend to 8 steps: must resume from 6
        loop2 = TrainLoopConfig(steps=8, ckpt_every=3, log_every=2,
                                ckpt_dir=str(tmp_path), batch=2, seq=16)
        state, history, _ = train(cfg, mesh, loop2)
        assert int(state.step) == 8
        assert history[0][0] >= 6   # first logged step after resume
