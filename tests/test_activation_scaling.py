"""Per-row activation quantization: ``quantize_per_row`` and the
``models.common.activation_scaling`` scope.

The serving engine's identical-token-stream gate can only be strict under
backend execution if a request's integer codes are a pure function of its
own tokens — i.e. one absmax scale per activation *row*, not one spanning
the whole co-batched tensor.  These tests pin the axis semantics (per-row
vs the per-column weight convention), the batch-1 bit-exact equivalence
with per-tensor scaling, and the batch independence the strict gate needs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import backends
from repro.core.quantization import quantize, quantize_per_row, vmax
from repro.models import common


def _acts(rows, k, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(rows, k)), jnp.float32)


def test_per_row_axis_semantics():
    # Row 1 carries a 100x outlier: per-row scaling must leave row 0's grid
    # untouched, per-tensor coarsens both.
    x = jnp.asarray([[0.5, -0.25, 0.125, 0.0625],
                     [100.0, -50.0, 25.0, 12.5]], jnp.float32)
    q = quantize_per_row(x, bits=8)
    assert q.scale.shape == (2, 1)
    np.testing.assert_allclose(np.asarray(q.scale[:, 0]),
                               [0.5 / vmax(8), 100.0 / vmax(8)], rtol=1e-6)
    # per_channel=True reduces all-but-last axis (per COLUMN) — different.
    col = quantize(x, bits=8, per_channel=True)
    assert col.scale.shape == (1, 4)
    back = q.dequantize()
    np.testing.assert_allclose(np.asarray(back[0]), np.asarray(x[0]),
                               atol=0.5 / vmax(8))


def test_per_row_equals_per_tensor_at_one_row():
    x = _acts(1, 32)
    pr = quantize_per_row(x, bits=8)
    pt = quantize(x, bits=8, per_channel=False)
    assert (np.asarray(pr.values) == np.asarray(pt.values)).all()
    np.testing.assert_allclose(np.asarray(pr.scale).ravel(),
                               np.asarray(pt.scale).ravel(), rtol=1e-7)


def test_per_row_codes_are_batch_independent():
    # The strict-gate property itself: a row's codes must not change when
    # it is co-batched with an outlier row.
    x = _acts(2, 16)
    outlier = x.at[1].multiply(100.0)
    solo = quantize_per_row(x[:1], bits=8)
    with_outlier = quantize_per_row(outlier, bits=8)
    assert (np.asarray(solo.values[0])
            == np.asarray(with_outlier.values[0])).all()
    # Per-tensor coupling really does move row 0's codes (the outlier
    # coarsens the shared grid) — without it the gate has nothing to fix.
    pt_solo = quantize(x[:1], bits=8, per_channel=False)
    pt_out = quantize(outlier, bits=8, per_channel=False)
    assert (np.asarray(pt_solo.values[0])
            != np.asarray(pt_out.values[0])).any()


def test_activation_scaling_scope():
    assert common.activation_scale_mode() == "per-tensor"
    with common.activation_scaling("per-row"):
        assert common.activation_scale_mode() == "per-row"
        with common.activation_scaling("per-tensor"):
            assert common.activation_scale_mode() == "per-tensor"
        assert common.activation_scale_mode() == "per-row"
    assert common.activation_scale_mode() == "per-tensor"
    with pytest.raises(ValueError):
        with common.activation_scaling("per-batch"):
            pass


def test_dense_per_row_bit_exact_at_batch_one():
    x = _acts(1, 32)[None]  # (batch=1, seq=1, k)
    w = jnp.asarray(np.random.default_rng(1).normal(size=(32, 8)), jnp.float32)
    with backends.use_backend("bgemm", bits=8):
        pt = common.dense(w, x, name="probe")
        with common.activation_scaling("per-row"):
            pr = common.dense(w, x, name="probe")
    assert (np.asarray(pt) == np.asarray(pr)).all()


def test_dense_per_row_output_independent_of_batchmates():
    w = jnp.asarray(np.random.default_rng(1).normal(size=(16, 8)), jnp.float32)
    x = _acts(2, 16, seed=2)
    outlier = x.at[1].multiply(100.0)
    with backends.use_backend("bgemm", bits=8), \
            common.activation_scaling("per-row"):
        solo = common.dense(w, x[:1][None], name="probe")
        batched = common.dense(w, outlier[None], name="probe")
    assert (np.asarray(solo[0, 0]) == np.asarray(batched[0, 0])).all()
