"""Multi-device numerical equivalence on fake CPU meshes (subprocess — the
device count must be pinned before jax initializes).

Covers the shard_map code paths the dry-run only exercises structurally:
flash-decoding (GQA + MLA) vs the single-device oracle, expert-parallel MoE
vs the dense reference, and the int8 compressed all-reduce.
"""

import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_mesh
from repro.models import attention as A
from repro.models.config import ModelConfig, MLAConfig, MoEConfig

rng = np.random.default_rng(0)
mesh = make_mesh((2, 4), ("data", "model"))

# ---- 1. GQA flash-decoding vs naive oracle --------------------------------
B, S, H, KVH, D = 4, 32, 8, 2, 16
q = jnp.asarray(rng.normal(0, 1, (B, 1, H, D)), jnp.float32)
kc = jnp.asarray(rng.normal(0, 1, (B, S, KVH, D)), jnp.float32)
vc = jnp.asarray(rng.normal(0, 1, (B, S, KVH, D)), jnp.float32)
pos = 19  # only the first pos+1 cache slots are valid

with mesh:
    q_s = jax.device_put(q, NamedSharding(mesh, P("data")))
    kc_s = jax.device_put(kc, NamedSharding(mesh, P("data", "model")))
    vc_s = jax.device_put(vc, NamedSharding(mesh, P("data", "model")))
    out = A._sharded_decode_attention(q_s, kc_s, vc_s, H, q_offset=pos,
                                      kv_valid_len=pos + 1, mesh=mesh)
kf = A._repeat_kv(kc, H)
vf = A._repeat_kv(vc, H)
want = A.naive_attention(q, kf, vf, causal=True, q_offset=pos,
                         kv_valid_len=np.full(B, pos + 1))
err = float(jnp.max(jnp.abs(out - want)))
assert err < 1e-5, f"gqa flash-decode mismatch {err}"
print("GQA_DECODE_OK", err)

# ---- 2. MLA flash-decoding vs absorbed oracle ------------------------------
cfg = ModelConfig(d_model=32, num_heads=4, num_kv_heads=4, attention="mla",
                  mla=MLAConfig(q_lora_rank=16, kv_lora_rank=8,
                                rope_head_dim=4, nope_head_dim=8, v_head_dim=8))
m = cfg.mla
params = {
    "w_uk": jnp.asarray(rng.normal(0, 0.3, (m.kv_lora_rank, 4, m.nope_head_dim)), jnp.float32),
    "w_uv": jnp.asarray(rng.normal(0, 0.3, (m.kv_lora_rank, 4, m.v_head_dim)), jnp.float32),
}
qn = jnp.asarray(rng.normal(0, 1, (B, 1, 4, m.nope_head_dim)), jnp.float32)
qr = jnp.asarray(rng.normal(0, 1, (B, 1, 4, m.rope_head_dim)), jnp.float32)
ckv = jnp.asarray(rng.normal(0, 1, (B, S, m.kv_lora_rank)), jnp.float32)
kr = jnp.asarray(rng.normal(0, 1, (B, S, m.rope_head_dim)), jnp.float32)
with mesh:
    ckv_s = jax.device_put(ckv, NamedSharding(mesh, P("data", "model")))
    kr_s = jax.device_put(kr, NamedSharding(mesh, P("data", "model")))
    ctx = A._mla_sharded_decode(params, qn, qr, ckv_s, kr_s, cfg,
                                q_offset=pos, kv_valid_len=pos + 1, mesh=mesh)
    got = jnp.einsum("bqhr,rhv->bqhv", ctx, params["w_uv"])
want = A._mla_absorbed_attend(params, qn, qr, ckv, kr, cfg,
                              np.full(B, pos + 1), q_offset=pos)
err = float(jnp.max(jnp.abs(got - want)))
assert err < 1e-5, f"mla flash-decode mismatch {err}"
print("MLA_DECODE_OK", err)

# ---- 3. expert-parallel MoE (psum) vs dense reference ----------------------
from repro.models import moe as MOE
from repro.models.common import init_tree
mcfg = ModelConfig(family="moe", d_model=32, d_ff=64, vocab_size=64,
                   moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=32,
                                 capacity_factor=8.0))
mparams = init_tree(MOE.moe_defs(mcfg), jax.random.PRNGKey(1), jnp.float32)
x = jnp.asarray(rng.normal(0, 1, (2, 16, 32)), jnp.float32)
with mesh:
    out_ep, aux = MOE.moe_fwd(mparams, x, mcfg)      # EP over model=4
out_ref, _ = MOE.moe_fwd(mparams, x, mcfg)           # no mesh -> local path
err = float(jnp.max(jnp.abs(out_ep - out_ref)))
assert err < 1e-4, f"EP-psum vs local mismatch {err}"
print("MOE_EP_OK", err)

# ---- 3b. a2a EP vs psum EP --------------------------------------------------
import dataclasses as dc
mcfg_a2a = mcfg.replace(moe=dc.replace(mcfg.moe, ep_impl="a2a"))
xa = jnp.asarray(rng.normal(0, 1, (2, 16, 32)), jnp.float32)   # T=32 >= 4*4
with mesh:
    out_a2a, _ = MOE.moe_fwd(mparams, xa, mcfg_a2a)
    out_psum, _ = MOE.moe_fwd(mparams, xa, mcfg)
err = float(jnp.max(jnp.abs(out_a2a - out_psum)))
assert err < 1e-4, f"a2a vs psum mismatch {err}"
print("MOE_A2A_OK", err)

# ---- 4. int8 compressed all-reduce over data axis ---------------------------
from repro.optim.compression import int8_psum
g = {"w": jnp.asarray(rng.normal(0, 1, (64, 64)), jnp.float32)}
with mesh:
    out = int8_psum(g, mesh, axis="data")
# with identical replicas the psum returns n_data * g (up to int8 rounding)
rel = float(jnp.max(jnp.abs(out["w"] - 2 * g["w"])) / jnp.max(jnp.abs(2 * g["w"])))
assert rel < 0.02, f"int8 psum rel err {rel}"
print("INT8_PSUM_OK", rel)
"""


def test_multidevice_numerics():
    # JAX_PLATFORMS=cpu: without it jax tries to initialize the TPU backend
    # (libtpu is installed in the image) and stalls for minutes before
    # falling back — the fake-device mesh only needs the CPU platform.
    # Persistent compilation cache is safe here (isolated process, no data
    # threads / donated-buffer reloads) and cuts warm reruns to seconds.
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
           "JAX_PLATFORMS": "cpu",
           "JAX_DISABLE_MOST_OPTIMIZATIONS": "1",
           "JAX_COMPILATION_CACHE_DIR": os.path.abspath(".jax_cache"),
           "JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS": "0"}
    res = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                         text=True, timeout=900, env=env)
    out = res.stdout
    for marker in ("GQA_DECODE_OK", "MLA_DECODE_OK", "MOE_EP_OK",
                   "MOE_A2A_OK", "INT8_PSUM_OK"):
        assert marker in out, f"missing {marker}\n{out}\n{res.stderr}"
