"""Per-architecture smoke tests: reduced configs, one forward + one train
step on CPU, asserting output shapes and no NaNs (assignment requirement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model as M
from repro.optim import AdamWConfig, adamw_init, adamw_update

ARCHS = list(configs.ARCH_IDS)


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def arch_setup(key):
    """(cfg, params) per arch, shared by both smoke tests (params are
    immutable jax trees; init is seconds per arch and was paid twice)."""
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = configs.get_smoke_config(arch)
            cache[arch] = (cfg, M.init_params(cfg, key))
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch, arch_setup, rng):
    cfg, params = arch_setup(arch)
    B, Sq = 2, 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, Sq)), jnp.int32)
    embeds = None
    if cfg.frontend_stub:
        embeds = jnp.asarray(rng.normal(0, 1, (B, Sq, cfg.d_model)), jnp.float32)

    logits, aux = M.forward(params, cfg, None if cfg.frontend_stub else toks,
                            embeds=embeds)
    assert logits.shape == (B, Sq, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits))), f"{arch}: NaN logits"

    # one full train step (loss -> grads -> AdamW update)
    def loss_of(p):
        return M.loss_fn(p, cfg, None if cfg.frontend_stub else toks[:, :-1],
                         toks[:, 1:],
                         embeds=None if embeds is None else embeds[:, :-1])[0]

    # jit once and reuse: un-jitted value_and_grad re-traces op-by-op on
    # every call, which used to dominate the suite's wall clock
    val_grad = jax.jit(jax.value_and_grad(loss_of))
    loss, grads = val_grad(params)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    opt_cfg = AdamWConfig(lr=1e-3)
    opt = adamw_init(params, opt_cfg)
    new_params, new_opt, metrics = adamw_update(grads, opt, params, opt_cfg, 1e-3)
    assert np.isfinite(float(metrics["grad_norm"]))
    # parameters actually moved
    moved = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(new_params)))
    assert moved, f"{arch}: update was a no-op"
    # loss must decrease after a few steps on the same batch (sanity)
    p, o = new_params, new_opt
    for _ in range(3):
        l2, g = val_grad(p)
        p, o, _ = adamw_update(g, o, p, opt_cfg, 1e-3)
    assert float(val_grad(p)[0]) < float(loss), f"{arch}: loss not decreasing"


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_consistency(arch, arch_setup, rng):
    """prefill+decode logits match full forward (bf16 tolerance)."""
    cfg, params = arch_setup(arch)
    if cfg.frontend_stub:
        pytest.skip("frontend-stub archs serve embeddings; covered elsewhere")
    B, Sq = 2, 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, Sq)), jnp.int32)
    logits, _ = M.forward(params, cfg, toks)
    caches = M.init_caches(cfg, B, Sq + 4, dtype=jnp.float32)
    plog, caches = M.prefill(params, cfg, toks, caches=caches)
    np.testing.assert_allclose(np.asarray(plog[:, -1], np.float32),
                               np.asarray(logits[:, -1], np.float32),
                               rtol=0.15, atol=0.15)
    dlog, _ = M.decode_step(params, cfg, toks[:, -1:], caches=caches,
                            cache_pos=Sq)
    toks2 = jnp.concatenate([toks, toks[:, -1:]], axis=1)
    ref2, _ = M.forward(params, cfg, toks2)
    np.testing.assert_allclose(np.asarray(dlog[:, 0], np.float32),
                               np.asarray(ref2[:, -1], np.float32),
                               rtol=0.15, atol=0.15)


def test_full_config_param_counts():
    """Full configs land near published parameter counts (defs only)."""
    from repro.models.common import ParamDef
    expect = {"llama3-8b": 8.0e9, "gemma-7b": 8.5e9, "phi3-mini-3.8b": 3.8e9,
              "internlm2-1.8b": 1.9e9, "zamba2-1.2b": 1.2e9,
              "rwkv6-3b": 3.1e9, "phi3.5-moe-42b-a6.6b": 41.9e9,
              "chameleon-34b": 34.3e9, "musicgen-medium": 1.4e9,
              "deepseek-v3-671b": 700e9}
    for arch, want in expect.items():
        cfg = configs.get_config(arch)
        defs = M.model_defs(cfg)
        tot = 0
        for d in jax.tree_util.tree_leaves(
                defs, is_leaf=lambda x: isinstance(x, ParamDef)):
            sz = 1
            for s in d.shape:
                sz *= s
            tot += sz
        assert tot == pytest.approx(want, rel=0.12), f"{arch}: {tot/1e9:.2f}B"


def test_long_500k_applicability():
    """Assignment: long_500k runs only for sub-quadratic archs."""
    runnable = {a for a, s in configs.cells() if s == "long_500k"}
    assert runnable == {"zamba2-1.2b", "rwkv6-3b"}
    assert len(configs.cells(include_skipped=True)) == 40
    assert len(configs.cells()) == 32
