"""Decode hot-path microbenchmark: fused page-walk vs gather attention.

Times ONE jitted decode-attention call — the serving engine's per-layer
inner loop — across a (batch, context, page_size) grid on synthetic GQA
shapes, for both paths:

* ``fused``  — :func:`repro.kernels.paged_attention_fused
  .fused_paged_decode_attention` (the XLA page-walk lowering, the CPU
  serving default);
* ``gather`` — :func:`repro.kernels.paged_attention
  .paged_decode_attention` (materialize + ``_repeat_kv`` + naive, the
  differential oracle).

Every grid point's KV pool is sized for the WORST-CASE context
(``max_seq`` slots per request) while requests only hold ``context``
tokens of live history — exactly the regime the fused kernel targets: the
gather path pays O(max_blocks · page_size · H) per step regardless of
``context``, the page walk pays O(context · KVH).  Alongside wall time
the bench reports steps/s, tokens/s and the *modeled* KV bytes per step
from the kernel module's traffic model (what a TPU-grade memory system
would move; the md/json feed ``benchmarks.roofline``).

Derived error (the ``benchmarks.run`` quality column) is 0.0 when the run
holds the acceptance properties, +1.0 per violation:

* fused beats gather on decode steps/s at the acceptance point
  (B=8, context>=512, page_size=4; the largest grid point under
  ``--smoke``);
* modeled bytes-moved reduced >= 4x at that same point;
* fused output stays within ``FUSED_LOGIT_TOL`` of the oracle at every
  grid point (the bench must not go fast by going wrong).

Writes ``reports/hotpath.json`` (BENCH-compatible schema, committed so CI
has a baseline) and ``reports/hotpath.md``.
"""

from __future__ import annotations

import json
import os
import time

# synthetic GQA decode shapes: 4x grouping, the paper's smoke-model scale
NUM_KV_HEADS = 2
NUM_HEADS = 8
HEAD_DIM = 64
ACCEPT_BATCH = 8
ACCEPT_CONTEXT = 512
ACCEPT_PAGE = 4
BYTES_RATIO_FLOOR = 4.0


def _grid(smoke: bool):
    """(batch, context, page_size, max_seq) points; last one is the gate."""
    if smoke:
        return [(2, 64, 4, 256), (8, 64, 8, 256), (8, 128, 4, 256)]
    return [
        (1, 128, 8, 1024),
        (4, 256, 8, 1024),
        (8, 512, 8, 1024),
        (8, 1024, 4, 1024),
        (ACCEPT_BATCH, ACCEPT_CONTEXT, ACCEPT_PAGE, 1024),
    ]


def _build_case(jnp, jax, batch, context, page_size, max_seq, seed):
    """Paged pools + block tables holding ``context`` live tokens each."""
    max_blocks = -(-max_seq // page_size)
    num_pages = 1 + batch * max_blocks  # page 0 is the trash page
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    pool_shape = (num_pages, page_size, NUM_KV_HEADS, HEAD_DIM)
    pool_k = jax.random.normal(k1, pool_shape, jnp.float32)
    pool_v = jax.random.normal(k2, pool_shape, jnp.float32)
    q = jax.random.normal(k3, (batch, 1, NUM_HEADS, HEAD_DIM), jnp.float32)
    bt = 1 + jnp.arange(batch * max_blocks, dtype=jnp.int32).reshape(
        batch, max_blocks)
    lengths = jnp.full((batch,), context, jnp.int32)
    return q, pool_k, pool_v, bt, lengths


def _time_call(fn, *args, reps: int):
    out = fn(*args)  # warm the jit cache
    out.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / reps, out


def _markdown(records, gate) -> str:
    lines = [
        "# Decode hot path: fused page-walk vs gather attention",
        "",
        f"One jitted decode-attention call, synthetic GQA shapes "
        f"(H={NUM_HEADS}, KVH={NUM_KV_HEADS}, hd={HEAD_DIM}, fp32 pools), "
        "pool sized for the max_seq worst case while requests hold only "
        "`context` live tokens.  Bytes are the kernel module's modeled KV "
        "traffic per step per layer.",
        "",
        "| batch | context | page | max_seq | fused us | gather us | "
        "speedup | fused MB | gather MB | bytes ratio | max dlogit |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in records:
        lines.append(
            f"| {r['batch']} | {r['context']} | {r['page_size']} "
            f"| {r['max_seq']} | {r['fused_us']:.0f} | {r['gather_us']:.0f} "
            f"| {r['speedup']:.2f}x | {r['fused_bytes'] / 2**20:.3f} "
            f"| {r['gather_bytes'] / 2**20:.3f} | {r['bytes_ratio']:.1f}x "
            f"| {r['max_abs_diff']:.2e} |")
    lines += [
        "",
        f"Acceptance point (B={gate['batch']}, context={gate['context']}, "
        f"page={gate['page_size']}): fused {gate['speedup']:.2f}x faster, "
        f"modeled KV traffic {gate['bytes_ratio']:.1f}x smaller "
        f"(floor {BYTES_RATIO_FLOOR:.0f}x).",
        "",
    ]
    return "\n".join(lines)


def hotpath(out_dir: str | None = None, smoke: bool = False):
    """Returns (rows, err) per the benchmarks.run contract; writes the files."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.paged_attention import paged_decode_attention
    from repro.kernels.paged_attention_fused import (
        fused_decode_bytes_moved, fused_paged_decode_attention,
        gather_decode_bytes_moved)
    from repro.serving import FUSED_LOGIT_TOL

    out_dir = out_dir or os.environ.get("HOTPATH_OUT", "reports")
    reps = 5 if smoke else 20
    fused_fn = jax.jit(
        lambda *a: fused_paged_decode_attention(*a, num_heads=NUM_HEADS,
                                                impl="xla"))
    gather_fn = jax.jit(
        lambda *a: paged_decode_attention(*a, num_heads=NUM_HEADS))

    records = []
    worst_diff = 0.0
    for seed, (batch, context, page_size, max_seq) in enumerate(_grid(smoke)):
        args = _build_case(jnp, jax, batch, context, page_size, max_seq, seed)
        fused_s, fused_out = _time_call(fused_fn, *args, reps=reps)
        gather_s, gather_out = _time_call(gather_fn, *args, reps=reps)
        diff = float(jnp.max(jnp.abs(fused_out.astype(jnp.float32)
                                     - gather_out.astype(jnp.float32))))
        worst_diff = max(worst_diff, diff)
        lengths = [context] * batch
        fused_bytes = fused_decode_bytes_moved(
            lengths, page_size=page_size, num_kv_heads=NUM_KV_HEADS,
            head_dim=HEAD_DIM)
        gather_bytes = gather_decode_bytes_moved(
            batch=batch, max_blocks=-(-max_seq // page_size),
            page_size=page_size, num_kv_heads=NUM_KV_HEADS,
            num_heads=NUM_HEADS, head_dim=HEAD_DIM)
        records.append({
            "batch": batch, "context": context, "page_size": page_size,
            "max_seq": max_seq,
            "fused_us": fused_s * 1e6, "gather_us": gather_s * 1e6,
            "fused_steps_per_s": 1.0 / fused_s,
            "gather_steps_per_s": 1.0 / gather_s,
            "fused_tok_per_s": batch / fused_s,
            "gather_tok_per_s": batch / gather_s,
            "speedup": gather_s / fused_s,
            "fused_bytes": fused_bytes, "gather_bytes": gather_bytes,
            "bytes_ratio": gather_bytes / fused_bytes,
            "max_abs_diff": diff,
        })

    gate = records[-1]  # the acceptance point closes both grids
    err = 0.0
    if gate["speedup"] < 1.0:
        err += 1.0  # fused must beat gather where the paper's regime lives
    if gate["bytes_ratio"] < BYTES_RATIO_FLOOR:
        err += 1.0  # modeled KV traffic must drop >= 4x
    if worst_diff > FUSED_LOGIT_TOL:
        err += 1.0  # speed must not come from wrong attention

    os.makedirs(out_dir, exist_ok=True)
    json_path = os.path.join(out_dir, "hotpath.json")
    with open(json_path, "w") as fh:
        json.dump({
            "dims": {"num_heads": NUM_HEADS, "num_kv_heads": NUM_KV_HEADS,
                     "head_dim": HEAD_DIM, "dtype": "float32"},
            "smoke": smoke, "reps": reps, "grid": records,
            "acceptance": {
                "point": {k: gate[k]
                          for k in ("batch", "context", "page_size")},
                "fused_beats_gather": gate["speedup"] >= 1.0,
                "speedup": gate["speedup"],
                "bytes_ratio": gate["bytes_ratio"],
                "bytes_ratio_floor": BYTES_RATIO_FLOOR,
                "max_abs_diff": worst_diff,
                "tol": FUSED_LOGIT_TOL,
            },
        }, fh, indent=2)
    md_path = os.path.join(out_dir, "hotpath.md")
    with open(md_path, "w") as fh:
        fh.write(_markdown(records, gate))

    rows = []
    for r in records:
        tag = f"B{r['batch']}_ctx{r['context']}_page{r['page_size']}"
        rows += [
            (f"{tag}_fused_steps_per_s", f"{r['fused_steps_per_s']:.1f}", None),
            (f"{tag}_gather_steps_per_s",
             f"{r['gather_steps_per_s']:.1f}", None),
            (f"{tag}_speedup", f"{r['speedup']:.2f}x", None),
            (f"{tag}_bytes_ratio", f"{r['bytes_ratio']:.1f}x", None),
        ]
    rows += [
        ("acceptance_fused_beats_gather", str(gate["speedup"] >= 1.0), None),
        ("acceptance_speedup", f"{gate['speedup']:.2f}x", None),
        ("acceptance_bytes_ratio", f"{gate['bytes_ratio']:.1f}x", None),
        ("max_abs_diff_vs_oracle", f"{worst_diff:.3e}", None),
        ("json", json_path, None),
        ("markdown", md_path, None),
    ]
    return rows, err
