"""Table V analog: weight sparsity profiling extended to the 10 assigned
architectures (the paper profiles 8 CNNs + LLaMA2-70B; same methodology:
per-tensor INT quantization, word sparsity + block-max bit sparsity).

Weights come from briefly-trained smoke models (a few hundred CPU steps) so
the distributions have the outlier structure of real training, not raw init.
A synthetic heavy-tailed calibration tensor reproduces the paper's LLaMA2
attention-FC numbers as a cross-check of the methodology.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import sparsity
from repro.core.quantization import vmax


def _trained_smoke_params(arch: str, steps: int = 30):
    from repro.launch.mesh import single_device_mesh
    from repro.launch.train import TrainLoopConfig, train
    cfg = configs.get_smoke_config(arch)
    loop = TrainLoopConfig(steps=steps, batch=4, seq=32, log_every=steps,
                           lr=1e-3)
    state, _, _ = train(cfg, single_device_mesh(), loop)
    return cfg, state.params


def arch_sparsity_table(bits=(8, 4, 2), steps: int = 30, archs=None):
    rows = []
    for arch in archs or configs.ARCH_IDS:
        cfg, params = _trained_smoke_params(arch, steps)
        stats_tree = sparsity.profile_tree(params, bits=8)
        for b in bits:
            per = [sparsity.profile_tensor(leaf, bits=b)
                   for name, leaf in _weight_leaves(params)]
            agg = sparsity.combine_stats(per)
            rows.append((f"{arch}_{b}b_word", agg.word, None))
            rows.append((f"{arch}_{b}b_bit_blockmax", agg.bit_blockmax, None))
    return rows, 0.0


def _weight_leaves(params):
    out = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        if hasattr(leaf, "ndim") and leaf.ndim >= 2:
            name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                            for p in path)
            out.append((name, leaf))
    return out


def llama2_calibration():
    """LLaMA2-like FC weights: the paper's Table V LLM rows are the
    stream-length floors of *group-quantized* weights.

    The published FC/FFN bit sparsities (0.82% / 12.5% / 50% at 8/4/2-bit)
    equal ``1 - Vmax / 2^(w-1)`` exactly — i.e. every 32x32 measurement block
    saturates its scale, which is what HuggingFace group-quantized (gs=32)
    checkpoints produce by construction.  Reproducing those floors from a
    synthetic Gaussian tensor + gs=32 group quantization validates the
    block-max methodology end to end.
    """
    rng = np.random.default_rng(0)
    w = rng.normal(0, 0.02, (4096, 4096)).astype(np.float32)
    rows = []
    refs = {8: 0.0082, 4: 0.125, 2: 0.50}
    errs = []
    for b in (2, 4, 8):
        v = vmax(b)
        wg = w.reshape(128, 32, 4096)
        scale = np.abs(wg).max(axis=1, keepdims=True) / v
        q = np.clip(np.round(wg / scale), -v, v).reshape(4096, 4096)
        st = sparsity.profile_tensor(jnp.asarray(q.astype(np.int8)), bits=b,
                                     pre_quantized=True)
        rows.append((f"llama2like_fc_{b}b_bit", st.bit_blockmax, refs[b]))
        errs.append(abs(st.bit_blockmax - refs[b]))
    return rows, max(errs)
