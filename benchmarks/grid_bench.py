"""Sharded PE-array grid benchmark: grid shapes × designs × bits cost sweep
plus a per-shard heterogeneous grid plan, emitted as ``reports/grid.json`` +
``reports/grid.md``.

Two parts:

1. **Grid cost sweep** — the llama3-8b smoke decode workload priced on every
   (grid shape × design × bit-width) via ``core.accounting.price_workload``'s
   grid branch (``ppa.GridDLAModel``): dynamic energy/latency, per-unit
   utilization and the interconnect-hop share.
2. **Per-shard grid plan** — ``repro.eval.planner.build_grid_plan`` at the
   paper-grid 64×64 DLA geometry: each shard of a 2×2 grid plans its own
   weight slices (per-shard sparsity profiles), and the verdict compares the
   heterogeneous planned energy against the best *uniform* grid assignment.

Derived error (the ``benchmarks.run`` quality column) is 0.0 when the
acceptance properties hold, +1.0 per violation:

* grid energy is monotone non-decreasing along the refinement chain
  (1,1) → (1,2) → (2,2) → (2,4) → (4,4) for every design × bits (the
  workload's dims divide every chain grid, so this is exact, not a fit);
* the per-shard plan is *mixed* (≥ 2 distinct (design, bits) across the
  shard assignments);
* the per-shard heterogeneous planned energy ≤ the best uniform grid
  assignment's energy (per-site, per-shard argmin over a superset);
* the emitted grid plan lints clean under ``repro.analysis.plan_lint``
  (each error finding adds +1.0; the verdict line lands in the report).
"""

from __future__ import annotations

import json
import os

ARCH = "llama3-8b"
UNIT_N = 64
NUM_UNITS = 64
BATCH = 4
#: refinement chain: each grid divides the next, so energy must be monotone
GRID_CHAIN = [(1, 1), (1, 2), (2, 2), (2, 4), (4, 4)]
PLAN_GRID = (2, 2)


def grid(out_dir: str | None = None):
    """Returns (rows, err) per the benchmarks.run contract; writes the files."""
    import jax

    from repro import configs
    from repro.core import accounting
    from repro.eval import planner as planner_lib
    from repro.eval import sweetspot as sweetspot_lib
    from repro.launch import serve as serve_lib
    from repro.models import model as model_lib

    out_dir = out_dir or os.environ.get("GRID_OUT", "reports")
    cfg = configs.get_smoke_config(ARCH)
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))

    rows = []
    err = 0.0

    # --- part 1: grid cost sweep -------------------------------------------
    sweep = []
    for bits in (2, 4, 8):
        rec, _ = serve_lib.build_workload(cfg, params, BATCH, 16, bits)
        for design in sweetspot_lib.CALIBRATED_DESIGNS:
            chain_energy = []
            for shape in GRID_CHAIN:
                cost = accounting.price_workload(
                    rec.calls, design=design, bits=bits, unit_n=UNIT_N,
                    num_units=NUM_UNITS, grid=shape)
                chain_energy.append(cost.dyn_energy_uj)
                sweep.append({
                    "design": design, "bits": bits,
                    "grid": list(shape),
                    "dyn_energy_uj": cost.dyn_energy_uj,
                    "dyn_latency_us": cost.dyn_latency_us,
                    "hop_energy_uj": cost.hop_energy_uj,
                    "hop_energy_share": cost.hop_energy_share,
                    "utilization": cost.utilization,
                })
                rows.append((
                    f"{design}@{bits}b_grid{shape[0]}x{shape[1]}",
                    f"dynE={cost.dyn_energy_uj:.4f}uJ "
                    f"dynL={cost.dyn_latency_us:.4f}us "
                    f"hop={cost.hop_energy_share:.1%} "
                    f"util={cost.utilization:.3f}", None))
            monotone = all(b >= a * (1 - 1e-9) for a, b in
                           zip(chain_energy, chain_energy[1:]))
            if not monotone:
                err += 1.0
                rows.append((f"NONMONOTONE_{design}@{bits}",
                             str(chain_energy), None))

    # --- part 2: per-shard heterogeneous grid plan -------------------------
    site_list = planner_lib.discover_sites(cfg, params, batch=BATCH)
    gplan = planner_lib.build_grid_plan(
        cfg, params, grid=PLAN_GRID, batch=BATCH, unit_n=UNIT_N,
        num_units=NUM_UNITS, sites=site_list)
    meta = gplan.metadata()
    agg = meta["totals"]["aggregate"]
    hetero = agg["planned_heterogeneous"]["dyn_energy_uj"]
    best_name = agg["uniform_best"]
    best = (agg["uniform"][best_name]["dyn_energy_uj"]
            if best_name else 0.0)
    shard_distinct = gplan.shard_distinct_backends()
    rows += [
        ("plan_grid", f"{PLAN_GRID[0]}x{PLAN_GRID[1]}", None),
        ("plan_heterogeneous_dyn_energy_uj", f"{hetero:.4f}", None),
        ("plan_executed_dyn_energy_uj",
         f"{agg['planned']['dyn_energy_uj']:.4f}", None),
        ("plan_best_uniform", f"{best_name} {best:.4f}uJ", None),
        ("plan_shard_distinct",
         ", ".join(f"{d}@{b}" for d, b in shard_distinct), None),
        ("plan_heterogeneous_sites",
         ", ".join(meta["heterogeneous_sites"]) or "none", None),
    ]
    from repro.analysis import findings as findings_lib
    from repro.analysis import plan_lint
    found = plan_lint.lint_plan(gplan,
                                site_names=[s.name for s in site_list])
    rows.append(("analysis", findings_lib.verdict_line(found), None))
    if len(shard_distinct) < 2:
        err += 1.0  # the per-shard assignment degenerated to uniform
    if best_name is None or hetero > best * (1 + 1e-9):
        err += 1.0  # the per-shard plan lost to a uniform grid assignment
    err += float(len(findings_lib.errors(found)))  # plan must lint clean

    # --- report files -------------------------------------------------------
    os.makedirs(out_dir, exist_ok=True)
    json_path = os.path.join(out_dir, "grid.json")
    with open(json_path, "w") as fh:
        json.dump({
            "schema": "repro.benchmarks.grid/v1",
            "arch": ARCH, "unit_n": UNIT_N, "num_units": NUM_UNITS,
            "batch": BATCH,
            "sweep": sweep,
            "plan": json.loads(gplan.to_json()),
        }, fh, indent=2)
        fh.write("\n")
    md_path = os.path.join(out_dir, "grid.md")
    with open(md_path, "w") as fh:
        fh.write(_sweep_markdown(sweep))
        fh.write("\n")
        fh.write(planner_lib.grid_plan_to_markdown(gplan))
    rows += [("json", json_path, None), ("markdown", md_path, None)]
    return rows, err


def _sweep_markdown(sweep: list[dict]) -> str:
    lines = [
        "# Grid cost sweep",
        "",
        f"llama3-8b smoke decode workload on {NUM_UNITS}× {UNIT_N}×{UNIT_N} "
        "DLA nodes composed into PE-array grids "
        "(`core.accounting.price_workload` grid branch; hop model "
        "`core.ppa.HOP_CYCLES` / `HOP_ENERGY_PJ_PER_BYTE`).",
        "",
        "| design | bits | grid | dyn energy (µJ) | dyn latency (µs) | "
        "hop share | utilization |",
        "|---|---|---|---|---|---|---|",
    ]
    for row in sweep:
        lines.append(
            f"| {row['design']} | {row['bits']} | "
            f"{row['grid'][0]}×{row['grid'][1]} | "
            f"{row['dyn_energy_uj']:.4f} | {row['dyn_latency_us']:.4f} | "
            f"{row['hop_energy_share']:.1%} | {row['utilization']:.3f} |")
    lines.append("")
    return "\n".join(lines)
