"""Sweet-spot sweep benchmark: drives ``repro.eval`` and emits JSON + markdown.

Sweeps bits x matrix size x design through the calibrated PPA model, writes
``reports/sweetspot.json`` and ``reports/sweetspot.md``, and returns the
per-metric winners as benchmark rows.  The derived error is the max relative
deviation of on-grid sweep points from the paper's Tables I/II (exact-lookup
metrics — must be 0), plus a 1.0 penalty if any derived Table III/IV grid
value strays past the repo's 1% reproduction bar or a kernel cross-check
disagrees with the cycle model.
"""

from __future__ import annotations

import os

from repro.eval import report as report_lib
from repro.eval import sweetspot as ss


def sweetspot(out_dir: str | None = None):
    """Returns (rows, err) per the benchmarks.run contract; writes the files."""
    out_dir = out_dir or os.environ.get("SWEETSPOT_OUT", "reports")
    rep = ss.build_report()
    json_path, md_path = report_lib.write(rep, out_dir)

    rows = []
    for w in rep.winners:
        rows.append((f"{w.metric}_{w.bits}b_n{w.n}_winner",
                     f"{w.design} ({w.margin:.2f}x vs {w.runner_up})", None))
    for c in rep.crossovers:
        rows.append((f"crossover_{c.metric}_{c.bits}b",
                     f"{c.from_design} -> {c.to_design} at n={c.n_at}", None))
    for r in rep.kernel_crosscheck:
        rows.append((f"kernel_{r['kernel']}_{r['bits']}b",
                     f"output_ok={r['output_ok']} cycles={r['kernel_cycles']} "
                     f"(wc model {r['wc_cycles']})", None))
    rows.append(("json", json_path, None))
    rows.append(("markdown", md_path, None))

    err = max(rep.grid_fidelity["area_um2"], rep.grid_fidelity["power_mw"])
    if rep.grid_fidelity["energy_nj"] > 0.01 or \
            rep.grid_fidelity["adp_mm2_ns"] > 0.01:
        err += 1.0
    if not all(r["output_ok"] and r["cycles_ok"]
               for r in rep.kernel_crosscheck):
        err += 1.0
    return rows, err
