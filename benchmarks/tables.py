"""Benchmark modules regenerating every table/figure of the paper from the
calibrated model + simulators, with pass/fail deltas against the published
numbers.  Each ``table*`` function returns (rows, max_rel_err)."""

from __future__ import annotations

import numpy as np

from repro.configs import paper_gemm
from repro.core import ppa
from repro.core.gemm_sims import DESIGNS, wc_cycles


def table1_area():
    """Table I: post-synthesis area (um^2)."""
    rows = []
    for cell in paper_gemm.table_grid():
        got = ppa.area_um2(cell.design, cell.bits, cell.n)
        ref = ppa.AREA_UM2[(cell.bits, cell.n)][cell.design]
        rows.append((f"{cell.bits}b_{cell.n}x{cell.n}_{cell.design}", got, ref))
    err = max(abs(g - r) / r for _, g, r in rows)
    return rows, err


def table2_power():
    """Table II: post-synthesis power (mW)."""
    rows = []
    for cell in paper_gemm.table_grid():
        got = ppa.power_mw(cell.design, cell.bits, cell.n)
        ref = ppa.POWER_MW[(cell.bits, cell.n)][cell.design]
        rows.append((f"{cell.bits}b_{cell.n}x{cell.n}_{cell.design}", got, ref))
    err = max(abs(g - r) / r for _, g, r in rows)
    return rows, err


def table3_energy():
    """Table III: energy (nJ) at worst-case latency — derived, not stored."""
    rows = []
    for cell in paper_gemm.table_grid():
        got = ppa.energy_nj(cell.design, cell.bits, cell.n)
        ref = ppa.PAPER_ENERGY_NJ[(cell.bits, cell.n)][cell.design]
        rows.append((f"{cell.bits}b_{cell.n}x{cell.n}_{cell.design}", got, ref))
    err = max(abs(g - r) / r for _, g, r in rows)
    return rows, err


def table4_tpu_sizes():
    """Table IV: EdgeTPU (64) / CloudTPUv3 (128) area, power, energy, ADP."""
    rows = []
    errs = []
    for cell in paper_gemm.tpu_grid():
        a = ppa.area_um2(cell.design, cell.bits, cell.n) * 1e-6
        p = ppa.power_mw(cell.design, cell.bits, cell.n)
        e = ppa.energy_nj(cell.design, cell.bits, cell.n)
        adp = ppa.adp_mm2_ns(cell.design, cell.bits, cell.n)
        e_ref = ppa.PAPER_ENERGY_NJ[(cell.bits, cell.n)][cell.design]
        adp_ref = ppa.PAPER_ADP_MM2_NS[(cell.bits, cell.n)][cell.design]
        rows.append((f"4b_{cell.n}x{cell.n}_{cell.design}_area_mm2", a, None))
        rows.append((f"4b_{cell.n}x{cell.n}_{cell.design}_power_mW", p, None))
        rows.append((f"4b_{cell.n}x{cell.n}_{cell.design}_energy_nJ", e, e_ref))
        rows.append((f"4b_{cell.n}x{cell.n}_{cell.design}_ADP", adp, adp_ref))
        errs.append(abs(e - e_ref) / e_ref)
        errs.append(abs(adp - adp_ref) / adp_ref)
    return rows, max(errs)


def fig2_scaling():
    """Fig. 2: per-bitwidth-doubling scaling slopes at 32x32."""
    paper_area = dict(ugemm=2.16, tugemm=2.12, tubgemm=2.12, bgemm=2.90)
    paper_power = dict(ugemm=1.56, tugemm=2.02, tubgemm=2.15, bgemm=3.25)
    rows, errs = [], []
    for d in DESIGNS:
        a = ppa.fig2_slope(ppa.AREA_UM2, d)
        p = ppa.fig2_slope(ppa.POWER_MW, d)
        rows.append((f"area_slope_{d}", a, paper_area[d]))
        rows.append((f"power_slope_{d}", p, paper_power[d]))
        errs += [abs(a - paper_area[d]) / paper_area[d],
                 abs(p - paper_power[d]) / paper_power[d]]
    return rows, max(errs)


# Paper Table V (published sparsity values) — inputs to the Fig. 3 analysis.
PAPER_TABLE5_BIT_SPARSITY = {
    # CNNs, 8-bit
    "MobileNetV2": 0.4466, "MobileNetV3": 0.3859, "GoogleNet": 0.4591,
    "InceptionV3": 0.4561, "ShuffleNetV3": 0.4718, "ResNet18": 0.4530,
    "ResNet50": 0.4624, "ResNeXt101": 0.4423,
    # LLaMA2-70B (2/4/8-bit)
    "llama2_fc_2b": 0.50, "llama2_fc_4b": 0.125, "llama2_fc_8b": 0.0082,
    "llama2_ffn_2b": 0.50, "llama2_ffn_4b": 0.125, "llama2_ffn_8b": 0.0080,
    "llama2_q_2b": 0.0056, "llama2_q_4b": 0.0889, "llama2_q_8b": 0.2884,
    "llama2_k_2b": 0.0819, "llama2_k_4b": 0.0858, "llama2_k_8b": 0.3252,
}


def fig3_sparsity_energy():
    """Fig. 3: 32x32 energy, worst-case vs sparsity-scaled (Eq. 1).

    Reproduces the three highlighted effects: (1) larger 2-bit tubGEMM gap to
    bGEMM, (2) earlier tub/b crossover, (3) larger 8-bit gap to uGEMM.
    """
    cnn_bspa = float(np.mean([v for k, v in PAPER_TABLE5_BIT_SPARSITY.items()
                              if not k.startswith("llama2")]))
    rows = []
    for bits in (2, 4, 8):
        for d in DESIGNS:
            wc = ppa.energy_nj(d, bits, 32)
            dyn = ppa.dynamic_energy_nj(d, bits, 32, cnn_bspa)
            rows.append((f"{bits}b_32x32_{d}_wc_nJ", wc, None))
            rows.append((f"{bits}b_32x32_{d}_dyn_nJ", dyn, None))
    # the three claims as derived booleans (1.0 = holds)
    gap2_wc = ppa.energy_nj("bgemm", 2, 32) / ppa.energy_nj("tubgemm", 2, 32)
    gap2_dyn = ppa.energy_nj("bgemm", 2, 32) / \
        ppa.dynamic_energy_nj("tubgemm", 2, 32, cnn_bspa)
    claim1 = float(gap2_dyn > gap2_wc)
    ratio4_wc = ppa.energy_nj("tubgemm", 4, 32) / ppa.energy_nj("bgemm", 4, 32)
    ratio4_dyn = ppa.dynamic_energy_nj("tubgemm", 4, 32, cnn_bspa) / \
        ppa.energy_nj("bgemm", 4, 32)
    claim2 = float(ratio4_dyn < ratio4_wc)
    gap8_wc = ppa.energy_nj("ugemm", 8, 32) / ppa.energy_nj("tubgemm", 8, 32)
    gap8_dyn = ppa.energy_nj("ugemm", 8, 32) / \
        ppa.dynamic_energy_nj("tubgemm", 8, 32, cnn_bspa)
    claim3 = float(gap8_dyn > gap8_wc)
    rows += [("claim_2bit_gap_grows", claim1, 1.0),
             ("claim_earlier_crossover", claim2, 1.0),
             ("claim_8bit_ugemm_gap_grows", claim3, 1.0)]
    err = 0.0 if (claim1 and claim2 and claim3) else 1.0
    return rows, err
