"""Mixed-precision planner benchmark: derives a per-layer backend plan for a
shipped config and emits ``reports/plan.json`` + ``reports/plan.md``.

The headline artifact of the paper's sweet-spot argument as an executable
decision: ``repro.eval.planner.build_plan`` profiles every dense GEMM site's
weight bit sparsity (Table V machinery), prices each (design, bits) candidate
with Eq. 1-scaled dynamic cycles on the DLA tiling, applies the quantization
accuracy guard and assigns each site its winner.

Derived error (the ``benchmarks.run`` quality column) is 0.0 when the plan
holds the acceptance properties, +1.0 for each violation:

* the assignment is *mixed* — ≥ 2 distinct (design, bits) backends chosen;
* the planned dynamic energy ≤ the best guard-feasible uniform baseline;
* the emitted plan lints clean under ``repro.analysis.plan_lint`` (each
  error finding adds +1.0; the verdict line lands in the report rows).
"""

from __future__ import annotations

import os

# Paper-grid DLA geometry where the sweet spot actually flips: at 64x64 the
# 4-bit tubGEMM-vs-bGEMM energy ratio is 1.24 x (1 - b_spa), so measured
# block-max sparsity ~0.2 is the crossover — right in the spread real weight
# tensors show.  (At 128x128 tubGEMM wins everywhere; at 32x32 bGEMM does.)
ARCH = "llama3-8b"
UNIT_N = 64
NUM_UNITS = 64
BATCH = 4


def plan(out_dir: str | None = None):
    """Returns (rows, err) per the benchmarks.run contract; writes the files."""
    import jax

    from repro import configs
    from repro.eval import planner as planner_lib
    from repro.models import model as model_lib

    out_dir = out_dir or os.environ.get("PLAN_OUT", "reports")
    cfg = configs.get_smoke_config(ARCH)
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    sites = planner_lib.discover_sites(cfg, params, batch=BATCH)
    plan = planner_lib.build_plan(cfg, params, batch=BATCH, unit_n=UNIT_N,
                                  num_units=NUM_UNITS, sites=sites)

    os.makedirs(out_dir, exist_ok=True)
    json_path = plan.save(os.path.join(out_dir, "plan.json"))
    md_path = os.path.join(out_dir, "plan.md")
    with open(md_path, "w") as fh:
        fh.write(planner_lib.to_markdown(plan))

    rows = [(f"site_{e.pattern}",
             f"{e.design}@{e.bits} b_spa={e.bit_blockmax:.3f} "
             f"dynE={e.dyn_energy_uj:.4f}uJ relMSE={e.rel_mse:.4f}", None)
            for e in plan.sites]
    meta = plan.metadata()
    totals = meta["totals"]
    planned = totals["planned"]["dyn_energy_uj"]
    best_name = totals["uniform_best"]
    best = totals["uniform"][best_name]["dyn_energy_uj"] if best_name else 0.0
    distinct = plan.distinct_backends()
    rows += [
        ("planned_dyn_energy_uj", f"{planned:.4f}", None),
        ("best_uniform", f"{best_name} {best:.4f}uJ", None),
        ("distinct_backends",
         ", ".join(f"{d}@{b}" for d, b in distinct), None),
        ("json", json_path, None),
        ("markdown", md_path, None),
    ]
    from repro.analysis import findings as findings_lib
    from repro.analysis import plan_lint
    found = plan_lint.lint_plan(plan, site_names=[s.name for s in sites])
    rows.append(("analysis", findings_lib.verdict_line(found), None))
    err = 0.0
    if len(distinct) < 2:
        err += 1.0  # assignment degenerated to a uniform plan
    if best_name is None or planned > best * (1 + 1e-9):
        err += 1.0  # planner lost to a uniform baseline
    err += float(len(findings_lib.errors(found)))  # plan must lint clean

    # Bits as bytes: freeze the planned widths bit-packed and report the
    # weight-HBM cut next to the energy verdict above (rows are additive —
    # the verdict fields stay byte-identical).
    from repro import backends as backends_lib
    from repro.core import accounting, packing
    packed_params = backends_lib.pack_weights(cfg, params, plan)
    rep = accounting.packed_store_report(packed_params)
    min4 = None
    for leaf in jax.tree_util.tree_leaves(packed_params,
                                          is_leaf=packing.is_packed):
        if packing.is_packed(leaf) and leaf.bits == 4:
            r = leaf.float32_bytes / leaf.stored_bytes
            min4 = r if min4 is None else min(min4, r)
    packed_found = plan_lint.lint_plan(
        plan, packed_bits=packing.packed_widths(packed_params))
    rows += [
        ("packed_store",
         f"{rep.packed_sites}/{rep.total_sites} sites, "
         f"{rep.stored_bytes} B vs {rep.float32_bytes} B fp32 "
         f"({rep.reduction:.2f}x; packed sites {rep.packed_reduction:.2f}x)",
         None),
        ("packed_min_4bit_reduction",
         f"{min4:.2f}x" if min4 is not None else "n/a", None),
        ("packed_lint", findings_lib.verdict_line(packed_found), None),
    ]
    if min4 is not None and min4 < 4.0:
        err += 1.0  # a 4-bit site's store must be >= 4x smaller than fp32
    err += float(len(findings_lib.errors(packed_found)))
    return rows, err
