"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per the harness contract: ``name`` is
the benchmark, ``us_per_call`` is its wall time, ``derived`` is the headline
quality metric (max relative error vs the paper's published numbers — 0 means
an exact reproduction; for benchmarks without published targets it is the
number of rows produced).

    PYTHONPATH=src python -m benchmarks.run [--full] [--details] [name ...]

Positional ``name`` arguments select a subset of benchmarks (e.g.
``python -m benchmarks.run sweetspot`` runs only the sweet-spot sweep).
An unknown name prints the available benchmarks and exits non-zero before
anything heavyweight (jax, the benchmark modules) is imported.
"""

from __future__ import annotations

import argparse
import importlib
import inspect
import sys
import time

# (name, module, function, kwargs) — modules import lazily so selection and
# unknown-name errors don't pay the jax startup cost.
BENCH_SPECS: list[tuple[str, str, str, dict]] = [
    ("table1_area", "benchmarks.tables", "table1_area", {}),
    ("table2_power", "benchmarks.tables", "table2_power", {}),
    ("table3_energy", "benchmarks.tables", "table3_energy", {}),
    ("table4_tpu_sizes", "benchmarks.tables", "table4_tpu_sizes", {}),
    ("fig2_scaling", "benchmarks.tables", "fig2_scaling", {}),
    ("fig3_sparsity_energy", "benchmarks.tables", "fig3_sparsity_energy", {}),
    ("table5_llama2_calibration", "benchmarks.sparsity_bench",
     "llama2_calibration", {}),
    ("sweetspot", "benchmarks.sweetspot_bench", "sweetspot", {}),
    ("plan", "benchmarks.plan_bench", "plan", {}),
    ("serving", "benchmarks.serving_bench", "serving", {}),
    ("hotpath", "benchmarks.hotpath_bench", "hotpath", {}),
    ("grid", "benchmarks.grid_bench", "grid", {}),
    ("stochastic", "benchmarks.stochastic_bench", "stochastic", {}),
    ("ugemm_accuracy", "benchmarks.accuracy_bench", "ugemm_accuracy", {}),
    ("unary_engine_sweep", "benchmarks.accuracy_bench", "unary_engine_sweep", {}),
    ("kernel_micro", "benchmarks.accuracy_bench", "kernel_micro", {}),
    ("roofline_dryrun", "benchmarks.roofline", "roofline_rows", {}),
]
# slow per-arch sparsity profiling sweep: --full, or naming it explicitly
GATED_SPEC = ("table5_arch_sparsity", "benchmarks.sparsity_bench",
              "arch_sparsity_table", {})


def available_benchmarks(full: bool = True) -> list[str]:
    names = [name for name, _, _, _ in BENCH_SPECS]
    if full:
        names.append(GATED_SPEC[0])
    return names


def _timed(fn, *args, **kw):
    t0 = time.perf_counter()
    rows, err = fn(*args, **kw)
    return rows, err, (time.perf_counter() - t0) * 1e6


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="include the slow per-arch sparsity profiling sweep")
    ap.add_argument("--details", action="store_true",
                    help="print every table row, not just the CSV summary")
    ap.add_argument("--smoke", action="store_true",
                    help="shrink benchmarks that take a smoke=... kwarg "
                         "(currently: hotpath) to CI-sized grids")
    ap.add_argument("only", nargs="*", metavar="name",
                    help="run only the named benchmarks")
    args = ap.parse_args(sys.argv[1:] if argv is None else argv)

    specs = list(BENCH_SPECS)
    if args.full or GATED_SPEC[0] in args.only:  # naming it explicitly selects it
        specs.append(GATED_SPEC)
    if args.only:
        known = [name for name, _, _, _ in specs]
        unknown = sorted(set(args.only) - set(known))
        if unknown:
            print(f"error: unknown benchmark(s): {', '.join(unknown)}",
                  file=sys.stderr)
            print(f"available benchmarks: {', '.join(available_benchmarks())}",
                  file=sys.stderr)
            return 2
        specs = [s for s in specs if s[0] in args.only]

    print("name,us_per_call,derived")
    failures = 0
    for name, module, attr, kw in specs:
        try:
            fn = getattr(importlib.import_module(module), attr)
            if args.smoke and "smoke" in inspect.signature(fn).parameters:
                kw = dict(kw, smoke=True)
            rows, err, us = _timed(fn, **kw)
            derived = err if err is not None else len(rows)
            print(f"{name},{us:.0f},{derived:.6f}")
            if args.details:
                for rname, got, ref in rows:
                    refs = "" if ref is None else f" (paper: {ref})"
                    print(f"#   {rname}: {got}{refs}")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},NaN,FAILED:{e}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
