"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per the harness contract: ``name`` is
the benchmark, ``us_per_call`` is its wall time, ``derived`` is the headline
quality metric (max relative error vs the paper's published numbers — 0 means
an exact reproduction; for benchmarks without published targets it is the
number of rows produced).

    PYTHONPATH=src python -m benchmarks.run [--full] [--details] [name ...]

Positional ``name`` arguments select a subset of benchmarks (e.g.
``python -m benchmarks.run sweetspot`` runs only the sweet-spot sweep).
"""

from __future__ import annotations

import argparse
import sys
import time


def _timed(fn, *args, **kw):
    t0 = time.perf_counter()
    rows, err = fn(*args, **kw)
    return rows, err, (time.perf_counter() - t0) * 1e6


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="include the slow per-arch sparsity profiling sweep")
    ap.add_argument("--details", action="store_true",
                    help="print every table row, not just the CSV summary")
    ap.add_argument("only", nargs="*", metavar="name",
                    help="run only the named benchmarks")
    args = ap.parse_args(sys.argv[1:])

    from benchmarks import (accuracy_bench, roofline, sparsity_bench,
                            sweetspot_bench, tables)

    benches = [
        ("table1_area", tables.table1_area, {}),
        ("table2_power", tables.table2_power, {}),
        ("table3_energy", tables.table3_energy, {}),
        ("table4_tpu_sizes", tables.table4_tpu_sizes, {}),
        ("fig2_scaling", tables.fig2_scaling, {}),
        ("fig3_sparsity_energy", tables.fig3_sparsity_energy, {}),
        ("table5_llama2_calibration", sparsity_bench.llama2_calibration, {}),
        ("sweetspot", sweetspot_bench.sweetspot, {}),
        ("ugemm_accuracy", accuracy_bench.ugemm_accuracy, {}),
        ("unary_engine_sweep", accuracy_bench.unary_engine_sweep, {}),
        ("kernel_micro", accuracy_bench.kernel_micro, {}),
        ("roofline_dryrun", roofline.roofline_rows, {}),
    ]
    gated = ("table5_arch_sparsity", sparsity_bench.arch_sparsity_table, {})
    if args.full or gated[0] in args.only:   # naming it explicitly selects it
        benches.append(gated)
    if args.only:
        known = {n for n, _, _ in benches}
        unknown = [n for n in args.only if n not in known]
        if unknown:
            ap.error(f"unknown benchmark(s) {unknown}; choose from {sorted(known)}")
        benches = [b for b in benches if b[0] in args.only]

    print("name,us_per_call,derived")
    failures = 0
    for name, fn, kw in benches:
        try:
            rows, err, us = _timed(fn, **kw)
            derived = err if err is not None else len(rows)
            print(f"{name},{us:.0f},{derived:.6f}")
            if args.details:
                for rname, got, ref in rows:
                    refs = "" if ref is None else f" (paper: {ref})"
                    print(f"#   {rname}: {got}{refs}")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},NaN,FAILED:{e}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
