"""Aggregate the dry-run JSONs into the §Roofline table (per arch x shape x
mesh: three terms, dominant bottleneck, MODEL_FLOPS ratio, roofline fraction)
and emit the markdown EXPERIMENTS.md consumes."""

from __future__ import annotations

import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load_records(dryrun_dir: str = DRYRUN_DIR) -> list[dict]:
    recs = []
    if not os.path.isdir(dryrun_dir):
        return recs
    for name in sorted(os.listdir(dryrun_dir)):
        if name.endswith(".json"):
            with open(os.path.join(dryrun_dir, name)) as f:
                recs.append(json.load(f))
    return recs


def roofline_rows(mesh: str = "16x16", dryrun_dir: str = DRYRUN_DIR):
    """CSV-ish rows for benchmarks.run — single-pod mesh only per assignment."""
    rows = []
    for r in load_records(dryrun_dir):
        if r["mesh"] != mesh or r.get("ep_impl") == "a2a":
            continue
        rl = r["roofline"]
        tag = f"{r['arch']}_{r['shape']}"
        rows.append((f"{tag}_dominant_{rl['dominant']}", rl["step_time_s"], None))
        rows.append((f"{tag}_useful_ratio", rl["useful_flops_ratio"], None))
        rows.append((f"{tag}_roofline_frac", rl["roofline_fraction"], None))
    return rows, 0.0


def markdown_table(dryrun_dir: str = DRYRUN_DIR, mesh: str = "16x16") -> str:
    lines = [
        "| arch | shape | compute (s) | memory (s) | collective (s) | dominant "
        "| useful | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in load_records(dryrun_dir):
        if r["mesh"] != mesh or r.get("ep_impl") == "a2a":
            continue
        rl = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.3e} | "
            f"{rl['memory_s']:.3e} | {rl['collective_s']:.3e} | "
            f"{rl['dominant']} | {rl['useful_flops_ratio']:.2f} | "
            f"{rl['roofline_fraction']:.3f} |")
    return "\n".join(lines)


def main():
    print(markdown_table())


if __name__ == "__main__":
    main()
