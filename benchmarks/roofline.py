"""Aggregate the dry-run JSONs into the §Roofline table (per arch x shape x
mesh: three terms, dominant bottleneck, MODEL_FLOPS ratio, roofline fraction)
and emit the markdown EXPERIMENTS.md consumes.  Also folds in the decode
KV-traffic model from ``reports/hotpath.json`` (written by
``benchmarks.run hotpath``): decode attention is the memory-bound term of
the serving hot path, and the fused page walk moves O(len·KVH) bytes where
the gather path moves O(max_blocks·page_size·H)."""

from __future__ import annotations

import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")
HOTPATH_REPORT = os.path.join(os.path.dirname(__file__), "..", "reports",
                              "hotpath.json")


def load_records(dryrun_dir: str = DRYRUN_DIR) -> list[dict]:
    recs = []
    if not os.path.isdir(dryrun_dir):
        return recs
    for name in sorted(os.listdir(dryrun_dir)):
        if name.endswith(".json"):
            with open(os.path.join(dryrun_dir, name)) as f:
                recs.append(json.load(f))
    return recs


def roofline_rows(mesh: str = "16x16", dryrun_dir: str = DRYRUN_DIR):
    """CSV-ish rows for benchmarks.run — single-pod mesh only per assignment."""
    rows = []
    for r in load_records(dryrun_dir):
        if r["mesh"] != mesh or r.get("ep_impl") == "a2a":
            continue
        rl = r["roofline"]
        tag = f"{r['arch']}_{r['shape']}"
        rows.append((f"{tag}_dominant_{rl['dominant']}", rl["step_time_s"], None))
        rows.append((f"{tag}_useful_ratio", rl["useful_flops_ratio"], None))
        rows.append((f"{tag}_roofline_frac", rl["roofline_fraction"], None))
    return rows, 0.0


def markdown_table(dryrun_dir: str = DRYRUN_DIR, mesh: str = "16x16") -> str:
    lines = [
        "| arch | shape | compute (s) | memory (s) | collective (s) | dominant "
        "| useful | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in load_records(dryrun_dir):
        if r["mesh"] != mesh or r.get("ep_impl") == "a2a":
            continue
        rl = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.3e} | "
            f"{rl['memory_s']:.3e} | {rl['collective_s']:.3e} | "
            f"{rl['dominant']} | {rl['useful_flops_ratio']:.2f} | "
            f"{rl['roofline_fraction']:.3f} |")
    return "\n".join(lines)


def decode_traffic_rows(report_path: str = HOTPATH_REPORT):
    """Modeled decode-attention KV bytes/step per hotpath grid point.

    Rows for benchmarks.run / EXPERIMENTS.md from the committed hotpath
    report; empty when the report has not been generated yet."""
    rows = []
    if not os.path.isfile(report_path):
        return rows, 0.0
    with open(report_path) as f:
        rep = json.load(f)
    for r in rep["grid"]:
        tag = f"B{r['batch']}_ctx{r['context']}_page{r['page_size']}"
        rows.append((f"{tag}_fused_kv_bytes", r["fused_bytes"], None))
        rows.append((f"{tag}_gather_kv_bytes", r["gather_bytes"], None))
        rows.append((f"{tag}_kv_bytes_ratio",
                     round(r["bytes_ratio"], 2), None))
    return rows, 0.0


def decode_traffic_markdown(report_path: str = HOTPATH_REPORT) -> str:
    rows, _ = decode_traffic_rows(report_path)
    if not rows:
        return "(no reports/hotpath.json — run `python -m benchmarks.run hotpath`)"
    lines = [
        "| point | fused KV MiB/step | gather KV MiB/step | ratio |",
        "|---|---|---|---|",
    ]
    for i in range(0, len(rows), 3):
        tag = rows[i][0].removesuffix("_fused_kv_bytes")
        fused_b, gather_b, ratio = rows[i][1], rows[i + 1][1], rows[i + 2][1]
        lines.append(f"| {tag} | {fused_b / 2**20:.3f} "
                     f"| {gather_b / 2**20:.3f} | {ratio}x |")
    return "\n".join(lines)


def main():
    print(markdown_table())
    print()
    print("## Decode attention KV traffic (modeled, per layer per step)")
    print()
    print(decode_traffic_markdown())


if __name__ == "__main__":
    main()
