"""uGEMM stochastic-accuracy benchmark (paper §II-A / §V claims) and
Pallas-kernel micro-benchmarks."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import paper_gemm
from repro.core import gemm_sims as gs
from repro.core.quantization import quantize, vmax
from repro.kernels import ops, ref


def ugemm_accuracy():
    """GEMM-level relative RMSE of the unified stochastic simulator, per
    bit-width, plus exact-design bit-identity checks."""
    rng = np.random.default_rng(0)
    rows = []
    errs = []
    for bits in (2, 4, 8):
        v = vmax(bits)
        a = jnp.asarray(rng.integers(-v, v + 1, (16, 64)), jnp.int8)
        b = jnp.asarray(rng.integers(-v, v + 1, (64, 16)), jnp.int8)
        rel = gs.rel_rmse(gs.ugemm_exact(a, b, bits=bits),
                          gs.bgemm_exact(a, b))
        rows.append((f"ugemm_{bits}b_gemm_relRMSE", rel, None))
        # deterministic designs: exact
        tu = np.asarray(gs.tugemm_stream(a[:, :8], b[:8], bits)[0])
        tub = np.asarray(gs.tubgemm_stream(a[:, :8], b[:8], bits)[0])
        o = np.asarray(gs.bgemm_exact(a[:, :8], b[:8]))
        exact = float(np.array_equal(tu, o) and np.array_equal(tub, o))
        rows.append((f"exact_designs_bitidentical_{bits}b", exact, 1.0))
        errs.append(0.0 if exact else 1.0)
    # the paper's qualitative claim: error small at 8-bit, zero at 2-bit
    err8 = [r for n, r, _ in rows if n == "ugemm_8b_gemm_relRMSE"][0]
    err2 = [r for n, r, _ in rows if n == "ugemm_2b_gemm_relRMSE"][0]
    errs.append(0.0 if (err8 < 0.04 and err2 == 0.0) else 1.0)
    return rows, max(errs)


def unary_engine_sweep():
    """Design x bit-width sweep through the batched vectorized engine.

    Exercises the typed backend objects (``repro.backends.resolve`` +
    batched ``GemmBackend.execute`` per design/bit-width over a stacked
    batch of problems), checks the Pallas tubGEMM slot-loop kernel for
    bit-identity, and reports the slot-parallel engine's speedup over the
    sequential scan reference.
    """
    from repro import backends

    rng = np.random.default_rng(0)
    rows, errs = [], []
    batch, (m, k, n) = 4, (16, 32, 16)
    for bits in (2, 4, 8):
        v = vmax(bits)
        a = jnp.asarray(rng.integers(-v, v + 1, (batch, m, k)), jnp.int8)
        b = jnp.asarray(rng.integers(-v, v + 1, (batch, k, n)), jnp.int8)
        oracle = np.asarray(
            backends.resolve("bgemm", bits=bits).execute(a, b), np.float64)
        # the four *simulated* designs — not the Pallas kernel mirrors
        for design in paper_gemm.DESIGNS:
            engine = backends.resolve(design, bits=bits)
            rel = gs.rel_rmse(engine.execute(a, b), oracle)
            rows.append((f"{design}_{bits}b_batched_relRMSE", rel,
                         None if design == "ugemm" else 0.0))
            if engine.exact:               # exact designs must be bit-identical
                errs.append(0.0 if rel == 0.0 else 1.0)
        got, _ = ops.tub_matmul(a[0], b[0], bits=bits, interpret=True)
        ok = bool(np.array_equal(np.asarray(got), oracle[0]))
        rows.append((f"unary_kernel_{bits}b_bitidentical", float(ok), 1.0))
        errs.append(0.0 if ok else 1.0)
    # slot-parallel engine vs the sequential scan reference (same numerics)
    bits = 8
    v = vmax(bits)
    a = jnp.asarray(rng.integers(-v, v + 1, (m, k)), jnp.int8)
    b = jnp.asarray(rng.integers(-v, v + 1, (k, n)), jnp.int8)
    gs.tubgemm_stream(a, b, bits)[0].block_until_ready()      # warm
    gs.tubgemm_stream_scan(a, b, bits)[0].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(5):
        gs.tubgemm_stream(a, b, bits)[0].block_until_ready()
    t_vec = (time.perf_counter() - t0) / 5
    t0 = time.perf_counter()
    for _ in range(5):
        gs.tubgemm_stream_scan(a, b, bits)[0].block_until_ready()
    t_scan = (time.perf_counter() - t0) / 5
    rows.append(("tubgemm_stream_8b_vec_vs_scan_speedup", t_scan / t_vec, None))
    return rows, max(errs)


def kernel_micro(repeats: int = 3):
    """Wall-time of the Pallas quant_gemm (interpret mode on CPU — correctness
    path; TPU timings require real hardware) vs the jnp reference."""
    rng = np.random.default_rng(0)
    rows = []
    for bits, (m, k, n) in ((8, (256, 512, 256)), (4, (256, 512, 256)),
                            (2, (256, 512, 256))):
        v = vmax(bits)
        x = jnp.asarray(rng.integers(-127, 128, (m, k)), jnp.int8)
        w = jnp.asarray(rng.integers(-v, v + 1, (k, n)), jnp.int8)
        wp = ops.pack_values(w, bits, axis=0)
        # warmup + check
        got = ops.int_matmul(x, wp, bits=bits, interpret=True)
        want = ref.quant_gemm_ref(x, wp, bits=bits)
        ok = bool(jnp.all(got == want))
        t0 = time.perf_counter()
        for _ in range(repeats):
            ops.int_matmul(x, wp, bits=bits, interpret=True).block_until_ready()
        t_kernel = (time.perf_counter() - t0) / repeats * 1e6
        t0 = time.perf_counter()
        for _ in range(repeats):
            ref.quant_gemm_ref(x, wp, bits=bits).block_until_ready()
        t_ref = (time.perf_counter() - t0) / repeats * 1e6
        rows.append((f"quant_gemm_{bits}b_{m}x{k}x{n}_us", t_kernel, t_ref))
        rows.append((f"quant_gemm_{bits}b_allclose", float(ok), 1.0))
    # bit-sparsity kernel
    q = quantize(jnp.asarray(rng.normal(0, 0.05, (1024, 1024)), jnp.float32),
                 bits=8, per_channel=False).values
    t0 = time.perf_counter()
    for _ in range(repeats):
        ops.bit_sparsity_stats(q, bits=8, interpret=True)[1].block_until_ready()
    rows.append(("bitsparsity_1024x1024_us",
                 (time.perf_counter() - t0) / repeats * 1e6, None))
    err = 0.0 if all(r == 1.0 for nm, r, ref_ in rows if nm.endswith("allclose")) else 1.0
    return rows, err
