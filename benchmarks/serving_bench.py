"""Continuous-batching serving benchmark: serves one seeded Poisson trace
through the paged-KV engine under tubGEMM execution, once with continuous
batching and once with static batching, and emits ``reports/serving.json`` +
``reports/serving.md``.

The paper's energy story under *traffic* rather than a single batched call:
every decode step contracts the smoke model's dense sites on the unary
backend (``use_backend`` scope inside ``repro.serving.ServingEngine``) while
the scheduler joins/evicts requests at step boundaries, and each step is
priced with Eq. 1-scaled dynamic energy so the report carries µJ/token
alongside throughput and latency percentiles.

Derived error (the ``benchmarks.run`` quality column) is 0.0 when the run
holds the acceptance properties, +1.0 for each violation:

* continuous batching's token throughput ≥ static batching's on the SAME
  trace (the tentpole gate);
* both schedulers complete every request (the per-request token streams are
  reported but NOT gated here: under backend execution the per-tensor
  activation-quantization scale spans the whole decode batch, so a request's
  tokens legitimately depend on which requests it is co-batched with — the
  float-path schedule-invariance gate lives in ``serve traffic`` and the
  tier-1 tests);
* the paged decode step is bit-exact with the contiguous
  ``model_lib.decode_step`` reference at fp32
  (``repro.serving.paged_vs_contiguous_probe`` returns 0.0).
"""

from __future__ import annotations

import dataclasses
import json
import os

ARCH = "llama3-8b"
MAX_BATCH = 4
PAGE_SIZE = 8
UNIT_N = 64
NUM_UNITS = 64
BITS = 4


def _markdown(tcfg, reports, probe: float) -> str:
    rc, rs = reports["continuous"], reports["static"]
    gain = rc.throughput_tok_per_step / max(rs.throughput_tok_per_step, 1e-30)
    lines = [
        "# Serving under traffic: continuous vs static batching",
        "",
        f"Seeded Poisson trace: {tcfg.num_requests} requests at "
        f"{tcfg.arrival_rate}/step (seed {tcfg.seed}), served on a "
        f"{MAX_BATCH}-slot paged engine ({rc.num_pages} pages x "
        f"{rc.page_size} slots), decode executed on "
        f"{rc.design}@{rc.bits} with Eq.-1 energy accounting.",
        "",
        "| scheduler | requests | tokens | steps | tok/step | p50 | p99 "
        "| queue | occupancy | uJ/token |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for name in ("continuous", "static"):
        r = reports[name]
        lines.append(
            f"| {name} | {r.requests} | {r.tokens} | {r.steps} "
            f"| {r.throughput_tok_per_step:.3f} | {r.latency_p50:.1f} "
            f"| {r.latency_p99:.1f} | {r.queue_delay_mean:.2f} "
            f"| {r.occupancy:.3f} | {r.energy_per_token_uj:.4f} |")
    lines += [
        "",
        f"Continuous batching: {gain:.2f}x throughput, p99 latency "
        f"{rc.latency_p99:.0f} vs {rs.latency_p99:.0f} steps, "
        f"{rc.energy_per_token_uj:.4f} vs {rs.energy_per_token_uj:.4f} "
        "uJ/token on the same trace.",
        f"Paged decode vs contiguous `decode_step` (fp32): "
        f"{'bit-exact' if probe == 0.0 else f'max |diff| {probe:.3e}'}.",
        "",
    ]
    return "\n".join(lines)


def serving(out_dir: str | None = None):
    """Returns (rows, err) per the benchmarks.run contract; writes the files."""
    import jax

    from repro import configs
    from repro.models import model as model_lib
    from repro.serving import (ServingEngine, TrafficConfig, generate_trace,
                               paged_vs_contiguous_probe)

    out_dir = out_dir or os.environ.get("SERVING_OUT", "reports")
    cfg = dataclasses.replace(configs.get_smoke_config(ARCH),
                              compute_dtype="float32", param_dtype="float32")
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    tcfg = TrafficConfig(num_requests=12, arrival_rate=1.0, seed=0)
    trace = generate_trace(tcfg)
    engine = ServingEngine(cfg, params, max_batch=MAX_BATCH,
                           page_size=PAGE_SIZE, backend="tubgemm", bits=BITS,
                           unit_n=UNIT_N, num_units=NUM_UNITS)
    reports = {name: engine.run(trace, name)
               for name in ("continuous", "static")}
    probe = paged_vs_contiguous_probe(cfg, params, page_size=PAGE_SIZE)

    rc, rs = reports["continuous"], reports["static"]
    gain = rc.throughput_tok_per_step / max(rs.throughput_tok_per_step, 1e-30)
    complete = rc.requests == len(trace) == rs.requests
    same_tokens = rc.request_tokens == rs.request_tokens

    os.makedirs(out_dir, exist_ok=True)
    json_path = os.path.join(out_dir, "serving.json")
    with open(json_path, "w") as fh:
        json.dump({
            "arch": ARCH, "traffic": dataclasses.asdict(tcfg),
            "continuous": rc.to_dict(), "static": rs.to_dict(),
            "throughput_gain": gain, "all_completed": complete,
            "token_streams_identical": same_tokens,
            "paged_probe_max_abs_diff": probe,
        }, fh, indent=2)
    md_path = os.path.join(out_dir, "serving.md")
    with open(md_path, "w") as fh:
        fh.write(_markdown(tcfg, reports, probe))

    rows = []
    for name in ("continuous", "static"):
        r = reports[name]
        rows += [
            (f"{name}_throughput_tok_per_step",
             f"{r.throughput_tok_per_step:.3f}", None),
            (f"{name}_latency_p50_steps", f"{r.latency_p50:.1f}", None),
            (f"{name}_latency_p99_steps", f"{r.latency_p99:.1f}", None),
            (f"{name}_occupancy", f"{r.occupancy:.3f}", None),
            (f"{name}_energy_per_token_uj",
             f"{r.energy_per_token_uj:.4f}", None),
        ]
    rows += [
        ("continuous_vs_static_throughput", f"{gain:.2f}x", None),
        ("all_requests_completed", str(complete), None),
        ("token_streams_identical", str(same_tokens), None),
        ("paged_vs_contiguous_max_abs_diff", f"{probe:.3e}", None),
        ("json", json_path, None),
        ("markdown", md_path, None),
    ]
    err = 0.0
    if rc.throughput_tok_per_step < rs.throughput_tok_per_step:
        err += 1.0  # continuous batching must not lose to static batching
    if not complete:
        err += 1.0  # every request must be served to completion
    if probe != 0.0:
        err += 1.0  # paged decode must match the contiguous path bit-for-bit
    return rows, err
