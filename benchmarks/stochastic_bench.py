"""Rate-coded stochastic uGEMM benchmark: accuracy-vs-cycles frontier plus a
planner run where stream length is the planned knob.

Two artifacts, both landing in ``reports/stochastic.{json,md}``:

* **frontier** — measured relative RMSE of ``ugemm_stochastic`` against the
  exact uGEMM oracle over stream length L, with the analytic expected/tail
  envelope from ``repro.analysis.ranges`` beside each point.  Cycles per
  value are L itself (a rate-coded MAC consumes one bit per cycle), so the
  curve IS the accuracy/energy trade the planner shops from.
* **plan** — ``eval.planner.build_plan`` over a scaled llama3 smoke config
  with ``ugemm_stochastic`` admitted at L in (16, 32, 64, 128) next to the
  exact designs.

Derived error (the ``benchmarks.run`` quality column) is 0.0 when every
acceptance property holds, +1.0 per violation:

* the measured RMSE curve is monotone non-increasing in stream length;
* every measured point sits under the calibrated analytic *tail* bound;
* the plan assigns ≥ 1 site a stochastic engine with L < 2^bits (a genuine
  short-stream win, not the exact-convergence point);
* the planned dynamic energy beats EVERY guard-feasible exact uniform
  baseline (not just the best one);
* the emitted plan lints clean under ``repro.analysis.plan_lint``.
"""

from __future__ import annotations

import json
import os

# The stock llama3 smoke config (d_model=64, d_ff=192) keeps the common dims
# too small for rate coding to pay: at k<=256 the exact tubGEMM@4's
# sparsity-scaled cycles undercut any guard-surviving stream length.  Scaling
# the hidden sizes up (still CPU-smoke cheap) pushes k to 512/1024 where
# tubGEMM's K-proportional cycles grow linearly but the stochastic engine's
# stay fixed at L — the regime the paper's unary-vs-binary crossover lives in.
ARCH = "llama3-8b"
D_MODEL = 512
D_FF = 1024
UNIT_N = 64
NUM_UNITS = 64
BATCH = 4
BITS = 8
CURVE_LENS = (16, 32, 64, 128, 256)
PLAN_LENS = (16, 32, 64, 128)
CURVE_K = 256


def stochastic(out_dir: str | None = None):
    """Returns (rows, err) per the benchmarks.run contract; writes the files."""
    import jax

    from repro import configs
    from repro.analysis import findings as findings_lib
    from repro.analysis import plan_lint
    from repro.analysis import ranges
    from repro.eval import planner as planner_lib
    from repro.models import model as model_lib
    from repro.stochastic import error as stoch_error

    out_dir = out_dir or os.environ.get("PLAN_OUT", "reports")
    os.makedirs(out_dir, exist_ok=True)
    rows = []
    err = 0.0

    # --- accuracy-vs-cycles frontier on seeded calibration operands --------
    curve = stoch_error.rmse_curve(BITS, CURVE_LENS, m=8, k=CURVE_K, n=32,
                                   seed=0)
    frontier = []
    prev = None
    for L, rmse in curve:
        bound = ranges.stochastic_error_bound(BITS, L)
        frontier.append({"stream_len": L, "cycles": L, "rel_rmse": rmse,
                         "expected_bound": bound.expected,
                         "tail_bound": bound.tail})
        rows.append((f"rmse_L{L}",
                     f"relRMSE={rmse:.4f} cycles={L} "
                     f"(envelope exp={bound.expected:.4f} "
                     f"tail={bound.tail:.4f})", None))
        if prev is not None and rmse > prev + 1e-12:
            err += 1.0  # frontier not monotone non-increasing in L
        if rmse > bound.tail:
            err += 1.0  # measurement escaped the calibrated tail envelope
        prev = rmse

    # --- planner run with stream length as the planned knob ----------------
    cfg = configs.get_smoke_config(ARCH).replace(d_model=D_MODEL, d_ff=D_FF)
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    sites = planner_lib.discover_sites(cfg, params, batch=BATCH)
    designs = planner_lib.DEFAULT_DESIGNS + (planner_lib.STOCHASTIC_DESIGN,)
    plan = planner_lib.build_plan(cfg, params, batch=BATCH, unit_n=UNIT_N,
                                  num_units=NUM_UNITS, sites=sites,
                                  designs=designs, stream_lens=PLAN_LENS)

    stochastic_sites = [e for e in plan.sites
                        if e.design == planner_lib.STOCHASTIC_DESIGN]
    short_stream = [e for e in stochastic_sites
                    if e.stream_len and e.stream_len < 2 ** e.bits]
    for e in plan.sites:
        rows.append((f"site_{e.pattern}",
                     f"{e.engine_label} b_spa={e.bit_blockmax:.3f} "
                     f"dynE={e.dyn_energy_uj:.4f}uJ relMSE={e.rel_mse:.4f}",
                     None))
    meta = plan.metadata()
    totals = meta["totals"]
    planned = totals["planned"]["dyn_energy_uj"]
    # metadata()["uniform"] already keeps only guard-feasible baselines —
    # and the planner's uniform candidates are exact designs only, so each
    # one is an exact uniform the stochastic-bearing plan must undercut.
    feasible = {name: tot["dyn_energy_uj"]
                for name, tot in totals["uniform"].items()}
    rows.append(("planned_dyn_energy_uj", f"{planned:.4f}", None))
    for name in sorted(feasible):
        rows.append((f"uniform_{name}", f"{feasible[name]:.4f}uJ", None))
    rows.append(("short_stream_sites",
                 ", ".join(e.engine_label for e in short_stream) or "none",
                 None))
    if not short_stream:
        err += 1.0  # no site won on a genuinely short stream
    if not feasible or any(planned > tot * (1 + 1e-9)
                           for tot in feasible.values()):
        err += 1.0  # plan failed to beat every feasible exact uniform
    found = plan_lint.lint_plan(plan, site_names=[s.name for s in sites])
    rows.append(("analysis", findings_lib.verdict_line(found), None))
    err += float(len(findings_lib.errors(found)))

    # --- reports ------------------------------------------------------------
    json_path = os.path.join(out_dir, "stochastic.json")
    with open(json_path, "w") as fh:
        json.dump({"bits": BITS, "frontier": frontier,
                   "plan": json.loads(plan.to_json()),
                   "uniform_feasible_uj": feasible,
                   "planned_dyn_energy_uj": planned,
                   "short_stream_sites": [e.engine_label
                                          for e in short_stream]},
                  fh, indent=2)
    md_path = os.path.join(out_dir, "stochastic.md")
    with open(md_path, "w") as fh:
        fh.write("# Rate-coded stochastic uGEMM\n\n")
        fh.write("## Accuracy vs cycles (bits=%d, k=%d, seed 0)\n\n"
                 % (BITS, CURVE_K))
        fh.write("| L (= cycles) | rel RMSE | expected bound | tail bound |\n")
        fh.write("|---:|---:|---:|---:|\n")
        for p in frontier:
            fh.write("| %d | %.4f | %.4f | %.4f |\n"
                     % (p["stream_len"], p["rel_rmse"],
                        p["expected_bound"], p["tail_bound"]))
        fh.write("\n## Planned assignment (stream length as the knob)\n\n")
        fh.write(planner_lib.to_markdown(plan))
    rows += [("json", json_path, None), ("markdown", md_path, None)]
    return rows, err
