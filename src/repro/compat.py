"""Cross-version jax API shims.

The repo targets the modern ``jax.shard_map`` API (keyword ``check_vma``),
but the pinned CI toolchain ships jax 0.4.37 where shard_map still lives in
``jax.experimental.shard_map`` and the replication-check keyword is spelled
``check_rep``.  Every shard_map call site in the codebase goes through
:func:`shard_map` below so the rest of the code can use one spelling.
"""

from __future__ import annotations

from typing import Any, Callable

import jax

__all__ = ["shard_map", "HAS_NATIVE_SHARD_MAP"]

HAS_NATIVE_SHARD_MAP = hasattr(jax, "shard_map")

if not HAS_NATIVE_SHARD_MAP:
    from jax.experimental.shard_map import shard_map as _experimental_shard_map


def shard_map(f: Callable, *, mesh: Any, in_specs: Any, out_specs: Any,
              check_vma: bool = True, **kwargs: Any) -> Callable:
    """``jax.shard_map`` on new jax; the experimental fallback on 0.4.x.

    ``check_vma`` is the modern name of 0.4.x's ``check_rep`` — both toggle
    the same per-output replication check, so it is translated, not dropped.
    """
    if HAS_NATIVE_SHARD_MAP:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma,
                             **kwargs)
    return _experimental_shard_map(f, mesh=mesh, in_specs=in_specs,
                                   out_specs=out_specs, check_rep=check_vma,
                                   **kwargs)
