"""Fault tolerance runtime: retries, straggler watchdog, elastic re-meshing.

At thousand-node scale three failure classes dominate; each has a handler:

* **transient step failure** (preemption, flaky ICI, data hiccup) —
  ``retry_with_backoff`` re-executes the step; combined with donated-buffer
  checkpoints, a failed step never corrupts state.
* **stragglers** (slow host, thermal throttle) — ``StragglerWatchdog`` keeps a
  robust running median of step times and flags outliers; the training loop
  responds by checkpointing and (optionally) excluding the slow host via
  elastic re-mesh.  On single-process CPU we detect and log (tests inject
  synthetic delays).
* **node loss** (hard failure) — auto-resume from the latest COMPLETE
  checkpoint onto a *smaller* mesh: ``plan_mesh`` picks the largest valid
  (data, model) factorization of the surviving chip count and
  ``checkpoint.restore(shardings=...)`` re-lays-out the global arrays
  (elastic scaling).
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable

log = logging.getLogger("repro.runtime")

__all__ = ["retry_with_backoff", "StragglerWatchdog", "plan_mesh", "StepTimer"]


def retry_with_backoff(fn: Callable, retries: int = 3, base_delay: float = 0.5,
                       on_retry: Callable[[int, Exception], None] | None = None):
    """Run ``fn()``; on exception retry with exponential backoff."""
    attempt = 0
    while True:
        try:
            return fn()
        except Exception as e:  # noqa: BLE001 — the point is to survive
            attempt += 1
            if attempt > retries:
                raise
            if on_retry is not None:
                on_retry(attempt, e)
            delay = base_delay * (2 ** (attempt - 1))
            log.warning("step failed (%s); retry %d/%d in %.1fs",
                        e, attempt, retries, delay)
            time.sleep(delay)


@dataclasses.dataclass
class StragglerWatchdog:
    """Flags steps slower than ``threshold`` x the running median."""

    threshold: float = 2.0
    window: int = 64
    warmup: int = 5
    _times: list = dataclasses.field(default_factory=list)
    slow_steps: int = 0

    def observe(self, seconds: float) -> bool:
        """Record a step time; returns True if this step is a straggler."""
        times = self._times
        is_slow = False
        if len(times) >= self.warmup:
            med = sorted(times)[len(times) // 2]
            if seconds > self.threshold * med:
                is_slow = True
                self.slow_steps += 1
                log.warning("straggler: step took %.3fs (median %.3fs)",
                            seconds, med)
        times.append(seconds)
        if len(times) > self.window:
            times.pop(0)
        return is_slow

    @property
    def median(self) -> float | None:
        if not self._times:
            return None
        return sorted(self._times)[len(self._times) // 2]


class StepTimer:
    """Context manager feeding the watchdog."""

    def __init__(self, watchdog: StragglerWatchdog):
        self.watchdog = watchdog
        self.was_slow = False

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.elapsed = time.perf_counter() - self._t0
        self.was_slow = self.watchdog.observe(self.elapsed)
        return False


def plan_mesh(n_chips: int, model_parallel: int | None = None,
              pod_size: int = 256) -> tuple[tuple[int, ...], tuple[str, ...]]:
    """Largest valid mesh for a (possibly degraded) chip count.

    Elastic policy: keep model parallelism fixed (it must divide the model's
    sharded dims), shrink data parallelism; add a 'pod' axis above pod_size.
    """
    if model_parallel is None:
        model_parallel = 16 if n_chips % 16 == 0 and n_chips >= 16 else 1
    usable = (n_chips // model_parallel) * model_parallel
    data = usable // model_parallel
    if usable > pod_size and usable % pod_size == 0:
        pods = usable // pod_size
        data = pod_size // model_parallel
        return (pods, data, model_parallel), ("pod", "data", "model")
    return (data, model_parallel), ("data", "model")
