"""Runtime substrate: fault tolerance, stragglers, elastic re-meshing."""

from repro.runtime.fault import (StepTimer, StragglerWatchdog, plan_mesh,
                                 retry_with_backoff)

__all__ = ["StepTimer", "StragglerWatchdog", "plan_mesh", "retry_with_backoff"]
