"""Pallas TPU kernel: fused unpack-and-contract GEMM over int32-word stores.

The decode-hot companion to :mod:`repro.core.packing`: weights travel
HBM->VMEM as the int32 words ``pack_codes`` emits (16 / 8 / 4 codes per
word at 2 / 4 / 8 bits — a 4–16x cut in weight-side HBM traffic vs the
float leaf) and are sign-extended *inside the tile loop*, right before the
MXU dot.  Neither the dequantized float matrix nor the full int8 code
matrix ever exists in HBM; per K-step only one ``(bk, bn)`` code tile
lives in VMEM.  The dequant epilogue (weight per-channel scales, with the
activations' scale folded in by the caller) runs once per output tile on
the final K step.

Same grid/accumulator scheme as :mod:`repro.kernels.quant_gemm` —
``(M/bm, N/bn, K/bk)`` with K innermost, int32 VMEM accumulator — so the
two kernels are drop-in comparable; the differential suite
(``tests/test_packed.py``) holds this kernel bit-exact against the
materializing reference and against every backend engine's
quantize-then-execute path.

Target: TPU v5e-class MXU; validated under ``interpret=True`` on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import packing
from repro.kernels.quant_gemm import _acc_scratch, _pad_to

__all__ = ["packed_gemm_kernel", "packed_gemm", "packed_matmul",
           "unpack_words", "DEFAULT_BLOCK"]

DEFAULT_BLOCK = (128, 128, 128)  # (bm, bn, bk) — MXU-aligned


def unpack_words(words: jax.Array, bits: int) -> jax.Array:
    """Sign-extend a ``(words, n)`` int32-word tile to ``(words*cpw, n)``
    int32 codes (lane order per ``packing.pack_codes``: low lanes first).

    Static Python-int shift amounts only — this is the in-kernel unpack,
    traced inside ``pl.pallas_call``.
    """
    cpw = packing.codes_per_word(bits)
    parts = [jnp.left_shift(words, 32 - bits * (j + 1)) >> (32 - bits)
             for j in range(cpw)]
    stacked = jnp.stack(parts, axis=1)            # (words, cpw, n)
    return stacked.reshape(words.shape[0] * cpw, words.shape[1])


def packed_gemm_kernel(x_ref, w_ref, s_ref, o_ref, acc_ref, *,
                      bits: int, n_k: int, fuse_dequant: bool):
    """One (bm, bn) output tile; K-step ``pl.program_id(2)``."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.int32)              # (bm, bk)
    w = unpack_words(w_ref[...], bits)            # (bk, bn) int32 codes
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        acc = acc_ref[...]
        if fuse_dequant:
            o_ref[...] = acc.astype(jnp.float32) * s_ref[...]
        else:
            o_ref[...] = acc


@functools.partial(
    jax.jit,
    static_argnames=("bits", "k", "block", "fuse_dequant", "interpret"))
def packed_gemm(x: jax.Array, w_words: jax.Array,
                scales: jax.Array | None = None, *, bits: int, k: int,
                block: tuple[int, int, int] = DEFAULT_BLOCK,
                fuse_dequant: bool = False,
                interpret: bool = False) -> jax.Array:
    """``x:(M,K) int8 @ unpack(w_words):(K,N) -> (M,N)`` int32 or fp32.

    ``w_words`` is the ``(ceil(K/cpw), N)`` int32 store ``pack_codes``
    emits for a (K, N) code matrix; ``k`` is the logical K (the padding
    lanes of the last word hold zero codes, which contract to exact
    zeros).  ``scales`` is (1, N) fp32, required when ``fuse_dequant``.
    """
    if x.dtype != jnp.int8:
        raise TypeError(f"packed_gemm wants int8 activations, got {x.dtype}")
    if w_words.dtype != jnp.int32:
        raise TypeError(
            f"packed_gemm wants an int32 word store, got {w_words.dtype}")
    cpw = packing.codes_per_word(bits)
    bm, bn, bk = block
    if bk % cpw:
        raise ValueError(f"bk={bk} must be a multiple of the {cpw} codes "
                         f"per word at {bits}-bit")
    m, kdim = x.shape
    n = w_words.shape[1]
    if kdim != k:
        raise ValueError(f"K mismatch: x has K={kdim}, store holds k={k}")
    if w_words.shape[0] != -(-k // cpw):
        raise ValueError(
            f"word-count mismatch: store has {w_words.shape[0]} words, "
            f"k={k} at {bits}-bit needs {-(-k // cpw)}")

    # bk is word-aligned (bk % cpw == 0), so padding K to bk also covers
    # the store's word-aligned length; the extra rows are zero codes.
    xp = _pad_to(_pad_to(x, 0, bm), 1, bk)
    wp = _pad_to(_pad_to(w_words, 0, bk // cpw), 1, bn)
    if scales is None:
        scales = jnp.ones((1, n), jnp.float32)
    sp = _pad_to(scales.astype(jnp.float32).reshape(1, n), 1, bn)

    mp, kp = xp.shape
    np_ = wp.shape[1]
    grid = (mp // bm, np_ // bn, kp // bk)

    out = pl.pallas_call(
        functools.partial(packed_gemm_kernel, bits=bits, n_k=grid[2],
                          fuse_dequant=fuse_dequant),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk // cpw, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct(
            (mp, np_), jnp.float32 if fuse_dequant else jnp.int32),
        scratch_shapes=[_acc_scratch(bm, bn)],
        interpret=interpret,
    )(xp, wp, sp)
    return out[:m, :n]


def packed_matmul(x: jax.Array, store: "packing.PackedQuantized", *,
                  block: tuple[int, int, int] = DEFAULT_BLOCK,
                  fuse_dequant: bool = True,
                  interpret: bool = False) -> jax.Array:
    """Contract int8 activation codes against a :class:`PackedQuantized`
    store without leaving the word domain.

    ``store`` must be a flat (non-grid, unstacked) 2-D-logical store —
    grid stores shard through ``GridBackend.execute``; stacked stores are
    sliced by the caller's scan.  With ``fuse_dequant`` the weight's
    per-channel scales apply in the epilogue (fold the activation scale
    into the fp32 result, as ``models/common._backend_matmul`` does).
    """
    if not packing.is_packed(store):
        raise TypeError(f"packed_matmul wants a PackedQuantized store, "
                        f"got {type(store).__name__}")
    if store.grid_x != 1:
        raise ValueError("grid stores execute through GridBackend; "
                         "packed_matmul wants a flat (grid_x=1) store")
    if store.packed.ndim != 2:
        raise ValueError(f"packed_matmul wants an unstacked store, got "
                         f"packed shape {store.packed.shape}")
    scales = store.scale.reshape(1, -1) if fuse_dequant else None
    return packed_gemm(x, store.packed, scales, bits=store.bits, k=store.k,
                       block=block, fuse_dequant=fuse_dequant,
                       interpret=interpret)
