"""Public jit'd wrappers around the Pallas kernels.

``quantized_matmul`` is the end-to-end float -> float op the modeling layer
calls: quantize activations per-tensor, run the packed integer kernel, apply
the folded dequant scales.  ``interpret`` defaults to True off-TPU so the same
code path runs in this CPU container and compiles natively on TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.quantization import Quantized, quantize, vmax
from repro.kernels import bitsparsity as _bs
from repro.kernels import quant_gemm as _qg
from repro.kernels import unary_gemm as _ug

__all__ = [
    "on_tpu",
    "pack_values",
    "quantized_matmul",
    "int_matmul",
    "tub_matmul",
    "tu_matmul",
    "bit_sparsity_stats",
]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret_default() -> bool:
    return not on_tpu()


def pack_values(values: jax.Array, bits: int, axis: int = 0) -> jax.Array:
    """Pack w-bit signed codes (int8 container) 8//w-per-byte along ``axis``."""
    if bits == 8:
        return values.astype(jnp.int8)
    pack = 8 // bits
    if values.shape[axis] % pack:
        raise ValueError(f"axis {axis} (len {values.shape[axis]}) not divisible by {pack}")
    v = jnp.moveaxis(values.astype(jnp.int32), axis, 0)
    mask = (1 << bits) - 1
    v = v.reshape(v.shape[0] // pack, pack, *v.shape[1:])
    byte = jnp.zeros(v.shape[:1] + v.shape[2:], jnp.int32)
    for i in range(pack):
        byte = byte | ((v[:, i] & mask) << (i * bits))
    # int8 container: values >= 128 wrap to negative — intentional.
    byte = ((byte + 128) % 256 - 128).astype(jnp.int8)
    return jnp.moveaxis(byte, 0, axis)


@functools.partial(jax.jit, static_argnames=("bits", "block", "interpret"))
def int_matmul(x_q: jax.Array, w_packed: jax.Array, *, bits: int = 8,
               block=_qg.DEFAULT_BLOCK, interpret: bool | None = None) -> jax.Array:
    """Raw integer GEMM on the kernel (int8 x packed-w -> int32)."""
    interp = _interpret_default() if interpret is None else interpret
    return _qg.quant_gemm(x_q, w_packed, None, bits=bits, block=block,
                          fuse_dequant=False, interpret=interp)


@functools.partial(jax.jit, static_argnames=("bits", "block", "interpret"))
def tub_matmul(a_q: jax.Array, b_q: jax.Array, *, bits: int = 8,
               block=_ug.DEFAULT_BLOCK, interpret: bool | None = None):
    """tubGEMM slot-loop GEMM on the Pallas kernel.

    ``a_q`` is (M, K) w-bit codes, ``b_q`` (K, N) int8.  Returns
    ``((M, N) int32, wc_cycles)`` — bit-identical to binary GEMM, scheduled
    as the paper's 2-unary unit.
    """
    interp = _interpret_default() if interpret is None else interpret
    return _ug.tub_gemm(a_q, b_q, bits=bits, block=block, interpret=interp)


@functools.partial(jax.jit, static_argnames=("bits", "block", "interpret"))
def tu_matmul(a_q: jax.Array, b_q: jax.Array, *, bits: int = 8,
              block=_ug.DEFAULT_BLOCK, interpret: bool | None = None):
    """tuGEMM temporal slot-loop GEMM on the Pallas kernel.

    ``a_q`` is (M, K) w-bit codes, ``b_q`` (K, N) int8.  Returns
    ``((M, N) int32, wc_cycles)`` — bit-identical to binary GEMM, scheduled
    as the paper's fully-temporal unit (``K * (2^(w-1))^2`` cycles).
    """
    interp = _interpret_default() if interpret is None else interpret
    return _ug.tu_gemm(a_q, b_q, bits=bits, block=block, interpret=interp)


@functools.partial(jax.jit, static_argnames=("bits", "act_bits", "block", "interpret"))
def quantized_matmul(x: jax.Array, w_q: Quantized, *, bits: int | None = None,
                     act_bits: int = 8, block=_qg.DEFAULT_BLOCK,
                     interpret: bool | None = None) -> jax.Array:
    """float x (quantized weight) -> float via the packed integer kernel.

    ``w_q.values`` is (K, N) int8 codes with per-channel ``scale`` (1, N) or
    broadcastable; activations are quantized per-tensor to ``act_bits``.
    """
    bits = w_q.bits if bits is None else bits
    interp = _interpret_default() if interpret is None else interpret
    orig_shape = x.shape
    x2 = x.reshape(-1, orig_shape[-1])
    xq = quantize(x2, bits=act_bits, per_channel=False)
    w_packed = pack_values(w_q.values, bits, axis=0)
    scales = (w_q.scale.reshape(1, -1) * xq.scale.reshape(1, 1)).astype(jnp.float32)
    out = _qg.quant_gemm(xq.values, w_packed, scales, bits=bits, block=block,
                         fuse_dequant=True, interpret=interp)
    return out.reshape(*orig_shape[:-1], out.shape[-1]).astype(x.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "tile", "interpret"))
def bit_sparsity_stats(q: jax.Array, *, bits: int, tile: int = 32,
                       interpret: bool | None = None):
    """(word_sparsity, bit_sparsity_blockmax) from the reduction kernel."""
    interp = _interpret_default() if interpret is None else interpret
    if q.ndim != 2:
        q = q.reshape(-1, q.shape[-1])
    m, n = q.shape
    maxes, zeros = _bs.block_stats(q, tile=tile, interpret=interp)
    pad_rows = maxes.shape[0] * tile - m
    pad_cols = maxes.shape[1] * tile - n
    total_pad = pad_rows * n + pad_cols * m + pad_rows * pad_cols
    word = (jnp.sum(zeros) - total_pad) / (m * n)
    bit_blockmax = 1.0 - jnp.mean(maxes.astype(jnp.float32)) / (2 ** (bits - 1))
    return word.astype(jnp.float32), bit_blockmax.astype(jnp.float32)
