"""Fused block-table paged-decode GQA attention (flash-style page walk).

The gather path (:mod:`repro.kernels.paged_attention`) materializes the
whole padded per-request KV view ``(B, max_blocks * page_size, KVH, hd)``
from the page pool every decode step — then ``_repeat_kv``-expands it
H/KVH-fold before ``naive_attention`` — O(max_blocks · page_size · H) HBM
traffic per request per layer regardless of how much history actually
exists.  This module fuses the page walk into the attention kernel:

* each request's block table is walked **page by page** with a flash-style
  online softmax (running max + denominator, fp32 accumulators), so no
  gathered KV copy ever exists;
* GQA is handled natively by grouping the H query heads per KV head
  (``q.reshape(KVH, H // KVH, hd)``) — the KV pages are contracted as
  stored, never repeated;
* per-request valid lengths are masked in-kernel (same ``-1e30`` fill the
  gather path uses, so masked weights underflow to exact fp32 zeros);
* pages past ``ceil(len / page_size)`` are skipped: the Pallas kernel
  clamps the block-table index map to the last valid page (identical
  consecutive block indices elide the copy) and gates the compute with
  ``pl.when``; the XLA lowering stops its ``lax.while_loop`` at the batch
  max — traffic drops to O(len · KVH) per request per layer.

Two interchangeable lowerings sit behind
:func:`fused_paged_decode_attention`:

* ``impl="pallas"`` — the Pallas TPU kernel (scalar-prefetched block
  table + lengths drive the page DMA), validated under ``interpret=True``
  on CPU like every kernel in this package;
* ``impl="xla"`` — a hybrid lowering as plain jax ops: the K/score side
  keeps the page walk (a jittable ``lax.while_loop`` over page *chunks*
  with a batch-wide dynamic early exit, so K pages past the batch's
  history are never read), while the softmax and the weighted-V product
  run at the gather oracle's exact widths and dtype-cast points (V read
  through one grouped KVH-width gather, never H-repeated).  This is the
  serving default on hosts without a TPU (the tier-1 CPU suite), where
  emulating the grid would cost more than it saves, and its oracle-shaped
  numerics are what keep low-bit per-row-quantized token streams
  identical to the gather path.

Online softmax (pallas) re-associates the reduction, and the XLA
lowering's chunked score writes can still reassociate f32 reductions, so
fused outputs are NOT guaranteed bit-exact against the gather oracle —
the contract is a gated max |Δ| (``tests/test_paged_fused.py``,
``repro.serving.fused_vs_gather_probe``) plus exact parity of the
sampled token streams on seeded traffic traces.

Target: TPU v5e-class MXU; validated under ``interpret=True`` on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["fused_paged_decode_attention", "fused_decode_bytes_moved",
           "gather_decode_bytes_moved", "DEFAULT_PAGES_PER_CHUNK"]

#: pages gathered per ``lax.while_loop`` iteration of the XLA lowering —
#: large enough that the per-iteration dispatch amortizes, small enough
#: that the early exit still tracks the batch's actual history length.
DEFAULT_PAGES_PER_CHUNK = 8

_MASK = -1e30  # same fill as models.attention.naive_attention


def _check_shapes(q, pool_k, pool_v, block_table, num_heads):
    if q.ndim != 4 or q.shape[1] != 1:
        raise ValueError(f"q must be (B, 1, H, hd), got {q.shape}")
    if pool_k.shape != pool_v.shape or pool_k.ndim != 4:
        raise ValueError(f"pools must share (P, page, KVH, hd): "
                         f"{pool_k.shape} vs {pool_v.shape}")
    kvh = pool_k.shape[2]
    if q.shape[2] != num_heads or num_heads % kvh:
        raise ValueError(f"num_heads {num_heads} must match q heads "
                         f"{q.shape[2]} and divide by KV heads {kvh}")
    if block_table.shape[0] != q.shape[0]:
        raise ValueError(f"block_table batch {block_table.shape[0]} != "
                         f"q batch {q.shape[0]}")


# ---------------------------------------------------------------------------
# Pallas kernel: grid (B, max_blocks), block table + lengths scalar-prefetched
# ---------------------------------------------------------------------------

def _fused_decode_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, page_size: int,
                         num_kv_heads: int):  # analysis: allow-float-accumulation (fp32 online-softmax accumulators are the kernel's contract)
    """One (request, page) grid step of the online-softmax page walk."""
    b, j = pl.program_id(0), pl.program_id(1)
    n_pages = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    valid = len_ref[b]
    n_blocks = (valid + page_size - 1) // page_size

    @pl.when(j < n_blocks)
    def _page():  # analysis: allow-float-accumulation (fp32 softmax accumulators)
        q = q_ref[0, 0].astype(jnp.float32)              # (H, hd)
        k = k_ref[0].astype(jnp.float32)                 # (page, KVH, hd)
        v = v_ref[0].astype(jnp.float32)
        h, hd = q.shape
        g = h // num_kv_heads
        qg = q.reshape(num_kv_heads, g, hd)
        s = jnp.einsum("kgd,tkd->kgt", qg, k) / jnp.sqrt(jnp.float32(hd))
        tok = j * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (1, 1, page_size), 2)
        s = jnp.where(tok < valid, s, _MASK)             # (KVH, G, page)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_ref[...] = alpha * l_ref[...] + p.sum(axis=-1)
        acc_ref[...] = (alpha[..., None] * acc_ref[...]
                        + jnp.einsum("kgt,tkd->kgd", p, v))
        m_ref[...] = m_new

    @pl.when(j == n_pages - 1)
    def _done():
        out = acc_ref[...] / l_ref[...][..., None]       # (KVH, G, hd)
        o_ref[0, 0] = out.reshape(o_ref.shape[2:]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("num_heads", "interpret"))
def _fused_decode_pallas(q, pool_k, pool_v, block_table, kv_valid_len, *,
                         num_heads: int, interpret: bool = False):
    batch, _, h, hd = q.shape
    _, page_size, kvh, _ = pool_k.shape
    max_blocks = block_table.shape[1]
    g = h // kvh

    def _page_index(b, j, bt_ref, len_ref):
        # clamp past-the-end steps to the last live page: consecutive
        # identical block indices elide the DMA, so skipped pages cost no
        # HBM traffic (their compute is gated off by pl.when above)
        n_blocks = (len_ref[b] + page_size - 1) // page_size
        return (bt_ref[b, jnp.minimum(j, n_blocks - 1)], 0, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(batch, max_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, h, hd), lambda b, j, bt, ln: (b, 0, 0, 0)),
            pl.BlockSpec((1, page_size, kvh, hd), _page_index),
            pl.BlockSpec((1, page_size, kvh, hd), _page_index),
        ],
        out_specs=pl.BlockSpec((1, 1, h, hd),
                               lambda b, j, bt, ln: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((kvh, g), jnp.float32),      # running max
            pltpu.VMEM((kvh, g), jnp.float32),      # running denominator
            pltpu.VMEM((kvh, g, hd), jnp.float32),  # fp32 out accumulator
        ],
    )
    kernel = functools.partial(_fused_decode_kernel, page_size=page_size,
                               num_kv_heads=kvh)
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((batch, 1, h, hd), q.dtype),
        interpret=interpret,
    )(jnp.asarray(block_table, jnp.int32),
      jnp.asarray(kv_valid_len, jnp.int32), q, pool_k, pool_v)


# ---------------------------------------------------------------------------
# XLA lowering: lax.while_loop over page chunks, batch-wide early exit
# ---------------------------------------------------------------------------

def _fused_decode_xla(q, pool_k, pool_v, block_table, kv_valid_len, *,  # analysis: allow-float-accumulation (fp32 softmax, dtype schedule mirrors the gather oracle)
                      num_heads: int,
                      pages_per_chunk: int = DEFAULT_PAGES_PER_CHUNK):
    """K-side page walk + oracle-shaped softmax, as plain jax ops.

    Scores are computed page-chunk by page-chunk through the block table
    (a ``lax.while_loop`` that stops at the batch's live-page high-water
    mark — K pages past any request's history are never read) into a
    full-width f32 buffer initialized to the mask fill.  The softmax and
    the weighted-V contraction then run at the oracle's exact widths and
    dtypes — same einsum operand dtypes, same f32 cast points, same
    ``w.astype(v.dtype)`` rounding before the V product — so every
    elementwise op matches ``paged_decode_attention`` bit-for-bit and only
    f32 reduction association can differ.  That is what keeps the sampled
    token streams identical to the gather path on the seeded traffic
    traces even under low-bit per-row activation quantization, where any
    systematic dtype mismatch gets amplified into argmax flips.

    V pages are read through one grouped (KVH-width, never H-repeated)
    gather so the contraction reduces in the oracle's order; the full
    O(len·KVH) two-sided walk is the Pallas kernel's job.
    """
    batch, _, h, hd = q.shape
    _, page_size, kvh, _ = pool_k.shape
    max_blocks = block_table.shape[1]
    g = h // kvh
    ppc = max(1, min(pages_per_chunk, max_blocks))
    n_chunks = -(-max_blocks // ppc)
    bt = jnp.pad(jnp.asarray(block_table, jnp.int32),
                 ((0, 0), (0, n_chunks * ppc - max_blocks)))  # trash page 0
    valid = jnp.asarray(kv_valid_len, jnp.int32)
    qg = q[:, 0].reshape(batch, kvh, g, hd)
    t_chunk = ppc * page_size
    width = max_blocks * page_size
    # chunks that contain at least one live token for some request
    stop = -(-jnp.max(-(-valid // page_size)) // ppc)

    def cond(state):
        return state[0] < stop

    def body(state):
        c, scores = state
        cols = jax.lax.dynamic_slice(bt, (0, c * ppc), (batch, ppc))
        k = pool_k[cols].astype(q.dtype).reshape(batch, t_chunk, kvh, hd)
        s = jnp.einsum("bkgd,btkd->bkgt", qg, k).astype(jnp.float32)
        s = s / jnp.sqrt(jnp.float32(hd))
        tok = c * t_chunk + jnp.arange(t_chunk, dtype=jnp.int32)
        s = jnp.where(tok[None, None, None, :] < valid[:, None, None, None],
                      s, _MASK)
        scores = jax.lax.dynamic_update_slice(scores, s, (0, 0, 0, c * t_chunk))
        return c + 1, scores

    init = (jnp.int32(0),
            jnp.full((batch, kvh, g, n_chunks * t_chunk), _MASK, jnp.float32))
    _, scores = jax.lax.while_loop(cond, body, init)
    w = jax.nn.softmax(scores[..., :width], axis=-1)     # (B, KVH, G, S)
    vc = pool_v[block_table].reshape(batch, width, kvh, hd).astype(q.dtype)
    out = jnp.einsum("bkgt,btkd->bkgd", w.astype(vc.dtype), vc)
    return out.reshape(batch, h, hd)[:, None].astype(q.dtype)


# ---------------------------------------------------------------------------
# dispatcher + modeled HBM traffic
# ---------------------------------------------------------------------------

def fused_paged_decode_attention(q, pool_k, pool_v, block_table,
                                 kv_valid_len, *, num_heads: int,
                                 impl: str = "auto", interpret: bool = False,
                                 pages_per_chunk: int = DEFAULT_PAGES_PER_CHUNK):
    """Single-token fused GQA decode attention over the paged KV pool.

    Drop-in for :func:`repro.kernels.paged_attention.paged_decode_attention`
    (same signature and masking semantics) minus its materialization:
    ``q`` (B, 1, H, hd); pools (P, page_size, KVH, hd); ``block_table``
    (B, max_blocks) int32 page ids; ``kv_valid_len`` (B,) valid history
    *including* the token written this step (must be >= 1 per request —
    evicted slots point at the trash page with length 0, so the engine
    passes ``lengths + 1``).

    ``impl``: ``"pallas"`` (the TPU kernel; pass ``interpret=True`` on
    CPU), ``"xla"`` (the while-loop lowering), or ``"auto"`` — pallas iff
    the default jax backend is a TPU.
    """
    _check_shapes(q, pool_k, pool_v, block_table, num_heads)
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "pallas":
        return _fused_decode_pallas(q, pool_k, pool_v, block_table,
                                    kv_valid_len, num_heads=num_heads,
                                    interpret=interpret)
    if impl == "xla":
        return _fused_decode_xla(q, pool_k, pool_v, block_table,
                                 kv_valid_len, num_heads=num_heads,
                                 pages_per_chunk=pages_per_chunk)
    raise ValueError(f"impl must be 'pallas', 'xla' or 'auto', got {impl!r}")


def gather_decode_bytes_moved(*, batch: int, max_blocks: int, page_size: int,
                              num_kv_heads: int, num_heads: int,
                              head_dim: int, dtype_bytes: int = 4) -> int:
    """Modeled KV bytes one gather-path decode step moves per layer.

    ``gather_kv`` reads every block-table page (live or trash) for K and V
    and ``_repeat_kv`` expands the gathered view to all H query heads, so
    the traffic scales with the pool's padded width and the *query* head
    count: O(max_blocks · page_size · H).
    """
    return (2 * batch * max_blocks * page_size * num_heads * head_dim
            * dtype_bytes)


def fused_decode_bytes_moved(lengths, *, page_size: int, num_kv_heads: int,
                             head_dim: int, dtype_bytes: int = 4) -> int:
    """Modeled KV bytes one fused decode step moves per layer.

    The page walk reads only ``ceil(len / page_size)`` pages per request,
    at KV-head width (queries are grouped, pages never repeated):
    O(len · KVH) per request.
    """
    pages = sum(-(-int(n) // page_size) for n in lengths)
    return 2 * pages * page_size * num_kv_heads * head_dim * dtype_bytes
