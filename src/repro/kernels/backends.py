"""Register the Pallas unary-GEMM kernels as executable designs.

The ``gemm_sims`` registry dispatches the four *simulated* paper designs; the
Pallas kernels are the same tuGEMM/tubGEMM schedules executed on-device (or
under ``interpret=True`` on CPU).  :func:`register_kernel_backends` adds them
as ``tugemm_pallas`` / ``tubgemm_pallas`` so anything that drives the
registry — ``gemm``, ``stream_gemm``, the sweet-spot explorer's kernel
cross-check — can run the kernels through the exact same dispatch surface and
compare their cycle reports against ``wc_cycles`` of the simulator siblings.

Registration is deliberately *not* done at import time: consumers that
snapshot ``gemm_sims.DESIGNS`` at import (the paper-table benchmarks, the
Fig. 2 slope reproduction) iterate exactly the four calibrated designs, and a
kernel mirror has no synthesis data of its own.  Call this explicitly where
kernel execution is wanted.  The mirrors inherit their sibling's latency and
sparsity model (``wc_cycles_fn``, ``dyn_operand_fn``), which is the point:
one cost model, two execution engines.
"""

from __future__ import annotations

import contextlib

from repro.core import gemm_sims

PALLAS_SUFFIX = "_pallas"
#: kernel-backed mirror name -> the simulated design it executes
KERNEL_SIBLINGS = {
    "tugemm" + PALLAS_SUFFIX: "tugemm",
    "tubgemm" + PALLAS_SUFFIX: "tubgemm",
}


def register_kernel_backends(*, block=None, interpret: bool | None = None
                             ) -> tuple[str, ...]:
    """Idempotently register ``tugemm_pallas`` / ``tubgemm_pallas``.

    Args: ``block`` — optional (bm, bn, bk) kernel tile override; ``interpret``
    — force Pallas interpret mode (None = auto: interpret off-TPU).
    Returns: the tuple of registered mirror names.  Safe to call repeatedly
    (re-registers with ``overwrite=True``).
    """
    from repro.kernels import ops

    kernel_fns = {"tugemm": ops.tu_matmul, "tubgemm": ops.tub_matmul}
    kw: dict = {}
    if block is not None:
        kw["block"] = tuple(block)
    if interpret is not None:
        kw["interpret"] = interpret

    for name, sibling in KERNEL_SIBLINGS.items():
        sib = gemm_sims.get_design(sibling)
        fn = kernel_fns[sibling]
        gemm_sims.register_design(
            name,
            # exact path drops the cycle report; stream path keeps (out, cycles)
            exact_fn=(lambda a, b, bits, _fn=fn: _fn(a, b, bits=bits, **kw)[0]),
            stream_fn=(lambda a, b, bits, _fn=fn: _fn(a, b, bits=bits, **kw)),
            wc_cycles_fn=sib.wc_cycles_fn,
            sparsity_aware=sib.sparsity_aware,
            dyn_operand_fn=sib.dyn_operand_fn,
            overwrite=True,
        )
    return tuple(KERNEL_SIBLINGS)


@contextlib.contextmanager
def kernel_backends(**kwargs):
    """Scoped registration: the mirrors exist only inside the ``with`` block.

    Snapshots the design registry, runs :func:`register_kernel_backends`
    (same kwargs), and restores the registry — including any pre-existing
    ``*_pallas`` registration it overwrote — on exit.  Use this for one-shot
    consumers (sweeps, cross-checks) so live-``DESIGNS`` iterators elsewhere
    never observe the uncalibrated mirrors.
    """
    saved = dict(gemm_sims._REGISTRY)
    try:
        yield register_kernel_backends(**kwargs)
    finally:
        gemm_sims._REGISTRY.clear()
        gemm_sims._REGISTRY.update(saved)
        gemm_sims.DESIGNS = tuple(saved)
