"""Registry-side access to the Pallas unary-GEMM kernel mirrors (legacy).

The typed way to run the kernels is ``repro.backends.resolve("tugemm_pallas")``
— pure construction, no global state.  This module keeps the older
*registry-mutating* surface alive for consumers that drive the kernels
through ``gemm_sims`` string dispatch:

* :func:`register_kernel_backends` (deprecated) registers the mirrors as
  ``tugemm_pallas`` / ``tubgemm_pallas`` registry designs.  Registration is
  deliberately *not* done at import time: consumers that snapshot
  ``gemm_sims.DESIGNS`` at import (the paper-table benchmarks, the Fig. 2
  slope reproduction) iterate exactly the four calibrated designs.
* :func:`kernel_backends` scopes a registration to a ``with`` block via
  ``gemm_sims.scoped_registry`` — snapshot/restore through the registry's
  own API, so ``DESIGNS`` stays in sync and nesting/exceptions unwind
  correctly.

The mirrors inherit their sibling's latency and sparsity model
(``wc_cycles_fn``, ``dyn_operand_fn``), which is the point: one cost model,
two execution engines.
"""

from __future__ import annotations

import contextlib
import warnings

from repro.core import gemm_sims

# Canonical mapping lives in repro.backends.registry; re-exported here for
# the existing import sites.
from repro.backends.registry import KERNEL_SIBLINGS, PALLAS_SUFFIX  # noqa: F401

_DEPRECATION_EMITTED = False


# Mutation is legal here: kernel_backends() calls this under its own
# scoped_registry, and register_kernel_backends is the deprecated
# caller-managed surface whose whole point is the unscoped write.
def _register(*, block=None, interpret: bool | None = None) -> tuple[str, ...]:  # analysis: allow-registry-mutation
    from repro.backends.registry import mirror_design_spec

    for name in KERNEL_SIBLINGS:
        spec = mirror_design_spec(name, block=block, interpret=interpret)
        gemm_sims.register_design(
            name,
            exact_fn=spec.exact_fn,
            stream_fn=spec.stream_fn,
            wc_cycles_fn=spec.wc_cycles_fn,
            sparsity_aware=spec.sparsity_aware,
            dyn_operand_fn=spec.dyn_operand_fn,
            exact=spec.exact,
            overwrite=True,
        )
    return tuple(KERNEL_SIBLINGS)


def register_kernel_backends(*, block=None, interpret: bool | None = None
                             ) -> tuple[str, ...]:
    """Deprecated: resolve mirrors with ``repro.backends.resolve`` instead.

    Idempotently registers ``tugemm_pallas`` / ``tubgemm_pallas`` into the
    ``gemm_sims`` registry (re-registers with ``overwrite=True``).  Args:
    ``block`` — optional (bm, bn, bk) kernel tile override; ``interpret`` —
    force Pallas interpret mode (None = auto: interpret off-TPU).  Returns
    the tuple of registered mirror names.
    """
    global _DEPRECATION_EMITTED
    if not _DEPRECATION_EMITTED:
        _DEPRECATION_EMITTED = True
        warnings.warn(
            "register_kernel_backends is deprecated; construct kernel "
            "backends with repro.backends.resolve('tugemm_pallas', ...) — "
            "no registry mutation needed (see docs/BACKENDS.md)",
            DeprecationWarning, stacklevel=2)
    return _register(block=block, interpret=interpret)


@contextlib.contextmanager
def kernel_backends(**kwargs):
    """Scoped registration: the mirrors exist only inside the ``with`` block.

    Snapshot/restore runs through ``gemm_sims.scoped_registry`` — the
    registry's own API — so ``gemm_sims.DESIGNS`` stays in sync with the
    registry contents, scopes nest, and an exception inside the body still
    restores the outer state (including any pre-existing ``*_pallas``
    registration this scope overwrote).
    """
    with gemm_sims.scoped_registry():
        yield _register(**kwargs)
