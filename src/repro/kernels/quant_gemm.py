"""Pallas TPU kernel: tiled low-precision integer GEMM with packed weights.

This is the TPU-native stand-in for the paper's PE array: the same (bm, bn)
output tiling with an inner loop over the common dimension K that the PPA
model prices (``core.ppa.DLAModel``), executed on the MXU with int8 inputs and
int32 accumulation.  INT4 and INT2 weights travel HBM->VMEM packed (2 or 4
values per byte) and are sign-extended in VMEM right before the MXU dot —
halving / quartering the weight-side HBM traffic, which is the memory-roofline
analog of the paper's "low precision cuts data movement" premise.

Grid: (M/bm, N/bn, K/bk) with the K axis innermost ("arbitrary" semantics);
the int32 accumulator lives in a VMEM scratch buffer and the output block is
written once on the final K step, optionally fused with the dequant epilogue
(per-output-channel scale, activations' per-tensor scale folded in).

Target: TPU v5e-class MXU (128x128); block defaults are MXU-aligned multiples
of 128.  Validated under ``interpret=True`` on CPU against ``ref.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["quant_gemm_kernel", "quant_gemm", "unpack_values", "DEFAULT_BLOCK"]

DEFAULT_BLOCK = (128, 128, 128)  # (bm, bn, bk) — MXU-aligned


def unpack_values(packed: jax.Array, bits: int, axis: int = 0) -> jax.Array:
    """Sign-extend packed w-bit integers (int8 container) along ``axis``.

    Packing layout (see ops.pack_values): consecutive values along ``axis``
    share a byte, low nibble/crumb first.
    """
    if bits == 8:
        return packed
    if bits == 4:
        lo = jnp.left_shift(packed, 4) >> 4          # arithmetic shifts sign-extend
        hi = packed >> 4
        parts = [lo, hi]
    elif bits == 2:
        parts = []
        for s in (0, 2, 4, 6):
            crumb = jnp.left_shift(packed, 6 - s) >> 6
            parts.append(crumb)
    else:
        raise ValueError(f"unsupported bits={bits}")
    stacked = jnp.stack(parts, axis=axis + 1)        # (..., packed_dim, P, ...)
    shape = list(packed.shape)
    shape[axis] = shape[axis] * len(parts)
    return stacked.reshape(shape)


def quant_gemm_kernel(x_ref, w_ref, s_ref, o_ref, acc_ref, *,
                      bits: int, n_k: int, fuse_dequant: bool):
    """One (bm, bn) output tile; K-step ``pl.program_id(2)``."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]                                  # (bm, bk) int8
    w = unpack_values(w_ref[...], bits, axis=0)     # (bk, bn) int8
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        acc = acc_ref[...]
        if fuse_dequant:
            o_ref[...] = acc.astype(jnp.float32) * s_ref[...]
        else:
            o_ref[...] = acc


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit,
    static_argnames=("bits", "block", "fuse_dequant", "interpret"))
def quant_gemm(x: jax.Array, w_packed: jax.Array, scales: jax.Array | None = None,
               *, bits: int = 8, block: tuple[int, int, int] = DEFAULT_BLOCK,
               fuse_dequant: bool = False, interpret: bool = False) -> jax.Array:
    """``x:(M,K) int8 @ unpack(w_packed):(K,N) -> (M,N)`` int32 or fp32.

    ``w_packed`` is (K*bits//8, N) int8.  ``scales`` is (1, N) fp32 (weight
    per-channel x activation per-tensor, pre-folded) and is required when
    ``fuse_dequant`` — the kernel then emits fp32.
    """
    if x.dtype != jnp.int8 or w_packed.dtype != jnp.int8:
        raise TypeError("quant_gemm wants int8 operands (packed for w)")
    pack = 8 // bits
    bm, bn, bk = block
    if bk % pack:
        raise ValueError("bk must be divisible by the packing factor")
    m, kdim = x.shape
    n = w_packed.shape[1]
    if w_packed.shape[0] * pack != kdim:
        raise ValueError(
            f"K mismatch: x has K={kdim}, w_packed unpacks to {w_packed.shape[0] * pack}")

    xp = _pad_to(_pad_to(x, 0, bm), 1, bk)
    wp = _pad_to(_pad_to(w_packed, 0, bk // pack), 1, bn)
    if scales is None:
        scales = jnp.ones((1, n), jnp.float32)
    sp = _pad_to(scales.astype(jnp.float32).reshape(1, n), 1, bn)

    mp, kp = xp.shape
    np_ = wp.shape[1]
    grid = (mp // bm, np_ // bn, kp // bk)

    out = pl.pallas_call(
        functools.partial(quant_gemm_kernel, bits=bits, n_k=grid[2],
                          fuse_dequant=fuse_dequant),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk // pack, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct(
            (mp, np_), jnp.float32 if fuse_dequant else jnp.int32),
        scratch_shapes=[_acc_scratch(bm, bn)],
        interpret=interpret,
    )(xp, wp, sp)
    return out[:m, :n]


def _acc_scratch(bm: int, bn: int):
    # pltpu.VMEM when the TPU plugin imports (it also drives interpret mode on
    # CPU); otherwise a backend-neutral MemoryRef.  MemorySpace members are
    # plain enum values, not scratch-shape constructors — the previous
    # ``pl.MemorySpace.ANY((bm, bn), ...)`` fallback raised TypeError.
    try:  # pragma: no cover - TPU path
        from jax.experimental.pallas import tpu as pltpu
        return pltpu.VMEM((bm, bn), jnp.int32)
    except Exception:  # pragma: no cover
        return pl.MemoryRef((bm, bn), jnp.int32, pl.MemorySpace.ANY)
