"""Pallas TPU kernels for the perf-critical compute (quantized GEMM, sparsity).

- quant_gemm   : tiled int8/int4/int2 matmul, VMEM BlockSpec tiling, MXU dot
- unary_gemm   : tubGEMM's 2-unary slot loop as a tiled on-device kernel
- bitsparsity  : per-PE-tile block-max / zero-count reduction (Eq. 1 stats)
- ops          : public jit'd wrappers (pack, quantized_matmul, stats)
- ref          : pure-jnp oracles the tests sweep against
"""

from repro.kernels import bitsparsity, ops, quant_gemm, ref, unary_gemm

__all__ = ["bitsparsity", "ops", "quant_gemm", "ref", "unary_gemm"]
