"""Pallas TPU kernels for the perf-critical compute (quantized GEMM, sparsity).

- quant_gemm   : tiled int8/int4/int2 matmul, VMEM BlockSpec tiling, MXU dot
- unary_gemm   : tuGEMM / tubGEMM slot loops as tiled on-device kernels
- bitsparsity  : per-PE-tile block-max / zero-count reduction (Eq. 1 stats)
- ops          : public jit'd wrappers (pack, quantized_matmul, stats)
- ref          : pure-jnp oracles the tests sweep against
- backends     : registers the kernels as gemm_sims registry designs
"""

from repro.kernels import backends, bitsparsity, ops, quant_gemm, ref, unary_gemm

__all__ = ["backends", "bitsparsity", "ops", "quant_gemm", "ref", "unary_gemm"]
