"""Pallas TPU kernel: per-PE-tile bit-sparsity statistics (paper Eq. 1 input).

For a quantized weight matrix, produces — per ``tile x tile`` sub-block (the
paper's PE-array block, default 32) —

* ``blk_max``  : max |q|   (the value that gates temporal-unary latency), and
* ``blk_zeros``: count of zero words (word sparsity).

One kernel block covers (bm, bn) = (256, 128) elements = an (8, 4) grid of
32x32 sub-blocks, so outputs stay TPU-tileable.  The tiny final reduction
(means over blocks) happens in ``ops.bit_sparsity_stats``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["bitsparsity_kernel", "block_stats"]


def bitsparsity_kernel(q_ref, max_ref, zero_ref, *, tile: int):
    q = q_ref[...].astype(jnp.int32)                     # (bm, bn)
    bm, bn = q.shape
    a = jnp.abs(q).reshape(bm // tile, tile, bn // tile, tile)
    max_ref[...] = jnp.max(a, axis=(1, 3)).astype(jnp.int32)
    z = (q == 0).astype(jnp.int32).reshape(bm // tile, tile, bn // tile, tile)
    zero_ref[...] = jnp.sum(z, axis=(1, 3))


@functools.partial(jax.jit, static_argnames=("tile", "block", "interpret"))
def block_stats(q: jax.Array, *, tile: int = 32,
                block: tuple[int, int] = (256, 128),
                interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """(M, N) int8 codes -> (ceil(M/tile), ceil(N/tile)) block max / zero count.

    Padding cells are zero; callers mask them (``ops.bit_sparsity_stats``).
    """
    if q.ndim != 2:
        q = q.reshape(-1, q.shape[-1])
    bm, bn = block
    if bm % tile or bn % tile:
        raise ValueError("block must be a multiple of tile")
    m, n = q.shape
    pm, pn = (-m) % bm, (-n) % bn
    qp = jnp.pad(q, ((0, pm), (0, pn)))
    mp, np_ = qp.shape
    grid = (mp // bm, np_ // bn)
    out_shape = (
        jax.ShapeDtypeStruct((mp // tile, np_ // tile), jnp.int32),
        jax.ShapeDtypeStruct((mp // tile, np_ // tile), jnp.int32),
    )
    bt_m, bt_n = bm // tile, bn // tile
    maxes, zeros = pl.pallas_call(
        functools.partial(bitsparsity_kernel, tile=tile),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=(
            pl.BlockSpec((bt_m, bt_n), lambda i, j: (i, j)),
            pl.BlockSpec((bt_m, bt_n), lambda i, j: (i, j)),
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(qp)
    nr, nc = -(-m // tile), -(-n // tile)
    return maxes[:nr, :nc], zeros[:nr, :nc]
