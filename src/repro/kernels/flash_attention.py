"""Pallas TPU flash attention (forward + backward), VMEM-tiled.

This is the fix for the dominant memory-roofline term of the attention archs:
XLA cannot fuse softmax(QKᵀ)V, so every (S, S) score chunk round-trips HBM
(measured: ~45% of zamba2/chameleon train_4k HBM traffic).  The kernel keeps
score tiles in VMEM scratch — HBM traffic collapses to Q/K/V/O (+ the (S,)
logsumexp residual for the backward).

Forward:  grid (B*H, nq, nk), online softmax carried in VMEM scratch
          (running max m, normalizer l, accumulator acc); causal tiles beyond
          the diagonal are skipped via ``pl.when``.
Backward: standard two-kernel flash bwd with in-kernel recompute —
          dq kernel over (B*H, nq, nk) and dkv kernel over (B*H, nk, nq) —
          using the forward's logsumexp and the precomputed row dot
          ``delta = rowsum(dO * O)``.

Block sizes default to (512, 512): MXU-aligned, (bq*d + bk*d*2 + bq*bk) * 4B
≈ 2.3 MB VMEM at d=128 — comfortably within a v5e core's 16 MB budget.
Validated in interpret mode against the jnp oracle (values AND grads) in
``tests/test_flash_attention.py``; used by the model layer on TPU backends.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_attention", "DEFAULT_BQ", "DEFAULT_BK"]

DEFAULT_BQ = 512
DEFAULT_BK = 512
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr, *,
                causal: bool, scale: float, bq: int, bk: int, nk: int,
                kv_len: int | None):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    run = (not causal) or (ki * bk <= qi * bq + bq - 1)
    if kv_len is not None:  # skip KV tiles that are entirely padding
        run = jnp.logical_and(run, ki * bk < kv_len)

    @pl.when(run)
    def _tile():
        q = q_ref[0]                                   # (bq, d)
        k = k_ref[0]                                   # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale                                  # (bq, bk)
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        if kv_len is not None:
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos < kv_len, s, NEG_INF)
        m_prev = m_scr[...]                            # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                         # (bq, bk)
        corr = jnp.exp(m_prev - m_new)                 # (bq, 1)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        m_scr[...] = m_new
        pv = jax.lax.dot_general(p.astype(v_ref.dtype), v_ref[0],
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr + pv

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)
        lse_ref[0] = (m_scr[...] + jnp.log(l))[:, 0]


def _fwd(q, k, v, *, causal: bool, bq: int, bk: int, kv_len: int | None,
         interpret: bool):
    bh, sq, d = q.shape
    skv = k.shape[1]
    nq, nk = sq // bq, skv // bk
    scale = 1.0 / (d ** 0.5)
    out, lse = pl.pallas_call(
        functools.partial(_fwd_kernel, causal=causal, scale=scale,
                          bq=bq, bk=bk, nk=nk, kv_len=kv_len),
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
            jax.ShapeDtypeStruct((bh, sq), jnp.float32),
        ),
        scratch_shapes=[
            _vmem((bq, 1), jnp.float32),
            _vmem((bq, 1), jnp.float32),
            _vmem((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out, lse


def _vmem(shape, dtype):
    try:  # pragma: no cover - TPU path
        from jax.experimental.pallas import tpu as pltpu
        return pltpu.VMEM(shape, dtype)
    except Exception:  # pragma: no cover
        return pl.MemorySpace.ANY(shape, dtype)


# ---------------------------------------------------------------------------
# Backward
# ---------------------------------------------------------------------------

def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               acc_scr, *, causal: bool, scale: float, bq: int, bk: int,
               nk: int, kv_len: int | None):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    run = (not causal) or (ki * bk <= qi * bq + bq - 1)
    if kv_len is not None:
        run = jnp.logical_and(run, ki * bk < kv_len)

    @pl.when(run)
    def _tile():
        q = q_ref[0]
        k = k_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        if kv_len is not None:
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos < kv_len, s, NEG_INF)
        p = jnp.exp(s - lse_ref[0][:, None])            # (bq, bk)
        dov = jax.lax.dot_general(do_ref[0], v_ref[0],
                                  (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        ds = p * (dov - delta_ref[0][:, None]) * scale  # (bq, bk)
        acc_scr[...] += jax.lax.dot_general(
            ds.astype(k.dtype), k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _finalize():
        dq_ref[0] = acc_scr[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_scr, dv_scr, *, causal: bool, scale: float,
                bq: int, bk: int, nq: int, kv_len: int | None):
    ki, qi = pl.program_id(1), pl.program_id(2)

    @pl.when(qi == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    run = (not causal) or (qi * bq + bq - 1 >= ki * bk)
    if kv_len is not None:  # all-padding key tiles keep their zero grads
        run = jnp.logical_and(run, ki * bk < kv_len)

    @pl.when(run)
    def _tile():
        q = q_ref[0]
        k = k_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        if kv_len is not None:
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos < kv_len, s, NEG_INF)
        p = jnp.exp(s - lse_ref[0][:, None])            # (bq, bk)
        dv_scr[...] += jax.lax.dot_general(
            p.astype(do_ref.dtype), do_ref[0], (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dov = jax.lax.dot_general(do_ref[0], v_ref[0],
                                  (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        ds = p * (dov - delta_ref[0][:, None]) * scale
        dk_scr[...] += jax.lax.dot_general(
            ds.astype(q.dtype), q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(qi == nq - 1)
    def _finalize():
        dk_ref[0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_scr[...].astype(dv_ref.dtype)


def _bwd(res, g, *, causal: bool, bq: int, bk: int, kv_len: int | None,
         interpret: bool):
    q, k, v, o, lse = res
    do = g[0] if isinstance(g, tuple) else g
    bh, sq, d = q.shape
    skv = k.shape[1]
    nq, nk = sq // bq, skv // bk
    scale = 1.0 / (d ** 0.5)
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32),
                    axis=-1)                               # (BH, S)

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, causal=causal, scale=scale, bq=bq,
                          bk=bk, nk=nk, kv_len=kv_len),
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[_vmem((bq, d), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, causal=causal, scale=scale, bq=bq,
                          bk=bk, nq=nq, kv_len=kv_len),
        grid=(bh, nk, nq),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, bq, d), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, j, i: (b, i)),
            pl.BlockSpec((1, bq), lambda b, j, i: (b, i)),
        ],
        out_specs=(
            pl.BlockSpec((1, bk, d), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, j, i: (b, j, 0)),
        ),
        out_shape=(jax.ShapeDtypeStruct(k.shape, k.dtype),
                   jax.ShapeDtypeStruct(v.shape, v.dtype)),
        scratch_shapes=[_vmem((bk, d), jnp.float32),
                        _vmem((bk, d), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    return dq, dk, dv


# ---------------------------------------------------------------------------
# Public op
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash(q, k, v, causal, bq, bk, kv_len, interpret):
    out, _ = _fwd(q, k, v, causal=causal, bq=bq, bk=bk, kv_len=kv_len,
                  interpret=interpret)
    return out


def _flash_fwd_rule(q, k, v, causal, bq, bk, kv_len, interpret):
    out, lse = _fwd(q, k, v, causal=causal, bq=bq, bk=bk, kv_len=kv_len,
                    interpret=interpret)
    return out, (q, k, v, out, lse)


def _flash_bwd_rule(causal, bq, bk, kv_len, interpret, res, g):
    return _bwd(res, g, causal=causal, bq=bq, bk=bk, kv_len=kv_len,
                interpret=interpret)


_flash.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def flash_attention(q, k, v, *, causal: bool = True, bq: int = DEFAULT_BQ,
                    bk: int = DEFAULT_BK, interpret: bool | None = None):
    """q/k/v: (B, S, H, D) -> (B, S, H, Dv).  Differentiable flash attention.

    Ragged sequence lengths (not a multiple of the block size — routine for
    serving shapes) are padded up to the block grid internally: padded
    *keys* are masked to ``NEG_INF`` inside the kernels (a static ``kv_len``
    bound, so real queries never attend them and their gradients are exact
    zeros), padded *query* rows attend real keys only through the causal
    mask and are sliced off the output (their upstream cotangent is zero, so
    they contribute nothing to dK/dV).  ``tests/test_flash_attention.py``
    pins padded-vs-exact-multiple agreement for values and grads.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, sq, h, d = q.shape
    skv = k.shape[1]
    bq_ = min(bq, sq)
    bk_ = min(bk, skv)
    pad_q = -sq % bq_
    pad_k = -skv % bk_
    # (B, S, H, D) -> (B*H, S, D)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, skv, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, skv, d)
    if pad_q:
        qf = jnp.pad(qf, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kf = jnp.pad(kf, ((0, 0), (0, pad_k), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad_k), (0, 0)))
    kv_len = skv if pad_k else None
    of = _flash(qf, kf, vf, causal, bq_, bk_, kv_len, interpret)
    if pad_q:
        of = of[:, :sq]
    return of.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
