"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function mirrors one kernel's semantics exactly; tests sweep shapes,
bit-widths and dtypes asserting bit-identical (integer) or allclose (float)
agreement with the kernels run under ``interpret=True``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "unpack_values_ref",
    "quant_gemm_ref",
    "tub_gemm_ref",
    "tu_gemm_ref",
    "block_stats_ref",
    "bit_sparsity_stats_ref",
]


def unpack_values_ref(packed: jax.Array, bits: int, axis: int = 0) -> jax.Array:
    """NumPy-style unpack: low bits first along ``axis``."""
    if bits == 8:
        return packed
    pack = 8 // bits
    arr = jnp.asarray(packed, jnp.int8)
    out = []
    for i in range(pack):
        shift = i * bits
        v = jnp.left_shift(arr, 8 - bits - shift) >> (8 - bits)
        out.append(v)
    stacked = jnp.stack(out, axis=axis + 1)
    shape = list(arr.shape)
    shape[axis] *= pack
    return stacked.reshape(shape)


def quant_gemm_ref(x: jax.Array, w_packed: jax.Array,
                   scales: jax.Array | None = None, *, bits: int = 8,
                   fuse_dequant: bool = False) -> jax.Array:
    w = unpack_values_ref(w_packed, bits, axis=0)
    out = jnp.matmul(x.astype(jnp.int32), w.astype(jnp.int32),
                     preferred_element_type=jnp.int32)
    if fuse_dequant:
        s = jnp.ones((1, out.shape[1]), jnp.float32) if scales is None else scales
        return out.astype(jnp.float32) * s.reshape(1, -1)
    return out


def tub_gemm_ref(a: jax.Array, b: jax.Array, *, bits: int = 8) -> jax.Array:
    """Slot-by-slot mirror of the tubGEMM kernel's 2-unary schedule.

    Builds the (L2, M, K) weight train — weight-2 gated slots plus the odd
    bit on slot 0, times the sign — and sums slot contributions, exactly what
    the kernel's ``fori_loop`` accumulates.  Equal to int32 GEMM by the
    paper's equivalence argument.
    """
    a32 = a.astype(jnp.int32)
    mag, sgn = jnp.abs(a32), jnp.sign(a32)
    v1, v0 = mag // 2, mag % 2
    slots = jnp.arange(max(1, 2 ** (bits - 2)), dtype=jnp.int32)
    gates = 2 * (slots[:, None, None] < v1[None]).astype(jnp.int32)
    gates = gates.at[0].add(v0)
    weights = gates * sgn[None]                              # (L2, M, K)
    return jnp.einsum("tmk,kn->mn", weights, b.astype(jnp.int32),
                      preferred_element_type=jnp.int32)


def tu_gemm_ref(a: jax.Array, b: jax.Array, *, bits: int = 8) -> jax.Array:
    """Slot-by-slot mirror of the tuGEMM kernel's temporal schedule.

    Builds the (L, M, K) pulse train — slot i fires iff ``i < |a|``, times the
    sign — and sums each slot's signed add of B, exactly what the kernel's
    ``fori_loop`` accumulates (B's replayed temporal stream summed by the
    adder tree).  Equal to int32 GEMM by the paper's equivalence argument.
    """
    a32 = a.astype(jnp.int32)
    mag, sgn = jnp.abs(a32), jnp.sign(a32)
    slots = jnp.arange(2 ** (bits - 1), dtype=jnp.int32)
    pulses = (slots[:, None, None] < mag[None]).astype(jnp.int32) * sgn[None]
    return jnp.einsum("tmk,kn->mn", pulses, b.astype(jnp.int32),
                      preferred_element_type=jnp.int32)


def block_stats_ref(q: jax.Array, tile: int = 32):
    if q.ndim != 2:
        q = q.reshape(-1, q.shape[-1])
    m, n = q.shape
    pm, pn = (-m) % tile, (-n) % tile
    qp = jnp.pad(q, ((0, pm), (0, pn))).astype(jnp.int32)
    r, c = qp.shape[0] // tile, qp.shape[1] // tile
    a = jnp.abs(qp).reshape(r, tile, c, tile)
    maxes = jnp.max(a, axis=(1, 3))
    zeros = jnp.sum((qp == 0).astype(jnp.int32).reshape(r, tile, c, tile),
                    axis=(1, 3))
    return maxes, zeros


def bit_sparsity_stats_ref(q: jax.Array, bits: int, tile: int = 32):
    """(word_sparsity, bit_sparsity_blockmax) — must equal core.sparsity."""
    if q.ndim != 2:
        q = q.reshape(-1, q.shape[-1])
    m, n = q.shape
    maxes, zeros = block_stats_ref(q, tile)
    pad_rows = maxes.shape[0] * tile - m
    pad_cols = maxes.shape[1] * tile - n
    total_pad = pad_rows * n + pad_cols * m + pad_rows * pad_cols
    word = (jnp.sum(zeros) - total_pad) / (m * n)
    bit_blockmax = 1.0 - jnp.mean(maxes.astype(jnp.float32)) / (2 ** (bits - 1))
    return word, bit_blockmax
