"""Gather-based paged-KV decode attention.

The serving engine (``repro.serving``) keeps each request's KV history in
fixed-size *pages* of a preallocated pool — ``(num_pages, page_size, KVH,
head_dim)`` per layer — indexed through a per-request *block table* (a row of
page ids).  This module is the device-side read/write path over that layout:

* :func:`write_kv_token` scatters one new K (or V) vector per request into
  the page/slot its current length maps to;
* :func:`gather_kv` materializes the per-request view ``(B, max_blocks *
  page_size, KVH, head_dim)`` by gathering pool pages through the block
  table;
* :func:`paged_decode_attention` runs the gathered view through the exact
  same ``naive_attention`` math as the contiguous decode path in
  ``models/attention._gqa_fwd`` (same score widths, same mask construction,
  same softmax), so paged decode is **bit-exact** with the contiguous
  reference at fp32 — ``tests/test_serving.py`` pins this, including through
  the ``kernels/flash_attention`` reference.

Everything is functional (pools in, pools out) so the serving engine can jit
one decode step over the whole layer stack with ``lax.scan``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import _repeat_kv, naive_attention

__all__ = ["write_kv_token", "gather_kv", "paged_decode_attention"]


def write_kv_token(pool: jax.Array, block_table: jax.Array,
                   lengths: jax.Array, new: jax.Array,
                   page_size: int) -> jax.Array:
    """Scatter one new KV vector per request into its page pool.

    ``pool``: (num_pages, page_size, KVH, hd); ``block_table``: (B,
    max_blocks) int32 page ids; ``lengths``: (B,) int32 — the position the
    new token lands at; ``new``: (B, KVH, hd).  Requests that should not
    write (evicted slots) must point their block-table row at the reserved
    trash page (page 0, never allocated — see ``serving.paged_kv``), which
    absorbs their scatter without aliasing any live request's pages.
    """
    pages = jnp.take_along_axis(
        block_table, (lengths // page_size)[:, None], axis=1)[:, 0]
    slots = lengths % page_size
    return pool.at[pages, slots].set(new.astype(pool.dtype))


def gather_kv(pool: jax.Array, block_table: jax.Array) -> jax.Array:
    """(num_pages, page_size, ...) gathered to (B, max_blocks * page_size, ...)."""
    b, max_blocks = block_table.shape
    gathered = pool[block_table]           # (B, max_blocks, page_size, ...)
    return gathered.reshape(b, max_blocks * pool.shape[1], *pool.shape[2:])


def paged_decode_attention(q: jax.Array, pool_k: jax.Array, pool_v: jax.Array,
                           block_table: jax.Array, kv_valid_len: jax.Array,
                           *, num_heads: int) -> jax.Array:
    """Single-token GQA decode attention over the paged KV pool.

    ``q``: (B, 1, H, hd); ``kv_valid_len``: (B,) — per-request valid history
    *including* the token written this step.  Positions past a request's
    valid length (page padding plus whatever the gathered pages carry beyond
    it) are masked to the same -1e30 the contiguous path uses, so the
    softmax rows match the contiguous cache bit-for-bit whenever the
    gathered width equals the contiguous cache width.
    """
    kc = gather_kv(pool_k, block_table)
    vc = gather_kv(pool_v, block_table)
    k_full = _repeat_kv(kc.astype(q.dtype), num_heads)
    v_full = _repeat_kv(vc.astype(q.dtype), num_heads)
    return naive_attention(q, k_full, v_full, causal=False,
                           kv_valid_len=kv_valid_len)
