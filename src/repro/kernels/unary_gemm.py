"""Pallas TPU kernels: the temporal-unary slot loops as tiled on-device GEMMs.

Two kernels, one per temporal design of the paper (§II):

* **tubGEMM** (``tub_gemm``) streams the A operand in *2-unary*: per
  outer-product step, ``|a| = 2*v1 + v0`` where ``v1`` gates
  ``L2 = 2^(w-2)`` weight-2 slots and the odd bit ``v0`` rides slot 0;
  B stays binary and is conditionally accumulated every slot.
* **tuGEMM** (``tu_gemm``) streams A in plain temporal-unary over
  ``L = 2^(w-1)`` slots; each 1-slot of A gates a full replay of B's own
  temporal stream into the output counters.  The replay sums to exactly
  ``sign(b) * |b| = b``, so the kernel folds it into one signed add of B per
  A-slot (the adder tree's total, bit-for-bit) while keeping the outer
  temporal schedule — the part that sets the cycle count — literal.

Both kernels execute their slot loop as a ``fori_loop`` inside each
(bm, bn, bk) tile, one conditional-add (masked MXU dot) per slot, so the
on-device schedule mirrors the hardware schedule the PPA model prices, while
the result stays bit-identical to binary int32 GEMM (the equivalence the
paper proves).

Structure mirrors ``quant_gemm.py``: grid (M/bm, N/bn, K/bk) with the K axis
innermost, an int32 VMEM scratch accumulator, and the output block written on
the final K step.  Validated under ``interpret=True`` against
``ref.tub_gemm_ref`` / ``ref.tu_gemm_ref`` and ``gemm_sims.bgemm_exact``.

Alongside the output the wrappers report the design's cycle count
(``K * 2^(w-2)`` for tubGEMM, ``K * (2^(w-1))^2`` for tuGEMM — the paper's WC
latency for the simulated unit, a host-side constant, not a device
measurement).  ``kernels.backends`` registers both as executable designs in
the ``gemm_sims`` registry so sweeps can cross-check simulator cycles against
kernel cycle reports.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.quant_gemm import _acc_scratch, _pad_to

__all__ = [
    "tub_gemm_kernel",
    "tub_gemm",
    "tub_wc_cycles",
    "tu_gemm_kernel",
    "tu_gemm",
    "tu_wc_cycles",
    "DEFAULT_BLOCK",
]

DEFAULT_BLOCK = (128, 128, 128)  # (bm, bn, bk) — MXU-aligned


def tub_wc_cycles(bits: int, common_dim: int) -> int:
    """Worst-case tubGEMM cycles for one GEMM with common dimension K.

    Args: ``bits`` — operand bit-width w; ``common_dim`` — K.
    Returns: cycles (dimensionless count; multiply by
    ``ppa.CLOCK_PERIOD_NS`` for ns): one pass of ``L2 = 2^(w-2)`` slots per
    outer-product step, ``K * L2``.  Equals ``wc_cycles("tubgemm", ...)``.
    """
    return common_dim * max(1, 2 ** (bits - 2))


def tu_wc_cycles(bits: int, common_dim: int) -> int:
    """Worst-case tuGEMM cycles for one GEMM with common dimension K.

    Args: ``bits`` — operand bit-width w; ``common_dim`` — K.
    Returns: cycles (dimensionless count; multiply by
    ``ppa.CLOCK_PERIOD_NS`` for ns): every one of A's ``L = 2^(w-1)`` slots
    replays B's full L-slot stream, per outer-product step — ``K * L^2``.
    Equals ``wc_cycles("tugemm", ...)``.
    """
    return common_dim * (2 ** (bits - 1)) ** 2


def tub_gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, bits: int, n_k: int):
    """One (bm, bn) output tile; K-step ``pl.program_id(2)``.

    Per K tile: decompose A into (v1, v0, sign) and run the 2-unary slot
    loop — slot t adds ``(2*[t < v1] + [t == 0]*v0) * sign @ B`` into the
    accumulator, exactly the conditional adder bank of the tubGEMM PE column.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.int32)                 # (bm, bk)
    b = b_ref[...].astype(jnp.int32)                 # (bk, bn)
    mag = jnp.abs(a)
    sgn = jnp.sign(a)
    v1, v0 = mag // 2, mag % 2
    n_slots = max(1, 2 ** (bits - 2))

    def slot(t, acc):
        two_gate = 2 * (t < v1).astype(jnp.int32)    # weight-2 slots
        one_gate = jnp.where(t == 0, v0, 0)          # odd bit on slot 0
        pulses = (two_gate + one_gate) * sgn         # (bm, bk)
        return acc + jax.lax.dot_general(
            pulses, b, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)

    acc_ref[...] += jax.lax.fori_loop(0, n_slots, slot,
                                      jnp.zeros_like(acc_ref))

    @pl.when(k == n_k - 1)
    def _epilogue():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("bits", "block", "interpret"))
def tub_gemm(a: jax.Array, b: jax.Array, *, bits: int = 8,
             block: tuple[int, int, int] = DEFAULT_BLOCK,
             interpret: bool = False) -> tuple[jax.Array, int]:
    """``a:(M,K) int8 codes @ b:(K,N) int8 -> ((M,N) int32, wc_cycles)``.

    ``a`` holds w-bit sign-magnitude-encodable codes (|a| <= 2^(w-1)-1, the
    symmetric-quantization range); ``b`` is plain int8.  Output is exactly
    ``bgemm_exact(a, b)`` — the point is the *schedule*, priced by
    ``core.ppa`` at ``tub_wc_cycles(bits, K)`` cycles.
    """
    if a.dtype != jnp.int8 or b.dtype != jnp.int8:
        raise TypeError("tub_gemm wants int8 operands")
    bm, bn, bk = block
    m, kdim = a.shape
    if b.shape[0] != kdim:
        raise ValueError(f"K mismatch: a has K={kdim}, b has K={b.shape[0]}")
    n = b.shape[1]

    ap = _pad_to(_pad_to(a, 0, bm), 1, bk)
    bp = _pad_to(_pad_to(b, 0, bk), 1, bn)
    mp, kp = ap.shape
    np_ = bp.shape[1]
    grid = (mp // bm, np_ // bn, kp // bk)

    out = pl.pallas_call(
        functools.partial(tub_gemm_kernel, bits=bits, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        scratch_shapes=[_acc_scratch(bm, bn)],
        interpret=interpret,
    )(ap, bp)
    return out[:m, :n], tub_wc_cycles(bits, kdim)


def tu_gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, bits: int, n_k: int):
    """One (bm, bn) output tile; K-step ``pl.program_id(2)``.

    Per K tile: decompose A into (magnitude, sign) and run the temporal slot
    loop — slot i adds ``[i < |a|] * sign @ B`` into the accumulator.  The
    masked dot is the adder-tree total of B's replayed temporal stream for
    that slot (the replay's counter sum is ``sign(b) * |b| = b``), so each
    loop iteration is one outer slot of the tuGEMM PE column, bit-for-bit.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.int32)                 # (bm, bk)
    b = b_ref[...].astype(jnp.int32)                 # (bk, bn)
    mag = jnp.abs(a)
    sgn = jnp.sign(a)
    n_slots = 2 ** (bits - 1)

    def slot(i, acc):
        pulses = (i < mag).astype(jnp.int32) * sgn   # (bm, bk)
        return acc + jax.lax.dot_general(
            pulses, b, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)

    acc_ref[...] += jax.lax.fori_loop(0, n_slots, slot,
                                      jnp.zeros_like(acc_ref))

    @pl.when(k == n_k - 1)
    def _epilogue():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("bits", "block", "interpret"))
def tu_gemm(a: jax.Array, b: jax.Array, *, bits: int = 8,
            block: tuple[int, int, int] = DEFAULT_BLOCK,
            interpret: bool = False) -> tuple[jax.Array, int]:
    """``a:(M,K) int8 codes @ b:(K,N) int8 -> ((M,N) int32, wc_cycles)``.

    ``a`` holds w-bit sign-magnitude-encodable codes (|a| <= 2^(w-1)-1, the
    symmetric-quantization range); ``b`` is plain int8.  Output is exactly
    ``tugemm_exact(a, b)`` (== binary int32 GEMM) — the point is the
    *schedule*, priced by ``core.ppa`` at ``tu_wc_cycles(bits, K)`` cycles.
    """
    if a.dtype != jnp.int8 or b.dtype != jnp.int8:
        raise TypeError("tu_gemm wants int8 operands")
    bm, bn, bk = block
    m, kdim = a.shape
    if b.shape[0] != kdim:
        raise ValueError(f"K mismatch: a has K={kdim}, b has K={b.shape[0]}")
    n = b.shape[1]

    ap = _pad_to(_pad_to(a, 0, bm), 1, bk)
    bp = _pad_to(_pad_to(b, 0, bk), 1, bn)
    mp, kp = ap.shape
    np_ = bp.shape[1]
    grid = (mp // bm, np_ // bn, kp // bk)

    out = pl.pallas_call(
        functools.partial(tu_gemm_kernel, bits=bits, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        scratch_shapes=[_acc_scratch(bm, bn)],
        interpret=interpret,
    )(ap, bp)
    return out[:m, :n], tu_wc_cycles(bits, kdim)
