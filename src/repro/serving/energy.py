"""Energy-per-token accounting for the serving loop (Eq. 1 pricing).

The engine charges every scheduler step the Eq.-1 dynamic energy of the
weight GEMMs it actually ran, priced through ``core.accounting`` exactly
like ``launch/serve.py``'s one-shot report:

* weights are walked and sparsity-profiled ONCE at engine start (the
  block-max bit-sparsity statistic the paper's cost tables use);
* a decode step with ``m`` active requests prices the per-layer workload at
  ``m`` GEMM rows (one token per active request);
* an admission prices the prompt's prefill at ``prompt_len`` rows;
* energy-per-token = total dynamic energy / tokens generated.

Costs are cached per row count ``m``, so a whole trace re-prices nothing.

:func:`iter_weight_matrices` is the single canonical walk — the serve
driver's pricing/measured-cycles reports build on the same function, so the
serving report and ``serve``'s tables see identical matrices.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import backends as backends_lib
from repro.core import accounting, packing, sparsity

__all__ = ["iter_weight_matrices", "EnergyModel"]


def iter_weight_matrices(cfg, params):
    """Yield ``(name, (k, n_out) float32 weight)`` for every priced matmul.

    ``name`` is the "/"-joined parameter-tree path (the plan site-naming
    contract).  The tied-embedding table is skipped when an ``lm_head``
    leaf exists, mirroring which matmuls the backend scope contracts.

    Packed leaves (:class:`repro.core.packing.PackedQuantized`) yield their
    dequantized matrix — the only float weight the stored codes can honestly
    reconstruct.  Energy pricing should normally run on the pre-pack float
    tree (the engine keeps it for exactly this), but the walk stays total so
    report paths handed a packed tree don't crash.
    """
    flat = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=packing.is_packed)[0]
    for path, leaf in flat:
        if packing.is_packed(leaf):
            leaf = leaf.dequantize()
        if not hasattr(leaf, "ndim") or leaf.ndim < 2:
            continue
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        if "embed" in name and not cfg.tie_embeddings:
            continue
        w = np.asarray(leaf, np.float32).reshape(leaf.shape[0], -1) \
            if leaf.ndim == 2 \
            else np.asarray(leaf, np.float32).reshape(-1, leaf.shape[-1])
        yield name, w


class EnergyModel:
    """Prices one forward step of the model at ``m`` rows on one design."""

    def __init__(self, cfg, params, *, design: str = "tubgemm", bits: int = 4,
                 unit_n: int = 64, num_units: int = 64,
                 grid: tuple[int, int] | None = None) -> None:
        self.design = design
        self.bits = bits
        self.unit_n = unit_n
        self.num_units = num_units
        backend = backends_lib.resolve(design, bits=bits)
        if grid is not None:
            backend = backends_lib.as_grid(backend, *grid)
        self._backend = backend
        self._shapes = []
        for name, w in iter_weight_matrices(cfg, params):
            st = sparsity.profile_tensor(jnp.asarray(w), bits=bits)
            self._shapes.append((name, w.shape[0], w.shape[1], st.bit_blockmax))
        self._costs: dict[int, accounting.ModelCost] = {}

    def step_cost(self, m: int) -> accounting.ModelCost:
        """ModelCost of one forward step contracting ``m`` rows per site."""
        cost = self._costs.get(m)
        if cost is None:
            rec = accounting.GemmWorkloadRecorder()
            for name, k, n_out, bit_blockmax in self._shapes:
                rec.record(name, m=m, k=k, n_out=n_out,
                           bit_sparsity=bit_blockmax, count=1)
            cost = self._backend.price(rec.calls, unit_n=self.unit_n,
                                       num_units=self.num_units)
            self._costs[m] = cost
        return cost

    def decode_energy_uj(self, n_active: int) -> float:
        """Dynamic energy of one decode step with ``n_active`` requests."""
        return 0.0 if n_active == 0 else self.step_cost(n_active).dyn_energy_uj

    def prefill_energy_uj(self, prompt_len: int) -> float:
        """Dynamic energy of prefilling one ``prompt_len``-token prompt."""
        return self.step_cost(prompt_len).dyn_energy_uj
