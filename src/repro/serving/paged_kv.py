"""Paged KV cache: fixed-size pages in a preallocated pool + block tables.

Layout (vLLM-style, one logical page id spanning every layer):

* two device pools of shape ``(L, num_pages, page_size, KVH, head_dim)``
  (K and V), allocated once at engine start;
* a free-list :class:`PageAllocator` over page ids ``1..num_pages-1`` —
  **page 0 is reserved as the trash page**: it is never handed out, and
  evicted batch slots point their block-table row at it so the jitted
  decode step's scatter (which always writes all B rows) can never alias a
  live request's pages;
* per-request block tables (``list[int]`` of page ids, host side) padded
  with the trash page to the engine's static ``max_blocks`` width when
  shipped to the device.

Invariants (property-tested in ``tests/test_serving.py``):

* no page id is ever owned by two live requests (no aliasing);
* ``free + sum(owned)`` is conserved at ``num_pages - 1`` across any
  alloc/free/append sequence;
* reconstructing a request's KV by walking its block table is
  element-identical to an append-only contiguous cache fed the same
  values.
"""

from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np

__all__ = ["OutOfPages", "PageAllocator", "PagedKVCache"]


class OutOfPages(RuntimeError):
    """Raised when an allocation asks for more pages than are free."""


class PageAllocator:
    """Free-list allocator over page ids, with ownership tracking.

    Page ids ``reserved..num_pages-1`` are allocatable; ids below
    ``reserved`` (the trash page) are never handed out.  Ownership is
    tracked per page so aliasing is an *assertion failure*, not a silent
    corruption.
    """

    def __init__(self, num_pages: int, reserved: int = 1) -> None:
        if num_pages <= reserved:
            raise ValueError(f"need more than {reserved} pages, got {num_pages}")
        self.num_pages = num_pages
        self.reserved = reserved
        self._free = list(range(num_pages - 1, reserved - 1, -1))  # pop() -> low ids first
        self._owner: dict[int, object] = {}

    @property
    def capacity(self) -> int:
        return self.num_pages - self.reserved

    @property
    def num_free(self) -> int:
        return len(self._free)

    def alloc(self, n: int, owner: object) -> list[int]:
        """Allocate ``n`` pages for ``owner``; raises :class:`OutOfPages`."""
        if n < 0:
            raise ValueError(f"cannot allocate {n} pages")
        if n > len(self._free):
            raise OutOfPages(f"requested {n} pages, {len(self._free)} free")
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            assert p not in self._owner, f"page {p} double-allocated"
            self._owner[p] = owner
        return pages

    def free(self, pages: list[int], owner: object) -> None:
        for p in pages:
            assert self._owner.get(p) == owner, \
                f"page {p} freed by {owner!r} but owned by {self._owner.get(p)!r}"
            del self._owner[p]
            self._free.append(p)

    def owner_of(self, page: int):
        return self._owner.get(page)


class PagedKVCache:
    """Preallocated paged KV pools + per-request block tables.

    ``k_pool`` / ``v_pool`` are jax arrays ``(L, num_pages, page_size, KVH,
    head_dim)``; the jitted decode step consumes and returns them
    functionally (``sync_pools`` writes the step's result back).  Host-side
    bookkeeping (block tables, lengths, the allocator) stays in plain
    Python — the device never sees a page id that the allocator has not
    handed out.
    """

    def __init__(self, *, num_layers: int, num_kv_heads: int, head_dim: int,
                 num_pages: int, page_size: int, max_seq_len: int,
                 dtype=jnp.float32) -> None:
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.num_layers = num_layers
        self.num_pages = num_pages
        self.page_size = page_size
        self.max_blocks = max(1, math.ceil(max_seq_len / page_size))
        self.max_seq_len = self.max_blocks * page_size
        shape = (num_layers, num_pages, page_size, num_kv_heads, head_dim)
        self.k_pool = jnp.zeros(shape, dtype)
        self.v_pool = jnp.zeros(shape, dtype)
        self.allocator = PageAllocator(num_pages)
        self.block_tables: dict[object, list[int]] = {}
        self.lengths: dict[object, int] = {}

    # -- allocation ---------------------------------------------------------

    def pages_needed(self, total_len: int) -> int:
        return math.ceil(total_len / self.page_size)

    def can_allocate(self, total_len: int) -> bool:
        return self.pages_needed(total_len) <= self.allocator.num_free

    def allocate(self, req_id, total_len: int) -> list[int]:
        """Reserve pages covering ``total_len`` positions for ``req_id``."""
        if req_id in self.block_tables:
            raise ValueError(f"request {req_id!r} already has pages")
        if total_len > self.max_seq_len:
            raise ValueError(f"request {req_id!r} needs {total_len} positions, "
                             f"cache max_seq_len is {self.max_seq_len}")
        pages = self.allocator.alloc(self.pages_needed(total_len), req_id)
        self.block_tables[req_id] = pages
        self.lengths[req_id] = 0
        return pages

    def free_request(self, req_id) -> None:
        self.allocator.free(self.block_tables.pop(req_id), req_id)
        del self.lengths[req_id]

    # -- device views -------------------------------------------------------

    def block_table_row(self, req_id=None) -> np.ndarray:
        """(max_blocks,) int32 row — trash-page padded; all-trash if None."""
        row = np.zeros(self.max_blocks, np.int32)
        if req_id is not None:
            pages = self.block_tables[req_id]
            row[: len(pages)] = pages
        return row

    def sync_pools(self, k_pool, v_pool) -> None:
        """Adopt the pools a jitted decode step returned."""
        self.k_pool = k_pool
        self.v_pool = v_pool

    # -- host-side writes (prefill, property tests) --------------------------

    def write_prefill(self, req_id, k: jnp.ndarray, v: jnp.ndarray) -> None:
        """Write a prompt's KV — ``k``/``v``: (L, S, KVH, hd) — into pages."""
        s = int(k.shape[1])
        pages = self.block_tables[req_id]
        ps = self.page_size
        assert s <= len(pages) * ps, "prefill longer than the reservation"
        for j in range(math.ceil(s / ps)):
            lo, hi = j * ps, min((j + 1) * ps, s)
            self.k_pool = self.k_pool.at[:, pages[j], : hi - lo].set(k[:, lo:hi])
            self.v_pool = self.v_pool.at[:, pages[j], : hi - lo].set(v[:, lo:hi])
        self.lengths[req_id] = s

    def append_token(self, req_id, k: jnp.ndarray, v: jnp.ndarray) -> None:
        """Append one position — ``k``/``v``: (L, KVH, hd) — host-side.

        The jitted decode step performs the same page/slot scatter on
        device (``kernels.paged_attention.write_kv_token``); this method is
        the host mirror the property tests drive.
        """
        pos = self.lengths[req_id]
        pages = self.block_tables[req_id]
        assert pos < len(pages) * self.page_size, "append past the reservation"
        page, slot = pages[pos // self.page_size], pos % self.page_size
        self.k_pool = self.k_pool.at[:, page, slot].set(k)
        self.v_pool = self.v_pool.at[:, page, slot].set(v)
        self.lengths[req_id] = pos + 1

    def gather_request(self, req_id) -> tuple[np.ndarray, np.ndarray]:
        """Reconstruct (L, len, KVH, hd) K/V by walking the block table."""
        n = self.lengths[req_id]
        pages = self.block_tables[req_id]
        kp = np.asarray(self.k_pool[:, pages])   # (L, blocks, page, KVH, hd)
        vp = np.asarray(self.v_pool[:, pages])
        flat = kp.reshape(kp.shape[0], -1, *kp.shape[3:])
        flatv = vp.reshape(vp.shape[0], -1, *vp.shape[3:])
        return flat[:, :n], flatv[:, :n]
