"""Continuous-batching serving engine over the paged KV cache.

One :class:`ServingEngine` owns a fixed decode batch of ``max_batch`` slots,
a :class:`~repro.serving.paged_kv.PagedKVCache`, and a single jitted decode
step that advances *every* slot one token per scheduler step:

* **prefill** (admission): the prompt runs through ``model_lib.prefill``
  (padded to a power-of-two bucket — causal attention makes the valid
  prefix independent of tail padding), its KV is copied into freshly
  allocated pages, and its first token comes off the prompt's last logits;
* **decode** (every step): the jitted step embeds each slot's pending
  token at its own position, scatters the new K/V into its pages
  (``kernels.paged_attention.write_kv_token``), attends over the gathered
  pages, and emits next-token logits.  The step mirrors
  ``models.blocks._transformer_block`` op for op — same ``dense`` sites
  under the same ``site_scope`` names (``layers/attn/wq`` …, ``lm_head``)
  — so ``use_backend(...)``/``use_plan(...)`` scopes contract every token
  on the selected unary engine exactly as the one-shot ``serve`` driver
  does, and paged decode logits are bit-exact with
  ``model_lib.decode_step`` whenever the requests are aligned
  (``tests/test_serving.py``).

Evicted/empty slots are kept deterministic: their hidden state is zeroed
after embedding and their block-table rows point at the reserved trash
page, so a freed slot can neither corrupt live pages nor leak
schedule-dependent garbage into the per-tensor activation-quantization
scales of a live backend scope.

Time is counted in scheduler steps (1 decode step each); energy in Eq.-1
dynamic µJ via :class:`~repro.serving.energy.EnergyModel`.
"""

from __future__ import annotations

import contextlib
import dataclasses
from collections import OrderedDict, deque

import jax
import jax.numpy as jnp
import numpy as np

from repro import backends as backends_lib
from repro.backends.runtime import site_scope
from repro.kernels import paged_attention as paged_lib
from repro.kernels import paged_attention_fused as fused_lib
from repro.launch.mesh import make_grid_mesh, single_device_mesh
from repro.models import attention as attn_lib
from repro.models import model as model_lib
from repro.models import rope as rope_lib
from repro.models.common import activation_scale_mode, dense, rmsnorm
from repro.models.config import ModelConfig
from repro.models.mlp import mlp_fwd
from repro.serving.energy import EnergyModel
from repro.serving.paged_kv import PagedKVCache
from repro.serving.scheduler import (Request, RequestState, _SchedulerBase,
                                     make_scheduler)
from repro.serving.traffic import TrafficRequest

__all__ = ["ServingEngine", "ServingReport", "paged_vs_contiguous_probe",
           "fused_vs_gather_probe", "FUSED_LOGIT_TOL"]

#: gated max |Δlogit| between the fused online-softmax decode path and the
#: bit-exact gather oracle on the fp32 smoke probe — online softmax
#: re-associates the reduction, so exact equality is not the contract; the
#: sampled token streams still must match exactly on the seeded traces.
FUSED_LOGIT_TOL = 1e-4

#: shared, bounded cache of jitted prefill callables.  Keyed on everything
#: the *trace* depends on — (cfg, backend/plan scope, grid, activation-scale
#: mode, padded prompt bucket) — so any two ServingEngine instances with
#: identical keys reuse one compiled entry instead of recompiling per
#: engine construction, and the cache cannot grow without bound across a
#: long-lived benchmark process.
PREFILL_CACHE_MAXSIZE = 32
_PREFILL_FNS: OrderedDict[tuple, object] = OrderedDict()


def _prefill_cache_get(key: tuple, make):
    fn = _PREFILL_FNS.get(key)
    if fn is None:
        fn = _PREFILL_FNS[key] = make()
        while len(_PREFILL_FNS) > PREFILL_CACHE_MAXSIZE:
            _PREFILL_FNS.popitem(last=False)
    else:
        _PREFILL_FNS.move_to_end(key)
    return fn


@dataclasses.dataclass(frozen=True)
class ServingReport:
    """Metrics of one trace served under one scheduler."""
    scheduler: str
    requests: int
    tokens: int
    steps: int
    throughput_tok_per_step: float
    latency_p50: float
    latency_p99: float
    queue_delay_mean: float
    occupancy: float
    energy_uj: float
    energy_per_token_uj: float
    design: str
    bits: int
    max_batch: int
    page_size: int
    num_pages: int
    events: tuple[tuple[int, str, int], ...]
    latencies: tuple[int, ...]
    request_tokens: dict[int, tuple[int, ...]]

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["events"] = [list(e) for e in self.events]
        d["latencies"] = list(self.latencies)
        d["request_tokens"] = {str(k): list(v)
                               for k, v in self.request_tokens.items()}
        return d


def _bucket(n: int, floor: int = 4) -> int:
    """Next power of two >= max(n, floor) — bounds prefill retraces."""
    b = floor
    while b < n:
        b *= 2
    return b


def paged_vs_contiguous_probe(cfg: ModelConfig, params, *, batch: int = 2,
                              prompt_len: int = 5, steps: int = 3,
                              page_size: int = 4) -> float:
    """Max |paged - contiguous| decode logit difference at fp32 (0.0 = exact).

    Runs ``steps`` aligned decode steps (every slot at the same position, so
    ``model_lib.decode_step``'s scalar ``cache_pos`` applies) through both
    the engine's paged scatter/gather step and the contiguous
    ``dynamic_update_slice`` cache path, greedy-feeding each path its own
    argmax token, and returns the worst absolute logit difference seen.
    ``page_size`` deliberately defaults to a non-divisor of typical prompt
    lengths so partially filled pages are exercised.  The serving CLI, the
    serving benchmark and the tier-1 tests all gate on this returning 0.0.
    """
    from repro.launch import steps as steps_lib  # avoid cycle at import time

    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    total = prompt_len + steps + 1
    # the gather path is the bit-exactness oracle; the fused path is held
    # to FUSED_LOGIT_TOL by fused_vs_gather_probe instead
    engine = ServingEngine(cfg, params, max_batch=batch, page_size=page_size,
                           max_seq_len=_bucket(total), attention="gather")
    rng = np.random.default_rng(1234)
    prompts = rng.integers(0, cfg.vocab_size,
                           (batch, prompt_len)).astype(np.int32)
    cache = PagedKVCache(
        num_layers=cfg.num_layers, num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.resolved_head_dim, num_pages=engine.num_pages,
        page_size=page_size, max_seq_len=engine.max_seq_len)
    btables = np.zeros((batch, cache.max_blocks), np.int32)
    worst = 0.0
    with engine._mesh as mesh:
        prefill_step = steps_lib.make_prefill_step(cfg, mesh,
                                                   params_like=params)
        decode_step = steps_lib.make_decode_step(cfg, mesh,
                                                 params_like=params)
        caches = model_lib.init_caches(cfg, batch, total, dtype=jnp.float32)
        logits, caches = prefill_step(params, {"tokens": jnp.asarray(prompts)},
                                      caches)
        tok_ref = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        for i in range(batch):
            _, k_l, v_l = engine._prefill(jnp.asarray(prompts[i: i + 1]))
            cache.allocate(i, total)
            cache.write_prefill(i, k_l[:, 0, :prompt_len],
                                v_l[:, 0, :prompt_len])
            btables[i] = cache.block_table_row(i)
        tok_paged = tok_ref
        for i in range(steps):
            pos = prompt_len + i
            ref_logits, caches = decode_step(params, tok_ref, caches,
                                             jnp.int32(pos))
            lg, k_pool, v_pool, _ = engine._decode(
                params, tok_paged, cache.k_pool, cache.v_pool,
                jnp.asarray(btables), jnp.full((batch,), pos, jnp.int32),
                jnp.ones((batch,), bool))
            cache.sync_pools(k_pool, v_pool)
            worst = max(worst, float(jnp.max(jnp.abs(
                lg[:, 0] - ref_logits[:, 0]))))
            tok_ref = jnp.argmax(ref_logits[:, -1:], axis=-1).astype(jnp.int32)
            tok_paged = jnp.argmax(lg[:, :1], axis=-1).astype(jnp.int32)
    return worst


def fused_vs_gather_probe(cfg, params, *, batch: int = 2, prompt_len: int = 5,
                          steps: int = 3, page_size: int = 4,
                          attention_impl: str = "auto") -> float:
    """Max |fused − gather| decode logit difference at fp32.

    Runs aligned decode steps through two engines sharing one paged cache —
    one on the fused page-walk kernel, one on the gather oracle — feeding
    both the oracle's argmax token each step, and returns the worst
    absolute logit difference.  The fused path's online softmax
    re-associates the reduction, so the contract is ``<= FUSED_LOGIT_TOL``
    (gated in ``serve traffic``, ``benchmarks.hotpath_bench`` and the
    tier-1 tests), not bit-exactness; exact parity of the *sampled token
    streams* on seeded traces is asserted separately.
    """
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    total = prompt_len + steps + 1
    kw = dict(max_batch=batch, page_size=page_size,
              max_seq_len=_bucket(total))
    fused = ServingEngine(cfg, params, attention="fused",
                          attention_impl=attention_impl, **kw)
    gather = ServingEngine(cfg, params, attention="gather", **kw)
    rng = np.random.default_rng(1234)
    prompts = rng.integers(0, cfg.vocab_size,
                           (batch, prompt_len)).astype(np.int32)
    cache = PagedKVCache(
        num_layers=cfg.num_layers, num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.resolved_head_dim, num_pages=fused.num_pages,
        page_size=page_size, max_seq_len=fused.max_seq_len)
    btables = np.zeros((batch, cache.max_blocks), np.int32)
    worst = 0.0
    with fused._mesh:
        for i in range(batch):
            _, k_l, v_l = gather._prefill(jnp.asarray(prompts[i: i + 1]))
            cache.allocate(i, total)
            cache.write_prefill(i, k_l[:, 0, :prompt_len],
                                v_l[:, 0, :prompt_len])
            btables[i] = cache.block_table_row(i)
        tok = jnp.asarray(prompts[:, -1:])  # any aligned token works
        for i in range(steps):
            pos = prompt_len + i
            args = (jnp.asarray(btables), jnp.full((batch,), pos, jnp.int32),
                    jnp.ones((batch,), bool))
            lg_f, _, _, _ = fused._decode(params, tok, cache.k_pool,
                                          cache.v_pool, *args)
            lg_g, k_pool, v_pool, _ = gather._decode(params, tok,
                                                     cache.k_pool,
                                                     cache.v_pool, *args)
            cache.sync_pools(k_pool, v_pool)  # both paths scatter identically
            worst = max(worst, float(jnp.max(jnp.abs(lg_f - lg_g))))
            tok = jnp.argmax(lg_g[:, :1], axis=-1).astype(jnp.int32)
    return worst


class ServingEngine:
    """Paged continuous/static batching over the backend/plan/grid stack."""

    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 page_size: int = 8, num_pages: int | None = None,
                 max_seq_len: int = 64, backend: str | None = None,
                 plan=None, bits: int = 4, grid: tuple[int, int] | None = None,
                 unit_n: int = 64, num_units: int = 64,
                 pricing_design: str | None = None, prompt_seed: int = 0,
                 packed: bool = False, attention: str = "fused",
                 attention_impl: str = "auto", batched_prefill: bool = True):
        if cfg.attention != "gqa" or cfg.ssm is not None or cfg.rwkv is not None \
                or cfg.family not in ("dense", "audio", "vlm") or cfg.is_moe:
            raise ValueError(
                "ServingEngine supports the dense GQA transformer family "
                f"(got family={cfg.family!r}, attention={cfg.attention!r})")
        if backend is not None and plan is not None:
            raise ValueError("pass either backend= or plan=, not both")
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.page_size = page_size
        self.max_seq_len = max_seq_len
        self.backend = backend
        self.plan = plan
        self.bits = bits
        self.grid = grid
        self.prompt_seed = prompt_seed
        blocks_per_req = -(-max_seq_len // page_size)
        # default pool: every slot can hold a worst-case request, +1 trash page
        self.num_pages = (1 + max_batch * blocks_per_req
                          if num_pages is None else num_pages)
        design = pricing_design or backend or "tubgemm"
        # EnergyModel (and any measurement) always reads the FLOAT leaves —
        # Eq.-1 pricing and cycle evidence must not depend on the storage
        # format.  Only *execution* switches to the bit-packed store.
        self.energy = EnergyModel(cfg, params, design=design, bits=bits,
                                  unit_n=unit_n, num_units=num_units, grid=grid)
        self.packed = packed
        if packed:
            if backend is None and plan is None:
                raise ValueError("packed=True needs a backend= or plan= "
                                 "scope to fix each site's bit-width")
            if plan is not None:
                self._exec_params = backends_lib.pack_weights(
                    cfg, params, plan, grid=grid)
            else:
                self._exec_params = backends_lib.pack_weights(
                    cfg, params, bits=bits, grid=grid)
        else:
            self._exec_params = params
        if attention not in ("fused", "gather"):
            raise ValueError(f"attention must be 'fused' or 'gather', "
                             f"got {attention!r}")
        if attention_impl not in ("auto", "xla", "pallas"):
            raise ValueError(f"attention_impl must be 'auto', 'xla' or "
                             f"'pallas', got {attention_impl!r}")
        self.attention = attention
        self.attention_impl = attention_impl
        # interpret= fallback: the Pallas kernel emulates its grid on
        # non-TPU hosts (the tier-1 CPU suite exercises exactly this)
        self._fused_interpret = (attention_impl == "pallas"
                                 and jax.default_backend() != "tpu")
        self.batched_prefill = batched_prefill
        self._mesh = make_grid_mesh(*grid) if grid else single_device_mesh()
        self._decode = jax.jit(self._decode_fn)

    # -- jitted model steps ---------------------------------------------------

    def _decode_fn(self, params, tokens, k_pool, v_pool, block_tables,
                   lengths, active):
        """One ragged decode step for the whole batch.

        tokens (B, 1) int32; pools (L, P, page, KVH, hd); block_tables
        (B, max_blocks) int32; lengths (B,) int32 — each slot's own position
        for the incoming token; active (B,) bool.  Mirrors
        ``blocks._transformer_block`` exactly (sites, scopes, op order) with
        the contiguous ``dynamic_update_slice`` cache swapped for the paged
        scatter/gather path.
        """
        cfg = self.cfg
        x = model_lib.embed_in(params, cfg, tokens)          # (B, 1, D)
        x = jnp.where(active[:, None, None], x, jnp.zeros((), x.dtype))
        positions = lengths[:, None].astype(jnp.int32)

        def body(carry, xs):
            xh = carry
            lp, pk, pv = xs
            with site_scope("layers"):
                h = rmsnorm(lp["ln1"], xh, cfg.rms_eps)
                with site_scope("attn"):
                    q = dense(lp["attn"]["wq"], h, cfg, name="wq")
                    k = dense(lp["attn"]["wk"], h, cfg, name="wk")
                    v = dense(lp["attn"]["wv"], h, cfg, name="wv")
                    q = rope_lib.apply_rope(q, positions, cfg.rope_theta)
                    k = rope_lib.apply_rope(k, positions, cfg.rope_theta)
                    pk = paged_lib.write_kv_token(pk, block_tables, lengths,
                                                  k[:, 0], self.page_size)
                    pv = paged_lib.write_kv_token(pv, block_tables, lengths,
                                                  v[:, 0], self.page_size)
                    if self.attention == "fused":
                        out = fused_lib.fused_paged_decode_attention(
                            q, pk, pv, block_tables, lengths + 1,
                            num_heads=cfg.num_heads, impl=self.attention_impl,
                            interpret=self._fused_interpret)
                    else:
                        out = paged_lib.paged_decode_attention(
                            q, pk, pv, block_tables, lengths + 1,
                            num_heads=cfg.num_heads)
                    out = attn_lib._out_proj(lp["attn"], out, cfg)
                xh = xh + out
                h2 = rmsnorm(lp["ln2"], xh, cfg.rms_eps)
                with site_scope("mlp"):
                    xh = xh + mlp_fwd(lp["mlp"], h2, cfg)
            return xh, (pk, pv)

        x, (new_k, new_v) = jax.lax.scan(
            body, x, (params["layers"], k_pool, v_pool))
        logits = model_lib.logits_out(params, cfg, x)
        # lengths advance on-device so the host never re-uploads them
        new_lengths = jnp.where(active, lengths + 1, lengths)
        return logits, new_k, new_v, new_lengths

    def _prefill_cache_key(self, s: int) -> tuple:
        """Everything a compiled prefill's trace depends on, besides params.

        The plan/backend scope and the activation-scale mode are bound at
        trace time, so they are part of the key; parameter *values* (and
        packed-vs-float storage) are jit arguments and retrace on their
        own.  Engines built with equal keys share one compiled entry.
        """
        try:
            plan_key = hash(self.plan) if self.plan is not None else None
        except TypeError:  # unhashable plan object: no sharing across plans
            plan_key = id(self.plan)
        return (self.cfg, self.backend, self.bits, plan_key, self.grid,
                activation_scale_mode(), s)

    def _prefill(self, tokens):
        """(n, S) padded prompts -> (logits, stacked K, stacked V)."""
        s = tokens.shape[1]
        cfg = self.cfg

        def make():
            def prefill_fn(params, toks):
                caches = model_lib.init_caches(cfg, toks.shape[0],
                                               toks.shape[1],
                                               dtype=jnp.float32)
                logits, new = model_lib.prefill(params, cfg, toks,
                                                caches=caches)
                return logits, new["attn"]["k"], new["attn"]["v"]

            return jax.jit(prefill_fn)

        fn = _prefill_cache_get(self._prefill_cache_key(s), make)
        return fn(self._exec_params, tokens)

    # -- host-side serving loop -----------------------------------------------

    def prompt_tokens(self, req: TrafficRequest) -> np.ndarray:
        """Deterministic synthetic prompt for a request (seeded per id)."""
        rng = np.random.default_rng([self.prompt_seed, req.req_id])
        return rng.integers(0, self.cfg.vocab_size,
                            req.prompt_len).astype(np.int32)

    def _scope(self):
        if self.plan is not None:
            return backends_lib.use_plan(self.plan, grid=self.grid)
        if self.backend is not None:
            return backends_lib.use_backend(self.backend, bits=self.bits,
                                            grid=self.grid)
        return contextlib.nullcontext()

    def run(self, trace: tuple[TrafficRequest, ...],
            scheduler: str | _SchedulerBase = "continuous") -> ServingReport:
        """Serve ``trace`` to completion; returns the metrics report.

        Per step: (1) one jitted decode step advances every running request
        by a token (finished ones are evicted at the boundary: pages freed,
        slot zeroed); (2) the scheduler admits arrivals into freed slots —
        admitted requests prefill now (their first token counts this step)
        and join decode from the next step.
        """
        if not trace:
            raise ValueError("empty traffic trace")
        if isinstance(scheduler, str):
            scheduler = make_scheduler(scheduler, self.max_batch)
        if scheduler.max_batch != self.max_batch:
            raise ValueError("scheduler.max_batch != engine max_batch")
        cfg = self.cfg
        cache = PagedKVCache(
            num_layers=cfg.num_layers, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.resolved_head_dim, num_pages=self.num_pages,
            page_size=self.page_size, max_seq_len=self.max_seq_len)
        for req in trace:
            if req.total_len > cache.max_seq_len:
                raise ValueError(f"request {req.req_id} needs {req.total_len} "
                                 f"positions > max_seq_len {cache.max_seq_len}")
            if cache.pages_needed(req.total_len) > cache.allocator.capacity:
                raise ValueError(f"request {req.req_id} can never be admitted: "
                                 f"needs {cache.pages_needed(req.total_len)} "
                                 f"pages, pool holds {cache.allocator.capacity}")

        b = self.max_batch
        lengths = np.zeros(b, np.int64)     # host mirror for cache bookkeeping
        active = np.zeros(b, bool)
        slot_req: list[Request | None] = [None] * b
        # hot-path state lives device-resident: block tables and lengths are
        # updated incrementally with .at[].set at admission/eviction (and
        # lengths advance inside the jitted step itself), so the per-step
        # host->device upload of (B, max_blocks) tables disappears
        d_tokens = jnp.zeros((b, 1), jnp.int32)
        d_lengths = jnp.zeros((b,), jnp.int32)
        d_active = jnp.zeros((b,), bool)
        d_btables = jnp.zeros((b, cache.max_blocks), jnp.int32)

        waiting = deque(Request(spec=r)
                        for r in sorted(trace, key=lambda r: (r.arrival_step,
                                                              r.req_id)))
        finished: list[Request] = []
        events: list[tuple[int, str, int]] = []
        req_tokens: dict[int, list[int]] = {r.req_id: [] for r in trace}
        tokens_total = 0
        energy_uj = 0.0
        decode_ticks = 0
        decoded_slots = 0
        step = 0
        max_steps = (max(r.arrival_step for r in trace)
                     + 2 * sum(r.output_len + 1 for r in trace) + 16)

        def finish(req: Request, at: int, slot: int) -> None:
            nonlocal d_tokens, d_lengths, d_active, d_btables
            req.state = RequestState.FINISHED
            req.finish_step = at
            cache.free_request(req.req_id)
            slot_req[slot] = None
            active[slot] = False
            lengths[slot] = 0
            d_tokens = d_tokens.at[slot, 0].set(0)
            d_lengths = d_lengths.at[slot].set(0)
            d_active = d_active.at[slot].set(False)
            d_btables = d_btables.at[slot].set(0)   # back to the trash page
            finished.append(req)
            events.append((at, "evict", req.req_id))

        def prefill_admissions(reqs: list[Request]) -> dict:
            """req_id -> (last-logits row, K rows, V rows) for this step's
            admissions — one jitted prefill call per ``_bucket(prompt_len)``
            group (or per request when ``batched_prefill=False``).

            Causal attention makes each padded prompt's valid prefix
            independent of both the tail padding and the other prompts in
            the batch, so grouping changes nothing the tests can see —
            ``tests/test_paged_fused.py`` pins the token streams identical
            to the per-request path.
            """
            groups: dict[object, list] = {}
            for req in reqs:
                key = (_bucket(req.spec.prompt_len) if self.batched_prefill
                       else ("solo", req.spec.req_id))
                groups.setdefault(key, []).append(req.spec)
            out = {}
            for specs in groups.values():
                width = _bucket(max(s.prompt_len for s in specs))
                padded = np.zeros((len(specs), width), np.int32)
                for i, spec in enumerate(specs):
                    padded[i, : spec.prompt_len] = self.prompt_tokens(spec)
                logits, k_l, v_l = self._prefill(jnp.asarray(padded))
                for i, spec in enumerate(specs):
                    out[spec.req_id] = (logits[i, spec.prompt_len - 1],
                                        k_l[:, i, : spec.prompt_len],
                                        v_l[:, i, : spec.prompt_len])
            return out

        def admit(req: Request, at: int, last_logits, k_rows, v_rows) -> None:
            nonlocal d_tokens, d_lengths, d_active, d_btables
            spec = req.spec
            cache.allocate(spec.req_id, spec.total_len)
            cache.write_prefill(spec.req_id, k_rows, v_rows)
            first = int(jnp.argmax(last_logits))
            slot = next(i for i in range(b) if slot_req[i] is None)
            slot_req[slot] = req
            lengths[slot] = spec.prompt_len
            active[slot] = True
            d_tokens = d_tokens.at[slot, 0].set(first)
            d_lengths = d_lengths.at[slot].set(spec.prompt_len)
            d_active = d_active.at[slot].set(True)
            d_btables = d_btables.at[slot].set(
                jnp.asarray(cache.block_table_row(spec.req_id), jnp.int32))
            req.state = RequestState.RUNNING
            req.admitted_step = at
            req.slot = slot
            req.generated = 1
            req_tokens[spec.req_id].append(first)
            events.append((at, "admit", spec.req_id))
            nonlocal tokens_total, energy_uj
            tokens_total += 1
            # charged exactly once per admission, at the prompt's TRUE row
            # count (not the padded bucket, not the prefill group size); the
            # first token comes off the prefill's last logits, so no decode
            # tick is charged for it — tests/test_paged_fused.py pins
            # energy == prefill(P) + decode-per-tick against the event
            # stream so a double charge can never creep back in
            energy_uj += self.energy.prefill_energy_uj(spec.prompt_len)
            if req.generated >= spec.output_len:
                finish(req, at, slot)

        with self._mesh, self._scope():
            while waiting or any(active):
                if step > max_steps:
                    raise RuntimeError("serving loop exceeded its step bound "
                                       "— scheduler stuck?")
                # 1) decode the running set (admitted before this step)
                n_active = int(active.sum())
                if n_active:
                    logits, k_pool, v_pool, d_lengths = self._decode(
                        self._exec_params, d_tokens, cache.k_pool,
                        cache.v_pool, d_btables, d_lengths, d_active)
                    cache.sync_pools(k_pool, v_pool)
                    nxt_dev = jnp.argmax(logits[:, 0],
                                         axis=-1).astype(jnp.int32)
                    d_tokens = nxt_dev[:, None]
                    nxt = np.asarray(nxt_dev)
                    decode_ticks += 1
                    decoded_slots += n_active
                    energy_uj += self.energy.decode_energy_uj(n_active)
                    for slot in range(b):
                        req = slot_req[slot]
                        if req is None:
                            continue
                        lengths[slot] += 1          # KV written for the input
                        cache.lengths[req.req_id] = int(lengths[slot])
                        req.generated += 1
                        req_tokens[req.req_id].append(int(nxt[slot]))
                        tokens_total += 1
                        if req.generated >= req.spec.output_len:
                            finish(req, step, slot)
                # 2) step boundary: admit arrivals (join decode next step);
                # same-step admissions share one prefill call per bucket
                admitted = scheduler.admissions(step, list(waiting),
                                                int(active.sum()), cache)
                if admitted:
                    prefills = prefill_admissions(admitted)
                    for req in admitted:
                        waiting.remove(req)
                        admit(req, step, *prefills[req.spec.req_id])
                step += 1

        lat = np.array([r.latency for r in finished])
        qd = np.array([r.queue_delay for r in finished])
        return ServingReport(
            scheduler=scheduler.name,
            requests=len(finished),
            tokens=tokens_total,
            steps=step,
            throughput_tok_per_step=tokens_total / max(step, 1),
            latency_p50=float(np.percentile(lat, 50)),
            latency_p99=float(np.percentile(lat, 99)),
            queue_delay_mean=float(qd.mean()),
            occupancy=decoded_slots / max(decode_ticks * b, 1),
            energy_uj=energy_uj,
            energy_per_token_uj=energy_uj / max(tokens_total, 1),
            design=self.energy.design,
            bits=self.bits,
            max_batch=b,
            page_size=self.page_size,
            num_pages=self.num_pages,
            events=tuple(events),
            latencies=tuple(int(v) for v in lat),
            request_tokens={k: tuple(v) for k, v in req_tokens.items()},
        )
