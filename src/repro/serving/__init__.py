"""Continuous-batching serving on the unary backend/plan/grid stack.

The request-serving loop the ROADMAP's north star hangs off: a paged KV
cache (``paged_kv``) read through the gather-based decode path in
``kernels.paged_attention``, a continuous-batching scheduler with
page-reservation admission control (``scheduler``), a seeded synthetic
traffic generator (``traffic``), Eq.-1 energy-per-token accounting
(``energy``), and the engine that jits one ragged decode step for the whole
batch under ``use_backend(...)``/``use_plan(...)`` (``engine``).

See ``docs/SERVING.md`` for the scheduler states, page-table layout,
admission rules and accounting; ``tests/test_serving.py`` pins the
allocator invariants, the paged-vs-contiguous bit-exactness, and the
seed-determinism of the whole loop.
"""

from repro.serving.engine import (FUSED_LOGIT_TOL, ServingEngine,
                                  ServingReport, fused_vs_gather_probe,
                                  paged_vs_contiguous_probe)
from repro.serving.paged_kv import OutOfPages, PageAllocator, PagedKVCache
from repro.serving.scheduler import (ContinuousBatchingScheduler, Request,
                                     RequestState, StaticBatchingScheduler,
                                     make_scheduler)
from repro.serving.traffic import TrafficConfig, TrafficRequest, generate_trace

__all__ = [
    "ServingEngine", "ServingReport", "paged_vs_contiguous_probe",
    "fused_vs_gather_probe", "FUSED_LOGIT_TOL",
    "OutOfPages", "PageAllocator", "PagedKVCache",
    "ContinuousBatchingScheduler", "StaticBatchingScheduler",
    "Request", "RequestState", "make_scheduler",
    "TrafficConfig", "TrafficRequest", "generate_trace",
]
