"""Request lifecycle + batching schedulers (continuous vs static).

A request moves ``WAITING -> RUNNING -> FINISHED``:

* WAITING — arrived (its ``arrival_step`` has passed) but not admitted;
* RUNNING — admitted: pages reserved, prompt prefilled, first token out,
  occupying one batch slot of the engine's fixed decode batch;
* FINISHED — produced its ``output_len``-th token; slot and pages freed at
  the step boundary (eviction happens mid-trace, not at end-of-batch).

Admission rule (both schedulers, documented in docs/SERVING.md): a request
is admitted only when a batch slot is free AND the allocator can reserve
``ceil((prompt_len + output_len) / page_size)`` pages up front — the full
worst-case footprint — so a running request can never hit an out-of-pages
fault mid-decode and no preemption/swapping machinery is needed.  Admission
is strict FIFO by arrival (head-of-line blocking is deterministic and fair;
no request can starve).

:class:`ContinuousBatchingScheduler` admits at every step boundary into any
freed slot; :class:`StaticBatchingScheduler` is the baseline the benchmark
gate compares against — it fills a batch, then admits nothing until *every*
request in the batch has finished (classic static batching; freed slots sit
idle, which is exactly the occupancy the continuous scheduler recovers).
"""

from __future__ import annotations

import dataclasses
import enum

from repro.serving.paged_kv import PagedKVCache
from repro.serving.traffic import TrafficRequest

__all__ = ["RequestState", "Request", "ContinuousBatchingScheduler",
           "StaticBatchingScheduler", "make_scheduler"]


class RequestState(enum.Enum):
    WAITING = "waiting"
    RUNNING = "running"
    FINISHED = "finished"


@dataclasses.dataclass
class Request:
    """Runtime state wrapped around one immutable trace entry."""
    spec: TrafficRequest
    state: RequestState = RequestState.WAITING
    admitted_step: int = -1
    finish_step: int = -1
    generated: int = 0
    slot: int = -1

    @property
    def req_id(self) -> int:
        return self.spec.req_id

    @property
    def latency(self) -> int:
        """Completion latency in decode steps (finish - arrival)."""
        assert self.state is RequestState.FINISHED
        return self.finish_step - self.spec.arrival_step

    @property
    def queue_delay(self) -> int:
        return self.admitted_step - self.spec.arrival_step


class _SchedulerBase:
    """Shared FIFO + page-reservation admission; subclasses gate *when*."""

    name = "base"

    def __init__(self, max_batch: int) -> None:
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.max_batch = max_batch

    def admissions(self, step: int, waiting: list[Request],
                   n_running: int, cache: PagedKVCache) -> list[Request]:
        """Requests to admit at this step boundary, in FIFO order.

        Callers admit each returned request (allocating its pages) before
        this is consulted again, so the free-page check here uses a running
        tally of what the earlier picks will consume.
        """
        if not self._may_admit(n_running):
            return []
        picked: list[Request] = []
        budget = cache.allocator.num_free
        for req in waiting:
            if req.spec.arrival_step > step:
                break  # FIFO by arrival; later entries arrived even later
            if n_running + len(picked) >= self.max_batch:
                break
            need = cache.pages_needed(req.spec.total_len)
            if need > budget:
                break  # strict FIFO: head-of-line blocks (deterministic)
            budget -= need
            picked.append(req)
        return picked

    def _may_admit(self, n_running: int) -> bool:
        raise NotImplementedError


class ContinuousBatchingScheduler(_SchedulerBase):
    """Join new requests at every step boundary, evict finished mid-decode."""

    name = "continuous"

    def _may_admit(self, n_running: int) -> bool:
        return True


class StaticBatchingScheduler(_SchedulerBase):
    """Baseline: admit a batch, then wait for ALL of it to finish.

    Admission is possible only while the batch is empty — once anything
    runs, freed slots stay idle until the whole batch drains (it does not
    wait for ``max_batch`` arrivals: at the end of a trace that would
    deadlock on a partial batch)."""

    name = "static"

    def _may_admit(self, n_running: int) -> bool:
        return n_running == 0


def make_scheduler(name: str, max_batch: int) -> _SchedulerBase:
    try:
        cls = {"continuous": ContinuousBatchingScheduler,
               "static": StaticBatchingScheduler}[name]
    except KeyError:
        raise ValueError(f"unknown scheduler {name!r}") from None
    return cls(max_batch)
