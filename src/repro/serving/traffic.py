"""Seeded synthetic request traffic: Poisson arrivals, mixed length mixture.

All randomness flows from one ``np.random.default_rng(seed)`` — no module
state, no wall clock — so the same seed always produces the identical trace
(pinned by ``tests/test_serving.py``) and two engines can be compared on
byte-identical workloads.  Time is measured in *scheduler steps* (one decode
step per step), matching the engine's latency unit.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["TrafficConfig", "TrafficRequest", "generate_trace"]


@dataclasses.dataclass(frozen=True)
class TrafficRequest:
    """One synthetic request: arrives at ``arrival_step``, carries a
    ``prompt_len``-token prompt, and wants ``output_len`` generated tokens."""
    req_id: int
    arrival_step: int
    prompt_len: int
    output_len: int

    @property
    def total_len(self) -> int:
        return self.prompt_len + self.output_len


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    """Poisson arrivals at ``arrival_rate`` requests/step; prompt and output
    lengths drawn from a short/long mixture (``p_long`` weighs the long
    range) — the bimodal mix interactive serving actually sees."""
    num_requests: int = 16
    arrival_rate: float = 0.5
    prompt_short: tuple[int, int] = (2, 8)
    prompt_long: tuple[int, int] = (12, 24)
    output_short: tuple[int, int] = (2, 6)
    output_long: tuple[int, int] = (8, 16)
    p_long: float = 0.3
    seed: int = 0


def _mixture(rng: np.random.Generator, short: tuple[int, int],
             long: tuple[int, int], p_long: float) -> int:
    lo, hi = long if rng.random() < p_long else short
    return int(rng.integers(lo, hi + 1))


def generate_trace(tcfg: TrafficConfig) -> tuple[TrafficRequest, ...]:
    """Deterministic trace for ``tcfg`` — same config (incl. seed) ⇒ same
    trace, element for element."""
    if tcfg.num_requests < 1:
        raise ValueError("num_requests must be >= 1")
    if tcfg.arrival_rate <= 0:
        raise ValueError("arrival_rate must be > 0")
    rng = np.random.default_rng(tcfg.seed)
    inter = rng.exponential(1.0 / tcfg.arrival_rate, size=tcfg.num_requests)
    arrivals = np.floor(np.cumsum(inter)).astype(int)
    out = []
    for i in range(tcfg.num_requests):
        out.append(TrafficRequest(
            req_id=i,
            arrival_step=int(arrivals[i]),
            prompt_len=_mixture(rng, tcfg.prompt_short, tcfg.prompt_long,
                                tcfg.p_long),
            output_len=_mixture(rng, tcfg.output_short, tcfg.output_long,
                                tcfg.p_long),
        ))
    return tuple(out)
