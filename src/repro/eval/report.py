"""Serialize a :class:`~repro.eval.sweetspot.SweetspotReport`.

Two renderings of the same report object:

* :func:`to_json` — machine-readable (every sweep point, winner, crossover
  and kernel cross-check row, plus the sweep axes) for downstream tooling.
* :func:`to_markdown` — human-readable: one winner table per metric
  (rows = bit-width, columns = matrix size, cell = winning design and its
  margin over the runner-up), the crossover frontier, grid fidelity vs the
  paper tables, and the kernel cycle cross-check.

:func:`write` emits both next to each other (``sweetspot.json`` /
``sweetspot.md``), which is what ``benchmarks.run sweetspot`` calls.
"""

from __future__ import annotations

import dataclasses
import json
import os

from repro.eval.sweetspot import METRICS, SweetspotReport

__all__ = ["to_json", "to_markdown", "write"]

_UNITS = {"area_um2": "um^2", "power_mw": "mW", "latency_ns": "ns",
          "energy_nj": "nJ", "adp_mm2_ns": "mm^2*ns"}


def to_json(report: SweetspotReport, indent: int = 2) -> str:
    """Render the full report as a JSON document (str)."""
    doc = dataclasses.asdict(report)
    # JSON objects need string keys; Winner.values already uses design names
    doc["schema"] = "repro.eval.sweetspot/v1"
    return json.dumps(doc, indent=indent, sort_keys=False)


def _winner_table(report: SweetspotReport, metric: str) -> list[str]:
    cells = {(w.bits, w.n): w for w in report.winners if w.metric == metric}
    head = "| bits \\ n | " + " | ".join(str(n) for n in report.sizes) + " |"
    sep = "|" + "---|" * (len(report.sizes) + 1)
    lines = [f"### {metric} [{_UNITS.get(metric, '')}]", "", head, sep]
    for bits in report.bits:
        row = [f"| **{bits}b** "]
        for n in report.sizes:
            w = cells[(bits, n)]
            star = "" if _on_grid(report, bits, n) else "~"
            row.append(f"| {star}{w.design} ({w.margin:.2f}x) ")
        lines.append("".join(row) + "|")
    lines.append("")
    return lines


def _on_grid(report: SweetspotReport, bits: int, n: int) -> bool:
    for p in report.points:
        if p.bits == bits and p.n == n:
            return p.on_grid
    return False


def to_markdown(report: SweetspotReport) -> str:
    """Render the report as markdown tables (str)."""
    lines = [
        "# Sweet-spot report",
        "",
        f"Designs: {', '.join(report.designs)} — bit-widths "
        f"{list(report.bits)}, sizes {list(report.sizes)}.",
        "Each cell names the winning (lowest) design and its margin over the",
        "runner-up; `~` marks off-grid points priced by the log-log fit",
        "(grid points are the paper's exact post-synthesis values).",
        "",
    ]
    for metric in METRICS:
        lines += _winner_table(report, metric)

    lines += ["## Crossover frontier", ""]
    if report.crossovers:
        lines.append("| metric | bits | winner below | n range | winner from |")
        lines.append("|---|---|---|---|---|")
        for c in report.crossovers:
            lines.append(f"| {c.metric} | {c.bits}b | {c.from_design} "
                         f"| {c.n_below} -> {c.n_at} | {c.to_design} |")
    else:
        lines.append("No winner changes along n on the swept grid.")
    lines.append("")

    lines += ["## Grid fidelity vs paper tables", ""]
    lines.append("| metric | max rel err on grid |")
    lines.append("|---|---|")
    for m, e in report.grid_fidelity.items():
        lines.append(f"| {m} | {e:.2%} |")
    lines.append("")

    if report.kernel_crosscheck:
        lines += ["## Pallas kernel cross-check", "",
                  "| design | bits | output == simulator | kernel cycles "
                  "| sim cycles | wc_cycles model | cycles agree |",
                  "|---|---|---|---|---|---|---|"]
        for r in report.kernel_crosscheck:
            lines.append(
                f"| {r['kernel']} | {r['bits']}b | {r['output_ok']} "
                f"| {r['kernel_cycles']} | {r['sim_cycles']} "
                f"| {r['wc_cycles']} | {r['cycles_ok']} |")
        lines.append("")
    return "\n".join(lines)


def write(report: SweetspotReport, out_dir: str = "reports",
          stem: str = "sweetspot") -> tuple[str, str]:
    """Write ``<out_dir>/<stem>.json`` and ``.md``; returns the two paths."""
    os.makedirs(out_dir, exist_ok=True)
    json_path = os.path.join(out_dir, stem + ".json")
    md_path = os.path.join(out_dir, stem + ".md")
    with open(json_path, "w") as f:
        f.write(to_json(report))
    with open(md_path, "w") as f:
        f.write(to_markdown(report))
    return json_path, md_path
