"""Sweet-spot explorer: cross-design PPA sweeps over bits x size x design.

The paper's §IV contribution beyond the individual units is the *sweet-spot
analysis*: post-synthesis PPA swept across bit-widths and matrix sizes to
find where each unary design beats binary GEMM (Tables I-IV, Fig. 2).  This
module turns that from a fixed set of tables into an explorable space:

* :func:`sweep` prices every (design, bits, n) point through ``core.ppa`` —
  paper-grid points are the exact published values, off-grid points come from
  the per-design log-log fit (tested monotone in ``n`` and exact on the grid).
* :func:`winners` / :func:`winner_grid` reduce the sweep to the per-metric
  winning design at every (bits, n), with the margin over the runner-up.
* :func:`crossovers` finds the frontier: walking ``n`` upward at fixed bits,
  the points where a metric's winner changes hands (e.g. the tubGEMM-over-
  bGEMM 4-bit energy takeover between 32x32 and 64x64 the paper highlights).
* :func:`kernel_crosscheck` executes the Pallas kernels (resolved as typed
  ``repro.backends`` objects — no registry mutation) and verifies their
  outputs and cycle reports against the stream simulators and ``wc_cycles``.
* :func:`recommend_backend` prices a *model's* recorded GEMM workload
  (``core.accounting``) on every design and names the optimal backend for the
  model's actual layer shapes — wired into ``launch/serve.py``.

Units note (everything lower-is-better): ``area_um2`` um^2, ``power_mw`` mW,
``latency_ns`` ns (worst-case), ``energy_nj`` nJ per GEMM, ``adp_mm2_ns``
mm^2*ns.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

from repro.configs import paper_gemm
from repro.core import ppa
from repro.core import gemm_sims
from repro.core.accounting import GemmCall

__all__ = [
    "METRICS",
    "DEFAULT_BITS",
    "DEFAULT_SIZES",
    "CALIBRATED_DESIGNS",
    "SweepPoint",
    "Winner",
    "Crossover",
    "SweetspotReport",
    "sweep",
    "winners",
    "winner_grid",
    "crossovers",
    "kernel_crosscheck",
    "grid_fidelity",
    "build_report",
    "recommend_backend",
]

#: metric name -> pricing function (design, bits, n) -> float; all lower-better
METRICS: tuple[str, ...] = ("area_um2", "power_mw", "latency_ns",
                            "energy_nj", "adp_mm2_ns")

DEFAULT_BITS: tuple[int, ...] = (2, 4, 8)
DEFAULT_SIZES: tuple[int, ...] = (16, 32, 64, 128, 256)

#: the four designs the paper synthesized (the only ones ppa can price)
CALIBRATED_DESIGNS: tuple[str, ...] = paper_gemm.DESIGNS

_METRIC_FNS = {
    "area_um2": ppa.area_um2,
    "power_mw": ppa.power_mw,
    "latency_ns": lambda d, b, n: ppa.latency_ns(d, b, n),
    "energy_nj": lambda d, b, n: ppa.energy_nj(d, b, n),
    "adp_mm2_ns": ppa.adp_mm2_ns,
}


@dataclasses.dataclass(frozen=True)
class SweepPoint:
    """One priced configuration: an n x n ``design`` unit at ``bits`` width.

    ``on_grid`` is True iff (bits, n) is a paper-synthesized point, i.e. the
    metric values are the exact published Table I/II numbers (and Table
    III/IV derivations) rather than log-log-fit extrapolations.
    """

    design: str
    bits: int
    n: int
    on_grid: bool
    wc_cycles: int
    area_um2: float
    power_mw: float
    latency_ns: float
    energy_nj: float
    adp_mm2_ns: float

    def metric(self, name: str) -> float:
        """Value of one of :data:`METRICS` (raises AttributeError if unknown)."""
        return getattr(self, name)


@dataclasses.dataclass(frozen=True)
class Winner:
    """Per-metric winner at one (bits, n): lowest-valued design.

    ``margin`` is runner-up value / winner value (>= 1.0; how decisively the
    winner wins).  ``values`` maps every competing design to its value.
    """

    metric: str
    bits: int
    n: int
    design: str
    value: float
    runner_up: str
    margin: float
    values: dict[str, float]


@dataclasses.dataclass(frozen=True)
class Crossover:
    """A frontier edge: walking n upward at fixed bits, ``metric``'s winner
    changes from ``from_design`` (still best at ``n_below``) to ``to_design``
    (best from ``n_at`` on)."""

    metric: str
    bits: int
    n_below: int
    n_at: int
    from_design: str
    to_design: str


@dataclasses.dataclass(frozen=True)
class SweetspotReport:
    """Everything ``benchmarks.run sweetspot`` serializes."""

    bits: tuple[int, ...]
    sizes: tuple[int, ...]
    designs: tuple[str, ...]
    points: list[SweepPoint]
    winners: list[Winner]
    crossovers: list[Crossover]
    grid_fidelity: dict[str, float]
    kernel_crosscheck: list[dict]


def sweep(bits_list: Sequence[int] = DEFAULT_BITS,
          sizes: Sequence[int] = DEFAULT_SIZES,
          designs: Sequence[str] = CALIBRATED_DESIGNS) -> list[SweepPoint]:
    """Price the full (design x bits x n) cross product.

    Args: ``bits_list`` — operand widths; ``sizes`` — square unit sizes n;
    ``designs`` — registry design names (must have ppa calibration).
    Returns: one :class:`SweepPoint` per combination, grid hits exact.
    """
    pts = []
    for bits in bits_list:
        for n in sizes:
            on_grid = (bits, n) in ppa.AREA_UM2
            for d in designs:
                pts.append(SweepPoint(
                    design=d, bits=bits, n=n, on_grid=on_grid,
                    wc_cycles=gemm_sims.wc_cycles(d, bits, n),
                    **{m: float(fn(d, bits, n))
                       for m, fn in _METRIC_FNS.items()}))
    return pts


def winners(points: Iterable[SweepPoint]) -> list[Winner]:
    """Reduce a sweep to the per-(metric, bits, n) winning design."""
    by_cell: dict[tuple[int, int], list[SweepPoint]] = {}
    for p in points:
        by_cell.setdefault((p.bits, p.n), []).append(p)
    out = []
    for (bits, n), cell in sorted(by_cell.items()):
        for metric in METRICS:
            ranked = sorted(cell, key=lambda p: p.metric(metric))
            best, second = ranked[0], ranked[min(1, len(ranked) - 1)]
            out.append(Winner(
                metric=metric, bits=bits, n=n, design=best.design,
                value=best.metric(metric), runner_up=second.design,
                margin=second.metric(metric) / max(best.metric(metric), 1e-30),
                values={p.design: p.metric(metric) for p in cell}))
    return out


def winner_grid(points: Iterable[SweepPoint]
                ) -> dict[str, dict[tuple[int, int], Winner]]:
    """``{metric: {(bits, n): Winner}}`` view of :func:`winners`."""
    grid: dict[str, dict[tuple[int, int], Winner]] = {m: {} for m in METRICS}
    for w in winners(points):
        grid[w.metric][(w.bits, w.n)] = w
    return grid


def crossovers(points: Iterable[SweepPoint]) -> list[Crossover]:
    """Frontier edges: winner changes along ascending n at fixed (metric, bits)."""
    grid = winner_grid(points)
    out = []
    for metric, cells in grid.items():
        by_bits: dict[int, list[tuple[int, Winner]]] = {}
        for (bits, n), w in cells.items():
            by_bits.setdefault(bits, []).append((n, w))
        for bits, seq in sorted(by_bits.items()):
            seq.sort()
            for (n0, w0), (n1, w1) in zip(seq, seq[1:]):
                if w0.design != w1.design:
                    out.append(Crossover(metric=metric, bits=bits,
                                         n_below=n0, n_at=n1,
                                         from_design=w0.design,
                                         to_design=w1.design))
    return out


def grid_fidelity(points: Iterable[SweepPoint]) -> dict[str, float]:
    """Max relative error of on-grid sweep values vs the published tables.

    ``area_um2`` / ``power_mw`` compare against the verbatim Table I/II data
    (must be 0.0 — grid hits bypass the fit); ``energy_nj`` / ``adp_mm2_ns``
    compare the derived values against the paper's rounded Table III/IV
    entries (< 1%, the repo-wide reproduction bar).
    """
    errs = {"area_um2": 0.0, "power_mw": 0.0, "energy_nj": 0.0,
            "adp_mm2_ns": 0.0}

    def rel(got, ref):
        return abs(got - ref) / abs(ref)

    for p in points:
        if not p.on_grid:
            continue
        key = (p.bits, p.n)
        errs["area_um2"] = max(errs["area_um2"],
                               rel(p.area_um2, ppa.AREA_UM2[key][p.design]))
        errs["power_mw"] = max(errs["power_mw"],
                               rel(p.power_mw, ppa.POWER_MW[key][p.design]))
        if key in ppa.PAPER_ENERGY_NJ:
            errs["energy_nj"] = max(
                errs["energy_nj"],
                rel(p.energy_nj, ppa.PAPER_ENERGY_NJ[key][p.design]))
        if key in ppa.PAPER_ADP_MM2_NS:
            errs["adp_mm2_ns"] = max(
                errs["adp_mm2_ns"],
                rel(p.adp_mm2_ns, ppa.PAPER_ADP_MM2_NS[key][p.design]))
    return errs


def kernel_crosscheck(bits_list: Sequence[int] = (2, 4, 8),
                      mkn: tuple[int, int, int] = (8, 16, 8),
                      block: tuple[int, int, int] = (32, 32, 32),
                      seed: int = 0) -> list[dict]:
    """Run the Pallas kernel backends against their simulator siblings.

    Resolves each mirror/sibling pair as typed ``repro.backends`` objects —
    pure construction, the ``gemm_sims`` registry is never touched, so live
    ``DESIGNS`` iterators elsewhere never observe the uncalibrated mirrors.
    For each pair and bit-width both engines run the same random
    (m, k) x (k, n) operands; records bit-identity of outputs, equality of
    the kernel's cycle report with the simulator's, and with the analytic
    worst-case cycle model.  Returns one dict per (design, bits) with
    boolean ``output_ok`` / ``cycles_ok`` plus both cycle numbers.
    """
    import numpy as np
    import jax.numpy as jnp
    from repro import backends

    rng = np.random.default_rng(seed)
    m, k, n = mkn
    rows = []
    for bits in bits_list:
        v = 2 ** (bits - 1) - 1
        a = jnp.asarray(rng.integers(-v, v + 1, (m, k)), jnp.int8)
        b = jnp.asarray(rng.integers(-v, v + 1, (k, n)), jnp.int8)
        for name, sibling in backends.KERNEL_SIBLINGS.items():
            kb = backends.resolve(name, bits=bits, block=block)
            sb = backends.resolve(sibling, bits=bits)
            k_out, k_cyc = kb.stream(a, b)
            s_out, s_cyc = sb.stream(a, b)
            wc = sb.cycles(k)
            rows.append(dict(
                design=sibling, kernel=name, bits=bits, m=m, k=k, n=n,
                output_ok=bool(np.array_equal(np.asarray(k_out),
                                              np.asarray(s_out))),
                cycles_ok=(int(k_cyc) == int(s_cyc) == wc),
                kernel_cycles=int(k_cyc), sim_cycles=int(s_cyc),
                wc_cycles=wc))
    return rows


def build_report(bits_list: Sequence[int] = DEFAULT_BITS,
                 sizes: Sequence[int] = DEFAULT_SIZES,
                 designs: Sequence[str] = CALIBRATED_DESIGNS,
                 *, crosscheck: bool = True) -> SweetspotReport:
    """Assemble the full sweet-spot report (see :class:`SweetspotReport`).

    ``crosscheck=False`` skips the Pallas-kernel execution (pure cost-model
    sweep; useful where kernel interpret runs are unwanted, e.g. docs builds).
    """
    pts = sweep(bits_list, sizes, designs)
    return SweetspotReport(
        bits=tuple(bits_list), sizes=tuple(sizes), designs=tuple(designs),
        points=pts, winners=winners(pts), crossovers=crossovers(pts),
        grid_fidelity=grid_fidelity(pts),
        kernel_crosscheck=kernel_crosscheck(bits_list) if crosscheck else [])


def recommend_backend(calls: list[GemmCall], *, bits: int, unit_n: int,
                      num_units: int = 1,
                      designs: Sequence[str] = CALIBRATED_DESIGNS,
                      costs: dict | None = None) -> dict[str, dict]:
    """Name the optimal PE-array design for a model's actual GEMM workload.

    Prices ``calls`` (recorded layer shapes + measured bit sparsity, see
    ``core.accounting``) on every design at the given ``bits`` / ``unit_n``
    and ranks them.  Callers that already priced the workload (serve.py's
    cost table) pass ``costs`` — ``{design: ModelCost}`` — to skip the
    re-pricing; ``calls``/``bits``/``unit_n`` are then unused.  Returns
    ``{objective: {"best": design, "ranking": [(design, value), ...]}}`` for
    the four serving objectives — ``dyn_energy_uj``, ``wc_energy_uj`` (uJ)
    and ``dyn_latency_us``, ``wc_latency_us`` (us); lower is better,
    rankings ascending.
    """
    if costs is None:
        from repro import backends
        costs = {d: backends.resolve(d, bits=bits)
                 .price(calls, unit_n=unit_n, num_units=num_units)
                 for d in designs}
    out: dict[str, dict] = {}
    for objective in ("dyn_energy_uj", "wc_energy_uj",
                      "dyn_latency_us", "wc_latency_us"):
        ranking = sorted(((d, getattr(c, objective))
                          for d, c in costs.items()), key=lambda t: t[1])
        out[objective] = {"best": ranking[0][0], "ranking": ranking}
    return out
