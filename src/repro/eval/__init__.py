"""Design-space evaluation layer: the paper's §IV sweet-spot analysis as code.

- sweetspot : sweeps bits x matrix size x design over the ``gemm_sims``
  registry, prices every point with ``core.ppa``, finds per-metric winners
  and crossover frontiers, and cross-checks simulator cycle models against
  the Pallas kernels' cycle reports.
- planner   : the per-layer mixed-precision backend planner — profiles every
  dense GEMM site's weight sparsity, prices (design, bits) candidates with
  Eq. 1-scaled dynamic cycles under an accuracy guard, and emits a typed
  ``repro.backends.BackendPlan`` that ``use_plan`` / ``serve --backend-plan``
  execute.
- report    : serializes a sweep to machine-readable JSON and human-readable
  markdown tables (``benchmarks.run sweetspot`` writes both).
"""

from repro.eval import planner, report, sweetspot

__all__ = ["planner", "report", "sweetspot"]
