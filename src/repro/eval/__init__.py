"""Design-space evaluation layer: the paper's §IV sweet-spot analysis as code.

- sweetspot : sweeps bits x matrix size x design over the ``gemm_sims``
  registry, prices every point with ``core.ppa``, finds per-metric winners
  and crossover frontiers, and cross-checks simulator cycle models against
  the Pallas kernels' cycle reports.
- report    : serializes a sweep to machine-readable JSON and human-readable
  markdown tables (``benchmarks.run sweetspot`` writes both).
"""

from repro.eval import report, sweetspot

__all__ = ["report", "sweetspot"]
