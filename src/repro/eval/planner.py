"""Per-layer mixed-precision backend planner (paper Table V + Eq. 1 + Fig. 3
composed into a decision).

The paper's sweet-spot conclusion is a *map*, not a winner: which GEMM design
is cheapest depends on bit-width, matrix size, and — through Eq. 1 — the
measured weight bit sparsity.  This module turns that map into an executable
per-site assignment:

1. **Discover** every dense GEMM site of a model with a zero-FLOP
   ``jax.eval_shape`` trace under ``repro.backends.record_sites`` — the site
   names and contraction shapes are exactly what ``models/common.dense``
   executes under a backend scope (see the naming contract in
   ``repro.backends.runtime``).
2. **Profile** each site's weight with ``core.sparsity.profile_tensor`` at
   every candidate bit-width (word / element-bit / block-max-bit sparsity)
   and measure its quantization error (relative per-output-channel MSE, the
   accuracy-guard statistic).
3. **Price** every (site, design, bits) candidate on the ``core.ppa``
   DLA tiling with Eq. 1 sparsity-scaled dynamic cycles instead of worst
   case, drop candidates whose quantization error violates the guard —
   and, first, candidates whose accumulator envelope the site's
   contraction length provably leaves (``repro.analysis.ranges``): an
   overflow-hazardous (design, bits) is never priced, never picked, and
   never a uniform baseline, and the pruning evidence ships in the plan's
   ``range_pruned`` meta block.
4. **Pick** the per-site argmin of the objective.
5. **Emit** a typed :class:`repro.backends.plan.BackendPlan` — frozen
   site-pattern → (design, bits) entries with the predicted energy/latency
   and guard evidence — which ``repro.backends.use_plan`` executes and
   ``launch/serve.py --backend-plan`` replays.

Because every uniform single-backend assignment that satisfies the guard at
all sites is in each site's candidate set, the planned total is ≤ the best
uniform plan's total by construction (tested, together with the
monotonicity property: more sparsity never raises a temporal design's
priced dynamic energy).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import ranges as ranges_lib
from repro.backends import grid as grid_lib
from repro.backends import runtime as runtime_lib
from repro.backends.plan import BackendPlan, SiteAssignment
from repro.core import packing, ppa, sparsity
from repro.core.quantization import quantize
from repro.core.sparsity import SparsityStats

__all__ = [
    "DEFAULT_BITS_CANDIDATES",
    "DEFAULT_DESIGNS",
    "DEFAULT_MAX_REL_MSE",
    "DEFAULT_STREAM_LENS",
    "STOCHASTIC_DESIGN",
    "GemmSite",
    "Candidate",
    "discover_sites",
    "quantization_rel_mse",
    "price_site",
    "site_candidates",
    "build_plan",
    "build_grid_plan",
    "measure_site_cycles",
    "measure_grid_site_cycles",
    "plan_totals",
    "to_markdown",
    "grid_plan_to_markdown",
]

#: candidate operand widths (paper grid); 2-bit usually fails the guard
DEFAULT_BITS_CANDIDATES: tuple[int, ...] = (2, 4, 8)
#: exact calibrated designs — stochastic uGEMM is excluded by default so a
#: planned model stays bit-identical to the binary oracle
DEFAULT_DESIGNS: tuple[str, ...] = ("tugemm", "tubgemm", "bgemm")
#: default accuracy guard: per-site relative quantization MSE ceiling
DEFAULT_MAX_REL_MSE: float = 0.05
#: the rate-coded family (opt-in: add to ``designs`` + pass ``stream_lens``)
STOCHASTIC_DESIGN = ranges_lib.STOCHASTIC_FAMILY
#: default stream lengths tried per stochastic candidate (8-bit sweet
#: range: short enough to beat exact designs on cycles, long enough that
#: the analytic expected-error bound can survive the accuracy guard)
DEFAULT_STREAM_LENS: tuple[int, ...] = (16, 32, 64, 128)


@dataclasses.dataclass(frozen=True)
class GemmSite:
    """One plannable GEMM site of a model.

    ``name`` — the site name per the runtime naming contract (equals the
    weight's parameter-tree path); ``m``/``k``/``n_out`` — the per-invocation
    contraction ``(m, k) @ (k, n_out)`` ``dense`` performs there; ``count`` —
    invocations per forward pass (scanned layers, shared-block applications);
    ``leaf`` — the site's parameter-tree leaf, held by reference (zero-copy).

    The float32 profiling matrix is materialized **on demand** by
    :meth:`weight_matrix` and dropped by the caller when it moves to the
    next site, so a full-model planning pass peaks at ONE weight matrix of
    float32 scratch instead of a copy of the whole model (the ROADMAP-flagged
    memory hazard).
    """

    name: str
    m: int
    k: int
    n_out: int
    count: int
    leaf: object = dataclasses.field(repr=False, compare=False)

    def weight_matrix(self) -> np.ndarray:
        """The (count · k, n_out) float32 matrix the contraction consumes
        (all invocations stacked along rows), materialized fresh per call.

        Refuses a bit-packed leaf: the planner's sparsity/guard statistics
        and candidate quantization must read the *pre-quantization* float
        weight — silently re-quantizing a :class:`PackedQuantized` store's
        dequantized codes at a second width would compound rounding error
        into every downstream plan decision.
        """
        if packing.is_packed(self.leaf):
            raise TypeError(
                f"site {self.name!r}: leaf is an already-packed "
                f"{self.leaf.bits}-bit PackedQuantized store — plan from the "
                f"float parameters (pack with backends.pack_weights only "
                f"*after* planning); re-quantizing packed codes at a second "
                f"width compounds quantization error")
        return np.asarray(self.leaf, np.float32).reshape(-1, self.n_out)

    @property
    def weight(self) -> np.ndarray:
        """Back-compat alias for :meth:`weight_matrix` (materializes)."""
        return self.weight_matrix()


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One priced (design, bits[, stream_len]) option for a site.

    ``stream_len`` is 0 for count-exact designs.  For stochastic
    candidates ``rel_mse`` is the *combined* accuracy statistic —
    quantization rel-MSE plus the measured stream-error rel-RMSE squared
    (independent error sources; variances add) — so the guard bounds the
    end-to-end deviation from the float weight.
    """

    design: str
    bits: int
    stats: SparsityStats
    rel_mse: float
    guard_ok: bool
    dyn_energy_uj: float
    dyn_latency_us: float
    wc_energy_uj: float
    wc_latency_us: float
    stream_len: int = 0


def _leaf_index(params) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=packing.is_packed)[0]
    out = {}
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out[name] = leaf
    return out


def discover_sites(cfg, params, *, batch: int = 1,
                   seq_len: int = 8) -> list[GemmSite]:
    """Find every dense GEMM site of ``cfg``'s model, with weights attached.

    Traces one forward pass with ``jax.eval_shape`` inside a
    ``repro.backends.record_sites`` scope — no FLOPs run — and joins the
    recorded (site, k, n_out) against the parameter tree.  ``count`` per site
    is ``leaf.size / (k · n_out)`` (the stacked-layers multiplier), times the
    number of shared-block applications for the hybrid family's ``shared/…``
    sites (a scanned body traces once; see the runtime jit caveat).

    Discovery itself never materializes a weight: sites hold the parameter
    leaves by reference and stream one float32 matrix at a time through
    :meth:`GemmSite.weight_matrix` (like serve's ``_iter_weight_matrices``),
    bounding the planner's peak scratch memory at one matrix.

    ``m`` is reported for a *decode step*: ``batch`` rows per invocation
    (``seq_len`` only shapes the discovery trace).  Returns sites in model
    order, deduplicated by name.
    """
    from repro import backends
    from repro.models import model as model_lib

    tokens = jnp.zeros((batch, seq_len), jnp.int32)
    with backends.record_sites() as rec:
        if getattr(cfg, "frontend_stub", False):
            embeds = jax.ShapeDtypeStruct((batch, seq_len, cfg.d_model),
                                          jnp.float32)
            jax.eval_shape(
                lambda p, e: model_lib.forward(p, cfg, embeds=e)[0],
                params, embeds)
        else:
            jax.eval_shape(lambda p, t: model_lib.forward(p, cfg, t)[0],
                           params, tokens)

    leaves = _leaf_index(params)
    shared_applications = 1
    if getattr(cfg, "family", None) == "hybrid":
        from repro.models import blocks as blocks_lib
        shared_applications = blocks_lib.hybrid_counts(cfg)[0]

    sites: list[GemmSite] = []
    seen: set[str] = set()
    for call in rec.calls:
        if call.site in seen:
            continue
        seen.add(call.site)
        leaf = leaves.get(call.site)
        if leaf is None:
            raise ValueError(
                f"recorded site {call.site!r} has no parameter-tree leaf — "
                "a dense(name=...) annotation disagrees with the param path")
        count = leaf.size // (call.k * call.n_out)
        if count * call.k * call.n_out != leaf.size:
            raise ValueError(
                f"site {call.site!r}: leaf shape {tuple(leaf.shape)} is not "
                f"a stack of (k={call.k}, n_out={call.n_out}) matrices")
        if call.site.startswith("shared/"):
            count *= shared_applications
        sites.append(GemmSite(name=call.site, m=max(int(batch), 1),
                              k=call.k, n_out=call.n_out, count=count,
                              leaf=leaf))
    return sites


def quantization_rel_mse(w, bits: int) -> float:
    """Relative quantization MSE of ``w`` at ``bits`` — the guard statistic.

    Per-output-channel symmetric quantization (exactly what
    ``models/common.dense`` applies to the weight under a backend scope),
    dequantized and compared to the original: ``mean((w - dq)²) / mean(w²)``.
    Dimensionless; 0 = lossless, ~0.01–0.03 for 4-bit Gaussian weights,
    ≫ 0.1 for 2-bit.
    """
    w = jnp.asarray(w, jnp.float32)
    q = quantize(w, bits=bits)
    dq = q.values.astype(jnp.float32) * q.scale
    denom = float(jnp.mean(jnp.square(w)))
    return float(jnp.mean(jnp.square(w - dq))) / max(denom, 1e-30)


def price_site(design: str, bits: int, *, m: int, k: int, n_out: int,
               count: int, bit_sparsity: float, unit_n: int,
               num_units: int, cycle_scale: float = 1.0) -> dict[str, float]:
    """Price one site's per-decode-step cost on a (design, bits) DLA.

    Uses the same ``core.ppa.DLAModel`` tiling the serve cost table uses,
    with Eq. 1 ``bit_sparsity`` (block-max statistic) scaling the dynamic
    numbers and 0.0 for the worst case.  ``cycle_scale`` is the stochastic
    family's per-tile multiplier (``stream_len / 2^bits``, priced as
    uGEMM); 1.0 otherwise.  Returns µJ / µs totals over the site's
    ``count`` invocations: ``dyn_energy_uj``, ``dyn_latency_us``,
    ``wc_energy_uj``, ``wc_latency_us``.
    """
    dla = ppa.DLAModel(design=design, bits=bits, n=unit_n,
                       num_units=num_units, cycle_scale=cycle_scale)
    return {
        "dyn_energy_uj":
            dla.matmul_energy_nj(m, k, n_out, bit_sparsity) * count * 1e-3,
        "dyn_latency_us":
            dla.matmul_latency_ns(m, k, n_out, bit_sparsity) * count * 1e-3,
        "wc_energy_uj":
            dla.matmul_energy_nj(m, k, n_out, 0.0) * count * 1e-3,
        "wc_latency_us":
            dla.matmul_latency_ns(m, k, n_out, 0.0) * count * 1e-3,
    }


def prune_infeasible(site_name: str, k: int,
                     designs: Sequence[str],
                     bits_candidates: Sequence[int],
                     pruned: list | None) -> set[tuple[str, int]]:
    """(design, bits) pairs whose accumulator envelope ``k`` provably
    leaves (``repro.analysis.ranges``) — the planner never prices, picks,
    or baselines them.  Evidence is appended to ``pruned`` (the plan's
    ``range_pruned`` meta block) when a list is given."""
    out: set[tuple[str, int]] = set()
    for design in designs:
        for bits in bits_candidates:
            finding = ranges_lib.check_gemm(design, bits, int(k),
                                            where=site_name)
            if finding is not None:
                out.add((design, bits))
                if pruned is not None:
                    pruned.append({
                        "site": site_name, "design": design, "bits": bits,
                        "k": int(k),
                        "max_safe_k": ranges_lib.max_safe_k(design, bits),
                        "reason": finding.message})
    return out


def _stochastic_candidates(site: GemmSite, weight, bits: int,
                           stream_lens: Sequence[int], *,
                           quant_rel_mse: float, stats: SparsityStats,
                           max_rel_mse: float, unit_n: int, num_units: int,
                           pruned: list | None) -> list[Candidate]:
    """Priced ``(ugemm_stochastic, bits, L)`` candidates for one site.

    Two static filters run before any measurement, mirroring the
    range-pruning contract (excluded candidates are never priced, never
    picked, and their evidence lands in ``pruned``):

    1. the analytic expected-error bound
       (``ranges.stochastic_error_bound``) squared must fit the guard on
       its own — this is exactly what ``plan-lint``'s ``stream-guard``
       rule re-derives from the document, so lint can never flag a
       planner-admitted entry;
    2. the int32 pulse-count envelope at the site's K and this L.

    Surviving lengths get a *measured* seeded RMSE on the site's real
    quantized weight (``repro.stochastic.error.site_rmse_curve``); the
    guard then applies to quantization + stream error combined.  Priced as
    uGEMM (identical rate-coded datapath; k-independent cycles) with
    ``L / 2^bits`` cycle scaling.
    """
    from repro.stochastic import error as stoch_error
    out: list[Candidate] = []
    admissible: list[int] = []
    for L in sorted({int(L) for L in stream_lens}):
        bound = ranges_lib.stochastic_error_bound(bits, L)
        if bound.expected_rel_mse > max_rel_mse:
            if pruned is not None:
                pruned.append({
                    "site": site.name, "design": STOCHASTIC_DESIGN,
                    "bits": bits, "stream_len": L, "k": int(site.k),
                    "reason": f"{bound.describe()} — expected rel MSE "
                              f"{bound.expected_rel_mse:.4f} > guard "
                              f"{max_rel_mse}"})
            continue
        finding = ranges_lib.check_gemm(STOCHASTIC_DESIGN, bits,
                                        int(site.k), where=site.name,
                                        stream_len=L)
        if finding is not None:
            if pruned is not None:
                pruned.append({
                    "site": site.name, "design": STOCHASTIC_DESIGN,
                    "bits": bits, "stream_len": L, "k": int(site.k),
                    "max_safe_k": ranges_lib.max_safe_k(
                        STOCHASTIC_DESIGN, bits, stream_len=L),
                    "reason": finding.message})
            continue
        admissible.append(L)
    if not admissible:
        return out
    curve = dict(stoch_error.site_rmse_curve(
        weight, bits, admissible, rows=max(site.m, 1)))
    for L in admissible:
        stream_rel_mse = curve[L] ** 2
        combined = quant_rel_mse + stream_rel_mse
        priced = price_site("ugemm", bits, m=site.m, k=site.k,
                            n_out=site.n_out, count=site.count,
                            bit_sparsity=stats.bit_blockmax,
                            unit_n=unit_n, num_units=num_units,
                            cycle_scale=L / float(2 ** bits))
        out.append(Candidate(design=STOCHASTIC_DESIGN, bits=bits,
                             stats=stats, rel_mse=combined,
                             guard_ok=combined <= max_rel_mse,
                             stream_len=L, **priced))
    return out


def site_candidates(site: GemmSite, *,
                    bits_candidates: Sequence[int] = DEFAULT_BITS_CANDIDATES,
                    designs: Sequence[str] = DEFAULT_DESIGNS,
                    max_rel_mse: float = DEFAULT_MAX_REL_MSE,
                    unit_n: int = 64, num_units: int = 64,
                    block: int = 32,
                    pruned: list | None = None,
                    stream_lens: Sequence[int] = ()) -> list[Candidate]:
    """Profile and price every feasible (design, bits) candidate for one
    site.

    Candidates whose accumulator envelope the site's contraction length
    leaves are pruned *before* pricing (see :func:`prune_infeasible`;
    evidence lands in ``pruned`` when given).  The site's stacked weight
    matrix is profiled per the paper's convention (per-tensor quantization
    grid, ``block``×``block`` maxima for the Eq. 1 statistic); the guard
    statistic is :func:`quantization_rel_mse` at each bit-width.
    ``guard_ok`` is False where ``rel_mse > max_rel_mse``.

    When ``designs`` contains ``ugemm_stochastic`` AND ``stream_lens`` is
    non-empty, each bit-width additionally gets rate-coded candidates per
    stream length (see :func:`_stochastic_candidates` — analytic + envelope
    pre-filters, then measured per-site stream RMSE folded into the guard).

    The weight is materialized once for the call and released with it (the
    streaming contract — see :class:`GemmSite`).
    """
    exact_designs = [d for d in designs if d != STOCHASTIC_DESIGN]
    want_stochastic = STOCHASTIC_DESIGN in designs and len(stream_lens) > 0
    infeasible = prune_infeasible(site.name, site.k, exact_designs,
                                  bits_candidates, pruned)
    weight = jnp.asarray(site.weight_matrix())
    out: list[Candidate] = []
    for bits in bits_candidates:
        stats = sparsity.profile_tensor(weight, bits=bits, block=block)
        rel_mse = quantization_rel_mse(weight, bits)
        guard_ok = rel_mse <= max_rel_mse
        for design in exact_designs:
            if (design, bits) in infeasible:
                continue
            priced = price_site(design, bits, m=site.m, k=site.k,
                                n_out=site.n_out, count=site.count,
                                bit_sparsity=stats.bit_blockmax,
                                unit_n=unit_n, num_units=num_units)
            out.append(Candidate(design=design, bits=bits, stats=stats,
                                 rel_mse=rel_mse, guard_ok=guard_ok,
                                 **priced))
        if want_stochastic:
            out.extend(_stochastic_candidates(
                site, weight, bits, stream_lens,
                quant_rel_mse=rel_mse, stats=stats,
                max_rel_mse=max_rel_mse, unit_n=unit_n,
                num_units=num_units, pruned=pruned))
    return out


def _pick(cands: list[Candidate], objective: str) -> tuple[Candidate, bool]:
    """Per-site argmin of ``objective`` among guard-passing candidates.

    Falls back to the most accurate (lowest rel_mse, then widest) candidates
    when the guard rejects every bit-width — the returned bool flags the
    relaxation.  Ties break deterministically by (value, design, bits).
    """
    allowed = [c for c in cands if c.guard_ok]
    relaxed = not allowed
    if relaxed:
        best_mse = min(c.rel_mse for c in cands)
        allowed = [c for c in cands if c.rel_mse == best_mse]
    return min(allowed, key=lambda c: (getattr(c, objective), c.design,
                                       c.bits, c.stream_len)), relaxed


def build_plan(cfg, params, *, batch: int = 1,
               bits_candidates: Sequence[int] = DEFAULT_BITS_CANDIDATES,
               designs: Sequence[str] = DEFAULT_DESIGNS,
               objective: str = "dyn_energy_uj",
               max_rel_mse: float = DEFAULT_MAX_REL_MSE,
               unit_n: int = 64, num_units: int = 64,
               seq_len: int = 8,
               sites: list[GemmSite] | None = None,
               stream_lens: Sequence[int] = ()) -> BackendPlan:
    """Derive a per-site mixed-precision :class:`BackendPlan` for a model.

    Args: ``cfg``/``params`` — the model; ``batch`` — decode rows per step
    (prices the tiling; does not change the per-site winner); ``objective``
    — one of ``dyn_energy_uj`` / ``dyn_latency_us`` / ``wc_energy_uj`` /
    ``wc_latency_us`` (lower is better); ``unit_n``/``num_units`` — the DLA
    geometry (n×n PE arrays); ``max_rel_mse`` — the accuracy guard;
    ``sites`` — optionally a pre-computed :func:`discover_sites` result
    (callers that also measure cycles reuse one discovery pass);
    ``stream_lens`` — rate-coded stream lengths tried per bit-width when
    ``designs`` contains ``ugemm_stochastic`` (the (design, bits,
    stream_len) axis — e.g. :data:`DEFAULT_STREAM_LENS`).

    Returns a plan whose entries use exact site names as patterns, with
    ``meta`` carrying the planning inputs, per-(design, bits) uniform
    baselines, and the planned totals.  The planned total never exceeds the
    best guard-feasible uniform baseline (per-site argmin over a superset).
    Uniform baselines are **exact designs only** — a uniform stochastic
    assignment is not a meaningful accuracy reference, so stochastic
    candidates only ever compete per site, where they must beat every
    exact candidate on the objective *and* survive the combined guard.
    """
    if sites is None:
        sites = discover_sites(cfg, params, batch=batch, seq_len=seq_len)
    if not sites:
        raise ValueError("model exposes no dense GEMM sites to plan")

    entries: list[SiteAssignment] = []
    range_pruned: list[dict] = []
    uniform: dict[tuple[str, int], dict[str, float]] = {
        (d, b): {"dyn_energy_uj": 0.0, "dyn_latency_us": 0.0,
                 "wc_energy_uj": 0.0, "wc_latency_us": 0.0, "feasible": True}
        for d in designs if d != STOCHASTIC_DESIGN
        for b in bits_candidates}
    for site in sites:
        n_pruned = len(range_pruned)
        cands = site_candidates(site, bits_candidates=bits_candidates,
                                designs=designs, max_rel_mse=max_rel_mse,
                                unit_n=unit_n, num_units=num_units,
                                pruned=range_pruned,
                                stream_lens=stream_lens)
        for rec in range_pruned[n_pruned:]:
            tot = uniform.get((rec["design"], rec["bits"]))
            if tot is not None:        # stochastic prunes have no baseline
                tot["feasible"] = False
        if not cands:
            raise ValueError(
                f"site {site.name!r}: no (design, bits) candidate among "
                f"{list(designs)} x {list(bits_candidates)} keeps a K="
                f"{site.k} contraction inside its accumulator envelope "
                f"(see repro.analysis.ranges)")
        best, relaxed = _pick(cands, objective)
        entries.append(SiteAssignment(
            pattern=site.name, design=best.design, bits=best.bits,
            m=site.m, k=site.k, n_out=site.n_out, count=site.count,
            word=best.stats.word, bit_elem=best.stats.bit_elem,
            bit_blockmax=best.stats.bit_blockmax,
            dyn_energy_uj=best.dyn_energy_uj,
            dyn_latency_us=best.dyn_latency_us,
            wc_energy_uj=best.wc_energy_uj,
            wc_latency_us=best.wc_latency_us,
            rel_mse=best.rel_mse, guard_relaxed=relaxed,
            stream_len=best.stream_len))
        for c in cands:
            tot = uniform.get((c.design, c.bits))
            if tot is None:            # stochastic: per-site only
                continue
            if not c.guard_ok:
                tot["feasible"] = False
            for key in ("dyn_energy_uj", "dyn_latency_us",
                        "wc_energy_uj", "wc_latency_us"):
                tot[key] += getattr(c, key)

    planned = plan_totals(entries)
    feasible = {f"{d}@{b}": tot for (d, b), tot in uniform.items()
                if tot["feasible"]}
    best_uniform = (min(feasible, key=lambda k: feasible[k][objective])
                    if feasible else None)
    meta = {
        "arch": getattr(cfg, "arch_id", None),
        "objective": objective,
        "bits_candidates": list(bits_candidates),
        "designs": list(designs),
        "stream_lens": sorted({int(L) for L in stream_lens}),
        "max_rel_mse": max_rel_mse,
        "unit_n": unit_n,
        "num_units": num_units,
        "batch": batch,
        # Numeric-safety evidence: every pruned (site, design, bits) with
        # its envelope bound.  Always present — an empty list is the
        # verifier's proof that no candidate was overflow-hazardous.
        "range_pruned": range_pruned,
        "totals": {
            "planned": planned,
            "uniform": {name: {k: v for k, v in tot.items()
                               if k != "feasible"}
                        for name, tot in feasible.items()},
            "uniform_best": best_uniform,
        },
    }
    return BackendPlan(sites=tuple(entries),
                       meta=tuple(sorted(meta.items())))


def _zero_totals() -> dict[str, float]:
    return {"dyn_energy_uj": 0.0, "dyn_latency_us": 0.0,
            "wc_energy_uj": 0.0, "wc_latency_us": 0.0}


def _assignment(site: GemmSite, best: Candidate, relaxed: bool, *,
                k: int, n_out: int) -> SiteAssignment:
    """A plan entry for ``site`` from a picked candidate (``k``/``n_out``
    record the priced contraction — full dims for aggregate entries, the
    shard's real slice dims for per-shard entries)."""
    return SiteAssignment(
        pattern=site.name, design=best.design, bits=best.bits,
        m=site.m, k=int(k), n_out=int(n_out), count=site.count,
        word=best.stats.word, bit_elem=best.stats.bit_elem,
        bit_blockmax=best.stats.bit_blockmax,
        dyn_energy_uj=best.dyn_energy_uj,
        dyn_latency_us=best.dyn_latency_us,
        wc_energy_uj=best.wc_energy_uj,
        wc_latency_us=best.wc_latency_us,
        rel_mse=best.rel_mse, guard_relaxed=relaxed,
        stream_len=best.stream_len)


def _fold_uniform(uniform: dict, cands: list[Candidate]) -> None:
    """Accumulate every candidate into the per-(design, bits) uniform
    baselines (a uniform assignment is infeasible once any site's guard
    rejects that bit-width)."""
    for c in cands:
        tot = uniform[(c.design, c.bits)]
        if not c.guard_ok:
            tot["feasible"] = False
        for key in _zero_totals():
            tot[key] += getattr(c, key)


def _uniform_verdict(uniform: dict, planned: dict,
                     objective: str) -> dict:
    """The planned-vs-uniform totals block (shared by plan flavours)."""
    feasible = {f"{d}@{b}": {k: v for k, v in tot.items() if k != "feasible"}
                for (d, b), tot in uniform.items() if tot["feasible"]}
    best = (min(feasible, key=lambda name: feasible[name][objective])
            if feasible else None)
    return {"planned": planned, "uniform": feasible, "uniform_best": best}


def build_grid_plan(cfg, params, *, grid=(2, 2), batch: int = 1,
                    bits_candidates: Sequence[int] = DEFAULT_BITS_CANDIDATES,
                    designs: Sequence[str] = DEFAULT_DESIGNS,
                    objective: str = "dyn_energy_uj",
                    max_rel_mse: float = DEFAULT_MAX_REL_MSE,
                    unit_n: int = 64, num_units: int = 64,
                    seq_len: int = 8,
                    sites: list[GemmSite] | None = None):
    """Derive a per-shard heterogeneous :class:`repro.backends.grid.GridPlan`.

    Shards every site's weight the way ``GridBackend.execute`` does (K rows
    ceil-split over ``units_x``, output columns over ``units_y``), profiles
    **each shard's slice separately** — a shard's weight slice has its own
    sparsity, so the Eq. 1-priced winner may differ across shards — and
    prices every (shard, design, bits) candidate on the per-node DLA tiling
    (padded shard dims) plus that shard's share of the interconnect-hop
    energy and the full hop latency.

    The accuracy guard uses the **full-weight** quantization error at each
    bit-width: execution quantizes the whole weight per output channel
    before sharding the codes, so the shard slices see the full tensor's
    quantization grid — and per-shard, aggregate and uniform candidate sets
    then share one feasibility structure, keeping the planned-total ≤
    best-uniform property airtight at every level.

    Returns a :class:`~repro.backends.grid.GridPlan`: one
    :class:`BackendPlan` per shard (its meta carries that shard's
    planned-vs-uniform verdict), the *aggregate* plan SPMD execution replays
    (per-site argmin of the summed per-shard cost), and a meta block with
    the per-shard and aggregate verdicts plus the sites whose assignment is
    heterogeneous across shards.
    """
    grid = grid_lib.parse_grid(grid)
    units_x, units_y = grid
    num_shards = units_x * units_y
    if sites is None:
        sites = discover_sites(cfg, params, batch=batch, seq_len=seq_len)
    if not sites:
        raise ValueError("model exposes no dense GEMM sites to plan")

    shard_keys = [f"{gx},{gy}" for gx in range(units_x)
                  for gy in range(units_y)]
    shard_entries: dict[str, list[SiteAssignment]] = \
        {k: [] for k in shard_keys}
    shard_uniform = {k: {(d, b): {**_zero_totals(), "feasible": True}
                         for d in designs for b in bits_candidates}
                     for k in shard_keys}
    agg_entries: list[SiteAssignment] = []
    agg_uniform = {(d, b): {**_zero_totals(), "feasible": True}
                   for d in designs for b in bits_candidates}
    range_pruned: list[dict] = []

    for site in sites:
        weight = site.weight_matrix()          # streamed: one site at a time
        w3, _applications = _site_copies(site, weight)
        full = jnp.asarray(weight)
        full_mse = {b: quantization_rel_mse(full, b) for b in bits_candidates}
        full_stats = {b: sparsity.profile_tensor(full, bits=b)
                      for b in bits_candidates}
        ks_pad = -(-site.k // units_x)
        ns_pad = -(-site.n_out // units_y)
        # Envelope pruning at the *padded shard* contraction length — what
        # each grid node actually accumulates over.  Infeasible pairs are
        # never priced for any shard, the aggregate, or a uniform baseline.
        infeasible = prune_infeasible(site.name, ks_pad, designs,
                                      bits_candidates, range_pruned)
        for pair in infeasible:
            agg_uniform[pair]["feasible"] = False
            for skey in shard_keys:
                shard_uniform[skey][pair]["feasible"] = False
        if len(infeasible) == len(designs) * len(bits_candidates):
            raise ValueError(
                f"site {site.name!r}: no (design, bits) candidate among "
                f"{list(designs)} x {list(bits_candidates)} keeps the "
                f"per-shard K={ks_pad} contraction (grid {units_x}x"
                f"{units_y}) inside its accumulator envelope "
                f"(see repro.analysis.ranges)")
        agg_costs: dict[tuple[str, int], dict[str, float]] = {}

        def _fold_agg(priced: dict[str, float], design: str,
                      bits: int) -> None:
            # energy sums across shards; shards run in parallel, so the
            # grid's latency is the slowest shard's (matching GridDLAModel)
            agg = agg_costs.setdefault((design, bits), _zero_totals())
            for key in ("dyn_energy_uj", "wc_energy_uj"):
                agg[key] += priced[key]
            for key in ("dyn_latency_us", "wc_latency_us"):
                agg[key] = max(agg[key], priced[key])

        for (gx, gy), (rows_sl, cols_sl) in grid_lib.shard_slices(
                site.k, site.n_out, units_x, units_y).items():
            sub = w3[:, rows_sl, cols_sl]
            # A pure-padding shard (units_x ∤ k) has nothing to plan, but
            # execution still streams its zero codes and the reduction
            # still crosses it: charge its padded compute (all-zero codes
            # → block-max sparsity 1.0) and hop share into the aggregate,
            # keeping planner totals consistent with the grid pricer.
            padding_only = sub.size == 0
            if padding_only:
                shard_stats = {b: sparsity.SparsityStats(
                    bits=b, word=1.0, bit_elem=1.0, bit_blockmax=1.0,
                    numel=0) for b in bits_candidates}
            else:
                sub2 = jnp.asarray(sub.reshape(-1, sub.shape[-1]))
                shard_stats = {b: sparsity.profile_tensor(sub2, bits=b)
                               for b in bits_candidates}
            cands: list[Candidate] = []
            for bits in bits_candidates:
                stats = shard_stats[bits]
                guard_ok = full_mse[bits] <= max_rel_mse
                for design in designs:
                    if (design, bits) in infeasible:
                        continue
                    node = ppa.DLAModel(design=design, bits=bits, n=unit_n,
                                        num_units=num_units)
                    gdla = ppa.GridDLAModel(
                        design=design, bits=bits, n=unit_n,
                        num_units=num_units, units_x=units_x,
                        units_y=units_y)
                    hop_e = gdla.hop_energy_nj(site.m, site.k, site.n_out) \
                        / num_shards * site.count * 1e-3
                    hop_l = gdla.hop_latency_ns() * site.count * 1e-3
                    priced = {
                        "dyn_energy_uj": node.matmul_energy_nj(
                            site.m, ks_pad, ns_pad, stats.bit_blockmax)
                        * site.count * 1e-3 + hop_e,
                        "dyn_latency_us": node.matmul_latency_ns(
                            site.m, ks_pad, ns_pad, stats.bit_blockmax)
                        * site.count * 1e-3 + hop_l,
                        "wc_energy_uj": node.matmul_energy_nj(
                            site.m, ks_pad, ns_pad, 0.0)
                        * site.count * 1e-3 + hop_e,
                        "wc_latency_us": node.matmul_latency_ns(
                            site.m, ks_pad, ns_pad, 0.0)
                        * site.count * 1e-3 + hop_l,
                    }
                    _fold_agg(priced, design, bits)
                    if not padding_only:
                        cands.append(Candidate(design=design, bits=bits,
                                               stats=stats,
                                               rel_mse=full_mse[bits],
                                               guard_ok=guard_ok, **priced))
            if padding_only:
                continue
            best, relaxed = _pick(cands, objective)
            key = f"{gx},{gy}"
            shard_entries[key].append(_assignment(
                site, best, relaxed, k=sub.shape[1], n_out=sub.shape[2]))
            _fold_uniform(shard_uniform[key], cands)
        agg_cands = [
            Candidate(design=d, bits=b, stats=full_stats[b],
                      rel_mse=full_mse[b],
                      guard_ok=full_mse[b] <= max_rel_mse, **vals)
            for (d, b), vals in sorted(agg_costs.items())]
        best, relaxed = _pick(agg_cands, objective)
        agg_entries.append(_assignment(site, best, relaxed,
                                       k=site.k, n_out=site.n_out))
        _fold_uniform(agg_uniform, agg_cands)

    common = {
        "arch": getattr(cfg, "arch_id", None),
        "grid": list(grid),
        "objective": objective,
        "bits_candidates": list(bits_candidates),
        "designs": list(designs),
        "max_rel_mse": max_rel_mse,
        "unit_n": unit_n,
        "num_units": num_units,
        "batch": batch,
        # Always present — an empty list is the verifier's proof that every
        # candidate stayed inside its accumulator envelope at shard-local K.
        "range_pruned": range_pruned,
    }
    shards = []
    per_shard_verdicts = {}
    hetero_planned = _zero_totals()
    for key in shard_keys:
        entries = shard_entries[key]
        if not entries:
            continue
        verdict = _uniform_verdict(shard_uniform[key], plan_totals(entries),
                                   objective)
        per_shard_verdicts[key] = verdict
        for tkey in ("dyn_energy_uj", "wc_energy_uj"):
            hetero_planned[tkey] += verdict["planned"][tkey]
        for tkey in ("dyn_latency_us", "wc_latency_us"):
            # shards run in parallel: heterogeneous latency = slowest shard
            hetero_planned[tkey] = max(hetero_planned[tkey],
                                       verdict["planned"][tkey])
        shards.append((key, BackendPlan(
            sites=tuple(entries),
            meta=tuple(sorted({**common, "shard": key,
                               "totals": verdict}.items())))))
    agg_verdict = _uniform_verdict(agg_uniform, plan_totals(agg_entries),
                                   objective)
    aggregate = BackendPlan(
        sites=tuple(agg_entries),
        meta=tuple(sorted({**common, "shard": None,
                           "totals": agg_verdict}.items())))
    gplan = grid_lib.GridPlan(units_x=units_x, units_y=units_y,
                              aggregate=aggregate, shards=tuple(shards))
    meta = {
        **common,
        "totals": {
            "aggregate": {**agg_verdict,
                          "planned_heterogeneous": hetero_planned},
            "per_shard": per_shard_verdicts,
        },
        "heterogeneous_sites": list(gplan.heterogeneous_sites()),
    }
    return dataclasses.replace(gplan, meta=tuple(sorted(meta.items())))


def grid_plan_to_markdown(gplan) -> str:
    """Human-readable rendering of a grid plan (``reports/grid.md`` body)."""
    meta = gplan.metadata()
    totals = meta.get("totals", {})
    agg = totals.get("aggregate", {})
    lines = [
        "# Per-shard mixed-precision grid plan",
        "",
        f"Arch: `{meta.get('arch')}` on a {gplan.units_x}×{gplan.units_y} "
        f"PE-array grid of {meta.get('num_units')}× {meta.get('unit_n')}×"
        f"{meta.get('unit_n')} DLA nodes — objective "
        f"`{meta.get('objective')}`, decode batch {meta.get('batch')}.",
        "",
        "## Aggregate (executed) assignment",
        "",
        "| site | backend | b_spa | dyn energy (µJ) | guard |",
        "|---|---|---|---|---|",
    ]
    for e in gplan.aggregate.sites:
        guard = "relaxed" if e.guard_relaxed else "ok"
        lines.append(f"| `{e.pattern}` ×{e.count} | {e.design}@{e.bits} | "
                     f"{e.bit_blockmax:.3f} | {e.dyn_energy_uj:.4f} | "
                     f"{guard} |")
    planned = agg.get("planned", {})
    hetero = agg.get("planned_heterogeneous", {})
    lines += [
        "",
        f"**Aggregate planned**: {planned.get('dyn_energy_uj', 0.0):.4f} µJ "
        f"dyn energy / decode step; per-shard heterogeneous planned: "
        f"{hetero.get('dyn_energy_uj', 0.0):.4f} µJ.",
        "",
        "## Uniform grid baselines (guard-feasible)",
        "",
        "| uniform backend | dyn energy (µJ) | dyn latency (µs) |",
        "|---|---|---|",
    ]
    uniform = agg.get("uniform", {})
    for name in sorted(uniform):
        tot = uniform[name]
        mark = " ← best" if name == agg.get("uniform_best") else ""
        lines.append(f"| {name}{mark} | {tot['dyn_energy_uj']:.4f} | "
                     f"{tot['dyn_latency_us']:.4f} |")
    lines += [
        "",
        "## Per-shard verdicts",
        "",
        "| shard | planned dyn energy (µJ) | best uniform | assignment |",
        "|---|---|---|---|",
    ]
    for key, plan in gplan.shards:
        verdict = totals.get("per_shard", {}).get(key, {})
        p = verdict.get("planned", {}).get("dyn_energy_uj", 0.0)
        best = verdict.get("uniform_best")
        tags = ", ".join(f"{s.design}@{s.bits}" for s in plan.sites)
        lines.append(f"| {key} | {p:.4f} | {best} | {tags} |")
    hsites = meta.get("heterogeneous_sites", [])
    lines += [
        "",
        f"Sites with shard-heterogeneous assignments: "
        f"{', '.join(f'`{s}`' for s in hsites) if hsites else 'none'}.",
        "",
        "Per-site, per-shard argmin over the same candidate set makes every "
        "shard's planned total ≤ its best uniform baseline and the "
        "aggregate ≤ the best uniform grid assignment, by construction; "
        "`use_plan` executes the aggregate under `shard_map` "
        "(`serve --backend-plan … --grid X,Y` replays it with bit-exactness "
        "and per-shard cycle-bound checks).",
        "",
    ]
    return "\n".join(lines)


def _site_copies(site: GemmSite, weight: np.ndarray) -> tuple[np.ndarray, int]:
    """The site's physical weight copies and the application multiplier.

    A site's ``count`` can exceed its physical weight copies (the hybrid
    shared block applies one weight n_groups times per step): measure the
    physical copies, scale by applications.  Returns ``(copies-stacked
    (copies, k, n_out) array, applications)``.
    """
    copies = weight.shape[0] // site.k
    return (weight.reshape(copies, site.k, site.n_out),
            site.count // copies)


def measure_site_cycles(site: GemmSite, entry, *, unit_n: int,
                        num_units: int) -> dict[str, float]:
    """Measured (operand-driven) decode-step cycles for one planned site.

    Runs the shared measured-cycles contract
    (``repro.backends.runtime.measure_matrix_cycles`` — the same helper the
    serve driver totals with) over each of the site's physical weight
    copies with the entry's profiled Eq. 1 statistics, and sums.  Returns
    cycles per decode step: ``measured`` (operand-driven early termination),
    ``dyn`` (Eq. 1 block-max), ``dyn_floor`` (Eq. 1 element-level), ``wc``
    (worst case).  For sparsity-aware designs ``dyn_floor ≤ measured ≤ wc``;
    designs without early termination report all four equal.
    """
    backend = entry.backend()
    w3, applications = _site_copies(site, site.weight_matrix())
    totals = {"measured": 0.0, "dyn": 0.0, "dyn_floor": 0.0, "wc": 0.0}
    for i in range(w3.shape[0]):
        cyc = runtime_lib.measure_matrix_cycles(
            backend, w3[i], rows=site.m, unit_n=unit_n, num_units=num_units,
            bit_blockmax=entry.bit_blockmax, bit_elem=entry.bit_elem)
        for key in totals:
            totals[key] += cyc[key]
    return {key: val * applications for key, val in totals.items()}


def measure_grid_site_cycles(site: GemmSite, entry, *, grid: tuple[int, int],
                             unit_n: int, num_units: int
                             ) -> dict[str, dict[str, float]]:
    """Per-shard measured decode-step cycles for one planned site on a grid.

    Like :func:`measure_site_cycles` but sharded: each grid node measures
    its own weight slice (``repro.backends.grid_matrix_cycles`` — per-shard
    tile counts, per-shard sparsity, hop term added to every bound), summed
    over the site's physical copies and scaled by applications.  Returns
    ``{"gx,gy": {measured, dyn, dyn_floor, wc}}``; the per-shard invariant
    ``dyn_floor ≤ measured ≤ wc`` holds shard by shard.
    """
    backend = grid_lib.as_grid(entry.backend(), *grid)
    w3, applications = _site_copies(site, site.weight_matrix())
    totals: dict[str, dict[str, float]] = {}
    for i in range(w3.shape[0]):
        per_shard = grid_lib.grid_matrix_cycles(
            backend, w3[i], rows=site.m, unit_n=unit_n, num_units=num_units)
        for coord, cyc in per_shard.items():
            tot = totals.setdefault(
                coord, {"measured": 0.0, "dyn": 0.0, "dyn_floor": 0.0,
                        "wc": 0.0})
            for key in tot:
                tot[key] += cyc[key]
    return {coord: {key: val * applications for key, val in tot.items()}
            for coord, tot in totals.items()}


def plan_totals(entries) -> dict[str, float]:
    """Summed predicted cost of a plan's entries (µJ / µs per decode step)."""
    keys = ("dyn_energy_uj", "dyn_latency_us", "wc_energy_uj",
            "wc_latency_us")
    return {k: sum(getattr(e, k) for e in entries) for k in keys}


def to_markdown(plan: BackendPlan) -> str:
    """Human-readable rendering of a plan (the ``reports/plan.md`` body)."""
    meta = plan.metadata()
    totals = meta.get("totals", {})
    planned = totals.get("planned", {})
    lines = [
        "# Per-layer mixed-precision backend plan",
        "",
        f"Arch: `{meta.get('arch')}` — objective `{meta.get('objective')}` "
        f"on a {meta.get('num_units')}× {meta.get('unit_n')}×"
        f"{meta.get('unit_n')} DLA, decode batch {meta.get('batch')}.",
        f"Candidates: designs {meta.get('designs')} × bits "
        f"{meta.get('bits_candidates')}; accuracy guard rel. quant MSE ≤ "
        f"{meta.get('max_rel_mse')}.",
        "",
        "| site | backend | bits | b_spa (blockmax) | dyn energy (µJ) | "
        "dyn latency (µs) | rel MSE | guard |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for e in plan.sites:
        guard = "relaxed" if e.guard_relaxed else "ok"
        design = (f"{e.design}:{e.stream_len}" if e.stream_len
                  else e.design)
        lines.append(
            f"| `{e.pattern}` ×{e.count} | {design} | {e.bits} | "
            f"{e.bit_blockmax:.3f} | {e.dyn_energy_uj:.4f} | "
            f"{e.dyn_latency_us:.4f} | {e.rel_mse:.4f} | {guard} |")
    lines += [
        "",
        f"**Planned totals**: {planned.get('dyn_energy_uj', 0.0):.4f} µJ "
        f"dyn energy, {planned.get('dyn_latency_us', 0.0):.4f} µs dyn "
        "latency per decode step.",
        "",
        "## Uniform single-backend baselines (guard-feasible)",
        "",
        "| uniform backend | dyn energy (µJ) | dyn latency (µs) | "
        "wc energy (µJ) |",
        "|---|---|---|---|",
    ]
    uniform = totals.get("uniform", {})
    for name in sorted(uniform):
        tot = uniform[name]
        mark = " ← best" if name == totals.get("uniform_best") else ""
        lines.append(f"| {name}{mark} | {tot['dyn_energy_uj']:.4f} | "
                     f"{tot['dyn_latency_us']:.4f} | "
                     f"{tot['wc_energy_uj']:.4f} |")
    distinct = ", ".join(f"{d}@{b}" + (f":{sl}" if sl else "")
                         for d, b, sl in plan.distinct_engines())
    lines += [
        "",
        f"Distinct backends chosen: {distinct}.",
        "",
        "Per-site argmin over the same candidate set makes the planned "
        "total ≤ every guard-feasible uniform baseline by construction; "
        "`repro.backends.use_plan` executes this mapping and "
        "`serve --backend-plan` replays it with bit-exactness checks.",
        "",
    ]
    return "\n".join(lines)
