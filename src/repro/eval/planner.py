"""Per-layer mixed-precision backend planner (paper Table V + Eq. 1 + Fig. 3
composed into a decision).

The paper's sweet-spot conclusion is a *map*, not a winner: which GEMM design
is cheapest depends on bit-width, matrix size, and — through Eq. 1 — the
measured weight bit sparsity.  This module turns that map into an executable
per-site assignment:

1. **Discover** every dense GEMM site of a model with a zero-FLOP
   ``jax.eval_shape`` trace under ``repro.backends.record_sites`` — the site
   names and contraction shapes are exactly what ``models/common.dense``
   executes under a backend scope (see the naming contract in
   ``repro.backends.runtime``).
2. **Profile** each site's weight with ``core.sparsity.profile_tensor`` at
   every candidate bit-width (word / element-bit / block-max-bit sparsity)
   and measure its quantization error (relative per-output-channel MSE, the
   accuracy-guard statistic).
3. **Price** every (site, design, bits) candidate on the ``core.ppa``
   DLA tiling with Eq. 1 sparsity-scaled dynamic cycles instead of worst
   case, drop candidates whose quantization error violates the guard, and
   pick the per-site argmin of the objective.
4. **Emit** a typed :class:`repro.backends.plan.BackendPlan` — frozen
   site-pattern → (design, bits) entries with the predicted energy/latency
   and guard evidence — which ``repro.backends.use_plan`` executes and
   ``launch/serve.py --backend-plan`` replays.

Because every uniform single-backend assignment that satisfies the guard at
all sites is in each site's candidate set, the planned total is ≤ the best
uniform plan's total by construction (tested, together with the
monotonicity property: more sparsity never raises a temporal design's
priced dynamic energy).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.backends.plan import BackendPlan, SiteAssignment
from repro.core import ppa, sparsity
from repro.core.quantization import quantize
from repro.core.sparsity import SparsityStats

__all__ = [
    "DEFAULT_BITS_CANDIDATES",
    "DEFAULT_DESIGNS",
    "DEFAULT_MAX_REL_MSE",
    "GemmSite",
    "Candidate",
    "discover_sites",
    "quantization_rel_mse",
    "price_site",
    "site_candidates",
    "build_plan",
    "measure_site_cycles",
    "plan_totals",
    "to_markdown",
]

#: candidate operand widths (paper grid); 2-bit usually fails the guard
DEFAULT_BITS_CANDIDATES: tuple[int, ...] = (2, 4, 8)
#: exact calibrated designs — stochastic uGEMM is excluded by default so a
#: planned model stays bit-identical to the binary oracle
DEFAULT_DESIGNS: tuple[str, ...] = ("tugemm", "tubgemm", "bgemm")
#: default accuracy guard: per-site relative quantization MSE ceiling
DEFAULT_MAX_REL_MSE: float = 0.05


@dataclasses.dataclass(frozen=True)
class GemmSite:
    """One plannable GEMM site of a model.

    ``name`` — the site name per the runtime naming contract (equals the
    weight's parameter-tree path); ``m``/``k``/``n_out`` — the per-invocation
    contraction ``(m, k) @ (k, n_out)`` ``dense`` performs there; ``count`` —
    invocations per forward pass (scanned layers, shared-block applications);
    ``weight`` — the site's weight as the (count · k, n_out) float32 matrix
    the contraction consumes, all invocations stacked along rows.
    """

    name: str
    m: int
    k: int
    n_out: int
    count: int
    weight: np.ndarray = dataclasses.field(repr=False, compare=False)


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One priced (design, bits) option for a site."""

    design: str
    bits: int
    stats: SparsityStats
    rel_mse: float
    guard_ok: bool
    dyn_energy_uj: float
    dyn_latency_us: float
    wc_energy_uj: float
    wc_latency_us: float


def _leaf_index(params) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    out = {}
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out[name] = leaf
    return out


def discover_sites(cfg, params, *, batch: int = 1,
                   seq_len: int = 8) -> list[GemmSite]:
    """Find every dense GEMM site of ``cfg``'s model, with weights attached.

    Traces one forward pass with ``jax.eval_shape`` inside a
    ``repro.backends.record_sites`` scope — no FLOPs run — and joins the
    recorded (site, k, n_out) against the parameter tree.  ``count`` per site
    is ``leaf.size / (k · n_out)`` (the stacked-layers multiplier), times the
    number of shared-block applications for the hybrid family's ``shared/…``
    sites (a scanned body traces once; see the runtime jit caveat).

    ``m`` is reported for a *decode step*: ``batch`` rows per invocation
    (``seq_len`` only shapes the discovery trace).  Returns sites in model
    order, deduplicated by name.
    """
    from repro import backends
    from repro.models import model as model_lib

    tokens = jnp.zeros((batch, seq_len), jnp.int32)
    with backends.record_sites() as rec:
        if getattr(cfg, "frontend_stub", False):
            embeds = jax.ShapeDtypeStruct((batch, seq_len, cfg.d_model),
                                          jnp.float32)
            jax.eval_shape(
                lambda p, e: model_lib.forward(p, cfg, embeds=e)[0],
                params, embeds)
        else:
            jax.eval_shape(lambda p, t: model_lib.forward(p, cfg, t)[0],
                           params, tokens)

    leaves = _leaf_index(params)
    shared_applications = 1
    if getattr(cfg, "family", None) == "hybrid":
        from repro.models import blocks as blocks_lib
        shared_applications = blocks_lib.hybrid_counts(cfg)[0]

    sites: list[GemmSite] = []
    seen: set[str] = set()
    for call in rec.calls:
        if call.site in seen:
            continue
        seen.add(call.site)
        leaf = leaves.get(call.site)
        if leaf is None:
            raise ValueError(
                f"recorded site {call.site!r} has no parameter-tree leaf — "
                "a dense(name=...) annotation disagrees with the param path")
        w = np.asarray(leaf, np.float32).reshape(-1, call.n_out)
        count = leaf.size // (call.k * call.n_out)
        if count * call.k * call.n_out != leaf.size:
            raise ValueError(
                f"site {call.site!r}: leaf shape {tuple(leaf.shape)} is not "
                f"a stack of (k={call.k}, n_out={call.n_out}) matrices")
        if call.site.startswith("shared/"):
            count *= shared_applications
        sites.append(GemmSite(name=call.site, m=max(int(batch), 1),
                              k=call.k, n_out=call.n_out, count=count,
                              weight=w))
    return sites


def quantization_rel_mse(w, bits: int) -> float:
    """Relative quantization MSE of ``w`` at ``bits`` — the guard statistic.

    Per-output-channel symmetric quantization (exactly what
    ``models/common.dense`` applies to the weight under a backend scope),
    dequantized and compared to the original: ``mean((w - dq)²) / mean(w²)``.
    Dimensionless; 0 = lossless, ~0.01–0.03 for 4-bit Gaussian weights,
    ≫ 0.1 for 2-bit.
    """
    w = jnp.asarray(w, jnp.float32)
    q = quantize(w, bits=bits)
    dq = q.values.astype(jnp.float32) * q.scale
    denom = float(jnp.mean(jnp.square(w)))
    return float(jnp.mean(jnp.square(w - dq))) / max(denom, 1e-30)


def price_site(design: str, bits: int, *, m: int, k: int, n_out: int,
               count: int, bit_sparsity: float, unit_n: int,
               num_units: int) -> dict[str, float]:
    """Price one site's per-decode-step cost on a (design, bits) DLA.

    Uses the same ``core.ppa.DLAModel`` tiling the serve cost table uses,
    with Eq. 1 ``bit_sparsity`` (block-max statistic) scaling the dynamic
    numbers and 0.0 for the worst case.  Returns µJ / µs totals over the
    site's ``count`` invocations: ``dyn_energy_uj``, ``dyn_latency_us``,
    ``wc_energy_uj``, ``wc_latency_us``.
    """
    dla = ppa.DLAModel(design=design, bits=bits, n=unit_n,
                       num_units=num_units)
    return {
        "dyn_energy_uj":
            dla.matmul_energy_nj(m, k, n_out, bit_sparsity) * count * 1e-3,
        "dyn_latency_us":
            dla.matmul_latency_ns(m, k, n_out, bit_sparsity) * count * 1e-3,
        "wc_energy_uj":
            dla.matmul_energy_nj(m, k, n_out, 0.0) * count * 1e-3,
        "wc_latency_us":
            dla.matmul_latency_ns(m, k, n_out, 0.0) * count * 1e-3,
    }


def site_candidates(site: GemmSite, *,
                    bits_candidates: Sequence[int] = DEFAULT_BITS_CANDIDATES,
                    designs: Sequence[str] = DEFAULT_DESIGNS,
                    max_rel_mse: float = DEFAULT_MAX_REL_MSE,
                    unit_n: int = 64, num_units: int = 64,
                    block: int = 32) -> list[Candidate]:
    """Profile and price every (design, bits) candidate for one site.

    The site's stacked weight matrix is profiled per the paper's convention
    (per-tensor quantization grid, ``block``×``block`` maxima for the Eq. 1
    statistic); the guard statistic is :func:`quantization_rel_mse` at each
    bit-width.  ``guard_ok`` is False where ``rel_mse > max_rel_mse``.
    """
    out: list[Candidate] = []
    for bits in bits_candidates:
        stats = sparsity.profile_tensor(jnp.asarray(site.weight), bits=bits,
                                        block=block)
        rel_mse = quantization_rel_mse(site.weight, bits)
        guard_ok = rel_mse <= max_rel_mse
        for design in designs:
            priced = price_site(design, bits, m=site.m, k=site.k,
                                n_out=site.n_out, count=site.count,
                                bit_sparsity=stats.bit_blockmax,
                                unit_n=unit_n, num_units=num_units)
            out.append(Candidate(design=design, bits=bits, stats=stats,
                                 rel_mse=rel_mse, guard_ok=guard_ok,
                                 **priced))
    return out


def _pick(cands: list[Candidate], objective: str) -> tuple[Candidate, bool]:
    """Per-site argmin of ``objective`` among guard-passing candidates.

    Falls back to the most accurate (lowest rel_mse, then widest) candidates
    when the guard rejects every bit-width — the returned bool flags the
    relaxation.  Ties break deterministically by (value, design, bits).
    """
    allowed = [c for c in cands if c.guard_ok]
    relaxed = not allowed
    if relaxed:
        best_mse = min(c.rel_mse for c in cands)
        allowed = [c for c in cands if c.rel_mse == best_mse]
    return min(allowed, key=lambda c: (getattr(c, objective), c.design,
                                       c.bits)), relaxed


def build_plan(cfg, params, *, batch: int = 1,
               bits_candidates: Sequence[int] = DEFAULT_BITS_CANDIDATES,
               designs: Sequence[str] = DEFAULT_DESIGNS,
               objective: str = "dyn_energy_uj",
               max_rel_mse: float = DEFAULT_MAX_REL_MSE,
               unit_n: int = 64, num_units: int = 64,
               seq_len: int = 8,
               sites: list[GemmSite] | None = None) -> BackendPlan:
    """Derive a per-site mixed-precision :class:`BackendPlan` for a model.

    Args: ``cfg``/``params`` — the model; ``batch`` — decode rows per step
    (prices the tiling; does not change the per-site winner); ``objective``
    — one of ``dyn_energy_uj`` / ``dyn_latency_us`` / ``wc_energy_uj`` /
    ``wc_latency_us`` (lower is better); ``unit_n``/``num_units`` — the DLA
    geometry (n×n PE arrays); ``max_rel_mse`` — the accuracy guard;
    ``sites`` — optionally a pre-computed :func:`discover_sites` result
    (callers that also measure cycles reuse one discovery pass).

    Returns a plan whose entries use exact site names as patterns, with
    ``meta`` carrying the planning inputs, per-(design, bits) uniform
    baselines, and the planned totals.  The planned total never exceeds the
    best guard-feasible uniform baseline (per-site argmin over a superset).
    """
    if sites is None:
        sites = discover_sites(cfg, params, batch=batch, seq_len=seq_len)
    if not sites:
        raise ValueError("model exposes no dense GEMM sites to plan")

    entries: list[SiteAssignment] = []
    uniform: dict[tuple[str, int], dict[str, float]] = {
        (d, b): {"dyn_energy_uj": 0.0, "dyn_latency_us": 0.0,
                 "wc_energy_uj": 0.0, "wc_latency_us": 0.0, "feasible": True}
        for d in designs for b in bits_candidates}
    for site in sites:
        cands = site_candidates(site, bits_candidates=bits_candidates,
                                designs=designs, max_rel_mse=max_rel_mse,
                                unit_n=unit_n, num_units=num_units)
        best, relaxed = _pick(cands, objective)
        entries.append(SiteAssignment(
            pattern=site.name, design=best.design, bits=best.bits,
            m=site.m, k=site.k, n_out=site.n_out, count=site.count,
            word=best.stats.word, bit_elem=best.stats.bit_elem,
            bit_blockmax=best.stats.bit_blockmax,
            dyn_energy_uj=best.dyn_energy_uj,
            dyn_latency_us=best.dyn_latency_us,
            wc_energy_uj=best.wc_energy_uj,
            wc_latency_us=best.wc_latency_us,
            rel_mse=best.rel_mse, guard_relaxed=relaxed))
        for c in cands:
            tot = uniform[(c.design, c.bits)]
            if not c.guard_ok:
                tot["feasible"] = False
            for key in ("dyn_energy_uj", "dyn_latency_us",
                        "wc_energy_uj", "wc_latency_us"):
                tot[key] += getattr(c, key)

    planned = plan_totals(entries)
    feasible = {f"{d}@{b}": tot for (d, b), tot in uniform.items()
                if tot["feasible"]}
    best_uniform = (min(feasible, key=lambda k: feasible[k][objective])
                    if feasible else None)
    meta = {
        "arch": getattr(cfg, "arch_id", None),
        "objective": objective,
        "bits_candidates": list(bits_candidates),
        "designs": list(designs),
        "max_rel_mse": max_rel_mse,
        "unit_n": unit_n,
        "num_units": num_units,
        "batch": batch,
        "totals": {
            "planned": planned,
            "uniform": {name: {k: v for k, v in tot.items()
                               if k != "feasible"}
                        for name, tot in feasible.items()},
            "uniform_best": best_uniform,
        },
    }
    return BackendPlan(sites=tuple(entries),
                       meta=tuple(sorted(meta.items())))


def measure_site_cycles(site: GemmSite, entry, *, unit_n: int,
                        num_units: int) -> dict[str, float]:
    """Measured (operand-driven) decode-step cycles for one planned site.

    Quantizes each of the site's ``count`` per-invocation weight matrices
    per output channel — exactly what ``models/common.dense`` contracts
    under the plan — and sums the entry's backend's early-terminating
    ``dyn_cycles(operand=...)`` over them, times the DLA wave count.
    Returns cycles per decode step:

    * ``measured`` — operand-driven early termination;
    * ``dyn`` — the plan's Eq. 1 estimate (worst case × (1 − block-max));
    * ``dyn_floor`` — Eq. 1 with element-level sparsity (optimistic bound);
    * ``wc`` — worst case.

    For sparsity-aware designs ``dyn_floor ≤ measured ≤ wc``; designs
    without early termination report all four equal.
    """
    backend = entry.backend()
    dla = ppa.DLAModel(design=backend.pricing_design, bits=backend.bits,
                       n=unit_n, num_units=num_units)
    waves = math.ceil(dla.tiles(site.m, site.n_out) / num_units)
    # A site's count can exceed its physical weight copies (the hybrid
    # shared block applies one weight n_groups times per step): measure the
    # physical copies, scale by applications.
    copies = site.weight.shape[0] // site.k
    applications = site.count // copies
    w3 = site.weight.reshape(copies, site.k, site.n_out)
    measured = 0.0
    for i in range(copies):
        q = quantize(jnp.asarray(w3[i]), bits=backend.bits).values
        measured += float(backend.dyn_cycles(operand=q))
    measured *= applications
    wc = float(backend.cycles(site.k)) * site.count
    return {
        "measured": measured * waves,
        "dyn": float(backend.dyn_cycles(site.k,
                                        bit_sparsity=entry.bit_blockmax))
        * site.count * waves,
        "dyn_floor": float(backend.dyn_cycles(site.k,
                                              bit_sparsity=entry.bit_elem))
        * site.count * waves,
        "wc": wc * waves,
    }


def plan_totals(entries) -> dict[str, float]:
    """Summed predicted cost of a plan's entries (µJ / µs per decode step)."""
    keys = ("dyn_energy_uj", "dyn_latency_us", "wc_energy_uj",
            "wc_latency_us")
    return {k: sum(getattr(e, k) for e in entries) for k in keys}


def to_markdown(plan: BackendPlan) -> str:
    """Human-readable rendering of a plan (the ``reports/plan.md`` body)."""
    meta = plan.metadata()
    totals = meta.get("totals", {})
    planned = totals.get("planned", {})
    lines = [
        "# Per-layer mixed-precision backend plan",
        "",
        f"Arch: `{meta.get('arch')}` — objective `{meta.get('objective')}` "
        f"on a {meta.get('num_units')}× {meta.get('unit_n')}×"
        f"{meta.get('unit_n')} DLA, decode batch {meta.get('batch')}.",
        f"Candidates: designs {meta.get('designs')} × bits "
        f"{meta.get('bits_candidates')}; accuracy guard rel. quant MSE ≤ "
        f"{meta.get('max_rel_mse')}.",
        "",
        "| site | backend | bits | b_spa (blockmax) | dyn energy (µJ) | "
        "dyn latency (µs) | rel MSE | guard |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for e in plan.sites:
        guard = "relaxed" if e.guard_relaxed else "ok"
        lines.append(
            f"| `{e.pattern}` ×{e.count} | {e.design} | {e.bits} | "
            f"{e.bit_blockmax:.3f} | {e.dyn_energy_uj:.4f} | "
            f"{e.dyn_latency_us:.4f} | {e.rel_mse:.4f} | {guard} |")
    lines += [
        "",
        f"**Planned totals**: {planned.get('dyn_energy_uj', 0.0):.4f} µJ "
        f"dyn energy, {planned.get('dyn_latency_us', 0.0):.4f} µs dyn "
        "latency per decode step.",
        "",
        "## Uniform single-backend baselines (guard-feasible)",
        "",
        "| uniform backend | dyn energy (µJ) | dyn latency (µs) | "
        "wc energy (µJ) |",
        "|---|---|---|---|",
    ]
    uniform = totals.get("uniform", {})
    for name in sorted(uniform):
        tot = uniform[name]
        mark = " ← best" if name == totals.get("uniform_best") else ""
        lines.append(f"| {name}{mark} | {tot['dyn_energy_uj']:.4f} | "
                     f"{tot['dyn_latency_us']:.4f} | "
                     f"{tot['wc_energy_uj']:.4f} |")
    distinct = ", ".join(f"{d}@{b}" for d, b in plan.distinct_backends())
    lines += [
        "",
        f"Distinct backends chosen: {distinct}.",
        "",
        "Per-site argmin over the same candidate set makes the planned "
        "total ≤ every guard-feasible uniform baseline by construction; "
        "`repro.backends.use_plan` executes this mapping and "
        "`serve --backend-plan` replays it with bit-exactness checks.",
        "",
    ]
    return "\n".join(lines)
