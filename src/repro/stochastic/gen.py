"""Vectorized unary bitstream generation (UnarySim RNG / SourceGen / BSGen).

The UnarySim hardware decomposition (SNIPPETS.md snippets 1-2) splits a
bitstream source into three stages, all kept here:

* **RNG** — a shared pseudo-random *integer* sequence ``r[t] in [0, 2^bits)``
  per cycle: a Sobol low-discrepancy sequence (the uGEMM paper's choice) or
  a maximal-length Fibonacci LFSR.
* **SourceGen** — probability pre-scaling: a value is converted ONCE to an
  integer comparator threshold ``tau = round(p * 2^bits)`` (unipolar) or
  ``round((x+1)/2 * 2^bits)`` (bipolar) so the per-cycle datapath is
  integer-only.
* **BSGen** — the per-cycle comparator ``bit[t] = r[t] < tau``.

Everything is **seeded and deterministic**: sequences derive from a
SplitMix-style integer hash of ``(seed, dim, period)`` — no global RNG
state, identical output on every host.  Operand decorrelation comes from
*distinct Sobol dimensions* (distinct generator matrices), not from
shifting one sequence: XOR-scrambles of a single dimension stay perfectly
correlated under AND, which would compute ``min`` rather than a product.

Two execution forms are provided and tested bit-identical:

* the **vectorized** form — the whole ``(L, ...)`` bitstream tensor from
  one broadcast comparator, feeding ``einsum`` contractions in ``sgemm``;
* the **scan reference** — a ``lax.scan`` that re-derives each ``r[t]``
  from the cycle counter (Sobol: XOR-fold of direction numbers over the
  counter's set bits; LFSR: stepping the shift register), the
  hardware-faithful slow path.

Sobol sequences use *binary* (non-Gray) indexing: the first ``2^l`` points
of each dimension are then a stratified ``(0, l, 1)``-net and the first
full period ``2^bits`` is a permutation of ``[0, 2^bits)`` — which is what
makes unipolar decode exact at ``L = 2^bits`` (every threshold ``tau``
fires exactly ``tau`` slots per period).  Streams longer than one period
re-scramble each period with a fresh XOR digital shift (a bijection, so
the permutation property survives).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "SOBOL_DIMS", "LFSR_TAPS",
    "sobol_direction_numbers", "sobol_sequence", "lfsr_sequence",
    "rng_sequence", "rng_sequence_scan",
    "source_gen", "source_gen_codes", "decode_counts",
    "bsgen", "bsgen_scan", "unipolar_and", "bipolar_xnor",
]

_M64 = (1 << 64) - 1


def _hash64(*keys: int) -> int:
    """Deterministic 64-bit mix of integer keys (SplitMix64 finalizer)."""
    h = 0x9E3779B97F4A7C15
    for k in keys:
        h = (h ^ (int(k) & _M64)) * 0xBF58476D1CE4E5B9 & _M64
        h ^= h >> 27
        h = h * 0x94D049BB133111EB & _M64
        h ^= h >> 31
    return h


# ---------------------------------------------------------------------------
# RNG stage: Sobol direction numbers + LFSR taps
# ---------------------------------------------------------------------------

#: Joe-Kuo primitive-polynomial parameters ``(s, a, m_init)`` per Sobol
#: dimension.  Dimension 0 is the degenerate bit-reversal (van der Corput
#: base 2) dimension; its generator matrix is the identity.
SOBOL_DIMS: tuple[tuple[int, int, tuple[int, ...]], ...] = (
    (0, 0, ()),                 # dim 0: van der Corput
    (1, 0, (1,)),               # dim 1
    (2, 1, (1, 3)),             # dim 2
    (3, 1, (1, 3, 1)),          # dim 3
    (3, 2, (1, 1, 1)),          # dim 4
    (4, 1, (1, 1, 3, 3)),       # dim 5
    (4, 4, (1, 3, 5, 13)),      # dim 6
    (5, 2, (1, 1, 5, 5, 17)),   # dim 7
)

#: Maximal-length Fibonacci LFSR tap positions (1-indexed, MSB first) per
#: register width; period ``2^bits - 1`` (the all-zero state never occurs).
LFSR_TAPS: dict[int, tuple[int, ...]] = {
    2: (2, 1), 3: (3, 2), 4: (4, 3), 5: (5, 3),
    6: (6, 5), 7: (7, 6), 8: (8, 6, 5, 4),
}


@functools.lru_cache(maxsize=None)
def sobol_direction_numbers(bits: int, dim: int) -> tuple[int, ...]:
    """Direction numbers ``v_j`` (``j = 0..bits-1``) for one Sobol dimension.

    ``v_j = m_j << (bits - 1 - j)`` with odd ``m_j < 2^(j+1)``, so the
    generator matrix is unit upper triangular — each dimension's first
    ``2^bits`` points are a permutation of ``[0, 2^bits)``.
    """
    if not 0 <= dim < len(SOBOL_DIMS):
        raise ValueError(f"sobol dim {dim} not in [0, {len(SOBOL_DIMS)})")
    if dim == 0:
        return tuple(1 << (bits - 1 - j) for j in range(bits))
    s, a, m_init = SOBOL_DIMS[dim]
    m = list(m_init)
    while len(m) < bits:
        j = len(m)
        val = m[j - s] ^ (m[j - s] << s)
        for k in range(1, s):
            if (a >> (s - 1 - k)) & 1:
                val ^= m[j - k] << k
        m.append(val)
    return tuple(m[j] << (bits - 1 - j) for j in range(bits))


def _period_masks(bits: int, dim: int, seed: int, periods: int) -> np.ndarray:
    """XOR digital-shift masks, one per ``2^bits`` period of the stream."""
    mask = (1 << bits) - 1
    return np.asarray([_hash64(seed, dim, p) & mask for p in range(periods)],
                      np.int32)


def sobol_sequence(bits: int, length: int, *, dim: int = 0,
                   seed: int = 0) -> np.ndarray:
    """``length`` Sobol integers in ``[0, 2^bits)`` (binary indexing).

    Each ``2^bits`` period is the full permutation, XOR-scrambled by a
    per-``(seed, dim, period)`` digital shift.
    """
    period = 1 << bits
    dirs = sobol_direction_numbers(bits, dim)
    n = np.arange(period, dtype=np.int64)
    base = np.zeros(period, np.int64)
    for j in range(bits):
        base ^= np.where((n >> j) & 1, dirs[j], 0)
    masks = _period_masks(bits, dim, seed, -(-length // period))
    out = (base[None, :] ^ masks[:, None].astype(np.int64)).reshape(-1)
    return out[:length].astype(np.int32)


def lfsr_sequence(bits: int, length: int, *, dim: int = 0,
                  seed: int = 0) -> np.ndarray:
    """``length`` states of a maximal Fibonacci LFSR in ``[1, 2^bits)``.

    The register restarts from a fresh hashed nonzero state every
    ``2^bits - 1`` cycles.  Unlike Sobol, the all-zero value never appears,
    so unipolar decode carries an O(1/2^bits) bias — Sobol is the default
    RNG; the LFSR is the cheap-hardware alternative.
    """
    if bits not in LFSR_TAPS:
        raise ValueError(f"no maximal LFSR taps for bits={bits}")
    taps = LFSR_TAPS[bits]
    period = (1 << bits) - 1
    out = np.empty(length, np.int32)
    state = 0
    for t in range(length):
        if t % period == 0:
            state = (_hash64(seed, dim, t // period) % period) + 1
        out[t] = state
        fb = 0
        for pos in taps:
            fb ^= (state >> (pos - 1)) & 1
        state = ((state << 1) | fb) & ((1 << bits) - 1)
    return out


def rng_sequence(kind: str, bits: int, length: int, *, dim: int = 0,
                 seed: int = 0) -> jax.Array:
    """The shared RNG stage: ``(length,)`` int32 comparator inputs."""
    if kind == "sobol":
        seq = sobol_sequence(bits, length, dim=dim, seed=seed)
    elif kind == "lfsr":
        seq = lfsr_sequence(bits, length, dim=dim, seed=seed)
    else:
        raise ValueError(f"unknown RNG kind {kind!r} (sobol|lfsr)")
    return jnp.asarray(seq, jnp.int32)


# ---------------------------------------------------------------------------
# Scan reference: re-derive r[t] from the cycle counter inside lax.scan
# ---------------------------------------------------------------------------

def _sobol_point(n: jax.Array, dirs: jax.Array, bits: int) -> jax.Array:
    """XOR-fold of direction numbers over the set bits of counter ``n``."""
    x = jnp.int32(0)
    for j in range(bits):
        x = x ^ jnp.where((n >> j) & 1 != 0, dirs[j], 0)
    return x


@functools.partial(jax.jit, static_argnames=("kind", "bits", "length", "dim",
                                             "seed"))
def rng_sequence_scan(kind: str, bits: int, length: int, *, dim: int = 0,
                      seed: int = 0) -> jax.Array:
    """Per-cycle ``lax.scan`` re-derivation of :func:`rng_sequence`.

    The hardware-faithful slow path: Sobol points are rebuilt from the
    cycle counter, the LFSR steps its register — tested bit-identical to
    the vectorized host precomputation.
    """
    if kind == "sobol":
        period = 1 << bits
        dirs = jnp.asarray(sobol_direction_numbers(bits, dim), jnp.int32)
        masks = jnp.asarray(_period_masks(bits, dim, seed,
                                          -(-length // period)))

        def step(n, _):
            x = _sobol_point(n % period, dirs, bits) ^ masks[n // period]
            return n + 1, x

        _, seq = jax.lax.scan(step, jnp.int32(0), None, length=length)
        return seq
    if kind == "lfsr":
        period = (1 << bits) - 1
        taps = LFSR_TAPS[bits]
        starts = jnp.asarray(
            [(_hash64(seed, dim, p) % period) + 1
             for p in range(-(-length // period))], jnp.int32)

        def step(carry, _):
            n, state = carry
            state = jnp.where(n % period == 0, starts[n // period], state)
            fb = jnp.int32(0)
            for pos in taps:
                fb = fb ^ ((state >> (pos - 1)) & 1)
            nxt = ((state << 1) | fb) & ((1 << bits) - 1)
            return (n + 1, nxt), state

        _, seq = jax.lax.scan(step, (jnp.int32(0), jnp.int32(1)), None,
                              length=length)
        return seq
    raise ValueError(f"unknown RNG kind {kind!r} (sobol|lfsr)")


# ---------------------------------------------------------------------------
# SourceGen: probability pre-scaling to integer thresholds
# ---------------------------------------------------------------------------

def source_gen(prob, bits: int, mode: str = "unipolar") -> jax.Array:
    """Pre-scale values to integer comparator thresholds in ``[0, 2^bits]``.

    * ``unipolar`` — ``prob`` holds probabilities in [0, 1];
      ``tau = round(p * 2^bits)``.  The stream's 1-rate is ``tau / 2^bits``.
    * ``bipolar`` — ``prob`` holds values in [-1, 1], mapped through
      ``p = (x + 1) / 2`` first; decode is ``2 p - 1`` and multiplication
      is XNOR (:func:`bipolar_xnor`).
    """
    p = jnp.asarray(prob, jnp.float32)
    if mode == "bipolar":
        p = (p + 1.0) * 0.5
    elif mode != "unipolar":
        raise ValueError(f"unknown mode {mode!r} (unipolar|bipolar)")
    period = 1 << bits
    return jnp.clip(jnp.round(p * period), 0, period).astype(jnp.int32)


def source_gen_codes(mags, bits: int) -> jax.Array:
    """SourceGen for the repo's signed-magnitude integer codes.

    ``mags`` are magnitudes ``|q| in [0, vmax]`` (``vmax = 2^(bits-1)-1``);
    the encoded probability is ``|q| / vmax`` and the returned threshold is
    ``round(|q| * 2^bits / vmax)`` computed exactly in integers.
    """
    period = 1 << bits
    v = (1 << (bits - 1)) - 1
    m = jnp.asarray(mags, jnp.int32)
    return (2 * m * period + v) // (2 * v)


def decode_counts(counts, stream_len: int, mode: str = "unipolar"):
    """Invert SourceGen: slot counts back to probabilities / values."""
    p = jnp.asarray(counts, jnp.float32) / stream_len
    return 2.0 * p - 1.0 if mode == "bipolar" else p


# ---------------------------------------------------------------------------
# BSGen: the per-cycle comparator
# ---------------------------------------------------------------------------

def bsgen(thresholds, rng_seq) -> jax.Array:
    """Comparator bitstreams: ``bit[t, ...] = rng_seq[t] < thresholds[...]``.

    Returns an int8 tensor of shape ``(len(rng_seq), *thresholds.shape)``
    with values in {0, 1} — the whole stream from one broadcast compare.
    """
    tau = jnp.asarray(thresholds, jnp.int32)
    seq = jnp.asarray(rng_seq, jnp.int32)
    seq = seq.reshape((seq.shape[0],) + (1,) * tau.ndim)
    return (seq < tau[None]).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("kind", "bits", "length", "dim",
                                             "seed"))
def bsgen_scan(thresholds, *, kind: str, bits: int, length: int,
               dim: int = 0, seed: int = 0) -> jax.Array:
    """Per-cycle BSGen: RNG stepping and comparison inside one ``lax.scan``.

    The slow reference for :func:`bsgen` ∘ :func:`rng_sequence` — one
    comparator evaluation per cycle, as the hardware would issue them.
    """
    tau = jnp.asarray(thresholds, jnp.int32)
    seq = rng_sequence_scan(kind, bits, length, dim=dim, seed=seed)

    def step(t, _):
        return t + 1, (seq[t] < tau).astype(jnp.int8)

    _, bits_out = jax.lax.scan(step, jnp.int32(0), None, length=length)
    return bits_out


def unipolar_and(bit_a, bit_b) -> jax.Array:
    """Unipolar multiply: AND gate (``p_out = p_a * p_b`` for independent
    streams)."""
    return jnp.asarray(bit_a) * jnp.asarray(bit_b)


def bipolar_xnor(bit_a, bit_b) -> jax.Array:
    """Bipolar multiply: XNOR gate (``x_out = x_a * x_b`` in value space)."""
    a = jnp.asarray(bit_a)
    b = jnp.asarray(bit_b)
    return (1 - (a ^ b)).astype(jnp.int8)
