"""Measured accuracy of the stochastic engine vs exact uGEMM.

``eval.planner`` plans ``(design, bits, stream_len)`` assignments; the
stream-length axis needs an accuracy statistic per site.  This module
provides the *measured* side: seeded, deterministic RMSE-vs-exact-uGEMM
curves over stream length, evaluated on a site's actual quantized weight
codes against seeded calibration activations.  The *analytic* expected and
tail envelopes (closed-form, used by the planner's pre-filter and by
``plan-lint``) live in ``repro.analysis.ranges.stochastic_error_bound`` so
the static-analysis layer stays JAX-free.

Everything here keys off ``(seed, bits, stream_len)`` only — the same
inputs always produce the same curve, which is what lets the benchmark
gate on exact monotonicity.
"""

from __future__ import annotations

import numpy as np

from repro.core import gemm_sims
from repro.core.quantization import quantize, vmax
from repro.stochastic import sgemm

__all__ = [
    "calibration_codes", "measured_rel_rmse", "rmse_curve", "site_rmse_curve",
]


def calibration_codes(rows: int, cols: int, bits: int, *,
                      seed: int = 0) -> np.ndarray:
    """Deterministic uniform integer codes in ``[-vmax, vmax]``."""
    rng = np.random.default_rng(seed)
    v = vmax(bits)
    return rng.integers(-v, v + 1, size=(rows, cols)).astype(np.int32)


def measured_rel_rmse(a, b, bits: int, stream_len: int, *,
                      seed: int = 0, rng_kind: str = "sobol") -> float:
    """Relative RMSE of the stochastic engine against ``ugemm_exact``."""
    est = sgemm.stochastic_gemm(a, b, bits, stream_len=stream_len, seed=seed,
                                rng_kind=rng_kind)
    oracle = gemm_sims.ugemm_exact(a, b, bits=bits)
    return gemm_sims.rel_rmse(est, oracle)


def rmse_curve(bits: int, stream_lens, *, m: int = 8, k: int = 64,
               n: int = 32, seed: int = 0,
               rng_kind: str = "sobol") -> list[tuple[int, float]]:
    """``(stream_len, rel_rmse)`` pairs on seeded calibration operands."""
    a = calibration_codes(m, k, bits, seed=seed)
    b = calibration_codes(k, n, bits, seed=seed + 1)
    return [(int(L), measured_rel_rmse(a, b, bits, int(L), seed=seed,
                                       rng_kind=rng_kind))
            for L in stream_lens]


def site_rmse_curve(weight, bits: int, stream_lens, *, rows: int = 4,
                    max_cols: int = 64, seed: int = 0,
                    rng_kind: str = "sobol") -> list[tuple[int, float]]:
    """Per-site curve: the site's real weight, seeded activations.

    ``weight`` is the float ``(k, n_out)`` site matrix; it is quantized
    per output channel at ``bits`` — the same codes backend execution
    contracts — and multiplied by ``rows`` seeded calibration activations.
    ``max_cols`` caps the measured output columns to bound planner cost
    (error statistics are column-stationary).
    """
    w = np.asarray(weight, np.float32)
    cols = min(w.shape[1], max_cols)
    wq = quantize(w[:, :cols], bits=bits)
    b = np.asarray(wq.values, np.int32)
    a = calibration_codes(rows, w.shape[0], bits, seed=seed)
    return [(int(L), measured_rel_rmse(a, b, bits, int(L), seed=seed,
                                       rng_kind=rng_kind))
            for L in stream_lens]
