"""Stream-faithful stochastic uGEMM: rate-coded bitstream compute.

The paper's uGEMM hardware is *stochastic*: operands become rate-coded
bitstreams (a value is the probability that a stream bit is 1), a multiply
is a per-cycle AND/XNOR gate, and accuracy is bought with stream length.
The repo's ``core.gemm_sims.ugemm_exact`` idealizes that to closed-form
slot counts; this package keeps the bitstreams, so *stream length* joins
bit-width as a plannable accuracy/energy knob.

Modules
-------
``gen``
    Vectorized bitstream generation (UnarySim's RNG / SourceGen / BSGen
    split): seeded Sobol and LFSR integer sequences, probability
    pre-scaling to comparator thresholds, unipolar + bipolar formats, and
    ``lax.scan`` per-cycle references tested bit-identical to the
    vectorized forms.
``sgemm``
    The rate-coded GEMM engine (``stochastic_gemm``) with UnaryLinear
    scaled accumulation, and the pure ``DesignSpec`` factory behind
    ``repro.backends.resolve("ugemm_stochastic", bits=..., stream_len=...)``.
``error``
    Measured per-site RMSE-vs-exact-uGEMM curves over stream length — the
    planner's stream-length accuracy-guard statistic (the analytic
    expected/tail envelope lives in ``repro.analysis.ranges``).
"""

from repro.stochastic import error, gen, sgemm
from repro.stochastic.sgemm import (STOCHASTIC_DESIGN, default_stream_len,
                                    stochastic_design_spec, stochastic_gemm)

__all__ = [
    "gen", "sgemm", "error",
    "STOCHASTIC_DESIGN", "default_stream_len", "stochastic_design_spec",
    "stochastic_gemm",
]
