"""Rate-coded stochastic GEMM: the ``ugemm_stochastic`` design family.

``stochastic_gemm`` multiplies signed-magnitude integer codes the way the
paper's uGEMM hardware does — as rate-coded bitstreams — instead of the
closed-form slot counts of ``core.gemm_sims.ugemm_exact``:

1. **SourceGen** maps each magnitude to a comparator threshold
   (``gen.source_gen_codes``).
2. **BSGen** turns thresholds into ``stream_len``-cycle bitstreams against
   *distinct Sobol dimensions* per operand (dim 0 for A, dim 1 for B —
   shared-sequence XOR shifts would stay correlated under AND and compute
   ``min`` rather than a product).
3. The per-cycle **AND** products are accumulated over cycles *and* the
   common dimension by an exact integer adder tree (one ``einsum`` with
   int32 accumulation — bit products are in {-1, 0, 1}, so counts are
   exact while ``stream_len * k < 2^31``).
4. Decode scales counts by ``vmax^2 / stream_len`` (sign-magnitude, the
   same convention as ``ugemm_exact``).

Stream length ``L`` is the engine's accuracy/energy knob: the error
against exact uGEMM falls roughly as ``1/L`` (Sobol pairing — see
``repro.analysis.ranges.stochastic_error_bound``) while worst-case cycles
are exactly ``L`` per outer-product slot structure, independent of the
common dimension (every k-lane streams in parallel into the adder tree,
as in uGEMM).

:func:`scaled_output_stream` additionally models UnarySim's *UnaryLinear*
scaled accumulation — folding the per-cycle popcount of ``k`` product bits
back into a single rate-coded output stream with ``acc_bound`` /
``offset`` bookkeeping — for stream-faithful layer composition; the GEMM
decode path above uses the parallel counter read-out.

:func:`stochastic_design_spec` packages the engine as a *pure*
``DesignSpec`` (no registry mutation — the same closure pattern as the
Pallas kernel mirrors), which ``repro.backends.resolve`` exposes as
``resolve("ugemm_stochastic", bits=..., stream_len=...)``.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import gemm_sims
from repro.core.quantization import vmax
from repro.stochastic import gen

__all__ = [
    "STOCHASTIC_DESIGN", "default_stream_len", "stochastic_gemm",
    "stochastic_gemm_stream", "stochastic_design_spec",
    "UnaryLinearAcc", "scaled_output_stream",
]

#: The design-family name ``repro.backends.resolve`` accepts (optionally
#: spelled ``"ugemm_stochastic:<stream_len>"``).
STOCHASTIC_DESIGN = "ugemm_stochastic"


def default_stream_len(bits: int) -> int:
    """One full RNG period — the stream length exact uGEMM implicitly uses."""
    return 2 ** bits


def _bitstreams(codes, bits: int, stream_len: int, *, dim: int, seed: int,
                rng_kind: str) -> jax.Array:
    """Signed bitstreams: BSGen on |codes| times the code's sign.

    Shape ``(stream_len, *codes.shape)`` int8 in {-1, 0, 1}; the sign rides
    along so one integer contraction accumulates signed counts.
    """
    q = jnp.asarray(codes, jnp.int32)
    tau = gen.source_gen_codes(jnp.abs(q), bits)
    seq = gen.rng_sequence(rng_kind, bits, stream_len, dim=dim, seed=seed)
    return gen.bsgen(tau, seq) * jnp.sign(q).astype(jnp.int8)[None]


@functools.partial(jax.jit,
                   static_argnames=("bits", "stream_len", "seed", "rng_kind"))
def stochastic_gemm(a, b, bits: int = 8, *, stream_len: int | None = None,
                    seed: int = 0, rng_kind: str = "sobol") -> jax.Array:
    """Rate-coded GEMM of signed integer codes ``a @ b``.

    ``a``: ``(m, k)``; ``b``: ``(k, n)``; both with entries in
    ``[-vmax(bits), vmax(bits)]``.  Returns float32 decoded estimates; the
    contraction itself is an exact int32 count.
    """
    if stream_len is None:
        stream_len = default_stream_len(bits)
    at = _bitstreams(a, bits, stream_len, dim=0, seed=seed, rng_kind=rng_kind)
    bt = _bitstreams(b, bits, stream_len, dim=1, seed=seed, rng_kind=rng_kind)
    counts = jnp.einsum("tmk,tkn->mn", at, bt,
                        preferred_element_type=jnp.int32)
    v = vmax(bits)
    return counts.astype(jnp.float32) * (v * v / stream_len)


def stochastic_gemm_stream(a, b, bits: int = 8, *,
                           stream_len: int | None = None, seed: int = 0,
                           rng_kind: str = "sobol"):
    """Streamed form: ``(estimate, cycles)`` — cycles is the stream length."""
    if stream_len is None:
        stream_len = default_stream_len(bits)
    est = stochastic_gemm(a, b, bits, stream_len=stream_len, seed=seed,
                          rng_kind=rng_kind)
    return est, stream_len


def stochastic_design_spec(stream_len: int, *, seed: int = 0,
                           rng_kind: str = "sobol") -> gemm_sims.DesignSpec:
    """A pure ``DesignSpec`` for one ``(stream_len, seed, rng)`` engine.

    Constructed per-backend (never registered in the global design
    registry — the ``source-lint`` registry-mutation rule); worst-case
    cycles are ``stream_len`` regardless of the common dimension, mirroring
    uGEMM's k-independent ``2^bits``.
    """
    if stream_len < 1:
        raise ValueError(f"stream_len must be >= 1, got {stream_len}")

    def exact_fn(a, b, bits):
        return stochastic_gemm(a, b, bits, stream_len=stream_len, seed=seed,
                               rng_kind=rng_kind)

    def stream_fn(a, b, bits):
        return stochastic_gemm_stream(a, b, bits, stream_len=stream_len,
                                      seed=seed, rng_kind=rng_kind)

    return gemm_sims.DesignSpec(
        name=STOCHASTIC_DESIGN,
        exact_fn=exact_fn,
        stream_fn=stream_fn,
        wc_cycles_fn=lambda bits, common_dim: stream_len,
        sparsity_aware=False,
        exact=False,
    )


# ---------------------------------------------------------------------------
# UnaryLinear scaled accumulation (UnarySim's output-stream regeneration)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class UnaryLinearAcc:
    """UnaryLinear accumulation bookkeeping (UnarySim conventions).

    ``acc_bound`` is the scaled-addition divisor (number of summed input
    streams, +1 when a bias stream joins); ``offset`` recenters bipolar
    sums so the output stream stays a valid rate code.
    """

    in_features: int
    bias: bool = False
    bipolar: bool = False

    @property
    def acc_bound(self) -> int:
        return self.in_features + (1 if self.bias else 0)

    @property
    def offset(self) -> float:
        if not self.bipolar:
            return 0.0
        return (self.in_features - 1) / 2 + (0.5 if self.bias else 0.0)


@functools.partial(jax.jit, static_argnames=("acc",))
def scaled_output_stream(product_bits, acc: UnaryLinearAcc) -> jax.Array:
    """Fold per-cycle product bits into one scaled rate-coded output stream.

    ``product_bits``: ``(L, ..., in_features)`` bits in {0, 1}.  Each cycle
    adds the popcount across ``in_features`` into a running accumulator and
    emits one output bit whenever it crosses ``acc_bound`` — a rate divider
    whose output 1-rate converges to ``sum_k p_k / acc_bound`` (plus the
    bipolar ``offset`` recentering).  Returns int8 ``(L, ...)`` bits.
    """
    psum = jnp.sum(jnp.asarray(product_bits, jnp.int32), axis=-1)

    def step(carry, s):
        carry = carry + s
        bit = (carry >= acc.acc_bound).astype(jnp.int8)
        return carry - bit.astype(jnp.int32) * acc.acc_bound, bit

    init = jnp.zeros(psum.shape[1:], jnp.int32)
    _, out = jax.lax.scan(step, init, psum)
    return out
