"""HLO analysis: collective-byte extraction + roofline terms from compiled
artifacts (the CPU-only container's substitute for a real profile).

``collective_bytes`` parses the (SPMD-partitioned, per-device) HLO text and
sums a per-chip wire-byte model over every collective:

    all-reduce        : 2 x |operand|   (ring: reduce-scatter + all-gather)
    all-gather        : 1 x |result|    (each chip receives ~the full result)
    reduce-scatter    : 1 x |operand|
    all-to-all        : 1 x |operand|
    collective-permute: 1 x |operand|

Shapes in partitioned HLO are already per-device.
"""

from __future__ import annotations

import dataclasses
import re

import numpy as np

__all__ = ["collective_bytes", "CollectiveStats", "RooflineTerms", "roofline",
           "HW"]

# TPU v5e-class hardware constants (per chip) — see assignment.
HW = dict(peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

# e.g.  %all-reduce.5 = bf16[16,128]{1,0} all-reduce(%x), ...
#       ROOT %r = (f32[2,4], f32[]) tuple(...)
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^=]*?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute|"
    r"[\w\-]+)\(", re.M)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
           "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_bytes(shape_text: str) -> int:
    """Total bytes of all array shapes appearing in ``shape_text``."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    total_bytes: float
    by_op: dict[str, float]
    counts: dict[str, int]


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Parse HLO text; wire-byte model per chip (see module docstring)."""
    # First pass: result shapes for every named instruction.
    result_shape: dict[str, str] = {}
    op_of: dict[str, str] = {}
    for m in _INSTR_RE.finditer(hlo_text):
        name, shape_text, opcode = m.groups()
        result_shape[name] = shape_text
        op_of[name] = opcode

    by_op: dict[str, float] = {c: 0.0 for c in COLLECTIVES}
    counts: dict[str, int] = {c: 0 for c in COLLECTIVES}
    for m in _INSTR_RE.finditer(hlo_text):
        name, shape_text, opcode = m.groups()
        if opcode not in COLLECTIVES:
            continue
        counts[opcode] += 1
        # operand bytes: the instruction's operand list references %names
        line_start = m.start()
        line_end = hlo_text.find("\n", line_start)
        line = hlo_text[line_start:line_end]
        args = line.split("(", 1)[1] if "(" in line else ""
        operand_names = re.findall(r"%([\w.\-]+)", args)
        op_bytes = sum(_shape_bytes(result_shape.get(o, "")) for o in operand_names)
        # fall back to inline shapes in the operand list, then to the result
        if op_bytes == 0:
            op_bytes = _shape_bytes(args)
        if op_bytes == 0:
            op_bytes = _shape_bytes(shape_text)
        if opcode == "all-gather":
            op_bytes = _shape_bytes(shape_text)      # result bytes
        by_op[opcode] += _FACTOR[opcode] * op_bytes
    total = float(sum(by_op.values()))
    return CollectiveStats(total_bytes=total, by_op=by_op, counts=counts)


@dataclasses.dataclass
class RooflineTerms:
    """The three roofline terms (seconds) for one (arch, shape, mesh) cell."""

    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops: float            # per device
    hlo_bytes: float            # per device
    coll_bytes: float           # per device
    chips: int
    model_flops: float = 0.0    # 6·N·D (or 6·N_active·D) for the whole step

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Optimistic (max-overlap) step time estimate."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs x chips) — remat/redundancy waste."""
        if self.hlo_flops <= 0:
            return 0.0
        return self.model_flops / (self.hlo_flops * self.chips)

    @property
    def roofline_fraction(self) -> float:
        """Useful-model-FLOPs throughput / peak, at the estimated step time."""
        if self.step_time_s <= 0:
            return 0.0
        return (self.model_flops / self.step_time_s) / (
            self.chips * HW["peak_flops"])


def roofline(cost_analysis: dict, coll: CollectiveStats, chips: int,
             model_flops: float = 0.0) -> RooflineTerms:
    """Terms from ``compiled.cost_analysis()`` + parsed collective bytes.

    cost_analysis flops/bytes are per-device (the HLO module is the per-device
    program after SPMD partitioning).
    """
    flops = float(cost_analysis.get("flops", 0.0))
    byts = float(cost_analysis.get("bytes accessed", 0.0))
    return RooflineTerms(
        compute_s=flops / HW["peak_flops"],
        memory_s=byts / HW["hbm_bw"],
        collective_s=coll.total_bytes / HW["ici_bw"],
        hlo_flops=flops, hlo_bytes=byts, coll_bytes=coll.total_bytes,
        chips=chips, model_flops=model_flops)
