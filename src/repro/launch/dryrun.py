import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
meshes and extract memory / cost / collective statistics.

The two lines above MUST stay the first statements in this module — jax locks
the device count on first init, and the dry-run needs 512 placeholder CPU
devices to build the 16x16 (single-pod) and 2x16x16 (multi-pod) meshes.
Do NOT set that flag anywhere else (tests/benchmarks see the 1 real device).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod|--both]
    PYTHONPATH=src python -m repro.launch.dryrun --all --out experiments/dryrun

Writes one JSON per cell to --out (consumed by benchmarks/roofline.py and
EXPERIMENTS.md §Dry-run/§Roofline).
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs
from repro.launch import hlo_cost, hlo_stats
from repro.launch.mesh import make_production_mesh
from repro.launch import steps as steps_lib
from repro.models import model as model_lib
from repro.models.common import ParamDef
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig


def _param_sizes(cfg: ModelConfig):
    """(total, matmul_active) parameter counts from defs (no allocation)."""
    defs = model_lib.model_defs(cfg)
    total = active = 0.0
    expert_frac = None
    if cfg.is_moe:
        expert_frac = cfg.moe.top_k / cfg.moe.num_experts
    flat = jax.tree_util.tree_flatten_with_path(
        defs, is_leaf=lambda x: isinstance(x, ParamDef))[0]
    for path, d in flat:
        names = [str(getattr(p, "key", "")) for p in path]
        sz = 1.0
        for s in d.shape:
            sz *= s
        total += sz
        if len(d.shape) < 2:
            continue
        if "embed" in names and not cfg.tie_embeddings:
            continue  # lookup table: no matmul flops (lm_head counted separately)
        frac = 1.0
        if expert_frac is not None and "moe" in names and names[-1] in (
                "w_gate", "w_up", "w_down") and "shared" not in names:
            frac = expert_frac
        active += sz * frac
    return total, active


def model_flops(cfg: ModelConfig, shape_name: str) -> float:
    """Assignment convention: 6·N·D train / 2·N·D inference (N = active)."""
    sh = configs.SHAPES[shape_name]
    _, active = _param_sizes(cfg)
    if sh["step"] == "train":
        return 6.0 * active * sh["global_batch"] * sh["seq_len"]
    if sh["step"] == "prefill":
        return 2.0 * active * sh["global_batch"] * sh["seq_len"]
    return 2.0 * active * sh["global_batch"]  # decode: one token per request


def lower_cell(cfg: ModelConfig, shape_name: str, mesh):
    """Build the right step and .lower() it with ShapeDtypeStruct inputs."""
    sh = configs.SHAPES[shape_name]
    b, s = sh["global_batch"], sh["seq_len"]
    ins = steps_lib.input_specs(cfg, shape_name)
    if sh["step"] == "train":
        opt_cfg = AdamWConfig(state_dtype="bfloat16" if cfg.fsdp else "float32")
        step = steps_lib.make_train_step(cfg, mesh, opt_cfg, batch_size=b)
        state_shapes = jax.eval_shape(
            lambda: steps_lib.init_train_state(cfg, opt_cfg, jax.random.PRNGKey(0)))
        return step.lower(state_shapes, ins)
    params = jax.eval_shape(lambda: model_lib.init_params(cfg, jax.random.PRNGKey(0)))
    caches = steps_lib.cache_input_specs(cfg, b, s)
    if sh["step"] == "prefill":
        step = steps_lib.make_prefill_step(cfg, mesh, batch_size=b, max_len=s)
        return step.lower(params, ins, caches)
    step = steps_lib.make_decode_step(cfg, mesh, batch_size=b, max_len=s)
    return step.lower(params, ins["tokens"], caches, ins["cache_pos"])


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str | None,
             ep_impl: str | None = None) -> dict:
    cfg = configs.get_config(arch)
    if ep_impl and cfg.is_moe:
        import dataclasses as dc
        cfg = cfg.replace(moe=dc.replace(cfg.moe, ep_impl=ep_impl))
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
           "ep_impl": ep_impl or (cfg.moe.ep_impl if cfg.is_moe else None)}
    t0 = time.time()
    with mesh:
        lowered = lower_cell(cfg, shape_name, mesh)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

        ca = compiled.cost_analysis() or {}
        rec["cost_analysis"] = {k: float(v) for k, v in ca.items()
                                if isinstance(v, (int, float)) and k in
                                ("flops", "bytes accessed", "transcendentals",
                                 "optimal_seconds", "utilization")}
        try:
            ma = compiled.memory_analysis()
            rec["memory_analysis"] = {
                a: int(getattr(ma, a)) for a in
                ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(ma, a)}
        except Exception as e:  # noqa: BLE001 — backend-dependent
            rec["memory_analysis"] = {"error": str(e)}

        hlo = compiled.as_text()
        # XLA's cost_analysis counts while-loop (scan) bodies once; the
        # trip-count-aware analyzer (hlo_cost) is the roofline source.
        # Both are recorded; the discrepancy == scan undercount.
        hc = hlo_cost.analyze(hlo)
        rec["hlo_cost"] = {"flops": hc.flops, "bytes": hc.bytes_accessed,
                           "collective_bytes": hc.collective_bytes,
                           "coll_by_op": hc.coll_by_op,
                           "coll_counts": hc.coll_counts}
        mf = model_flops(cfg, shape_name)
        coll = hlo_stats.CollectiveStats(total_bytes=hc.collective_bytes,
                                         by_op=hc.coll_by_op,
                                         counts={k: int(v) for k, v in
                                                 hc.coll_counts.items()})
        terms = hlo_stats.roofline(
            {"flops": hc.flops, "bytes accessed": hc.bytes_accessed},
            coll, chips, mf)
        rec["roofline"] = {
            "compute_s": terms.compute_s, "memory_s": terms.memory_s,
            "collective_s": terms.collective_s, "dominant": terms.dominant,
            "model_flops": mf,
            "useful_flops_ratio": terms.useful_flops_ratio,
            "roofline_fraction": terms.roofline_fraction,
            "step_time_s": terms.step_time_s,
        }
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch}_{shape_name}_{rec['mesh']}"
        if ep_impl:
            tag += f"_{ep_impl}"
        with open(os.path.join(out_dir, tag.replace("/", "-") + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(configs.ARCH_IDS))
    ap.add_argument("--shape", default=None, choices=list(configs.SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both", action="store_true")
    ap.add_argument("--ep-impl", default=None, choices=["psum", "a2a"])
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    if args.all:
        cells = configs.cells()
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        if not configs.shape_applicable(configs.get_config(args.arch), args.shape):
            print(f"SKIP {args.arch} x {args.shape}: long_500k needs "
                  "sub-quadratic attention (see DESIGN.md)")
            return 0
        cells = [(args.arch, args.shape)]

    pods = [False, True] if args.both else [args.multi_pod]
    failures = 0
    for arch, shape in cells:
        for mp in pods:
            tag = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}"
            try:
                rec = run_cell(arch, shape, mp, args.out, args.ep_impl)
                r = rec["roofline"]
                print(f"OK   {tag}: compile={rec['compile_s']}s "
                      f"dominant={r['dominant']} "
                      f"terms=({r['compute_s']:.2e},{r['memory_s']:.2e},"
                      f"{r['collective_s']:.2e})s "
                      f"useful={r['useful_flops_ratio']:.2f}", flush=True)
            except Exception:
                failures += 1
                print(f"FAIL {tag}\n{traceback.format_exc()}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
