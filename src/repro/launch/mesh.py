"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — required because the
dry-run pins the device count via XLA_FLAGS before any jax init, while tests
and benchmarks must keep seeing the single real CPU device.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh", "make_grid_mesh",
           "single_device_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (elastic re-mesh path; see runtime.plan_mesh).

    Uses the first prod(shape) devices so a 256-chip mesh builds fine in the
    512-placeholder-device dry-run process.
    """
    n = 1
    for s in shape:
        n *= s
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(f"need {n} devices, have {len(devs)} "
                           "(dry-run must set xla_force_host_platform_device_count)")
    return jax.make_mesh(shape, axes, devices=devs[:n])


def make_grid_mesh(units_x: int, units_y: int):
    """Mesh for a ``units_x`` × ``units_y`` PE-array grid backend.

    Axes are ``("gx", "gy")`` — ``gx`` is the contraction-dim partition the
    partial-sum psum reduces over, ``gy`` the output-column partition (see
    ``repro.backends.grid``).  Deliberately disjoint from the model-parallel
    axis names (``data``/``model``/``pod``) so the modeling layer's logical
    sharding rules all fall back to replication on a grid mesh and the only
    partitioned compute is the grid's own shard_map.

    Needs ``units_x * units_y`` visible devices (pin fake host devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before jax init).
    """
    return make_mesh((units_x, units_y), ("gx", "gy"))


def single_device_mesh(model_axis: bool = True):
    """Trivial mesh for CPU tests: same axis names, size-1 axes."""
    if model_axis:
        return jax.make_mesh((1, 1), ("data", "model"))
    return jax.make_mesh((1,), ("data",))
