"""GPipe-style pipeline parallelism over the ``pod`` axis.

The production meshes put 256 chips in a pod; the multi-pod mesh adds a
``pod`` axis that §Dry-run exercises as a pure data axis.  This module
provides the alternative: treat pods as PIPELINE STAGES — layers are split
into ``n_pods`` contiguous stages, microbatches stream through a
``shard_map`` whose only cross-stage communication is a ``lax.ppermute`` of
the (microbatch, seq, d_model) activation per tick (point-to-point over the
inter-pod DCI links, instead of gradient all-reduces spanning pods).

Differentiable by construction: the transpose of ``ppermute`` is the reverse
permute, so wrapping the pipelined forward in a loss gives pipeline-parallel
*training* gradients from plain ``jax.grad`` (bubble fraction
``(P-1)/(M+P-1)`` as usual for GPipe).

This is a capability + correctness test (tests/test_pipeline.py), not the
default path — the assigned shapes are lowered with the pod axis as data
parallelism, which wins at these batch sizes.
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

__all__ = ["pipeline_apply", "split_stages"]


def split_stages(stacked_params, n_stages: int):
    """Reshape stacked (L, ...) layer params into (n_stages, L/n_stages, ...)."""
    def rs(a):
        l = a.shape[0]
        if l % n_stages:
            raise ValueError(f"{l} layers not divisible by {n_stages} stages")
        return a.reshape(n_stages, l // n_stages, *a.shape[1:])
    return jax.tree_util.tree_map(rs, stacked_params)


def pipeline_apply(stage_fn: Callable, staged_params, x, mesh,
                   axis: str = "pod"):
    """Run ``x``'s microbatches through the layer pipeline.

    stage_fn(stage_params, h) -> h : applies ONE stage's layers.
    staged_params: pytree with leading (n_stages, ...) axis (see split_stages).
    x: (n_micro, mb, ...) microbatched activations (replicated across pods).
    Returns (n_micro, mb, ...) outputs (replicated).
    """
    n_stages = mesh.shape[axis]
    n_micro = x.shape[0]

    def block(params_local, xb):
        # shard_map gives each pod its stage slice with a leading axis of 1
        params_local = jax.tree_util.tree_map(lambda a: a[0], params_local)
        p = lax.axis_index(axis)
        buf = jnp.zeros_like(xb[0])
        outs = jnp.zeros_like(xb)
        fwd_perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
        for t in range(n_micro + n_stages - 1):
            # stage 0 ingests microbatch t (zeros once the stream dries up)
            feed = xb[t] if t < n_micro else jnp.zeros_like(xb[0])
            buf = jnp.where(p == 0, feed, buf)
            buf = stage_fn(params_local, buf)
            # last stage emits microbatch t-(P-1)
            out_idx = t - (n_stages - 1)
            if 0 <= out_idx < n_micro:
                emit = jnp.where(p == n_stages - 1, buf, jnp.zeros_like(buf))
                outs = outs.at[out_idx].add(emit)
            buf = lax.ppermute(buf, axis, fwd_perm)
        # outputs live on the last pod only; sum-replicate across stages
        return lax.psum(outs, axis)

    fn = shard_map(
        block, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_vma=False)
    return fn(staged_params, x)
