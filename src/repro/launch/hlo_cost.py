"""Trip-count-aware HLO cost analysis.

XLA's built-in ``compiled.cost_analysis()`` counts ``while`` bodies ONCE —
for scan-over-layers models that undercounts FLOPs/bytes/collectives by the
layer count (verified empirically: a 24-iteration scan of a matmul reports
1/24th the flops of its unrolled twin).  This module re-derives the three
roofline inputs from the compiled, SPMD-partitioned HLO text with loop
multiplicity applied:

* **flops** — every ``dot`` counted as ``2 * |result| * K`` (contracted dims
  from the printed ``lhs_contracting_dims``), scaled by the product of
  enclosing-loop trip counts (``backend_config known_trip_count``, which jax
  scans always carry).  Elementwise flops are ignored (<1% for these models;
  transcendentals are reported separately by XLA if needed).
* **bytes** — per executed top-level instruction (fusion / dot / copy /
  collectives / dynamic-slice...), operand + result array bytes: a standard
  HBM-traffic proxy for post-fusion scheduled HLO.
* **collective wire bytes** — same model as ``hlo_stats.collective_bytes``
  (all-reduce 2x operand, all-gather 1x result, others 1x operand), now
  loop-scaled.

Shapes in the partitioned module are per-device, so all outputs are per-chip.
"""

from __future__ import annotations

import dataclasses
import json
import re

__all__ = ["analyze", "HloCost"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# result shape may be a tuple containing /*index=N*/ comments; match lazily up
# to the first `opcode(` token.
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\(")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "while", "conditional", "call", "after-all", "partition-id", "replica-id",
    "bitcast-convert",
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_COLL_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(shape_text: str) -> list[int]:
    m = _SHAPE_RE.search(shape_text)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class _Instr:
    name: str
    shape: str
    opcode: str
    line: str
    args: str       # text after the opcode's opening paren


@dataclasses.dataclass
class HloCost:
    flops: float
    bytes_accessed: float
    collective_bytes: float
    coll_by_op: dict[str, float]
    coll_counts: dict[str, float]
    dot_flops_by_comp: dict[str, float]
    # (total_bytes, mult, opcode, shape, comp) of the top byte contributors —
    # the "profile" the §Perf loop reads in lieu of a real-TPU trace.
    top_bytes: list = dataclasses.field(default_factory=list)
    top_coll: list = dataclasses.field(default_factory=list)


def _split_computations(text: str) -> dict[str, list[str]]:
    """computation name -> instruction lines."""
    comps: dict[str, list[str]] = {}
    cur: str | None = None
    for line in text.splitlines():
        if line and not line[0].isspace():
            if ((line.startswith("%") or line.startswith("ENTRY"))
                    and line.rstrip().endswith("{")):
                m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)", line)
                cur = m.group(1) if m else None
                if cur is not None:
                    comps[cur] = []
            elif line.startswith("}"):
                cur = None
        elif cur is not None:
            comps[cur].append(line)
    return comps


def _entry_name(text: str) -> str | None:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)\s*\(", text, re.M)
    return m.group(1) if m else None


def analyze(hlo_text: str, top_n: int = 24) -> HloCost:
    comps = _split_computations(hlo_text)
    entry = _entry_name(hlo_text)

    # global result-shape table (instruction names are module-unique)
    shape_of: dict[str, str] = {}
    parsed: dict[str, list[_Instr]] = {}
    for cname, lines in comps.items():
        instrs = []
        for line in lines:
            m = _INSTR_RE.match(line)
            if not m:
                continue
            name, shape, opcode = m.groups()
            shape_of[name] = shape
            instrs.append(_Instr(name, shape, opcode, line, line[m.end():]))
        parsed[cname] = instrs

    # call edges: comp -> [(callee, multiplier, is_control_flow)]
    # Control-flow edges (while body/cond, conditional branches, call) keep
    # the callee byte-countable; `calls=`/`to_apply=` edges mark the callee as
    # a fused/applied computation — its instructions produce no HBM traffic of
    # their own (the fusion boundary is charged instead), but dots inside
    # still count flops.
    edges: dict[str, list[tuple[str, float, bool]]] = {c: [] for c in comps}
    for cname, instrs in parsed.items():
        for ins in instrs:
            if ins.opcode == "while":
                trip = 1.0
                mt = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', ins.line)
                if mt:
                    trip = float(mt.group(1))
                mb = re.search(r"body=%?([\w.\-]+)", ins.line)
                mc = re.search(r"condition=%?([\w.\-]+)", ins.line)
                if mb:
                    edges[cname].append((mb.group(1), trip, True))
                if mc:
                    edges[cname].append((mc.group(1), trip + 1, True))
            elif ins.opcode in ("conditional", "call"):
                for mm in re.finditer(r"(?:branch_computations|to_apply)="
                                      r"\{?%?([\w.\-,%\s]+)\}?", ins.line):
                    for callee in re.findall(r"[\w.\-]+", mm.group(1)):
                        if callee in comps:
                            edges[cname].append((callee, 1.0, True))
            else:
                for mm in re.finditer(r"(?:calls|to_apply)="
                                      r"\{?%?([\w.\-,%\s]+)\}?", ins.line):
                    for callee in re.findall(r"[\w.\-]+", mm.group(1)):
                        if callee in comps:
                            edges[cname].append((callee, 1.0, False))

    # multiplicity via DFS from entry; byte_countable = reached through
    # control-flow edges only (never inside a fused computation)
    mult: dict[str, float] = {c: 0.0 for c in comps}
    byte_countable: set[str] = set()
    if entry is None:
        for c in comps:
            mult[c] = 1.0
            byte_countable.add(c)
    else:
        stack = [(entry, 1.0, True)]
        while stack:
            c, m, cf = stack.pop()
            mult[c] = mult.get(c, 0.0) + m
            if cf:
                byte_countable.add(c)
            for callee, k, edge_cf in edges.get(c, []):
                stack.append((callee, m * k, cf and edge_cf))

    def operand_names(ins: _Instr) -> list[str]:
        return re.findall(r"%([\w.\-]+)", ins.args.split(")", 1)[0])

    def operand_bytes(ins: _Instr) -> int:
        names = operand_names(ins)
        b = sum(_shape_bytes(shape_of.get(n, "")) for n in names)
        if b == 0:
            b = _shape_bytes(ins.args.split(")", 1)[0])
        return b

    def fusion_callee(ins: _Instr) -> str | None:
        m = re.search(r"calls=%?([\w.\-]+)", ins.line)
        return m.group(1) if m and m.group(1) in parsed else None

    def instr_bytes(ins: _Instr) -> float:
        """HBM-traffic model per executed instruction.

        Slicing ops read only the slice; in-place updates touch only the
        updated region; fusions are inspected for internal dynamic-(update-)
        slices of their parameters so loop-carried stacked buffers (scanned
        layer weights / residual stashes) are charged per-slice, not
        per-full-buffer, per iteration.
        """
        res_b = _shape_bytes(ins.shape)
        if ins.opcode in ("dynamic-slice", "gather"):
            return 2.0 * res_b
        if ins.opcode == "dynamic-update-slice":
            ops = operand_names(ins)
            upd = _shape_bytes(shape_of.get(ops[1], "")) if len(ops) > 1 else res_b
            return 2.0 * upd
        if ins.opcode != "fusion":
            return float(operand_bytes(ins) + res_b)
        # fusion: per-parameter traffic via internal consumers
        callee = fusion_callee(ins)
        ops = operand_names(ins)
        if callee is None:
            return float(operand_bytes(ins) + res_b)
        callee_instrs = parsed[callee]
        # parameter index -> internal instruction name
        param_name: dict[int, str] = {}
        for ci in callee_instrs:
            if ci.opcode == "parameter":
                mi = re.match(r"\s*(\d+)", ci.args)
                if mi:
                    param_name[int(mi.group(1))] = ci.name
        # transitive alias set: instructions that are pure views of a param
        total = 0.0
        dus_update_b = 0.0
        internal_dus = None
        for ci in callee_instrs:
            if ci.opcode == "dynamic-update-slice":
                internal_dus = ci
                onames = operand_names(ci)
                if len(onames) > 1:
                    dus_update_b = _shape_bytes(shape_of.get(onames[1], ""))
        for i, oname in enumerate(ops):
            ob = _shape_bytes(shape_of.get(oname, ""))
            pn = param_name.get(i)
            if pn is None or ob == 0:
                total += ob
                continue
            # find direct consumers of this parameter inside the fusion
            charged = None
            aliases = {pn}
            for ci in callee_instrs:
                if ci.opcode in ("bitcast", "convert", "copy", "reshape") and \
                        set(operand_names(ci)) & aliases and \
                        _shape_bytes(ci.shape) == ob:
                    aliases.add(ci.name)
            for ci in callee_instrs:
                if not (set(operand_names(ci)) & aliases):
                    continue
                if ci.opcode == "dynamic-slice":
                    charged = (charged or 0.0) + _shape_bytes(ci.shape)
                elif ci.opcode == "dynamic-update-slice" and \
                        operand_names(ci)[0] in aliases:
                    charged = (charged or 0.0) + dus_update_b
            total += ob if charged is None else min(ob, charged)
        if internal_dus is not None:
            # in-place update: write only the updated region
            return total + dus_update_b
        return total + res_b

    flops = 0.0
    byts = 0.0
    coll_bytes = 0.0
    coll_by_op = {c: 0.0 for c in COLLECTIVES}
    coll_counts = {c: 0.0 for c in COLLECTIVES}
    dot_by_comp: dict[str, float] = {}
    contributors: list = []
    coll_contrib: list = []

    for cname, instrs in parsed.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        for ins in instrs:
            if ins.opcode == "dot":
                res = 1
                for d in _shape_dims(ins.shape):
                    res *= d
                # contracted size from lhs operand shape + contracting dims
                k = 1
                mdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
                onames = re.findall(r"%([\w.\-]+)", ins.args.split(")", 1)[0])
                if mdims and onames:
                    lhs_dims = _shape_dims(shape_of.get(onames[0], ""))
                    for ci in mdims.group(1).split(","):
                        if ci and int(ci) < len(lhs_dims):
                            k *= lhs_dims[int(ci)]
                f = 2.0 * res * k
                flops += m * f
                dot_by_comp[cname] = dot_by_comp.get(cname, 0.0) + m * f
            if ins.opcode in COLLECTIVES:
                ob = operand_bytes(ins)
                if ins.opcode == "all-gather":
                    ob = _shape_bytes(ins.shape)
                coll_by_op[ins.opcode] += m * _COLL_FACTOR[ins.opcode] * ob
                coll_counts[ins.opcode] += m
                coll_bytes += m * _COLL_FACTOR[ins.opcode] * ob
                coll_contrib.append((m * _COLL_FACTOR[ins.opcode] * ob, m,
                                     ins.opcode, ins.shape[:60], cname[:40]))
            if ins.opcode not in _SKIP_BYTES_OPS and cname in byte_countable:
                ib = instr_bytes(ins)
                byts += m * ib
                contributors.append((m * ib, m, ins.opcode, ins.shape[:48],
                                     cname[:40]))

    contributors.sort(reverse=True)
    coll_contrib.sort(reverse=True)
    return HloCost(flops=flops, bytes_accessed=byts,
                   collective_bytes=coll_bytes, coll_by_op=coll_by_op,
                   coll_counts=coll_counts, dot_flops_by_comp=dot_by_comp,
                   top_bytes=contributors[:top_n],
                   top_coll=coll_contrib[:top_n])
