"""Batched serving driver: prefill + decode with unary-DLA energy accounting.

This is where the paper's technique meets the serving stack:

* **pricing** (always on): every quantized GEMM in the model is priced on a
  chosen unary/binary PE-array backend (--gemm-backend, --bits) using the
  *measured* block-max bit sparsity of the actual weights (Eq. 1), giving
  per-token energy/latency for the whole model alongside the generated tokens.
* **execution** (--execute-backend): prefill and decode actually run every
  quantized dense layer through a typed ``repro.backends`` engine — int
  tiles contracted on the selected unary design (or its Pallas kernel
  mirror), dequantized back to the activation dtype — and the driver reports
  the int GEMMs' bit-exactness vs the binary oracle, the output drift vs the
  float model, and the measured cycle totals against the priced dyn/wc
  bounds.
* **planning** (``serve plan``): derive a per-layer mixed-precision backend
  plan for the served config (``repro.eval.planner``), save it to
  ``--plan-out``, and report predicted vs uniform-backend energy plus the
  measured decode-cycle totals per site.
* **plan replay** (--backend-plan FILE): execute prefill+decode with every
  dense site contracted on the backend its plan entry names, with the same
  bit-exactness / drift / cycle-bounds evidence as --execute-backend, per
  site.
* **grid serving** (--grid X,Y): everything above on a tensor-parallel
  PE-array grid.  ``serve plan --grid X,Y`` derives a per-shard
  heterogeneous ``GridPlan`` (each shard's weight slice has its own
  sparsity profile); execution modes shard every dense contraction under
  ``shard_map`` on an X×Y device mesh (``launch.mesh.make_grid_mesh``) with
  the k-dim partial sums psum-reduced, report bit-exactness vs the
  *unsharded* binary oracle, and check measured cycles within the
  [Eq. 1 floor, wc] bounds per shard.

    PYTHONPATH=src python -m repro.launch.serve plan --arch llama3-8b \
        --smoke --unit-n 64 --plan-out reports/plan.json
    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
        --backend-plan reports/plan.json --tokens 8
    # sharded: derive + replay a 2x2 grid plan on 4+ (fake) host devices
    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
        python -m repro.launch.serve plan --arch llama3-8b --smoke \
        --unit-n 64 --grid 2,2 --plan-out reports/grid_plan.json
    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
        python -m repro.launch.serve --arch llama3-8b --smoke \
        --backend-plan reports/grid_plan.json --grid 2,2 --tokens 8
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import backends as backends_lib
from repro import configs
from repro.core import accounting, packing, ppa, sparsity
from repro.core import gemm_sims as gemm_sims_lib
from repro.core.quantization import quantize
from repro.eval import planner as planner_lib
from repro.eval import sweetspot as sweetspot_lib
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_grid_mesh, single_device_mesh
from repro.models import model as model_lib
from repro.serving import (FUSED_LOGIT_TOL, ServingEngine, TrafficConfig,
                           fused_vs_gather_probe, generate_trace,
                           paged_vs_contiguous_probe)
from repro.serving import energy as serving_energy


def _iter_weight_matrices(cfg, params):
    """Yield ``(name, (k, n_out) float32 weight)`` for every priced matmul.

    The single walk the pricing workload, the measured-cycle report AND the
    serving engine's energy-per-token model are built from (the canonical
    implementation lives in ``repro.serving.energy``), so they all see
    identical matrices.
    """
    return serving_energy.iter_weight_matrices(cfg, params)


def build_workload(cfg, params, batch: int, ctx_len: int, bits: int):
    """GemmCalls for ONE decode step, with measured per-matrix sparsity."""
    rec = accounting.GemmWorkloadRecorder()
    stats = {}
    for name, w in _iter_weight_matrices(cfg, params):
        st = sparsity.profile_tensor(jnp.asarray(w), bits=bits)
        stats[name] = st
        k, n_out = w.shape
        rec.record(name, m=batch, k=k, n_out=n_out,
                   bit_sparsity=st.bit_blockmax, count=1)
    return rec, stats


def validate_backend_numerics(params, design, bits: int | None = None,
                              n_tiles: int = 8, tile: int = 16,
                              oracle: str = "bgemm") -> float:
    """Spot-check the selected GEMM backend on tiles of the real weights.

    Quantizes ``n_tiles`` (tile x tile) slices of actual model weights,
    stacks them on a batch axis, and pushes the whole stack through
    ``GemmBackend.execute`` in one batched call against the ``oracle``
    design (binary by default).  ``design`` is a backend name or
    ``repro.backends.GemmBackend`` (``bits`` then defaults to the backend's
    own width).  Exact designs (tu/tub/b and the Pallas mirrors) must come
    back bit-identical — returns 0.0 — while uGEMM reports its stochastic
    relative RMSE.  Rate-coded stochastic backends are judged with
    ``oracle="ugemm"`` — the exact uGEMM value their bitstreams converge to
    at L=2^bits — so the number isolates the *stream-length* error.
    """
    backend = backends_lib.resolve(design, bits=bits)
    oracle = backends_lib.resolve(oracle, bits=backend.bits)
    # Packed leaves dequantize for tiling — the spot-check wants float
    # matrices to quantize fresh at the backend's width.
    leaves = [l.dequantize() if packing.is_packed(l) else l
              for l in jax.tree_util.tree_leaves(
                  params, is_leaf=packing.is_packed)]
    leaves = [l for l in leaves
              if hasattr(l, "ndim") and l.ndim >= 2 and l.size >= 2 * tile * tile]
    if not leaves:
        return 0.0
    tiles = []
    for i in range(2 * n_tiles):
        flat = np.asarray(leaves[i % len(leaves)], np.float32).reshape(-1)
        off = (i // len(leaves)) * tile * tile
        chunk = flat[off:off + tile * tile]
        if chunk.size < tile * tile:
            chunk = flat[:tile * tile]
        q = quantize(jnp.asarray(chunk.reshape(tile, tile)), bits=backend.bits,
                     per_channel=False)
        tiles.append(q.values.astype(jnp.int8))
    a = jnp.stack(tiles[:n_tiles])
    b = jnp.stack(tiles[n_tiles:])
    return gemm_sims_lib.rel_rmse(backend.execute(a, b), oracle.execute(a, b))


def _oracle_for(backend) -> str:
    """The oracle design a backend's numerics are judged against.

    Rate-coded stochastic backends carry a ``stream_len`` and converge to
    the exact uGEMM value, so that is their reference; everything else is
    checked against the binary int32 oracle.
    """
    return "ugemm" if getattr(backend, "stream_len", None) else "bgemm"


def measure_decode_cycles(cfg, params, backend, *, batch: int, unit_n: int,
                          num_units: int, stats=None) -> dict[str, float]:
    """Per-decode-token cycle totals for the model on one backend.

    Sums the shared measured-cycles contract
    (``repro.backends.measure_matrix_cycles`` — the same helper behind the
    planner's ``measure_site_cycles``) over every priced weight matrix.
    Four numbers per the DLA tiling ``core.ppa.DLAModel`` uses (per-tile
    cycles x ceil(tiles / num_units) waves, common dim = k):

    * ``wc`` — worst case, ``backend.cycles(k)`` per tile;
    * ``dyn_floor`` — Eq. 1 with *element-level* bit sparsity: every lane
      terminating at its own magnitude, an optimistic lower bound the shared
      slot schedule cannot beat;
    * ``measured`` — operand-driven: ``backend.dyn_cycles(operand=...)`` on
      the same **per-channel** quantized codes ``models/common.dense``
      contracts under ``use_backend`` — the cycles the early-terminating
      counters really take, with each outer-product step gated by the
      largest magnitude in flight;
    * ``dyn`` — the priced Eq. 1 estimate (worst case scaled by the
      block-max bit sparsity the cost tables use): gating at PE-block
      granularity.  Comparable to ``measured`` but not a bound on it — the
      statistic profiles a per-tensor grid while execution contracts
      per-channel codes.

    For sparsity-aware designs ``dyn_floor <= measured <= wc`` (wc caps
    every step); designs without early termination report all four equal.
    The serve driver checks ``dyn_floor <= measured <= wc``.

    ``stats`` — optional ``{name: SparsityStats}`` at ``backend.bits`` (from
    ``build_workload``) to skip re-profiling every weight matrix.
    """
    totals = {"wc": 0.0, "dyn": 0.0, "dyn_floor": 0.0, "measured": 0.0}
    for name, w in _iter_weight_matrices(cfg, params):
        st = (stats or {}).get(name)
        cyc = backends_lib.measure_matrix_cycles(
            backend, w, rows=batch, unit_n=unit_n, num_units=num_units,
            bit_blockmax=None if st is None else st.bit_blockmax,
            bit_elem=None if st is None else st.bit_elem)
        for key in totals:
            totals[key] += cyc[key]
    return totals


def generate(cfg, params, mesh, prompt, max_new: int, temperature: float = 0.0):
    """Greedy/temperature decoding with the jitted prefill/decode steps."""
    b, s = prompt.shape
    max_len = s + max_new
    prefill_step = steps_lib.make_prefill_step(cfg, mesh, params_like=params)
    decode_step = steps_lib.make_decode_step(cfg, mesh, params_like=params)
    with mesh:
        caches = model_lib.init_caches(cfg, b, max_len, dtype=jnp.float32)
        logits, caches = prefill_step(params, {"tokens": prompt}, caches)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out = [tok]
        key = jax.random.PRNGKey(0)
        for i in range(max_new - 1):
            logits, caches = decode_step(params, tok, caches,
                                         jnp.int32(s + i))
            if temperature > 0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(
                    sub, logits[:, -1] / temperature)[:, None].astype(jnp.int32)
            else:
                tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            out.append(tok)
    return jnp.concatenate(out, axis=1)


def prefill_logits(cfg, params, mesh, prompt):
    """Full prefill logits via a freshly traced step (so an active
    ``use_backend`` scope is honored — jitted steps bind the backend at
    trace time)."""
    prefill_step = steps_lib.make_prefill_step(cfg, mesh, params_like=params)
    with mesh:
        caches = model_lib.init_caches(cfg, prompt.shape[0],
                                       prompt.shape[1] + 1, dtype=jnp.float32)
        logits, _ = prefill_step(params, {"tokens": prompt}, caches)
    return logits


def run_backend_execution(cfg, params, mesh, prompt, backend, max_new: int,
                          *, unit_n: int, num_units: int,
                          ref_logits=None, stats=None,
                          packed: bool = False) -> dict:
    """Execute prefill+decode on ``backend`` and collect the evidence.

    Returns a dict: generated ``tokens``, number of distinct GEMM ``sites``
    contracted on the backend, int-GEMM ``rel_rmse`` vs the binary oracle,
    prefill-logits ``drift`` + ``top1_agreement`` vs the float model, wall
    time, and the measured/dyn/wc ``cycles`` totals per decode token.
    ``stats`` — optional pre-profiled sparsity stats at the backend's
    bit-width, forwarded to :func:`measure_decode_cycles`.  ``packed``
    freezes every GEMM site's weight bit-packed at the backend's width and
    executes from the packed store; the float ``params`` keep feeding the
    reference/measurement paths, so the evidence is comparable — and the
    execution is bit-identical — to the unpacked run.
    """
    backend = backends_lib.resolve(backend)
    exec_params = (backends_lib.pack_weights(cfg, params, bits=backend.bits)
                   if packed else params)
    if ref_logits is None:
        ref_logits = prefill_logits(cfg, params, mesh, prompt)
    t0 = time.time()
    with backends_lib.use_backend(backend) as execution:
        tokens = generate(cfg, exec_params, mesh, prompt, max_new)
        exec_logits = prefill_logits(cfg, exec_params, mesh, prompt)
    wall = time.time() - t0
    if not execution.calls:
        raise RuntimeError(
            "backend execution recorded no GEMM sites — the model traced "
            "outside the use_backend scope?")
    ref = np.asarray(ref_logits, np.float32)
    got = np.asarray(exec_logits, np.float32)
    agree = float(np.mean(np.argmax(got, -1) == np.argmax(ref, -1)))
    oracle = _oracle_for(backend)
    return {
        "backend": backend,
        "tokens": tokens,
        "sites": len(execution.calls),
        "wall_s": wall,
        "oracle": oracle,
        "rel_rmse": validate_backend_numerics(params, backend, oracle=oracle),
        "drift": gemm_sims_lib.rel_rmse(got, ref),
        "top1_agreement": agree,
        "cycles": measure_decode_cycles(cfg, params, backend,
                                        batch=prompt.shape[0], unit_n=unit_n,
                                        num_units=num_units, stats=stats),
    }


def run_plan_execution(cfg, params, mesh, prompt, plan, max_new: int,
                       *, ref_logits=None, packed: bool = False) -> dict:
    """Execute prefill+decode under ``use_plan`` and collect the evidence.

    Like :func:`run_backend_execution` but per-site: every dense site
    contracts on the backend its plan entry names (unmatched sites stay
    float).  ``plan`` may be a ``BackendPlan`` or a ``GridPlan`` — a grid
    plan's aggregate entries execute sharded (``GridBackend`` under
    ``shard_map`` on the grid mesh), the oracle comparison stays unsharded,
    and the measured cycles come back **per shard**.

    Returns generated ``tokens``, the ``site_backends`` mapping actually
    traced, per-distinct-backend int-GEMM ``rel_rmse`` vs the (unsharded)
    binary oracle, prefill ``drift`` / ``top1_agreement`` vs the float
    model, wall time, the ``grid`` shape (None unsharded), and per-site
    measured/dyn/floor/wc decode-cycle totals (``site_cycles``; for a grid,
    ``{site: {"gx,gy": totals}}``; DLA geometry from the plan's meta).
    """
    grid = plan.grid if isinstance(plan, backends_lib.GridPlan) else None
    entry_plan = plan.aggregate if grid else plan
    # packed: planned sites execute from the bit-packed store (bit-identical
    # codes); reference logits, numerics spot-checks, site discovery and
    # cycle measurement all keep reading the float params, so every evidence
    # field below matches the unpacked replay.
    exec_params = (backends_lib.pack_weights(cfg, params, plan)
                   if packed else params)
    if ref_logits is None:
        ref_logits = prefill_logits(cfg, params, mesh, prompt)
    t0 = time.time()
    with backends_lib.use_plan(plan) as execution:
        tokens = generate(cfg, exec_params, mesh, prompt, max_new)
        exec_logits = prefill_logits(cfg, exec_params, mesh, prompt)
    wall = time.time() - t0
    if not execution.calls:
        raise RuntimeError(
            "plan execution contracted no GEMM sites — do the plan's "
            "patterns match this model's site names?")
    site_backends = {
        c.site: f"{c.backend}@{c.bits}"
        + (f":{c.stream_len}" if getattr(c, "stream_len", 0) else "")
        for c in execution.calls}
    rel_rmse = {}
    for design, bits, stream_len in entry_plan.distinct_engines():
        tag = f"{design}@{bits}" + (f":{stream_len}" if stream_len else "")
        if not any(tag == t for t in site_backends.values()):
            continue
        backend = backends_lib.resolve(design, bits=bits,
                                       stream_len=stream_len or None)
        if grid:
            backend = backends_lib.as_grid(backend, *grid)
        rel_rmse[tag] = validate_backend_numerics(
            params, backend, oracle=_oracle_for(backend))
    ref = np.asarray(ref_logits, np.float32)
    got = np.asarray(exec_logits, np.float32)
    meta = entry_plan.metadata()
    unit_n = int(meta.get("unit_n", 64))
    num_units = int(meta.get("num_units", 64))
    sites = {s.name: s for s in planner_lib.discover_sites(
        cfg, params, batch=prompt.shape[0])}
    site_cycles = {}
    for entry in entry_plan.sites:
        site = sites.get(entry.pattern)
        if site is None or entry.pattern not in site_backends:
            continue
        if grid:
            site_cycles[entry.pattern] = planner_lib.measure_grid_site_cycles(
                site, entry, grid=grid, unit_n=unit_n, num_units=num_units)
        else:
            site_cycles[entry.pattern] = planner_lib.measure_site_cycles(
                site, entry, unit_n=unit_n, num_units=num_units)
    return {
        "tokens": tokens,
        "site_backends": site_backends,
        "wall_s": wall,
        "rel_rmse": rel_rmse,
        "drift": gemm_sims_lib.rel_rmse(got, ref),
        "top1_agreement": float(np.mean(np.argmax(got, -1)
                                        == np.argmax(ref, -1))),
        "grid": grid,
        "site_cycles": site_cycles,
    }


def _parse_stream_lens(spec: str | None) -> tuple[int, ...]:
    """``"16,32,64"`` -> ``(16, 32, 64)`` (empty/None -> no stochastic)."""
    if not spec:
        return ()
    try:
        lens = tuple(int(tok) for tok in spec.split(",") if tok.strip())
    except ValueError:
        raise SystemExit(f"error: --stream-lens must be a comma-separated "
                         f"list of ints, got {spec!r}")
    if any(L < 1 for L in lens):
        raise SystemExit(f"error: stream lengths must be >= 1, got {spec!r}")
    return lens


def run_plan_mode(args, cfg, params) -> int:
    """``serve plan``: derive, save and report a mixed-precision plan."""
    site_list = planner_lib.discover_sites(cfg, params, batch=args.batch)
    stream_lens = _parse_stream_lens(args.stream_lens)
    designs = planner_lib.DEFAULT_DESIGNS
    if stream_lens:
        designs = designs + (planner_lib.STOCHASTIC_DESIGN,)
    plan = planner_lib.build_plan(
        cfg, params, batch=args.batch, unit_n=args.unit_n,
        num_units=args.units, sites=site_list, designs=designs,
        stream_lens=stream_lens)
    path = plan.save(args.plan_out)
    meta = plan.metadata()
    totals = meta["totals"]
    sites = {s.name: s for s in site_list}

    print(f"\n=== backend plan for {args.arch} "
          f"({args.units}x {args.unit_n}x{args.unit_n} units, objective "
          f"{meta['objective']}) ===")
    print(f"{'site':>24s} {'engine':>20s} {'b_spa':>6s} {'dynE_uJ':>9s} "
          f"{'relMSE':>7s} {'measured_cyc':>13s} {'wc_cyc':>10s}")
    for e in plan.sites:
        cyc = planner_lib.measure_site_cycles(
            sites[e.pattern], e, unit_n=args.unit_n, num_units=args.units)
        print(f"{e.pattern:>24s} {e.engine_label:>20s} "
              f"{e.bit_blockmax:6.3f} {e.dyn_energy_uj:9.4f} "
              f"{e.rel_mse:7.4f} {cyc['measured']:13.1f} {cyc['wc']:10.1f}")
    planned = totals["planned"]
    print(f"\nplanned dyn energy {planned['dyn_energy_uj']:.4f} uJ / decode "
          f"step (wc {planned['wc_energy_uj']:.4f} uJ)")
    for name in sorted(totals["uniform"]):
        tot = totals["uniform"][name]
        mark = " <-- best uniform" if name == totals["uniform_best"] else ""
        print(f"  uniform {name:>12s}: dyn {tot['dyn_energy_uj']:.4f} uJ"
              f"{mark}")
    best = totals["uniform_best"]
    if best is not None:
        saving = 1.0 - planned["dyn_energy_uj"] \
            / max(totals["uniform"][best]["dyn_energy_uj"], 1e-30)
        print(f"plan vs best uniform ({best}): {saving:.2%} predicted "
              f"energy saving")
    distinct = plan.distinct_engines()
    print(f"distinct engines chosen: "
          f"{', '.join(f'{d}@{b}' + (f':{L}' if L else '') for d, b, L in distinct)} "
          f"({'mixed' if len(distinct) > 1 else 'uniform'} assignment)")
    print(analysis_verdict(plan, site_names=[s.name for s in site_list]))
    print(f"plan saved to {path} (replay: serve --arch {args.arch}"
          f"{' --smoke' if args.smoke else ''} --backend-plan {path})")
    return 0


def analysis_verdict(plan, site_names=None) -> str:
    """One-line static numeric-safety verdict for a plan.

    Runs ``repro.analysis.plan_lint`` over the plan (against the model's
    site inventory when given, so dead/shadowed patterns and unmatched
    sites are checked too) and renders the findings as the analysis CLI
    would — the serving report carries the same verdict the gate enforces.
    """
    from repro.analysis import findings as findings_lib
    from repro.analysis import plan_lint
    found = plan_lint.lint_plan(plan, site_names=site_names)
    for f in found:
        print(f"  {f.render()}")
    return findings_lib.verdict_line(found)


def run_grid_plan_mode(args, cfg, params, grid: tuple[int, int]) -> int:
    """``serve plan --grid X,Y``: derive, save and report a per-shard plan."""
    site_list = planner_lib.discover_sites(cfg, params, batch=args.batch)
    gplan = planner_lib.build_grid_plan(
        cfg, params, grid=grid, batch=args.batch, unit_n=args.unit_n,
        num_units=args.units, sites=site_list)
    path = gplan.save(args.plan_out)
    meta = gplan.metadata()
    totals = meta["totals"]
    agg = totals["aggregate"]
    sites = {s.name: s for s in site_list}

    print(f"\n=== grid backend plan for {args.arch} "
          f"({grid[0]}x{grid[1]} grid of {args.units}x {args.unit_n}x"
          f"{args.unit_n} nodes, objective {meta['objective']}) ===")
    print("aggregate (executed) assignment, with per-shard measured cycles:")
    for e in gplan.aggregate.sites:
        cyc = planner_lib.measure_grid_site_cycles(
            sites[e.pattern], e, grid=grid, unit_n=args.unit_n,
            num_units=args.units)
        shard_meas = ", ".join(f"{c}:{v['measured']:.0f}"
                               for c, v in sorted(cyc.items()))
        print(f"  {e.pattern:>24s} -> {e.design}@{e.bits} "
              f"(b_spa {e.bit_blockmax:.3f}, dynE {e.dyn_energy_uj:.4f} uJ; "
              f"measured cyc/shard {shard_meas})")
    print("\nper-shard verdicts (each shard plans its own weight slices):")
    for key, _plan in gplan.shards:
        v = totals["per_shard"][key]
        best = v["uniform_best"]
        best_e = v["uniform"][best]["dyn_energy_uj"] if best else 0.0
        print(f"  shard {key}: planned {v['planned']['dyn_energy_uj']:.4f} uJ"
              f" vs best uniform {best} {best_e:.4f} uJ")
    hetero = meta["heterogeneous_sites"]
    print(f"shard-heterogeneous sites: "
          f"{', '.join(hetero) if hetero else 'none'}")
    best = agg["uniform_best"]
    if best is not None:
        best_e = agg["uniform"][best]["dyn_energy_uj"]
        planned = agg["planned"]["dyn_energy_uj"]
        hetero_e = agg["planned_heterogeneous"]["dyn_energy_uj"]
        print(f"aggregate: executed plan {planned:.4f} uJ, per-shard "
              f"heterogeneous {hetero_e:.4f} uJ, best uniform ({best}) "
              f"{best_e:.4f} uJ -> {1.0 - hetero_e / max(best_e, 1e-30):.2%} "
              f"predicted saving")
    print(analysis_verdict(gplan, site_names=[s.name for s in site_list]))
    print(f"grid plan saved to {path} (replay: serve --arch {args.arch}"
          f"{' --smoke' if args.smoke else ''} --backend-plan {path} "
          f"--grid {grid[0]},{grid[1]})")
    return 0


def run_traffic_mode(args, cfg, params, grid, plan) -> int:
    """``serve traffic``: continuous vs static batching on one seeded trace.

    Generates a Poisson traffic trace, serves it twice through the SAME
    :class:`repro.serving.ServingEngine` (same paged pool geometry, same
    backend/plan scope) — once under continuous batching, once under static
    batching — and reports throughput, latency percentiles, batch occupancy
    and Eq.-1 energy per token for both.  Gates (non-zero exit) on:

    * continuous throughput >= static throughput on the same trace,
    * both schedulers completing every request; the per-request token
      streams must also be identical across schedulers — a strict gate on
      the float path and, under --execute-backend/--backend-plan, whenever
      ``--act-scale per-row`` is active (per-row activation quantization
      makes each request's integer codes a pure function of its own
      tokens).  Only under backend execution with the default per-tensor
      scale is the identity check informational: that scale spans the
      whole decode batch, so a request's tokens legitimately depend on
      which requests it is co-batched with,
    * the paged decode step staying bit-exact with the contiguous
      ``decode_step`` reference at fp32 (skipped under --grid: the sharded
      variant is covered by the tier-1 subprocess tests).
    """
    from repro.models import common as common_lib
    if args.execute_backend and plan is not None:
        print("error: serve traffic takes --execute-backend OR "
              "--backend-plan, not both")
        return 2
    tcfg = TrafficConfig(num_requests=args.requests,
                         arrival_rate=args.arrival_rate, seed=args.seed)
    trace = generate_trace(tcfg)
    engine_kw = dict(
        max_batch=args.batch, page_size=args.page_size,
        num_pages=args.num_pages, max_seq_len=args.max_seq_len,
        backend=args.execute_backend, plan=plan, bits=args.bits, grid=grid,
        unit_n=args.unit_n, num_units=args.units,
        pricing_design=args.gemm_backend, packed=args.packed)
    engine = ServingEngine(cfg, params, attention=args.decode_attention,
                           **engine_kw)
    scope = (f"plan {args.backend_plan}" if plan is not None
             else f"backend {args.execute_backend}@{args.bits}"
             if args.execute_backend else "float model")
    if args.packed:
        rep = accounting.packed_store_report(engine._exec_params)
        scope += " [packed]"
        print(f"packed weight store: {rep.packed_sites}/{rep.total_sites} "
              f"sites bit-packed, {rep.stored_bytes / 2**20:.2f} MiB vs "
              f"{rep.float32_bytes / 2**20:.2f} MiB fp32 "
              f"({rep.reduction:.2f}x smaller; packed sites alone "
              f"{rep.packed_reduction:.2f}x)")
    print(f"\n=== serving traffic on {args.arch}: {len(trace)} requests "
          f"(Poisson rate {args.arrival_rate}/step, seed {args.seed}), "
          f"{args.batch} slots, {engine.num_pages} pages x {args.page_size} "
          f"slots, {scope}, energy priced on {engine.energy.design} ===")
    with common_lib.activation_scaling(args.act_scale):
        reports = {name: engine.run(trace, name)
                   for name in ("continuous", "static")}
    print(f"{'scheduler':>12s} {'reqs':>5s} {'tokens':>7s} {'steps':>6s} "
          f"{'tok/step':>9s} {'p50':>6s} {'p99':>7s} {'queue':>6s} "
          f"{'occup':>6s} {'uJ/tok':>9s}")
    for name, r in reports.items():
        print(f"{name:>12s} {r.requests:5d} {r.tokens:7d} {r.steps:6d} "
              f"{r.throughput_tok_per_step:9.3f} {r.latency_p50:6.1f} "
              f"{r.latency_p99:7.1f} {r.queue_delay_mean:6.2f} "
              f"{r.occupancy:6.3f} {r.energy_per_token_uj:9.4f}")
    rc, rs = reports["continuous"], reports["static"]
    ok = True
    gain = rc.throughput_tok_per_step / max(rs.throughput_tok_per_step, 1e-30)
    beats = rc.throughput_tok_per_step >= rs.throughput_tok_per_step
    print(f"continuous vs static on the same trace: {gain:.2f}x throughput, "
          f"p99 latency {rc.latency_p99:.0f} vs {rs.latency_p99:.0f} steps")
    if not beats:
        print("WARNING: continuous batching did not beat static batching")
        ok = False
    complete = (rc.requests == len(trace) == rs.requests)
    same_tokens = rc.request_tokens == rs.request_tokens
    quantized = args.execute_backend or plan is not None
    strict = (not quantized) or args.act_scale == "per-row"
    note = ("" if not quantized else
            " (strict: per-row act-quant decouples co-batched rows)"
            if strict else
            " (informational: per-tensor act-quant couples co-batched rows)")
    print(f"all {len(trace)} requests completed under both schedulers: "
          f"{complete}; per-request token streams identical: "
          f"{same_tokens}{note}")
    ok = ok and complete and (same_tokens or not strict)
    if args.decode_attention == "fused":
        # replay the continuous run on the gather oracle: the fused page
        # walk may move logits by <= FUSED_LOGIT_TOL, but the sampled token
        # streams must be identical whenever the identity gate is strict
        gather_engine = ServingEngine(cfg, params, attention="gather",
                                      **engine_kw)
        with common_lib.activation_scaling(args.act_scale):
            rg = gather_engine.run(trace, "continuous")
        fused_same = rc.request_tokens == rg.request_tokens
        print(f"fused vs gather decode token streams (continuous): "
              f"identical: {fused_same}{note}")
        ok = ok and (fused_same or not strict)
    if grid is None:
        diff = paged_vs_contiguous_probe(cfg, params,
                                         page_size=args.page_size)
        tag = "bit-exact" if diff == 0.0 else f"max |diff| {diff:.3e}"
        print(f"paged decode vs contiguous decode_step (fp32): {tag}")
        ok = ok and diff == 0.0
        fdiff = fused_vs_gather_probe(cfg, params, page_size=args.page_size)
        print(f"fused page-walk vs gather oracle (fp32): max |dlogit| "
              f"{fdiff:.3e} (tol {FUSED_LOGIT_TOL:.0e})")
        ok = ok and fdiff <= FUSED_LOGIT_TOL
    return 0 if ok else 1


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("mode", nargs="?", default="serve",
                    choices=["serve", "plan", "traffic"],
                    help="'serve' generates tokens (default); 'plan' derives "
                         "+ saves a per-layer mixed-precision backend plan "
                         "for the config and reports predicted vs uniform "
                         "energy and measured per-site decode cycles; "
                         "'traffic' serves a seeded Poisson trace through "
                         "the paged continuous-batching engine and compares "
                         "continuous vs static batching")
    ap.add_argument("--arch", default="llama3-8b", choices=list(configs.ARCH_IDS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--gemm-backend", default="tubgemm",
                    choices=["ugemm", "tugemm", "tubgemm", "bgemm"],
                    help="design the pricing table highlights")
    ap.add_argument("--execute-backend", default=None, metavar="SPEC",
                    help="also EXECUTE prefill/decode with every quantized "
                         "dense layer contracted on this backend "
                         "(simulated design, *_pallas kernel mirror, or a "
                         "rate-coded spec like 'ugemm_stochastic:64' where "
                         ":L overrides the stream length); one of "
                         f"{', '.join(backends_lib.available())}")
    ap.add_argument("--backend-plan", default=None, metavar="FILE",
                    help="execute prefill/decode with every dense site "
                         "contracted on the backend its plan entry names "
                         "(a JSON file from 'serve plan' or "
                         "benchmarks.run plan)")
    ap.add_argument("--plan-out", default="reports/plan.json",
                    help="where 'serve plan' saves the derived plan")
    ap.add_argument("--stream-lens", default=None, metavar="L1,L2,...",
                    help="[plan] admit rate-coded ugemm_stochastic "
                         "candidates at these stream lengths, making "
                         "(design, bits, stream_len) the planned assignment "
                         "(e.g. --stream-lens 16,32,64,128)")
    ap.add_argument("--act-scale", default="per-tensor",
                    choices=["per-tensor", "per-row"],
                    help="[traffic] activation quantization granularity "
                         "under backend execution; per-row decouples "
                         "co-batched requests and turns the identical-"
                         "token-stream check into a strict gate")
    ap.add_argument("--bits", type=int, default=4, choices=[2, 4, 8])
    ap.add_argument("--unit-n", type=int, default=128)
    ap.add_argument("--units", type=int, default=64)
    ap.add_argument("--requests", type=int, default=12,
                    help="[traffic] number of requests in the seeded trace")
    ap.add_argument("--arrival-rate", type=float, default=1.0,
                    help="[traffic] Poisson arrivals per scheduler step")
    ap.add_argument("--seed", type=int, default=0,
                    help="[traffic] trace seed (arrivals + lengths)")
    ap.add_argument("--page-size", type=int, default=8,
                    help="[traffic] KV-cache page size in token slots")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="[traffic] KV pool size in pages (default: every "
                         "slot can hold a worst-case request, +1 trash page)")
    ap.add_argument("--max-seq-len", type=int, default=64,
                    help="[traffic] per-request position budget "
                         "(prompt + output)")
    ap.add_argument("--decode-attention", default="fused",
                    choices=["fused", "gather"],
                    help="[traffic] decode attention path: 'fused' walks "
                         "each block table page-by-page with online softmax "
                         "(O(len*KVH) KV traffic; the default), 'gather' "
                         "materializes the padded KV view (the bit-exact "
                         "oracle).  Under 'fused' the continuous run is "
                         "replayed on the gather path and the sampled "
                         "token streams must match exactly whenever the "
                         "scheduler-identity gate is strict")
    ap.add_argument("--packed", action="store_true",
                    help="freeze every planned site's weight bit-packed "
                         "(int32 words, 32/bits codes each) at its assigned "
                         "width and execute from the packed store; "
                         "bit-identical to quantize-then-execute, 4-16x "
                         "fewer weight bytes; needs --execute-backend or "
                         "--backend-plan to fix the widths")
    ap.add_argument("--grid", default=None, metavar="X,Y",
                    help="tensor-parallel PE-array grid: 'plan' derives a "
                         "per-shard heterogeneous GridPlan; execution modes "
                         "shard every dense contraction under shard_map on "
                         "an XxY device mesh (needs X*Y visible devices, "
                         "e.g. XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=N)")
    args = ap.parse_args()

    if args.packed and not (args.execute_backend or args.backend_plan):
        print("error: --packed needs --execute-backend or --backend-plan "
              "to fix each site's bit-width")
        return 2
    if args.execute_backend:
        # No argparse choices= — the spec grammar ("ugemm_stochastic:64")
        # is the registry's; let resolve() validate it once, up front.
        try:
            backends_lib.resolve(args.execute_backend, bits=args.bits)
        except (KeyError, ValueError) as exc:
            print(f"error: --execute-backend {args.execute_backend!r}: {exc}")
            return 2
    grid = backends_lib.parse_grid(args.grid) if args.grid else None
    plan = None
    if args.backend_plan and args.mode != "plan":
        # Load up front: a GridPlan implies grid execution even without
        # --grid, and the mesh below must match the plan's device needs.
        plan = backends_lib.load_plan(args.backend_plan)
        if isinstance(plan, backends_lib.GridPlan):
            if grid is not None and grid != plan.grid:
                print(f"error: --grid {grid} conflicts with the grid plan's "
                      f"own grid {plan.grid}")
                return 2
            grid = plan.grid
        elif grid is not None:
            # shard a flat plan's sites across the requested grid
            plan = backends_lib.GridPlan(units_x=grid[0], units_y=grid[1],
                                         aggregate=plan, shards=())
    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    if cfg.frontend_stub:
        print(f"note: {args.arch} uses a frontend stub; serving raw backbone tokens")
    # Planning is analytic (no grid devices needed); execution with a grid
    # runs the jitted steps on the grid mesh so the in-step shard_maps and
    # the step shardings agree on one device set.
    needs_grid_mesh = grid is not None and args.mode != "plan" \
        and (args.execute_backend or args.backend_plan
             or args.mode == "traffic")
    mesh = (make_grid_mesh(*grid) if needs_grid_mesh
            else single_device_mesh())
    with mesh:
        params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    if args.mode == "plan":
        if grid is not None:
            return run_grid_plan_mode(args, cfg, params, grid)
        return run_plan_mode(args, cfg, params)
    if args.mode == "traffic":
        return run_traffic_mode(args, cfg, params, grid, plan)
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)

    t0 = time.time()
    toks = generate(cfg, params, mesh, prompt, args.tokens)
    wall = time.time() - t0
    print(f"generated {toks.shape} tokens in {wall:.2f}s "
          f"({args.batch * args.tokens / wall:.1f} tok/s on CPU sim)")

    # --- backend numerics: batched engine vs binary oracle on real weights ---
    rel = validate_backend_numerics(params, args.gemm_backend, args.bits)
    tag = "bit-exact" if rel == 0.0 else f"relRMSE {rel:.2e}"
    print(f"backend numerics ({args.gemm_backend}, {args.bits}-bit, "
          f"batched weight tiles): {tag}")

    # --- unary-DLA energy accounting (the paper's technique, end to end) ---
    rec, stats = build_workload(cfg, params, args.batch, args.prompt_len, args.bits)
    agg = sparsity.combine_stats(list(stats.values()))
    print(f"\nweight sparsity ({args.bits}-bit): word={agg.word:.4f} "
          f"bit_elem={agg.bit_elem:.4f} bit_blockmax={agg.bit_blockmax:.4f}")
    print(f"\nper-decode-token DLA cost ({args.units}x {args.unit_n}x{args.unit_n} "
          f"units, {args.bits}-bit):")
    print(f"{'design':>9s} {'wc_energy_uJ':>13s} {'dyn_energy_uJ':>14s} "
          f"{'dyn_latency_us':>15s} {'saving':>7s}")
    costs = {design: backends_lib.resolve(design, bits=args.bits)
             .price(rec.calls, unit_n=args.unit_n, num_units=args.units)
             for design in sweetspot_lib.CALIBRATED_DESIGNS}
    for design, cost in costs.items():
        mark = " <-- selected" if design == args.gemm_backend else ""
        print(f"{design:>9s} {cost.wc_energy_uj:13.2f} {cost.dyn_energy_uj:14.2f} "
              f"{cost.dyn_latency_us:15.2f} {cost.sparsity_saving:6.1%}{mark}")

    # --- sweet-spot verdict for this model's actual layer shapes ------------
    rec_by = sweetspot_lib.recommend_backend(
        rec.calls, bits=args.bits, unit_n=args.unit_n, num_units=args.units,
        costs=costs)
    best_e = rec_by["dyn_energy_uj"]["best"]
    best_l = rec_by["dyn_latency_us"]["best"]
    print(f"\nsweet-spot ({args.bits}-bit, {args.unit_n}x{args.unit_n} units): "
          f"{best_e} minimizes energy, {best_l} minimizes latency "
          f"for this model's layer shapes")
    if args.gemm_backend not in (best_e, best_l):
        e_sel = dict(rec_by["dyn_energy_uj"]["ranking"])[args.gemm_backend]
        e_best = dict(rec_by["dyn_energy_uj"]["ranking"])[best_e]
        print(f"note: selected backend {args.gemm_backend} spends "
              f"{e_sel / e_best:.2f}x the energy of {best_e} here "
              f"(rerun with --gemm-backend {best_e})")

    # --- end-to-end execution on the chosen backend -------------------------
    if args.execute_backend:
        backend = backends_lib.resolve(args.execute_backend, bits=args.bits)
        stream_len = getattr(backend, "stream_len", None)
        if grid is not None:
            backend = backends_lib.as_grid(backend, *grid)
        gtag = (f" on a {grid[0]}x{grid[1]} grid (shard_map, psum over k)"
                if grid else "")
        ltag = f", L={stream_len} bitstreams" if stream_len else ""
        print(f"\n=== executing model on {backend.name} "
              f"({backend.bits}-bit int tiles{ltag}){gtag} ===")
        result = run_backend_execution(
            cfg, params, mesh, prompt, backend, args.tokens,
            unit_n=args.unit_n, num_units=args.units, stats=stats,
            packed=args.packed)
        qt = result["tokens"]
        print(f"generated {qt.shape} tokens in {result['wall_s']:.2f}s; "
              f"{result['sites']} dense GEMM sites contracted on the backend")
        tag = ("bit-exact" if result["rel_rmse"] == 0.0
               else f"relRMSE {result['rel_rmse']:.2e}")
        kind = "exact design" if backend.exact else "stochastic design"
        oracle = ("exact-uGEMM oracle" if result["oracle"] == "ugemm"
                  else "binary oracle")
        print(f"int GEMMs vs {oracle}: {tag} ({kind})")
        print(f"output drift vs float model (prefill logits): "
              f"relRMSE {result['drift']:.3f}, "
              f"top-1 agreement {result['top1_agreement']:.1%}")
        cyc = result["cycles"]
        in_bounds = cyc["dyn_floor"] - 0.5 <= cyc["measured"] <= cyc["wc"] + 0.5
        priced_dyn = costs[backend.pricing_design].dyn_latency_us * 1e3 \
            / ppa.CLOCK_PERIOD_NS * getattr(backend, "cycle_scale", 1.0)
        stag = (f", measured stream relRMSE {result['rel_rmse']:.2e} at "
                f"L={stream_len}" if stream_len else "")
        print(f"per-decode-token cycles ({args.units}x {args.unit_n}x"
              f"{args.unit_n} units): measured {cyc['measured']:.3e} within "
              f"[dyn floor {cyc['dyn_floor']:.3e}, wc {cyc['wc']:.3e}]: "
              f"{in_bounds} (priced Eq.1 dyn {priced_dyn:.3e}{stag})")
        if not in_bounds:
            print("WARNING: measured cycles outside the priced dyn/wc bounds")
            return 1

    # --- end-to-end execution on a per-site mixed-precision plan ------------
    if args.backend_plan:
        is_grid = isinstance(plan, backends_lib.GridPlan)
        distinct = (plan.aggregate if is_grid else plan).distinct_engines()
        gtag = (f" on a {plan.units_x}x{plan.units_y} grid" if is_grid
                else "")
        labels = ", ".join(f"{d}@{b}" + (f":{L}" if L else "")
                           for d, b, L in distinct)
        print(f"\n=== executing model on backend plan {args.backend_plan}"
              f"{gtag} ({labels}) ===")
        print(analysis_verdict(plan))
        result = run_plan_execution(cfg, params, mesh, prompt, plan,
                                    args.tokens, packed=args.packed)
        qt = result["tokens"]
        print(f"generated {qt.shape} tokens in {result['wall_s']:.2f}s; "
              f"{len(result['site_backends'])} dense GEMM sites contracted:")
        for site, tag in sorted(result["site_backends"].items()):
            print(f"  {site:>24s} -> {tag}")
        ok = True
        for tag, rel in sorted(result["rel_rmse"].items()):
            design = tag.split("@")[0]
            exact = backends_lib.resolve(design).exact
            label = "bit-exact" if rel == 0.0 else f"relRMSE {rel:.2e}"
            oracle = "exact-uGEMM oracle" if ":" in tag else "binary oracle"
            if is_grid:
                oracle = "unsharded " + oracle
            print(f"int GEMMs vs {oracle} on {tag}: {label}")
            if exact and rel != 0.0:
                ok = False
        print(f"output drift vs float model (prefill logits): "
              f"relRMSE {result['drift']:.3f}, "
              f"top-1 agreement {result['top1_agreement']:.1%}")
        total = {"measured": 0.0, "dyn": 0.0, "dyn_floor": 0.0, "wc": 0.0}

        def _check(label, cyc):
            in_bounds = (cyc["dyn_floor"] - 0.5 <= cyc["measured"]
                         <= cyc["wc"] + 0.5)
            print(f"  {label:>30s} cycles: measured {cyc['measured']:.3e} in "
                  f"[floor {cyc['dyn_floor']:.3e}, wc {cyc['wc']:.3e}]: "
                  f"{in_bounds} (planned Eq.1 dyn {cyc['dyn']:.3e})")
            return in_bounds

        for site, cyc in sorted(result["site_cycles"].items()):
            if result["grid"]:
                for coord, shard_cyc in sorted(cyc.items()):
                    ok = _check(f"{site} [{coord}]", shard_cyc) and ok
                    for key in total:
                        total[key] += shard_cyc[key]
            else:
                ok = _check(site, cyc) and ok
                for key in total:
                    total[key] += cyc[key]
        scope = "per-shard " if result["grid"] else ""
        print(f"per-decode-token {scope}cycle totals: measured "
              f"{total['measured']:.3e} within [dyn floor "
              f"{total['dyn_floor']:.3e}, wc {total['wc']:.3e}] "
              f"(planned Eq.1 dyn {total['dyn']:.3e})")
        if not ok:
            print("WARNING: plan replay violated bit-exactness or cycle "
                  "bounds")
            return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
