"""Batched serving driver: prefill + decode with unary-DLA energy accounting.

This is where the paper's technique meets the serving stack: every quantized
GEMM in the model is priced on a chosen unary/binary PE-array backend
(--gemm-backend {ugemm,tugemm,tubgemm,bgemm}, --bits {2,4,8}) using the
*measured* block-max bit sparsity of the actual weights (Eq. 1), giving
per-token energy/latency for the whole model alongside the generated tokens.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
        --gemm-backend tubgemm --bits 4 --tokens 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import accounting, sparsity
from repro.core import gemm_sims as gemm_sims_lib
from repro.core.quantization import quantize
from repro.eval import sweetspot as sweetspot_lib
from repro.launch import steps as steps_lib
from repro.launch.mesh import single_device_mesh
from repro.models import model as model_lib


def build_workload(cfg, params, batch: int, ctx_len: int, bits: int):
    """GemmCalls for ONE decode step, with measured per-matrix sparsity."""
    rec = accounting.GemmWorkloadRecorder()
    stats = {}
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    for path, leaf in flat:
        if not hasattr(leaf, "ndim") or leaf.ndim < 2:
            continue
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if "embed" in name and not cfg.tie_embeddings:
            continue
        w = np.asarray(leaf, np.float32).reshape(leaf.shape[0], -1) \
            if leaf.ndim == 2 else np.asarray(leaf, np.float32).reshape(-1, leaf.shape[-1])
        st = sparsity.profile_tensor(jnp.asarray(w), bits=bits)
        stats[name] = st
        k, n_out = w.shape
        rec.record(name, m=batch, k=k, n_out=n_out,
                   bit_sparsity=st.bit_blockmax, count=1)
    return rec, stats


def validate_backend_numerics(params, design: str, bits: int,
                              n_tiles: int = 8, tile: int = 16) -> float:
    """Spot-check the selected GEMM backend on tiles of the real weights.

    Quantizes ``n_tiles`` (tile x tile) slices of actual model weights,
    stacks them on a batch axis, and pushes the whole stack through
    ``gemm_sims.gemm_batched`` in one jit against the binary oracle.  Exact
    designs (tu/tub/b) must come back bit-identical; uGEMM reports its
    stochastic relative RMSE.  Returns the relative error.
    """
    leaves = [l for l in jax.tree_util.tree_leaves(params)
              if hasattr(l, "ndim") and l.ndim >= 2 and l.size >= 2 * tile * tile]
    if not leaves:
        return 0.0
    tiles = []
    for i in range(2 * n_tiles):
        flat = np.asarray(leaves[i % len(leaves)], np.float32).reshape(-1)
        off = (i // len(leaves)) * tile * tile
        chunk = flat[off:off + tile * tile]
        if chunk.size < tile * tile:
            chunk = flat[:tile * tile]
        q = quantize(jnp.asarray(chunk.reshape(tile, tile)), bits=bits,
                     per_channel=False)
        tiles.append(q.values.astype(jnp.int8))
    a = jnp.stack(tiles[:n_tiles])
    b = jnp.stack(tiles[n_tiles:])
    return gemm_sims_lib.rel_rmse(
        gemm_sims_lib.gemm_batched(design, a, b, bits),
        gemm_sims_lib.gemm_batched("bgemm", a, b, bits))


def generate(cfg, params, mesh, prompt, max_new: int, temperature: float = 0.0):
    """Greedy/temperature decoding with the jitted prefill/decode steps."""
    b, s = prompt.shape
    max_len = s + max_new
    prefill_step = steps_lib.make_prefill_step(cfg, mesh)
    decode_step = steps_lib.make_decode_step(cfg, mesh)
    with mesh:
        caches = model_lib.init_caches(cfg, b, max_len, dtype=jnp.float32)
        logits, caches = prefill_step(params, {"tokens": prompt}, caches)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out = [tok]
        key = jax.random.PRNGKey(0)
        for i in range(max_new - 1):
            logits, caches = decode_step(params, tok, caches,
                                         jnp.int32(s + i))
            if temperature > 0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(
                    sub, logits[:, -1] / temperature)[:, None].astype(jnp.int32)
            else:
                tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            out.append(tok)
    return jnp.concatenate(out, axis=1)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=list(configs.ARCH_IDS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--gemm-backend", default="tubgemm",
                    choices=["ugemm", "tugemm", "tubgemm", "bgemm"])
    ap.add_argument("--bits", type=int, default=4, choices=[2, 4, 8])
    ap.add_argument("--unit-n", type=int, default=128)
    ap.add_argument("--units", type=int, default=64)
    args = ap.parse_args()

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    if cfg.frontend_stub:
        print(f"note: {args.arch} uses a frontend stub; serving raw backbone tokens")
    mesh = single_device_mesh()
    with mesh:
        params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)

    t0 = time.time()
    toks = generate(cfg, params, mesh, prompt, args.tokens)
    wall = time.time() - t0
    print(f"generated {toks.shape} tokens in {wall:.2f}s "
          f"({args.batch * args.tokens / wall:.1f} tok/s on CPU sim)")

    # --- backend numerics: batched engine vs binary oracle on real weights ---
    rel = validate_backend_numerics(params, args.gemm_backend, args.bits)
    tag = "bit-exact" if rel == 0.0 else f"relRMSE {rel:.2e}"
    print(f"backend numerics ({args.gemm_backend}, {args.bits}-bit, "
          f"batched weight tiles): {tag}")

    # --- unary-DLA energy accounting (the paper's technique, end to end) ---
    rec, stats = build_workload(cfg, params, args.batch, args.prompt_len, args.bits)
    agg = sparsity.combine_stats(list(stats.values()))
    print(f"\nweight sparsity ({args.bits}-bit): word={agg.word:.4f} "
          f"bit_elem={agg.bit_elem:.4f} bit_blockmax={agg.bit_blockmax:.4f}")
    print(f"\nper-decode-token DLA cost ({args.units}x {args.unit_n}x{args.unit_n} "
          f"units, {args.bits}-bit):")
    print(f"{'design':>9s} {'wc_energy_uJ':>13s} {'dyn_energy_uJ':>14s} "
          f"{'dyn_latency_us':>15s} {'saving':>7s}")
    costs = {design: accounting.price_workload(
                 rec.calls, design=design, bits=args.bits,
                 unit_n=args.unit_n, num_units=args.units)
             for design in sweetspot_lib.CALIBRATED_DESIGNS}
    for design, cost in costs.items():
        mark = " <-- selected" if design == args.gemm_backend else ""
        print(f"{design:>9s} {cost.wc_energy_uj:13.2f} {cost.dyn_energy_uj:14.2f} "
              f"{cost.dyn_latency_us:15.2f} {cost.sparsity_saving:6.1%}{mark}")

    # --- sweet-spot verdict for this model's actual layer shapes ------------
    rec_by = sweetspot_lib.recommend_backend(
        rec.calls, bits=args.bits, unit_n=args.unit_n, num_units=args.units,
        costs=costs)
    best_e = rec_by["dyn_energy_uj"]["best"]
    best_l = rec_by["dyn_latency_us"]["best"]
    print(f"\nsweet-spot ({args.bits}-bit, {args.unit_n}x{args.unit_n} units): "
          f"{best_e} minimizes energy, {best_l} minimizes latency "
          f"for this model's layer shapes")
    if args.gemm_backend not in (best_e, best_l):
        e_sel = dict(rec_by["dyn_energy_uj"]["ranking"])[args.gemm_backend]
        e_best = dict(rec_by["dyn_energy_uj"]["ranking"])[best_e]
        print(f"note: selected backend {args.gemm_backend} spends "
              f"{e_sel / e_best:.2f}x the energy of {best_e} here "
              f"(rerun with --gemm-backend {best_e})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
