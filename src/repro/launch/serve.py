"""Batched serving driver: prefill + decode with unary-DLA energy accounting.

This is where the paper's technique meets the serving stack, in two modes:

* **pricing** (always on): every quantized GEMM in the model is priced on a
  chosen unary/binary PE-array backend (--gemm-backend, --bits) using the
  *measured* block-max bit sparsity of the actual weights (Eq. 1), giving
  per-token energy/latency for the whole model alongside the generated tokens.
* **execution** (--execute-backend): prefill and decode actually run every
  quantized dense layer through a typed ``repro.backends`` engine — int
  tiles contracted on the selected unary design (or its Pallas kernel
  mirror), dequantized back to the activation dtype — and the driver reports
  the int GEMMs' bit-exactness vs the binary oracle, the output drift vs the
  float model, and the measured cycle totals against the priced dyn/wc
  bounds.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
        --execute-backend tubgemm --bits 4 --tokens 8
"""

from __future__ import annotations

import argparse
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import backends as backends_lib
from repro import configs
from repro.core import accounting, ppa, sparsity
from repro.core import gemm_sims as gemm_sims_lib
from repro.core.quantization import quantize
from repro.eval import sweetspot as sweetspot_lib
from repro.launch import steps as steps_lib
from repro.launch.mesh import single_device_mesh
from repro.models import model as model_lib


def _iter_weight_matrices(cfg, params):
    """Yield ``(name, (k, n_out) float32 weight)`` for every priced matmul.

    The single walk both the pricing workload and the measured-cycle report
    are built from, so they see identical matrices.
    """
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    for path, leaf in flat:
        if not hasattr(leaf, "ndim") or leaf.ndim < 2:
            continue
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if "embed" in name and not cfg.tie_embeddings:
            continue
        w = np.asarray(leaf, np.float32).reshape(leaf.shape[0], -1) \
            if leaf.ndim == 2 else np.asarray(leaf, np.float32).reshape(-1, leaf.shape[-1])
        yield name, w


def build_workload(cfg, params, batch: int, ctx_len: int, bits: int):
    """GemmCalls for ONE decode step, with measured per-matrix sparsity."""
    rec = accounting.GemmWorkloadRecorder()
    stats = {}
    for name, w in _iter_weight_matrices(cfg, params):
        st = sparsity.profile_tensor(jnp.asarray(w), bits=bits)
        stats[name] = st
        k, n_out = w.shape
        rec.record(name, m=batch, k=k, n_out=n_out,
                   bit_sparsity=st.bit_blockmax, count=1)
    return rec, stats


def validate_backend_numerics(params, design, bits: int | None = None,
                              n_tiles: int = 8, tile: int = 16) -> float:
    """Spot-check the selected GEMM backend on tiles of the real weights.

    Quantizes ``n_tiles`` (tile x tile) slices of actual model weights,
    stacks them on a batch axis, and pushes the whole stack through
    ``GemmBackend.execute`` in one batched call against the binary oracle.
    ``design`` is a backend name or ``repro.backends.GemmBackend`` (``bits``
    then defaults to the backend's own width).  Exact designs (tu/tub/b and
    the Pallas mirrors) must come back bit-identical — returns 0.0 — while
    uGEMM reports its stochastic relative RMSE.
    """
    backend = backends_lib.resolve(design, bits=bits)
    oracle = backends_lib.resolve("bgemm", bits=backend.bits)
    leaves = [l for l in jax.tree_util.tree_leaves(params)
              if hasattr(l, "ndim") and l.ndim >= 2 and l.size >= 2 * tile * tile]
    if not leaves:
        return 0.0
    tiles = []
    for i in range(2 * n_tiles):
        flat = np.asarray(leaves[i % len(leaves)], np.float32).reshape(-1)
        off = (i // len(leaves)) * tile * tile
        chunk = flat[off:off + tile * tile]
        if chunk.size < tile * tile:
            chunk = flat[:tile * tile]
        q = quantize(jnp.asarray(chunk.reshape(tile, tile)), bits=backend.bits,
                     per_channel=False)
        tiles.append(q.values.astype(jnp.int8))
    a = jnp.stack(tiles[:n_tiles])
    b = jnp.stack(tiles[n_tiles:])
    return gemm_sims_lib.rel_rmse(backend.execute(a, b), oracle.execute(a, b))


def measure_decode_cycles(cfg, params, backend, *, batch: int, unit_n: int,
                          num_units: int, stats=None) -> dict[str, float]:
    """Per-decode-token cycle totals for the model on one backend.

    Four numbers per the DLA tiling ``core.ppa.DLAModel`` uses (per-tile
    cycles x ceil(tiles / num_units) waves, common dim = k):

    * ``wc`` — worst case, ``backend.cycles(k)`` per tile;
    * ``dyn_floor`` — Eq. 1 with *element-level* bit sparsity: every lane
      terminating at its own magnitude, an optimistic lower bound the shared
      slot schedule cannot beat;
    * ``measured`` — operand-driven: ``backend.dyn_cycles(operand=...)`` on
      the same **per-channel** quantized codes ``models/common.dense``
      contracts under ``use_backend`` — the cycles the early-terminating
      counters really take, with each outer-product step gated by the
      largest magnitude in flight;
    * ``dyn`` — the priced Eq. 1 estimate (worst case scaled by the
      block-max bit sparsity the cost tables use): gating at PE-block
      granularity.  Comparable to ``measured`` but not a bound on it — the
      statistic profiles a per-tensor grid while execution contracts
      per-channel codes.

    The Eq. 1 statistics follow the paper's per-tensor profiling
    (``core.sparsity.profile_tensor``); ``measured`` reflects the executed
    codes.  For sparsity-aware designs ``dyn_floor <= measured <= wc`` (wc
    caps every step); designs without early termination report all four
    equal.  The serve driver checks ``dyn_floor <= measured <= wc``.

    ``stats`` — optional ``{name: SparsityStats}`` at ``backend.bits`` (from
    ``build_workload``) to skip re-profiling every weight matrix.
    """
    dla = ppa.DLAModel(design=backend.pricing_design, bits=backend.bits,
                       n=unit_n, num_units=num_units)
    totals = {"wc": 0.0, "dyn": 0.0, "dyn_floor": 0.0, "measured": 0.0}
    for name, w in _iter_weight_matrices(cfg, params):
        k, n_out = w.shape
        # per-channel, matching models/common._backend_matmul exactly
        q = quantize(jnp.asarray(w), bits=backend.bits).values
        st = (stats or {}).get(name)
        if st is None:
            st = sparsity.profile_tensor(jnp.asarray(w), bits=backend.bits)
        waves = math.ceil(dla.tiles(batch, n_out) / num_units)
        totals["wc"] += backend.cycles(k) * waves
        totals["dyn"] += backend.dyn_cycles(k, bit_sparsity=st.bit_blockmax) * waves
        totals["dyn_floor"] += backend.dyn_cycles(k, bit_sparsity=st.bit_elem) * waves
        totals["measured"] += backend.dyn_cycles(operand=q) * waves
    return totals


def generate(cfg, params, mesh, prompt, max_new: int, temperature: float = 0.0):
    """Greedy/temperature decoding with the jitted prefill/decode steps."""
    b, s = prompt.shape
    max_len = s + max_new
    prefill_step = steps_lib.make_prefill_step(cfg, mesh)
    decode_step = steps_lib.make_decode_step(cfg, mesh)
    with mesh:
        caches = model_lib.init_caches(cfg, b, max_len, dtype=jnp.float32)
        logits, caches = prefill_step(params, {"tokens": prompt}, caches)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out = [tok]
        key = jax.random.PRNGKey(0)
        for i in range(max_new - 1):
            logits, caches = decode_step(params, tok, caches,
                                         jnp.int32(s + i))
            if temperature > 0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(
                    sub, logits[:, -1] / temperature)[:, None].astype(jnp.int32)
            else:
                tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            out.append(tok)
    return jnp.concatenate(out, axis=1)


def prefill_logits(cfg, params, mesh, prompt):
    """Full prefill logits via a freshly traced step (so an active
    ``use_backend`` scope is honored — jitted steps bind the backend at
    trace time)."""
    prefill_step = steps_lib.make_prefill_step(cfg, mesh)
    with mesh:
        caches = model_lib.init_caches(cfg, prompt.shape[0],
                                       prompt.shape[1] + 1, dtype=jnp.float32)
        logits, _ = prefill_step(params, {"tokens": prompt}, caches)
    return logits


def run_backend_execution(cfg, params, mesh, prompt, backend, max_new: int,
                          *, unit_n: int, num_units: int,
                          ref_logits=None, stats=None) -> dict:
    """Execute prefill+decode on ``backend`` and collect the evidence.

    Returns a dict: generated ``tokens``, number of distinct GEMM ``sites``
    contracted on the backend, int-GEMM ``rel_rmse`` vs the binary oracle,
    prefill-logits ``drift`` + ``top1_agreement`` vs the float model, wall
    time, and the measured/dyn/wc ``cycles`` totals per decode token.
    ``stats`` — optional pre-profiled sparsity stats at the backend's
    bit-width, forwarded to :func:`measure_decode_cycles`.
    """
    backend = backends_lib.resolve(backend)
    if ref_logits is None:
        ref_logits = prefill_logits(cfg, params, mesh, prompt)
    t0 = time.time()
    with backends_lib.use_backend(backend) as execution:
        tokens = generate(cfg, params, mesh, prompt, max_new)
        exec_logits = prefill_logits(cfg, params, mesh, prompt)
    wall = time.time() - t0
    if not execution.calls:
        raise RuntimeError(
            "backend execution recorded no GEMM sites — the model traced "
            "outside the use_backend scope?")
    ref = np.asarray(ref_logits, np.float32)
    got = np.asarray(exec_logits, np.float32)
    agree = float(np.mean(np.argmax(got, -1) == np.argmax(ref, -1)))
    return {
        "backend": backend,
        "tokens": tokens,
        "sites": len(execution.calls),
        "wall_s": wall,
        "rel_rmse": validate_backend_numerics(params, backend),
        "drift": gemm_sims_lib.rel_rmse(got, ref),
        "top1_agreement": agree,
        "cycles": measure_decode_cycles(cfg, params, backend,
                                        batch=prompt.shape[0], unit_n=unit_n,
                                        num_units=num_units, stats=stats),
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=list(configs.ARCH_IDS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--gemm-backend", default="tubgemm",
                    choices=["ugemm", "tugemm", "tubgemm", "bgemm"],
                    help="design the pricing table highlights")
    ap.add_argument("--execute-backend", default=None,
                    choices=list(backends_lib.available()),
                    help="also EXECUTE prefill/decode with every quantized "
                         "dense layer contracted on this backend "
                         "(simulated design or *_pallas kernel mirror)")
    ap.add_argument("--bits", type=int, default=4, choices=[2, 4, 8])
    ap.add_argument("--unit-n", type=int, default=128)
    ap.add_argument("--units", type=int, default=64)
    args = ap.parse_args()

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    if cfg.frontend_stub:
        print(f"note: {args.arch} uses a frontend stub; serving raw backbone tokens")
    mesh = single_device_mesh()
    with mesh:
        params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)

    t0 = time.time()
    toks = generate(cfg, params, mesh, prompt, args.tokens)
    wall = time.time() - t0
    print(f"generated {toks.shape} tokens in {wall:.2f}s "
          f"({args.batch * args.tokens / wall:.1f} tok/s on CPU sim)")

    # --- backend numerics: batched engine vs binary oracle on real weights ---
    rel = validate_backend_numerics(params, args.gemm_backend, args.bits)
    tag = "bit-exact" if rel == 0.0 else f"relRMSE {rel:.2e}"
    print(f"backend numerics ({args.gemm_backend}, {args.bits}-bit, "
          f"batched weight tiles): {tag}")

    # --- unary-DLA energy accounting (the paper's technique, end to end) ---
    rec, stats = build_workload(cfg, params, args.batch, args.prompt_len, args.bits)
    agg = sparsity.combine_stats(list(stats.values()))
    print(f"\nweight sparsity ({args.bits}-bit): word={agg.word:.4f} "
          f"bit_elem={agg.bit_elem:.4f} bit_blockmax={agg.bit_blockmax:.4f}")
    print(f"\nper-decode-token DLA cost ({args.units}x {args.unit_n}x{args.unit_n} "
          f"units, {args.bits}-bit):")
    print(f"{'design':>9s} {'wc_energy_uJ':>13s} {'dyn_energy_uJ':>14s} "
          f"{'dyn_latency_us':>15s} {'saving':>7s}")
    costs = {design: backends_lib.resolve(design, bits=args.bits)
             .price(rec.calls, unit_n=args.unit_n, num_units=args.units)
             for design in sweetspot_lib.CALIBRATED_DESIGNS}
    for design, cost in costs.items():
        mark = " <-- selected" if design == args.gemm_backend else ""
        print(f"{design:>9s} {cost.wc_energy_uj:13.2f} {cost.dyn_energy_uj:14.2f} "
              f"{cost.dyn_latency_us:15.2f} {cost.sparsity_saving:6.1%}{mark}")

    # --- sweet-spot verdict for this model's actual layer shapes ------------
    rec_by = sweetspot_lib.recommend_backend(
        rec.calls, bits=args.bits, unit_n=args.unit_n, num_units=args.units,
        costs=costs)
    best_e = rec_by["dyn_energy_uj"]["best"]
    best_l = rec_by["dyn_latency_us"]["best"]
    print(f"\nsweet-spot ({args.bits}-bit, {args.unit_n}x{args.unit_n} units): "
          f"{best_e} minimizes energy, {best_l} minimizes latency "
          f"for this model's layer shapes")
    if args.gemm_backend not in (best_e, best_l):
        e_sel = dict(rec_by["dyn_energy_uj"]["ranking"])[args.gemm_backend]
        e_best = dict(rec_by["dyn_energy_uj"]["ranking"])[best_e]
        print(f"note: selected backend {args.gemm_backend} spends "
              f"{e_sel / e_best:.2f}x the energy of {best_e} here "
              f"(rerun with --gemm-backend {best_e})")

    # --- end-to-end execution on the chosen backend -------------------------
    if args.execute_backend:
        backend = backends_lib.resolve(args.execute_backend, bits=args.bits)
        print(f"\n=== executing model on {backend.name} "
              f"({backend.bits}-bit int tiles) ===")
        result = run_backend_execution(
            cfg, params, mesh, prompt, backend, args.tokens,
            unit_n=args.unit_n, num_units=args.units, stats=stats)
        qt = result["tokens"]
        print(f"generated {qt.shape} tokens in {result['wall_s']:.2f}s; "
              f"{result['sites']} dense GEMM sites contracted on the backend")
        tag = ("bit-exact" if result["rel_rmse"] == 0.0
               else f"relRMSE {result['rel_rmse']:.2e}")
        kind = "exact design" if backend.exact else "stochastic design"
        print(f"int GEMMs vs binary oracle: {tag} ({kind})")
        print(f"output drift vs float model (prefill logits): "
              f"relRMSE {result['drift']:.3f}, "
              f"top-1 agreement {result['top1_agreement']:.1%}")
        cyc = result["cycles"]
        in_bounds = cyc["dyn_floor"] - 0.5 <= cyc["measured"] <= cyc["wc"] + 0.5
        priced_dyn = costs[backend.pricing_design].dyn_latency_us * 1e3 \
            / ppa.CLOCK_PERIOD_NS
        print(f"per-decode-token cycles ({args.units}x {args.unit_n}x"
              f"{args.unit_n} units): measured {cyc['measured']:.3e} within "
              f"[dyn floor {cyc['dyn_floor']:.3e}, wc {cyc['wc']:.3e}]: "
              f"{in_bounds} (priced Eq.1 dyn {priced_dyn:.3e})")
        if not in_bounds:
            print("WARNING: measured cycles outside the priced dyn/wc bounds")
            return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
