"""End-to-end training driver with fault tolerance.

Runs at any scale: the examples train a ~few-M-param smoke config on this
CPU container; on TPU the same loop drives the production mesh.  Features:
auto-resume from the latest COMPLETE checkpoint, keep-k async checkpointing,
straggler watchdog, per-step retry, and optional int8 gradient compression.

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/run1
"""

from __future__ import annotations

import argparse
import dataclasses
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, make_pipeline
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_mesh, make_production_mesh, single_device_mesh
from repro.optim import AdamWConfig, cosine_schedule
from repro.runtime import StepTimer, StragglerWatchdog, retry_with_backoff

log = logging.getLogger("repro.train")


@dataclasses.dataclass
class TrainLoopConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: str | None = None
    keep: int = 3
    seed: int = 0
    batch: int = 8
    seq: int = 128
    lr: float = 3e-4
    warmup: int = 20
    compress_grads: bool = False
    inject_failures: float = 0.0    # probability of a synthetic step failure


def train(cfg, mesh, loop: TrainLoopConfig):
    opt_cfg = AdamWConfig(lr=loop.lr, compress_grads=loop.compress_grads)
    sched = cosine_schedule(loop.lr, loop.warmup, loop.steps)
    step_fn = steps_lib.make_train_step(cfg, mesh, opt_cfg, sched)

    data_cfg = DataConfig(batch_size=loop.batch, seq_len=loop.seq + 1,
                          vocab_size=cfg.vocab_size, seed=loop.seed,
                          embed_dim=cfg.d_model if cfg.frontend_stub else None)
    data = make_pipeline(data_cfg)

    mgr = CheckpointManager(loop.ckpt_dir, keep=loop.keep) if loop.ckpt_dir else None
    with mesh:
        state = steps_lib.init_train_state(cfg, opt_cfg,
                                           jax.random.PRNGKey(loop.seed))
        start = 0
        if mgr is not None and mgr.has_checkpoint():
            st_specs = steps_lib.named(mesh, steps_lib.train_state_pspecs(cfg, mesh))
            state, start, extras = mgr.restore_latest(state, shardings=st_specs)
            log.info("auto-resumed from step %d", start)

        watchdog = StragglerWatchdog()
        rng = np.random.default_rng(loop.seed + 1)
        history = []
        for i in range(start, loop.steps):
            batch_np = next(data)
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()
                     if k in ("tokens", "targets", "embeds")}
            if cfg.frontend_stub:
                batch.pop("tokens", None)

            def do_step():
                if loop.inject_failures and rng.random() < loop.inject_failures:
                    raise RuntimeError("synthetic node failure (injected)")
                return step_fn(state, batch)

            with StepTimer(watchdog):
                state, metrics = retry_with_backoff(do_step, retries=3,
                                                    base_delay=0.01)
            if (i + 1) % loop.log_every == 0 or i == start:
                m = {k: float(v) for k, v in metrics.items()}
                history.append((i + 1, m))
                log.info("step %d loss=%.4f nll=%.4f gnorm=%.2f lr=%.2e",
                         i + 1, m["loss"], m["nll"], m["grad_norm"], m["lr"])
            if mgr is not None and (i + 1) % loop.ckpt_every == 0:
                mgr.save(i + 1, state, extras={"loss": float(metrics["loss"])})
        if mgr is not None:
            mgr.save(loop.steps, state)
            mgr.wait()
    return state, history, watchdog


def main() -> int:
    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=list(configs.ARCH_IDS))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--inject-failures", type=float, default=0.0)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "pod", "multipod"])
    args = ap.parse_args()

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    if args.mesh == "single":
        mesh = single_device_mesh()
    elif args.mesh == "pod":
        mesh = make_production_mesh()
    else:
        mesh = make_production_mesh(multi_pod=True)

    loop = TrainLoopConfig(steps=args.steps, batch=args.batch, seq=args.seq,
                           lr=args.lr, ckpt_dir=args.ckpt_dir,
                           ckpt_every=args.ckpt_every,
                           compress_grads=args.compress_grads,
                           inject_failures=args.inject_failures)
    t0 = time.time()
    state, history, watchdog = train(cfg, mesh, loop)
    if history:
        first, last = history[0][1]["loss"], history[-1][1]["loss"]
        print(f"trained {args.arch} ({'smoke' if args.smoke else 'full'}): "
              f"loss {first:.4f} -> {last:.4f} in {time.time()-t0:.1f}s "
              f"({watchdog.slow_steps} straggler steps)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
