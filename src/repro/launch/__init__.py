"""Launch layer: meshes, distributed steps, dry-run, train/serve drivers.

NOTE: ``repro.launch.dryrun`` sets XLA_FLAGS at import — import it only in a
dedicated dry-run process, never from tests or benchmarks.
"""

from repro.launch import hlo_stats, mesh, steps

__all__ = ["hlo_stats", "mesh", "steps"]
