"""Jitted distributed steps: train_step, prefill, decode (serve_step).

Builds in/out shardings from the model's logical-axis ParamDefs, donates
state buffers, and exposes ``input_specs`` — ShapeDtypeStruct stand-ins for
every (arch x shape) dry-run cell (weak-type-correct, shardable, no device
allocation).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES
from repro.models import model as model_lib
from repro.models.common import (logical_to_pspec, rule_overrides, rules_for,
                                 shardable_batch_axes)
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, OptState, adamw_init, adamw_update

__all__ = [
    "TrainState", "input_specs", "batch_pspecs",
    "make_train_step", "make_prefill_step", "make_decode_step",
    "train_state_pspecs", "init_train_state", "named", "cache_input_specs",
]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TrainState:
    params: dict
    opt: OptState
    step: jax.Array

    def tree_flatten(self):
        return (self.params, self.opt, self.step), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


# ---------------------------------------------------------------------------
# Sharding trees
# ---------------------------------------------------------------------------

def named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def adapt_param_pspecs(p_specs, params):
    """Re-rank spec leaves whose parameter is a bit-packed store.

    ``model_lib.param_pspecs`` specs the *float* leaf shapes; a
    ``PackedQuantized`` leaf flattens to (words, scales) children of
    different ranks, so the float spec cannot broadcast onto it.  Packed
    stores replicate (weight bytes are 4-16x smaller — replication is the
    point); every other position keeps its spec.
    """
    from repro.core import packing

    def one(spec, leaf):
        if packing.is_packed(leaf):
            return jax.tree_util.tree_map(lambda _: P(), leaf)
        return spec

    return jax.tree_util.tree_map(one, p_specs, params,
                                  is_leaf=lambda x: isinstance(x, P))


def train_state_pspecs(cfg: ModelConfig, mesh):
    pspec = model_lib.param_pspecs(cfg, mesh)
    return TrainState(
        params=pspec,
        opt=OptState(step=P(), m=pspec, v=pspec, ef=None),
        step=P())


def batch_pspecs(cfg: ModelConfig, mesh, with_embeds: bool | None = None,
                 batch_size: int | None = None):
    rules = rules_for(cfg)
    if batch_size is not None:
        rules["batch"] = shardable_batch_axes(mesh, batch_size,
                                              candidates=rules["batch"])
    axes = tuple(mesh.axis_names)
    bspec = logical_to_pspec(("batch", "seq"), rules, axes)
    out = {"tokens": bspec, "targets": bspec}
    stub = cfg.frontend_stub if with_embeds is None else with_embeds
    if stub:
        out["embeds"] = logical_to_pspec(("batch", "seq", None), rules, axes)
        del out["tokens"]
    return out


def _batch_rules(cfg: ModelConfig, mesh, batch_size: int | None):
    """Effective rules + the override kwargs for in-model shard() calls.

    The overrides carry every rule that differs from DEFAULT_RULES (fsdp /
    dp_over_model archs) plus the batch axes adjusted for divisibility, so
    in-model ``shard()`` constraints agree with the jit in/out shardings.
    """
    from repro.models.common import DEFAULT_RULES
    rules = rules_for(cfg)
    if batch_size is not None:
        rules["batch"] = shardable_batch_axes(mesh, batch_size,
                                              candidates=rules["batch"])
    overrides = {k: v for k, v in rules.items() if DEFAULT_RULES.get(k) != v}
    return rules, overrides


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins for the dry-run)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """Abstract inputs for one dry-run cell.

    train   : {"tokens"|"embeds", "targets"}
    prefill : {"tokens"|"embeds"} (+ caches built via cache_input_specs)
    decode  : {"tokens" (B,1), "cache_pos" scalar} (+ caches)
    """
    sh = SHAPES[shape_name]
    b, s = sh["global_batch"], sh["seq_len"]
    step = sh["step"]
    i32 = jnp.int32
    if step == "train":
        if cfg.frontend_stub:
            return {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16),
                    "targets": jax.ShapeDtypeStruct((b, s), i32)}
        return {"tokens": jax.ShapeDtypeStruct((b, s), i32),
                "targets": jax.ShapeDtypeStruct((b, s), i32)}
    if step == "prefill":
        if cfg.frontend_stub:
            return {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)}
        return {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
    # decode: one new token against a seq_len cache
    return {"tokens": jax.ShapeDtypeStruct((b, 1), i32),
            "cache_pos": jax.ShapeDtypeStruct((), i32)}


def cache_input_specs(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """ShapeDtypeStruct tree for the caches (no allocation)."""
    shaped = jax.eval_shape(
        lambda: model_lib.init_caches(cfg, batch, max_len, dtype=jnp.bfloat16))
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), shaped)


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------

def init_train_state(cfg: ModelConfig, opt_cfg: AdamWConfig, key) -> TrainState:
    params = model_lib.init_params(cfg, key)
    return TrainState(params=params, opt=adamw_init(params, opt_cfg),
                      step=jnp.zeros((), jnp.int32))


def make_train_step(cfg: ModelConfig, mesh, opt_cfg: AdamWConfig,
                    lr_schedule=None, donate: bool = True,
                    batch_size: int | None = None):
    """Returns jitted (state, batch) -> (state, metrics)."""
    if lr_schedule is None:
        lr_schedule = lambda step: jnp.float32(opt_cfg.lr)
    _, overrides = _batch_rules(cfg, mesh, batch_size)

    def step_fn(state: TrainState, batch: dict):
        with rule_overrides(**overrides):
            def loss_of(params):
                return model_lib.loss_fn(
                    params, cfg, batch.get("tokens"), batch["targets"],
                    embeds=batch.get("embeds"))

            (loss, parts), grads = jax.value_and_grad(
                loss_of, has_aux=True)(state.params)
            lr = lr_schedule(state.step)
            new_params, new_opt, om = adamw_update(grads, state.opt,
                                                   state.params, opt_cfg, lr)
            metrics = {"loss": loss, "nll": parts["nll"], "aux": parts["aux"],
                       "lr": lr, **om}
            return TrainState(params=new_params, opt=new_opt,
                              step=state.step + 1), metrics

    st_specs = train_state_pspecs(cfg, mesh)
    if opt_cfg.compress_grads:
        st_specs = TrainState(params=st_specs.params,
                              opt=OptState(step=P(), m=st_specs.params,
                                           v=st_specs.params, ef=st_specs.params),
                              step=P())
    b_specs = batch_pspecs(cfg, mesh, batch_size=batch_size)
    return jax.jit(
        step_fn,
        in_shardings=(named(mesh, st_specs), named(mesh, b_specs)),
        out_shardings=(named(mesh, st_specs), None),
        donate_argnums=(0,) if donate else ())


def make_prefill_step(cfg: ModelConfig, mesh, batch_size: int | None = None,
                      max_len: int | None = None, params_like=None):
    """(params, inputs, caches) -> (logits, caches).

    ``params_like`` — the actual parameter tree when it may hold bit-packed
    stores (their spec leaves re-rank, see :func:`adapt_param_pspecs`).
    """
    rules, overrides = _batch_rules(cfg, mesh, batch_size)

    def step_fn(params, inputs, caches):
        with rule_overrides(**overrides):
            return model_lib.prefill(params, cfg, inputs.get("tokens"),
                                     caches=caches, embeds=inputs.get("embeds"))

    p_specs = model_lib.param_pspecs(cfg, mesh, phase="inference")
    if params_like is not None:
        p_specs = adapt_param_pspecs(p_specs, params_like)
    c_specs = model_lib.cache_pspecs(cfg, mesh, batch=batch_size or 0,
                                     max_len=max_len or 0)
    in_specs = batch_pspecs(cfg, mesh, batch_size=batch_size)
    in_specs.pop("targets", None)
    axes = tuple(mesh.axis_names)
    logits_spec = logical_to_pspec(("batch", "seq", "vocab"), rules, axes)
    return jax.jit(
        step_fn,
        in_shardings=(named(mesh, p_specs), named(mesh, in_specs),
                      named(mesh, c_specs)),
        out_shardings=(named(mesh, logits_spec), named(mesh, c_specs)),
        donate_argnums=(2,))


def make_decode_step(cfg: ModelConfig, mesh, batch_size: int | None = None,
                     max_len: int | None = None, params_like=None):
    """(params, tokens (B,1), caches, cache_pos) -> (logits, caches)."""
    rules, overrides = _batch_rules(cfg, mesh, batch_size)

    def step_fn(params, tokens, caches, cache_pos):
        with rule_overrides(**overrides):
            return model_lib.decode_step(params, cfg, tokens, caches=caches,
                                         cache_pos=cache_pos)

    p_specs = model_lib.param_pspecs(cfg, mesh, phase="inference")
    if params_like is not None:
        p_specs = adapt_param_pspecs(p_specs, params_like)
    c_specs = model_lib.cache_pspecs(cfg, mesh, batch=batch_size or 0,
                                     max_len=max_len or 0)
    axes = tuple(mesh.axis_names)
    tok_spec = logical_to_pspec(("batch", None), rules, axes)
    logits_spec = logical_to_pspec(("batch", None, "vocab"), rules, axes)
    return jax.jit(
        step_fn,
        in_shardings=(named(mesh, p_specs), named(mesh, tok_spec),
                      named(mesh, c_specs), None),
        out_shardings=(named(mesh, logits_spec), named(mesh, c_specs)),
        donate_argnums=(2,))
