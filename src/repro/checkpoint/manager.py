"""Fault-tolerant checkpointing: atomic sharded save/restore, keep-last-k,
async writer, auto-resume, and cross-mesh (elastic) resharding.

Layout (mesh-agnostic — every leaf is saved as its *global* array, so a
checkpoint written on a 256-chip mesh restores onto 512 chips or 1 CPU):

    <dir>/step_000042/
        manifest.json      # {key_path: {file, shape, dtype}}, step, extras
        <leaf>.npy         # one file per pytree leaf
        COMPLETE           # written last; restore ignores dirs without it

Atomicity: written into ``step_X.tmp`` then ``os.rename``d (POSIX-atomic), so
a crash mid-save can never corrupt the latest checkpoint — the standard
checkpoint/restart contract for node failures.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "CheckpointManager"]

_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)
    leaves = []
    for path, leaf in flat[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        leaves.append((key, leaf))
    return leaves, flat[1]


def save(ckpt_dir: str, step: int, tree: Any, extras: dict | None = None) -> str:
    """Blocking atomic save.  Returns the final directory path."""
    final = os.path.join(ckpt_dir, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves, _ = _flatten(tree)
    manifest = {"step": step, "extras": extras or {}, "leaves": {}}
    for i, (key, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][key] = {
            "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, "COMPLETE"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    """Newest step with a COMPLETE marker (ignores partial/corrupt saves)."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        m = _STEP_RE.match(name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "COMPLETE")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore(ckpt_dir: str, target: Any, step: int | None = None,
            shardings: Any = None) -> tuple[Any, int, dict]:
    """Restore into the structure of ``target``.

    ``shardings``: optional matching pytree of NamedShardings — this is the
    *elastic* path: global arrays are re-laid-out onto whatever mesh the
    restored job runs on (different chip count than the writer is fine).
    Returns (tree, step, extras).
    """
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no complete checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:09d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = _flatten(target)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(leaves))
    out = []
    for (key, tgt), shd in zip(leaves, shard_leaves):
        ent = manifest["leaves"].get(key)
        if ent is None:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = np.load(os.path.join(d, ent["file"]))
        if tuple(arr.shape) != tuple(tgt.shape):
            raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} "
                             f"vs target {tgt.shape}")
        arr = arr.astype(tgt.dtype)
        out.append(jax.device_put(arr, shd) if shd is not None else arr)
    tree = jax.tree_util.tree_unflatten(treedef, out)
    return tree, step, manifest.get("extras", {})


class CheckpointManager:
    """keep-last-k retention + optional async (background-thread) saves."""

    def __init__(self, ckpt_dir: str, keep: int = 3, async_save: bool = True):
        self.dir = ckpt_dir
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(ckpt_dir, exist_ok=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree: Any, extras: dict | None = None):
        # Materialize on host *before* returning so the training loop can
        # donate/overwrite device buffers safely.
        host_tree = jax.tree_util.tree_map(lambda x: np.asarray(jax.device_get(x)),
                                           tree)

        def work():
            save(self.dir, step, host_tree, extras)
            self._gc()

        self.wait()
        if self.async_save:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()

    def restore_latest(self, target: Any, shardings: Any = None):
        self.wait()
        return restore(self.dir, target, shardings=shardings)

    def has_checkpoint(self) -> bool:
        return latest_step(self.dir) is not None

    def _gc(self):
        steps = sorted(
            int(m.group(1)) for m in
            (_STEP_RE.match(n) for n in os.listdir(self.dir)) if m)
        for s in steps[:-self.keep] if self.keep > 0 else []:
            p = os.path.join(self.dir, f"step_{s:09d}")
            if os.path.exists(os.path.join(p, "COMPLETE")):
                shutil.rmtree(p, ignore_errors=True)
