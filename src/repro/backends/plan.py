"""The :class:`BackendPlan`: a frozen per-site mixed-precision backend map.

A plan is the executable form of the paper's sweet-spot argument — not one
winning design but a *mapping* from GEMM sites to the (design, bit-width)
that wins there, driven by each site's measured weight bit sparsity (Eq. 1)
and guarded by its quantization error.  Plans are produced by
``repro.eval.planner.build_plan`` and executed by
``repro.backends.use_plan`` (which threads them into
``models/common.dense``); they serialize to a stable JSON format
(``schema: repro.backends.plan/v1``, documented in docs/PLANNER.md).

**Site-pattern matching rules** (``BackendPlan.assignment_for``):

1. Candidate entries are those whose ``pattern`` matches the site name with
   ``fnmatch`` semantics (``*`` matches any run of characters *including*
   ``/``; ``?`` one character; ``[seq]`` character sets).  Matching is
   case-sensitive.
2. Exact patterns (no wildcard characters) beat every glob.
3. Among globs, the pattern with the most literal (non-wildcard) characters
   wins — "most specific wins".
4. Remaining ties go to the earliest entry in the plan.
5. No match → no backend: ``use_plan`` leaves that site on the float path.

A plan's entries are value objects: loading a saved plan and re-saving it is
byte-stable, and two plans with equal entries compare equal.
"""

from __future__ import annotations

import dataclasses
import fnmatch
import json
import os
from typing import Mapping

from repro.backends.base import GemmBackend

__all__ = ["SCHEMA", "SiteAssignment", "BackendPlan"]

SCHEMA = "repro.backends.plan/v1"

_WILDCARDS = set("*?[")


def _specificity(pattern: str) -> tuple[int, int]:
    """(exactness, literal-char count) — the match-precedence key."""
    exact = 1 if not (_WILDCARDS & set(pattern)) else 0
    literal = sum(1 for ch in pattern if ch not in "*?[]!")
    return (exact, literal)


@dataclasses.dataclass(frozen=True)
class SiteAssignment:
    """One plan entry: sites matching ``pattern`` run on ``design@bits``.

    Only ``pattern`` / ``design`` / ``bits`` are required (hand-written
    plans).  Planner-built entries also carry the evidence behind the
    choice, all for ONE decode step across the pattern's ``count``
    invocations:

    ``m``/``k``/``n_out``/``count`` — the contraction shape and how many
    identical GEMMs per step (scanned layers);
    ``word``/``bit_elem``/``bit_blockmax`` — measured weight sparsity at
    ``bits`` (``core.sparsity``; ``bit_blockmax`` is the Eq. 1 input);
    ``dyn_energy_uj``/``dyn_latency_us``/``wc_energy_uj``/``wc_latency_us``
    — predicted DLA cost (µJ / µs, Eq. 1-scaled dyn vs worst case);
    ``rel_mse`` — the accuracy guard's statistic: per-output-channel
    quantization MSE of the site's weight at ``bits``, relative to the
    weight's mean square (dimensionless; 0 = lossless; for stochastic
    entries it also folds in the measured stream-error variance);
    ``guard_relaxed`` — True when every candidate bit-width violated the
    guard and the planner fell back to the most accurate one;
    ``stream_len`` — rate-coded stream length for ``ugemm_stochastic``
    entries (0 = not a stream-coded entry, the count-exact default — old
    serialized plans load unchanged).
    """

    pattern: str
    design: str
    bits: int
    m: int = 0
    k: int = 0
    n_out: int = 0
    count: int = 1
    word: float = 0.0
    bit_elem: float = 0.0
    bit_blockmax: float = 0.0
    dyn_energy_uj: float = 0.0
    dyn_latency_us: float = 0.0
    wc_energy_uj: float = 0.0
    wc_latency_us: float = 0.0
    rel_mse: float = 0.0
    guard_relaxed: bool = False
    stream_len: int = 0

    def backend(self) -> GemmBackend:
        """Resolve the entry's engine as a typed ``GemmBackend``."""
        from repro.backends.registry import resolve  # lazy: avoids an
        # import cycle through repro.configs (see runtime.py's note)
        return resolve(self.design, bits=self.bits,
                       stream_len=self.stream_len or None)

    @property
    def engine_label(self) -> str:
        """``design@bits`` plus a ``:L`` stream suffix for stochastic
        entries — the display/matching tag of the *engine*, not just the
        design."""
        base = f"{self.design}@{self.bits}"
        return f"{base}:{self.stream_len}" if self.stream_len else base

    def matches(self, site: str) -> bool:
        return fnmatch.fnmatchcase(site, self.pattern)


@dataclasses.dataclass(frozen=True)
class BackendPlan:
    """An ordered, immutable set of :class:`SiteAssignment` entries.

    ``meta`` — free-form provenance (arch, DLA geometry, objective, guard
    threshold, predicted totals…) serialized verbatim; stored as a sorted
    tuple of ``(key, json-value)`` pairs so the dataclass stays frozen and
    comparable.  Use :meth:`metadata` for a dict view.
    """

    sites: tuple[SiteAssignment, ...]
    meta: tuple[tuple[str, object], ...] = ()

    def __post_init__(self) -> None:
        if not isinstance(self.sites, tuple):
            object.__setattr__(self, "sites", tuple(self.sites))
        if not isinstance(self.meta, tuple):
            object.__setattr__(self, "meta",
                               tuple(sorted(dict(self.meta).items())))

    # -- matching -----------------------------------------------------------

    def assignment_for(self, site: str) -> SiteAssignment | None:
        """Most specific matching entry for ``site`` (None = unplanned).

        Precedence per the module docstring: exact > most literal glob >
        earliest entry.
        """
        best: SiteAssignment | None = None
        best_key: tuple[int, int, int] | None = None
        for i, entry in enumerate(self.sites):
            if not entry.matches(site):
                continue
            key = (*_specificity(entry.pattern), -i)
            if best_key is None or key > best_key:
                best, best_key = entry, key
        return best

    def backend_for(self, site: str) -> GemmBackend | None:
        """Resolved backend for ``site``, or None (float path)."""
        entry = self.assignment_for(site)
        return None if entry is None else entry.backend()

    def distinct_backends(self) -> tuple[tuple[str, int], ...]:
        """Sorted unique (design, bits) pairs the plan assigns."""
        return tuple(sorted({(s.design, s.bits) for s in self.sites}))

    def distinct_engines(self) -> tuple[tuple[str, int, int], ...]:
        """Sorted unique (design, bits, stream_len) triples — the full
        engine identity (two stochastic entries with different stream
        lengths are different engines; stream_len is 0 for count-exact
        designs)."""
        return tuple(sorted({(s.design, s.bits, s.stream_len)
                             for s in self.sites}))

    def metadata(self) -> dict:
        return dict(self.meta)

    # -- (de)serialization --------------------------------------------------

    def to_json(self, indent: int = 2) -> str:
        """Stable JSON rendering (``schema: repro.backends.plan/v1``)."""
        doc = {
            "schema": SCHEMA,
            "meta": dict(self.meta),
            "sites": [dataclasses.asdict(s) for s in self.sites],
        }
        return json.dumps(doc, indent=indent)

    @classmethod
    def from_json(cls, text: str) -> "BackendPlan":
        """Parse :meth:`to_json` output; validates schema and entry fields."""
        doc = json.loads(text)
        if doc.get("schema") != SCHEMA:
            raise ValueError(
                f"not a backend plan: schema {doc.get('schema')!r} "
                f"(expected {SCHEMA!r})")
        fields = {f.name for f in dataclasses.fields(SiteAssignment)}
        sites = []
        for raw in doc.get("sites", []):
            unknown = set(raw) - fields
            if unknown:
                raise ValueError(f"unknown site fields {sorted(unknown)} "
                                 f"in entry {raw.get('pattern')!r}")
            for req in ("pattern", "design", "bits"):
                if req not in raw:
                    raise ValueError(f"site entry missing {req!r}: {raw}")
            sites.append(SiteAssignment(**raw))
        meta = doc.get("meta", {})
        if not isinstance(meta, Mapping):
            raise ValueError("plan meta must be a JSON object")
        return cls(sites=tuple(sites),
                   meta=tuple(sorted(meta.items())))

    def save(self, path: str | os.PathLike) -> str:
        """Write :meth:`to_json` to ``path`` (dirs created); returns path."""
        path = os.fspath(path)
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as fh:
            fh.write(self.to_json())
            fh.write("\n")
        return path

    @classmethod
    def load(cls, path: str | os.PathLike) -> "BackendPlan":
        """Read a plan saved by :meth:`save`."""
        with open(os.fspath(path)) as fh:
            return cls.from_json(fh.read())
