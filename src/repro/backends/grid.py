"""Sharded PE-array grids as first-class backends.

The paper prices *single* GEMM units; an edge/cloud DLA deploys a **grid** of
them fed by a partitioned model.  This module composes any resolved
:class:`~repro.backends.base.GemmBackend` into a ``units_x`` × ``units_y``
tensor-parallel grid that is simultaneously

* **executable** — :meth:`GridBackend.execute` runs the contraction under
  ``repro.compat.shard_map`` on a real ``launch/mesh`` device mesh: the
  contraction dim K is split over the ``gx`` axis (per-chip partial sums
  reduced with ``lax.psum``), the output columns over ``gy``.  Partial sums
  are exact (int32 for the exact designs; uGEMM's float counts are exact
  integers below the validated ``L·K < 2^24`` envelope), so a grid of exact
  units is **bit-identical** to the single-unit backend;
* **priceable** — :meth:`GridBackend.cycles` / :meth:`~GridBackend.dyn_cycles`
  account per-shard tile counts plus the interconnect-hop term, and
  :meth:`~repro.backends.base.GemmBackend.price` routes through
  ``core.accounting.price_workload``'s grid branch
  (``ppa.GridDLAModel``), returning a ``GridCost`` with per-unit utilization
  and link energy;
* **plannable** — :class:`GridPlan` holds one
  :class:`~repro.backends.plan.BackendPlan` per shard (each shard's weight
  slice has its own sparsity profile, so assignments may differ across
  shards) plus the *aggregate* plan execution replays.

**Shard-local site names.**  A grid plan addresses a single shard's
assignment with the shard-qualified name ``"{gx},{gy}/{site}"`` (see
:func:`shard_site`); :meth:`GridPlan.backend_for` resolves those to the
shard's own (unwrapped) backend, while plain site names resolve to the
aggregate entry wrapped in a :class:`GridBackend`.  SPMD execution traces
``models/common.dense`` once for all shards, so the executed lookup resolves
identically on every shard by construction — per-shard heterogeneity lives
in the pricing verdict, not the traced program (all candidate designs are
exact, so the aggregate execution's bit-exactness evidence transfers).
"""

from __future__ import annotations

import dataclasses
import functools
import json
import os
import re
from typing import Iterator

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.backends.base import GemmBackend
from repro.backends.plan import SCHEMA as PLAN_SCHEMA
from repro.backends.plan import BackendPlan
from repro.core import ppa

__all__ = ["GRID_SCHEMA", "GridBackend", "GridPlan", "as_grid", "parse_grid",
           "grid_mesh", "shard_site", "shard_slices", "grid_matrix_cycles",
           "load_plan"]

GRID_SCHEMA = "repro.backends.gridplan/v1"

#: the "{gx},{gy}" prefix of a shard-local site name (see :func:`shard_site`)
_SHARD_KEY_RE = re.compile(r"\d+,\d+")


def parse_grid(grid) -> tuple[int, int]:
    """Normalize a grid spec to ``(units_x, units_y)``.

    Accepts a 2-tuple/list, or a string ``"2,2"`` / ``"2x2"`` (the
    ``serve --grid`` CLI syntax).  Both entries must be >= 1.
    """
    if isinstance(grid, str):
        sep = "," if "," in grid else "x"
        parts = grid.split(sep)
        if len(parts) != 2:
            raise ValueError(f"grid spec {grid!r} is not 'X,Y' or 'XxY'")
        grid = (int(parts[0]), int(parts[1]))
    units_x, units_y = int(grid[0]), int(grid[1])
    if units_x < 1 or units_y < 1:
        raise ValueError(f"grid must be >= 1x1, got {units_x}x{units_y}")
    return (units_x, units_y)


@functools.lru_cache(maxsize=None)
def grid_mesh(units_x: int, units_y: int):
    """The (cached) ``("gx", "gy")`` device mesh grid execution runs on.

    Lazy — pricing and planning never touch devices; only
    :meth:`GridBackend.execute` builds the mesh, and a grid larger than the
    visible device count fails there with ``launch.mesh``'s error.
    """
    from repro.launch import mesh as mesh_lib  # deferred: devices only on use
    return mesh_lib.make_grid_mesh(units_x, units_y)


def shard_site(coord: tuple[int, int], site: str) -> str:
    """The shard-local name of ``site`` on shard ``(gx, gy)``:
    ``"{gx},{gy}/{site}"`` (the key :class:`GridPlan` stores shards under)."""
    return f"{coord[0]},{coord[1]}/{site}"


def shard_slices(k: int, n_out: int, units_x: int,
                 units_y: int) -> dict[tuple[int, int], tuple[slice, slice]]:
    """Per-shard ``(k-rows, n-cols)`` slices of a (k, n_out) weight.

    The ceil-split :meth:`GridBackend.execute` applies: shard ``(gx, gy)``
    owns rows ``[gx·⌈k/X⌉, (gx+1)·⌈k/X⌉) ∩ [0, k)`` and the matching column
    band.  Shards that are pure padding (possible when X ∤ k) map to empty
    slices.
    """
    ks, ns = -(-k // units_x), -(-n_out // units_y)
    return {
        (gx, gy): (slice(gx * ks, min((gx + 1) * ks, k)),
                   slice(gy * ns, min((gy + 1) * ns, n_out)))
        for gx in range(units_x) for gy in range(units_y)}


@dataclasses.dataclass(frozen=True)
class GridBackend(GemmBackend):
    """A ``units_x`` × ``units_y`` tensor-parallel grid of one unit design.

    Subclasses :class:`GemmBackend`, so everything that accepts a backend
    (``use_backend``, ``price_workload``, ``models/common.dense``) accepts a
    grid.  ``name``/``bits``/``exact``/``pricing_design`` are the wrapped
    unit's; the grid adds the shard topology (``units_x`` K-partitions whose
    partial sums psum-reduce, ``units_y`` output-column partitions) and the
    interconnect-hop cost terms (``core.ppa.HOP_CYCLES``).  Build with
    :func:`as_grid`.
    """

    units_x: int = 1
    units_y: int = 1

    def __post_init__(self) -> None:
        super().__post_init__()
        if self.units_x < 1 or self.units_y < 1:
            raise ValueError(f"grid must be >= 1x1, got "
                             f"{self.units_x}x{self.units_y}")

    # -- topology -----------------------------------------------------------

    @property
    def grid(self) -> tuple[int, int]:
        """The (units_x, units_y) shape (``price_workload``'s grid switch)."""
        return (self.units_x, self.units_y)

    @property
    def num_shards(self) -> int:
        return self.units_x * self.units_y

    def inner(self) -> GemmBackend:
        """The wrapped single-unit backend (one grid node)."""
        return GemmBackend(
            name=self.name, bits=self.bits, exact=self.exact,
            has_synthesis_data=self.has_synthesis_data,
            pricing_design=self.pricing_design, spec=self.spec,
            block=self.block, interpret=self.interpret)

    def shard_common_dim(self, common_dim: int) -> int:
        """Per-shard contraction length: ``⌈common_dim / units_x⌉``."""
        return -(-int(common_dim) // self.units_x)

    def hop_cycles(self) -> int:
        """Interconnect critical path per GEMM, in cycles: one hop per
        activation fan-out step (``units_y - 1``) plus one per partial-sum
        reduction step (``units_x - 1``)."""
        return ppa.HOP_CYCLES * ((self.units_x - 1) + (self.units_y - 1))

    def shard_operands(self, q: jax.Array) -> Iterator[
            tuple[tuple[int, int], jax.Array]]:
        """Yield ``((gx, gy), slice)`` of a (K,) / (K, n) temporal-operand
        tile — the codes each grid node actually streams (real rows only;
        pure-padding shards are skipped)."""
        q = jnp.asarray(q)
        if q.ndim == 1:
            q = q[:, None]
        for coord, (rows, cols) in shard_slices(
                q.shape[0], q.shape[1], self.units_x, self.units_y).items():
            sub = q[rows, cols]
            if sub.size:
                yield coord, sub

    # -- execution ----------------------------------------------------------

    def execute(self, a: jax.Array, b: jax.Array) -> jax.Array:
        """Sharded GEMM on quantized codes, bit-identical to the wrapped
        backend.

        Shapes as :meth:`GemmBackend.execute`.  2-D operands are zero-padded
        to the grid (zero codes contribute exact zeros on every design), K
        is split over ``gx`` and N over ``gy`` under ``compat.shard_map`` on
        the :func:`grid_mesh` devices, and the per-chip partial sums reduce
        with ``lax.psum`` — int32 (exact designs) or exact-integer float32
        (uGEMM), so the reduction order cannot change the result.  Batched
        operands recurse on the 2-D path.
        """
        if a.ndim == 3:
            if b.ndim == 3:
                return jnp.stack([self.execute(a[i], b[i])
                                  for i in range(a.shape[0])])
            m = a.shape[1]
            out = self.execute(a.reshape(-1, a.shape[-1]), b)
            return out.reshape(a.shape[0], m, out.shape[-1])
        if a.ndim != 2:
            raise ValueError(
                f"execute wants (M, K) or (B, M, K) operands, got {a.shape}")
        x_parts, y_parts = self.units_x, self.units_y
        k, n = a.shape[1], b.shape[1]
        # Envelope guard at the *shard-local* contraction length: each node
        # accumulates over its ceil(K / units_x) padded rows, so K-splitting
        # is exactly what buys headroom back (see repro.analysis.ranges).
        self._guard_envelope(self.shard_common_dim(k))
        kp = -(-k // x_parts) * x_parts
        n_pad = -(-n // y_parts) * y_parts
        ap = jnp.pad(a, ((0, 0), (0, kp - k)))
        bp = jnp.pad(b, ((0, kp - k), (0, n_pad - n)))
        exact_fn, bits, reduce_k = self.spec.exact_fn, self.bits, x_parts > 1

        def node(a_sub, b_sub):
            part = exact_fn(a_sub, b_sub, bits)
            return jax.lax.psum(part, "gx") if reduce_k else part

        fn = compat.shard_map(node, mesh=grid_mesh(x_parts, y_parts),
                              in_specs=(P(None, "gx"), P("gx", "gy")),
                              out_specs=P(None, "gy"), check_vma=False)
        return fn(ap, bp)[:, :n]

    def stream(self, a: jax.Array, b: jax.Array):
        """Grids have no single cycle-faithful stream — the schedule is
        per-shard.  Stream one node via ``.inner().stream(...)`` and account
        the grid with :meth:`cycles` / :meth:`dyn_cycles`."""
        raise NotImplementedError(
            "GridBackend.stream: stream the wrapped unit per shard "
            "(backend.inner().stream on a shard_operands slice); grid cycle "
            "accounting goes through cycles()/dyn_cycles()")

    # -- cost ---------------------------------------------------------------

    def cycles(self, common_dim: int) -> int:
        """Worst-case grid cycles: the per-shard worst case over the
        ceil-split contraction length, plus the interconnect hops."""
        return self.spec.wc_cycles_fn(
            self.bits, self.shard_common_dim(common_dim)) + self.hop_cycles()

    def dyn_cycles(self, common_dim: int | None = None, *,
                   bit_sparsity: float | None = None,
                   operand=None) -> float:
        """Dynamic grid cycles (same three modes as the base method).

        ``operand`` — per-shard early termination on each node's own slice
        of the codes; the grid finishes with its slowest shard (max), plus
        hops.  ``bit_sparsity`` — Eq. 1 applied to the per-shard worst case
        (the statistic is assumed shard-uniform; per-shard statistics go
        through :func:`grid_matrix_cycles`).  Neither — worst case.
        """
        hops = float(self.hop_cycles())
        if operand is not None:
            if bit_sparsity is not None:
                raise ValueError("pass either operand or bit_sparsity, not both")
            node = self.inner()
            slowest = max(
                (float(node.dyn_cycles(operand=sub))
                 for _, sub in self.shard_operands(operand)), default=0.0)
            return slowest + hops
        if common_dim is None:
            raise ValueError("common_dim is required without an operand")
        ks = self.shard_common_dim(common_dim)
        wc = self.spec.wc_cycles_fn(self.bits, ks)
        if bit_sparsity is not None and self.spec.sparsity_aware:
            return wc * (1.0 - float(bit_sparsity)) + hops
        return float(wc) + hops


def as_grid(backend: GemmBackend, units_x: int, units_y: int) -> GridBackend:
    """Wrap a resolved backend in a ``units_x`` × ``units_y`` grid.

    Idempotent re-gridding: an existing :class:`GridBackend` is re-shaped,
    not nested.  A ``(1, 1)`` grid is a valid degenerate topology (one node,
    zero hops) whose execute path still runs the shard_map machinery.
    """
    units_x, units_y = parse_grid((units_x, units_y))
    return GridBackend(
        name=backend.name, bits=backend.bits, exact=backend.exact,
        has_synthesis_data=backend.has_synthesis_data,
        pricing_design=backend.pricing_design, spec=backend.spec,
        block=backend.block, interpret=backend.interpret,
        units_x=units_x, units_y=units_y)


def grid_matrix_cycles(backend: GridBackend, weight, *, rows: int,
                       unit_n: int, num_units: int) -> dict[str, dict]:
    """Per-shard measured/dyn/floor/wc cycles for ONE (k, n_out) weight.

    Each shard's slice is profiled and measured on its *own* codes (this is
    where per-shard sparsity heterogeneity becomes visible), with waves from
    the shard-local tile count and the grid's hop term added to every bound
    identically — so the single-unit invariant ``dyn_floor ≤ measured ≤ wc``
    holds per shard.  Keys are ``"{gx},{gy}"``; pure-padding shards are
    omitted.
    """
    import numpy as np

    from repro.backends import runtime

    node = backend.inner()
    hops = float(backend.hop_cycles())
    w = np.asarray(weight, np.float32)
    out: dict[str, dict] = {}
    for coord, (r, c) in shard_slices(w.shape[0], w.shape[1],
                                      backend.units_x,
                                      backend.units_y).items():
        sub = w[r, c]
        if not sub.size:
            continue
        cyc = runtime.measure_matrix_cycles(node, sub, rows=rows,
                                            unit_n=unit_n,
                                            num_units=num_units)
        out[f"{coord[0]},{coord[1]}"] = {k: v + hops for k, v in cyc.items()}
    return out


# ---------------------------------------------------------------------------
# GridPlan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GridPlan:
    """Per-shard mixed-precision plans for a PE-array grid.

    ``shards`` maps ``"{gx},{gy}"`` keys to each shard's own
    :class:`BackendPlan` (derived from that shard's weight slices);
    ``aggregate`` is the plan SPMD execution replays (one entry per site,
    argmin of the summed per-shard candidate cost).  ``meta`` carries the
    per-shard and aggregate planned-vs-uniform verdicts.  Serializes to
    ``schema: repro.backends.gridplan/v1`` (one nested plan/v1 document per
    shard plus the aggregate).
    """

    units_x: int
    units_y: int
    aggregate: BackendPlan
    shards: tuple[tuple[str, BackendPlan], ...]
    meta: tuple[tuple[str, object], ...] = ()

    def __post_init__(self) -> None:
        if not isinstance(self.shards, tuple):
            object.__setattr__(self, "shards", tuple(self.shards))
        if not isinstance(self.meta, tuple):
            object.__setattr__(self, "meta",
                               tuple(sorted(dict(self.meta).items())))

    @property
    def grid(self) -> tuple[int, int]:
        return (self.units_x, self.units_y)

    def shard_plan(self, gx: int, gy: int) -> BackendPlan | None:
        """Shard ``(gx, gy)``'s own plan (None when absent)."""
        key = f"{gx},{gy}"
        for name, plan in self.shards:
            if name == key:
                return plan
        return None

    def backend_for(self, site: str) -> GemmBackend | None:
        """Resolve a site name to its executing backend.

        Plain names resolve against the aggregate plan and come back wrapped
        in a :class:`GridBackend` (this is what ``use_plan`` executes).  A
        shard-local name (``"{gx},{gy}/{site}"``, see :func:`shard_site`)
        resolves *only* against that shard's own plan and returns the
        unwrapped single-node backend — the engine that one chip runs; a
        missing shard or unmatched shard site is None, never an aggregate
        fallback (site names contain no commas, so the prefix is
        unambiguous).
        """
        head, sep, rest = site.partition("/")
        if sep and _SHARD_KEY_RE.fullmatch(head):
            gx, gy = (int(p) for p in head.split(","))
            plan = self.shard_plan(gx, gy)
            return None if plan is None else plan.backend_for(rest)
        backend = self.aggregate.backend_for(site)
        if backend is None:
            return None
        return as_grid(backend, self.units_x, self.units_y)

    def distinct_backends(self) -> tuple[tuple[str, int], ...]:
        """Sorted unique (design, bits) of the *aggregate* (executed) plan."""
        return self.aggregate.distinct_backends()

    def shard_distinct_backends(self) -> tuple[tuple[str, int], ...]:
        """Sorted unique (design, bits) across every shard's own plan."""
        pairs = {(s.design, s.bits)
                 for _, plan in self.shards for s in plan.sites}
        return tuple(sorted(pairs))

    def heterogeneous_sites(self) -> tuple[str, ...]:
        """Site names whose assignment differs across shards — the sites
        where per-shard sparsity actually flips the sweet spot."""
        out = []
        for entry in self.aggregate.sites:
            picks = {(p.assignment_for(entry.pattern).design,
                      p.assignment_for(entry.pattern).bits)
                     for _, p in self.shards
                     if p.assignment_for(entry.pattern) is not None}
            if len(picks) > 1:
                out.append(entry.pattern)
        return tuple(out)

    def metadata(self) -> dict:
        return dict(self.meta)

    # -- (de)serialization --------------------------------------------------

    def to_json(self, indent: int = 2) -> str:
        """Stable JSON rendering (``schema: repro.backends.gridplan/v1``)."""
        doc = {
            "schema": GRID_SCHEMA,
            "grid": [self.units_x, self.units_y],
            "meta": dict(self.meta),
            "aggregate": json.loads(self.aggregate.to_json()),
            "shards": {key: json.loads(plan.to_json())
                       for key, plan in self.shards},
        }
        return json.dumps(doc, indent=indent)

    @classmethod
    def from_json(cls, text: str) -> "GridPlan":
        """Parse :meth:`to_json` output; validates both schema layers."""
        doc = json.loads(text)
        if doc.get("schema") != GRID_SCHEMA:
            raise ValueError(
                f"not a grid plan: schema {doc.get('schema')!r} "
                f"(expected {GRID_SCHEMA!r})")
        grid = doc.get("grid")
        if (not isinstance(grid, (list, tuple)) or len(grid) != 2):
            raise ValueError(f"grid plan needs a 2-element grid, got {grid!r}")
        sub = lambda d: BackendPlan.from_json(json.dumps(d))  # noqa: E731
        return cls(units_x=int(grid[0]), units_y=int(grid[1]),
                   aggregate=sub(doc["aggregate"]),
                   shards=tuple(sorted(
                       (key, sub(val))
                       for key, val in doc.get("shards", {}).items())),
                   meta=tuple(sorted(doc.get("meta", {}).items())))

    def save(self, path: str | os.PathLike) -> str:
        """Write :meth:`to_json` to ``path`` (dirs created); returns path."""
        path = os.fspath(path)
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as fh:
            fh.write(self.to_json())
            fh.write("\n")
        return path

    @classmethod
    def load(cls, path: str | os.PathLike) -> "GridPlan":
        with open(os.fspath(path)) as fh:
            return cls.from_json(fh.read())


def load_plan(path: str | os.PathLike) -> BackendPlan | GridPlan:
    """Load either plan flavour by sniffing the ``schema`` field.

    ``repro.backends.plan/v1`` → :class:`BackendPlan`;
    ``repro.backends.gridplan/v1`` → :class:`GridPlan`.  Anything else is a
    ValueError naming both accepted schemas.
    """
    with open(os.fspath(path)) as fh:
        text = fh.read()
    schema = json.loads(text).get("schema")
    if schema == GRID_SCHEMA:
        return GridPlan.from_json(text)
    if schema == PLAN_SCHEMA:
        return BackendPlan.from_json(text)
    raise ValueError(f"{path}: unknown plan schema {schema!r} "
                     f"(expected {PLAN_SCHEMA!r} or {GRID_SCHEMA!r})")
