"""Scoped backend execution: :func:`use_backend` / :func:`use_plan` thread a
GEMM engine (one global backend, or a per-site :class:`~repro.backends.plan.
BackendPlan`) into ``models/common.dense`` so the quantized forward pass
actually contracts its integer tiles on the selected unary engine(s).

Both scopes live on one thread-local stack (nestable, exception-safe, the
innermost scope wins).  Inside a scope, every ``dense`` call asks the scope
for the backend of its *site* (see the naming contract below), quantizes both
operands to that backend's bit-width, contracts the int tiles with
:meth:`GemmBackend.execute`, and dequantizes back to the activation dtype;
outside any scope — or when a plan maps the site to no backend — the float
path runs untouched.

**Site-naming contract** (what plan patterns match against).  A GEMM site is
the parameter-tree path of its weight, ``"/"``-joined:

* model code pushes path segments with :func:`site_scope` (``"layers"`` around
  the scanned stack, ``"attn"`` / ``"mlp"`` / ``"ssm"`` / ``"tm"`` / ``"cm"``
  around the sub-module, ``"shared"`` for the hybrid shared block) and passes
  the weight's leaf key as ``dense(..., name="wq")``;
* :func:`current_site` joins the live stack with the leaf name, yielding
  exactly the names ``jax.tree_util.tree_flatten_with_path`` produces for the
  parameter pytree (``"layers/attn/wq"``, ``"layers/mlp/w_up"``,
  ``"lm_head"``, …) — the same names ``core.sparsity.profile_tree`` and the
  serve-time workload recorder use, so profiling, pricing, planning and
  execution all key on one name;
* an un-named ``dense`` outside any :func:`site_scope` has site ``""`` (the
  empty string), which only a wildcard pattern can match.

**Jit caveat** — the active scope, the site stack and the per-site backend
lookup are all read at *trace* time.  A step function jitted (traced) outside
the scope keeps its float execution when later called inside it; build/trace
the jitted steps inside the scope (``launch/serve.py --execute-backend`` and
``--backend-plan`` do).  For the same reason the execution trace records one
entry per traced GEMM *site*: a layer body scanned over L layers appears
once, not L times.
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
import threading

from repro.backends.base import GemmBackend

# NOTE: repro.backends.registry is imported lazily inside use_backend —
# registry pulls in repro.configs, whose model-config import would close a
# cycle with the model modules that import site_scope from here.
# repro.backends.grid is imported lazily for the same reason grid execution
# is lazy about devices: scoping must stay importable everywhere.

__all__ = ["ExecutedGemm", "BackendExecution", "PlanExecution",
           "SiteRecorder", "use_backend", "use_plan", "pack_weights",
           "record_sites", "active_backend", "active_execution", "site_scope",
           "current_site", "measure_matrix_cycles"]


@dataclasses.dataclass(frozen=True)
class ExecutedGemm:
    """One GEMM site contracted on a backend (shapes static at trace time).

    ``m``/``k``/``n_out`` — the contraction ``(m, k) @ (k, n_out)``;
    ``backend``/``bits`` — the engine that site ran on; ``site`` — the
    site name per the module-level naming contract (``""`` for un-named
    ``dense`` calls outside any :func:`site_scope`); ``stream_len`` — the
    rate-coded stream length for stochastic engines (0 = count-exact).
    """

    m: int
    k: int
    n_out: int
    backend: str
    bits: int
    site: str = ""
    stream_len: int = 0


class BackendExecution:
    """Live handle for one :func:`use_backend` scope.

    ``backend`` — the resolved :class:`GemmBackend` every site executes on;
    ``calls`` — the :class:`ExecutedGemm` sites recorded as the model traces
    through ``dense`` (see the jit caveat in the module docstring).
    """

    def __init__(self, backend: GemmBackend) -> None:
        self.backend = backend
        self.calls: list[ExecutedGemm] = []

    def backend_for(self, site: str) -> GemmBackend | None:
        """The backend ``dense`` must execute ``site`` on (None = float)."""
        return self.backend

    def record(self, site: str, m: int, k: int, n_out: int,
               backend: GemmBackend) -> None:
        """Append one traced GEMM site to ``calls``."""
        self.calls.append(ExecutedGemm(
            int(m), int(k), int(n_out), backend.name, backend.bits,
            str(site), int(getattr(backend, "stream_len", 0) or 0)))

    def observe(self, site: str, m: int, k: int, n_out: int) -> None:
        """Called by ``dense`` for sites the scope maps to NO backend.

        A no-op for execution scopes; :class:`SiteRecorder` overrides it to
        collect the site inventory.
        """


class PlanExecution(BackendExecution):
    """Live handle for one :func:`use_plan` scope.

    ``plan`` — the :class:`~repro.backends.plan.BackendPlan` (or a
    :class:`~repro.backends.grid.GridPlan`, which wraps its aggregate
    entries in grid backends itself); ``backend`` is None (there is no
    single engine — :meth:`backend_for` resolves per site).  ``grid`` — an
    optional (units_x, units_y) shape that wraps every resolved backend in a
    :class:`~repro.backends.grid.GridBackend`.  Backends are resolved once
    per site name and cached for the scope's lifetime, so re-tracing is
    cheap and every trace sees the same objects.
    """

    def __init__(self, plan, grid: tuple[int, int] | None = None) -> None:
        super().__init__(backend=None)
        self.plan = plan
        self.grid = grid
        self._cache: dict[str, GemmBackend | None] = {}

    def backend_for(self, site: str) -> GemmBackend | None:
        try:
            return self._cache[site]
        except KeyError:
            backend = self.plan.backend_for(site)
            if backend is not None and self.grid is not None:
                from repro.backends.grid import as_grid
                backend = as_grid(backend, *self.grid)
            self._cache[site] = backend
            return backend


class SiteRecorder(BackendExecution):
    """Scope that *names* every dense GEMM site without executing on any
    backend — the planner's discovery pass (see :func:`record_sites`).

    ``backend_for`` always returns None, so the float path runs (or, under
    ``jax.eval_shape``, merely traces); ``dense`` still records the site name
    and contraction shape into ``calls`` with backend ``"none"`` / bits 0.
    """

    def __init__(self) -> None:
        super().__init__(backend=None)

    def backend_for(self, site: str) -> GemmBackend | None:
        return None

    def observe(self, site: str, m: int, k: int, n_out: int) -> None:
        self.calls.append(ExecutedGemm(int(m), int(k), int(n_out),
                                       "none", 0, str(site)))


_TLS = threading.local()


def _stack() -> list[BackendExecution]:
    stack = getattr(_TLS, "stack", None)
    if stack is None:
        stack = _TLS.stack = []
    return stack


def _site_stack() -> list[str]:
    stack = getattr(_TLS, "sites", None)
    if stack is None:
        stack = _TLS.sites = []
    return stack


def active_execution() -> BackendExecution | None:
    """The innermost live :func:`use_backend` / :func:`use_plan` /
    :func:`record_sites` scope, or None."""
    stack = _stack()
    return stack[-1] if stack else None


def active_backend() -> GemmBackend | None:
    """The single backend ``dense`` executes on right now, or None.

    None outside any scope (float path) and inside :func:`use_plan` /
    :func:`record_sites` scopes, whose backend is per-site — use
    :meth:`BackendExecution.backend_for` with a site name there.
    """
    execution = active_execution()
    return execution.backend if execution is not None else None


@contextlib.contextmanager
def site_scope(segment: str):
    """Push one ``"/"``-separated path segment onto the site-name stack.

    Model code wraps sub-module forwards so the ``dense`` calls inside
    compose the parameter-tree path (see the module-level naming contract).
    Entered at trace time; nests and unwinds on exceptions.
    """
    stack = _site_stack()
    stack.append(str(segment))
    try:
        yield
    finally:
        stack.pop()


def current_site(name: str | None = None) -> str:
    """The full site name for a leaf ``name`` under the live scopes.

    Joins the :func:`site_scope` stack with ``name`` (omitted if None);
    returns ``""`` when both are empty.
    """
    parts = list(_site_stack())
    if name:
        parts.append(str(name))
    return "/".join(parts)


@contextlib.contextmanager
def _pushed(execution: BackendExecution):
    stack = _stack()
    stack.append(execution)
    try:
        yield execution
    finally:
        stack.remove(execution)


@contextlib.contextmanager
def use_backend(spec: str | GemmBackend, *, bits: int | None = None,
                block=None, interpret: bool | None = None,
                stream_len: int | None = None, grid=None):
    """Execute every ``dense`` contraction in the block on ``spec``.

    Args as :func:`repro.backends.resolve` (``stream_len`` selects the
    stochastic family's rate-coded stream length), plus ``grid`` — an
    optional (units_x, units_y) tuple or ``"X,Y"`` string that wraps the
    resolved backend in a :class:`~repro.backends.grid.GridBackend`, so
    every dense contraction is sharded across the PE-array grid under
    ``shard_map``.  Yields the scope's :class:`BackendExecution`
    (``.backend``, ``.calls``).  Scopes nest — the innermost wins — and
    unwind correctly on exceptions.
    """
    from repro.backends.registry import resolve
    backend = resolve(spec, bits=bits, block=block, interpret=interpret,
                      stream_len=stream_len)
    if grid is not None:
        from repro.backends.grid import as_grid, parse_grid
        backend = as_grid(backend, *parse_grid(grid))
    execution = BackendExecution(backend)
    with _pushed(execution):
        yield execution


def _validate_plan_envelopes(plan, grid: tuple[int, int] | None) -> None:
    """Fail fast on assignments whose evidence leaves the safe envelope.

    Entries record the contraction length they were planned for (``k``;
    shard entries record their slice, aggregate grid entries the full K).
    Executing outside the envelope would raise mid-trace anyway (the
    backend guard); checking here turns that into an immediate, plan-level
    error naming the offending entry.  Entries without geometry evidence
    (hand-written pattern-only plans) are skipped — the execute guard
    still covers them.
    """
    from repro.analysis import ranges
    from repro.backends.grid import GridPlan

    def check(entries, units_x: int, label: str) -> None:
        for entry in entries:
            if not entry.k:
                continue
            k_local = -(-int(entry.k) // units_x)
            try:
                ranges.assert_within_envelope(
                    entry.design, entry.bits, k_local,
                    where=f"{label} entry {entry.pattern!r}",
                    stream_len=getattr(entry, "stream_len", 0) or None)
            except KeyError:
                continue

    if isinstance(plan, GridPlan):
        check(plan.aggregate.sites, plan.units_x, "aggregate plan")
        for key, shard_plan in plan.shards:
            check(shard_plan.sites, 1, f"shard {key} plan")
    else:
        check(plan.sites, grid[0] if grid else 1, "plan")


@contextlib.contextmanager
def use_plan(plan, *, grid=None):
    """Execute every ``dense`` contraction on the site's planned backend.

    ``plan`` — a :class:`~repro.backends.plan.BackendPlan`, a
    :class:`~repro.backends.grid.GridPlan`, or a path-like / str (loaded via
    :func:`repro.backends.grid.load_plan`, which sniffs the schema).  Each
    dense site is matched against the plan's patterns (most specific wins,
    see ``repro.backends.plan``); unmatched sites run the float path.

    ``grid`` — optional (units_x, units_y) / ``"X,Y"`` grid every resolved
    backend is wrapped in.  A :class:`GridPlan` brings its own grid (its
    aggregate entries execute grid-wrapped; shard-local site names resolve
    to single-node backends) — passing a mismatching ``grid`` next to one is
    an error.

    Yields a :class:`PlanExecution` whose ``.calls`` lists every contracted
    site with the backend it actually ran on.  Nests with
    :func:`use_backend` (innermost scope wins) and unwinds on exceptions.

    Entering the scope statically validates the plan's recorded contraction
    geometry against each assignment's accumulator envelope
    (``repro.analysis.ranges``) — an overflow-hazardous plan fails here,
    before any weight is quantized or any GEMM traced.
    """
    from repro.backends.grid import GridPlan, load_plan, parse_grid
    from repro.backends.plan import BackendPlan
    if not isinstance(plan, (BackendPlan, GridPlan)):
        plan = load_plan(plan)
    if grid is not None:
        grid = parse_grid(grid)
    _validate_plan_envelopes(plan, grid)
    if isinstance(plan, GridPlan):
        if grid is not None and grid != plan.grid:
            raise ValueError(f"use_plan(grid={grid}) conflicts with the "
                             f"GridPlan's own grid {plan.grid}")
        grid = None  # GridPlan.backend_for wraps its aggregate itself
    with _pushed(PlanExecution(plan, grid=grid)) as execution:
        yield execution


def pack_weights(cfg, params, plan=None, *, bits: int | None = None,
                 grid=None):
    """Freeze each planned site's weight bit-packed at its assigned width.

    Returns a new parameter tree in which every dense GEMM site that
    ``plan`` assigns a backend is replaced by a
    :class:`repro.core.packing.PackedQuantized` store holding the *exact*
    codes and scales ``models/common.dense`` would compute on that site
    under the plan — so executing the packed tree inside :func:`use_plan`
    is bit-identical to executing the float tree, while the weight bytes
    shrink 4–16x (``core.accounting.packed_store_report``).

    ``plan`` — a :class:`~repro.backends.plan.BackendPlan` /
    :class:`~repro.backends.grid.GridPlan` or a path (schema-sniffed via
    ``load_plan``).  Alternatively pass ``bits`` to freeze every
    discovered site at one uniform width (the ``use_backend`` analogue).
    Sites the plan leaves unmatched keep their float leaves — they run
    the float path under ``use_plan``, exactly as before.

    ``grid`` — (units_x, units_y) / ``"X,Y"``: pack per shard along the
    same ceil K-split :meth:`~repro.backends.grid.GridBackend.execute`
    applies, so no int32 word straddles a shard boundary.  A
    :class:`GridPlan` brings its own grid.

    Already-packed leaves pass through when their width matches the
    assignment and raise otherwise (the stale-width hazard plan-lint's
    ``packed-width-mismatch`` rule catches statically).
    """
    import jax

    from repro.core import packing

    if (plan is None) == (bits is None):
        raise ValueError("pack_weights wants exactly one of plan= or bits=")
    entry_plan = None
    if plan is not None:
        from repro.backends.grid import GridPlan, load_plan
        from repro.backends.plan import BackendPlan
        if not isinstance(plan, (BackendPlan, GridPlan)):
            plan = load_plan(plan)
        entry_plan = plan.aggregate if isinstance(plan, GridPlan) else plan
        if isinstance(plan, GridPlan):
            if grid is not None:
                from repro.backends.grid import parse_grid
                if parse_grid(grid) != plan.grid:
                    raise ValueError(
                        f"pack_weights(grid={grid}) conflicts with the "
                        f"GridPlan's own grid {plan.grid}")
            grid = plan.grid
    grid_x = 1
    if grid is not None:
        from repro.backends.grid import parse_grid
        grid_x = parse_grid(grid)[0]

    from repro.eval import planner as planner_lib  # lazy: imports the stack
    assignments: dict[str, tuple[int, int, int]] = {}
    for site in planner_lib.discover_sites(cfg, params):
        if entry_plan is not None:
            entry = entry_plan.assignment_for(site.name)
            if entry is None:
                continue
            width = int(entry.bits)
        else:
            width = int(bits)
        assignments[site.name] = (width, site.k, site.n_out)

    flat, treedef = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=packing.is_packed)
    leaves = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        picked = assignments.get(name)
        if picked is None:
            leaves.append(leaf)
            continue
        width, k, n_out = picked
        if packing.is_packed(leaf):
            if int(leaf.bits) != width:
                raise ValueError(
                    f"site {name!r}: packed store holds {leaf.bits}-bit "
                    f"codes but the plan assigns {width}-bit — repack from "
                    f"the float parameters (packed-width-mismatch)")
            leaves.append(leaf)
            continue
        leaves.append(packing.pack_quantized(leaf, bits=width, k=k,
                                             n_out=n_out, grid_x=grid_x))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def measure_matrix_cycles(backend: GemmBackend, weight, *, rows: int,
                          unit_n: int, num_units: int,
                          bit_blockmax: float | None = None,
                          bit_elem: float | None = None) -> dict[str, float]:
    """Measured-cycles contract for ONE (k, n_out) weight matrix on one
    backend — the single implementation behind both the planner's per-site
    report (``eval/planner.measure_site_cycles``) and the serve driver's
    decode totals (``launch/serve.measure_decode_cycles``).

    Quantizes ``weight`` per output channel (exactly what
    ``models/common.dense`` contracts under a scope) and returns cycles for
    one invocation of the ``(rows, k) @ (k, n_out)`` decode GEMM on the
    ``core.ppa.DLAModel`` tiling (per-tile cycles × ⌈tiles / num_units⌉
    waves), four ways:

    * ``measured`` — operand-driven early termination,
      ``backend.dyn_cycles(operand=codes)``;
    * ``dyn`` — paper Eq. 1 from the block-max statistic (profiled here at
      ``backend.bits`` unless ``bit_blockmax`` is supplied);
    * ``dyn_floor`` — Eq. 1 from the element-level statistic (optimistic
      bound the shared slot schedule cannot beat);
    * ``wc`` — worst case.

    For sparsity-aware designs ``dyn_floor ≤ measured ≤ wc``; designs
    without early termination report measured == dyn == floor == wc.

    Grid backends stay consistent with their per-shard cycle model: the
    per-tile cycles already cover the ceil-split contraction (plus hops),
    so the wave count comes from a *shard's* output tile share
    (``⌈n_out / units_y⌉``), matching ``ppa.GridDLAModel`` — all shards
    run their waves in parallel.
    """
    import jax.numpy as jnp

    from repro.core import packing, ppa, sparsity
    from repro.core.quantization import quantize

    if packing.is_packed(weight):
        raise TypeError(
            "measure_matrix_cycles wants the float weight — measuring a "
            "PackedQuantized store would re-quantize its dequantized codes "
            "at a second scale; keep the float parameters for measurement "
            "(serve's plan replay does)")
    w = jnp.asarray(weight)
    k, n_out = int(w.shape[0]), int(w.shape[1])
    if bit_blockmax is None or bit_elem is None:
        st = sparsity.profile_tensor(w, bits=backend.bits)
        bit_blockmax = st.bit_blockmax if bit_blockmax is None else bit_blockmax
        bit_elem = st.bit_elem if bit_elem is None else bit_elem
    dla = ppa.DLAModel(design=backend.pricing_design, bits=backend.bits,
                       n=unit_n, num_units=num_units)
    shard_n_out = math.ceil(n_out / getattr(backend, "units_y", 1))
    waves = math.ceil(dla.tiles(rows, shard_n_out) / num_units)
    codes = quantize(w, bits=backend.bits).values
    return {
        "measured": float(backend.dyn_cycles(operand=codes)) * waves,
        "dyn": float(backend.dyn_cycles(k, bit_sparsity=bit_blockmax)) * waves,
        "dyn_floor": float(backend.dyn_cycles(k, bit_sparsity=bit_elem))
        * waves,
        "wc": float(backend.cycles(k)) * waves,
    }


@contextlib.contextmanager
def record_sites():
    """Record every dense GEMM site's name and shape, executing nothing.

    The planner's discovery pass: trace the model inside this scope (cheapest
    via ``jax.eval_shape`` — no FLOPs run) and read ``.calls`` for the
    ``(site, m, k, n_out)`` of every GEMM ``models/common.dense`` would
    contract under a backend scope.  Scanned layer bodies record once (see
    the jit caveat), so per-site invocation counts come from the parameter
    shapes, not from this trace.
    """
    with _pushed(SiteRecorder()) as execution:
        yield execution
