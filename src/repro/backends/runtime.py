"""Scoped backend execution: :func:`use_backend` threads a backend into
``models/common.dense`` so the quantized forward pass actually contracts its
integer tiles on the selected unary engine.

The scope is a thread-local stack (nestable, exception-safe).  Inside a
``with use_backend(...)`` block, every ``dense`` call quantizes both operands
to the backend's bit-width, contracts the int tiles with
:meth:`GemmBackend.execute`, and dequantizes back to the activation dtype;
outside any scope the float path runs untouched.

**Jit caveat** — the active backend is read at *trace* time.  A step function
jitted (traced) outside the scope keeps its float execution when later called
inside it; build/trace the jitted steps inside the scope (``launch/serve.py
--execute-backend`` does).  For the same reason the execution trace records
one entry per traced GEMM *site*: a layer body scanned over L layers appears
once, not L times.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading

from repro.backends.base import GemmBackend
from repro.backends.registry import resolve

__all__ = ["ExecutedGemm", "BackendExecution", "use_backend",
           "active_backend", "active_execution"]


@dataclasses.dataclass(frozen=True)
class ExecutedGemm:
    """One GEMM site contracted on the backend (shapes static at trace time)."""

    m: int
    k: int
    n_out: int
    backend: str
    bits: int


class BackendExecution:
    """Live handle for one :func:`use_backend` scope.

    ``backend`` — the resolved :class:`GemmBackend`; ``calls`` — the
    :class:`ExecutedGemm` sites recorded as the model traces through
    ``dense`` (see the jit caveat in the module docstring).
    """

    def __init__(self, backend: GemmBackend) -> None:
        self.backend = backend
        self.calls: list[ExecutedGemm] = []

    def record(self, m: int, k: int, n_out: int) -> None:
        self.calls.append(ExecutedGemm(int(m), int(k), int(n_out),
                                       self.backend.name, self.backend.bits))


_TLS = threading.local()


def _stack() -> list[BackendExecution]:
    stack = getattr(_TLS, "stack", None)
    if stack is None:
        stack = _TLS.stack = []
    return stack


def active_execution() -> BackendExecution | None:
    """The innermost live :func:`use_backend` scope, or None."""
    stack = _stack()
    return stack[-1] if stack else None


def active_backend() -> GemmBackend | None:
    """The backend ``dense`` will execute on right now, or None (float path)."""
    execution = active_execution()
    return execution.backend if execution is not None else None


@contextlib.contextmanager
def use_backend(spec: str | GemmBackend, *, bits: int | None = None,
                block=None, interpret: bool | None = None):
    """Execute every ``dense`` contraction in the block on ``spec``.

    Args as :func:`repro.backends.resolve`.  Yields the scope's
    :class:`BackendExecution` (``.backend``, ``.calls``).  Scopes nest — the
    innermost wins — and unwind correctly on exceptions.
    """
    execution = BackendExecution(resolve(spec, bits=bits, block=block,
                                         interpret=interpret))
    stack = _stack()
    stack.append(execution)
    try:
        yield execution
    finally:
        stack.remove(execution)
