"""Backend construction: :func:`resolve` specs into :class:`GemmBackend`s.

Resolution rules (in order):

1. A :class:`GemmBackend` instance resolves to itself (re-widthed if ``bits``
   differs; re-built by name if kernel knobs ``block``/``interpret`` are
   given, so they can apply).
2. A Pallas mirror name (``tugemm_pallas`` / ``tubgemm_pallas``) with
   explicit ``block``/``interpret`` — or one absent from the live
   ``gemm_sims`` registry — is built **directly** from the kernel entry
   points: no registration, no global mutation.  The mirror inherits its
   simulator sibling's cycle/sparsity model and prices as the sibling.
3. Any other name is looked up in the live ``gemm_sims`` registry (so
   designs registered at runtime — including mirrors registered through the
   deprecated ``register_kernel_backends`` — stay resolvable), else a
   ValueError names the resolvable backends.

``block``/``interpret`` are kernel-only knobs: passing them for a simulated
design is an error rather than a silent no-op.
"""

from __future__ import annotations

import dataclasses

from repro.backends.base import GemmBackend
from repro.configs import paper_gemm
from repro.core import gemm_sims

__all__ = ["KERNEL_SIBLINGS", "PALLAS_SUFFIX", "available", "resolve",
           "mirror_design_spec"]

PALLAS_SUFFIX = "_pallas"
#: kernel-backed mirror name -> the simulated design it executes
KERNEL_SIBLINGS: dict[str, str] = {
    "tugemm" + PALLAS_SUFFIX: "tugemm",
    "tubgemm" + PALLAS_SUFFIX: "tubgemm",
}


def available() -> tuple[str, ...]:
    """Names :func:`resolve` accepts right now: live registry + Pallas mirrors."""
    names = list(gemm_sims.DESIGNS)
    names.extend(n for n in KERNEL_SIBLINGS if n not in names)
    return tuple(names)


def mirror_design_spec(name: str, *, block=None,
                       interpret: bool | None = None) -> gemm_sims.DesignSpec:
    """Build a Pallas-mirror :class:`~repro.core.gemm_sims.DesignSpec`.

    Pure construction — nothing is registered.  ``block`` is an optional
    (bm, bn, bk) kernel tile override; ``interpret`` forces Pallas interpret
    mode (None = auto: interpret off-TPU).  The returned spec shares the
    sibling's ``wc_cycles_fn`` / ``dyn_operand_fn`` / ``sparsity_aware`` /
    ``exact`` — one cost model, two execution engines.
    """
    from repro.kernels import ops  # deferred: pulls in Pallas

    sibling = KERNEL_SIBLINGS[name]
    sib = gemm_sims.get_design(sibling)
    fn = {"tugemm": ops.tu_matmul, "tubgemm": ops.tub_matmul}[sibling]
    kw: dict = {}
    if block is not None:
        kw["block"] = tuple(block)
    if interpret is not None:
        kw["interpret"] = interpret
    return dataclasses.replace(
        sib, name=name,
        # exact path drops the cycle report; stream path keeps (out, cycles)
        exact_fn=lambda a, b, bits, _fn=fn: _fn(a, b, bits=bits, **kw)[0],
        stream_fn=lambda a, b, bits, _fn=fn: _fn(a, b, bits=bits, **kw))


def _check_envelope_nonempty(name: str, bits: int) -> None:
    """Reject (design, bits) points whose accumulator envelope is empty.

    ``repro.analysis.ranges`` proves per-K safety at execute time; here we
    catch the degenerate widths where *no* contraction length is safe (e.g.
    a hypothetical ``ugemm`` at 24+ bits, whose 2^bits-slot counts already
    exceed the fp32 exact-integer window at K=1) at construction, where the
    error is cheapest to act on.  Designs without an accumulator model
    (custom registrations) pass — their numerics contract is their own.
    """
    from repro.analysis import ranges
    try:
        safe_k = ranges.max_safe_k(KERNEL_SIBLINGS.get(name, name), bits)
    except KeyError:
        return
    if safe_k < 1:
        raise ValueError(
            f"{name}@{bits}b has an empty accumulator envelope: even a K=1 "
            f"contraction exceeds its register capacity "
            f"(see repro.analysis.ranges.max_safe_k) — lower bits")


def resolve(spec: str | GemmBackend, *, bits: int | None = None,
            block=None, interpret: bool | None = None) -> GemmBackend:
    """Construct (or pass through) a :class:`GemmBackend`.

    ``spec`` — a backend instance or a design name; ``bits`` — operand
    bit-width (default 8, or the instance's own width); ``block`` /
    ``interpret`` — Pallas-mirror kernel knobs (error for simulated designs).
    Never mutates the ``gemm_sims`` registry.
    """
    if isinstance(spec, GemmBackend):
        backend = spec
        if block is not None or interpret is not None:
            # re-build by name so the knobs can apply; the knob not being
            # overridden is inherited from the instance
            return resolve(backend.name,
                           bits=backend.bits if bits is None else bits,
                           block=backend.block if block is None else block,
                           interpret=(backend.interpret if interpret is None
                                      else interpret))
        if bits is not None and int(bits) != backend.bits:
            backend = dataclasses.replace(backend, bits=int(bits))
            _check_envelope_nonempty(backend.name, backend.bits)
        return backend

    name = str(spec)
    bits = 8 if bits is None else int(bits)
    block = tuple(block) if block is not None else None
    is_mirror = name in KERNEL_SIBLINGS
    if (block is not None or interpret is not None) and not is_mirror:
        raise ValueError(
            f"block/interpret are Pallas-kernel knobs; {name!r} is not one of "
            f"the kernel mirrors {tuple(KERNEL_SIBLINGS)}")
    if is_mirror and (block is not None or interpret is not None
                      or name not in gemm_sims.DESIGNS):
        dspec = mirror_design_spec(name, block=block, interpret=interpret)
    elif name in gemm_sims.DESIGNS:
        dspec = gemm_sims.get_design(name)
    else:
        raise ValueError(
            f"unknown design {name!r}; resolvable backends: {available()}")
    _check_envelope_nonempty(name, bits)
    return GemmBackend(
        name=name, bits=bits, exact=dspec.exact,
        has_synthesis_data=name in paper_gemm.DESIGNS,
        pricing_design=KERNEL_SIBLINGS.get(name, name), spec=dspec,
        block=block, interpret=interpret)
