"""Backend construction: :func:`resolve` specs into :class:`GemmBackend`s.

Resolution rules (in order):

1. A :class:`GemmBackend` instance resolves to itself (re-widthed if ``bits``
   differs; re-built by name if kernel knobs ``block``/``interpret`` are
   given, so they can apply).
2. A Pallas mirror name (``tugemm_pallas`` / ``tubgemm_pallas``) with
   explicit ``block``/``interpret`` — or one absent from the live
   ``gemm_sims`` registry — is built **directly** from the kernel entry
   points: no registration, no global mutation.  The mirror inherits its
   simulator sibling's cycle/sparsity model and prices as the sibling.
3. The rate-coded stochastic family ``ugemm_stochastic`` — optionally
   spelled ``"ugemm_stochastic:<stream_len>"`` — builds a **pure** spec from
   ``repro.stochastic.sgemm`` closing over the stream length (default one
   full RNG period, ``2^bits``).  No registration; prices as ``ugemm``
   with ``stream_len / 2^bits`` cycle scaling (``GemmBackend.cycle_scale``).
4. Any other name is looked up in the live ``gemm_sims`` registry (so
   designs registered at runtime — including mirrors registered through the
   deprecated ``register_kernel_backends`` — stay resolvable), else a
   ValueError names the resolvable backends.

``block``/``interpret`` are kernel-only knobs; ``stream_len`` is a
stochastic-family knob: passing either for the wrong design is an error
rather than a silent no-op.
"""

from __future__ import annotations

import dataclasses

from repro.backends.base import GemmBackend
from repro.configs import paper_gemm
from repro.core import gemm_sims

__all__ = ["KERNEL_SIBLINGS", "PALLAS_SUFFIX", "STOCHASTIC_DESIGN",
           "available", "resolve", "mirror_design_spec"]

PALLAS_SUFFIX = "_pallas"
#: kernel-backed mirror name -> the simulated design it executes
KERNEL_SIBLINGS: dict[str, str] = {
    "tugemm" + PALLAS_SUFFIX: "tugemm",
    "tubgemm" + PALLAS_SUFFIX: "tubgemm",
}

#: the rate-coded bitstream family (repro.stochastic); prices as ugemm
STOCHASTIC_DESIGN = "ugemm_stochastic"


def available() -> tuple[str, ...]:
    """Names :func:`resolve` accepts right now: live registry + Pallas
    mirrors + the stochastic bitstream family."""
    names = list(gemm_sims.DESIGNS)
    names.extend(n for n in KERNEL_SIBLINGS if n not in names)
    if STOCHASTIC_DESIGN not in names:
        names.append(STOCHASTIC_DESIGN)
    return tuple(names)


def _parse_spec_string(name: str) -> tuple[str, int | None]:
    """Split ``"ugemm_stochastic:64"`` into ``(name, stream_len)``.

    Only the stochastic family takes a ``:<stream_len>`` suffix; a colon on
    any other name falls through to the unknown-design error in resolve.
    """
    head, sep, tail = name.partition(":")
    if sep and head == STOCHASTIC_DESIGN:
        try:
            return head, int(tail)
        except ValueError:
            raise ValueError(
                f"bad stream length {tail!r} in backend spec {name!r}; "
                f"expected {STOCHASTIC_DESIGN}:<int>") from None
    return name, None


def mirror_design_spec(name: str, *, block=None,
                       interpret: bool | None = None) -> gemm_sims.DesignSpec:
    """Build a Pallas-mirror :class:`~repro.core.gemm_sims.DesignSpec`.

    Pure construction — nothing is registered.  ``block`` is an optional
    (bm, bn, bk) kernel tile override; ``interpret`` forces Pallas interpret
    mode (None = auto: interpret off-TPU).  The returned spec shares the
    sibling's ``wc_cycles_fn`` / ``dyn_operand_fn`` / ``sparsity_aware`` /
    ``exact`` — one cost model, two execution engines.
    """
    from repro.kernels import ops  # deferred: pulls in Pallas

    sibling = KERNEL_SIBLINGS[name]
    sib = gemm_sims.get_design(sibling)
    fn = {"tugemm": ops.tu_matmul, "tubgemm": ops.tub_matmul}[sibling]
    kw: dict = {}
    if block is not None:
        kw["block"] = tuple(block)
    if interpret is not None:
        kw["interpret"] = interpret
    return dataclasses.replace(
        sib, name=name,
        # exact path drops the cycle report; stream path keeps (out, cycles)
        exact_fn=lambda a, b, bits, _fn=fn: _fn(a, b, bits=bits, **kw)[0],
        stream_fn=lambda a, b, bits, _fn=fn: _fn(a, b, bits=bits, **kw))


def _check_envelope_nonempty(name: str, bits: int,
                             stream_len: int | None = None) -> None:
    """Reject (design, bits) points whose accumulator envelope is empty.

    ``repro.analysis.ranges`` proves per-K safety at execute time; here we
    catch the degenerate widths where *no* contraction length is safe (e.g.
    a hypothetical ``ugemm`` at 24+ bits, whose 2^bits-slot counts already
    exceed the fp32 exact-integer window at K=1) at construction, where the
    error is cheapest to act on.  Designs without an accumulator model
    (custom registrations) pass — their numerics contract is their own.
    """
    from repro.analysis import ranges
    try:
        safe_k = ranges.max_safe_k(KERNEL_SIBLINGS.get(name, name), bits,
                                   stream_len=stream_len)
    except KeyError:
        return
    if safe_k < 1:
        raise ValueError(
            f"{name}@{bits}b has an empty accumulator envelope: even a K=1 "
            f"contraction exceeds its register capacity "
            f"(see repro.analysis.ranges.max_safe_k) — lower bits")


def resolve(spec: str | GemmBackend, *, bits: int | None = None,
            block=None, interpret: bool | None = None,
            stream_len: int | None = None) -> GemmBackend:
    """Construct (or pass through) a :class:`GemmBackend`.

    ``spec`` — a backend instance or a design name (the stochastic family
    also as ``"ugemm_stochastic:<stream_len>"``); ``bits`` — operand
    bit-width (default 8, or the instance's own width); ``block`` /
    ``interpret`` — Pallas-mirror kernel knobs (error for simulated
    designs); ``stream_len`` — rate-coded stream length (stochastic family
    only; default one full RNG period, ``2^bits``).  Never mutates the
    ``gemm_sims`` registry.
    """
    if isinstance(spec, GemmBackend):
        backend = spec
        if block is not None or interpret is not None \
                or stream_len is not None:
            # re-build by name so the knobs can apply; the knob not being
            # overridden is inherited from the instance
            return resolve(backend.name,
                           bits=backend.bits if bits is None else bits,
                           block=backend.block if block is None else block,
                           interpret=(backend.interpret if interpret is None
                                      else interpret),
                           stream_len=(backend.stream_len
                                       if stream_len is None else stream_len))
        if bits is not None and int(bits) != backend.bits:
            if backend.stream_len is not None:
                # a stream length tuned for one width is meaningless at
                # another — re-resolve with the new default period
                return resolve(backend.name, bits=int(bits))
            backend = dataclasses.replace(backend, bits=int(bits))
            _check_envelope_nonempty(backend.name, backend.bits)
        return backend

    name, spec_stream_len = _parse_spec_string(str(spec))
    if spec_stream_len is not None:
        if stream_len is not None and stream_len != spec_stream_len:
            raise ValueError(
                f"stream_len={stream_len} conflicts with the spec string "
                f"{spec!r}")
        stream_len = spec_stream_len
    bits = 8 if bits is None else int(bits)
    block = tuple(block) if block is not None else None
    is_mirror = name in KERNEL_SIBLINGS
    is_stochastic = name == STOCHASTIC_DESIGN and name not in gemm_sims.DESIGNS
    if (block is not None or interpret is not None) and not is_mirror:
        raise ValueError(
            f"block/interpret are Pallas-kernel knobs; {name!r} is not one of "
            f"the kernel mirrors {tuple(KERNEL_SIBLINGS)}")
    if stream_len is not None and not is_stochastic:
        raise ValueError(
            f"stream_len is a {STOCHASTIC_DESIGN!r} knob; {name!r} is "
            f"count-exact per design (its slot count is not plannable)")
    if is_mirror and (block is not None or interpret is not None
                      or name not in gemm_sims.DESIGNS):
        dspec = mirror_design_spec(name, block=block, interpret=interpret)
    elif is_stochastic:
        from repro.stochastic import sgemm  # deferred: pulls in the engine
        if stream_len is None:
            stream_len = sgemm.default_stream_len(bits)
        dspec = sgemm.stochastic_design_spec(stream_len)
    elif name in gemm_sims.DESIGNS:
        dspec = gemm_sims.get_design(name)
    else:
        raise ValueError(
            f"unknown design {name!r}; resolvable backends: {available()}")
    _check_envelope_nonempty(name, bits, stream_len=stream_len)
    return GemmBackend(
        name=name, bits=bits, exact=dspec.exact,
        has_synthesis_data=name in paper_gemm.DESIGNS,
        pricing_design=("ugemm" if is_stochastic
                        else KERNEL_SIBLINGS.get(name, name)),
        spec=dspec, block=block, interpret=interpret, stream_len=stream_len)
