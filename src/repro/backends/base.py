"""The :class:`GemmBackend` object: one GEMM engine at a fixed bit-width.

A backend bundles, behind one typed interface, everything the rest of the
stack previously reached for through string keys into the mutable
``gemm_sims`` registry:

* **execution** — :meth:`GemmBackend.execute` (fast functional GEMM, 2-D or
  batched, jit-/vmap-friendly) and :meth:`GemmBackend.stream` (cycle-faithful
  simulation returning ``(out, cycles)``);
* **cost** — :meth:`GemmBackend.cycles` (worst case), :meth:`GemmBackend.dyn_cycles`
  (Eq. 1 from a sparsity statistic, or operand-driven from a concrete
  quantized tile) and :meth:`GemmBackend.price` (a whole model workload on
  ``core.accounting``'s DLA tiling);
* **metadata** — ``name``, ``bits``, ``exact`` (deterministic integer result,
  bit-identical to the binary oracle) and ``has_synthesis_data`` (the paper
  published post-synthesis PPA for this design under its own name).

Backends are immutable values: constructing one never mutates any global
registry, two backends with the same construction arguments compare equal,
and a backend captured by a jitted function is a trace-time constant.
Construct them with :func:`repro.backends.resolve`.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.analysis import ranges
from repro.core import gemm_sims

__all__ = ["GemmBackend"]


@dataclasses.dataclass(frozen=True)
class GemmBackend:
    """A GEMM execution engine (simulated or Pallas) at a fixed bit-width.

    ``pricing_design`` is the calibrated design name :meth:`price`,
    :meth:`cycles` and :meth:`dyn_cycles` charge against — the backend's own
    name for the four paper designs, the simulator sibling for the Pallas
    mirrors (one cost model, two execution engines).

    Equality/hash compare the construction arguments (name, bits, kernel
    knobs, metadata), not engine identity: two backends resolved from the
    same arguments compare equal.  The converse caveat: a design
    re-registered under an existing name (``register_design(...,
    overwrite=True)`` inside a ``scoped_registry``) resolves to a backend
    that still compares equal to the stock one — don't key caches by
    backend across registry mutations.
    """

    name: str
    bits: int
    exact: bool
    has_synthesis_data: bool
    pricing_design: str
    # Execution engine.  Excluded from equality/hash: mirror specs hold
    # per-resolve closures, and the value identity of a backend is fully
    # determined by the fields above plus the kernel knobs below.
    spec: gemm_sims.DesignSpec = dataclasses.field(repr=False, compare=False)
    # Pallas-kernel knobs the spec was built with (None for simulated
    # designs and for registry-resolved mirrors, whose knobs are baked in).
    block: tuple | None = None
    interpret: bool | None = None
    # Rate-coded stream length (the ``ugemm_stochastic`` family's
    # accuracy/energy knob); None for every count-exact design.
    stream_len: int | None = None

    def __post_init__(self) -> None:
        if self.bits < 2:
            raise ValueError(f"bits must be >= 2, got {self.bits}")
        if self.stream_len is not None and self.stream_len < 1:
            raise ValueError(
                f"stream_len must be >= 1, got {self.stream_len}")

    # -- execution ----------------------------------------------------------

    def execute(self, a: jax.Array, b: jax.Array) -> jax.Array:
        """Functional GEMM on already-quantized integer codes.

        ``a``: (M, K) codes, or (B, M, K) for a batch of problems; ``b``:
        (K, N), or (B, K, N) per-problem, or (K, N) shared across the batch
        (the weight-stationary serving case).  Returns (…, M, N) — int32 for
        exact designs, float32 estimate for stochastic uGEMM.  Traceable:
        safe to call under ``jax.jit`` / ``jax.vmap``.

        Raises ``ValueError`` when the contraction length leaves the
        design's validated accumulator envelope (uGEMM's fp32 exact-count
        window ``L*K < 2^24``, int32 partial sums for the exact designs)
        — shapes are static, so the guard costs nothing under tracing.
        """
        self._guard_envelope(a.shape[-1])
        if a.ndim == 2:
            return self.spec.exact_fn(a, b, self.bits)
        if a.ndim != 3:
            raise ValueError(
                f"execute wants (M, K) or (B, M, K) operands, got {a.shape}")
        fn = lambda x, y: self.spec.exact_fn(x, y, self.bits)  # noqa: E731
        return jax.vmap(fn, in_axes=(0, 0 if b.ndim == 3 else None))(a, b)

    def stream(self, a: jax.Array, b: jax.Array):
        """Cycle-faithful simulation (or kernel run): ``(out, cycles)``.

        ``cycles`` equals :meth:`cycles` of the contraction length — the
        simulated schedules are worst-case.  Same accumulator-envelope
        guard as :meth:`execute` (the streamed registers are the model).
        """
        self._guard_envelope(a.shape[-1])
        return self.spec.stream_fn(a, b, self.bits)

    def _guard_envelope(self, k: int) -> None:
        """Static numeric-safety check (see ``repro.analysis.ranges``)."""
        # Stream-coded backends check their own stream-aware envelope (the
        # per-step count is the stream length, not the pricing design's
        # 2^bits slots); everything else checks as the design it prices as.
        design = self.name if self.stream_len is not None \
            else self.pricing_design
        ranges.assert_within_envelope(design, self.bits, int(k),
                                      where=f"backend {self.name}",
                                      stream_len=self.stream_len)

    # -- cost ---------------------------------------------------------------

    @property
    def cycle_scale(self) -> float:
        """Per-tile cycle multiplier vs ``pricing_design``'s wc formula.

        1.0 for every design priced under its own name.  The stochastic
        family prices as uGEMM (identical rate-coded datapath power;
        k-independent cycles) with ``stream_len / 2^bits`` scaling — energy
        and latency are linear in slot count.
        """
        if self.stream_len is None:
            return 1.0
        return self.stream_len / float(2 ** self.bits)

    def cycles(self, common_dim: int) -> int:
        """Worst-case clock cycles for one GEMM streaming over ``common_dim``."""
        return self.spec.wc_cycles_fn(self.bits, common_dim)

    def dyn_cycles(self, common_dim: int | None = None, *,
                   bit_sparsity: float | None = None,
                   operand=None) -> float:
        """Dynamic (early-terminating) cycles for one GEMM.

        Exactly one source of dynamism:

        * ``operand`` — a concrete quantized temporal-operand tile, shape
          (K, n) or (K,); cycles follow the per-outer-product-step max
          magnitudes (the largest value in flight gates every lane).
        * ``bit_sparsity`` — paper Eq. 1: ``wc * (1 - bit_sparsity)``
          (requires ``common_dim``; only sparsity-aware designs benefit).
        * neither — worst case (requires ``common_dim``).
        """
        if operand is not None:
            if bit_sparsity is not None:
                raise ValueError("pass either operand or bit_sparsity, not both")
            q = jnp.asarray(operand, jnp.int32)
            if q.ndim == 1:
                q = q[:, None]
            k = q.shape[0]
            if self.spec.dyn_operand_fn is None:
                return float(self.spec.wc_cycles_fn(self.bits, k))
            step_max = jnp.max(jnp.abs(q), axis=tuple(range(1, q.ndim)))
            return float(self.spec.dyn_operand_fn(self.bits, step_max))
        if common_dim is None:
            raise ValueError("common_dim is required without an operand")
        wc = self.cycles(common_dim)
        if bit_sparsity is not None and self.spec.sparsity_aware:
            return wc * (1.0 - float(bit_sparsity))
        return float(wc)

    def price(self, workload, *, unit_n: int = 128, num_units: int = 1):
        """Price a model workload on a DLA built from this design.

        ``workload`` — a list of ``core.accounting.GemmCall`` or a
        ``GemmWorkloadRecorder``.  Returns a ``core.accounting.ModelCost``.
        Pallas mirrors price as their simulator sibling (same silicon, same
        schedule — a different execution engine doesn't change PPA); designs
        with no paper calibration raise ppa's "no PPA calibration" error.
        """
        from repro.core import accounting
        calls = getattr(workload, "calls", workload)
        return accounting.price_workload(calls, design=self, bits=self.bits,
                                         unit_n=unit_n, num_units=num_units)
