"""First-class GEMM backend API: typed, scoped backend objects and plans.

One import surface for everything backend-shaped:

    from repro import backends

    b = backends.resolve("tubgemm", bits=4)        # typed, immutable
    out = b.execute(a_q, w_q)                      # run the int GEMM
    out, cyc = b.stream(a_q, w_q)                  # cycle-faithful sim/kernel
    cost = b.price(recorder.calls, unit_n=128)     # whole-model PPA
    with backends.use_backend(b):                  # execute the *model* on it
        logits, _ = model.forward(params, cfg, tokens)

    plan = backends.BackendPlan.load("reports/plan.json")
    with backends.use_plan(plan):                  # per-site mixed precision
        logits, _ = model.forward(params, cfg, tokens)

See ``docs/BACKENDS.md`` for the protocol, resolve rules and scoping
semantics, and ``docs/PLANNER.md`` for the plan file format, site-pattern
matching rules and how ``repro.eval.planner`` derives plans.
"""

from repro.backends.base import GemmBackend
from repro.backends.grid import (GridBackend, GridPlan, as_grid,
                                 grid_matrix_cycles, load_plan, parse_grid,
                                 shard_site, shard_slices)
from repro.backends.plan import BackendPlan, SiteAssignment
from repro.backends.registry import (KERNEL_SIBLINGS, PALLAS_SUFFIX,
                                     available, mirror_design_spec, resolve)
from repro.backends.runtime import (BackendExecution, ExecutedGemm,
                                    PlanExecution, SiteRecorder,
                                    active_backend, active_execution,
                                    current_site, measure_matrix_cycles,
                                    pack_weights, record_sites, site_scope,
                                    use_backend, use_plan)

__all__ = [
    "GemmBackend",
    "GridBackend",
    "GridPlan",
    "BackendPlan",
    "SiteAssignment",
    "KERNEL_SIBLINGS",
    "PALLAS_SUFFIX",
    "as_grid",
    "available",
    "grid_matrix_cycles",
    "load_plan",
    "mirror_design_spec",
    "parse_grid",
    "resolve",
    "shard_site",
    "shard_slices",
    "BackendExecution",
    "PlanExecution",
    "SiteRecorder",
    "ExecutedGemm",
    "active_backend",
    "active_execution",
    "current_site",
    "measure_matrix_cycles",
    "pack_weights",
    "record_sites",
    "site_scope",
    "use_backend",
    "use_plan",
]
