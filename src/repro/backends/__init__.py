"""First-class GEMM backend API: typed, scoped backend objects.

One import surface for everything backend-shaped:

    from repro import backends

    b = backends.resolve("tubgemm", bits=4)        # typed, immutable
    out = b.execute(a_q, w_q)                      # run the int GEMM
    out, cyc = b.stream(a_q, w_q)                  # cycle-faithful sim/kernel
    cost = b.price(recorder.calls, unit_n=128)     # whole-model PPA
    with backends.use_backend(b):                  # execute the *model* on it
        logits, _ = model.forward(params, cfg, tokens)

See ``docs/BACKENDS.md`` for the protocol, resolve rules, scoping semantics
and the migration table from the deprecated string-registry calls.
"""

from repro.backends.base import GemmBackend
from repro.backends.registry import (KERNEL_SIBLINGS, PALLAS_SUFFIX,
                                     available, mirror_design_spec, resolve)
from repro.backends.runtime import (BackendExecution, ExecutedGemm,
                                    active_backend, active_execution,
                                    use_backend)

__all__ = [
    "GemmBackend",
    "KERNEL_SIBLINGS",
    "PALLAS_SUFFIX",
    "available",
    "mirror_design_spec",
    "resolve",
    "BackendExecution",
    "ExecutedGemm",
    "active_backend",
    "active_execution",
    "use_backend",
]
