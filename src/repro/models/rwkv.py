"""RWKV6 ("Finch") blocks: data-dependent decay WKV, chunked for matmuls.

Time-mix recurrence per head (K = V = head_dim):

    y_t = r_t · (S_{t-1} + diag(u) k_t v_tᵀ)
    S_t = diag(w_t) S_{t-1} + k_t v_tᵀ,   w_t = exp(-exp(w0 + LoRA(x_t)))

evaluated chunkwise: within a chunk the pairwise weights
``exp(Lc_{t-1} - Lc_j)`` (cumulative log-decay differences, always ≤ 0)
factor into query/key exponentials, giving (Q, Q) score matmuls; across
chunks a short scan carries the (B, H, K, V) state.  Exponents are clamped to
±``EXP_CLAMP`` — pairs whose true weight is below e^-2·clamp are numerically
zero anyway (validated against the recurrent oracle in tests).

Decode is O(1): state + one-token shift buffers, which is what makes the
``long_500k`` shape runnable for this attention-free arch.

Simplifications vs. the released checkpoints (noted in DESIGN.md): token-shift
mixing coefficients are static (the decay LoRA — the defining Finch feature —
*is* data-dependent); LayerNorm is used in both sub-blocks as in the paper.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.backends.runtime import site_scope
from repro.models.common import ParamDef, dense, shard
from repro.models.config import ModelConfig

__all__ = ["rwkv_defs", "rwkv_block_fwd", "init_rwkv_cache",
           "wkv_chunked", "wkv_recurrent_ref"]

EXP_CLAMP = 20.0
CHUNK = 32


def _dims(cfg: ModelConfig):
    k = cfg.rwkv.head_dim
    h = cfg.d_model // k
    return h, k


def rwkv_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    h, k = _dims(cfg)
    r = cfg.rwkv.decay_lora
    return {
        "ln1_s": ParamDef((d,), ("embed",), init="ones"),
        "ln1_b": ParamDef((d,), ("embed",), init="zeros"),
        "ln2_s": ParamDef((d,), ("embed",), init="ones"),
        "ln2_b": ParamDef((d,), ("embed",), init="zeros"),
        "tm": {
            "mu_r": ParamDef((d,), ("embed",), init="zeros"),
            "mu_k": ParamDef((d,), ("embed",), init="zeros"),
            "mu_v": ParamDef((d,), ("embed",), init="zeros"),
            "mu_w": ParamDef((d,), ("embed",), init="zeros"),
            "mu_g": ParamDef((d,), ("embed",), init="zeros"),
            "w_r": ParamDef((d, h, k), ("embed", "heads", "head_dim")),
            "w_k": ParamDef((d, h, k), ("embed", "heads", "head_dim")),
            "w_v": ParamDef((d, h, k), ("embed", "heads", "head_dim")),
            "w_g": ParamDef((d, h, k), ("embed", "heads", "head_dim")),
            "w0": ParamDef((h, k), ("heads", "head_dim"), init="ssm_dt"),
            "wa": ParamDef((d, r), ("embed", "lora")),
            "wb": ParamDef((r, h, k), ("lora", "heads", "head_dim"), init="zeros"),
            "u": ParamDef((h, k), ("heads", "head_dim"), init="zeros"),
            "gn_s": ParamDef((d,), ("embed",), init="ones"),
            "gn_b": ParamDef((d,), ("embed",), init="zeros"),
            "w_o": ParamDef((h, k, d), ("heads", "head_dim", "embed"),
                            fan_in_axes=(0, 1)),
        },
        "cm": {
            "mu_k": ParamDef((d,), ("embed",), init="zeros"),
            "mu_r": ParamDef((d,), ("embed",), init="zeros"),
            "w_k": ParamDef((d, cfg.d_ff), ("embed", "mlp")),
            "w_v": ParamDef((cfg.d_ff, d), ("mlp", "embed")),
            "w_r": ParamDef((d, d), ("embed", "embed")),
        },
    }


def init_rwkv_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> dict:
    h, k = _dims(cfg)
    return {
        "state": jnp.zeros((batch, h, k, k), jnp.float32),
        "tm_last": jnp.zeros((batch, cfg.d_model), dtype),
        "cm_last": jnp.zeros((batch, cfg.d_model), dtype),
    }


def _layernorm(x, s, b, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * lax.rsqrt(var + eps)
    return (y * s.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def _group_norm(x, s, b, n_heads, eps=1e-5):
    """Per-head normalization of (B, S, H*K)."""
    bsz, slen, d = x.shape
    xh = x.reshape(bsz, slen, n_heads, d // n_heads).astype(jnp.float32)
    mu = jnp.mean(xh, axis=-1, keepdims=True)
    var = jnp.var(xh, axis=-1, keepdims=True)
    y = ((xh - mu) * lax.rsqrt(var + eps)).reshape(bsz, slen, d)
    return (y * s.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def _token_shift(x, mu, last=None):
    """mix x_t with x_{t-1}: x + mu * (x_{t-1} - x_t).  last: (B, D)."""
    if last is None:
        prev = jnp.pad(x[:, :-1], ((0, 0), (1, 0), (0, 0)))
    else:
        prev = jnp.concatenate([last[:, None].astype(x.dtype), x[:, :-1]], axis=1)
    return x + mu.astype(x.dtype) * (prev - x)


# ---------------------------------------------------------------------------
# WKV core
# ---------------------------------------------------------------------------

def wkv_recurrent_ref(r, k, v, logw, u, init_state=None):
    """Oracle.  r/k/v: (B,S,H,K); logw: (B,S,H,K) (≤0); u: (H,K)."""
    b, s, h, kk = r.shape
    state = (jnp.zeros((b, h, kk, kk), jnp.float32) if init_state is None
             else init_state)

    def step(state, t):
        rt = r[:, t].astype(jnp.float32)
        kt = k[:, t].astype(jnp.float32)
        vt = v[:, t].astype(jnp.float32)
        wt = jnp.exp(logw[:, t].astype(jnp.float32))
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        y = jnp.einsum("bhk,bhkv->bhv", rt, state + u[None, :, :, None] * kv)
        state = state * wt[..., None] + kv
        return state, y

    state, ys = lax.scan(step, state, jnp.arange(s))
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype), state


def wkv_chunked(r, k, v, logw, u, chunk: int = CHUNK, init_state=None):
    """Chunked WKV; same semantics as the oracle."""
    b, s, h, kk = r.shape
    if s % chunk:
        pad = chunk - s % chunk
        zpad = ((0, 0), (0, pad), (0, 0), (0, 0))
        r, k, v = (jnp.pad(t, zpad) for t in (r, k, v))
        logw = jnp.pad(logw, zpad)   # log w = 0 -> w = 1 for padding (harmless)
    sp = r.shape[1]
    nc = sp // chunk
    f32 = jnp.float32
    rc = r.reshape(b, nc, chunk, h, kk).astype(f32)
    kc = k.reshape(b, nc, chunk, h, kk).astype(f32)
    vc = v.reshape(b, nc, chunk, h, kk).astype(f32)
    lw = logw.reshape(b, nc, chunk, h, kk).astype(f32)

    # inclusive cumsum as a triangular matmul (see ssm.ssd_chunked: the
    # associative-scan lowering of jnp.cumsum thrashes HBM inside layer scans)
    tril = jnp.tril(jnp.ones((chunk, chunk), f32))
    lc = jnp.einsum("qt,bcthk->bcqhk", tril, lw)    # inclusive cumsum (B,C,Q,H,K)
    lc_prev = lc - lw                                # Lc_{t-1} (exclusive)
    total = lc[:, :, -1]                             # (B,C,H,K)

    clamp = lambda e: jnp.clip(e, -EXP_CLAMP, EXP_CLAMP)
    r_tilde = rc * jnp.exp(clamp(lc_prev))           # query side
    k_tilde = kc * jnp.exp(clamp(-lc))               # key side
    k_carry = kc * jnp.exp(clamp(total[:, :, None] - lc))  # decay to chunk end

    idx = jnp.arange(chunk)
    strict = (idx[:, None] > idx[None, :])[None, None, None]   # (1,1,1,Q,Q) t>j

    scores = jnp.einsum("bcthk,bcjhk->bchtj", r_tilde, k_tilde)
    scores = jnp.where(strict, scores, 0.0)
    y_intra = jnp.einsum("bchtj,bcjhv->bcthv", scores, vc)

    diag = jnp.einsum("bcthk,hk,bcthk->bcth", rc, u.astype(f32), kc)
    y_intra = y_intra + diag[..., None] * vc

    chunk_state = jnp.einsum("bcjhk,bcjhv->bchkv", k_carry, vc)
    chunk_decay = jnp.exp(total)                     # (B,C,H,K)

    state0 = (jnp.zeros((b, h, kk, kk), f32) if init_state is None
              else init_state.astype(f32))

    def chunk_step(state, inp):
        cs, cd = inp
        prev = state
        state = state * cd[..., None] + cs
        return state, prev

    final_state, prev_states = lax.scan(
        chunk_step, state0,
        (jnp.moveaxis(chunk_state, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)    # (B,C,H,K,V)

    y_inter = jnp.einsum("bcthk,bchkv->bcthv", r_tilde, prev_states)
    y = (y_intra + y_inter).reshape(b, sp, h, kk)[:, :s]
    return y.astype(r.dtype), final_state


# ---------------------------------------------------------------------------
# Full block
# ---------------------------------------------------------------------------

def rwkv_block_fwd(params: dict, x: jax.Array, cfg: ModelConfig, *,
                   cache: dict | None = None):
    """Full RWKV6 block (time-mix + channel-mix).  x: (B, S, D)."""
    h, kdim = _dims(cfg)
    tm, cm = params["tm"], params["cm"]
    new_cache = dict(cache) if cache is not None else None

    # ---- time mix -----------------------------------------------------
    xn = _layernorm(x, params["ln1_s"], params["ln1_b"])
    last = cache["tm_last"] if cache is not None else None
    xr = _token_shift(xn, tm["mu_r"], last)
    xk = _token_shift(xn, tm["mu_k"], last)
    xv = _token_shift(xn, tm["mu_v"], last)
    xw = _token_shift(xn, tm["mu_w"], last)
    xg = _token_shift(xn, tm["mu_g"], last)

    with site_scope("tm"):
        r = dense(tm["w_r"], xr, cfg, name="w_r")      # (B,S,H,K)
        k = dense(tm["w_k"], xk, cfg, name="w_k")
        v = dense(tm["w_v"], xv, cfg, name="w_v")
        g = jax.nn.silu(dense(tm["w_g"], xg, cfg, name="w_g"))
    r = shard(r, "batch", None, "heads", "head_dim")
    k = shard(k, "batch", None, "heads", "head_dim")
    v = shard(v, "batch", None, "heads", "head_dim")

    # data-dependent decay (the Finch LoRA)
    lora = jnp.einsum("bsd,dr->bsr", jnp.tanh(xw.astype(jnp.float32)),
                      tm["wa"].astype(jnp.float32))
    ddd = jnp.einsum("bsr,rhk->bshk", lora, tm["wb"].astype(jnp.float32))
    logw = -jnp.exp(jnp.clip(tm["w0"].astype(jnp.float32)[None, None] + ddd,
                             -8.0, 8.0))            # per-step log decay ≤ 0

    state0 = cache["state"] if cache is not None else None
    if x.shape[1] == 1 and cache is not None:
        y, state = wkv_recurrent_ref(r, k, v, logw, tm["u"], init_state=state0)
    else:
        y, state = wkv_chunked(r, k, v, logw, tm["u"], init_state=state0)
    y = y.reshape(x.shape[0], x.shape[1], -1)
    y = _group_norm(y, tm["gn_s"], tm["gn_b"], h)
    y = y * g.reshape(y.shape)
    att = jnp.einsum("bshk,hkd->bsd", y.reshape(*x.shape[:2], h, kdim),
                     tm["w_o"].astype(y.dtype))
    x = x + shard(att, "batch", None, None)

    # ---- channel mix ----------------------------------------------------
    xn2 = _layernorm(x, params["ln2_s"], params["ln2_b"])
    last2 = cache["cm_last"] if cache is not None else None
    xk2 = _token_shift(xn2, cm["mu_k"], last2)
    xr2 = _token_shift(xn2, cm["mu_r"], last2)
    with site_scope("cm"):
        kk = jnp.square(jax.nn.relu(dense(cm["w_k"], xk2, cfg, name="w_k")))
        kk = shard(kk, "batch", None, "mlp")
        vv = dense(cm["w_v"], kk, cfg, name="w_v")
        rr = jax.nn.sigmoid(dense(cm["w_r"], xr2, cfg, name="w_r"))
    x = x + shard(rr * vv, "batch", None, None)

    if cache is not None:
        new_cache = {"state": state, "tm_last": xn[:, -1], "cm_last": xn2[:, -1]}
    return x, new_cache
