"""Mixture-of-Experts with expert parallelism over the ``model`` mesh axis.

Baseline EP ("psum"): expert weights are sharded over ``model`` inside a
``shard_map``; every rank routes the *same* (data-sharded, model-replicated)
tokens, computes only its local experts' contributions via capacity-bounded
gather -> FFN -> weighted scatter-add, and a single ``psum`` over ``model``
combines.  One (T_local, D) all-reduce per MoE layer — simple and robust.

Optimized EP ("a2a"): tokens are exchanged with ``all_to_all`` so each rank
runs its experts on a (E_local * C, D) buffer instead of scoring all tokens,
replacing the big combine all-reduce with two smaller all-to-alls.  This is a
§Perf hillclimb lever; both paths produce identical outputs when capacity is
not exceeded.

Routing: softmax (Switch/Mixtral) or sigmoid (DeepSeek-V3) scoring, top-k with
renormalization, optional shared (always-on) experts, and a Switch-style
load-balance auxiliary loss.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.models.common import ParamDef, shard
from repro.models.config import ModelConfig
from repro.backends.runtime import site_scope
from repro.models.mlp import mlp_defs, mlp_fwd

__all__ = ["moe_defs", "moe_fwd"]


def moe_defs(cfg: ModelConfig) -> dict:
    assert cfg.moe is not None
    m, d = cfg.moe, cfg.d_model
    ffe = m.d_ff_expert
    defs = {
        "router": ParamDef((d, m.num_experts), ("embed", "experts")),
        "w_gate": ParamDef((m.num_experts, d, ffe), ("experts", "embed", "expert_mlp"),
                           fan_in_axes=(1,)),
        "w_up": ParamDef((m.num_experts, d, ffe), ("experts", "embed", "expert_mlp"),
                         fan_in_axes=(1,)),
        "w_down": ParamDef((m.num_experts, ffe, d), ("experts", "expert_mlp", "embed"),
                           fan_in_axes=(1,)),
    }
    if m.num_shared_experts:
        defs["shared"] = mlp_defs(cfg, d_ff=m.num_shared_experts * ffe)
    return defs


def _routing(router_w, x_flat, cfg: ModelConfig, scoring: str = "softmax"):
    """-> (topk_idx (T,K), topk_w (T,K), probs (T,E))."""
    m = cfg.moe
    logits = jnp.matmul(x_flat.astype(jnp.float32),
                        router_w.astype(jnp.float32))          # (T, E)
    if scoring == "sigmoid":
        probs = jax.nn.sigmoid(logits)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
    topk_w, topk_idx = lax.top_k(probs, m.top_k)
    topk_w = topk_w / jnp.maximum(jnp.sum(topk_w, axis=-1, keepdims=True), 1e-9)
    return topk_idx, topk_w, probs


def _capacity(t_local: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    c = math.ceil(t_local * m.top_k / m.num_experts * m.capacity_factor)
    return min(t_local, max(4, c))


def _local_expert_pass(x_flat, topk_idx, topk_w, wg, wu, wd, cfg: ModelConfig,
                       first_global_expert):
    """Capacity-gather each local expert's tokens, FFN, weighted scatter-add.

    x_flat: (T, D);  wg/wu/wd: (E_local, ...) local expert stacks.
    Returns the summed contribution (T, D) of the local experts.
    """
    t_local, d = x_flat.shape
    e_local = wg.shape[0]
    cap = _capacity(t_local, cfg)

    def one_expert(acc, inputs):
        w_g, w_u, w_d, local_e = inputs
        global_e = first_global_expert + local_e
        # per-token weight for this expert (0 if not routed here)
        hit = (topk_idx == global_e)                         # (T, K)
        w_tok = jnp.sum(jnp.where(hit, topk_w, 0.0), axis=-1)  # (T,)
        sel_w, sel_idx = lax.top_k(w_tok, cap)               # capacity selection
        xs = jnp.take(x_flat, sel_idx, axis=0)               # (C, D)
        h = jax.nn.silu(jnp.matmul(xs, w_g.astype(xs.dtype))) * jnp.matmul(
            xs, w_u.astype(xs.dtype))
        y = jnp.matmul(h, w_d.astype(xs.dtype))              # (C, D)
        y = y * sel_w[:, None].astype(y.dtype)               # weight (0 for non-routed)
        acc = acc.at[sel_idx].add(y)
        return acc, None

    acc0 = jnp.zeros_like(x_flat)
    acc, _ = lax.scan(one_expert, acc0,
                      (wg, wu, wd, jnp.arange(e_local)))
    return acc


def _aux_loss(probs, topk_idx, cfg: ModelConfig):
    """Switch-style load-balance loss: E * sum_e f_e * p_e."""
    m = cfg.moe
    e = m.num_experts
    hits = jax.nn.one_hot(topk_idx[..., 0], e, dtype=jnp.float32)  # primary expert
    f = jnp.mean(hits, axis=0)
    p = jnp.mean(probs, axis=0)
    return e * jnp.sum(f * p)


def _current_mesh():
    env = jax.interpreters.pxla.thread_resources.env
    mesh = env.physical_mesh
    return None if mesh.empty else mesh


def moe_fwd(params: dict, x: jax.Array, cfg: ModelConfig,
            scoring: str = "softmax"):
    """x: (B, S, D) -> (out (B, S, D), aux_loss scalar)."""
    m = cfg.moe
    b, s, d = x.shape
    x_flat = x.reshape(-1, d)
    mesh = _current_mesh()
    use_ep = (mesh is not None and "model" in mesh.axis_names
              and mesh.shape["model"] > 1 and m.num_experts % mesh.shape["model"] == 0)

    if use_ep:
        n_model = mesh.shape["model"]
        a2a_ok = (m.ep_impl == "a2a" and x_flat.shape[0] % n_model == 0
                  and x_flat.shape[0] >= n_model * n_model)
        if a2a_ok:
            out_flat, aux = _moe_ep_a2a(params, x_flat, cfg, mesh, scoring)
        else:
            out_flat, aux = _moe_ep_psum(params, x_flat, cfg, mesh, scoring)
    else:
        topk_idx, topk_w, probs = _routing(params["router"], x_flat, cfg, scoring)
        out_flat = _local_expert_pass(x_flat, topk_idx, topk_w, params["w_gate"],
                                      params["w_up"], params["w_down"], cfg, 0)
        aux = _aux_loss(probs, topk_idx, cfg)

    out = out_flat.reshape(b, s, d)
    if m.num_shared_experts:
        # site path matches the param tree ("…/moe/shared/w_up"); the routed
        # experts' batched einsums are not dense sites and stay float under
        # backend/plan scopes (see docs/PLANNER.md coverage notes)
        with site_scope("shared"):
            out = out + mlp_fwd(params["shared"], x, cfg)
    return shard(out, "batch", None, None), aux


def _batch_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _moe_ep_psum(params, x_flat, cfg: ModelConfig, mesh, scoring):
    m = cfg.moe
    baxes = _batch_axes(mesh)

    def block(router_w, wg, wu, wd, xb):
        rank = lax.axis_index("model")
        e_local = wg.shape[0]
        topk_idx, topk_w, probs = _routing(router_w, xb, cfg, scoring)
        contrib = _local_expert_pass(xb, topk_idx, topk_w, wg, wu, wd, cfg,
                                     rank * e_local)
        out = lax.psum(contrib, "model")
        aux = _aux_loss(probs, topk_idx, cfg)   # identical on every rank
        return out, aux

    fn = shard_map(
        block, mesh=mesh,
        in_specs=(P(), P("model"), P("model"), P("model"), P(baxes)),
        out_specs=(P(baxes), P()),
        check_vma=False)
    return fn(params["router"], params["w_gate"], params["w_up"],
              params["w_down"], x_flat)


def _moe_ep_a2a(params, x_flat, cfg: ModelConfig, mesh, scoring):
    """All-to-all dispatch EP (§Perf optimized variant).

    Per rank: route local tokens, build (E, C_out) send buffers, all_to_all to
    expert owners, run local experts on (ranks * E_local * C_out) rows,
    all_to_all back, weighted scatter-add.  Collective volume:
    2 * E * C_out * D per rank vs. psum's T_local * D all-reduce.
    """
    m = cfg.moe
    baxes = _batch_axes(mesh)
    n_model = mesh.shape["model"]

    def block(router_w, wg, wu, wd, xb):
        rank = lax.axis_index("model")
        t_local, d = xb.shape
        e = m.num_experts
        e_local = e // n_model
        # Each model-rank handles a distinct slice of the data-parallel tokens
        # (tokens arrive replicated over 'model'; slice so ranks don't repeat
        # work, at the price of an extra gather at the end).
        t_slice = t_local // n_model
        xb_my = lax.dynamic_slice_in_dim(xb, rank * t_slice, t_slice, 0)
        topk_idx, topk_w, probs = _routing(router_w, xb_my, cfg, scoring)
        cap = _capacity(t_slice, cfg)

        # Build per-expert send buffers (E, C, D) + weights + source rows.
        w_tok = jnp.zeros((t_slice, e), xb.dtype)
        w_tok = jax.vmap(lambda wt, ti, tw: wt.at[ti].add(tw))(
            w_tok, topk_idx, topk_w.astype(xb.dtype))        # (T_s, E)
        sel_w, sel_idx = lax.top_k(w_tok.T, cap)              # (E, C)
        send = jnp.take(xb_my, sel_idx.reshape(-1), axis=0).reshape(e, cap, d)
        # (E, C, D) -> regroup as (n_model, E_local, C, D) and exchange.
        send = send.reshape(n_model, e_local, cap, d)
        recv = lax.all_to_all(send, "model", split_axis=0, concat_axis=0,
                              tiled=False)                    # (n_model, E_local, C, D)
        recv = jnp.moveaxis(recv, 1, 0)                       # (E_local, n_model, C, D)
        recv = recv.reshape(e_local, n_model * cap, d)

        def run_expert(args):
            w_g, w_u, w_d, xs = args
            h = jax.nn.silu(jnp.matmul(xs, w_g.astype(xs.dtype))) * jnp.matmul(
                xs, w_u.astype(xs.dtype))
            return jnp.matmul(h, w_d.astype(xs.dtype))

        ys = jax.vmap(lambda w_g, w_u, w_d, xs: run_expert((w_g, w_u, w_d, xs)))(
            wg, wu, wd, recv)                                 # (E_local, n_model*C, D)
        ys = ys.reshape(e_local, n_model, cap, d)
        ys = jnp.moveaxis(ys, 1, 0)                           # (n_model, E_local, C, D)
        back = lax.all_to_all(ys, "model", split_axis=0, concat_axis=0,
                              tiled=False)                    # (n_model, E_local, C, D)
        back = back.reshape(e, cap, d)

        out_my = jnp.zeros((t_slice, d), xb.dtype)
        out_my = out_my.at[sel_idx.reshape(-1)].add(
            (back * sel_w[..., None].astype(back.dtype)).reshape(-1, d))
        # Reassemble the full local token block across model ranks.
        out = jnp.zeros((t_local, d), xb.dtype)
        out = lax.dynamic_update_slice_in_dim(out, out_my, rank * t_slice, 0)
        out = lax.psum(out, "model")
        aux = lax.psum(_aux_loss(probs, topk_idx, cfg), "model") / n_model
        return out, aux

    fn = shard_map(
        block, mesh=mesh,
        in_specs=(P(), P("model"), P("model"), P("model"), P(baxes)),
        out_specs=(P(baxes), P()),
        check_vma=False)
    return fn(params["router"], params["w_gate"], params["w_up"],
              params["w_down"], x_flat)
