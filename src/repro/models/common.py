"""Shared modeling primitives: sharding helper, param definitions, dense
layers (with optional unary-backend quantized execution), norms, embeddings.

Parameters are plain pytrees (dicts of arrays).  Every parameter is declared
through a ``ParamDef`` carrying its *logical axes*; one walk materializes
init values, another produces `PartitionSpec`s for pjit — keeping init and
sharding definitions in one place (MaxText-style logical axis rules).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import packing
from repro.core.quantization import Quantized, quantize, quantize_per_row
from repro.models.config import ModelConfig

__all__ = [
    "ParamDef", "init_tree", "pspec_tree", "DEFAULT_RULES",
    "shard", "dense", "rmsnorm", "RMS_SCALE_INIT",
    "embed_lookup", "logits_from_embedding", "dtype_of",
    "activation_scaling", "activation_scale_mode",
]

# ---------------------------------------------------------------------------
# Logical axis rules
# ---------------------------------------------------------------------------

# logical axis name -> mesh axis (or tuple) — the single source of sharding
# truth.  The distribution layer can override (e.g. add "pod" to batch).
DEFAULT_RULES: dict[str, object] = {
    "batch": ("pod", "data"),
    "seq": None,
    "kv_seq": "model",        # decode-time KV cache sequence sharding
    "embed": None,
    "fsdp_embed": "data",     # embed axis when cfg.fsdp is on
    "heads": "model",
    "qkv": None,
    "kv_heads": None,          # kv heads usually < model-axis size: replicate
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "expert_mlp": None,
    "layers": None,
    "conv": None,
    "state": None,
    "lora": None,
}


def rules_for(cfg: ModelConfig) -> dict[str, object]:
    rules = dict(DEFAULT_RULES)
    if cfg.fsdp:
        rules["embed"] = "data"
    if cfg.dp_over_model:
        # archs whose heads don't divide the model axis (rwkv6: 40 heads,
        # musicgen: 24) run pure data parallelism across the WHOLE mesh
        # (batch also sharded over 'model') with FSDP for weight memory —
        # no tensor parallelism, no redundant compute.  'pod' is LAST so the
        # divisibility filter spends the global batch on data x model first
        # (batch 256 = 16 x 16 exactly; on the 512-chip mesh the pod axis
        # replicates rather than idling the model axis).
        rules["batch"] = ("data", "model", "pod")
        rules["heads"] = None
        rules["mlp"] = None
        rules["vocab"] = None
    return rules


# Thread-local logical-rule overrides (e.g. batch=() when the global batch is
# too small to shard over the data axes — long_500k has batch 1).  Entered by
# the step factories during tracing so in-model shard() calls agree with the
# jit in_shardings.
import contextlib
import threading

_TLS = threading.local()


@contextlib.contextmanager
def rule_overrides(**kw):
    prev = getattr(_TLS, "overrides", {})
    _TLS.overrides = {**prev, **kw}
    try:
        yield
    finally:
        _TLS.overrides = prev


def _active_overrides() -> dict:
    return getattr(_TLS, "overrides", {})


def shardable_batch_axes(mesh, batch_size: int,
                         candidates=("pod", "data")) -> tuple[str, ...]:
    """Longest prefix of batch axes whose product divides batch_size."""
    if isinstance(candidates, str):
        candidates = (candidates,)
    keep: list[str] = []
    prod = 1
    for a in candidates or ():
        if a in mesh.axis_names and batch_size % (prod * mesh.shape[a]) == 0:
            keep.append(a)
            prod *= mesh.shape[a]
    return tuple(keep)


def _mesh_axes_present() -> tuple[str, ...]:
    env = jax.interpreters.pxla.thread_resources.env
    mesh = env.physical_mesh
    return () if mesh.empty else tuple(mesh.axis_names)


def logical_to_pspec(logical: tuple[str | None, ...],
                     rules: dict[str, object],
                     mesh_axes: tuple[str, ...],
                     shape: tuple[int, ...] | None = None,
                     mesh_shape: dict[str, int] | None = None) -> P:
    """Map logical axis names to a PartitionSpec.

    When ``shape`` + ``mesh_shape`` are provided, mesh axes whose size does
    not divide the corresponding dim are dropped (e.g. 40 RWKV heads or 24
    musicgen heads on a 16-way model axis fall back to replication; batch=1
    long_500k cells fall back to unsharded batch).
    """
    rules = {**rules, **_active_overrides()}
    spec = []
    used: set[str] = set()
    for i, name in enumerate(logical):
        axis = rules.get(name) if name else None
        if axis is None:
            spec.append(None)
            continue
        axes = tuple(a for a in (axis if isinstance(axis, (tuple, list))
                                 else (axis,))
                     if a in mesh_axes and a not in used)
        if shape is not None and mesh_shape is not None:
            kept = []
            prod = 1
            for a in axes:
                if shape[i] % (prod * mesh_shape[a]) == 0:
                    kept.append(a)
                    prod *= mesh_shape[a]
            axes = tuple(kept)
        used.update(axes)
        if not axes:
            spec.append(None)
        elif len(axes) == 1:
            spec.append(axes[0])
        else:
            spec.append(axes)
    return P(*spec)


def shard(x: jax.Array, *logical: str | None,
          rules: dict[str, object] | None = None) -> jax.Array:
    """Constrain ``x``'s sharding by logical axis names (no-op without mesh)."""
    env = jax.interpreters.pxla.thread_resources.env
    mesh = env.physical_mesh
    if mesh.empty or not mesh.axis_names:
        return x
    rules = DEFAULT_RULES if rules is None else rules
    spec = logical_to_pspec(tuple(logical), rules, tuple(mesh.axis_names),
                            shape=tuple(x.shape),
                            mesh_shape=dict(mesh.shape))
    return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# Parameter definitions
# ---------------------------------------------------------------------------

RMS_SCALE_INIT = "ones"


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    logical: tuple[str | None, ...]
    init: str = "lecun"           # lecun | zeros | ones | normal(σ=0.02) | ssm_a | ssm_dt
    fan_in_axes: tuple[int, ...] = (0,)

    def materialize(self, key: jax.Array, dtype) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, dtype)
        if self.init == "normal":
            return (0.02 * jax.random.normal(key, self.shape)).astype(dtype)
        if self.init == "ssm_a":
            # A_log init: log of [1, 16] range over heads (Mamba2 convention);
            # broadcast across any leading (stacked-layer) axes.
            base = jnp.log(jnp.linspace(1.0, 16.0, self.shape[-1]))
            return jnp.broadcast_to(base, self.shape).astype(dtype)
        if self.init == "ssm_dt":
            # dt bias ~ softplus-inv of log-uniform dt in [1e-3, 1e-1]
            u = jax.random.uniform(key, self.shape)
            dt = jnp.exp(u * (math.log(0.1) - math.log(0.001)) + math.log(0.001))
            return jnp.log(jnp.expm1(dt)).astype(dtype)
        fan_in = 1
        for a in self.fan_in_axes:
            fan_in *= self.shape[a]
        scale = 1.0 / math.sqrt(max(fan_in, 1))
        return (scale * jax.random.normal(key, self.shape)).astype(dtype)


def init_tree(defs, key: jax.Array, dtype) -> dict:
    """Materialize a (nested dict) tree of ParamDefs with split keys."""
    leaves, treedef = jax.tree_util.tree_flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(key, len(leaves))
    vals = [d.materialize(k, dtype) for d, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def pspec_tree(defs, rules: dict[str, object], mesh_axes: tuple[str, ...],
               mesh_shape: dict[str, int] | None = None):
    return jax.tree_util.tree_map(
        lambda d: logical_to_pspec(d.logical, rules, mesh_axes,
                                   shape=d.shape, mesh_shape=mesh_shape),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# Activation quantization granularity (backend-execution scopes)
# ---------------------------------------------------------------------------

#: Granularities ``_backend_matmul`` accepts for the activation operand.
_ACT_SCALE_MODES = ("per-tensor", "per-row")


@contextlib.contextmanager
def activation_scaling(mode: str):
    """Select the activation quantization granularity for backend execution.

    ``"per-tensor"`` (default) — one absmax scale across the whole
    activation batch, the paper's INT-inference convention; co-batched rows
    share a grid, so a request's integer codes depend on its batchmates.
    ``"per-row"`` — one scale per activation row, making each co-batched
    request's codes a pure function of its own tokens (the property the
    serving engine's identical-token-stream check needs to be a *strict*
    gate under backend execution).  Read at trace time, like the backend
    scopes — trace jitted steps inside the context.
    """
    if mode not in _ACT_SCALE_MODES:
        raise ValueError(f"activation scaling mode must be one of "
                         f"{_ACT_SCALE_MODES}, got {mode!r}")
    prev = getattr(_TLS, "act_scale", "per-tensor")
    _TLS.act_scale = mode
    try:
        yield
    finally:
        _TLS.act_scale = prev


def activation_scale_mode() -> str:
    """The granularity ``_backend_matmul`` quantizes activations at now."""
    return getattr(_TLS, "act_scale", "per-tensor")


# ---------------------------------------------------------------------------
# Layers
# ---------------------------------------------------------------------------

def dense(w: jax.Array, x: jax.Array, cfg: ModelConfig | None = None,
          out_logical: tuple[str | None, ...] | None = None,
          name: str | None = None) -> jax.Array:
    """x @ w with optional unary-backend quantized execution.

    ``name`` — the weight's parameter-tree leaf key (``"wq"``, ``"w_up"``…).
    Combined with the live ``repro.backends.site_scope`` stack it forms the
    GEMM's *site name* (``"layers/attn/wq"``), which per-site backend plans
    match against; see the naming contract in ``repro.backends.runtime``.

    Execution precedence:

    1. An active ``repro.backends.use_backend(...)`` / ``use_plan(...)``
       scope — the scope names the backend for this site (a plan may name
       none, falling through to the float path); both operands are quantized
       to the backend's bit-width and the int tiles are contracted on the
       backend engine (simulator or Pallas kernel), then dequantized back to
       the activation dtype.  The scope is read at trace time; see
       ``repro.backends.runtime`` for the jit caveat.
    2. ``cfg.quant_kernel`` — the Pallas packed-integer kernel (the paper's
       PE array stand-in).  tuGEMM/tubGEMM/bGEMM are numerically identical
       (deterministic integer GEMM); uGEMM adds its stochastic multiplier
       error via the LUT path.
    3. The plain float matmul (default).
    """
    from repro.backends import runtime as backend_runtime
    execution = backend_runtime.active_execution()
    if execution is not None:
        site = backend_runtime.current_site(name)
        backend = execution.backend_for(site)
        if backend is not None:
            return _backend_matmul(execution, backend, site, w, x)
        k = w.shape[0]
        execution.observe(site, m=math.prod(x.shape[:-1]), k=k,
                          n_out=w.size // k)
        # A live scope owns execution: sites its plan leaves unmatched run
        # FLOAT (the documented contract) — never the cfg.quant_kernel path,
        # which would silently mix a second quantization scheme into the
        # plan's drift/bit-exactness evidence.
        return _plain_matmul(x, w)
    if cfg is not None and cfg.quant_bits is not None and cfg.quant_kernel:
        if packing.is_packed(w):
            raise TypeError(
                "cfg.quant_kernel re-quantizes at cfg.quant_bits, which "
                "would round already-packed codes a second time — execute "
                "packed stores under use_backend/use_plan at the store's "
                "width, or keep float parameters for the quant-kernel path")
        from repro.kernels import ops as kops
        w2 = w.reshape(w.shape[0], -1) if w.ndim > 2 else w
        wq = quantize(w2.astype(jnp.float32), bits=cfg.quant_bits)
        if cfg.quant_backend == "ugemm":
            from repro.core import gemm_sims
            xq = quantize(x.reshape(-1, x.shape[-1]).astype(jnp.float32),
                          bits=cfg.quant_bits, per_channel=False)
            out = gemm_sims.ugemm_exact(xq.values, wq.values, bits=cfg.quant_bits)
            out = (out * xq.scale * wq.scale.reshape(1, -1)).astype(x.dtype)
        else:
            out = kops.quantized_matmul(x, wq, act_bits=min(cfg.quant_bits * 2, 8))
        return out.reshape(*x.shape[:-1], *w.shape[1:])
    return _plain_matmul(x, w)


def _backend_matmul(execution, backend, site: str, w: jax.Array,
                    x: jax.Array) -> jax.Array:
    """Contract ``x @ w`` on ``backend`` (the scope's choice for ``site``)
    as integer tiles.

    Both operands are quantized at the backend's bit-width — the hardware
    units consume w-bit codes on both ports — weights per output channel,
    activations per tensor by default or per row under
    ``activation_scaling("per-row")``; the integer result is rescaled by
    both quantization scales and cast back to the activation dtype.  The
    activation streams as the temporal operand (orientation does not change
    the integer result; cycle accounting prices the weight-streamed
    schedule, see ``launch/serve.py``).

    A :class:`repro.core.packing.PackedQuantized` weight skips the weight
    quantize: its store holds exactly the codes and scales ``quantize``
    would produce at pack time, so the execute + rescale recipe below is
    bit-identical to the float-leaf path — *iff* the store's width matches
    the backend's.  A mismatch is the stale-weight hazard (the codes were
    rounded for a different grid) and raises rather than re-quantizing.
    """
    x2 = x.reshape(-1, x.shape[-1])
    if packing.is_packed(w):
        if int(w.bits) != int(backend.bits):
            raise ValueError(
                f"site {site!r}: packed store holds {w.bits}-bit codes but "
                f"the backend executes at {backend.bits}-bit — re-quantizing "
                f"packed codes at a second width compounds quantization "
                f"error; repack from the float parameters with "
                f"backends.pack_weights (packed-width-mismatch)")
        wq = w.quantized()  # exact pack-time codes (k, n) + per-channel scale
        k, n_out = w.k, w.n_out
    else:
        w2 = w.reshape(w.shape[0], -1) if w.ndim > 2 else w
        wq = quantize(w2.astype(jnp.float32), bits=backend.bits)
        k, n_out = w2.shape[0], w2.shape[1]
    if activation_scale_mode() == "per-row":
        xq = quantize_per_row(x2.astype(jnp.float32), bits=backend.bits)
    else:
        xq = quantize(x2.astype(jnp.float32), bits=backend.bits,
                      per_channel=False)
    out = backend.execute(xq.values, wq.values)
    # Apply the two dequant scales sequentially (one multiply per port)
    # rather than pre-multiplying them: the pre-product `xq.scale * wq.scale`
    # is not bit-stable under XLA when one operand chain is a baked constant
    # (a packed store's scales) and the other is computed in-graph, which
    # would break packed-vs-float bit-identity by 1-2 ulp inside scanned
    # layers.  Sequential application compiles identically for both.
    out = out.astype(jnp.float32) * xq.scale * wq.scale.reshape(1, -1)
    execution.record(site, m=x2.shape[0], k=k, n_out=n_out, backend=backend)
    return out.astype(x.dtype).reshape(*x.shape[:-1], *w.shape[1:])


def _plain_matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    if packing.is_packed(w):
        # Float path over a packed leaf (e.g. a plan leaving this site
        # unmatched): dequantize the stored codes — the only float matrix
        # the codes can honestly reconstruct.
        w = w.dequantize()
    wshape = w.shape
    w2 = w.reshape(wshape[0], -1)
    y = jnp.matmul(x, w2.astype(x.dtype))
    return y.reshape(*x.shape[:-1], *wshape[1:])


@jax.custom_vjp
def bf16_grad(x: jax.Array) -> jax.Array:
    """Identity whose cotangent is rounded through bf16.

    Placed at block boundaries so the backward tensor-parallel all-reduces of
    activation gradients run at bf16 instead of f32 (the f32 comes from the
    norm layers' f32 internals) — halves the dominant collective term of
    TP-heavy training cells (§Perf pair 2).  Gradient noise added: one bf16
    rounding per block boundary, far below optimizer noise floor.
    """
    return x


def _bf16_grad_fwd(x):
    return x, None


def _bf16_grad_bwd(_, g):
    return (g.astype(jnp.bfloat16).astype(g.dtype),)


bf16_grad.defvjp(_bf16_grad_fwd, _bf16_grad_bwd)


def rmsnorm(scale: jax.Array, x: jax.Array, eps: float = 1e-5,
            gemma_style: bool = False) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    s = scale.astype(jnp.float32)
    y = y * (1.0 + s) if gemma_style else y * s
    return y.astype(dt)


def embed_lookup(table: jax.Array, ids: jax.Array, compute_dtype) -> jax.Array:
    return jnp.take(table, ids, axis=0).astype(compute_dtype)


def logits_from_embedding(table: jax.Array, x: jax.Array,
                          softcap: float | None = None) -> jax.Array:
    logits = jnp.matmul(x, jnp.swapaxes(table.astype(x.dtype), 0, 1))
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    return logits
