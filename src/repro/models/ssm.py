"""Mamba2 (SSD — state-space duality) blocks, chunked for MXU-friendly matmuls.

The selective state-space recurrence

    h_t = exp(dt_t * A) * h_{t-1} + dt_t * (B_t ⊗ x_t)
    y_t = C_t · h_t + D * x_t

is evaluated with the chunked SSD algorithm: the sequence is split into
chunks of length Q; intra-chunk terms become (Q, Q)-masked matmuls (MXU
work), inter-chunk terms reduce to a short `lax.scan` over chunk states
(B, H, N, P).  Decode keeps the (B, H, N, P) state plus a depthwise-conv tail
buffer and costs O(1) per token — this is what makes ``long_500k`` runnable.

Layout follows Mamba2: in_proj -> [z | x | B | C | dt], depthwise causal
conv over the (x, B, C) channels, SSD core, gated RMSNorm, out_proj.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.common import ParamDef, dense, rmsnorm, shard
from repro.models.config import ModelConfig

__all__ = ["ssm_defs", "ssm_fwd", "init_ssm_cache", "ssd_chunked", "ssd_recurrent_ref"]


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    return s, d_inner, n_heads


def ssm_defs(cfg: ModelConfig) -> dict:
    """Separate projections per component (not one fused in_proj).

    A fused (D, 2*d_inner + 2GN + H) projection sharded over 'mlp' puts the
    split boundaries off the 16-way shard grid — XLA re-partitions each piece
    with thousands of masked select/slice ops inside the layer scan (measured
    ~45% of zamba2 train HBM traffic; §Perf pair 1, iteration 4).  Separate
    matrices shard each output on its natural axis; same math, same FLOPs.
    """
    s, d_inner, n_heads = _dims(cfg)
    gn = s.n_groups * s.state_dim
    return {
        "w_z": ParamDef((cfg.d_model, d_inner), ("embed", "mlp")),
        "w_x": ParamDef((cfg.d_model, d_inner), ("embed", "mlp")),
        "w_b": ParamDef((cfg.d_model, gn), ("embed", None)),
        "w_c": ParamDef((cfg.d_model, gn), ("embed", None)),
        "w_dt": ParamDef((cfg.d_model, n_heads), ("embed", "heads")),
        "conv_x_w": ParamDef((s.conv_kernel, d_inner), ("conv", "mlp")),
        "conv_x_b": ParamDef((d_inner,), ("mlp",), init="zeros"),
        "conv_bc_w": ParamDef((s.conv_kernel, 2 * gn), ("conv", None)),
        "conv_bc_b": ParamDef((2 * gn,), (None,), init="zeros"),
        "a_log": ParamDef((n_heads,), ("heads",), init="ssm_a"),
        "dt_bias": ParamDef((n_heads,), ("heads",), init="ssm_dt"),
        "d_skip": ParamDef((n_heads,), ("heads",), init="ones"),
        "norm": ParamDef((d_inner,), ("mlp",), init="ones"),
        "out_proj": ParamDef((d_inner, cfg.d_model), ("mlp", "embed")),
    }


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> dict:
    s, d_inner, n_heads = _dims(cfg)
    gn = s.n_groups * s.state_dim
    return {
        "state": jnp.zeros((batch, n_heads, s.state_dim, s.head_dim), jnp.float32),
        "conv_x": jnp.zeros((batch, s.conv_kernel - 1, d_inner), dtype),
        "conv_bc": jnp.zeros((batch, s.conv_kernel - 1, 2 * gn), dtype),
    }


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------

def ssd_recurrent_ref(x, dt, a, b, c, init_state=None):
    """Step-by-step oracle.  x:(B,S,H,P) dt:(B,S,H) a:(H,) b,c:(B,S,G,N)."""
    bs, s, h, p = x.shape
    g = b.shape[2]
    rep = h // g
    state = (jnp.zeros((bs, h, b.shape[-1], p), jnp.float32)
             if init_state is None else init_state)

    def step(state, t):
        xt, dtt = x[:, t].astype(jnp.float32), dt[:, t]
        bt = jnp.repeat(b[:, t], rep, axis=1).astype(jnp.float32)   # (B,H,N)
        ct = jnp.repeat(c[:, t], rep, axis=1).astype(jnp.float32)
        da = jnp.exp(dtt * a)                                       # (B,H)
        state = (state * da[..., None, None]
                 + jnp.einsum("bhn,bhp->bhnp", bt, xt * dtt[..., None]))
        y = jnp.einsum("bhn,bhnp->bhp", ct, state)
        return state, y

    state, ys = lax.scan(step, state, jnp.arange(s))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), state


def ssd_chunked(x, dt, a, b, c, chunk: int, init_state=None):
    """Chunked SSD.  Same signature/semantics as the oracle, O(S·Q) matmuls."""
    bs, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    rep = h // g
    if s % chunk:
        pad = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = x.shape[1]
    nc = sp // chunk

    f32 = jnp.float32
    xc = x.reshape(bs, nc, chunk, h, p).astype(f32)
    dtc = dt.reshape(bs, nc, chunk, h).astype(f32)
    bc = jnp.repeat(b.reshape(bs, nc, chunk, g, n), rep, axis=3).astype(f32)
    cc = jnp.repeat(c.reshape(bs, nc, chunk, g, n), rep, axis=3).astype(f32)

    la = dtc * a                                   # (B,C,Q,H) log-decay per step
    # inclusive cumsum as a triangular matmul: jnp.cumsum lowers to an
    # associative-scan tree of thousands of small slice/select ops inside the
    # layer scan (measured ~19% of zamba2 train HBM traffic); one (Q,Q) dot on
    # the MXU replaces it (§Perf pair 1, iteration 3).
    tril = jnp.tril(jnp.ones((chunk, chunk), f32))
    cum = jnp.einsum("qt,bcth->bcqh", tril, la)    # inclusive cumsum
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]      # (B,C,Qi,Qj,H)
    idx = jnp.arange(chunk)
    causal = (idx[:, None] >= idx[None, :])[None, None, :, :, None]
    decay = jnp.where(causal, jnp.exp(seg), 0.0)

    xdt = xc * dtc[..., None]                      # dt-weighted input
    # intra-chunk: y[i] = sum_{j<=i} (C_i.B_j) * exp(cum_i - cum_j) * xdt_j
    cb = jnp.einsum("bcihn,bcjhn->bcijh", cc, bc)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", cb * decay, xdt)

    # chunk summary state: sum_j exp(cum_last - cum_j) * B_j ⊗ xdt_j
    tail = jnp.exp(cum[:, :, -1:, :] - cum)        # (B,C,Q,H)
    chunk_state = jnp.einsum("bcjhn,bcjhp->bchnp", bc * tail[..., None], xdt)
    chunk_decay = jnp.exp(jnp.sum(la, axis=2))     # (B,C,H)

    # inter-chunk scan over chunk states
    state0 = (jnp.zeros((bs, h, n, p), f32) if init_state is None
              else init_state.astype(f32))

    def chunk_step(state, inp):
        cs, cd = inp                               # (B,H,N,P), (B,H)
        prev = state
        state = state * cd[..., None, None] + cs
        return state, prev

    final_state, prev_states = lax.scan(
        chunk_step, state0,
        (jnp.moveaxis(chunk_state, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (B,C,H,N,P)

    # inter-chunk contribution: C_i · (exp(cum_i) * state_entering_chunk)
    y_inter = jnp.einsum("bcihn,bchnp->bcihp",
                         cc * jnp.exp(cum)[..., None], prev_states)

    y = (y_intra + y_inter).reshape(bs, sp, h, p)[:, :s]
    return y.astype(x.dtype), final_state


# ---------------------------------------------------------------------------
# Full Mamba2 block
# ---------------------------------------------------------------------------

def _causal_conv(seq, conv_w, conv_b, tail=None):
    """Depthwise causal conv along seq.  seq: (B,S,C); tail: (B,K-1,C)."""
    k = conv_w.shape[0]
    if tail is None:
        tail = jnp.zeros((seq.shape[0], k - 1, seq.shape[2]), seq.dtype)
    full = jnp.concatenate([tail.astype(seq.dtype), seq], axis=1)
    out = jnp.zeros_like(seq)
    for i in range(k):
        out = out + full[:, i:i + seq.shape[1]] * conv_w[i].astype(seq.dtype)
    out = out + conv_b.astype(seq.dtype)
    new_tail = full[:, full.shape[1] - (k - 1):]
    return jax.nn.silu(out), new_tail


def ssm_fwd(params: dict, x: jax.Array, cfg: ModelConfig, *,
            cache: dict | None = None):
    """x: (B, S, D) -> (out, new_cache_or_None)."""
    s, d_inner, n_heads = _dims(cfg)
    gn = s.n_groups * s.state_dim
    z = shard(dense(params["w_z"], x, cfg, name="w_z"), "batch", None, "mlp")
    xin = shard(dense(params["w_x"], x, cfg, name="w_x"), "batch", None, "mlp")
    bc = jnp.concatenate(
        [dense(params["w_b"], x, cfg, name="w_b"), dense(params["w_c"], x, cfg, name="w_c")], axis=-1)
    dt = shard(dense(params["w_dt"], x, cfg, name="w_dt"), "batch", None, "heads")

    tail_x = cache["conv_x"] if cache is not None else None
    tail_bc = cache["conv_bc"] if cache is not None else None
    xin, new_tail_x = _causal_conv(xin, params["conv_x_w"], params["conv_x_b"],
                                   tail_x)
    bc, new_tail_bc = _causal_conv(bc, params["conv_bc_w"], params["conv_bc_b"],
                                   tail_bc)
    xin = shard(xin, "batch", None, "mlp")
    bb, cc = jnp.split(bc, [gn], axis=-1)

    bsz, slen = x.shape[0], x.shape[1]
    xh = xin.reshape(bsz, slen, n_heads, s.head_dim)
    bh = bb.reshape(bsz, slen, s.n_groups, s.state_dim)
    ch = cc.reshape(bsz, slen, s.n_groups, s.state_dim)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    dt_full = jax.nn.softplus(dt.astype(jnp.float32)
                              + params["dt_bias"].astype(jnp.float32))

    init_state = cache["state"] if cache is not None else None
    if slen == 1 and cache is not None:
        # O(1) decode step.
        rep = n_heads // s.n_groups
        xt, dtt = xh[:, 0].astype(jnp.float32), dt_full[:, 0]
        bt = jnp.repeat(bh[:, 0], rep, axis=1).astype(jnp.float32)
        ct = jnp.repeat(ch[:, 0], rep, axis=1).astype(jnp.float32)
        da = jnp.exp(dtt * a)
        state = (init_state * da[..., None, None]
                 + jnp.einsum("bhn,bhp->bhnp", bt, xt * dtt[..., None]))
        yh = jnp.einsum("bhn,bhnp->bhp", ct, state)[:, None]
        final_state = state
    else:
        yh, final_state = ssd_chunked(xh, dt_full, a, bh, ch, s.chunk,
                                      init_state=init_state)
    yh = yh + params["d_skip"].astype(yh.dtype)[None, None, :, None] * xh.astype(yh.dtype)
    y = yh.reshape(bsz, slen, d_inner).astype(x.dtype)

    y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.rms_eps)
    out = dense(params["out_proj"], y, cfg, name="out_proj")
    out = shard(out, "batch", None, None)
    new_cache = None
    if cache is not None:
        new_cache = {"state": final_state,
                     "conv_x": new_tail_x.astype(cache["conv_x"].dtype),
                     "conv_bc": new_tail_bc.astype(cache["conv_bc"].dtype)}
    return out, new_cache
