"""Model configuration covering all assigned architecture families.

One dataclass drives dense / MoE / SSM / hybrid assembly, attention flavor
(GQA vs. MLA), activation flavor, quantized-GEMM backend selection, and the
sharding/remat knobs the distribution layer consumes.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

__all__ = ["ModelConfig", "MoEConfig", "MLAConfig", "SSMConfig", "RWKVConfig"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 16
    top_k: int = 2
    num_shared_experts: int = 0      # DeepSeek-style always-on experts
    d_ff_expert: int = 2048
    capacity_factor: float = 1.25
    router_noise: float = 0.0
    # "psum" = every model-rank computes its local experts for all tokens and
    # the results are all-reduced (baseline).  "a2a" = all-to-all dispatch
    # (optimized variant, see EXPERIMENTS.md §Perf).
    ep_impl: Literal["psum", "a2a"] = "psum"


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 64          # N
    head_dim: int = 64           # P
    expand: int = 2              # d_inner = expand * d_model
    n_groups: int = 1            # B/C groups (G)
    conv_kernel: int = 4
    chunk: int = 256             # SSD chunk length
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora: int = 64         # rank of the data-dependent decay LoRA
    ffn_mult_key: float = 1.0    # channel-mix sizing handled via d_ff


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str = "custom"
    family: Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"] = "dense"

    num_layers: int = 4
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int | None = None          # default d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 1024

    attention: Literal["gqa", "mla", "none"] = "gqa"
    activation: Literal["swiglu", "geglu", "gelu"] = "swiglu"
    tie_embeddings: bool = False
    scale_embeddings: bool = False       # gemma-style sqrt(d_model) scaling
    rope_theta: float = 10000.0
    rms_eps: float = 1e-5
    logit_softcap: float | None = None   # gemma-style

    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    rwkv: RWKVConfig | None = None

    # hybrid (zamba2-style): a shared attention+MLP block applied every
    # ``hybrid_attn_every`` SSM layers with shared weights.
    hybrid_attn_every: int = 6

    # modality frontend stubs ([audio]/[vlm]): input_specs() provides
    # precomputed frame/patch embeddings of this dim instead of token ids.
    frontend_stub: bool = False

    # numerics / execution
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True

    # distribution
    fsdp: bool = False                    # shard params over the data axis too
    # keep FSDP sharding at inference?  False = replicate weights over 'data'
    # for serving (kills the per-step FSDP all-gathers) — only for models
    # that fit HBM when sharded over 'model' alone (chameleon yes, 671B no)
    fsdp_inference: bool = True
    # pure DP across the whole mesh (batch also over 'model'; no TP) — for
    # archs whose head counts don't divide the model axis (rwkv6, musicgen)
    dp_over_model: bool = False
    # quantized-GEMM backend (the paper's technique as a first-class feature)
    quant_bits: int | None = None         # None = float path
    quant_backend: str = "tubgemm"        # priced by core.ppa / accounting
    quant_kernel: bool = False            # execute via kernels.quantized_matmul

    # sub-quadratic? (drives long_500k applicability)
    @property
    def subquadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def is_moe(self) -> bool:
        return self.moe is not None

    @property
    def uses_attention(self) -> bool:
        return self.attention != "none"

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)
