"""Attention: GQA/MQA/MHA and MLA (DeepSeek), train/prefill + cached decode.

Long sequences use blockwise (flash-style, online-softmax) attention — a
double `lax.scan` over query/KV chunks — so 32k-token prefill never
materializes the full (S, S) score matrix.  Decode attends against a KV cache
whose sequence axis is sharded over the ``model`` mesh axis (flash-decoding:
XLA inserts the distributed max/sum for the partial softmax).

MLA keeps the compressed latent (c_kv, k_rope) as the cache — the ~9x cache
shrink vs. GQA is visible in the dry-run bytes — and decodes in the absorbed
form (W_uk folded into the query) so no per-head K/V are ever materialized at
decode time.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.compat import shard_map
from repro.models import rope as rope_lib
from repro.models.common import ParamDef, dense, rmsnorm, shard
from repro.models.config import ModelConfig

__all__ = [
    "gqa_defs", "mla_defs", "attention_defs",
    "init_kv_cache", "attention_fwd",
    "naive_attention", "blockwise_attention",
    "BLOCKWISE_THRESHOLD",
]

BLOCKWISE_THRESHOLD = 8192   # switch to chunked attention above this seq len
# (a 2048 threshold was tried during the zamba2 memory iteration and REFUTED:
#  XLA chunked attention still round-trips score tiles through HBM and adds
#  correction passes — measured WORSE at 4k for zamba2/chameleon/deepseek.
#  Blockwise is kept for >=8k where O(S^2) peak memory forces it; on TPU the
#  fused Pallas flash kernel takes over at every length.)
Q_CHUNK = 2048
KV_CHUNK = 2048


# ---------------------------------------------------------------------------
# Parameter definitions
# ---------------------------------------------------------------------------

def gqa_defs(cfg: ModelConfig) -> dict:
    d, h, kvh, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "wq": ParamDef((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": ParamDef((d, kvh, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamDef((d, kvh, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamDef((h, hd, d), ("heads", "head_dim", "embed"),
                       fan_in_axes=(0, 1)),
    }


def mla_defs(cfg: ModelConfig) -> dict:
    assert cfg.mla is not None
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    qk = m.nope_head_dim + m.rope_head_dim
    return {
        "w_dq": ParamDef((d, m.q_lora_rank), ("embed", "lora")),
        "q_norm": ParamDef((m.q_lora_rank,), ("lora",), init="ones"),
        "w_uq": ParamDef((m.q_lora_rank, h, qk), ("lora", "heads", "head_dim")),
        "w_dkv": ParamDef((d, m.kv_lora_rank), ("embed", "lora")),
        "kv_norm": ParamDef((m.kv_lora_rank,), ("lora",), init="ones"),
        "w_kr": ParamDef((d, m.rope_head_dim), ("embed", "head_dim")),
        "w_uk": ParamDef((m.kv_lora_rank, h, m.nope_head_dim),
                         ("lora", "heads", "head_dim")),
        "w_uv": ParamDef((m.kv_lora_rank, h, m.v_head_dim),
                         ("lora", "heads", "head_dim")),
        "wo": ParamDef((h, m.v_head_dim, d), ("heads", "head_dim", "embed"),
                       fan_in_axes=(0, 1)),
    }


def attention_defs(cfg: ModelConfig) -> dict:
    return mla_defs(cfg) if cfg.attention == "mla" else gqa_defs(cfg)


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Zeroed cache pytree for one attention layer-instance."""
    if cfg.attention == "mla":
        m = cfg.mla
        return {
            "ckv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
            "krope": jnp.zeros((batch, max_len, m.rope_head_dim), dtype),
        }
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, max_len, kvh, hd), dtype),
        "v": jnp.zeros((batch, max_len, kvh, hd), dtype),
    }


def kv_cache_pspec(cfg: ModelConfig, rules, mesh_axes):
    """Logical shardings for the cache (seq axis over 'model')."""
    from repro.models.common import logical_to_pspec as l2p
    if cfg.attention == "mla":
        return {
            "ckv": l2p(("batch", "kv_seq", None), rules, mesh_axes),
            "krope": l2p(("batch", "kv_seq", None), rules, mesh_axes),
        }
    spec = l2p(("batch", "kv_seq", None, None), rules, mesh_axes)
    return {"k": spec, "v": spec}


# ---------------------------------------------------------------------------
# Score computation
# ---------------------------------------------------------------------------

def naive_attention(q, k, v, *, causal: bool, q_offset=0,
                    kv_valid_len=None) -> jax.Array:
    """q: (B,Sq,H,D), k/v: (B,Skv,H,D) -> (B,Sq,H,Dv).  f32 softmax."""
    d = q.shape[-1]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(d))
    sq, sk = q.shape[1], k.shape[1]
    mask = None
    if causal:
        qpos = jnp.arange(sq)[:, None] + q_offset
        kpos = jnp.arange(sk)[None, :]
        mask = qpos >= kpos
    if kv_valid_len is not None:
        valid = jnp.arange(sk)[None, :] < jnp.asarray(kv_valid_len).reshape(-1, 1)
        valid = valid[:, None, None, :]  # (B,1,1,Sk)
        mask = valid if mask is None else (mask[None, None] & valid)
    elif mask is not None:
        mask = mask[None, None]
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w.astype(v.dtype), v)


def blockwise_attention(q, k, v, *, causal: bool,
                        q_chunk: int = Q_CHUNK, kv_chunk: int = KV_CHUNK) -> jax.Array:
    """Flash-style online-softmax attention; never materializes (Sq, Skv)."""
    b, sq, h, d = q.shape
    skv = k.shape[1]
    dv = v.shape[-1]
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    if sq % q_chunk or skv % kv_chunk:
        raise ValueError(f"seq lens ({sq},{skv}) must divide chunks ({q_chunk},{kv_chunk})")
    nq, nk = sq // q_chunk, skv // kv_chunk
    scale = 1.0 / jnp.sqrt(jnp.float32(d))

    qc = q.reshape(b, nq, q_chunk, h, d)
    kc = k.reshape(b, nk, kv_chunk, h, d)
    vc = v.reshape(b, nk, kv_chunk, h, dv)

    def q_step(_, qi):
        qblk = qc[:, qi]  # (B, qc, H, D)

        def kv_step(carry, ki):
            m_prev, l_prev, acc = carry
            kblk, vblk = kc[:, ki], vc[:, ki]
            s = jnp.einsum("bqhd,bkhd->bhqk", qblk, kblk).astype(jnp.float32) * scale
            if causal:
                qpos = qi * q_chunk + jnp.arange(q_chunk)[:, None]
                kpos = ki * kv_chunk + jnp.arange(kv_chunk)[None, :]
                s = jnp.where((qpos >= kpos)[None, None], s, -1e30)
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_prev - m_new)
            l_new = l_prev * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, vblk.astype(jnp.float32))
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, h, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, h, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, h, q_chunk, dv), jnp.float32)
        # causal: KV chunks beyond the diagonal contribute nothing; still
        # scanned for static shape, masked to -inf (cheap relative to matmul).
        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.transpose(0, 2, 1, 3)  # (B, qc, H, Dv)

    _, outs = lax.scan(q_step, None, jnp.arange(nq))  # (nq, B, qc, H, Dv)
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, dv).astype(v.dtype)


def _mixed_attention(q, k, v, *, causal: bool) -> jax.Array:
    """Backend-dispatching attention for full-sequence (no-cache) paths.

    TPU: the fused Pallas flash kernel (kernels/flash_attention.py) — score
    tiles stay in VMEM, HBM traffic is Q/K/V/O only.  CPU (this container):
    blockwise above BLOCKWISE_THRESHOLD, naive below (XLA cannot fuse the
    softmax(QKᵀ)V chain, so score chunks round-trip HBM either way — see
    EXPERIMENTS.md §Perf pair 1 for the measured delta the kernel removes).
    """
    if jax.default_backend() == "tpu":  # pragma: no cover - TPU path
        from repro.kernels.flash_attention import flash_attention
        return flash_attention(q, k, v, causal=causal)
    if q.shape[1] > BLOCKWISE_THRESHOLD:
        return blockwise_attention(q, k, v, causal=causal)
    return naive_attention(q, k, v, causal=causal)


def _repeat_kv(kv: jax.Array, h: int) -> jax.Array:
    kvh = kv.shape[2]
    if kvh == h:
        return kv
    return jnp.repeat(kv, h // kvh, axis=2)


def _current_mesh():
    env = jax.interpreters.pxla.thread_resources.env
    return None if env.physical_mesh.empty else env.physical_mesh


def _sharded_decode_attention(q, kc, vc, h: int, *, q_offset, kv_valid_len,
                              mesh) -> jax.Array:
    """Explicit flash-decoding over the seq-sharded KV cache (shard_map).

    XLA's SPMD partitioner will NOT distribute a softmax whose reduction axis
    is sharded — it all-gathers K/V instead (measured 2 x 34 GB per decode
    step for llama3 decode_32k).  This shard_map computes shard-local partial
    (max, sumexp, context) and combines with the log-sum-exp trick: the only
    collectives are a pmax/psum of (B, H, 1)-sized stats and the (B, H, 1, d)
    partial context — a few MB.

    q: (B, Sq, H, hd) replicated over 'model'; kc/vc: (B, Smax, KVH, hd)
    seq-sharded over 'model'.
    """
    from jax.sharding import PartitionSpec as P
    from repro.models.common import shardable_batch_axes
    baxes = shardable_batch_axes(mesh, q.shape[0], candidates=("pod", "data"))
    n_model = mesh.shape["model"]
    s_local = kc.shape[1] // n_model

    def block(qb, kb, vb, q_off, valid):
        rank = lax.axis_index("model")
        kb = _repeat_kv(kb.astype(qb.dtype), h)
        vb = _repeat_kv(vb.astype(qb.dtype), h)
        d = qb.shape[-1]
        s = jnp.einsum("bqhd,bkhd->bhqk", qb, kb).astype(jnp.float32)
        s = s / jnp.sqrt(jnp.float32(d))
        sq = qb.shape[1]
        qpos = jnp.arange(sq)[:, None] + q_off
        kpos = rank * s_local + jnp.arange(s_local)[None, :]
        mask = (qpos >= kpos) & (kpos < valid)
        s = jnp.where(mask[None, None], s, -1e30)
        m = jnp.max(s, axis=-1)                              # (B,H,Sq)
        p = jnp.exp(s - m[..., None])
        l = jnp.sum(p, axis=-1)
        ctx = jnp.einsum("bhqk,bkhd->bhqd", p.astype(vb.dtype), vb)
        m_g = lax.pmax(m, "model")
        alpha = jnp.exp(m - m_g)
        l_g = lax.psum(l * alpha, "model")
        ctx_g = lax.psum(ctx * alpha[..., None].astype(ctx.dtype), "model")
        out = ctx_g / jnp.maximum(l_g[..., None], 1e-30).astype(ctx_g.dtype)
        return out.transpose(0, 2, 1, 3)                     # (B,Sq,H,hd)

    fn = shard_map(
        block, mesh=mesh,
        in_specs=(P(baxes), P(baxes, "model"), P(baxes, "model"), P(), P()),
        out_specs=P(baxes),
        check_vma=False)
    return fn(q, kc, vc, jnp.asarray(q_offset, jnp.int32),
              jnp.asarray(kv_valid_len, jnp.int32).reshape(()))


def _update_cache(cache_arr: jax.Array, new: jax.Array, pos) -> jax.Array:
    """Write ``new`` (B, S_new, ...) into the seq axis at ``pos`` (scalar)."""
    return lax.dynamic_update_slice_in_dim(cache_arr, new.astype(cache_arr.dtype),
                                           pos, axis=1)


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def attention_fwd(params: dict, x: jax.Array, cfg: ModelConfig, *,
                  positions: jax.Array, cache: dict | None = None,
                  cache_pos=0, kv_valid_len=None):
    """Returns (out (B,S,D), new_cache_or_None)."""
    if cfg.attention == "mla":
        return _mla_fwd(params, x, cfg, positions=positions, cache=cache,
                        cache_pos=cache_pos, kv_valid_len=kv_valid_len)
    return _gqa_fwd(params, x, cfg, positions=positions, cache=cache,
                    cache_pos=cache_pos, kv_valid_len=kv_valid_len)


def _gqa_fwd(params, x, cfg, *, positions, cache, cache_pos, kv_valid_len):
    h, hd = cfg.num_heads, cfg.resolved_head_dim
    q = dense(params["wq"], x, cfg, name="wq")         # (B,S,H,hd)
    k = dense(params["wk"], x, cfg, name="wk")         # (B,S,KVH,hd)
    v = dense(params["wv"], x, cfg, name="wv")
    q = rope_lib.apply_rope(q, positions, cfg.rope_theta)
    k = rope_lib.apply_rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", None, "heads", "head_dim")

    new_cache = None
    if cache is not None:
        kc = _update_cache(cache["k"], k, cache_pos)
        vc = _update_cache(cache["v"], v, cache_pos)
        kc = shard(kc, "batch", "kv_seq", None, None)
        vc = shard(vc, "batch", "kv_seq", None, None)
        new_cache = {"k": kc, "v": vc}
        mesh = _current_mesh()
        use_flash_decode = (
            x.shape[1] == 1 and mesh is not None
            and "model" in mesh.axis_names and mesh.shape["model"] > 1
            and not cfg.dp_over_model
            and kc.shape[1] % mesh.shape["model"] == 0)
        if use_flash_decode:
            # q is tiny at decode — replicate it over 'model' and combine
            # shard-local partial softmaxes explicitly.  Leaving this to the
            # SPMD partitioner all-gathers the whole K/V cache per layer
            # (measured 2 x 34 GB/step for llama3 decode_32k; §Perf pair 3).
            q = shard(q, "batch", None, None, None)
            out = _sharded_decode_attention(
                q, kc, vc, h, q_offset=cache_pos,
                kv_valid_len=kv_valid_len if kv_valid_len is not None
                else cache_pos + 1, mesh=mesh)
        else:
            k_full = _repeat_kv(kc.astype(q.dtype), h)
            v_full = _repeat_kv(vc.astype(q.dtype), h)
            out = naive_attention(q, k_full, v_full, causal=True,
                                  q_offset=cache_pos, kv_valid_len=kv_valid_len)
    else:
        k = _repeat_kv(k, h)
        v = _repeat_kv(v, h)
        k = shard(k, "batch", None, "heads", "head_dim")
        v = shard(v, "batch", None, "heads", "head_dim")
        out = _mixed_attention(q, k, v, causal=True)
    out = shard(out, "batch", None, "heads", "head_dim")
    out = _out_proj(params, out, cfg)
    out = shard(out, "batch", None, None)
    return out, new_cache


def _out_proj(params, attn_out, cfg):
    """(B,S,H,hd) x (H,hd,D) -> (B,S,D).

    Under a backend/plan scope the contraction is routed through ``dense``
    as the flattened (H*hd, D) GEMM so the output projection is a plannable
    site (``…/attn/wo``) and contracts on the scoped engine; the float path
    keeps the original einsum (identical math, unchanged sharding).
    """
    wo = params["wo"]
    from repro.backends import runtime as backend_runtime
    if backend_runtime.active_execution() is not None:
        h, hd, d = wo.shape
        x2 = attn_out.reshape(*attn_out.shape[:-2], h * hd)
        return dense(wo.reshape(h * hd, d), x2, cfg, name="wo")
    return jnp.einsum("bshd,hde->bse", attn_out, wo.astype(attn_out.dtype))


def _mla_fwd(params, x, cfg, *, positions, cache, cache_pos, kv_valid_len):
    m = cfg.mla
    h = cfg.num_heads
    # Query path: low-rank down -> norm -> up, split nope/rope.
    cq = rmsnorm(params["q_norm"], dense(params["w_dq"], x, cfg, name="w_dq"),
                 cfg.rms_eps)
    q = dense(params["w_uq"], cq, cfg, name="w_uq")    # (B,S,H,nope+rope)
    q_nope, q_rope = jnp.split(q, [m.nope_head_dim], axis=-1)
    q_rope = rope_lib.apply_rope(q_rope, positions, cfg.rope_theta)

    # KV latent path.
    ckv = rmsnorm(params["kv_norm"],
                  dense(params["w_dkv"], x, cfg, name="w_dkv"), cfg.rms_eps)
    krope = dense(params["w_kr"], x, cfg, name="w_kr")[:, :, None, :]  # (B,S,1,rd)
    krope = rope_lib.apply_rope(krope, positions, cfg.rope_theta)[:, :, 0]

    if cache is not None:
        ckv_c = _update_cache(cache["ckv"], ckv, cache_pos)
        krope_c = _update_cache(cache["krope"], krope, cache_pos)
        ckv_c = shard(ckv_c, "batch", "kv_seq", None)
        krope_c = shard(krope_c, "batch", "kv_seq", None)
        new_cache = {"ckv": ckv_c, "krope": krope_c}
        mesh = _current_mesh()
        use_flash_decode = (
            x.shape[1] == 1 and mesh is not None
            and "model" in mesh.axis_names and mesh.shape["model"] > 1
            and not cfg.dp_over_model
            and ckv_c.shape[1] % mesh.shape["model"] == 0)
        if use_flash_decode:
            ctx_lat = _mla_sharded_decode(
                params, q_nope, q_rope, ckv_c.astype(q.dtype),
                krope_c.astype(q.dtype), cfg,
                q_offset=cache_pos,
                kv_valid_len=kv_valid_len if kv_valid_len is not None
                else cache_pos + 1, mesh=mesh)
            out = jnp.einsum("bqhr,rhv->bqhv", ctx_lat,
                             params["w_uv"].astype(ctx_lat.dtype))
        else:
            out = _mla_absorbed_attend(params, q_nope, q_rope,
                                       ckv_c.astype(q.dtype),
                                       krope_c.astype(q.dtype),
                                       cfg, kv_valid_len, q_offset=cache_pos)
    else:
        new_cache = None
        # Train/prefill: materialize per-head K/V from the latent.
        k_nope = dense(params["w_uk"], ckv, cfg, name="w_uk")  # (B,S,H,nope)
        vfull = dense(params["w_uv"], ckv, cfg, name="w_uv")   # (B,S,H,vd)
        kr = jnp.broadcast_to(krope[:, :, None, :],
                              (*krope.shape[:2], h, m.rope_head_dim))
        k = jnp.concatenate([k_nope, kr], axis=-1)
        q_all = jnp.concatenate([q_nope, q_rope], axis=-1)
        q_all = shard(q_all, "batch", None, "heads", "head_dim")
        k = shard(k, "batch", None, "heads", "head_dim")
        vfull = shard(vfull, "batch", None, "heads", "head_dim")
        out = _mixed_attention(q_all, k, vfull, causal=True)
    out = shard(out, "batch", None, "heads", "head_dim")
    out = _out_proj(params, out, cfg)
    out = shard(out, "batch", None, None)
    return out, new_cache


def _mla_sharded_decode(params, q_nope, q_rope, ckv, krope, cfg, *,
                        q_offset, kv_valid_len, mesh):
    """Flash-decoding for MLA: absorbed scoring against the seq-sharded
    latent cache inside shard_map, log-sum-exp combine (see
    _sharded_decode_attention — same SPMD-partitioner limitation).

    Returns the combined latent context (B, Sq, H, rank); the caller applies
    W_uv outside the shard_map.
    """
    from jax.sharding import PartitionSpec as P
    from repro.models.common import shardable_batch_axes
    m = cfg.mla
    d_qk = m.nope_head_dim + m.rope_head_dim
    baxes = shardable_batch_axes(mesh, q_nope.shape[0],
                                 candidates=("pod", "data"))
    n_model = mesh.shape["model"]
    s_local = ckv.shape[1] // n_model
    # absorb W_uk into the query once, outside the shard_map
    q_lat = jnp.einsum("bqhn,rhn->bqhr", q_nope,
                       params["w_uk"].astype(q_nope.dtype))

    def block(ql, qr, ckv_b, kr_b, q_off, valid):
        rank = lax.axis_index("model")
        s_lat = jnp.einsum("bqhr,bkr->bhqk", ql, ckv_b)
        s_rope = jnp.einsum("bqhd,bkd->bhqk", qr, kr_b)
        s = (s_lat + s_rope).astype(jnp.float32) / jnp.sqrt(jnp.float32(d_qk))
        sq = ql.shape[1]
        qpos = jnp.arange(sq)[:, None] + q_off
        kpos = rank * s_local + jnp.arange(s_local)[None, :]
        mask = (qpos >= kpos) & (kpos < valid)
        s = jnp.where(mask[None, None], s, -1e30)
        mx = jnp.max(s, axis=-1)
        p = jnp.exp(s - mx[..., None])
        l = jnp.sum(p, axis=-1)
        ctx = jnp.einsum("bhqk,bkr->bhqr", p.astype(ckv_b.dtype), ckv_b)
        m_g = lax.pmax(mx, "model")
        alpha = jnp.exp(mx - m_g)
        l_g = lax.psum(l * alpha, "model")
        ctx_g = lax.psum(ctx * alpha[..., None].astype(ctx.dtype), "model")
        out = ctx_g / jnp.maximum(l_g[..., None], 1e-30).astype(ctx_g.dtype)
        return out.transpose(0, 2, 1, 3)                 # (B,Sq,H,rank)

    fn = shard_map(
        block, mesh=mesh,
        in_specs=(P(baxes), P(baxes), P(baxes, "model"), P(baxes, "model"),
                  P(), P()),
        out_specs=P(baxes),
        check_vma=False)
    return fn(q_lat, q_rope, ckv, krope,
              jnp.asarray(q_offset, jnp.int32),
              jnp.asarray(kv_valid_len, jnp.int32).reshape(()))


def _mla_absorbed_attend(params, q_nope, q_rope, ckv, krope, cfg, kv_valid_len,
                         q_offset=0):
    """Absorbed-decode MLA: score and read directly in the latent space.

    scores = (q_nope @ W_uk) . ckv + q_rope . krope ;  out_h = (attn @ ckv) @ W_uv
    Cache stays (B, S, rank+rd) — no per-head K/V materialization.
    """
    m = cfg.mla
    d_qk = m.nope_head_dim + m.rope_head_dim
    # (B,Sq,H,nope) x (rank,H,nope) -> (B,Sq,H,rank)
    q_lat = jnp.einsum("bqhn,rhn->bqhr", q_nope, params["w_uk"].astype(q_nope.dtype))
    s_lat = jnp.einsum("bqhr,bkr->bhqk", q_lat, ckv)
    s_rope = jnp.einsum("bqhd,bkd->bhqk", q_rope, krope)
    scores = (s_lat + s_rope).astype(jnp.float32) / jnp.sqrt(jnp.float32(d_qk))
    sq, sk = q_nope.shape[1], ckv.shape[1]
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(sk)[None, :]
    causal = (qpos >= kpos)[None, None]
    scores = jnp.where(causal, scores, -1e30)
    if kv_valid_len is not None:
        valid = jnp.arange(sk)[None, :] < jnp.asarray(kv_valid_len).reshape(-1, 1)
        scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(ckv.dtype)
    ctx_lat = jnp.einsum("bhqk,bkr->bqhr", w, ckv)       # (B,Sq,H,rank)
    return jnp.einsum("bqhr,rhv->bqhv", ctx_lat, params["w_uv"].astype(ctx_lat.dtype))
