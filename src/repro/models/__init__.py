"""Model substrate: layers, blocks, architectures.

- config     : ModelConfig (dense / moe / ssm / hybrid / audio / vlm)
- common     : ParamDef system, sharding helper, dense/norm/embedding
- attention  : GQA + MLA, blockwise (flash-style) + cached decode
- mlp / moe  : gated MLPs; expert-parallel MoE (psum + a2a variants)
- ssm / rwkv : Mamba2 SSD and RWKV6 chunked kernels + blocks
- blocks     : per-family block assembly, scan-over-layers
- model      : end-to-end LM (forward / prefill / decode_step / loss)
"""

from repro.models import attention, blocks, common, config, mlp, model, moe, rope, rwkv, ssm
from repro.models.config import MLAConfig, ModelConfig, MoEConfig, RWKVConfig, SSMConfig

__all__ = [
    "attention", "blocks", "common", "config", "mlp", "model", "moe",
    "rope", "rwkv", "ssm",
    "MLAConfig", "ModelConfig", "MoEConfig", "RWKVConfig", "SSMConfig",
]
