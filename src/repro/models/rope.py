"""Rotary position embeddings (shared by all attention archs)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rope_freqs", "apply_rope"]


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies for the even half of the head dimension."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: (..., S, H, D) rotated by ``positions`` (..., S) or (S,)."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                        # (d/2,)
    pos = positions.astype(jnp.float32)
    angles = pos[..., None] * inv                      # (..., S, d/2)
    # broadcast over the heads axis: (..., S, 1, d/2)
    angles = angles[..., None, :]
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
