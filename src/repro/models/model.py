"""Top-level language model: embeddings -> layer stack -> norm -> logits.

Provides the three entry points the launch layer jits:
  * ``forward``       — logits for a full sequence (train / prefill)
  * ``prefill``       — forward + populated KV/state caches
  * ``decode_step``   — one token with caches (serve_step)
plus parameter/cache initialization and their `PartitionSpec` trees.

Modality frontends ([audio]/[vlm]) are stubs per the assignment: when
``cfg.frontend_stub``, ``forward`` accepts precomputed frame/patch embeddings
(B, S, D) instead of token ids (the backbone is the deliverable).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import blocks as blocks_lib
from repro.models.common import (ParamDef, dense, dtype_of, embed_lookup,
                                 init_tree, logits_from_embedding, pspec_tree,
                                 rmsnorm, rules_for, shard)
from repro.models.config import ModelConfig

__all__ = [
    "model_defs", "init_params", "param_pspecs", "cache_pspecs",
    "forward", "prefill", "decode_step", "init_caches", "loss_fn",
    "count_params", "embed_in", "logits_out",
]


def model_defs(cfg: ModelConfig) -> dict:
    defs: dict = {
        "embed": ParamDef((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                          init="normal"),
        "final_norm": ParamDef((cfg.d_model,), ("embed",), init="ones"),
        "layers": blocks_lib.stacked_layer_defs(cfg),
    }
    if cfg.family == "hybrid":
        defs["shared"] = blocks_lib.shared_attn_defs(cfg)
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((cfg.d_model, cfg.vocab_size),
                                   ("embed", "vocab"))
    return defs


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    return init_tree(model_defs(cfg), key, dtype_of(cfg.param_dtype))


def param_pspecs(cfg: ModelConfig, mesh, phase: str = "train") -> dict:
    rules = rules_for(cfg)
    if phase == "inference" and cfg.fsdp and not cfg.fsdp_inference:
        # serving layout: no FSDP — weights replicate over 'data', killing
        # the per-step weight all-gathers (§Perf pair 3 residual finding)
        rules["embed"] = None
    return pspec_tree(model_defs(cfg), rules, tuple(mesh.axis_names),
                      mesh_shape=dict(mesh.shape))


def cache_pspecs(cfg: ModelConfig, mesh, batch: int = 0, max_len: int = 0):
    """PartitionSpec tree matching init_caches (stacked leading layer axis).

    Pass the real (batch, max_len) so non-divisible dims (batch=1 long_500k)
    fall back to replication consistently with the lowered shapes.
    """
    rules = rules_for(cfg)
    axes = tuple(mesh.axis_names)
    mesh_shape = dict(mesh.shape)
    caches = jax.eval_shape(
        lambda: init_caches(cfg, batch=batch or 8, max_len=max_len or 64))

    def spec_for(path, leaf):
        names = [str(getattr(p, "key", "")) for p in path]
        if "attn" in names:
            if leaf.ndim == 4:   # (L, B, S, rank/rd) MLA latent
                logical = (None, "batch", "kv_seq", None)
            else:                 # (L, B, S, KVH, hd)
                logical = (None, "batch", "kv_seq", None, None)
        else:                     # ssm/rwkv states & conv tails: batch only
            logical = (None, "batch") + (None,) * (leaf.ndim - 2)
        from repro.models.common import logical_to_pspec
        return logical_to_pspec(logical, rules, axes, shape=tuple(leaf.shape),
                                mesh_shape=mesh_shape)

    flat = jax.tree_util.tree_flatten_with_path(caches)
    specs = [spec_for(path, leaf) for path, leaf in flat[0]]
    return jax.tree_util.tree_unflatten(flat[1], specs)


def _embed_in(params, cfg: ModelConfig, tokens=None, embeds=None):
    compute = dtype_of(cfg.compute_dtype)
    if embeds is not None:
        x = embeds.astype(compute)
    else:
        x = embed_lookup(params["embed"], tokens, compute)
    if cfg.scale_embeddings:
        x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(compute)
    return shard(x, "batch", None, None)


def _logits_out(params, cfg: ModelConfig, x):
    x = rmsnorm(params["final_norm"], x, cfg.rms_eps)
    if cfg.tie_embeddings:
        # tied head: the transposed-embedding matmul stays float (the
        # backend/plan scopes cover weight-stationary GEMM sites)
        logits = logits_from_embedding(params["embed"], x, cfg.logit_softcap)
    else:
        from repro.backends import runtime as backend_runtime
        if backend_runtime.active_execution() is not None:
            # plannable "lm_head" site under a backend/plan scope; outside
            # any scope the head keeps its historical plain-float matmul
            # (in particular it never enters the cfg.quant_kernel path)
            logits = dense(params["lm_head"], x, cfg, name="lm_head")
        else:
            logits = jnp.matmul(x, params["lm_head"].astype(x.dtype))
        if cfg.logit_softcap is not None:
            logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return shard(logits, "batch", None, "vocab")


# Public aliases: the serving engine (repro.serving.engine) drives its own
# ragged paged decode loop over the layer stack but must share the
# embedding/head math with decode_step *exactly* — its paged-vs-contiguous
# bit-exactness tests compare full logits between the two paths.
embed_in = _embed_in
logits_out = _logits_out


def forward(params: dict, cfg: ModelConfig, tokens=None, *, embeds=None,
            positions=None):
    """Full-sequence logits.  Returns (logits (B,S,V), aux_loss)."""
    x = _embed_in(params, cfg, tokens, embeds)
    if positions is None:
        positions = jnp.arange(x.shape[1])[None, :]
    x, _, aux = blocks_lib.stack_fwd(params, x, cfg, positions=positions)
    return _logits_out(params, cfg, x), aux


def init_caches(cfg: ModelConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16) -> dict:
    return blocks_lib.init_layer_caches(cfg, batch, max_len, dtype)


def prefill(params: dict, cfg: ModelConfig, tokens=None, *, caches,
            embeds=None):
    """Populate caches from a prompt.  Returns (logits, new_caches)."""
    x = _embed_in(params, cfg, tokens, embeds)
    s = x.shape[1]
    positions = jnp.arange(s)[None, :]
    x, new_caches, _ = blocks_lib.stack_fwd(
        params, x, cfg, positions=positions, caches=caches, cache_pos=0,
        kv_valid_len=jnp.full((x.shape[0],), s, jnp.int32))
    return _logits_out(params, cfg, x), new_caches


def decode_step(params: dict, cfg: ModelConfig, tokens, *, caches, cache_pos):
    """One decode step.  tokens: (B, 1); cache_pos: scalar int (shared).

    Returns (logits (B, 1, V), new_caches).
    """
    x = _embed_in(params, cfg, tokens)
    positions = jnp.full((x.shape[0], 1), cache_pos, jnp.int32)
    x, new_caches, _ = blocks_lib.stack_fwd(
        params, x, cfg, positions=positions, caches=caches,
        cache_pos=cache_pos, kv_valid_len=cache_pos + 1)
    return _logits_out(params, cfg, x), new_caches


def loss_fn(params: dict, cfg: ModelConfig, tokens, targets, *,
            aux_weight: float = 0.01, embeds=None):
    """Mean next-token cross-entropy (+ MoE aux).  targets: (B, S) int32."""
    logits, aux = forward(params, cfg, tokens, embeds=embeds)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = jnp.mean(logz - gold)
    return nll + aux_weight * aux, {"nll": nll, "aux": aux}


def count_params(params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))
