"""Gated MLPs (SwiGLU / GeGLU / GELU) with tensor-parallel sharding."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ParamDef, dense, shard
from repro.models.config import ModelConfig

__all__ = ["mlp_defs", "mlp_fwd"]


def mlp_defs(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    ff = cfg.d_ff if d_ff is None else d_ff
    defs = {
        "w_up": ParamDef((d, ff), ("embed", "mlp")),
        "w_down": ParamDef((ff, d), ("mlp", "embed")),
    }
    if cfg.activation in ("swiglu", "geglu"):
        defs["w_gate"] = ParamDef((d, ff), ("embed", "mlp"))
    return defs


def _act(cfg: ModelConfig, g: jax.Array) -> jax.Array:
    if cfg.activation == "swiglu":
        return jax.nn.silu(g)
    if cfg.activation == "geglu":
        return jax.nn.gelu(g, approximate=True)
    return jax.nn.gelu(g, approximate=True)


def mlp_fwd(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    up = dense(params["w_up"], x, cfg, name="w_up")
    up = shard(up, "batch", None, "mlp")
    if "w_gate" in params:
        gate = dense(params["w_gate"], x, cfg, name="w_gate")
        gate = shard(gate, "batch", None, "mlp")
        h = _act(cfg, gate) * up
    else:
        h = _act(cfg, up)
    out = dense(params["w_down"], h, cfg, name="w_down")
    return shard(out, "batch", None, None)
