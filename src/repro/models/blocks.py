"""Layer blocks and scan-over-layers stacking for every architecture family.

Families:
  dense / moe / audio / vlm : pre-norm attention + (MLP | MoE) blocks, scanned
  ssm (cfg.ssm set)         : Mamba2 blocks, scanned
  ssm (cfg.rwkv set)        : RWKV6 blocks, scanned
  hybrid                    : Mamba2 backbone with a *shared* attention+MLP
                              block applied every ``hybrid_attn_every`` layers
                              (Zamba2-style); grouped scan so the shared block
                              lowers exactly once per application site.

All per-layer parameters are stacked with a leading ``layers`` axis and
consumed via ``lax.scan`` — keeping HLO size (and CPU dry-run compile time)
independent of depth.  ``jax.checkpoint`` wraps the body when cfg.remat.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.backends.runtime import site_scope
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import rwkv as rwkv_lib
from repro.models import ssm as ssm_lib
from repro.models.common import ParamDef, rmsnorm, shard
from repro.models.config import ModelConfig
from repro.models.mlp import mlp_defs, mlp_fwd

__all__ = [
    "layer_defs", "stacked_layer_defs", "shared_attn_defs",
    "stack_fwd", "init_layer_caches", "hybrid_counts",
]


# ---------------------------------------------------------------------------
# Definitions
# ---------------------------------------------------------------------------

def layer_defs(cfg: ModelConfig) -> dict:
    """ParamDefs for ONE layer of the given family."""
    if cfg.family == "hybrid" or (cfg.family == "ssm" and cfg.ssm is not None):
        return {"ln": ParamDef((cfg.d_model,), ("embed",), init="ones"),
                "ssm": ssm_lib.ssm_defs(cfg)}
    if cfg.family == "ssm" and cfg.rwkv is not None:
        return rwkv_lib.rwkv_defs(cfg)
    # attention transformer
    defs = {
        "ln1": ParamDef((cfg.d_model,), ("embed",), init="ones"),
        "attn": attn_lib.attention_defs(cfg),
        "ln2": ParamDef((cfg.d_model,), ("embed",), init="ones"),
    }
    if cfg.is_moe:
        defs["moe"] = moe_lib.moe_defs(cfg)
    else:
        defs["mlp"] = mlp_defs(cfg)
    return defs


def shared_attn_defs(cfg: ModelConfig) -> dict:
    """Zamba2 shared attention+MLP block (one copy, applied at many sites)."""
    return {
        "ln1": ParamDef((cfg.d_model,), ("embed",), init="ones"),
        "attn": attn_lib.gqa_defs(cfg),
        "ln2": ParamDef((cfg.d_model,), ("embed",), init="ones"),
        "mlp": mlp_defs(cfg),
    }


def _stack_def(d: ParamDef, n: int) -> ParamDef:
    return dataclasses.replace(
        d, shape=(n, *d.shape), logical=("layers", *d.logical),
        fan_in_axes=tuple(a + 1 for a in d.fan_in_axes))


def stacked_layer_defs(cfg: ModelConfig, n: int | None = None) -> dict:
    n = cfg.num_layers if n is None else n
    return jax.tree_util.tree_map(
        lambda d: _stack_def(d, n), layer_defs(cfg),
        is_leaf=lambda x: isinstance(x, ParamDef))


def hybrid_counts(cfg: ModelConfig) -> tuple[int, int, int]:
    """(n_groups, group_size, remainder) for the hybrid grouped scan."""
    every = cfg.hybrid_attn_every
    return cfg.num_layers // every, every, cfg.num_layers % every


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _transformer_block(layer_params, x, cfg: ModelConfig, *, positions,
                       cache, cache_pos, kv_valid_len):
    h = rmsnorm(layer_params["ln1"], x, cfg.rms_eps)
    with site_scope("attn"):
        attn_out, new_cache = attn_lib.attention_fwd(
            layer_params["attn"], h, cfg, positions=positions, cache=cache,
            cache_pos=cache_pos, kv_valid_len=kv_valid_len)
    x = x + attn_out
    h = rmsnorm(layer_params["ln2"], x, cfg.rms_eps)
    if cfg.is_moe:
        with site_scope("moe"):
            out, aux = moe_lib.moe_fwd(layer_params["moe"], h, cfg)
    else:
        with site_scope("mlp"):
            out, aux = mlp_fwd(layer_params["mlp"], h, cfg), jnp.float32(0.0)
    return x + out, new_cache, aux


def _mamba_block(layer_params, x, cfg: ModelConfig, *, cache):
    h = rmsnorm(layer_params["ln"], x, cfg.rms_eps)
    with site_scope("ssm"):
        out, new_cache = ssm_lib.ssm_fwd(layer_params["ssm"], h, cfg,
                                         cache=cache)
    return x + out, new_cache


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat:
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    return fn


def stack_fwd(params: dict, x: jax.Array, cfg: ModelConfig, *,
              positions, caches: dict | None = None, cache_pos=0,
              kv_valid_len=None):
    """Run the full layer stack.  Returns (x, new_caches, aux_loss).

    ``params`` holds "layers" (stacked) and, for hybrid, "shared" +
    "layers_tail".  ``caches`` mirrors that structure with stacked caches.
    """
    if cfg.family == "hybrid":
        return _hybrid_fwd(params, x, cfg, positions=positions, caches=caches,
                           cache_pos=cache_pos, kv_valid_len=kv_valid_len)
    if cfg.family == "ssm" and cfg.rwkv is not None:
        def body(carry, xs):
            xc = carry
            lp, lc = xs
            with site_scope("layers"):
                out, nc = rwkv_lib.rwkv_block_fwd(lp, xc, cfg, cache=lc)
            return out, nc
        body = _maybe_remat(body, cfg)
        lc = caches["rwkv"] if caches is not None else None
        x, new = _scan_layers(body, x, params["layers"], lc)
        return x, ({"rwkv": new} if caches is not None else None), jnp.float32(0.0)

    if cfg.family == "ssm":
        def body(carry, xs):
            xc = carry
            lp, lc = xs
            with site_scope("layers"):
                out, nc = _mamba_block(lp, xc, cfg, cache=lc)
            return out, nc
        body = _maybe_remat(body, cfg)
        lc = caches["ssm"] if caches is not None else None
        x, new = _scan_layers(body, x, params["layers"], lc)
        return x, ({"ssm": new} if caches is not None else None), jnp.float32(0.0)

    # attention transformer (dense / moe / audio / vlm)
    def body(carry, xs):
        xc, aux = carry
        lp, lc = xs
        with site_scope("layers"):
            out, nc, a = _transformer_block(lp, xc, cfg, positions=positions,
                                            cache=lc, cache_pos=cache_pos,
                                            kv_valid_len=kv_valid_len)
        return (out, aux + a), nc
    body = _maybe_remat(body, cfg)
    lc = caches["attn"] if caches is not None else None
    (x, aux), new = _scan_layers(body, (x, jnp.float32(0.0)), params["layers"], lc)
    return x, ({"attn": new} if caches is not None else None), aux


def _scan_layers(body, carry0, stacked_params, stacked_caches):
    if stacked_caches is None:
        carry, _ = lax.scan(lambda c, p: (body(c, (p, None))[0], None),
                            carry0, stacked_params)
        return carry, None
    return lax.scan(body, carry0, (stacked_params, stacked_caches))


def _hybrid_fwd(params, x, cfg, *, positions, caches, cache_pos, kv_valid_len):
    """Grouped scan: [group_size mamba layers + shared attn] x n_groups + tail."""
    n_groups, gsize, rem = hybrid_counts(cfg)
    shared = params["shared"]
    has_cache = caches is not None

    def mamba_body(xc, xs):
        lp, lc = xs
        with site_scope("layers"):
            out, nc = _mamba_block(lp, xc, cfg, cache=lc)
        return out, nc
    mamba_body = _maybe_remat(mamba_body, cfg)

    def group_body(xc, xs):
        grp_params, grp_cache, attn_cache = xs
        if has_cache:
            xc, new_ssm = lax.scan(mamba_body, xc, (grp_params, grp_cache))
        else:
            xc, new_ssm = _scan_layers(mamba_body, xc, grp_params, None)
        h = rmsnorm(shared["ln1"], xc, cfg.rms_eps)
        with site_scope("shared"), site_scope("attn"):
            attn_out, new_attn = attn_lib.attention_fwd(
                shared["attn"], h, cfg, positions=positions, cache=attn_cache,
                cache_pos=cache_pos, kv_valid_len=kv_valid_len)
        xc = xc + attn_out
        h = rmsnorm(shared["ln2"], xc, cfg.rms_eps)
        with site_scope("shared"), site_scope("mlp"):
            xc = xc + mlp_fwd(shared["mlp"], h, cfg)
        return xc, (new_ssm, new_attn)

    # reshape stacked (L, ...) params into (n_groups, gsize, ...)
    main = jax.tree_util.tree_map(
        lambda a: a[: n_groups * gsize].reshape(n_groups, gsize, *a.shape[1:]),
        params["layers"])
    tail = jax.tree_util.tree_map(lambda a: a[n_groups * gsize:], params["layers"])

    if has_cache:
        ssm_c = jax.tree_util.tree_map(
            lambda a: a[: n_groups * gsize].reshape(n_groups, gsize, *a.shape[1:]),
            caches["ssm"])
        ssm_tail_c = jax.tree_util.tree_map(lambda a: a[n_groups * gsize:],
                                            caches["ssm"])
        attn_c = caches["attn"]
        x, (new_ssm_g, new_attn) = lax.scan(group_body, x, (main, ssm_c, attn_c))
        x, new_tail = lax.scan(mamba_body, x, (tail, ssm_tail_c)) if rem else (x, None)
        new_ssm = jax.tree_util.tree_map(
            lambda g: g.reshape(-1, *g.shape[2:]), new_ssm_g)
        if rem:
            new_ssm = jax.tree_util.tree_map(
                lambda g, t: jnp.concatenate([g, t], axis=0), new_ssm, new_tail)
        return x, {"ssm": new_ssm, "attn": new_attn}, jnp.float32(0.0)

    x, _ = lax.scan(lambda c, p: (group_body(c, (p, None, None))[0], None), x, main)
    if rem:
        x = lax.scan(lambda c, p: (mamba_body(c, (p, None))[0], None), x, tail)[0]
    return x, None, jnp.float32(0.0)


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------

def init_layer_caches(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16) -> dict:
    """Stacked caches matching stack_fwd's expectations."""
    def stack(make_one, n):
        one = make_one()
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (n, *a.shape)).copy(), one)

    if cfg.family == "hybrid":
        n_groups, _, _ = hybrid_counts(cfg)
        return {
            "ssm": stack(lambda: ssm_lib.init_ssm_cache(cfg, batch, dtype),
                         cfg.num_layers),
            "attn": stack(lambda: attn_lib.init_kv_cache(cfg, batch, max_len, dtype),
                          n_groups),
        }
    if cfg.family == "ssm" and cfg.rwkv is not None:
        return {"rwkv": stack(lambda: rwkv_lib.init_rwkv_cache(cfg, batch, dtype),
                              cfg.num_layers)}
    if cfg.family == "ssm":
        return {"ssm": stack(lambda: ssm_lib.init_ssm_cache(cfg, batch, dtype),
                             cfg.num_layers)}
    return {"attn": stack(lambda: attn_lib.init_kv_cache(cfg, batch, max_len, dtype),
                          cfg.num_layers)}
