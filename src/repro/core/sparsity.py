"""Weight/activation sparsity profiling (paper §III-B, Table V, Eq. 1).

Two statistics, exactly as the paper defines them:

* **word sparsity** — fraction of quantized values that are exactly zero.
* **bit sparsity**  — fraction of 0 slots in the temporal-unary bitstream.
  Because the paper's outer-product GEMM unit finishes a step only when the
  *largest* magnitude in the tile has streamed out ("largest value bottlenecks
  GEMM compute"), the latency-relevant bit sparsity tracks the **maximum value
  per PE-array block** (the paper uses 32x32 blocks for LLaMA2 and per-feature
  -map maxima for CNNs):

      b_spa = 1 - mean_over_blocks( max|q|_block ) / Vmax

The per-element variant (mean|q| instead of block max) is also provided — it
upper-bounds the achievable savings and is what Table V's CNN numbers (~43%)
correspond to after feature-map averaging.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.quantization import Quantized, quantize, vmax

__all__ = [
    "SparsityStats",
    "word_sparsity",
    "bit_sparsity_elementwise",
    "bit_sparsity_blockmax",
    "profile_tensor",
    "profile_tree",
    "combine_stats",
]


@dataclasses.dataclass(frozen=True)
class SparsityStats:
    """Profiled sparsity for one tensor (or an aggregate)."""

    bits: int
    word: float          # fraction of zero words
    bit_elem: float      # element-wise bit sparsity (upper bound on savings)
    bit_blockmax: float  # block-max bit sparsity (Eq. 1 input)
    numel: int

    def dynamic_fraction(self) -> float:
        """Multiplier on worst-case latency (Eq. 1): 1 - b_spa."""
        return 1.0 - self.bit_blockmax


@partial(jax.jit)
def word_sparsity(q: jax.Array) -> jax.Array:
    """Fraction of exactly-zero quantized words.

    Args: ``q`` — integer quantization codes, any shape.
    Returns: scalar float32 in [0, 1] (dimensionless fraction).
    """
    return jnp.mean((q == 0).astype(jnp.float32))


@partial(jax.jit, static_argnames=("bits",))
def bit_sparsity_elementwise(q: jax.Array, bits: int) -> jax.Array:
    """Element-level bit sparsity: ``1 - mean|q| / L``.

    Args: ``q`` — integer codes; ``bits`` — operand width w, setting the
    unary stream length ``L = 2^(w-1)`` slots (paper convention; see
    ``unary.temporal_stream_len``).
    Returns: scalar float32 in [0, 1).  Upper-bounds the achievable Eq. 1
    saving — every lane terminating at its own magnitude — and is the
    ``dyn_floor`` statistic in the serve/planner cycle reports.
    """
    L = 2 ** (bits - 1)
    return 1.0 - jnp.mean(jnp.abs(q.astype(jnp.float32))) / L


@partial(jax.jit, static_argnames=("bits", "block"))
def bit_sparsity_blockmax(q: jax.Array, bits: int, block: int = 32) -> jax.Array:
    """1 - mean(max|q| per block x block tile) / Vmax  (paper's LLM method).

    Args: ``q`` — integer codes (flattened to 2-D over the trailing axis);
    ``bits`` — operand width w (``Vmax``-equivalent stream length
    ``L = 2^(w-1)``); ``block`` — PE-array tile edge (paper uses 32).
    Returns: scalar float32 in [0, 1) — the **Eq. 1 input**: the shared slot
    schedule finishes a step only when the largest magnitude per block has
    streamed out, so this is the latency-relevant statistic.  Padded
    all-zero blocks are masked out of the mean.
    """
    L = 2 ** (bits - 1)
    x = jnp.abs(q.astype(jnp.float32))
    if x.ndim == 1:
        x = x[None, :]
    else:
        x = x.reshape(-1, x.shape[-1])
    r, c = x.shape
    pr, pc = (-r) % block, (-c) % block
    x = jnp.pad(x, ((0, pr), (0, pc)))
    x = x.reshape(x.shape[0] // block, block, x.shape[1] // block, block)
    blk_max = jnp.max(x, axis=(1, 3))
    # Padded all-zero blocks would bias the mean down; mask them out.
    nr, nc = (r + block - 1) // block, (c + block - 1) // block
    blk_max = blk_max[:nr, :nc]
    return 1.0 - jnp.mean(blk_max) / L


def profile_tensor(x: jax.Array, bits: int, block: int = 32,
                   pre_quantized: bool = False) -> SparsityStats:
    """Quantize (unless already integer codes) and profile one tensor.

    Args: ``x`` — float tensor (or integer codes with ``pre_quantized``);
    ``bits`` — operand width w ∈ {2, 4, 8}; ``block`` — block-max tile edge.
    Returns: a :class:`SparsityStats` (all statistics dimensionless
    fractions; ``numel`` the element count used for size-weighted
    aggregation).  This is the statistic the serve cost tables and the
    mixed-precision planner (``eval/planner``) feed into Eq. 1.
    """
    if pre_quantized:
        q = jnp.asarray(x, jnp.int32)
    else:
        # Per-tensor quantization, as the paper profiles (block maxima are
        # measured against the tensor-global Vmax; per-channel scales would
        # renormalize every channel to its own max and hide bit sparsity).
        q = quantize(jnp.asarray(x), bits=bits, per_channel=False).values
    return SparsityStats(
        bits=bits,
        word=float(word_sparsity(q)),
        bit_elem=float(bit_sparsity_elementwise(q, bits)),
        bit_blockmax=float(bit_sparsity_blockmax(q, bits, block)),
        numel=int(q.size),
    )


def combine_stats(stats: list[SparsityStats]) -> SparsityStats:
    """Size-weighted aggregate across tensors (a model's layers).

    Args: ``stats`` — per-tensor stats at one shared ``bits``.
    Returns: one :class:`SparsityStats` whose fractions are
    ``numel``-weighted means (Table V's per-model numbers).
    """
    if not stats:
        raise ValueError("no stats to combine")
    bits = stats[0].bits
    total = sum(s.numel for s in stats)
    w = lambda f: sum(getattr(s, f) * s.numel for s in stats) / total
    return SparsityStats(bits=bits, word=w("word"), bit_elem=w("bit_elem"),
                         bit_blockmax=w("bit_blockmax"), numel=total)


def profile_tree(params, bits: int, block: int = 32,
                 min_ndim: int = 2) -> dict[str, SparsityStats]:
    """Profile every weight matrix in a parameter pytree.

    Skips vectors (norms, biases) by default — the paper profiles GEMM
    operands (conv / FC / attention projection weights).

    Returns ``{name: SparsityStats}`` keyed by the ``"/"``-joined
    parameter-tree path (``"layers/attn/wq"``) — the same names the
    backend runtime uses as GEMM *site* names (the naming contract in
    ``repro.backends.runtime``), so these stats join directly against
    recorded workloads and backend plans.
    """
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    out: dict[str, SparsityStats] = {}
    for path, leaf in flat:
        if not hasattr(leaf, "ndim") or leaf.ndim < min_ndim:
            continue
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[name] = profile_tensor(leaf, bits=bits, block=block)
    return out
