"""INT2/4/8 symmetric quantization used by every unary/binary GEMM backend.

The paper evaluates integer GEMM units at w ∈ {2, 4, 8} bits.  We use symmetric
(zero-point-free) quantization so that the temporal-unary encodings — which
represent signed magnitudes as runs of 1s — map directly onto quantized values:

    q = clip(round(x / s), -Vmax, Vmax),   Vmax = 2^(w-1) - 1

Weights are quantized per output channel (axis=-1 of the (in, out) matrix),
activations per tensor, matching common INT-inference practice and the paper's
"quantized INT8 CNNs from torchvision" setup.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

__all__ = [
    "QuantConfig",
    "Quantized",
    "vmax",
    "quantize",
    "dequantize",
    "fake_quant",
    "quantize_per_channel",
    "quantize_per_tensor",
    "quantize_per_row",
]


def vmax(bits: int) -> int:
    """Largest representable magnitude for a signed w-bit integer (symmetric)."""
    if bits < 2:
        raise ValueError(f"bits must be >= 2, got {bits}")
    return 2 ** (bits - 1) - 1


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Static quantization parameters for one GEMM operand."""

    bits: int = 8
    per_channel: bool = True  # reduce scale over all-but-last axis
    # Percentile-free absmax calibration; stochastic rounding is off by default
    # (the paper's units consume deterministic integer operands).
    stochastic_rounding: bool = False

    @property
    def vmax(self) -> int:
        return vmax(self.bits)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Quantized:
    """A quantized tensor: integer values + float scale(s).

    ``values`` has an integer dtype (int8 container for all of w∈{2,4,8});
    ``scale`` broadcasts against ``values`` so ``values * scale ≈ original``.
    """

    values: jax.Array
    scale: jax.Array
    bits: int

    def tree_flatten(self):
        return (self.values, self.scale), (self.bits,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        values, scale = children
        return cls(values=values, scale=scale, bits=aux[0])

    def dequantize(self) -> jax.Array:
        return self.values.astype(self.scale.dtype) * self.scale

    @property
    def shape(self):
        return self.values.shape


def _absmax_scale(x: jax.Array, bits: int, axes) -> jax.Array:
    amax = jnp.max(jnp.abs(x), axis=axes, keepdims=True)
    # Avoid division by zero for all-zero channels.
    amax = jnp.maximum(amax, jnp.finfo(x.dtype).tiny)
    return amax / vmax(bits)


@partial(jax.jit, static_argnames=("bits", "per_channel", "stochastic_rounding"))
def quantize(
    x: jax.Array,
    bits: int = 8,
    per_channel: bool = True,
    stochastic_rounding: bool = False,
    rng: jax.Array | None = None,
) -> Quantized:
    """Symmetric absmax quantization to w-bit signed integers (int8 container)."""
    if per_channel and x.ndim >= 2:
        axes = tuple(range(x.ndim - 1))
    else:
        axes = tuple(range(x.ndim))
    scale = _absmax_scale(x, bits, axes)
    y = x / scale
    if stochastic_rounding:
        if rng is None:
            raise ValueError("stochastic_rounding requires rng")
        noise = jax.random.uniform(rng, x.shape, x.dtype) - 0.5
        q = jnp.floor(y + 0.5 + noise)
    else:
        q = jnp.round(y)
    q = jnp.clip(q, -vmax(bits), vmax(bits)).astype(jnp.int8)
    return Quantized(values=q, scale=scale.astype(jnp.float32), bits=bits)


def quantize_per_channel(x: jax.Array, bits: int = 8) -> Quantized:
    return quantize(x, bits=bits, per_channel=True)


def quantize_per_tensor(x: jax.Array, bits: int = 8) -> Quantized:
    return quantize(x, bits=bits, per_channel=False)


@partial(jax.jit, static_argnames=("bits",))
def quantize_per_row(x: jax.Array, bits: int = 8) -> Quantized:
    """Symmetric absmax quantization with one scale per *row* (axis=-1
    reduced).

    For a ``(rows, k)`` activation batch each row gets its own scale, so
    one row's outlier magnitude cannot coarsen another row's grid — the
    per-row option ``models/common.dense`` uses to make co-batched serve
    traffic rows independent (``quantize(per_channel=True)`` reduces over
    all-but-last axis, i.e. per *column*, which is the weight convention,
    not this).  At a single row this is exactly per-tensor quantization.
    """
    scale = _absmax_scale(x, bits, axes=(x.ndim - 1,))
    q = jnp.clip(jnp.round(x / scale), -vmax(bits), vmax(bits))
    return Quantized(values=q.astype(jnp.int8),
                     scale=scale.astype(jnp.float32), bits=bits)


def dequantize(q: Quantized) -> jax.Array:
    return q.dequantize()


@partial(jax.jit, static_argnames=("bits", "per_channel"))
def fake_quant(x: jax.Array, bits: int = 8, per_channel: bool = True) -> jax.Array:
    """Quantize-dequantize in the original dtype (QAT forward / error studies)."""
    q = quantize(x, bits=bits, per_channel=per_channel)
    return q.dequantize().astype(x.dtype)
