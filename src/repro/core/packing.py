"""Bit-packed weight stores: int2/int4/int8 codes in int32 words.

Plans assign 2/4/8 bits per GEMM site, but a float parameter leaf is
re-quantized on every call and occupies 4 bytes per element regardless of
the assigned width — the plan's bit-width never becomes a memory-traffic
saving.  This module freezes a site's weight at its planned width as a
:class:`PackedQuantized` store: the *exact* int8 codes the quantizer
produces, packed ``32 // bits`` to an int32 word, with the per-channel
scales carried alongside.

**Word layout.**  Along the packed axis (the contraction/K axis, ``-2`` of
the ``(k, n)`` weight view), each group of ``cpw = 32 // bits`` consecutive
codes forms one int32 word; code ``j`` of the group occupies bit lanes
``[j*bits, (j+1)*bits)`` — lowest lanes first, matching the byte-level
crumb/nibble order of ``repro.kernels.ops.pack_values`` and the in-kernel
unpack of ``repro.kernels.quant_gemm``.  Unpacking sign-extends with
arithmetic shifts, so the round trip is exact for every signed ``bits``-wide
code — in particular the symmetric quantizer's ``[-vmax, vmax]`` range.
Lengths that do not divide ``cpw`` are zero-padded into the last word and
truncated back on unpack.

**Scale placement.**  ``scale`` is stored verbatim from the quantizer —
per-output-channel ``(…, 1, n)`` for weights (the ``models/common.dense``
convention) or per-row ``(…, k, 1)``; it broadcasts against the unpacked
codes exactly as ``Quantized.scale`` does, so
``PackedQuantized.dequantize()`` is bit-identical to
``Quantized.dequantize()`` on the same codes.

**Grid shard packing** (``grid_x > 1``).  ``GridBackend.execute`` splits
the contraction dim into ``units_x`` ceil-sized row bands.  A grid store
packs each band's codes *separately* (``packed`` gains a leading shard
axis), so no int32 word straddles a shard boundary and every chip can
decode its own rows without touching a neighbour's words.  The
reassembled codes equal the full-weight quantization codes — the same
quantize-then-slice contract ``GridBackend.execute`` applies — so grid
execution from the packed store stays bit-identical.

**Pytree semantics.**  ``PackedQuantized`` registers as a pytree whose
static aux is invariant under leading-axis slicing: a stacked-layers store
``(L, words, n)`` scanned by ``jax.lax.scan`` yields per-layer
``(words, n)`` stores with the same ``bits`` / ``k`` / ``tail``.  The
logical ``shape`` / ``size`` / ``ndim`` accessors report the *unpacked*
weight geometry, so shape-driven code (``dense``'s observe path, site
discovery) keeps working; anything that would silently treat the store as
a float array (``np.asarray``) fails loudly instead — see
``repro.eval.planner.GemmSite.weight_matrix`` for the guarded hazard.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.quantization import Quantized, quantize

__all__ = [
    "PACK_BITS",
    "PackedQuantized",
    "codes_per_word",
    "is_packed",
    "pack_codes",
    "unpack_codes",
    "from_quantized",
    "pack_quantized",
    "packed_widths",
]

#: operand widths with a whole number of codes per int32 word
PACK_BITS = (2, 4, 8)


def codes_per_word(bits: int) -> int:
    """How many ``bits``-wide codes one int32 word holds (16 / 8 / 4)."""
    if bits not in PACK_BITS:
        raise ValueError(f"packable widths are {PACK_BITS}, got bits={bits}")
    return 32 // bits


@partial(jax.jit, static_argnames=("bits", "axis"))
def pack_codes(codes: jax.Array, bits: int, axis: int = -2) -> jax.Array:
    """Pack signed ``bits``-wide codes into int32 words along ``axis``.

    ``codes`` — any integer array whose values fit ``bits`` signed bits
    (the int8 container ``quantize`` emits).  The packed axis shrinks to
    ``ceil(len / cpw)`` words; a non-divisible length is zero-padded into
    the last word (zero codes are exact zeros on every design).  Exact
    inverse: :func:`unpack_codes` with the original length.
    """
    cpw = codes_per_word(bits)
    codes = jnp.asarray(codes)
    ax = axis % codes.ndim
    x = jnp.moveaxis(codes, ax, -1).astype(jnp.int32)
    n = x.shape[-1]
    words = -(-n // cpw)
    x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, words * cpw - n)])
    x = x.reshape(*x.shape[:-1], words, cpw)
    mask = (1 << bits) - 1
    shifts = (jnp.arange(cpw, dtype=jnp.int32) * bits).astype(jnp.int32)
    # Lanes are disjoint bit fields, so a wrapping int32 sum assembles the
    # word bit pattern exactly (the top lane may set the sign bit).
    word = jnp.sum(jnp.left_shift(jnp.bitwise_and(x, mask), shifts), axis=-1)
    return jnp.moveaxis(word.astype(jnp.int32), -1, ax)


@partial(jax.jit, static_argnames=("bits", "length", "axis"))
def unpack_codes(packed: jax.Array, bits: int, length: int,
                 axis: int = -2) -> jax.Array:
    """Exact inverse of :func:`pack_codes`: int8 codes of ``length`` along
    ``axis``, sign-extended with arithmetic shifts."""
    cpw = codes_per_word(bits)
    packed = jnp.asarray(packed)
    ax = axis % packed.ndim
    x = jnp.moveaxis(packed, ax, -1)
    # lane j: left-align its field, then arithmetic-shift down to sign-extend
    up_shift = (32 - bits * (jnp.arange(cpw, dtype=jnp.int32) + 1)).astype(
        jnp.int32)
    lanes = jnp.right_shift(jnp.left_shift(x[..., None], up_shift), 32 - bits)
    flat = lanes.reshape(*x.shape[:-1], x.shape[-1] * cpw)
    return jnp.moveaxis(flat[..., :length].astype(jnp.int8), -1, ax)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedQuantized:
    """A weight frozen at its planned width: packed int32 codes + scales.

    ``packed`` — int32 words, ``(*lead, words, n)`` (flat) or
    ``(*lead, grid_x, shard_words, n)`` (grid store); ``scale`` — the
    quantizer's float32 scales, broadcastable against the unpacked
    ``(*lead, k, n)`` codes; ``bits`` / ``k`` / ``tail`` / ``grid_x`` are
    static: operand width, logical length of the packed axis, and the
    logical trailing dims (``prod(tail) == n``) the 2-D code view folds.

    The aux data deliberately excludes leading (stack) dims so that
    ``lax.scan`` slicing a stacked store yields consistent per-layer
    stores.
    """

    packed: jax.Array
    scale: jax.Array
    bits: int
    k: int
    tail: tuple[int, ...]
    grid_x: int = 1
    #: logical dims folding to ``k`` (e.g. ``(heads, head_dim)`` for the
    #: attention out-projection); ``()`` means the single axis ``(k,)``.
    k_shape: tuple[int, ...] = ()

    def tree_flatten(self):
        return ((self.packed, self.scale),
                (self.bits, self.k, self.tail, self.grid_x, self.k_shape))

    @classmethod
    def tree_unflatten(cls, aux, children):
        packed, scale = children
        return cls(packed=packed, scale=scale, bits=aux[0], k=aux[1],
                   tail=aux[2], grid_x=aux[3], k_shape=aux[4])

    # -- logical geometry (the *unpacked* weight's) -------------------------

    @property
    def shape(self) -> tuple[int, ...]:
        lead = (self.packed.shape[:-3] if self.grid_x > 1
                else self.packed.shape[:-2])
        return (*lead, *(self.k_shape or (self.k,)), *self.tail)

    def reshape(self, *shape) -> "PackedQuantized":
        """Metadata-only regroup of the logical dims (no data movement).

        Supports the caller-side flattening ``models/attention._out_proj``
        performs (``wo.reshape(h * hd, d)``): the target must regroup the
        same elements into ``(*k_dims, *tail_dims)`` with the tail folding
        to ``n_out`` and the rest to ``k`` — the packed words and scales
        are untouched.  Only unstacked stores reshape (a stacked store is
        sliced by the scan before any per-layer reshape).
        """
        if len(shape) == 1 and isinstance(shape[0], (tuple, list)):
            shape = tuple(shape[0])
        shape = tuple(int(s) for s in shape)
        lead_ndim = (self.packed.ndim - 3 if self.grid_x > 1
                     else self.packed.ndim - 2)
        if lead_ndim:
            raise ValueError(
                f"cannot reshape a stacked packed store (lead dims "
                f"{self.packed.shape[:lead_ndim]}); slice it first")
        tail_len, prod = 0, 1
        while prod < self.n_out and tail_len < len(shape):
            tail_len += 1
            prod *= shape[len(shape) - tail_len]
        k_dims = shape[:len(shape) - tail_len]
        if prod != self.n_out or math.prod(k_dims) != self.k:
            raise ValueError(
                f"cannot reshape packed store of logical shape {self.shape} "
                f"(k={self.k}, n_out={self.n_out}) to {shape}: the target "
                f"must regroup into (k dims, tail dims) without mixing the "
                f"contraction and output axes")
        return dataclasses.replace(
            self, tail=shape[len(shape) - tail_len:],
            k_shape=() if k_dims == (self.k,) else k_dims)

    @property
    def ndim(self) -> int:
        return len(self.shape)

    @property
    def size(self) -> int:
        return math.prod(self.shape)

    @property
    def n_out(self) -> int:
        return math.prod(self.tail)

    # -- bytes accounting ---------------------------------------------------

    @property
    def stored_bytes(self) -> int:
        """Bytes the packed store actually occupies (words + scales)."""
        return int(self.packed.size) * 4 + int(
            self.scale.size) * self.scale.dtype.itemsize

    @property
    def float32_bytes(self) -> int:
        """Bytes the float32 leaf it replaced occupied."""
        return self.size * 4

    # -- decode -------------------------------------------------------------

    def codes(self) -> jax.Array:
        """The exact int8 quantizer codes, ``(*lead, k, n)``."""
        if self.grid_x > 1:
            ks = -(-self.k // self.grid_x)
            sub = unpack_codes(self.packed, self.bits, ks, axis=-2)
            full = sub.reshape(*sub.shape[:-3], self.grid_x * ks,
                               sub.shape[-1])
            return full[..., :self.k, :]
        return unpack_codes(self.packed, self.bits, self.k, axis=-2)

    def quantized(self) -> Quantized:
        """The equivalent :class:`~repro.core.quantization.Quantized` —
        what ``quantize(w, bits)`` produced before packing."""
        return Quantized(values=self.codes(), scale=self.scale,
                         bits=self.bits)

    def dequantize(self) -> jax.Array:
        """Float32 weight in the logical shape (codes × scale)."""
        dq = self.codes().astype(self.scale.dtype) * self.scale
        return dq.reshape(self.shape)


def is_packed(leaf) -> bool:
    """True iff ``leaf`` is a :class:`PackedQuantized` store (the
    ``is_leaf`` predicate every parameter-tree walk must pass so a store
    stays one leaf instead of decomposing into its children)."""
    return isinstance(leaf, PackedQuantized)


def from_quantized(q: Quantized, *, tail: tuple[int, ...] | None = None,
                   k_shape: tuple[int, ...] = (),
                   grid_x: int = 1) -> PackedQuantized:
    """Pack an existing :class:`Quantized` (codes ``(*lead, k, n)``).

    ``tail`` defaults to ``(n,)``; ``k_shape`` names the logical dims the
    packed axis folds (``()`` = the single axis); ``grid_x`` > 1 packs per
    K-band as described in the module docstring.
    """
    values = jnp.asarray(q.values)
    if values.ndim < 2:
        raise ValueError(f"packing wants (…, k, n) codes, got {values.shape}")
    k, n = int(values.shape[-2]), int(values.shape[-1])
    tail = (n,) if tail is None else tuple(int(t) for t in tail)
    if math.prod(tail) != n:
        raise ValueError(f"tail {tail} does not fold the {n} output columns")
    k_shape = tuple(int(s) for s in k_shape)
    if k_shape and math.prod(k_shape) != k:
        raise ValueError(f"k_shape {k_shape} does not fold the packed "
                         f"length {k}")
    if grid_x > 1:
        ks = -(-k // grid_x)
        pad = [(0, 0)] * (values.ndim - 2) + [(0, grid_x * ks - k), (0, 0)]
        banded = jnp.pad(values, pad).reshape(
            *values.shape[:-2], grid_x, ks, n)
        packed = pack_codes(banded, q.bits, axis=-2)
    else:
        packed = pack_codes(values, q.bits, axis=-2)
    return PackedQuantized(packed=packed, scale=jnp.asarray(q.scale),
                           bits=int(q.bits), k=k, tail=tail,
                           grid_x=int(grid_x), k_shape=k_shape)


def pack_quantized(w, *, bits: int, k: int | None = None,
                   n_out: int | None = None,
                   grid_x: int = 1) -> PackedQuantized:
    """Quantize a float leaf exactly as ``models/common.dense`` would and
    freeze the codes packed.

    ``w`` — a ``(…, k, *tail)`` float leaf (a dense weight, possibly
    stacked along leading scan axes).  ``k`` / ``n_out`` name the per-call
    contraction geometry (from the site record); they default to
    ``w.shape[0]`` / ``w.size // k`` — the unstacked case.  Each
    ``(k, n_out)`` slice is quantized per output channel with its *own*
    scales (what ``_backend_matmul`` computes per invocation), so packed
    execution is bit-identical to quantize-on-the-fly execution.
    """
    if is_packed(w):
        raise ValueError(
            f"leaf is already a PackedQuantized store at {w.bits}-bit — "
            "packing packed codes at a second width compounds quantization "
            "error; pack from the float parameters")
    w = jnp.asarray(w)
    if w.ndim < 2:
        raise ValueError(f"packing wants a >=2-D weight, got shape {w.shape}")
    k = int(w.shape[0]) if k is None else int(k)
    n_out = int(w.size) // k if n_out is None else int(n_out)
    # Split shape into (*lead, *k_dims, *tail): the trailing dims fold to
    # n_out, the middle ones to k (possibly several — e.g. the attention
    # out-projection's (heads, head_dim)), the rest are stack dims.
    tail_len, prod = 0, 1
    while prod < n_out and tail_len < w.ndim:
        tail_len += 1
        prod *= int(w.shape[w.ndim - tail_len])
    bad = prod != n_out
    k_len, kprod = 0, 1
    while not bad and kprod < k and k_len + tail_len < w.ndim:
        k_len += 1
        kprod *= int(w.shape[w.ndim - tail_len - k_len])
    lead_len = w.ndim - tail_len - k_len
    if (bad or kprod != k
            or math.prod(w.shape[:lead_len]) * k * n_out != w.size):
        raise ValueError(
            f"leaf shape {tuple(w.shape)} is not a stack of "
            f"(k={k}, n_out={n_out}) matrices")
    k_dims = tuple(int(s) for s in w.shape[lead_len:lead_len + k_len])
    tail = tuple(int(t) for t in w.shape[lead_len + k_len:])
    w3 = w.astype(jnp.float32).reshape(*w.shape[:lead_len], k, n_out)
    qfn = partial(quantize, bits=bits)
    for _ in range(lead_len):
        qfn = jax.vmap(qfn)
    q = qfn(w3)
    return from_quantized(q, tail=tail,
                          k_shape=() if k_dims == (k,) else k_dims,
                          grid_x=grid_x)


def packed_widths(params) -> dict[str, int]:
    """``{site-path: bits}`` for every packed store in ``params`` — the
    mapping plan-lint's ``packed-width-mismatch`` check consumes (site
    names equal parameter-tree paths per the runtime naming contract)."""
    flat = jax.tree_util.tree_flatten_with_path(params, is_leaf=is_packed)[0]
    out: dict[str, int] = {}
    for path, leaf in flat:
        if is_packed(leaf):
            name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                            for p in path)
            out[name] = int(leaf.bits)
    return out
