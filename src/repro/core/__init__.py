"""Core library: the paper's contribution as composable JAX modules.

- quantization  : INT2/4/8 symmetric quantization
- unary         : temporal-unary / 2-unary / rate-coded encodings
- gemm_sims     : functional + cycle-accurate simulators for the 4 GEMM units
- ppa           : calibrated Nangate45 PPA model (paper Tables I-IV)
- sparsity      : word/bit sparsity profiling (Table V, Eq. 1)
- accounting    : end-to-end DLA energy/latency pricing of model workloads
"""

from repro.core import accounting, gemm_sims, ppa, quantization, sparsity, unary
from repro.core.gemm_sims import DESIGNS, gemm, wc_cycles
from repro.core.ppa import DLAModel, PPAQuery
from repro.core.quantization import QuantConfig, Quantized, fake_quant, quantize
from repro.core.sparsity import SparsityStats, profile_tensor, profile_tree

__all__ = [
    "accounting", "gemm_sims", "ppa", "quantization", "sparsity", "unary",
    "DESIGNS", "gemm", "wc_cycles", "DLAModel", "PPAQuery",
    "QuantConfig", "Quantized", "fake_quant", "quantize",
    "SparsityStats", "profile_tensor", "profile_tree",
]
