"""Calibrated Power-Performance-Area model for the four GEMM units.

The paper's post-synthesis Tables I (area), II (power) and IV (64x64/128x128
@4-bit) are embedded verbatim as calibration data.  Energy (Table III/IV) and
ADP (Table IV) are *derived* quantities:

    energy = power * wc_cycles(design, bits, N) * CLOCK_PERIOD_NS
    ADP    = area  * wc_cycles(design, bits, N) * CLOCK_PERIOD_NS

We verified every derived entry reproduces the paper's tables (tests assert
< 1% relative error, limited only by the paper's rounding).

Off-grid queries — any (bits, n) the paper did not synthesize — use a
per-design log-log least-squares fit ``log2 x = c0 + cw*log2(w) + cn*log2(n)``
over all calibration points.  Grid hits always return the exact paper value.
The paper's Fig. 2 "slopes" are the geometric ratio per bitwidth doubling
(e.g. uGEMM power slope 1.56 = sqrt(784.4/323.8)); ``fig2_slope`` reproduces
them.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.gemm_sims import DESIGNS, wc_cycles

__all__ = [
    "CLOCK_PERIOD_NS",
    "HOP_CYCLES",
    "HOP_ENERGY_PJ_PER_BYTE",
    "AREA_UM2",
    "POWER_MW",
    "area_um2",
    "power_mw",
    "latency_ns",
    "energy_nj",
    "adp_mm2_ns",
    "fig2_slope",
    "dynamic_energy_nj",
    "PPAQuery",
    "DLAModel",
    "GridDLAModel",
]

CLOCK_PERIOD_NS = 2.5  # 400 MHz, Nangate45 (paper §III-A)

# --- Inter-chip interconnect model (GridDLAModel) ---------------------------
# The paper prices single units; composing them into a multi-chip grid adds
# link traffic the unit tables cannot see.  One hop = moving one shard-local
# operand/result tile to a neighbouring chip over a NoC-class link.  The
# constants are deliberately round figures in the range of published 2.5-D
# interposer links (~32 link cycles latency, ~10 pJ/byte including SerDes) —
# they set the *scale* of the composition overhead, not a calibrated value,
# and every grid number the repo emits carries them explicitly.
HOP_CYCLES = 32              # link latency per hop, in unit clock cycles
HOP_ENERGY_PJ_PER_BYTE = 10.0  # link energy per byte moved chip-to-chip

# --- Table I: post-synthesis cell area (um^2) --------------------------------
# key: (bits, n) ; value order follows DESIGNS = (ugemm, tugemm, tubgemm, bgemm)
AREA_UM2: dict[tuple[int, int], dict[str, float]] = {
    (2, 16): dict(ugemm=99_445.7, tugemm=13_436.4, tubgemm=19_112.6, bgemm=16_739.1),
    (2, 32): dict(ugemm=791_794.4, tugemm=52_272.4, tubgemm=76_375.5, bgemm=67_201.7),
    (4, 16): dict(ugemm=203_920.7, tugemm=29_061.0, tubgemm=38_912.6, bgemm=44_925.8),
    (4, 32): dict(ugemm=1_799_961.0, tugemm=117_261.3, tubgemm=151_933.6, bgemm=180_458.6),
    (8, 16): dict(ugemm=445_396.2, tugemm=61_064.0, tubgemm=99_916.8, bgemm=132_786.9),
    (8, 32): dict(ugemm=3_689_829.0, tugemm=235_470.9, tubgemm=338_692.7, bgemm=560_778.5),
    # Table IV (4-bit, EdgeTPU / CloudTPUv3 sizes), converted mm^2 -> um^2
    (4, 64): dict(ugemm=15.89e6, tugemm=0.46e6, tubgemm=0.59e6, bgemm=1.09e6),
    (4, 128): dict(ugemm=140.24e6, tugemm=1.83e6, tubgemm=2.41e6, bgemm=6.64e6),
}

# --- Table II: post-synthesis total power (mW) -------------------------------
POWER_MW: dict[tuple[int, int], dict[str, float]] = {
    (2, 16): dict(ugemm=42.2, tugemm=4.9, tubgemm=5.0, bgemm=7.7),
    (2, 32): dict(ugemm=323.8, tugemm=18.3, tubgemm=19.8, bgemm=30.9),
    (4, 16): dict(ugemm=64.1, tugemm=9.2, tubgemm=9.9, bgemm=22.4),
    (4, 32): dict(ugemm=513.6, tugemm=37.2, tubgemm=39.1, bgemm=88.3),
    (8, 16): dict(ugemm=100.8, tugemm=19.7, tubgemm=26.1, bgemm=72.8),
    (8, 32): dict(ugemm=784.4, tugemm=74.7, tubgemm=90.9, bgemm=321.3),
    # Table IV (4-bit)
    (4, 64): dict(ugemm=4_115.21, tugemm=145.52, tubgemm=154.42, bgemm=496.77),
    (4, 128): dict(ugemm=32_973.04, tugemm=579.28, tubgemm=620.92, bgemm=2_794.80),
}

# Paper Table III / IV reference energies (nJ) — used only by tests/benchmarks
# to validate the derived model; *not* consumed by the model itself.
PAPER_ENERGY_NJ: dict[tuple[int, int], dict[str, float]] = {
    (2, 16): dict(ugemm=0.42, tugemm=0.78, tubgemm=0.20, bgemm=0.31),
    (2, 32): dict(ugemm=3.24, tugemm=5.86, tubgemm=1.58, bgemm=2.47),
    (4, 16): dict(ugemm=2.56, tugemm=23.55, tubgemm=1.58, bgemm=0.90),
    (4, 32): dict(ugemm=20.54, tugemm=190.46, tubgemm=12.51, bgemm=7.06),
    (8, 16): dict(ugemm=64.51, tugemm=12_910.59, tubgemm=66.82, bgemm=2.91),
    (8, 32): dict(ugemm=502.02, tugemm=97_910.78, tubgemm=465.41, bgemm=25.70),
    (4, 64): dict(ugemm=164.61, tugemm=1_490.12, tubgemm=98.83, bgemm=79.48),
    (4, 128): dict(ugemm=1_318.92, tugemm=11_863.65, tubgemm=794.78, bgemm=894.34),
}

PAPER_ADP_MM2_NS: dict[tuple[int, int], dict[str, float]] = {
    (4, 64): dict(ugemm=635.6, tugemm=4_710.4, tubgemm=377.6, bgemm=174.4),
    (4, 128): dict(ugemm=5_609.6, tugemm=37_478.4, tubgemm=3_084.8, bgemm=2_124.8),
}


def _fit(table: dict[tuple[int, int], dict[str, float]], design: str):
    """Least-squares log-log fit: log2(x) = c0 + cw*log2(bits) + cn*log2(n)."""
    pts = [(b, n, vals[design]) for (b, n), vals in table.items()]
    A = np.array([[1.0, math.log2(b), math.log2(n)] for b, n, _ in pts])
    y = np.array([math.log2(v) for _, _, v in pts])
    coef, *_ = np.linalg.lstsq(A, y, rcond=None)
    return coef  # (c0, cw, cn)


_AREA_FIT = {d: _fit(AREA_UM2, d) for d in DESIGNS}
_POWER_FIT = {d: _fit(POWER_MW, d) for d in DESIGNS}


def _lookup(table, fit, design: str, bits: int, n: int) -> float:
    if design not in fit:
        # registered-but-uncalibrated designs (gemm_sims.register_design)
        # can simulate GEMMs, but pricing needs paper synthesis data
        raise ValueError(f"no PPA calibration for design {design!r}; "
                         f"paper tables cover {tuple(fit)}")
    key = (bits, n)
    if key in table:
        return table[key][design]
    c0, cw, cn = fit[design]
    return float(2.0 ** (c0 + cw * math.log2(bits) + cn * math.log2(n)))


def area_um2(design: str, bits: int, n: int) -> float:
    """Synthesized cell area of one n x n GEMM unit.

    Args: ``design`` — calibrated design name (``ugemm``/``tugemm``/
    ``tubgemm``/``bgemm``); ``bits`` — operand bit-width w; ``n`` — square
    PE-array size.
    Returns: area in **um^2** — the exact Table I value on the paper grid,
    the log-log fit off-grid.  Raises ValueError for uncalibrated designs.
    """
    return _lookup(AREA_UM2, _AREA_FIT, design, bits, n)


def power_mw(design: str, bits: int, n: int) -> float:
    """Total post-synthesis power of one n x n GEMM unit.

    Args: as :func:`area_um2`.
    Returns: power in **mW** (Table II exact on the grid, fit off-grid).
    """
    return _lookup(POWER_MW, _POWER_FIT, design, bits, n)


def latency_ns(design: str, bits: int, common_dim: int,
               bit_sparsity: float = 0.0) -> float:
    """Wall-clock latency of one GEMM on the unit.

    Args: ``design``/``bits`` as above; ``common_dim`` — the contraction
    length K the unit streams over (equals n for the paper's square GEMMs);
    ``bit_sparsity`` — fraction in [0, 1), Eq. 1 dynamic scaling (only the
    temporal designs tuGEMM/tubGEMM exploit it; others ignore it).
    Returns: latency in **ns** = cycles x ``CLOCK_PERIOD_NS`` (2.5 ns @
    400 MHz).  Not an area/power table lookup — pure cycle model.
    """
    cyc = wc_cycles(design, bits, common_dim)
    if design in ("tugemm", "tubgemm") and bit_sparsity:
        cyc = cyc * (1.0 - bit_sparsity)
    return cyc * CLOCK_PERIOD_NS


def energy_nj(design: str, bits: int, n: int, common_dim: int | None = None,
              bit_sparsity: float = 0.0) -> float:
    """Energy of one GEMM on an n x n unit: power x latency.

    Args: ``n`` — unit size (prices power); ``common_dim`` — contraction
    length K (prices latency; defaults to n, the paper's Tables III/IV
    convention); ``bit_sparsity`` — Eq. 1 scaling, 0 for worst case.
    Returns: energy in **nJ** (P[mW] x t[ns] x 1e-3).
    """
    N = n if common_dim is None else common_dim
    t_ns = latency_ns(design, bits, N, bit_sparsity)
    # P[mW] * t[ns] = 1e-12 J = 1e-3 nJ
    return power_mw(design, bits, n) * t_ns * 1e-3


def fig2_slope(table: dict, design: str, n: int = 32) -> float:
    """Paper Fig. 2 'slope': geometric ratio per bit-width doubling.

    Args: ``table`` — ``AREA_UM2`` or ``POWER_MW``; ``design`` — design name;
    ``n`` — size at which the slope is read (paper uses 32).
    Returns: dimensionless ratio ``sqrt(x(8b) / x(2b))`` — the factor the
    metric grows per 2b -> 4b -> 8b doubling.
    """
    lo, hi = table[(2, n)][design], table[(8, n)][design]
    return math.sqrt(hi / lo)


def dynamic_energy_nj(design: str, bits: int, n: int, bit_sparsity: float,
                      common_dim: int | None = None) -> float:
    """Fig. 3 right panel: workload-dependent energy via Eq. 1.

    Same args/units as :func:`energy_nj` (returns **nJ**) with
    ``bit_sparsity`` mandatory — the measured block-max weight sparsity.
    """
    return energy_nj(design, bits, n, common_dim, bit_sparsity)


def adp_mm2_ns(design: str, bits: int, n: int, common_dim: int | None = None) -> float:
    """Area-Delay Product of one GEMM on an n x n unit (Table IV).

    Args: as :func:`energy_nj` (``common_dim`` defaults to n).
    Returns: ADP in **mm^2 * ns** (area converted um^2 -> mm^2, worst-case
    latency — the paper tabulates ADP without sparsity scaling).
    """
    N = n if common_dim is None else common_dim
    return area_um2(design, bits, n) * 1e-6 * latency_ns(design, bits, N)


@dataclasses.dataclass(frozen=True)
class PPAQuery:
    """Convenience record bundling every metric for one configuration.

    Fields: ``design`` — calibrated design name; ``bits`` — operand width;
    ``n`` — square unit size.  Properties return area in mm^2, power in mW,
    worst-case latency in ns, worst-case energy in nJ and ADP in mm^2*ns.
    """

    design: str
    bits: int
    n: int

    @property
    def area_mm2(self) -> float:
        """Unit area in mm^2 (Table I um^2 value x 1e-6)."""
        return area_um2(self.design, self.bits, self.n) * 1e-6

    @property
    def power_mw(self) -> float:
        """Total power in mW (Table II)."""
        return power_mw(self.design, self.bits, self.n)

    @property
    def wc_latency_ns(self) -> float:
        """Worst-case (zero-sparsity) latency in ns, common_dim = n."""
        return latency_ns(self.design, self.bits, self.n)

    @property
    def wc_energy_nj(self) -> float:
        """Worst-case energy in nJ per GEMM, common_dim = n."""
        return energy_nj(self.design, self.bits, self.n)

    @property
    def adp(self) -> float:
        """Area-Delay Product in mm^2*ns (Table IV)."""
        return adp_mm2_ns(self.design, self.bits, self.n)


@dataclasses.dataclass(frozen=True)
class DLAModel:
    """A deep-learning accelerator built from ``num_units`` n x n GEMM units.

    Maps a (M, K, N_out) matmul onto the unit grid with the same tiling the
    Pallas kernel uses (outer-product over K inside a tile), and prices it
    with the calibrated PPA model.  ``bit_sparsity`` comes from the weight
    operand's measured block-max statistics (core.sparsity).
    """

    design: str = "tubgemm"
    bits: int = 4
    n: int = 128              # PE array size (CloudTPUv3-like default)
    num_units: int = 1
    # Per-tile cycle multiplier for designs whose slot count deviates from
    # the named design's wc_cycles formula.  The rate-coded stochastic
    # family prices as uGEMM (same datapath power, k-independent cycles)
    # scaled by stream_len / 2^bits — energy and latency are linear in
    # cycles, so one factor covers both.
    cycle_scale: float = 1.0

    def tiles(self, m: int, n_out: int) -> int:
        """Number of n x n output tiles a (m, n_out) result decomposes into."""
        return math.ceil(m / self.n) * math.ceil(n_out / self.n)

    def matmul_latency_ns(self, m: int, k: int, n_out: int,
                          bit_sparsity: float = 0.0) -> float:
        """End-to-end (m, k) @ (k, n_out) latency in **ns**: per-tile latency
        (common_dim = k, Eq. 1 scaled) x ceil(tiles / num_units) waves."""
        per_tile = latency_ns(self.design, self.bits, k, bit_sparsity) \
            * self.cycle_scale
        waves = math.ceil(self.tiles(m, n_out) / self.num_units)
        return per_tile * waves

    def matmul_energy_nj(self, m: int, k: int, n_out: int,
                         bit_sparsity: float = 0.0) -> float:
        """Total matmul energy in **nJ**: per-tile energy x tile count
        (independent of num_units — parallel units burn the same total)."""
        per_tile = energy_nj(self.design, self.bits, self.n, common_dim=k,
                             bit_sparsity=bit_sparsity) * self.cycle_scale
        return per_tile * self.tiles(m, n_out)

    @property
    def total_area_mm2(self) -> float:
        """Silicon area of the whole unit grid in **mm^2**."""
        return area_um2(self.design, self.bits, self.n) * 1e-6 * self.num_units


@dataclasses.dataclass(frozen=True)
class GridDLAModel:
    """A tensor-parallel grid of ``units_x`` × ``units_y`` DLA nodes.

    Each node is a :class:`DLAModel` (``num_units`` n×n units of ``design``
    at ``bits``).  One (M, K) @ (K, N_out) matmul is sharded the way
    ``repro.backends.grid.GridBackend.execute`` executes it: the contraction
    dim K is ceil-split ``units_x`` ways (partial sums reduced chip-to-chip),
    N_out is ceil-split ``units_y`` ways (disjoint output column slices), M
    is replicated.  Latency is the per-shard latency plus the interconnect
    critical path; energy is the per-shard energy summed over all shards plus
    the link energy of the activation fan-out and the partial-sum reduction.
    """

    design: str = "tubgemm"
    bits: int = 4
    n: int = 128
    num_units: int = 1
    units_x: int = 1          # K-dim partitions (partial-sum reduction)
    units_y: int = 1          # N-dim partitions (disjoint column slices)
    cycle_scale: float = 1.0  # see DLAModel.cycle_scale

    def __post_init__(self) -> None:
        if self.units_x < 1 or self.units_y < 1:
            raise ValueError(f"grid must be >= 1x1, got "
                             f"{self.units_x}x{self.units_y}")

    @property
    def num_shards(self) -> int:
        return self.units_x * self.units_y

    def node(self) -> DLAModel:
        """The per-shard single-chip cost model."""
        return DLAModel(design=self.design, bits=self.bits, n=self.n,
                        num_units=self.num_units,
                        cycle_scale=self.cycle_scale)

    def shard_dims(self, k: int, n_out: int) -> tuple[int, int]:
        """Per-shard (k, n_out) after the ceil-split (padded rows/cols)."""
        return (math.ceil(k / self.units_x), math.ceil(n_out / self.units_y))

    def utilization(self, m: int, k: int, n_out: int) -> float:
        """Useful MACs / padded MACs across the grid, in (0, 1].

        1.0 when ``units_x | k`` and ``units_y | n_out``; below 1.0 the
        ceil-split pads the operands with zero codes and the padded lanes
        burn cycles without contributing."""
        ks, ns = self.shard_dims(k, n_out)
        return (m * k * n_out) / (m * ks * self.units_x * ns * self.units_y)

    def hop_latency_ns(self) -> float:
        """Interconnect critical path per matmul: the activation fan-out
        across ``units_y`` columns plus the ``units_x``-chip partial-sum
        reduction, one hop each step."""
        hops = (self.units_x - 1) + (self.units_y - 1)
        return hops * HOP_CYCLES * CLOCK_PERIOD_NS

    def hop_energy_nj(self, m: int, k: int, n_out: int) -> float:
        """Link energy per matmul in **nJ**.

        Two traffic terms: every activation shard is fanned out to the other
        ``units_y - 1`` column replicas (w-bit codes), and every output
        column slice is reduced across ``units_x`` chips ((units_x - 1)
        int32 partial-tile moves).  Padded dims are what actually moves.
        """
        if self.num_shards == 1:
            return 0.0
        ks, ns = self.shard_dims(k, n_out)
        a_bytes = m * ks * self.units_x * self.bits / 8.0
        psum_bytes = m * ns * self.units_y * 4.0
        pj = ((self.units_y - 1) * a_bytes + (self.units_x - 1) * psum_bytes) \
            * HOP_ENERGY_PJ_PER_BYTE
        return pj * 1e-3

    def matmul_latency_ns(self, m: int, k: int, n_out: int,
                          bit_sparsity: float = 0.0) -> float:
        """End-to-end grid matmul latency in **ns**: all shards run in
        parallel (equal padded sizes), so per-shard latency + hop path."""
        ks, ns = self.shard_dims(k, n_out)
        return self.node().matmul_latency_ns(m, ks, ns, bit_sparsity) \
            + self.hop_latency_ns()

    def matmul_energy_nj(self, m: int, k: int, n_out: int,
                         bit_sparsity: float = 0.0) -> float:
        """Total grid matmul energy in **nJ**: per-shard compute energy
        summed over all ``units_x * units_y`` shards, plus link energy."""
        ks, ns = self.shard_dims(k, n_out)
        compute = self.node().matmul_energy_nj(m, ks, ns, bit_sparsity) \
            * self.num_shards
        return compute + self.hop_energy_nj(m, k, n_out)

    @property
    def total_area_mm2(self) -> float:
        """Silicon area of every node's unit grid in **mm^2**."""
        return self.node().total_area_mm2 * self.num_shards
