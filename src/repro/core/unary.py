"""Unary encodings: temporal-unary, 2-unary (tubGEMM), and rate-coded bitstreams.

Encoding conventions (bipolar / signed-magnitude, per the paper's non-scaled
bipolar compute):

* **temporal-unary** — a w-bit signed value ``v`` with ``|v| <= Vmax = 2^(w-1)-1``
  is a stream of ``Vmax`` slots: ``|v|`` consecutive 1s followed by 0s, plus a
  sign wire.  Exactly two signal transitions per stream → the paper's power
  argument for tu/tubGEMM.

* **2-unary (tubGEMM)** — ``|v| = 2*v1 + v0`` where ``v1`` streams over
  ``2^(w-2)`` slots with weight 2 and ``v0 ∈ {0,1}`` rides the first slot with
  weight 1.  Halves stream length vs. plain temporal-unary; still deterministic.

* **rate-unary (uGEMM)** — ``2^w`` slots; slot t is 1 iff ``ldseq(t) < p`` where
  ``p`` is the normalized magnitude and ``ldseq`` is a low-discrepancy sequence
  (van der Corput base-2 — the deterministic comparator uGEMM-style units use).
  Value is recovered as the 1s-frequency; multiplication is a slot-wise AND.

All encoders are shape-polymorphic: streams are materialized on a new leading
axis of length ``stream_len`` so downstream `lax` reductions/scan can consume
them.  These are *simulation* utilities — the fast inference path never
materializes streams; only the cycle-accurate simulators and tests do.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.quantization import vmax

__all__ = [
    "temporal_stream_len",
    "tub_stream_len",
    "rate_stream_len",
    "encode_temporal",
    "decode_temporal",
    "encode_tub",
    "decode_tub",
    "van_der_corput",
    "encode_rate",
    "decode_rate",
    "ones_count",
    "bit_sparsity_of_stream",
]


def temporal_stream_len(bits: int) -> int:
    """tuGEMM stream slots: 2^(w-1), matching the paper's latency formulas.

    Symmetric quantization uses |q| <= Vmax = 2^(w-1)-1, so the last slot is
    always 0 — the hardware still budgets the full power-of-two stream.
    """
    return 2 ** (bits - 1)


def tub_stream_len(bits: int) -> int:
    """tubGEMM 2-unary stream slots (halved via the weight-2 encoding)."""
    return max(1, 2 ** (bits - 2))


def rate_stream_len(bits: int) -> int:
    """uGEMM rate-coded stream slots."""
    return 2**bits


@partial(jax.jit, static_argnames=("bits",))
def encode_temporal(q: jax.Array, bits: int) -> tuple[jax.Array, jax.Array]:
    """q (int) -> (stream[L, ...] of 0/1, sign[...]).  L = Vmax(bits)."""
    mag = jnp.abs(q.astype(jnp.int32))
    sign = jnp.sign(q.astype(jnp.int32))
    slots = jnp.arange(temporal_stream_len(bits), dtype=jnp.int32)
    slots = slots.reshape((-1,) + (1,) * q.ndim)
    stream = (slots < mag[None]).astype(jnp.int32)
    return stream, sign


@jax.jit
def decode_temporal(stream: jax.Array, sign: jax.Array) -> jax.Array:
    return sign * jnp.sum(stream, axis=0)


@partial(jax.jit, static_argnames=("bits",))
def encode_tub(q: jax.Array, bits: int) -> tuple[jax.Array, jax.Array, jax.Array]:
    """q -> (stream2[L2,...] weight-2 slots, lsb[...] weight-1 bit, sign[...])."""
    mag = jnp.abs(q.astype(jnp.int32))
    sign = jnp.sign(q.astype(jnp.int32))
    v1 = mag // 2
    v0 = mag % 2
    slots = jnp.arange(tub_stream_len(bits), dtype=jnp.int32)
    slots = slots.reshape((-1,) + (1,) * q.ndim)
    stream2 = (slots < v1[None]).astype(jnp.int32)
    return stream2, v0, sign


@jax.jit
def decode_tub(stream2: jax.Array, lsb: jax.Array, sign: jax.Array) -> jax.Array:
    return sign * (2 * jnp.sum(stream2, axis=0) + lsb)


def van_der_corput(n: int) -> jax.Array:
    """First ``n`` points of the base-2 van der Corput low-discrepancy sequence.

    This is the deterministic "Sobol-like" comparator sequence unified-unary
    units use; it makes rate streams reproducible and near-ideally spaced.
    """
    idx = jnp.arange(n, dtype=jnp.uint32)
    # Bit-reverse a 32-bit integer, then scale to [0, 1).
    v = idx
    v = ((v >> 1) & 0x55555555) | ((v & 0x55555555) << 1)
    v = ((v >> 2) & 0x33333333) | ((v & 0x33333333) << 2)
    v = ((v >> 4) & 0x0F0F0F0F) | ((v & 0x0F0F0F0F) << 4)
    v = ((v >> 8) & 0x00FF00FF) | ((v & 0x00FF00FF) << 8)
    v = (v >> 16) | (v << 16)
    return v.astype(jnp.float64 if jax.config.read("jax_enable_x64") else jnp.float32) / jnp.float32(2**32)


@partial(jax.jit, static_argnames=("bits", "phase", "reflect"))
def encode_rate(q: jax.Array, bits: int, phase: int = 0,
                reflect: bool = False) -> tuple[jax.Array, jax.Array]:
    """q -> (rate stream[2^w, ...], sign[...]).

    Two *independent* per-port decorrelation knobs (uGEMM pairs different LD
    comparator sequences per input port):

    * ``phase`` rotates the comparator sequence by that many slots.  The slot
      *order* changes but the value multiset does not, so the 1s-count — and
      hence :func:`decode_rate` — is phase-invariant.
    * ``reflect`` mirrors the sequence (``1 - seq``), the second-port trick;
      it perturbs the count by at most one slot.

    Both may be combined for a third decorrelated port.  (An earlier revision
    silently applied *both* whenever ``phase`` was nonzero, contradicting this
    docstring — the modes are now explicit and separately testable.)
    """
    L = rate_stream_len(bits)
    mag = jnp.abs(q.astype(jnp.int32))
    p = mag.astype(jnp.float32) / jnp.float32(vmax(bits))
    seq = van_der_corput(L)
    if phase:
        seq = jnp.roll(seq, phase)
    if reflect:
        seq = 1.0 - seq
    seq = seq.reshape((-1,) + (1,) * q.ndim)
    stream = (seq < p[None]).astype(jnp.int32)
    sign = jnp.sign(q.astype(jnp.int32))
    return stream, sign


@partial(jax.jit, static_argnames=("bits",))
def decode_rate(stream: jax.Array, sign: jax.Array, bits: int) -> jax.Array:
    L = stream.shape[0]
    freq = jnp.sum(stream, axis=0).astype(jnp.float32) / jnp.float32(L)
    return sign.astype(jnp.float32) * freq * jnp.float32(vmax(bits))


@jax.jit
def ones_count(stream: jax.Array) -> jax.Array:
    return jnp.sum(stream, axis=0)


@partial(jax.jit, static_argnames=("bits", "scheme"))
def bit_sparsity_of_stream(q: jax.Array, bits: int, scheme: str = "temporal") -> jax.Array:
    """Fraction of 0 slots in the unary stream of ``q`` (paper's bit sparsity)."""
    mag = jnp.abs(q.astype(jnp.float32))
    if scheme == "temporal":
        L = temporal_stream_len(bits)
        ones = mag
    elif scheme == "tub":
        L = tub_stream_len(bits)
        ones = jnp.ceil(mag / 2.0)
    else:
        raise ValueError(f"unknown scheme {scheme!r}")
    return 1.0 - jnp.mean(ones) / L
