"""Functional + cycle-accurate simulators for the four GEMM units.

Each simulator consumes *already-quantized* integer matrices ``a: (M, K)`` and
``b: (K, N)`` (int8 container holding w-bit values) and produces the unit's
output in int32 (exact designs) or float32 (stochastic uGEMM), together with
the latency the unit would incur.

Two fidelity levels:

* ``*_exact`` — fast vectorized equivalents used by the model-level inference
  path.  For tuGEMM/tubGEMM/bGEMM the hardware is deterministic, so the exact
  functional result *is* integer GEMM; the value of the unary designs lies in
  the PPA/latency model (see ``core.ppa``), not a different numeric answer.
* ``*_stream`` — cycle-faithful stream/counter simulators built from
  ``lax.scan`` over time slots.  These exist to *prove* the functional
  equivalence claim (tests assert bit-identity with the oracle) and to model
  uGEMM's stochastic error.  They materialize streams, so use small shapes.

Latency formulas (paper §II, outer-product dataflow, ``N`` = common dim = K):

    bGEMM    : K
    uGEMM    : 2^w
    tuGEMM   : K * (2^(w-1))^2
    tubGEMM  : K * 2^(w-2)

Dynamic (sparsity-aware, Eq. 1) latency for the temporal designs scales the
worst case by the occupied fraction of the unary stream, which in hardware is
set by the *largest magnitude in the tile* (all lanes wait for the slowest
counter): ``dyn = wc * max|q| / Vmax-equivalent``.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.quantization import vmax
from repro.core import unary

__all__ = [
    "DESIGNS",
    "wc_cycles",
    "dynamic_cycles_from_sparsity",
    "dynamic_cycles_from_operand",
    "bgemm_exact",
    "tugemm_exact",
    "tubgemm_exact",
    "ugemm_exact",
    "tugemm_stream",
    "tubgemm_stream",
    "ugemm_stream",
    "gemm",
]

DESIGNS = ("ugemm", "tugemm", "tubgemm", "bgemm")


# ---------------------------------------------------------------------------
# Latency model
# ---------------------------------------------------------------------------

def wc_cycles(design: str, bits: int, common_dim: int) -> int:
    """Worst-case cycles for one (n x n x common_dim) GEMM on the unit."""
    if design == "bgemm":
        return common_dim
    if design == "ugemm":
        return 2**bits
    if design == "tugemm":
        return common_dim * (2 ** (bits - 1)) ** 2
    if design == "tubgemm":
        return common_dim * 2 ** (bits - 2)
    raise ValueError(f"unknown design {design!r}")


def dynamic_cycles_from_sparsity(design: str, bits: int, common_dim: int,
                                 bit_sparsity: float) -> float:
    """Paper Eq. 1: dynamic latency = WC latency * (1 - bit_sparsity).

    Only the temporal designs (tuGEMM, tubGEMM) exploit bit sparsity; uGEMM and
    bGEMM run at worst case regardless of operand values.
    """
    wc = wc_cycles(design, bits, common_dim)
    if design in ("tugemm", "tubgemm"):
        return wc * (1.0 - float(bit_sparsity))
    return float(wc)


def dynamic_cycles_from_operand(design: str, bits: int, q_weights) -> float:
    """Dynamic cycles for a concrete quantized operand tile.

    Early termination is gated by the largest magnitude in the tile — the
    paper's "largest value bottlenecks GEMM compute".  ``q_weights`` is the
    temporal-encoded operand, shape (K, n) or (K,) per outer-product step; we
    use the per-step max magnitude summed over steps.
    """
    q = jnp.asarray(q_weights, jnp.int32)
    if q.ndim == 1:
        q = q[:, None]
    k = q.shape[0]
    step_max = jnp.max(jnp.abs(q), axis=tuple(range(1, q.ndim)))  # (K,)
    if design == "tugemm":
        per_step = (2 ** (bits - 1)) * step_max  # outer stream gates inner full pass
        return float(jnp.sum(per_step))
    if design == "tubgemm":
        per_step = jnp.ceil(step_max / 2.0)  # 2-unary stream slots actually used
        return float(jnp.sum(jnp.maximum(per_step, 1)))
    return float(wc_cycles(design, bits, k))


# ---------------------------------------------------------------------------
# Fast functional paths
# ---------------------------------------------------------------------------

@jax.jit
def bgemm_exact(a: jax.Array, b: jax.Array) -> jax.Array:
    """Conventional binary GEMM: the int32 oracle every exact design equals."""
    return jnp.matmul(a.astype(jnp.int32), b.astype(jnp.int32),
                      preferred_element_type=jnp.int32)


def tugemm_exact(a: jax.Array, b: jax.Array) -> jax.Array:
    """tuGEMM is deterministic: functional result == integer GEMM."""
    return bgemm_exact(a, b)


def tubgemm_exact(a: jax.Array, b: jax.Array) -> jax.Array:
    """tubGEMM is deterministic: functional result == integer GEMM."""
    return bgemm_exact(a, b)


def _unified_streams(bits: int):
    """Comparator sequences of uGEMM's *unified* multiplier.

    Port A streams **temporal** (plain up-counter comparator: slot t fires iff
    ``t/L < |a|/V``); port B streams **rate** (bit-reversed / van-der-Corput
    comparator).  Counting A AND B over the 2^w slots approximates
    ``|a|*|b|*L/V^2`` with low-discrepancy error — this temporal x rate pairing
    is what makes the unified units far more accurate than rate x rate
    (measured GEMM rel-RMSE ~1.8% at 8-bit, exact at 2-bit; rate x rate is
    ~15%).  Sign-magnitude handles bipolar values; pure bipolar XNOR streams
    were evaluated and rejected (high SC variance at small magnitudes).
    """
    L = unary.rate_stream_len(bits)
    temporal = jnp.arange(L, dtype=jnp.float32) / L
    rate = unary.van_der_corput(L)
    return temporal, rate, L


@partial(jax.jit, static_argnames=("bits",))
def ugemm_exact(a: jax.Array, b: jax.Array, bits: int = 8) -> jax.Array:
    """Closed-form evaluation of the unified stream simulator.

    Fast path for model-level "run inference on a uGEMM array" studies:
    evaluates the deterministic AND-count per scalar product from a
    (V+1)x(V+1) lookup table instead of materializing (L, M, K, N) streams.
    Bit-identical to ``ugemm_stream`` — the count only depends on the two
    magnitudes and the fixed comparator sequences.
    """
    temporal, rate, L = _unified_streams(bits)
    V = vmax(bits)
    mags = jnp.arange(V + 1, dtype=jnp.int32)
    sa = (temporal[None, :] < (mags[:, None] / V)).astype(jnp.float32)  # (V+1, L)
    sb = (rate[None, :] < (mags[:, None] / V)).astype(jnp.float32)      # (V+1, L)
    counts = jnp.einsum("al,bl->ab", sa, sb)                            # (V+1, V+1)
    prod_lut = counts * (V * V / L)                                      # est of |a||b|
    ia = jnp.abs(a.astype(jnp.int32))
    ib = jnp.abs(b.astype(jnp.int32))
    est = prod_lut[ia[:, :, None], ib[None, :, :]]                       # (M, K, N)
    sgn = (jnp.sign(a.astype(jnp.int32))[:, :, None]
           * jnp.sign(b.astype(jnp.int32))[None, :, :]).astype(jnp.float32)
    return jnp.sum(est * sgn, axis=1)  # adder-tree accumulation over K is exact


# ---------------------------------------------------------------------------
# Cycle-accurate stream simulators (small shapes; tests prove equivalence)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("bits",))
def tugemm_stream(a: jax.Array, b: jax.Array, bits: int):
    """Counter-based fully-temporal GEMM.

    Hardware view: for each outer-product step k, stream a's temporal bits; for
    every 1-slot of a, replay b's full temporal stream into per-output counters.
    cycles(WC) = K * L^2 with L = 2^(w-1) slot budget.  Returns (out, cycles).
    """
    L = 2 ** (bits - 1)  # slot budget the paper's latency formula uses
    ia = jnp.abs(a.astype(jnp.int32))
    ib = jnp.abs(b.astype(jnp.int32))
    sa = jnp.sign(a.astype(jnp.int32))
    sb = jnp.sign(b.astype(jnp.int32))
    K = a.shape[1]

    def outer_step(acc, k):
        ak, sak = ia[:, k], sa[:, k]          # (M,)
        bk, sbk = ib[k, :], sb[k, :]          # (N,)

        def a_slot(acc, i):
            gate = (i < ak).astype(jnp.int32)  # (M,)

            def b_slot(acc, j):
                pulse = (j < bk).astype(jnp.int32)  # (N,)
                contrib = (gate[:, None] * pulse[None, :]
                           * (sak[:, None] * sbk[None, :]))
                return acc + contrib, None

            acc, _ = lax.scan(b_slot, acc, jnp.arange(L))
            return acc, None

        acc, _ = lax.scan(a_slot, acc, jnp.arange(L))
        return acc, None

    out0 = jnp.zeros((a.shape[0], b.shape[1]), jnp.int32)
    out, _ = lax.scan(outer_step, out0, jnp.arange(K))
    return out, K * L * L


@partial(jax.jit, static_argnames=("bits",))
def tubgemm_stream(a: jax.Array, b: jax.Array, bits: int):
    """Temporal-unary (a, 2-unary) x binary (b) hybrid GEMM.

    Hardware view: per outer-product step k, a's magnitude streams in 2-unary
    (L2 = 2^(w-2) slots, each slot worth 2), with the odd bit folded into slot
    0; b stays binary and is conditionally added into accumulators every slot.
    cycles(WC) = K * L2.  Returns (out, cycles).
    """
    L2 = max(1, 2 ** (bits - 2))
    ia = jnp.abs(a.astype(jnp.int32))
    sa = jnp.sign(a.astype(jnp.int32))
    ib = b.astype(jnp.int32)
    K = a.shape[1]

    def outer_step(acc, k):
        ak, sak = ia[:, k], sa[:, k]   # (M,)
        bk = ib[k, :]                   # (N,)
        v1, v0 = ak // 2, ak % 2

        def slot(acc, t):
            two_gate = 2 * (t < v1).astype(jnp.int32)        # weight-2 slots
            one_gate = (t == 0).astype(jnp.int32) * v0        # odd bit on slot 0
            weight = (two_gate + one_gate) * sak              # (M,)
            return acc + weight[:, None] * bk[None, :], None

        acc, _ = lax.scan(slot, acc, jnp.arange(L2))
        return acc, None

    out0 = jnp.zeros((a.shape[0], b.shape[1]), jnp.int32)
    out, _ = lax.scan(outer_step, out0, jnp.arange(K))
    return out, K * L2


@partial(jax.jit, static_argnames=("bits",))
def ugemm_stream(a: jax.Array, b: jax.Array, bits: int):
    """Unified-unary stochastic GEMM (uGEMM-style) stream simulator.

    Port A streams temporal, port B streams rate (see ``_unified_streams``);
    slot-wise AND multipliers feed signed parallel adder trees (binary
    counters — accumulation over K is exact, only the multiply is stochastic).
    Returns (float estimate, cycles = 2^w).
    """
    temporal, rate, L = _unified_streams(bits)
    V = vmax(bits)
    pa = jnp.abs(a.astype(jnp.int32)).astype(jnp.float32) / V
    pb = jnp.abs(b.astype(jnp.int32)).astype(jnp.float32) / V
    sgn_a = jnp.sign(a.astype(jnp.float32))
    sgn_b = jnp.sign(b.astype(jnp.float32))

    def body(acc, t):
        at = (temporal[t] < pa).astype(jnp.float32) * sgn_a   # (M, K)
        bt = (rate[t] < pb).astype(jnp.float32) * sgn_b        # (K, N)
        return acc + jnp.matmul(at, bt), None

    acc0 = jnp.zeros((a.shape[0], b.shape[1]), jnp.float32)
    acc, _ = lax.scan(body, acc0, jnp.arange(L))
    return acc * (V * V / L), L


# ---------------------------------------------------------------------------
# Dispatch
# ---------------------------------------------------------------------------

def gemm(design: str, a: jax.Array, b: jax.Array, bits: int = 8) -> jax.Array:
    """Fast functional GEMM under the chosen unit design."""
    if design == "bgemm":
        return bgemm_exact(a, b)
    if design == "tugemm":
        return tugemm_exact(a, b)
    if design == "tubgemm":
        return tubgemm_exact(a, b)
    if design == "ugemm":
        return ugemm_exact(a, b, bits=bits)
    raise ValueError(f"unknown design {design!r}")
